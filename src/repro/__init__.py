"""repro — SwitchAgg (in-network aggregation) as a JAX training framework."""

__version__ = "1.0.0"
