"""Deterministic synthetic data pipeline.

Restart-reproducible by construction: ``batch_at(step)`` is a pure function
of (seed, step), so a job restarted from a checkpoint at step k consumes
exactly the batches it would have seen — a fault-tolerance requirement, not
a convenience.  Token frequencies follow a Zipf(1.1) law so MoE routing and
the SwitchAgg KV benchmarks see realistic key skew (the paper uses
Zipf-0.99 workloads).

Modality stubs per the brief: vision batches carry precomputed patch
embeddings, audio batches carry frame embeddings.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf: float = 1.1


class SyntheticLMData:
    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** (-data.zipf)
        self._probs = p / p.sum()

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.data.seed, step))

    def batch_at(self, step: int) -> dict:
        cfg, d = self.cfg, self.data
        rng = self._rng(step)
        b, s = d.global_batch, d.seq_len
        toks = rng.choice(cfg.vocab_size, size=(b, s + 1), p=self._probs).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.frontend == "vision_stub":
            batch["patch_embeds"] = rng.standard_normal(
                (b, cfg.prefix_tokens, cfg.d_model), dtype=np.float32
            ) * 0.02
        elif cfg.frontend == "audio_stub":
            batch["frame_embeds"] = rng.standard_normal(
                (b, s, cfg.d_model), dtype=np.float32
            ) * 0.02
            del batch["tokens"]
        return batch

    def prompt_at(self, step: int, prompt_len: int) -> dict:
        """Serving-side prompts (for prefill/decode drivers)."""
        full = self.batch_at(step)
        out = {}
        if "tokens" in full:
            out["tokens"] = full["tokens"][:, :prompt_len]
        if "patch_embeds" in full:
            out["patch_embeds"] = full["patch_embeds"]
        if "frame_embeds" in full:
            out["frame_embeds"] = full["frame_embeds"][:, :prompt_len]
        return out
