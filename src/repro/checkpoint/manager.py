"""Mesh-agnostic, atomic, async-capable checkpointing.

Fault-tolerance properties:
  * **atomic**: writes land in ``<dir>/tmp.<step>`` and are renamed to
    ``<dir>/step_<k>`` only after the manifest (with per-leaf checksums)
    is fsynced — a crash mid-save never corrupts the latest checkpoint;
  * **mesh-agnostic**: leaves are stored as full logical arrays keyed by
    pytree path, so a restart may use a different mesh/device count
    (elastic scaling) — sharding is re-applied by the caller's specs;
  * **async**: ``save(..., blocking=False)`` hands the host copy to a
    background thread so the step loop is not blocked;
  * **self-pruning**: keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import zipfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_tree(tree, directory: str, step: int, extras: Optional[dict] = None) -> str:
    """Atomic save; returns the final path."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "extras": extras or {}, "leaves": {}}
    arr_path = os.path.join(tmp, "arrays.npz")
    np.savez(arr_path, **{k.replace("/", "__"): v for k, v in flat.items()})
    with open(arr_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    manifest["arrays_sha256"] = digest
    for k, v in flat.items():
        manifest["leaves"][k] = {"shape": list(v.shape), "dtype": str(v.dtype)}
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class CheckpointCorruptError(IOError):
    """The on-disk checkpoint is damaged (bad checksum / unparseable
    manifest or array archive) and can never restore.  Distinct from
    transient I/O or shape-mismatch errors so callers can safely delete
    *only* verified-corrupt checkpoints and fall back to older ones."""


def restore_tree(directory: str, step: Optional[int] = None):
    """Returns (flat dict {path: np.ndarray}, manifest). Verifies checksum.

    Raises :class:`CheckpointCorruptError` when the stored bytes are
    provably damaged; other failures (missing files, shape mismatches)
    keep their natural exception types."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except json.JSONDecodeError as e:
        raise CheckpointCorruptError(f"checkpoint {path} corrupt: bad manifest ({e})") from e
    arr_path = os.path.join(path, "arrays.npz")
    with open(arr_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    if digest != manifest["arrays_sha256"]:
        raise CheckpointCorruptError(f"checkpoint {path} corrupt: checksum mismatch")
    try:
        data = np.load(arr_path)
        flat = {k.replace("__", "/"): data[k] for k in data.files}
    except (zipfile.BadZipFile, ValueError) as e:
        raise CheckpointCorruptError(f"checkpoint {path} corrupt: bad archive ({e})") from e
    return flat, manifest


def unflatten_like(target_tree, flat: dict):
    """Rebuild a pytree shaped like ``target_tree`` from a flat path dict."""
    paths = jax.tree_util.tree_flatten_with_path(target_tree)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != target {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for m in (re.match(r"step_(\d+)$", d) for d in os.listdir(directory))
        if m
    ]
    return max(steps) if steps else None


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extras: Optional[dict] = None, blocking: bool = True):
        host_tree = jax.tree.map(np.asarray, tree)  # device->host before async
        self.wait()

        def work():
            try:
                save_tree(host_tree, self.directory, step, extras)
                self._prune()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self.wait()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, target_tree, step: Optional[int] = None):
        flat, manifest = restore_tree(self.directory, step)
        return unflatten_like(target_tree, flat), manifest

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _prune(self):
        steps = sorted(
            int(m.group(1))
            for m in (re.match(r"step_(\d+)$", d) for d in os.listdir(self.directory))
            if m
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
