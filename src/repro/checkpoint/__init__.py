from .manager import CheckpointCorruptError, CheckpointManager, restore_tree, save_tree

__all__ = ["CheckpointCorruptError", "CheckpointManager", "save_tree", "restore_tree"]
