"""Typed counter/gauge/histogram registry with labeled series (DESIGN.md §11).

Replaces the ad-hoc telemetry dicts scattered across the dataplane, the
two sim engines, transport, the placement planner, and the compressed
train step with one schema:

* a **metric** is a dotted name (``subsystem.noun.metric``) with a fixed
  kind — ``counter`` (monotonic, names end ``_total``), ``gauge`` (last
  value wins; unit-suffixed ``_s`` / ``_bytes`` / ``_ratio``), or
  ``histogram`` (count/sum/min/max of observations);
* a **series** is one metric plus a label set (``job``, ``level``,
  ``axis``, ``op``, ``engine``, ...).  Series are created on first use
  and keyed by the sorted label items, so publisher call-site order
  never forks a series.

Both sim engines publish through the *same* code path (the unified
report schema in ``repro.net.schema``), which is what lets the tests
assert node and vectorized runs emit bit-identical series — the parity
contract extended to telemetry.

Stdlib-only; importable from every layer without cycles.
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Callable, Iterator, Optional

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
    "scoped",
    "instrument_step",
    "InstrumentedStep",
]


class Counter:
    """Monotonic accumulator."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins value."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self):
        return self.value


class Histogram:
    """Streaming count/sum/min/max over observed values."""

    __slots__ = ("count", "sum", "min", "max")
    kind = "histogram"

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def snapshot(self):
        if not self.count:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0}
        return {"count": self.count, "sum": self.sum, "min": self.min,
                "max": self.max, "mean": self.sum / self.count}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create store of labeled metric series."""

    def __init__(self):
        # (name, ((k, v), ...)) -> metric instance
        self._series: dict = {}
        self._kind: dict = {}

    def _get(self, name: str, labels: dict, kind: str):
        known = self._kind.get(name)
        if known is None:
            self._kind[name] = kind
        elif known != kind:
            raise ValueError(
                f"metric {name!r} already registered as {known}, "
                f"requested as {kind}")
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        m = self._series.get(key)
        if m is None:
            m = self._series[key] = _KINDS[kind]()
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, labels, "counter")

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, labels, "gauge")

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(name, labels, "histogram")

    # -- reads -------------------------------------------------------------
    def value(self, name: str, **labels):
        """Snapshot of one series; KeyError if it was never published."""
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        return self._series[key].snapshot()

    def find(self, name: str) -> list:
        """All series of a metric as (labels_dict, snapshot) pairs."""
        out = []
        for (n, lk), m in sorted(self._series.items()):
            if n == name:
                out.append((dict(lk), m.snapshot()))
        return out

    def collect(self) -> list:
        """Stable-sorted dump of every series.

        Each entry: ``{"name", "kind", "labels", "value"}``.  Sorted by
        (name, labels) so two registries fed identical publishes compare
        equal with ``==`` — the engine-parity tests rely on this.
        """
        out = []
        for (name, lk), m in sorted(self._series.items()):
            out.append({"name": name, "kind": m.kind, "labels": dict(lk),
                        "value": m.snapshot()})
        return out

    def reset(self) -> None:
        self._series.clear()
        self._kind.clear()

    def to_dict(self) -> dict:
        return {"metrics": self.collect()}

    def write(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)


# -- process-wide default --------------------------------------------------

_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    global _REGISTRY
    prev, _REGISTRY = _REGISTRY, registry
    return prev


@contextlib.contextmanager
def scoped(registry: Optional[MetricsRegistry] = None
           ) -> Iterator[MetricsRegistry]:
    """Install a fresh (or given) registry for the with-block.

    Tests and parity harnesses use this to collect one run's series in
    isolation without resetting the process-wide registry.
    """
    r = MetricsRegistry() if registry is None else registry
    prev = set_registry(r)
    try:
        yield r
    finally:
        set_registry(prev)


class InstrumentedStep:
    """Callable wrapper publishing per-call count + wall-time histogram.

    Wraps a (possibly jitted) step function; attribute access is
    forwarded so ``.lower(...)`` / ``.trace(...)`` on the underlying
    ``jax.jit`` object keep working (dryrun lowers the wrapped step).
    """

    def __init__(self, fn: Callable, name: str = "train.step",
                 labels: Optional[dict] = None):
        self._fn = fn
        self._name = name
        self._labels = dict(labels or {})

    def __call__(self, *a, **kw):
        from repro.obs.trace import get_tracer
        reg = get_registry()
        t0 = time.perf_counter()
        with get_tracer().span(self._name, cat="train"):
            out = self._fn(*a, **kw)
        dt = time.perf_counter() - t0
        reg.counter(self._name + ".calls_total", **self._labels).inc()
        reg.histogram(self._name + ".wall_s", **self._labels).observe(dt)
        return out

    def __getattr__(self, item):
        return getattr(self._fn, item)


def instrument_step(fn: Callable, name: str = "train.step",
                    labels: Optional[dict] = None) -> InstrumentedStep:
    return InstrumentedStep(fn, name=name, labels=labels)
