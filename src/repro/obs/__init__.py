"""repro.obs — observability: span tracing, metrics, dashboards.

DESIGN.md §11.  Stdlib-only, so every layer (core, net, train, launch,
tools) can import it without cycles or jax.
"""

from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    InstrumentedStep,
    MetricsRegistry,
    get_registry,
    instrument_step,
    scoped,
    set_registry,
)
from repro.obs.trace import (  # noqa: F401
    Tracer,
    disable,
    enable,
    get_tracer,
    scoped_tracer,
    set_tracer,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "InstrumentedStep", "MetricsRegistry",
    "get_registry", "instrument_step", "scoped", "set_registry",
    "Tracer", "disable", "enable", "get_tracer", "scoped_tracer",
    "set_tracer",
]
