"""Hierarchical span tracing with Chrome trace-event export (DESIGN.md §11).

A process-wide :class:`Tracer` records *spans* — named intervals with a
category and optional args — and exports them as Chrome trace-event JSON
(the ``{"traceEvents": [...]}`` dict format) that loads directly in
Perfetto / ``chrome://tracing``.

Two kinds of time coexist in one trace:

* **wall clock** (pid 0): ``tracer.span(...)`` context-managers measure
  host time via ``perf_counter`` — planner searches, jit lowering,
  bench cells.
* **simulated time** (one pid per sim run, allocated with
  :meth:`Tracer.new_track`): the network simulator replays its virtual
  clock as explicit ``add_span(name, t0_s, t1_s)`` calls, so a
  simulated job renders as a timeline of per-level ingest /
  transport-drain lanes even though the whole thing executed in
  milliseconds of host time.

Timestamps are exported in microseconds (the trace-event unit);
fractional values are allowed and preserved.

Zero overhead when disabled: ``span()`` returns a module-level no-op
singleton without allocating, and ``add_span``/``instant`` return before
touching any state.  ``tests/test_obs.py`` pins both the zero-entry and
the zero-allocation behaviour; ``bench_sim.py``'s ``obs_overhead`` cell
floor-gates the throughput of the disabled path in CI.

Stdlib-only on purpose: every layer (core, net, train, tools) may import
this module without creating cycles or dragging in jax.
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Iterator, Optional

__all__ = [
    "Tracer",
    "get_tracer",
    "set_tracer",
    "scoped_tracer",
    "enable",
    "disable",
]

#: wall-clock track: every ``span()`` context-manager lands here.
WALL_PID = 0
#: first pid handed out by :meth:`Tracer.new_track` for virtual-time tracks.
_FIRST_TRACK_PID = 1


class _NullSpan:
    """No-op context manager returned by a disabled tracer (singleton)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Live wall-clock span; appends one complete ("X") event on exit."""

    __slots__ = ("_tracer", "_name", "_cat", "_args", "_tid", "_t0")

    def __init__(self, tracer, name, cat, args, tid):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._args = args
        self._tid = tid
        self._t0 = time.perf_counter()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        tr = self._tracer
        t0 = (self._t0 - tr._epoch) * 1e6
        dur = (time.perf_counter() - self._t0) * 1e6
        ev = {"name": self._name, "cat": self._cat, "ph": "X",
              "ts": t0, "dur": dur, "pid": WALL_PID, "tid": self._tid}
        if self._args:
            ev["args"] = self._args
        tr.events.append(ev)
        return False


class Tracer:
    """Collects trace events; exports Chrome trace-event JSON.

    Disabled by default.  All record methods are no-ops while disabled;
    ``enable()``/``disable()`` flip recording without losing prior events.
    """

    def __init__(self, enabled: bool = False):
        self.enabled = bool(enabled)
        self.events: list[dict] = []
        self._epoch = time.perf_counter()
        self._next_pid = _FIRST_TRACK_PID
        self._meta: list[dict] = []

    # -- lifecycle ---------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        self.events.clear()
        self._meta.clear()
        self._next_pid = _FIRST_TRACK_PID
        self._epoch = time.perf_counter()

    # -- recording ---------------------------------------------------------
    def span(self, name: str, cat: str = "wall", args: Optional[dict] = None,
             tid: int = 0):
        """Wall-clock span context manager (no-op singleton when disabled)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args, tid)

    def add_span(self, name: str, t0_s: float, t1_s: float, *,
                 cat: str = "sim", pid: int = WALL_PID, tid: int = 0,
                 args: Optional[dict] = None) -> None:
        """Record a span with explicit start/end times in *seconds*.

        Used by the simulator to replay virtual time: ``t0_s``/``t1_s``
        are simulated seconds, exported as microseconds on track ``pid``.
        """
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "X", "ts": t0_s * 1e6,
              "dur": max(t1_s - t0_s, 0.0) * 1e6, "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def add_wall_span(self, name: str, t0_perf: float, t1_perf: float, *,
                      cat: str = "wall", tid: int = 0,
                      args: Optional[dict] = None) -> None:
        """Record a wall-clock span from explicit ``perf_counter`` stamps
        (for callers that measured before deciding to record)."""
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": (t0_perf - self._epoch) * 1e6,
              "dur": max(t1_perf - t0_perf, 0.0) * 1e6,
              "pid": WALL_PID, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, *, t_s: Optional[float] = None,
                cat: str = "wall", pid: int = WALL_PID, tid: int = 0,
                args: Optional[dict] = None) -> None:
        """Record an instant ("i") event; wall-clock 'now' if t_s is None."""
        if not self.enabled:
            return
        ts = ((time.perf_counter() - self._epoch) if t_s is None else t_s)
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": ts * 1e6, "pid": pid, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def new_track(self, name: str) -> int:
        """Allocate a fresh pid for a virtual-time track (e.g. one sim job).

        Each simulated job gets its own track so repeated runs never
        interleave partially-overlapping spans on one lane — nesting per
        (pid, tid) stays well-formed by construction.
        """
        pid = self._next_pid
        self._next_pid += 1
        self._meta.append({"name": "process_name", "ph": "M", "pid": pid,
                           "tid": 0, "args": {"name": name}})
        return pid

    def name_thread(self, pid: int, tid: int, name: str) -> None:
        """Attach a human-readable lane name to (pid, tid)."""
        if not self.enabled:
            return
        self._meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": name}})

    # -- export ------------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome trace-event JSON dict (loads in Perfetto)."""
        meta = [{"name": "process_name", "ph": "M", "pid": WALL_PID,
                 "tid": 0, "args": {"name": "wall-clock"}}]
        return {"traceEvents": meta + self._meta + self.events,
                "displayTimeUnit": "ms"}

    def write(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


# -- process-wide default --------------------------------------------------

_TRACER = Tracer()


def get_tracer() -> Tracer:
    """The process-wide tracer (disabled until someone calls enable())."""
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Replace the process-wide tracer; returns the previous one."""
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


@contextlib.contextmanager
def scoped_tracer(tracer: Optional[Tracer] = None) -> Iterator[Tracer]:
    """Temporarily install ``tracer`` (a fresh enabled one by default)."""
    t = Tracer(enabled=True) if tracer is None else tracer
    prev = set_tracer(t)
    try:
        yield t
    finally:
        set_tracer(prev)


def enable() -> Tracer:
    _TRACER.enable()
    return _TRACER


def disable() -> Tracer:
    _TRACER.disable()
    return _TRACER
