"""Join traces + metrics into a self-contained dashboard (DESIGN.md §11).

:func:`write_obs_artifacts` is the one entry point: it dumps the metrics
registry (``metrics.json``), the tracer's Chrome trace (``trace.json``,
loads in Perfetto), and renders both a markdown and an HTML dashboard
with the views the paper's claims live on:

* **JCT breakdown** — per job: completion time, mapper-finish tail,
  reducer drain (``sim.job.*`` / ``sim.link.drain_s`` series);
* **per-level reduction waterfall** — records in vs out per cascade
  level (``sim.level.*_total``), the paper's R per hop;
* **link bytes / utilization heatline** — wire bytes and drain-time
  share of JCT per link tier (``sim.link.*``);
* **predicted Eq.3 vs simulated** — the dataplane's per-level
  prediction deltas (``dataplane.level.*reduction``);
* transport-loss counters and train-exchange series when present.

The HTML is a single file, no external assets; colors follow the
repo-standard palette with light/dark via ``prefers-color-scheme`` and
``[data-theme]``.  Every chart has a table twin, so nothing is
color-alone.  Renderers are defensive: sections whose series were never
published render as "no data" instead of failing, because dashboards
are emitted from partial runs (smoke bench vs full dryrun vs example).
"""

from __future__ import annotations

import html as html_lib
import json
import os

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

# repo-standard viz palette (validated light/dark pairs)
_SEQ = ("#cde2fb", "#9ec5f4", "#6da7ec", "#3987e5",
        "#256abf", "#1c5cab", "#104281")

_CSS = """
:root { color-scheme: light; }
body {
  margin: 0; padding: 24px; background: #f9f9f7; color: #0b0b0b;
  font-family: system-ui, -apple-system, "Segoe UI", sans-serif;
}
.viz-root {
  color-scheme: light;
  --surface-1: #fcfcfb; --page: #f9f9f7;
  --text-primary: #0b0b0b; --text-secondary: #52514e; --muted: #898781;
  --grid: #e1e0d9; --baseline: #c3c2b7;
  --series-1: #2a78d6; --series-2: #eb6834; --series-3: #1baf7a;
  --ring: rgba(11,11,11,0.10);
}
@media (prefers-color-scheme: dark) {
  :root:where(:not([data-theme="light"])) body {
    background: #0d0d0d; color: #ffffff;
  }
  :root:where(:not([data-theme="light"])) .viz-root {
    color-scheme: dark;
    --surface-1: #1a1a19; --page: #0d0d0d;
    --text-primary: #ffffff; --text-secondary: #c3c2b7; --muted: #898781;
    --grid: #2c2c2a; --baseline: #383835;
    --series-1: #3987e5; --series-2: #d95926; --series-3: #199e70;
    --ring: rgba(255,255,255,0.10);
  }
}
:root[data-theme="dark"] body { background: #0d0d0d; color: #ffffff; }
:root[data-theme="dark"] .viz-root {
  color-scheme: dark;
  --surface-1: #1a1a19; --page: #0d0d0d;
  --text-primary: #ffffff; --text-secondary: #c3c2b7; --muted: #898781;
  --grid: #2c2c2a; --baseline: #383835;
  --series-1: #3987e5; --series-2: #d95926; --series-3: #199e70;
  --ring: rgba(255,255,255,0.10);
}
.viz-root {
  background: var(--surface-1); color: var(--text-primary);
  border: 1px solid var(--ring); border-radius: 8px;
  padding: 20px; margin: 0 0 20px; max-width: 980px;
}
h1 { font-size: 20px; margin: 0 0 4px; }
h2 { font-size: 15px; margin: 0 0 2px; }
.sub { color: var(--text-secondary); font-size: 12px; margin: 0 0 12px; }
.row { display: flex; align-items: center; gap: 8px; margin: 2px 0; }
.rlab {
  flex: 0 0 200px; font-size: 12px; color: var(--text-secondary);
  text-align: right; overflow: hidden; text-overflow: ellipsis;
  white-space: nowrap;
}
.rtrack { flex: 1; background: none; border-left: 2px solid var(--baseline); }
.rbar { height: 14px; border-radius: 0 4px 4px 0; min-width: 2px; }
.rval {
  flex: 0 0 110px; font-size: 12px; color: var(--text-primary);
  font-variant-numeric: tabular-nums;
}
.heat { display: flex; gap: 2px; margin: 6px 0; }
.cell {
  flex: 1; height: 34px; border-radius: 4px; display: flex;
  align-items: center; justify-content: center; font-size: 11px;
}
.clab { font-size: 11px; color: var(--muted); flex: 1; text-align: center; }
table { border-collapse: collapse; font-size: 12px; margin: 10px 0 4px; }
th {
  text-align: left; color: var(--text-secondary); font-weight: 600;
  border-bottom: 1px solid var(--baseline); padding: 3px 14px 3px 0;
}
td {
  padding: 3px 14px 3px 0; border-bottom: 1px solid var(--grid);
  font-variant-numeric: tabular-nums; color: var(--text-primary);
}
.nodata { color: var(--muted); font-size: 12px; font-style: italic; }
"""


# -- series helpers --------------------------------------------------------

def _series(metrics: list, name: str) -> list:
    return [(m["labels"], m["value"]) for m in metrics
            if m["name"] == name]


def _jobs(metrics: list) -> list:
    seen = []
    for lbl, _ in _series(metrics, "sim.job.jct_s"):
        key = (lbl.get("job", "?"), lbl.get("agg", "1"),
               lbl.get("engine", "?"))
        if key not in seen:
            seen.append(key)
    return seen


def _job_name(job: str, agg: str) -> str:
    return job if agg == "1" else f"{job} (no agg)"


def _fmt(v: float) -> str:
    if v == 0:
        return "0"
    if abs(v) >= 1e6:
        return f"{v:,.0f}"
    if abs(v) >= 100:
        return f"{v:,.1f}"
    if abs(v) >= 0.01:
        return f"{v:.3g}"
    return f"{v:.3e}"


def _get(metrics: list, name: str, **want):
    for lbl, v in _series(metrics, name):
        if all(str(lbl.get(k)) == str(w) for k, w in want.items()):
            return v
    return None


# -- section extraction (shared by md + html) ------------------------------

def _jct_rows(metrics: list) -> list:
    rows = []
    for job, agg, engine in _jobs(metrics):
        want = {"job": job, "agg": agg, "engine": engine}
        jct = _get(metrics, "sim.job.jct_s", **want)
        rows.append({
            "job": _job_name(job, agg),
            "jct_s": jct or 0.0,
            "mapper_finish_s": _get(metrics, "sim.job.mapper_finish_max_s",
                                    **want) or 0.0,
            "reducer_drain_s": _get(metrics, "sim.link.drain_s",
                                    axis="reducer", **want) or 0.0,
            "engine": engine,
        })
    rows.sort(key=lambda r: -r["jct_s"])
    return rows


def _reduction_rows(metrics: list) -> list:
    rows = []
    for lbl, rin in _series(metrics, "sim.level.records_in_total"):
        want = {k: lbl[k] for k in ("job", "agg", "engine", "level", "axis")
                if k in lbl}
        rout = _get(metrics, "sim.level.records_out_total", **want)
        if rout is None:
            continue
        rows.append({
            "job": _job_name(lbl.get("job", "?"), lbl.get("agg", "1")),
            "level": int(lbl.get("level", 0)),
            "axis": lbl.get("axis", ""),
            "records_in": rin,
            "records_out": rout,
            "reduction": 1.0 - rout / max(rin, 1.0),
        })
    rows.sort(key=lambda r: (r["job"], r["level"]))
    return rows


def _link_rows(metrics: list) -> list:
    rows = []
    for job, agg, engine in _jobs(metrics):
        want = {"job": job, "agg": agg, "engine": engine}
        jct = _get(metrics, "sim.job.jct_s", **want) or 0.0
        for lbl, b in _series(metrics, "sim.link.wire_bytes_total"):
            if (lbl.get("job"), lbl.get("agg"),
                    lbl.get("engine")) != (job, agg, engine):
                continue
            ax = lbl.get("axis", "")
            drain = _get(metrics, "sim.link.drain_s", axis=ax,
                         **want) or 0.0
            rows.append({
                "job": _job_name(job, agg), "axis": ax, "wire_bytes": b,
                "drain_s": drain,
                "utilization": min(drain / jct, 1.0) if jct > 0 else 0.0,
            })
    return rows


def _eq3_rows(metrics: list) -> list:
    rows = []
    for lbl, pred in _series(metrics, "dataplane.level.predicted_reduction"):
        want = {k: lbl[k] for k in ("op", "source", "level") if k in lbl}
        meas = _get(metrics, "dataplane.level.reduction", **want)
        if meas is None:
            continue
        rows.append({"op": lbl.get("op", "?"), "level": int(lbl["level"]),
                     "predicted": pred, "simulated": meas,
                     "delta": meas - pred})
    rows.sort(key=lambda r: (r["op"], r["level"]))
    return rows


def _transport_rows(metrics: list) -> list:
    names = ("transport.retransmissions_total", "transport.timeouts_total",
             "transport.packets_dropped_total",
             "transport.gap_discards_total",
             "transport.duplicate_discards_total")
    rows = []
    for job, agg, engine in _jobs(metrics):
        want = {"job": job, "agg": agg, "engine": engine}
        vals = {n.split(".", 1)[1][:-len("_total")]:
                _get(metrics, n, **want) or 0 for n in names}
        rows.append({"job": _job_name(job, agg), **vals})
    return rows


def _fault_rows(metrics: list) -> list:
    rows = []
    seen = []
    for lbl, _ in _series(metrics, "sim.fault.epochs"):
        key = (lbl.get("job", "?"), lbl.get("engine", "?"))
        if key in seen:
            continue
        seen.append(key)
        job, engine = key
        want = {"job": job, "engine": engine}
        degraded = sorted({int(l.get("level", -1)) for l, _ in
                           _series(metrics, "sim.fault.degraded")
                           if (l.get("job"), l.get("engine")) == key})
        rows.append({
            "job": job, "engine": engine,
            "epochs": int(_get(metrics, "sim.fault.epochs", **want) or 0),
            "jct_s": _get(metrics, "sim.fault.jct_s", **want) or 0.0,
            "recovery_overhead_s": _get(
                metrics, "sim.fault.recovery_overhead_s", **want) or 0.0,
            "n_bypassed": int(_get(metrics, "sim.fault.n_bypassed",
                                   **want) or 0),
            "degraded_levels": (", ".join(f"L{l}" for l in degraded)
                                or "—"),
        })
    rows.sort(key=lambda r: -r["jct_s"])
    return rows


def _fault_timeline_rows(metrics: list) -> list:
    rows = []
    for lbl, t in _series(metrics, "sim.fault.event_t_s"):
        rows.append({
            "job": lbl.get("job", "?"), "engine": lbl.get("engine", "?"),
            "kind": lbl.get("kind", "?"),
            "level": int(lbl.get("level", -1)),
            "switch": int(lbl.get("switch", -1)),
            "epoch": int(lbl.get("epoch", 0)),
            "detected_by": lbl.get("detected_by", "?"),
            "t_detect_s": t,
        })
    rows.sort(key=lambda r: (r["job"], r["engine"], r["t_detect_s"]))
    return rows


def _churn_rows(metrics: list) -> list:
    """The online-controller snapshot (DESIGN.md §13) as one summary row
    per scarce axis, or [] when no controller ran."""
    rows = []
    for lbl, util in _series(metrics, "controller.scarce_utilization"):
        ax = lbl.get("axis", "?")
        rows.append({
            "scarce_axis": ax,
            "active": int(_get(metrics, "controller.active_jobs") or 0),
            "degraded": int(_get(metrics, "controller.degraded_jobs") or 0),
            "admitted": int(sum(v for _, v in _series(
                metrics, "controller.admitted_total"))),
            "evictions": int(sum(v for _, v in _series(
                metrics, "controller.evictions_total"))),
            "expansions": int(sum(v for _, v in _series(
                metrics, "controller.expansions_total"))),
            "placements_scored": int(sum(v for _, v in _series(
                metrics, "controller.candidates_scored_total"))),
            "scarce_bytes": _get(metrics, "controller.scarce_bytes",
                                 axis=ax) or 0.0,
            "utilization": util,
        })
    return rows


def _churn_tenant_rows(metrics: list) -> list:
    rows = []
    for lbl, d in _series(metrics, "controller.tenant.demand_bytes"):
        t = lbl.get("tenant", "?")
        want = {"tenant": t}
        share = _get(metrics, "controller.tenant.share_bytes", **want) or 0.0
        rows.append({
            "tenant": t,
            "jobs": int(_get(metrics, "controller.tenant.jobs",
                             **want) or 0),
            "weight": _get(metrics, "controller.tenant.weight",
                           **want) or 1.0,
            "demand_bytes": d,
            "share_bytes": share,
            "satisfied": min(share / d, 1.0) if d > 0 else 1.0,
        })
    rows.sort(key=lambda r: -r["demand_bytes"])
    return rows


def _trace_rows(tracer) -> list:
    agg: dict = {}
    for ev in tracer.events:
        if ev.get("ph") != "X":
            continue
        key = (ev.get("cat", ""), ev["name"])
        cnt, tot = agg.get(key, (0, 0.0))
        agg[key] = (cnt + 1, tot + ev.get("dur", 0.0))
    rows = [{"cat": c, "name": n, "count": cnt, "total_ms": tot / 1e3}
            for (c, n), (cnt, tot) in agg.items()]
    rows.sort(key=lambda r: -r["total_ms"])
    return rows[:20]


# -- markdown --------------------------------------------------------------

def _md_bar(frac: float, width: int = 24) -> str:
    n = max(0, min(width, round(frac * width)))
    return "█" * n + "░" * (width - n)


def dashboard_markdown(metrics: list, tracer=None,
                       title: str = "repro observability") -> str:
    L = [f"# {title}", ""]
    jct = _jct_rows(metrics)
    L += ["## JCT breakdown", ""]
    if jct:
        mx = max(r["jct_s"] for r in jct) or 1.0
        L += ["| job | jct_s | mapper_finish_s | reducer_drain_s | |",
              "|---|---|---|---|---|"]
        for r in jct:
            L.append(f"| {r['job']} | {_fmt(r['jct_s'])} | "
                     f"{_fmt(r['mapper_finish_s'])} | "
                     f"{_fmt(r['reducer_drain_s'])} | "
                     f"`{_md_bar(r['jct_s'] / mx)}` |")
    else:
        L.append("_no data_")
    L += ["", "## Per-level reduction waterfall", ""]
    red = _reduction_rows(metrics)
    if red:
        L += ["| job | level | axis | records in | records out | "
              "reduction | |", "|---|---|---|---|---|---|---|"]
        for r in red:
            L.append(f"| {r['job']} | {r['level']} | {r['axis']} | "
                     f"{_fmt(r['records_in'])} | {_fmt(r['records_out'])} "
                     f"| {r['reduction']:.1%} | "
                     f"`{_md_bar(r['reduction'])}` |")
    else:
        L.append("_no data_")
    L += ["", "## Link bytes / utilization", ""]
    links = _link_rows(metrics)
    if links:
        L += ["| job | axis | wire bytes | drain_s | utilization |",
              "|---|---|---|---|---|"]
        for r in links:
            L.append(f"| {r['job']} | {r['axis']} | "
                     f"{_fmt(r['wire_bytes'])} | {_fmt(r['drain_s'])} | "
                     f"{r['utilization']:.1%} |")
    else:
        L.append("_no data_")
    L += ["", "## Predicted (Eq.3) vs simulated reduction", ""]
    eq3 = _eq3_rows(metrics)
    if eq3:
        L += ["| op | level | predicted | simulated | delta |",
              "|---|---|---|---|---|"]
        for r in eq3:
            L.append(f"| {r['op']} | {r['level']} | {r['predicted']:.4f} "
                     f"| {r['simulated']:.4f} | {r['delta']:+.4f} |")
    else:
        L.append("_no data_")
    L += ["", "## Transport", ""]
    tr = _transport_rows(metrics)
    if tr:
        L += ["| job | retransmissions | timeouts | packets_dropped | "
              "gap_discards | duplicate_discards |",
              "|---|---|---|---|---|---|"]
        for r in tr:
            L.append(f"| {r['job']} | {r['retransmissions']:.0f} | "
                     f"{r['timeouts']:.0f} | {r['packets_dropped']:.0f} | "
                     f"{r['gap_discards']:.0f} | "
                     f"{r['duplicate_discards']:.0f} |")
    else:
        L.append("_no data_")
    L += ["", "## Failures & recovery", ""]
    faults = _fault_rows(metrics)
    if faults:
        L += ["| job | engine | epochs | jct_s | recovery_overhead_s | "
              "bypassed | degraded tiers |", "|---|---|---|---|---|---|---|"]
        for r in faults:
            L.append(f"| {r['job']} | {r['engine']} | {r['epochs']} | "
                     f"{_fmt(r['jct_s'])} | "
                     f"{_fmt(r['recovery_overhead_s'])} | "
                     f"{r['n_bypassed']} | {r['degraded_levels']} |")
        tl = _fault_timeline_rows(metrics)
        if tl:
            L += ["", "### Failure timeline", "",
                  "| job | engine | t_detect_s | kind | level | switch | "
                  "epoch | detected by |",
                  "|---|---|---|---|---|---|---|---|"]
            for r in tl:
                L.append(f"| {r['job']} | {r['engine']} | "
                         f"{_fmt(r['t_detect_s'])} | {r['kind']} | "
                         f"{r['level']} | {r['switch']} | {r['epoch']} | "
                         f"{r['detected_by']} |")
    else:
        L.append("_no data_")
    L += ["", "## Churn", ""]
    churn = _churn_rows(metrics)
    if churn:
        L += ["| scarce axis | active | degraded | admitted | evictions | "
              "re-expansions | placements scored | scarce bytes | "
              "utilization |", "|---|---|---|---|---|---|---|---|---|"]
        for r in churn:
            L.append(f"| {r['scarce_axis']} | {r['active']} | "
                     f"{r['degraded']} | {r['admitted']} | "
                     f"{r['evictions']} | {r['expansions']} | "
                     f"{r['placements_scored']} | "
                     f"{_fmt(r['scarce_bytes'])} | "
                     f"{r['utilization']:.1%} |")
        tn = _churn_tenant_rows(metrics)
        if tn:
            L += ["", "### Tenant fairness (weighted max-min)", "",
                  "| tenant | jobs | weight | demand bytes | fair share "
                  "bytes | satisfied | |", "|---|---|---|---|---|---|---|"]
            for r in tn:
                L.append(f"| {r['tenant']} | {r['jobs']} | "
                         f"{_fmt(r['weight'])} | "
                         f"{_fmt(r['demand_bytes'])} | "
                         f"{_fmt(r['share_bytes'])} | "
                         f"{r['satisfied']:.1%} | "
                         f"`{_md_bar(r['satisfied'])}` |")
    else:
        L.append("_no data_")
    if tracer is not None and tracer.events:
        L += ["", "## Top spans", "",
              "| cat | span | count | total_ms |", "|---|---|---|---|"]
        for r in _trace_rows(tracer):
            L.append(f"| {r['cat']} | {r['name']} | {r['count']} | "
                     f"{r['total_ms']:.3f} |")
    L.append("")
    return "\n".join(L)


# -- html ------------------------------------------------------------------

def _esc(s) -> str:
    return html_lib.escape(str(s))


def _html_bars(rows, label_key, value_key, *, color_var, fmt=_fmt,
               frac_of=None) -> str:
    if not rows:
        return '<p class="nodata">no data</p>'
    mx = frac_of or max(abs(r[value_key]) for r in rows) or 1.0
    out = []
    for r in rows:
        frac = max(0.0, min(1.0, r[value_key] / mx))
        out.append(
            f'<div class="row" title="{_esc(r[label_key])}: '
            f'{_esc(fmt(r[value_key]))}">'
            f'<div class="rlab">{_esc(r[label_key])}</div>'
            f'<div class="rtrack"><div class="rbar" style="width:'
            f'{frac * 100:.2f}%;background:var({color_var})"></div></div>'
            f'<div class="rval">{_esc(fmt(r[value_key]))}</div></div>')
    return "".join(out)


def _html_table(rows, cols, fmts=None) -> str:
    if not rows:
        return '<p class="nodata">no data</p>'
    fmts = fmts or {}
    head = "".join(f"<th>{_esc(c)}</th>" for c in cols)
    body = []
    for r in rows:
        tds = []
        for c in cols:
            v = r.get(c, "")
            f = fmts.get(c)
            tds.append(f"<td>{_esc(f(v) if f else v)}</td>")
        body.append("<tr>" + "".join(tds) + "</tr>")
    return (f"<table><thead><tr>{head}</tr></thead>"
            f"<tbody>{''.join(body)}</tbody></table>")


def _html_heatline(links) -> str:
    if not links:
        return '<p class="nodata">no data</p>'
    by_job: dict = {}
    for r in links:
        by_job.setdefault(r["job"], []).append(r)
    out = []
    for job, rows in by_job.items():
        cells, labs = [], []
        for r in rows:
            idx = min(len(_SEQ) - 1, int(r["utilization"] * len(_SEQ)))
            ink = "#0b0b0b" if idx < 4 else "#ffffff"
            cells.append(
                f'<div class="cell" style="background:{_SEQ[idx]};'
                f'color:{ink}" title="{_esc(r["axis"])}: '
                f'{r["utilization"]:.1%} of JCT, '
                f'{_esc(_fmt(r["wire_bytes"]))} B">'
                f'{r["utilization"]:.0%}</div>')
            labs.append(f'<div class="clab">{_esc(r["axis"])}</div>')
        out.append(f"<h2>{_esc(job)}</h2>"
                   f'<div class="heat">{"".join(cells)}</div>'
                   f'<div class="heat">{"".join(labs)}</div>')
    return "".join(out)


def dashboard_html(metrics: list, tracer=None,
                   title: str = "repro observability") -> str:
    jct = _jct_rows(metrics)
    red = _reduction_rows(metrics)
    links = _link_rows(metrics)
    eq3 = _eq3_rows(metrics)
    tr = _transport_rows(metrics)
    pct = lambda v: f"{v:.1%}"  # noqa: E731
    f4 = lambda v: f"{v:.4f}" if isinstance(v, float) else str(v)  # noqa: E731
    red_rows = [dict(r, label=f"{r['job']} · L{r['level']} {r['axis']}")
                for r in red]
    sec = []
    sec.append(
        '<section class="viz-root"><h1>JCT breakdown</h1>'
        '<p class="sub">job completion time per simulated job; bar = JCT, '
        "table adds the mapper-finish tail and reducer drain</p>"
        + _html_bars(jct, "job", "jct_s", color_var="--series-1")
        + _html_table(jct, ["job", "engine", "jct_s", "mapper_finish_s",
                            "reducer_drain_s"],
                      {"jct_s": _fmt, "mapper_finish_s": _fmt,
                       "reducer_drain_s": _fmt}) + "</section>")
    sec.append(
        '<section class="viz-root"><h1>Per-level reduction waterfall</h1>'
        '<p class="sub">share of records dying at each cascade level '
        "(the paper's per-hop R)</p>"
        + _html_bars(red_rows, "label", "reduction",
                     color_var="--series-2", fmt=pct, frac_of=1.0)
        + _html_table(red, ["job", "level", "axis", "records_in",
                            "records_out", "reduction"],
                      {"records_in": _fmt, "records_out": _fmt,
                       "reduction": pct}) + "</section>")
    sec.append(
        '<section class="viz-root"><h1>Link utilization heatline</h1>'
        '<p class="sub">per-tier drain time as a share of job completion '
        "time; darker = busier</p>" + _html_heatline(links)
        + _html_table(links, ["job", "axis", "wire_bytes", "drain_s",
                              "utilization"],
                      {"wire_bytes": _fmt, "drain_s": _fmt,
                       "utilization": pct}) + "</section>")
    sec.append(
        '<section class="viz-root"><h1>Predicted (Eq.3) vs simulated '
        "reduction</h1>"
        '<p class="sub">dataplane per-level reduction: model prediction '
        "against the simulated cascade</p>"
        + _html_table(eq3, ["op", "level", "predicted", "simulated",
                            "delta"],
                      {"predicted": f4, "simulated": f4,
                       "delta": lambda v: f"{v:+.4f}"}) + "</section>")
    sec.append(
        '<section class="viz-root"><h1>Transport</h1>'
        '<p class="sub">loss-recovery counters per job</p>'
        + _html_table(tr, ["job", "retransmissions", "timeouts",
                           "packets_dropped", "gap_discards",
                           "duplicate_discards"]) + "</section>")
    faults = _fault_rows(metrics)
    tl_rows = [dict(r, label=f"{r['kind']} L{r['level']}.s{r['switch']} "
                             f"({r['detected_by']}, e{r['epoch']})")
               for r in _fault_timeline_rows(metrics)]
    sec.append(
        '<section class="viz-root"><h1>Failures &amp; recovery</h1>'
        '<p class="sub">epoch-restart recovery per faulted job: total JCT '
        "including dead incarnations, recovery overhead, and tiers left "
        "degraded (bypass relays); bars below place each failure verdict "
        "on the detection timeline</p>"
        + _html_table(faults, ["job", "engine", "epochs", "jct_s",
                               "recovery_overhead_s", "n_bypassed",
                               "degraded_levels"],
                      {"jct_s": _fmt, "recovery_overhead_s": _fmt})
        + _html_bars(tl_rows, "label", "t_detect_s",
                     color_var="--series-2")
        + _html_table(tl_rows, ["job", "engine", "t_detect_s", "kind",
                                "level", "switch", "epoch", "detected_by"],
                      {"t_detect_s": _fmt}) + "</section>")
    churn = _churn_rows(metrics)
    tn_rows = _churn_tenant_rows(metrics)
    sec.append(
        '<section class="viz-root"><h1>Churn</h1>'
        '<p class="sub">online controller under arrivals/departures '
        "(DESIGN.md §13): active/degraded jobs, preemption and "
        "re-expansion totals, placement work, and the weighted max-min "
        "fair shares of the scarce uplink per tenant</p>"
        + _html_table(churn, ["scarce_axis", "active", "degraded",
                              "admitted", "evictions", "expansions",
                              "placements_scored", "scarce_bytes",
                              "utilization"],
                      {"scarce_bytes": _fmt, "utilization": pct})
        + _html_bars(tn_rows, "tenant", "satisfied",
                     color_var="--series-1", fmt=pct, frac_of=1.0)
        + _html_table(tn_rows, ["tenant", "jobs", "weight", "demand_bytes",
                                "share_bytes", "satisfied"],
                      {"demand_bytes": _fmt, "share_bytes": _fmt,
                       "satisfied": pct}) + "</section>")
    if tracer is not None and tracer.events:
        sec.append(
            '<section class="viz-root"><h1>Top spans</h1>'
            '<p class="sub">heaviest trace spans (full timeline: load '
            "trace.json in Perfetto)</p>"
            + _html_table(_trace_rows(tracer),
                          ["cat", "name", "count", "total_ms"],
                          {"total_ms": lambda v: f"{v:.3f}"})
            + "</section>")
    return ("<!doctype html><html><head><meta charset='utf-8'>"
            f"<title>{_esc(title)}</title><style>{_CSS}</style></head>"
            f"<body><h1>{_esc(title)}</h1>" + "".join(sec)
            + "</body></html>")


# -- artifact writer -------------------------------------------------------

def write_obs_artifacts(out_dir, *, registry=None, tracer=None,
                        title: str = "repro observability") -> dict:
    """Write metrics.json / trace.json / dashboard.{md,html} to ``out_dir``.

    Uses the process-wide registry/tracer unless given explicit ones;
    returns ``{artifact_name: path}`` for the files actually written
    (``trace.json`` is skipped when the tracer has no events).
    """
    reg = registry if registry is not None else obs_metrics.get_registry()
    trc = tracer if tracer is not None else obs_trace.get_tracer()
    os.makedirs(out_dir, exist_ok=True)
    metrics = reg.collect()
    paths = {}

    paths["metrics"] = os.path.join(out_dir, "metrics.json")
    with open(paths["metrics"], "w") as f:
        json.dump({"metrics": metrics}, f, indent=1)
    if trc.events:
        paths["trace"] = os.path.join(out_dir, "trace.json")
        trc.write(paths["trace"])
    paths["dashboard_md"] = os.path.join(out_dir, "dashboard.md")
    with open(paths["dashboard_md"], "w") as f:
        f.write(dashboard_markdown(metrics, trc, title=title))
    paths["dashboard_html"] = os.path.join(out_dir, "dashboard.html")
    with open(paths["dashboard_html"], "w") as f:
        f.write(dashboard_html(metrics, trc, title=title))
    return paths
