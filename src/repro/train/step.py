"""Train / prefill / serve step builders: sharding + the SwitchAgg exchange.

The gradient-exchange mode is the paper's comparison axis:

  flat          — gradients constrained replicated over (pod, data): XLA
                  emits one flat all-reduce over every chip; the scarce
                  inter-pod links carry FULL gradient bytes (the
                  no-in-network-aggregation baseline).
  tree          — gradients constrained to the ZeRO (data-sharded) spec:
                  XLA emits reduce-scatter(data) + all-reduce(pod) on
                  1/16-size shards + all-gather(data) of updated params —
                  the SwitchAgg aggregation tree as a collective schedule.
  tree_compress — the explicit shard_map exchange with top-k KV payloads
                  and the bounded-memory combiner (core.collectives);
                  used by the real-training examples; adds the paper's
                  FPE/BPE semantics on the pod boundary.

Memory features for the 100B+ configs: FSDP param storage (gather-at-use
via specs), int8 optimizer moments, fp32 ZeRO-1 masters, microbatch
gradient accumulation.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.collectives import GradAggMode, shard_map_compat
from repro.models import sharding as shd
from repro.models.attention import ShardingPolicy
from repro.models.model import LMModel
from repro.models.transformer import ApplyOptions
from repro.optim import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.optim.quant import QTensor


@dataclasses.dataclass(frozen=True)
class TrainProfile:
    """Per-(arch x mesh) distribution choices."""

    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"
    fsdp: bool = False  # shard dense/mamba/embed params over dp too
    accum_steps: int = 1  # microbatch gradient accumulation
    quantized_opt: bool = False
    master_fp32: bool = True
    remat: str = "full"
    q_chunk: int = 512
    k_chunk: int = 1024
    moe_token_chunk: int = 4096
    mode: GradAggMode = GradAggMode.TREE
    seq_shard: bool = False  # Megatron-SP inter-layer activation sharding


def _mesh_axis_size(mesh, name: str) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name, 1)


def _fsdp_specs(specs, params, cfg: ModelConfig, dp_axes, dp_size: int):
    """Add a dp axis to the largest free dim of big dense params."""

    def one(path, leaf, spec: P):
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        if leaf.size * 2 < (1 << 26):  # < 64 MiB stays replicated over dp
            return spec
        used = set()
        for e in dims:
            for a in (e if isinstance(e, (tuple, list)) else (e,)):
                if a is not None:
                    used.add(a)
        if used & set(dp_axes):  # already ZeRO-sharded (e.g. MoE experts)
            return spec
        best, best_size = -1, 0
        for i, (d, s) in enumerate(zip(leaf.shape, dims)):
            if s is None and d % dp_size == 0 and d > best_size:
                best, best_size = i, d
        if best >= 0:
            dims[best] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        return P(*dims)

    flat_s, treedef = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, P))
    flat_p = treedef.flatten_up_to(params)
    paths = [p for p, _ in jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: hasattr(x, "shape"))[0]]
    out = [one(pp, pl, ps) for pp, pl, ps in zip(paths, flat_p, flat_s)]
    return treedef.unflatten(out)


def make_param_specs(params, cfg: ModelConfig, mesh, prof: TrainProfile):
    tp_size = _mesh_axis_size(mesh, prof.tp_axis)
    dp_size = 1
    for a in prof.dp_axes:
        dp_size *= _mesh_axis_size(mesh, a)
    specs = shd.param_specs(
        params, cfg, tp=prof.tp_axis, tp_size=tp_size,
        dp_axes=prof.dp_axes, dp_size=dp_size,
    )
    if prof.fsdp:
        specs = _fsdp_specs(specs, params, cfg, prof.dp_axes, dp_size)
    return specs


def make_opt_specs(params, pspecs, mesh, prof: TrainProfile, opt_cfg: AdamWConfig):
    dp_size = 1
    for a in prof.dp_axes:
        dp_size *= _mesh_axis_size(mesh, a)
    zspecs = shd.zero1_specs(params, pspecs, dp_axes=prof.dp_axes, dp_size=dp_size)

    def moment_spec(pleaf, zspec: P):
        if not opt_cfg.quantized:
            return zspec
        # QTensor(q: param shape, scale: [*lead, nb]) — scale drops last dim
        lead = list(zspec)[:-1] if len(zspec) else []
        return QTensor(q=zspec, scale=P(*lead, None))

    m_specs = jax.tree.map(moment_spec, params, zspecs)
    master_specs = zspecs if opt_cfg.master_fp32 else None
    return AdamWState(count=P(), m=m_specs, v=m_specs, master=master_specs)


def make_policy(mesh, prof: TrainProfile, cache_seq_axes: tuple[str, ...] = (),
                batch_sharded: bool = True) -> ShardingPolicy:
    return ShardingPolicy(
        mesh=mesh,
        dp_axes=prof.dp_axes,
        tp_axis=prof.tp_axis,
        cache_seq_axes=cache_seq_axes,
        batch_sharded=batch_sharded,
        seq_shard=prof.seq_shard,
    )


# ---------------------------------------------------------------------------
# Train step.
# ---------------------------------------------------------------------------


def build_train_step(
    cfg: ModelConfig,
    mesh,
    prof: TrainProfile,
    opt_cfg: AdamWConfig,
    lr_fn,
    *,
    batch_example: Any,
    params_example: Any,
):
    """Returns (jitted step, shardings dict). Step signature:
    (params, opt_state, batch, step) -> (params, opt_state, metrics)."""
    policy = make_policy(mesh, prof)
    model = LMModel(
        cfg,
        policy=policy,
        opt=ApplyOptions(
            q_chunk=prof.q_chunk,
            k_chunk=prof.k_chunk,
            moe_token_chunk=prof.moe_token_chunk,
            remat=prof.remat,
        ),
    )

    pspecs = make_param_specs(params_example, cfg, mesh, prof)
    ospecs = make_opt_specs(params_example, pspecs, mesh, prof, opt_cfg)
    bspecs = shd.batch_specs(batch_example, prof.dp_axes)
    s = functools.partial(NamedSharding, mesh)

    dp_size = 1
    for a in prof.dp_axes:
        dp_size *= _mesh_axis_size(mesh, a)

    def grad_constraint(grads):
        if prof.mode == GradAggMode.GATHER:
            # Parameter-server baseline: every worker's raw partial flows to
            # the reducer — an explicit all-gather of UNREDUCED per-worker
            # grads over the dp axes, then a local mean.  This is the paper's
            # "no in-network aggregation" traffic pattern (N x grad bytes on
            # the scarce links), realized with shard_map so SPMD cannot
            # rewrite it into a reduce.
            def ps_exchange(g):
                def body(gl):
                    stacked = gl
                    for ax in prof.dp_axes:
                        stacked = jax.lax.all_gather(stacked, ax, axis=0, tiled=False)
                        stacked = jnp.mean(stacked, axis=0)
                    return stacked

                return shard_map_compat(
                    body, mesh=mesh,
                    in_specs=P(), out_specs=P(),
                    axis_names=set(prof.dp_axes), check_vma=False,
                )(g)

            # grads enter un-psummed per dp shard? No — under jit they are
            # already summed by SPMD unless we block it; emulate PS traffic
            # by gathering the (already-identical) replicas: byte-accounting
            # matches N x T on the wire, which is the metric under study.
            return jax.tree.map(ps_exchange, grads)
        if prof.mode == GradAggMode.FLAT:
            # replicated == all-reduce over everything at once (baseline)
            rep = jax.tree.map(lambda g, sp: jax.lax.with_sharding_constraint(
                g, s(_strip_dp(sp, prof.dp_axes))), grads, pspecs)
            return rep
        # TREE: reduce-scatter over data, all-reduce over pod — constrain to
        # the ZeRO layout (the aggregation-tree schedule).
        zspecs = shd.zero1_specs(params_example, pspecs, dp_axes=prof.dp_axes, dp_size=dp_size)
        return jax.tree.map(
            lambda g, sp: jax.lax.with_sharding_constraint(g, s(sp)), grads, zspecs
        )

    def loss_of(params, batch):
        loss, aux = model.loss_fn(params, batch)
        return loss, aux

    # ZeRO layout for the fp32 accumulation carry: without an explicit
    # constraint XLA replicates it (= full fp32 params per device, 16 GB for
    # a 4B model) and all-reduces every microbatch; constrained, the carry
    # is data-sharded and each microbatch reduce-scatters instead.
    zspecs_carry = shd.zero1_specs(params_example, pspecs,
                                   dp_axes=prof.dp_axes, dp_size=dp_size)

    def constrain_carry(g):
        return jax.tree.map(
            lambda x, sp: jax.lax.with_sharding_constraint(x, s(sp)),
            g, zspecs_carry)

    def train_step(params, opt_state, batch, step):
        if prof.accum_steps > 1:
            n = prof.accum_steps

            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (constrain_carry(gsum), lsum + l), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch
            )
            g0 = constrain_carry(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (gsum, lsum), _ = jax.lax.scan(micro, (g0, 0.0), micro_batches)
            grads = jax.tree.map(lambda g: g / n, gsum)
            loss = lsum / n
        else:
            (loss, _), grads = jax.value_and_grad(loss_of, has_aux=True)(params, batch)

        grads = grad_constraint(grads)
        lr = lr_fn(step)
        new_params, new_opt, stats = adamw_update(grads, opt_state, params, opt_cfg, lr)
        new_params = jax.tree.map(
            lambda p, sp: jax.lax.with_sharding_constraint(p, s(sp)), new_params, pspecs
        )
        metrics = {"loss": loss, **stats}
        return new_params, new_opt, metrics

    shardings = {
        "params": jax.tree.map(s, pspecs),
        "opt": jax.tree.map(s, ospecs, is_leaf=lambda x: isinstance(x, P)),
        "batch": jax.tree.map(s, bspecs),
        "pspecs": pspecs,
        "ospecs": ospecs,
        "bspecs": bspecs,
    }
    step_fn = jax.jit(
        train_step,
        in_shardings=(shardings["params"], shardings["opt"], shardings["batch"], None),
        out_shardings=(shardings["params"], shardings["opt"], None),
        donate_argnums=(0, 1),
    )
    return step_fn, shardings, model


def _strip_dp(spec: P, dp_axes) -> P:
    """Remove dp axes from a spec (replicate over data/pod)."""
    drop = set(dp_axes)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a not in drop)
            return kept if kept else None
        return None if entry in drop else entry

    return P(*(keep(e) for e in spec))


def init_train_state(cfg: ModelConfig, mesh, prof: TrainProfile, opt_cfg: AdamWConfig, seed=0):
    """Initialize params + opt state directly with their final shardings."""
    model = LMModel(cfg)
    abstract = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(seed)))
    pspecs = make_param_specs(abstract, cfg, mesh, prof)
    s = functools.partial(NamedSharding, mesh)
    init_fn = jax.jit(
        lambda: model.init(jax.random.PRNGKey(seed)),
        out_shardings=jax.tree.map(s, pspecs),
    )
    params = init_fn()
    ospecs = make_opt_specs(abstract, pspecs, mesh, prof, opt_cfg)
    opt_fn = jax.jit(
        lambda p: adamw_init(p, opt_cfg),
        out_shardings=jax.tree.map(s, ospecs, is_leaf=lambda x: isinstance(x, P)),
    )
    return params, opt_fn(params), pspecs, ospecs


# ---------------------------------------------------------------------------
# Serve steps (prefill + decode).
# ---------------------------------------------------------------------------


def build_prefill_step(
    cfg: ModelConfig, mesh, prof: TrainProfile, *, cache_len: int,
    batch_example: Any, params_example: Any, batch_shardable: bool = True,
    cache_seq_axes: tuple[str, ...] = (),
):
    policy = make_policy(mesh, prof, cache_seq_axes, batch_sharded=batch_shardable)
    model = LMModel(
        cfg, policy=policy,
        opt=ApplyOptions(q_chunk=prof.q_chunk, k_chunk=prof.k_chunk,
                         moe_token_chunk=prof.moe_token_chunk, remat="none"),
    )
    pspecs = make_param_specs(params_example, cfg, mesh, prof)
    bspecs = shd.batch_specs(batch_example, prof.dp_axes, batch_shardable)
    s = functools.partial(NamedSharding, mesh)

    b = jax.tree.leaves(batch_example)[0].shape[0]
    cache_example = jax.eval_shape(
        lambda: model.init_caches(b, cache_len, jnp.dtype(cfg.dtype))
    )
    tp_size = _mesh_axis_size(mesh, prof.tp_axis)
    cspecs = shd.cache_specs(
        cache_example, cfg, tp=prof.tp_axis, tp_size=tp_size,
        dp_axes=prof.dp_axes, cache_seq_axes=cache_seq_axes,
        batch_shardable=batch_shardable,
    )

    def prefill(params, batch):
        return model.prefill(params, batch, cache_len)

    fn = jax.jit(
        prefill,
        in_shardings=(jax.tree.map(s, pspecs), jax.tree.map(s, bspecs)),
        out_shardings=(None, jax.tree.map(s, cspecs)),
    )
    return fn, {"params": pspecs, "batch": bspecs, "cache": cspecs}, model


def build_serve_step(
    cfg: ModelConfig, mesh, prof: TrainProfile, *, cache_len: int, batch: int,
    params_example: Any, batch_shardable: bool = True,
    cache_seq_axes: tuple[str, ...] = ("model",),
):
    """Greedy decode step: (params, caches, token, cur_pos) ->
    (next_token, caches)."""
    policy = make_policy(mesh, prof, cache_seq_axes, batch_sharded=batch_shardable)
    model = LMModel(
        cfg, policy=policy,
        opt=ApplyOptions(q_chunk=prof.q_chunk, k_chunk=prof.k_chunk,
                         moe_token_chunk=max(batch, 16), remat="none"),
    )
    pspecs = make_param_specs(params_example, cfg, mesh, prof)
    s = functools.partial(NamedSharding, mesh)
    cache_example = jax.eval_shape(
        lambda: model.init_caches(batch, cache_len, jnp.dtype(cfg.dtype))
    )
    tp_size = _mesh_axis_size(mesh, prof.tp_axis)
    cspecs = shd.cache_specs(
        cache_example, cfg, tp=prof.tp_axis, tp_size=tp_size,
        dp_axes=prof.dp_axes, cache_seq_axes=cache_seq_axes,
        batch_shardable=batch_shardable,
    )
    dp = prof.dp_axes if batch_shardable else None
    tok_spec = P(dp, None) if cfg.frontend != "audio_stub" else P(dp, None, None)

    def serve_step(params, caches, token, cur_pos):
        logits, caches = model.decode_step(params, token, caches, cur_pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, caches

    fn = jax.jit(
        serve_step,
        in_shardings=(
            jax.tree.map(s, pspecs),
            jax.tree.map(s, cspecs),
            s(tok_spec),
            None,
        ),
        out_shardings=(s(tok_spec) if cfg.frontend != "audio_stub" else None,
                       jax.tree.map(s, cspecs)),
        donate_argnums=(1,),
    )
    return fn, {"params": pspecs, "cache": cspecs}, model
