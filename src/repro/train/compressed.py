"""Compressed SwitchAgg training step — the paper's full dataplane.

``build_train_step`` (step.py) realizes the aggregation *tree* as a
collective schedule (flat/tree/gather).  This module adds the third mode,
``tree_compress``: per-worker gradients become top-k KV payloads, cross the
scarce (inter-pod) links as (key, value) streams, and are combined by the
bounded-memory FPE/BPE node — the paper's aggregation packet flow, with
error feedback making the compression unbiased over steps.

The whole step runs inside ``jax.shard_map`` manual over the dp axes
(per-worker gradients exist only there); the model/TP axis stays automatic.
MoE expert-parallel dispatch uses the local (non-a2a) path inside the
manual region — EP's all-to-all is a permutation, not a reduction, and is
orthogonal to the gradient exchange under study (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import collectives as coll
from repro.core.collectives import GradAggMode, axis_size_compat
from repro.models.attention import ShardingPolicy
from repro.models.model import LMModel
from repro.models.transformer import ApplyOptions
from repro.obs import metrics as obs_metrics
from repro.optim import AdamWConfig, adamw_update
from repro.train.step import TrainProfile, make_param_specs, make_opt_specs

from repro.models import sharding as shd


def init_exchange_residuals(params_example, mesh, prof: TrainProfile):
    """Error-feedback state: one flat per-dp-shard residual per param leaf.

    Returns (residuals pytree of global arrays, their PartitionSpecs).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    leaf_axis = prof.dp_axes[0]
    world = 1
    for a in prof.dp_axes:
        world *= sizes[a]
    leaf_size = sizes[leaf_axis]

    def one(p):
        n = 1
        for d in p.shape:
            n *= d
        padded = n + ((-n) % leaf_size)
        return jnp.zeros((world * (padded // leaf_size),), jnp.float32)

    res = jax.tree.map(one, params_example)
    spec = jax.tree.map(lambda _: P(prof.dp_axes), params_example)
    return res, spec


def build_compressed_train_step(
    cfg: ModelConfig,
    mesh,
    prof: TrainProfile,
    opt_cfg: AdamWConfig,
    lr_fn,
    *,
    batch_example: Any,
    params_example: Any,
    k_fraction: float = 0.01,
    fpe_capacity: int = 0,
    mode: GradAggMode | None = None,
    wire_dtype=None,
    plan=None,
):
    """Returns (jitted step, shardings).  Step signature:
    (params, opt_state, residuals, batch, step) ->
    (params, opt_state, residuals, metrics).

    ``mode=TREE`` gives the *post-accumulation* exact exchange: microbatch
    gradients accumulate LOCALLY inside the manual region (zero collectives
    in the loop — unlike the pjit path, where the loop-carried sharded sum
    forces a reduction per microbatch), then ONE tree exchange crosses the
    wire.  ``wire_dtype`` (e.g. bf16) casts just the exchanged bytes.

    ``plan`` (a planner ``ExchangePlan``) overrides mode / k_fraction /
    fpe_capacity with the controller's decision for this job (DESIGN.md §3);
    its level ordering must use the profile's dp axes.  Compressed plans run
    the multi-level cascade dataplane across the upper hops, the plan's
    combiner budget partitioned per level (DESIGN.md §6)."""
    cascade = None
    if plan is not None:
        mode = plan.mode
        k_fraction = plan.k_fraction
        fpe_capacity = plan.fpe_capacity
        plan_axes = (plan.leaf_axis, *plan.upper_axes)
        assert set(plan_axes) == set(prof.dp_axes), (
            f"plan axes {plan_axes} != profile dp axes {prof.dp_axes}")
        prof = dataclasses.replace(prof, dp_axes=plan_axes)
        cascade = coll.cascade_for_plan(plan)
    # model math sees a single logical worker (dp manual, tp via GSPMD auto)
    model = LMModel(
        cfg,
        policy=ShardingPolicy(),  # no in-graph constraints inside the region
        opt=ApplyOptions(q_chunk=prof.q_chunk, k_chunk=prof.k_chunk,
                         moe_token_chunk=prof.moe_token_chunk, remat=prof.remat),
    )
    pspecs = make_param_specs(params_example, cfg, mesh, prof)
    ospecs = make_opt_specs(params_example, pspecs, mesh, prof, opt_cfg)
    bspecs = shd.batch_specs(batch_example, prof.dp_axes)
    res_example, res_specs = init_exchange_residuals(params_example, mesh, prof)
    s = functools.partial(NamedSharding, mesh)

    leaf_axis = prof.dp_axes[0]
    upper_axes = tuple(prof.dp_axes[1:])
    # NOTE: leaf = first dp axis. With dp_axes=('pod','data') the scarce pod
    # axis would be the LEAF; callers order dp_axes cheap-first for the tree
    # ('data' before 'pod') — asserted here.
    if "pod" in prof.dp_axes:
        assert prof.dp_axes[0] != "pod", (
            "compressed exchange wants dp_axes ordered (data, pod): "
            "reduce the cheap axis first, compress across the scarce one")

    # shard_map specs may only mention MANUAL axes; the auto (model/TP) axis
    # sharding flows through implicitly.  Keep only dp-axis references.
    manual = set(prof.dp_axes)

    def _manual_only(spec: P) -> P:
        def keep(e):
            if e is None:
                return None
            if isinstance(e, (tuple, list)):
                kept = tuple(a for a in e if a in manual)
                return kept if kept else None
            return e if e in manual else None

        return P(*(keep(e) for e in spec))

    pspecs_region = jax.tree.map(_manual_only, pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
    bspecs_region = jax.tree.map(
        lambda _: P(prof.dp_axes, *([None] * 0)), batch_example)

    xmode = mode or GradAggMode.TREE_COMPRESS

    def region(params, batch, residuals, step_idx):
        def loss_of(p, b):
            loss, aux = model.loss_fn(p, b)
            return loss, aux

        n = max(prof.accum_steps, 1)
        if n > 1:
            micro = jax.tree.map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)

            def mb(carry, b):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, b)
                # LOCAL accumulation: dp axes are manual here, so no
                # per-microbatch collective is emitted.
                gsum = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, lsum), _ = jax.lax.scan(mb, (g0, 0.0), micro)
            loss = lsum / n
            grads = jax.tree.map(lambda g: g / n, grads)
        else:
            (loss, _), grads = jax.value_and_grad(loss_of, has_aux=True)(
                params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        # mean over workers
        w = 1.0
        for ax in prof.dp_axes:
            w *= axis_size_compat(ax)
        grads = jax.tree.map(lambda g: g / w, grads)
        if wire_dtype is not None:
            grads = jax.tree.map(lambda g: g.astype(wire_dtype), grads)
        new_grads, new_res = coll.exchange_in_shardmap(
            grads, xmode, leaf_axis, upper_axes,
            k_fraction=k_fraction, fpe_capacity=fpe_capacity,
            residuals=residuals, cascade=cascade,
        )
        if wire_dtype is not None:
            new_grads = jax.tree.map(lambda g: g.astype(jnp.float32), new_grads)
        loss = jax.lax.pmean(loss, prof.dp_axes)
        return new_grads, new_res, loss

    def batch_region_specs(b):
        def one(leaf):
            nd = len(leaf.shape)
            return P(prof.dp_axes, *([None] * (nd - 1)))

        return jax.tree.map(one, b)

    mapped = coll.shard_map_compat(
        region,
        mesh=mesh,
        in_specs=(pspecs_region, batch_region_specs(batch_example),
                  jax.tree.map(lambda _: P(prof.dp_axes), res_example), P()),
        out_specs=(pspecs_region,
                   jax.tree.map(lambda _: P(prof.dp_axes), res_example), P()),
        axis_names=set(prof.dp_axes),
        check_vma=False,
    )

    def train_step(params, opt_state, residuals, batch, step_idx):
        grads, new_res, loss = mapped(params, batch, residuals, step_idx)
        lr = lr_fn(step_idx)
        new_params, new_opt, stats = adamw_update(grads, opt_state, params,
                                                  opt_cfg, lr)
        new_params = jax.tree.map(
            lambda p, sp: jax.lax.with_sharding_constraint(p, s(sp)),
            new_params, pspecs)
        return new_params, new_opt, new_res, {"loss": loss, **stats}

    shardings = {
        "params": jax.tree.map(s, pspecs),
        "opt": jax.tree.map(s, ospecs, is_leaf=lambda x: isinstance(x, P)),
        "batch": jax.tree.map(s, bspecs),
        "residuals": jax.tree.map(s, res_specs, is_leaf=lambda x: isinstance(x, P)),
        "pspecs": pspecs,
        "res_example": res_example,
    }
    step_fn = jax.jit(
        train_step,
        in_shardings=(shardings["params"], shardings["opt"],
                      shardings["residuals"], shardings["batch"], None),
        out_shardings=(shardings["params"], shardings["opt"],
                       shardings["residuals"], None),
        donate_argnums=(0, 1, 2),
    )
    # build-time exchange gauges + per-call span/latency series
    # (DESIGN.md §11); the wrapper forwards .lower()/.trace() so dryrun's
    # AOT path is untouched
    reg = obs_metrics.get_registry()
    lbl = {"mode": mode.value if hasattr(mode, "value") else str(mode)}
    reg.gauge("train.exchange.k_fraction", **lbl).set(k_fraction)
    reg.gauge("train.exchange.fpe_capacity", **lbl).set(fpe_capacity)
    if plan is not None:
        reg.gauge("train.exchange.scarce_link_bytes",
                  **lbl).set(plan.scarce_link_bytes)
    step_fn = obs_metrics.instrument_step(step_fn, name="train.step",
                                          labels=lbl)
    return step_fn, shardings
