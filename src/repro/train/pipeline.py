"""GPipe-style pipeline parallelism over a mesh axis (optional at 512 chips).

The assigned models fit on the production mesh with DP x TP (+FSDP), so the
default schedules do not use PP — but at 1000+-node scale (or >400B dense
models) a stage axis becomes necessary. This module provides the schedule
as a composable building block:

  * layers are split into ``n_stages`` contiguous groups; each stage's
    params live on one slice of the ``stage`` mesh axis;
  * a microbatch stream flows stage-to-stage via ``jax.lax.ppermute``
    (the TPU-native neighbor transfer — ICI point-to-point);
  * the classic GPipe bubble: stages idle for (S-1) of (M + S - 1) ticks;
    utilization = M / (M + S - 1), so callers pick M >> S.

Runs inside ``jax.shard_map`` manual over the stage axis. Exercised by
tests/drivers/pipeline_driver.py on an 8-device mesh; at production scale
the same function takes ``stage`` as the leading mesh axis.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.collectives import shard_map_compat


def gpipe_forward(
    x_micro: jnp.ndarray,  # [M, mb, ...] microbatch stream (fed to stage 0)
    stage_fn: Callable,  # (stage_params, x) -> x — one stage's layers
    stage_params: Any,  # this stage's parameter shard
    *,
    axis: str,
    n_stages: int,
) -> jnp.ndarray:
    """Run the GPipe schedule; returns the stage-(S-1) output stream.

    Must be called inside shard_map manual over ``axis``. Each device holds
    ``stage_params`` for ITS stage; microbatches enter at stage 0 and the
    finished stream is broadcast back to all stages at the end.
    """
    m = x_micro.shape[0]
    sid = jax.lax.axis_index(axis)
    ticks = m + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def tick(carry, t):
        buf, outs = carry  # buf: activation resident on this stage
        mb_idx = t - sid  # which microbatch this stage sees at tick t
        active = (mb_idx >= 0) & (mb_idx < m)
        feed = jax.lax.dynamic_index_in_dim(
            x_micro, jnp.clip(t, 0, m - 1), axis=0, keepdims=False)
        cur = jnp.where(sid == 0, feed, buf)
        y = stage_fn(stage_params, cur)
        y = jnp.where(active, y, buf)  # idle ticks keep the buffer
        # the last stage emits its finished microbatch into the output slot
        out_idx = jnp.clip(mb_idx, 0, m - 1)
        emit = active & (sid == n_stages - 1)
        outs = jax.lax.cond(
            emit,
            lambda o: jax.lax.dynamic_update_index_in_dim(o, y, out_idx, 0),
            lambda o: o,
            outs,
        )
        nxt = jax.lax.ppermute(y, axis, perm) if n_stages > 1 else y
        return (nxt, outs), None

    buf0 = jnp.zeros_like(x_micro[0])
    outs0 = jnp.zeros_like(x_micro)
    (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
    # results live on the last stage; hand every stage the same stream
    # (zero-mask + psum = broadcast from the last stage)
    if n_stages > 1:
        outs = jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs))
        outs = jax.lax.psum(outs, axis)
    return outs


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """GPipe idle fraction: (S-1)/(M+S-1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def make_gpipe_fn(mesh, stage_axis: str, n_stages: int, stage_fn: Callable):
    """jit-ready wrapper: (stacked_stage_params, x_micro) -> outputs.

    ``stacked_stage_params``: every leaf has leading dim n_stages, sharded
    over the stage axis (prefix spec); ``x_micro`` [M, mb, ...] replicated.
    """

    def region(params_stacked, x_micro):
        mine = jax.tree.map(lambda p: p[0], params_stacked)  # local stage
        return gpipe_forward(x_micro, stage_fn, mine, axis=stage_axis,
                             n_stages=n_stages)

    mapped = shard_map_compat(
        region,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),  # prefix spec for the params pytree
        out_specs=P(),
        axis_names={stage_axis},
        check_vma=False,
    )
    return jax.jit(mapped)
