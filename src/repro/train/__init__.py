from .step import TrainProfile, build_serve_step, build_train_step, build_prefill_step

__all__ = ["TrainProfile", "build_train_step", "build_serve_step", "build_prefill_step"]
