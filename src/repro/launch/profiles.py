"""Per-(arch x shape x mesh) distribution profiles + abstract input specs.

``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation — the
contract the dry-run lowers against.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.configs.base import InputShape, ModelConfig
from repro.core.collectives import GradAggMode
from repro.train.step import TrainProfile

# archs whose params are too big for plain TP storage -> FSDP + int8 opt
_HEAVY = {"jamba-1.5-large-398b", "deepseek-v2-236b"}
_QUANT_OPT = {"jamba-1.5-large-398b", "deepseek-v2-236b", "qwen3-32b"}


def mesh_dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh, dp_axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in dp_axes:
        n *= sizes[a]
    return n


def _accum_steps(cfg: ModelConfig, shape: InputShape, dp: int) -> int:
    b_local = max(1, shape.global_batch // dp)
    if cfg.n_groups <= 24:
        budget = 16384
    elif cfg.n_groups <= 48:
        budget = 8192
    else:
        budget = 4096
    want = max(1, (b_local * shape.seq_len) // budget)
    # accum must divide the local batch
    while b_local % want:
        want -= 1
    return max(1, want)


def _fit_chunk(total: int, want: int) -> int:
    """Largest chunk <= want that divides ``total`` (prefer x128 alignment)."""
    want = min(want, total)
    for c in range(want - want % 128, 0, -128):
        if total % c == 0:
            return c
    for c in range(min(want, total), 0, -1):
        if total % c == 0:
            return c
    return total


def make_profile(
    arch: str, shape: InputShape, mesh, *, mode: GradAggMode = GradAggMode.TREE,
    q_chunk: int | None = None, k_chunk: int | None = None,
    accum: int | None = None, seq_shard: bool = False,
) -> TrainProfile:
    cfg = configs.get_config(arch)
    dp_axes = mesh_dp_axes(mesh)
    dp = _dp_size(mesh, dp_axes)
    if accum is None:
        accum = _accum_steps(cfg, shape, dp) if shape.kind == "train" else 1
    # attention chunks must divide the full sequence incl. modality prefix
    # (paligemma: 4096 tokens + 256 patches = 4352 = 17 x 256)
    s_total = shape.seq_len + cfg.prefix_tokens
    return TrainProfile(
        dp_axes=dp_axes,
        tp_axis="model",
        fsdp=arch in _HEAVY,
        accum_steps=accum,
        quantized_opt=arch in _QUANT_OPT,
        master_fp32=True,
        remat="full" if shape.kind == "train" else "none",
        q_chunk=_fit_chunk(s_total, q_chunk or 512),
        k_chunk=_fit_chunk(s_total, k_chunk or 1024),
        moe_token_chunk=4096,
        mode=mode,
        seq_shard=seq_shard,
    )


def serve_plan(arch: str, shape: InputShape, mesh) -> dict:
    """Decode-cell choices: batch shardability + cache-seq sharding axes."""
    cfg = configs.get_config(arch)
    dp_axes = mesh_dp_axes(mesh)
    dp = _dp_size(mesh, dp_axes)
    batch_shardable = shape.global_batch % dp == 0 and shape.global_batch >= dp
    if shape.name == "long_500k":
        cache_seq_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    else:
        cache_seq_axes = ("model",)
    if cfg.family == "ssm":
        cache_seq_axes = ()  # no attention caches at all
    return {"batch_shardable": batch_shardable, "cache_seq_axes": cache_seq_axes}


def input_specs(arch: str, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for a global batch of this shape."""
    cfg = configs.get_config(arch)
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct

    if shape.kind == "train":
        batch = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        if cfg.frontend == "vision_stub":
            batch["patch_embeds"] = sds((b, cfg.prefix_tokens, cfg.d_model), f32)
        elif cfg.frontend == "audio_stub":
            batch["frame_embeds"] = sds((b, s, cfg.d_model), f32)
            del batch["tokens"]
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((b, s), i32)}
        if cfg.frontend == "vision_stub":
            batch["patch_embeds"] = sds((b, cfg.prefix_tokens, cfg.d_model), f32)
        elif cfg.frontend == "audio_stub":
            batch["frame_embeds"] = sds((b, s, cfg.d_model), f32)
            del batch["tokens"]
        return batch
    # decode: one new token against a cache of seq_len
    if cfg.frontend == "audio_stub":
        return {"token": sds((b, 1, cfg.d_model), f32)}
    return {"token": sds((b, 1), i32)}
