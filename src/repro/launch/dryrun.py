import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh; record memory analysis, cost analysis, and the collective
schedule for the roofline.

Usage:
  python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --multi-pod both
  python -m repro.launch.dryrun ... --mode flat --out benchmarks/artifacts
"""

import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.configs.base import shape_by_name
from repro.core import dataplane, planner
from repro.core.collectives import GradAggMode
from repro.launch import hlo_analysis as ha
from repro.launch import hlo_cost
from repro.launch import profiles
from repro.launch.mesh import make_production_mesh
from repro.models.model import LMModel
from repro.optim import AdamWConfig, adamw_init, make_lr_schedule
from repro.train.step import (
    TrainProfile,
    build_prefill_step,
    build_serve_step,
    build_train_step,
)

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "artifacts")


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               mode: str = "tree", q_chunk: int | None = None,
               k_chunk: int | None = None, accum: int | None = None,
               seq_shard: bool = False, post_accum: bool = False,
               wire_bf16: bool = False, k_fraction: float = 0.01):
    """Returns (lowered, mesh, cfg, shape, meta). No device allocation."""
    cfg = configs.get_config(arch)
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    prof = profiles.make_profile(arch, shape, mesh, mode=GradAggMode(mode),
                                 q_chunk=q_chunk, k_chunk=k_chunk,
                                 accum=accum, seq_shard=seq_shard)
    model = LMModel(cfg)
    params_sds = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    batch_sds = profiles.input_specs(arch, shape)
    # the controller's gradient-exchange plan: mode, level ordering, and
    # modeled per-level traffic (fpe=0 keeps the exact sorted-combine node).
    # Only train cells run an exchange; serve cells carry no plan.
    grad_plan = None
    dp_report = None
    if shape.kind == "train":
        grad_plan = planner.plan_grad_exchange(
            mesh, mode=GradAggMode(mode), grad_bytes=4 * cfg.param_count(),
            k_fraction=k_fraction, combiner_budget_pairs=0,
            reduce_axes=("data", "pod"))
        # dataplane validation: run a small synthetic KV stream through the
        # plan's cascade and record per-level predicted (Eq. 3) vs simulated
        # reduction ratio (DESIGN.md §6).  A bounded sibling plan shows the
        # capacity-limited regime next to the plan's exact (capacity=0) one.
        cascade = dataplane.cascade_from_exchange_plan(grad_plan, op="sum")
        dp_report = dataplane.simulate_plan(
            cascade, data_amount=4096, key_variety=512)
        bounded_cap = 128  # the capacity-limited regime, shared with JCT sim
        bounded = dataplane.CascadePlan(
            op="sum", levels=tuple(
                dataplane.LevelSpec(capacity=bounded_cap)
                for _ in cascade.levels))
        dp_report["bounded_c128"] = dataplane.simulate_plan(
            bounded, data_amount=4096, key_variety=512)["levels"]
        # packet-level JCT measurement (DESIGN.md §7): stream a small Zipf
        # KV job through the plan's full tree on the emulated network and
        # record in-network vs host-only completion time (paper Fig. 10).
        import math

        import numpy as np

        from repro.core import reduction_model as rm
        from repro.core import tree as tree_lib
        from repro.net import sim as netsim

        fanins = grad_plan.fanins
        axes = (grad_plan.leaf_axis, *grad_plan.upper_axes)
        gbps = tuple(tree_lib.DCN_GBPS if ax == "pod" else tree_lib.ICI_GBPS
                     for ax in axes)
        n_mappers = math.prod(fanins)
        sim_keys = rm.zipf_keys(64 * n_mappers, 512, seed=0)
        jct = netsim.jct_comparison(
            sim_keys, np.ones((sim_keys.size,), np.float32),
            fanins=fanins,
            plan=dataplane.CascadePlan(op="sum", levels=tuple(
                dataplane.LevelSpec(capacity=bounded_cap) for _ in fanins)),
            cfg=netsim.NetConfig(link_gbps=gbps), axes=axes)
        dp_report["jct"] = {
            "jct_switchagg_s": jct["jct_switchagg_s"],
            "jct_host_only_s": jct["jct_host_only_s"],
            "jct_saved": round(jct["jct_saved"], 4),
            "reducer_traffic_cut": round(jct["reduction"], 4),
        }
    meta = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "mode": mode, "accum": prof.accum_steps, "fsdp": prof.fsdp,
            "quant_opt": prof.quantized_opt, "seq_shard": seq_shard,
            "post_accum": post_accum, "wire_bf16": wire_bf16,
            "plan": None if grad_plan is None else {
                "leaf_axis": grad_plan.leaf_axis,
                "upper_axes": list(grad_plan.upper_axes),
                "fanins": list(grad_plan.fanins),
                "op": grad_plan.op,
                "k_fraction": grad_plan.k_fraction,
                "fpe_capacity": grad_plan.fpe_capacity,
                "level_bytes": [round(b, 1) for b in grad_plan.level_bytes],
                "scarce_link_bytes": round(grad_plan.scarce_link_bytes, 1),
                "predicted_root_reduction": round(
                    grad_plan.predicted_root_reduction, 4),
                "dataplane": dp_report,
            }}

    manual = post_accum or mode == "tree_compress"
    if shape.kind == "train" and manual:
        # post-accum manual exchange (shard_map region; see train/compressed)
        import dataclasses as _dc

        from repro.train.compressed import build_compressed_train_step

        # manual region wants cheap-first dp ordering (data before pod)
        dp = tuple(a for a in ("data", "pod") if a in mesh.axis_names)
        prof = _dc.replace(prof, dp_axes=dp)
        opt_cfg = AdamWConfig(quantized=prof.quantized_opt,
                              master_fp32=prof.master_fp32)
        lr_fn = make_lr_schedule(3e-4, 100, 10000)
        # mode / k / fpe capacity come from the controller's plan; the
        # post-accum tree case overrides the requested mode to exact TREE
        xplan = grad_plan if mode == "tree_compress" else _dc.replace(
            grad_plan, mode=GradAggMode.TREE)
        step_fn, sh = build_compressed_train_step(
            cfg, mesh, prof, opt_cfg, lr_fn,
            batch_example=batch_sds, params_example=params_sds,
            plan=xplan,
            wire_dtype=jnp.bfloat16 if wire_bf16 else None,
        )
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
        res_sds = jax.eval_shape(lambda: sh["res_example"])
        lowered = step_fn.lower(params_sds, opt_sds, res_sds, batch_sds,
                                jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.kind == "train":
        opt_cfg = AdamWConfig(quantized=prof.quantized_opt,
                              master_fp32=prof.master_fp32)
        lr_fn = make_lr_schedule(3e-4, 100, 10000)
        step_fn, shardings, _ = build_train_step(
            cfg, mesh, prof, opt_cfg, lr_fn,
            batch_example=batch_sds, params_example=params_sds,
        )
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
        lowered = step_fn.lower(params_sds, opt_sds, batch_sds,
                                jax.ShapeDtypeStruct((), jnp.int32))
    elif shape.kind == "prefill":
        plan = profiles.serve_plan(arch, shape, mesh)
        fn, shardings, _ = build_prefill_step(
            cfg, mesh, prof, cache_len=shape.seq_len,
            batch_example=batch_sds, params_example=params_sds,
            batch_shardable=plan["batch_shardable"],
            cache_seq_axes=plan["cache_seq_axes"],
        )
        lowered = fn.lower(params_sds, batch_sds)
    else:  # decode
        plan = profiles.serve_plan(arch, shape, mesh)
        fn, shardings, model2 = build_serve_step(
            cfg, mesh, prof, cache_len=shape.seq_len, batch=shape.global_batch,
            params_example=params_sds,
            batch_shardable=plan["batch_shardable"],
            cache_seq_axes=plan["cache_seq_axes"],
        )
        cache_sds = jax.eval_shape(
            lambda: model2.init_caches(shape.global_batch, shape.seq_len,
                                       jnp.dtype(cfg.dtype))
        )
        tok = batch_sds["token"]
        lowered = fn.lower(params_sds, cache_sds, tok,
                           jax.ShapeDtypeStruct((), jnp.int32))
        meta.update(plan)
    return lowered, mesh, cfg, shape, meta, prof


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             mode: str = "tree", dump_hlo: bool = False,
             q_chunk: int | None = None, k_chunk: int | None = None,
             tag: str = "", accum: int | None = None,
             seq_shard: bool = False, post_accum: bool = False,
             wire_bf16: bool = False, k_fraction: float = 0.01) -> dict:
    t0 = time.time()
    lowered, mesh, cfg, shape, meta, prof = lower_cell(
        arch, shape_name, multi_pod, mode, q_chunk, k_chunk,
        accum=accum, seq_shard=seq_shard, post_accum=post_accum,
        wire_bf16=wire_bf16, k_fraction=k_fraction)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()  # raw XLA numbers (loop bodies counted once)
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    walk = hlo_cost.analyze(hlo, mesh)  # trip-count-aware
    coll = ha.collectives_from_events(walk["coll"], mesh)
    n_chips = mesh.devices.size
    model_flops = ha.model_flops_for(cfg, shape)
    roof = ha.roofline_terms(
        hlo_flops=walk["flops"],
        hlo_bytes=walk["bytes"],
        coll=coll, n_chips=n_chips, model_flops=model_flops / n_chips,
    )
    # structural (model-derived) terms — the headline roofline; the HLO
    # walker over-multiplies XLA:CPU "wide" loop bodies (see structural.py)
    from repro.launch.structural import structural_cost

    sc = structural_cost(cfg, shape, mesh, prof)
    roof_struct = ha.roofline_terms(
        hlo_flops=sc.flops, hlo_bytes=sc.bytes,
        coll=coll, n_chips=n_chips, model_flops=model_flops / n_chips,
    )

    result = {
        **meta,
        "n_chips": n_chips,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_per_device": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost_xla_raw": {k: float(v) for k, v in cost.items()
                 if k in ("flops", "bytes accessed", "transcendentals")},
        "cost": {"flops": walk["flops"], "bytes": walk["bytes"],
                 "transcendentals": walk["transcendentals"]},
        "collectives": {
            "ici_bytes": coll.ici_bytes,
            "dcn_bytes": coll.dcn_bytes,
            "by_op": coll.by_op,
            "n_ops": len(coll.ops),
        },
        "roofline": roof.to_dict(),
        "roofline_structural": roof_struct.to_dict(),
        "structural_detail": {k: [float(f), float(b)]
                              for k, (f, b) in sc.detail.items()},
        "model_flops_global": model_flops,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    os.makedirs(out_dir, exist_ok=True)
    pod_tag = "pod2" if multi_pod else "pod1"
    name = f"{arch}__{shape_name}__{pod_tag}__{mode}{tag}"
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    if dump_hlo:
        with gzip.open(os.path.join(out_dir, name + ".hlo.gz"), "wt") as f:
            f.write(hlo)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=["0", "1", "both"], default="both")
    ap.add_argument("--mode", default="tree",
                    choices=[m.value for m in GradAggMode])
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--sp", action="store_true", help="sequence-parallel")
    ap.add_argument("--post-accum", action="store_true",
                    help="manual-region exchange once after accumulation")
    ap.add_argument("--wire-bf16", action="store_true")
    ap.add_argument("--k-fraction", type=float, default=0.01)
    ap.add_argument("--out", default=os.path.normpath(DEFAULT_OUT))
    ap.add_argument("--dump-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--k-chunk", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="enable span tracing + metrics and write "
                         "trace.json / metrics.json / dashboard.{md,html} "
                         "to DIR (DESIGN.md §11)")
    args = ap.parse_args()

    if args.trace:
        from repro.obs import trace as obs_trace
        obs_trace.enable()

    archs = list(configs.ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = ([s.name for s in configs.ALL_SHAPES] if args.shape == "all"
              else [args.shape])
    pods = {"0": [False], "1": [True], "both": [False, True]}[args.multi_pod]

    results = []
    for arch in archs:
        for shape_name in shapes:
            if (shape_name == "long_500k"
                    and arch not in configs.LONG_CONTEXT_ARCHS):
                print(f"SKIP(full-attn) {arch} x {shape_name}")
                continue
            for mp in pods:
                pod_tag = "pod2" if mp else "pod1"
                fname = os.path.join(
                    args.out,
                    f"{arch}__{shape_name}__{pod_tag}__{args.mode}{args.tag}.json")
                if args.skip_existing and os.path.exists(fname):
                    print(f"EXISTS {arch} x {shape_name} x {pod_tag}")
                    continue
                label = f"{arch} x {shape_name} x {pod_tag} x {args.mode}"
                try:
                    from repro.obs import trace as obs_trace
                    with obs_trace.get_tracer().span(
                            f"dryrun {label}", cat="dryrun"):
                        r = run_cell(
                            arch, shape_name, mp, args.out, args.mode,
                            args.dump_hlo, args.q_chunk, args.k_chunk,
                            args.tag, accum=args.accum, seq_shard=args.sp,
                            post_accum=args.post_accum,
                            wire_bf16=args.wire_bf16,
                            k_fraction=args.k_fraction)
                    rf = r["roofline"]
                    pl = r.get("plan")
                    plan_txt = ""
                    if pl:
                        order = " -> ".join([pl["leaf_axis"],
                                             *pl["upper_axes"]])
                        plan_txt = (
                            f" plan=[{order}] "
                            f"scarce={pl['scarce_link_bytes']/2**20:.1f}MiB "
                            f"(cut {pl['predicted_root_reduction']:.1%})")
                        dp = pl.get("dataplane")
                        if dp:
                            lv = "/".join(
                                f"{l['reduction']:.2f}~{l['predicted_reduction']:.2f}"
                                for l in dp["levels"])
                            plan_txt += f" dp[sim~eq3]={lv}"
                            if "jct" in dp:
                                plan_txt += (
                                    f" jct_cut={dp['jct']['jct_saved']:.0%}")
                    print(f"OK {label}: compile={r['compile_s']}s "
                          f"mem/dev={r['memory']['total_per_device']/2**30:.2f}GiB "
                          f"compute={rf['compute_s']:.4f}s mem={rf['memory_s']:.4f}s "
                          f"coll={rf['collective_s']:.4f}s dom={rf['dominant']}"
                          f"{plan_txt}",
                          flush=True)
                    results.append(r)
                except Exception as e:
                    print(f"FAIL {label}: {e}", flush=True)
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape_name,
                                    "multi_pod": mp, "ok": False,
                                    "error": str(e)})
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells OK")
    if args.trace:
        from repro.obs import report as obs_report
        paths = obs_report.write_obs_artifacts(
            args.trace, title="dryrun observability")
        print("obs artifacts: " + " ".join(sorted(paths.values())))
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
