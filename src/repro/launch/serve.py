"""Production serving driver: batched prefill + decode with TP-sharded
weights and model-axis-sharded KV caches (flash-decode combine).

CPU example:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python -m repro.launch.serve --arch gemma2-27b --reduce \\
      --mesh 2,4 --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.configs.reduced import reduced_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import LMModel
from repro.train.step import TrainProfile, build_prefill_step, build_serve_step

log = logging.getLogger("repro.launch.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b", choices=configs.ARCH_IDS)
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--fp32", action="store_true")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = reduced_config(args.arch) if args.reduce else configs.get_config(args.arch)
    if args.fp32:
        cfg = dataclasses.replace(cfg, dtype="float32")

    from repro.launch.train import parse_mesh

    mesh = parse_mesh(args.mesh)
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                      for a in dp_axes])) if dp_axes else 1
    shardable = args.batch % max(dp, 1) == 0 and args.batch >= dp
    prof = TrainProfile(dp_axes=dp_axes, tp_axis="model",
                        q_chunk=8, k_chunk=8, moe_token_chunk=64, remat="none")
    cache_len = cfg.prefix_tokens + args.prompt_len + args.gen

    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLMData(cfg, DataConfig(seq_len=args.prompt_len,
                                           global_batch=args.batch, seed=1))
    batch = data.prompt_at(0, args.prompt_len)

    prefill_fn, shp, _ = build_prefill_step(
        cfg, mesh, prof, cache_len=cache_len, batch_example=batch,
        params_example=params, batch_shardable=shardable,
        cache_seq_axes=("model",))
    serve_fn, shs, _ = build_serve_step(
        cfg, mesh, prof, cache_len=cache_len, batch=args.batch,
        params_example=params, batch_shardable=shardable,
        cache_seq_axes=("model",))

    t0 = time.time()
    logits, caches = prefill_fn(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]

    out = [np.asarray(tok[:, 0])]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok, caches = serve_fn(params, caches, tok,
                               jnp.asarray(cfg.prefix_tokens + args.prompt_len + i,
                                           jnp.int32))
        out.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.stack(out, 1)
    log.info("prefill %d x %d tokens in %.3fs; decoded %d steps in %.3fs "
             "(%.1f tok/s/seq)", args.batch, args.prompt_len, t_prefill,
             args.gen - 1, t_decode, (args.gen - 1) / max(t_decode, 1e-9))
    log.info("generations (first 8 token-ids per sequence):\n%s", gen[:, :8])
    return gen


if __name__ == "__main__":
    main()
