"""Production mesh construction (TPU v5e-class pods).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets the fake device count before
any jax initialization).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on the mesh
    from jax.sharding import AxisType
except ImportError:  # older jax: make_mesh has no axis_types kwarg
    AxisType = None


def _make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions (axis_types grew in later releases)."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh_compat(shape, axes)


def make_mesh(shape, axes):
    return _make_mesh_compat(tuple(shape), tuple(axes))


# Hardware constants for the roofline (TPU v5e-class target).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (per direction), ~4 links/chip usable
ICI_LINKS = 4
DCN_BW = 6.25e9  # inter-pod bytes/s per chip (25 GbE-class share x2)

def topology_from_mesh(mesh, *, reduce_axes=("data", "pod"),
                       scarce_budget_bytes: float = float("inf")):
    """The mesh as a scheduler `Topology` (DESIGN.md §3).

    ``scarce_budget_bytes`` bounds the bytes one exchange round may put on
    the scarcest (inter-pod) level across ALL concurrent jobs.  Per-axis
    bandwidths come from the canonical table in ``core/tree.py``
    (`Topology.from_mesh`'s default).
    """
    from repro.core.planner import Topology

    return Topology.from_mesh(mesh, reduce_axes=reduce_axes,
                              scarce_budget_bytes=scarce_budget_bytes)
