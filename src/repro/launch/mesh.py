"""Production mesh construction (TPU v5e-class pods).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets the fake device count before
any jax initialization).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


# Hardware constants for the roofline (TPU v5e-class target).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (per direction), ~4 links/chip usable
ICI_LINKS = 4
DCN_BW = 6.25e9  # inter-pod bytes/s per chip (25 GbE-class share x2)
