"""Trip-count-aware FLOP/byte accounting over optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop (lax.scan) body ONCE —
useless for scan-over-layers models where >90% of compute sits inside loops.
This walker parses the partitioned HLO, builds a per-computation symbol
table, scores dots/elementwise/reduces, and multiplies loop bodies by their
trip counts (recovered from the loop condition's comparison constant).

Validated against unrolled references in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OP_RE = re.compile(r"^\s*([\w\-]+)\(")


def parse_instr(line: str):
    """Parse '%name = SHAPE op(rest' robustly (tuple shapes may contain
    /*index=N*/ comments, so regexes over the shape are unsafe)."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):  # tuple shape: scan to the matching paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape, rest = rest[: i + 1], rest[i + 1 :]
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, rest = rest[:sp], rest[sp:]
    mo = _OP_RE.match(rest)
    if not mo:
        return None
    return name, shape, mo.group(1), rest[mo.end():]
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "not", "negate", "abs", "sign", "compare", "select",
    "clamp", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
}
_TRANSCEND = {"exponential", "log", "tanh", "rsqrt", "sqrt", "logistic",
              "cosine", "sine", "expm1", "log1p", "atan2", "cbrt",
              "exponential-minus-one"}
_FREE = {
    "parameter", "constant", "broadcast", "reshape", "bitcast", "transpose",
    "copy", "tuple", "get-tuple-element", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "convert", "iota", "reverse",
    "gather", "scatter", "pad", "after-all", "partition-id", "replica-id",
    "rng", "rng-bit-generator", "custom-call", "infeed", "outfeed",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "bitcast-convert", "copy-start", "copy-done",
    "all-reduce-start", "all-reduce-done", "optimization-barrier", "domain",
}


def _shape_elems(shape_str: str) -> int:
    total = 0
    for _, dims in _SHAPE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}

_GROUPS_LIT_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=(?:\[([\d,]+)\])?(?:T\(([\d,]+)\))?"
)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    # collective events: key "op|ax1,ax2|group_size" -> per-device tensor bytes
    coll: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.transcendentals += o.transcendentals
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.transcendentals * k,
                    {kk: v * k for kk, v in self.coll.items()})


class HloCostModel:
    def __init__(self, hlo_text: str, mesh_shape=None, axis_names=None):
        self.computations = self._split_computations(hlo_text)
        self.mesh_shape = tuple(mesh_shape) if mesh_shape else None
        self.axis_names = tuple(axis_names) if axis_names else None
        self._cost_cache: dict[str, Cost] = {}
        self._trip_cache: dict[str, int] = {}

    def _first_group(self, line: str):
        m = _GROUPS_LIT_RE.search(line)
        if m:
            first = m.group(1).split("},{")[0].strip("{}")
            return [int(x) for x in first.split(",") if x]
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            import numpy as np

            g, s = int(m.group(1)), int(m.group(2))
            dims = [int(x) for x in m.group(3).split(",")] if m.group(3) else [g * s]
            ids = np.arange(int(np.prod(dims))).reshape(dims)
            if m.group(4):
                perm = [int(x) for x in m.group(4).split(",")]
                ids = ids.transpose(perm)
            return ids.reshape(g, s)[0].tolist()
        return None

    def _axes_of(self, group) -> tuple[str, ...]:
        if self.mesh_shape is None or group is None:
            return ("?",)
        import numpy as np

        coords = np.array(np.unravel_index(np.array(group), self.mesh_shape)).T
        return tuple(
            n for i, n in enumerate(self.axis_names)
            if len(set(coords[:, i].tolist())) > 1
        )

    @staticmethod
    def _split_computations(text: str) -> dict[str, list[str]]:
        comps: dict[str, list[str]] = {}
        cur_name, cur_lines = None, []
        for line in text.splitlines():
            stripped = line.strip()
            if cur_name is None:
                m = _COMP_HDR.match(stripped)
                if m and stripped.endswith("{"):
                    cur_name = m.group(1)
                    cur_lines = []
                continue
            if stripped == "}":
                comps[cur_name] = cur_lines
                cur_name = None
            else:
                cur_lines.append(line)
        return comps

    def _trip_count(self, cond_name: str) -> int:
        if cond_name in self._trip_cache:
            return self._trip_cache[cond_name]
        lines = self.computations.get(cond_name, [])
        consts = [int(c) for l in lines for c in _CONST_S32.findall(l)]
        trip = max(consts) if consts else 1
        self._trip_cache[cond_name] = max(trip, 1)
        return self._trip_cache[cond_name]

    _ZERO_BYTES = {
        "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
        "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
        "reshape", "optimization-barrier", "domain", "copy-start", "copy-done",
    }
    _MOVE_BYTES = {  # pure data movement: ~read + write of the output
        "copy", "transpose", "slice", "dynamic-slice", "concatenate",
        "gather", "broadcast", "reverse", "pad", "rng-bit-generator",
    }

    def _instr_cost(self, shape_str: str, op: str, rest: str,
                    symtab: dict[str, str]) -> Cost:
        out_elems = _shape_elems(shape_str)
        operands = []
        head = rest.split("),", 1)[0] if ")," in rest else rest.rstrip(")")
        for name in _OPERAND.findall(head):
            if name in symtab:
                operands.append(symtab[name])
        in_bytes = sum(_shape_bytes(s) for s in operands)
        if op in self._ZERO_BYTES:
            bytes_ = 0.0
        elif op in self._MOVE_BYTES:
            bytes_ = 2.0 * _shape_bytes(shape_str)
        elif op == "dynamic-update-slice":
            upd = _shape_bytes(operands[1]) if len(operands) > 1 else 0
            bytes_ = 2.0 * upd  # in-place: read slice region + write update
        else:
            bytes_ = _shape_bytes(shape_str) + in_bytes
        c = Cost(bytes=bytes_)

        if op == "dot":
            contract = 1
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
            if m and operands:
                lhs_dims = _shape_dims(operands[0])
                for i in m.group(1).split(","):
                    if i and int(i) < len(lhs_dims):
                        contract *= lhs_dims[int(i)]
            c.flops = 2.0 * out_elems * contract
        elif op == "convolution":
            # rough: 2 * out * (kernel elems per output)
            kern = _shape_elems(operands[1]) if len(operands) > 1 else 1
            out_ch = _shape_dims(shape_str)[-1] if _shape_dims(shape_str) else 1
            c.flops = 2.0 * out_elems * max(kern // max(out_ch, 1), 1)
        elif op in _ELEMWISE:
            c.flops = float(out_elems)
        elif op in _TRANSCEND:
            c.flops = float(out_elems)
            c.transcendentals = float(out_elems)
        elif op in ("reduce", "reduce-window"):
            c.flops = float(sum(_shape_elems(s) for s in operands[:1]))
        elif op == "map":
            c.flops = float(out_elems)
        elif op in ("sort",):
            n = max(out_elems, 2)
            import math

            c.flops = n * math.log2(n)
        return c

    @lru_cache(maxsize=None)
    def computation_cost(self, name: str, fused: bool = False) -> Cost:
        """fused=True: computation is a fusion body — its internal ops never
        touch HBM, so only FLOPs/transcendentals count; bytes are charged at
        the fusion call site (operands + output)."""
        total = Cost()
        lines = self.computations.get(name, [])
        symtab: dict[str, str] = {}
        for line in lines:
            parsed = parse_instr(line)
            if parsed is None:
                continue
            iname, shape_str, op, rest = parsed
            symtab[iname] = shape_str
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", rest)
                mc = _COND.search(rest)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                mt = _TRIP_CFG.search(rest)
                if mt:
                    trip = int(mt.group(1))
                elif cond:
                    trip = self._trip_count(cond)
                else:
                    trip = 1
                if body:
                    total += self.computation_cost(body, fused).scaled(trip)
                if cond:
                    total += self.computation_cost(cond, fused).scaled(trip)
            elif op in ("fusion", "call", "conditional", "async-start"):
                inner_fused = fused or op == "fusion"
                for cname in _CALLS.findall(rest):
                    total += self.computation_cost(cname, inner_fused)
                if not fused:
                    # HBM traffic of the fused kernel: inputs + output
                    head = rest.split("),", 1)[0] if ")," in rest else rest.rstrip(")")
                    in_bytes = sum(
                        _shape_bytes(symtab[n]) for n in _OPERAND.findall(head)
                        if n in symtab
                    )
                    total += Cost(bytes=_shape_bytes(shape_str) + in_bytes)
            elif op in _COLLECTIVES:
                base = op.replace("-start", "")
                group = self._first_group(rest)
                if group and len(group) > 1:
                    axes = self._axes_of(group)
                    key = f"{base}|{','.join(axes)}|{len(group)}"
                    c = Cost(coll={key: float(_shape_bytes(shape_str))})
                    if not fused:
                        c.bytes = float(_shape_bytes(shape_str))
                    total += c
            else:
                c = self._instr_cost(shape_str, op, rest, symtab)
                if fused:
                    c.bytes = 0.0
                total += c
        return total

    def entry_cost(self) -> Cost:
        # the entry computation is conventionally named main.* (ENTRY)
        for name in self.computations:
            if name.startswith("main"):
                return self.computation_cost(name)
        # fallback: the largest computation
        best, best_cost = None, Cost()
        for name in self.computations:
            c = self.computation_cost(name)
            if c.flops >= best_cost.flops:
                best, best_cost = name, c
        return best_cost


def analyze(hlo_text: str, mesh=None) -> dict:
    mesh_shape = tuple(mesh.devices.shape) if mesh is not None else None
    axis_names = tuple(mesh.axis_names) if mesh is not None else None
    model = HloCostModel(hlo_text, mesh_shape, axis_names)
    c = model.entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "transcendentals": c.transcendentals,
        "coll": c.coll,
    }


def breakdown(hlo_text: str, top: int = 15) -> list[tuple]:
    """Debug/§Perf helper: biggest single-instruction flop contributors with
    their computation-level trip multipliers."""
    model = HloCostModel(hlo_text)

    # trip multiplier per computation: entry=1, while bodies *= trip
    mult: dict[str, float] = {}

    def visit(name: str, k: float):
        if mult.get(name, 0) >= k:
            return
        mult[name] = k
        for line in model.computations.get(name, []):
            parsed = parse_instr(line)
            if parsed is None:
                continue
            _, _, op, rest = parsed
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", rest)
                mc = _COND.search(rest)
                mt = _TRIP_CFG.search(rest)
                trip = (int(mt.group(1)) if mt
                        else model._trip_count(mc.group(1)) if mc else 1)
                if mb:
                    visit(mb.group(1), k * trip)
                if mc:
                    visit(mc.group(1), k * trip)
            elif op in ("fusion", "call", "conditional", "async-start"):
                for cname in _CALLS.findall(rest):
                    visit(cname, k)

    entry = next((n for n in model.computations if n.startswith("main")), None)
    if entry is None:
        return []
    visit(entry, 1.0)

    rows = []
    for cname, lines in model.computations.items():
        k = mult.get(cname, 0.0)
        if not k:
            continue
        symtab = {}
        for line in lines:
            parsed = parse_instr(line)
            if parsed is None:
                continue
            iname, shape_str, op, rest = parsed
            symtab[iname] = shape_str
            c = model._instr_cost(shape_str, op, rest, symtab)
            if c.flops:
                rows.append((c.flops * k, k, cname, op, shape_str[:60],
                             line.strip()[:140]))
    rows.sort(reverse=True)
    return rows[:top]
