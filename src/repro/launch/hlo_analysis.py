"""Collective-traffic and roofline analysis of compiled (SPMD) HLO.

``cost_analysis()`` gives HLO FLOPs and HBM bytes but NOT collective
traffic; this module parses the partitioned HLO text, decodes every
collective's replica groups (literal and iota ``[G,S]<=[dims]T(perm)``
forms), classifies which mesh axes each collective spans, and converts
tensor sizes to per-chip link bytes with the standard ring model:

  all-reduce      2 (S-1)/S x T        (T = per-device tensor bytes)
  all-gather      (S-1)/S x T_out
  reduce-scatter  (S-1)   x T_out
  all-to-all      (S-1)/S x T
  collective-permute  T

A collective spanning several mesh axes is charged hierarchically
(bandwidth-optimal decomposition, cheapest axis first) — charitable to the
flat baseline; the tree schedule needs no such charity since its levels are
separate HLO ops.  Bytes are then split per link level (ICI intra-pod vs
DCN inter-pod) for the roofline's collective term.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Iterable

import numpy as np

from .mesh import DCN_BW, HBM_BW, ICI_BW, ICI_LINKS, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>\([^=]*?\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_LIT_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=(?:\[([\d,]+)\])?(?:T\(([\d,]+)\))?"
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_group(line: str, n_devices: int) -> list[int] | None:
    m = _GROUPS_LIT_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        return [int(x) for x in first.split(",") if x]
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")] if m.group(3) else [g * s]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        ids = ids.reshape(g, s)
        return ids[0].tolist()
    return None


def _axes_spanned(group: list[int], mesh_shape: tuple[int, ...], axis_names) -> tuple[str, ...]:
    coords = np.array(np.unravel_index(np.array(group), mesh_shape)).T  # [S, n_axes]
    spanned = []
    for i, name in enumerate(axis_names):
        if len(set(coords[:, i].tolist())) > 1:
            spanned.append(name)
    return tuple(spanned)


@dataclasses.dataclass
class CollectiveStats:
    """Per-device link bytes by level + op census."""

    ici_bytes: float = 0.0  # intra-pod (data/model axes)
    dcn_bytes: float = 0.0  # inter-pod (pod axis)
    by_op: dict = dataclasses.field(default_factory=dict)
    ops: list = dataclasses.field(default_factory=list)

    @property
    def total_bytes(self) -> float:
        return self.ici_bytes + self.dcn_bytes


def collectives_from_events(coll_events: dict, mesh) -> CollectiveStats:
    """Convert walker events {"op|axes|gsize": tensor_bytes} to link bytes.

    Events come from the trip-count-aware HLO walker (hlo_cost), so
    collectives inside scan bodies are already multiplied out.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    stats = CollectiveStats()
    for key, t_bytes in coll_events.items():
        op, axes_s, gsize = key.split("|")
        spanned = tuple(a for a in axes_s.split(",") if a)
        if not spanned:
            continue
        order = [a for a in ("model", "data", "pod") if a in spanned]
        if not order:  # unknown axes — treat as ICI at full size
            stats.ici_bytes += t_bytes
            continue
        shard = float(t_bytes)
        per_level: dict[str, float] = {}
        for ax in order:
            f = sizes[ax]
            if op == "all-reduce":
                level = 2.0 * (f - 1) / f * shard
                shard = shard / f
            elif op == "all-gather":
                level = (f - 1) / f * float(t_bytes)  # output-sized
            elif op == "reduce-scatter":
                level = (f - 1) * float(t_bytes)  # output is the shard
            elif op == "all-to-all":
                level = (f - 1) / f * float(t_bytes)
            else:  # collective-permute
                level = float(t_bytes)
            per_level[ax] = per_level.get(ax, 0.0) + level
        for ax, b in per_level.items():
            if ax == "pod":
                stats.dcn_bytes += b
            else:
                stats.ici_bytes += b
        stats.by_op[op] = stats.by_op.get(op, 0.0) + sum(per_level.values())
        stats.ops.append(
            {"op": op, "bytes": t_bytes, "group_size": int(gsize), "axes": spanned}
        )
    return stats


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_ici_s: float
    collective_dcn_s: float
    hlo_flops: float
    hlo_bytes: float
    coll_ici_bytes: float
    coll_dcn_bytes: float
    model_flops: float
    n_chips: int

    @property
    def collective_s(self) -> float:
        return self.collective_ici_s + self.collective_dcn_s

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Model-useful compute time / achievable step time (bound).

        ``model_flops`` is PER-DEVICE (callers divide the global 6ND by
        n_chips), so the ideal time is model_flops / peak — not divided by
        n_chips again.
        """
        ideal = self.model_flops / PEAK_FLOPS_BF16
        bound = max(self.compute_s, self.memory_s, self.collective_s)
        return ideal / bound if bound else 0.0

    def to_dict(self) -> dict:
        return {
            **dataclasses.asdict(self),
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_terms(
    *, hlo_flops: float, hlo_bytes: float, coll: CollectiveStats,
    n_chips: int, model_flops: float,
) -> Roofline:
    """cost_analysis flops/bytes are per-device program totals (SPMD)."""
    return Roofline(
        compute_s=hlo_flops / PEAK_FLOPS_BF16,
        memory_s=hlo_bytes / HBM_BW,
        collective_ici_s=coll.ici_bytes / (ICI_BW * ICI_LINKS),
        collective_dcn_s=coll.dcn_bytes / DCN_BW,
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        coll_ici_bytes=coll.ici_bytes,
        coll_dcn_bytes=coll.dcn_bytes,
        model_flops=model_flops,
        n_chips=n_chips,
    )


def model_flops_for(cfg, shape, n_layers_active: int | None = None) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); decode: per step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
