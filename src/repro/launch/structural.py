"""Structural (model-derived) per-device FLOP/byte costs for the roofline.

Why this exists: the dry-run compiles on XLA:CPU, whose loop transforms
("wide" loop widening, body cloning) break text-level trip-count recovery —
the hlo_cost walker over-multiplies nested attention chunk loops by up to
~6x on some architectures (validated: olmoe walker/structural = 1.7x ~ remat
overhead; phi4 = 8.9x = wrong).  And ``compiled.cost_analysis()`` counts
loop bodies ONCE (under-counts scan-over-layers ~30-250x).  Since we own
the model code, the *executed* flops/bytes are exactly computable from the
config + shapes + execution plan — that is this module.  The HLO remains
the source of truth for the collective schedule (hlo_cost walker), whose
loops are simple (exchange sits outside the chunk loops).

All numbers are per-device-per-step, for the roofline terms:
    compute_s = flops / PEAK ; memory_s = bytes / HBM_BW.

Conventions:
  * bf16 params/activations (2B), fp32 master+moments (4B; int8+scale if
    quantized), fp32 gradients during accumulation.
  * flash attention computes FULL chunk products (masked), so local/causal
    attention flops count the chunk-rounded context, not the ideal half.
  * remat="full": backward recomputes the forward (fwd+bwd = 4 fwd-units
    of matmul flops, 2 of attention score flops are re-done too).
"""

from __future__ import annotations

import dataclasses
import math

from repro.configs.base import InputShape, LayerSpec, ModelConfig

BF16 = 2
F32 = 4


@dataclasses.dataclass
class StructuralCost:
    flops: float = 0.0        # per device per step
    bytes: float = 0.0        # HBM traffic per device per step
    detail: dict = dataclasses.field(default_factory=dict)

    def add(self, key: str, flops: float = 0.0, bytes_: float = 0.0):
        self.flops += flops
        self.bytes += bytes_
        f, b = self.detail.get(key, (0.0, 0.0))
        self.detail[key] = (f + flops, b + bytes_)


def _layer_list(cfg: ModelConfig) -> list[LayerSpec]:
    return list(cfg.prefix) + list(cfg.pattern) * cfg.n_groups


def _mat_params_per_layer(cfg: ModelConfig, spec: LayerSpec) -> tuple[float, float]:
    """(active matmul params, stored matmul params) of one layer."""
    d, hd = cfg.d_model, cfg.head_dim
    act = stored = 0.0
    if spec.mixer in ("attn", "attn_local"):
        if cfg.mla is not None:
            m = cfg.mla
            p = (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                 + d * (m.kv_lora_rank + m.qk_rope_dim)
                 + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
                 + cfg.n_heads * m.v_head_dim * d)
        else:
            p = d * cfg.n_heads * hd * 2 + 2 * d * cfg.n_kv_heads * hd
        act += p
        stored += p
    elif spec.mixer == "mamba":
        mc = cfg.mamba
        din = cfg.d_inner_mamba
        p = d * (2 * din + 2 * mc.n_groups * mc.d_state + cfg.n_mamba_heads) + din * d
        act += p
        stored += p
    if spec.ffn == "dense":
        act += 3 * d * cfg.d_ff
        stored += 3 * d * cfg.d_ff
    elif spec.ffn == "moe":
        mo = cfg.moe
        act += (mo.top_k + mo.n_shared) * 3 * d * mo.d_ff_expert + d * mo.n_experts
        stored += (mo.n_experts + mo.n_shared) * 3 * d * mo.d_ff_expert + d * mo.n_experts
    return act, stored


def _attn_ctx(spec: LayerSpec, cfg: ModelConfig, s_ctx: int, k_chunk: int) -> int:
    """Effective KV context a query attends to (chunk-rounded window)."""
    if spec.mixer == "attn_local" and cfg.window:
        return min(s_ctx, ((cfg.window + k_chunk - 1) // k_chunk + 1) * k_chunk)
    return s_ctx


def structural_cost(cfg: ModelConfig, shape: InputShape, mesh, prof) -> StructuralCost:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes.get(prof.tp_axis, 1)
    dp = 1
    for a in prof.dp_axes:
        dp *= sizes.get(a, 1)
    c = StructuralCost()
    d = cfg.d_model
    layers = _layer_list(cfg)
    train = shape.kind == "train"
    decode = shape.kind == "decode"

    if decode:
        tokens_dev = shape.global_batch / (dp if shape.global_batch >= dp else 1)
        s_ctx = shape.seq_len
    else:
        tokens_dev = shape.global_batch * shape.seq_len / dp + (
            cfg.prefix_tokens * shape.global_batch / dp)
        s_ctx = shape.seq_len + cfg.prefix_tokens

    # fwd/bwd multipliers
    if train:
        m_mat = 4.0 if prof.remat == "full" else 3.0  # fwd + (re)fwd + 2xbwd
        m_act = 2.0  # activation bytes written fwd + read bwd (checkpoint)
    else:
        m_mat, m_act = 1.0, 1.0

    # ---- per-layer matmuls ------------------------------------------------
    act_p = stored_p = 0.0
    for spec in layers:
        a, s_ = _mat_params_per_layer(cfg, spec)
        act_p += a
        stored_p += s_
    c.add("layer_matmul", flops=m_mat * 2.0 * act_p / tp * tokens_dev)

    # ---- attention scores (flash: full chunk products) --------------------
    for spec in layers:
        if spec.mixer not in ("attn", "attn_local"):
            if spec.mixer == "mamba":
                mc = cfg.mamba
                din, n = cfg.d_inner_mamba, mc.d_state
                if decode:
                    f = 2.0 * (3 * din * n) / tp * tokens_dev
                else:
                    # SSD chunked: intra-chunk (T*q*heads... ~ T*chunk*(pd+n))
                    # + state path ~ 6*T*din*n
                    f = (6.0 * din * n + 2.0 * mc.chunk * din) / tp * tokens_dev
                c.add("ssm_scan", flops=(m_mat if train else 1.0) * f)
            continue
        if cfg.mla is not None:
            hd_qk = cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim
            hd_v = cfg.mla.v_head_dim
            heads = cfg.n_heads
        else:
            hd_qk = hd_v = cfg.head_dim
            heads = cfg.n_heads
        ctx = _attn_ctx(spec, cfg, s_ctx, prof.k_chunk)
        if decode:
            eff = min(ctx, s_ctx if spec.mixer == "attn" else (cfg.window or s_ctx))
            f = 2.0 * tokens_dev * eff * heads / tp * (hd_qk + hd_v)
            c.add("attn_scores", flops=f)
        else:
            # causal flash over q-chunks: average visible ctx ~ ctx/2 rounded
            # up to chunk granularity; local layers see the window.
            if spec.mixer == "attn_local" and cfg.window and cfg.window < s_ctx:
                vis = ctx
            else:
                vis = (s_ctx / 2 + prof.k_chunk / 2)
            f = 2.0 * tokens_dev * vis * heads / tp * (hd_qk + hd_v)
            mult = 4.0 if (train and prof.remat == "full") else (3.0 if train else 1.0)
            c.add("attn_scores", flops=mult * f)

    # ---- LM head ----------------------------------------------------------
    v_sh = cfg.padded_vocab / tp
    c.add("lm_head", flops=(3.0 if train else 1.0) * 2.0 * tokens_dev * v_sh * d)
    # embed lookup is a gather: bytes only (below)

    # ======================= bytes ==========================================
    p_dev_b = 0.0  # resident param bytes per device
    emb = cfg.padded_vocab * d
    stored_total = stored_p + emb + (0 if cfg.tie_embeddings else emb)
    p_dev_b = stored_total / tp * BF16
    if prof.fsdp:
        p_dev_b /= dp  # stored sharded; gathered at use (counted as reads)

    if train:
        accum = max(prof.accum_steps, 1)
        # params read per microbatch fwd + bwd(recompute reads again)
        reads = (3.0 if prof.remat == "full" else 2.0) * accum
        c.add("param_reads", bytes_=reads * stored_total / tp * BF16 / (dp if prof.fsdp else 1) * (dp if prof.fsdp else 1))
        # grads: fp32 accumulate read+write per microbatch + final read
        gshard = act_p / tp  # ZeRO: grads land data-sharded but accum is full
        c.add("grad_accum", bytes_=2.0 * accum * (stored_total / tp) * F32)
        # optimizer: read m,v,master + write m,v,master + write param
        zdiv = dp  # ZeRO-1: optimizer shard per dp rank
        mom_b = (2 * 1 + 2 * 4 / 256) if prof.quantized_opt else 2 * F32
        opt_bytes = (stored_total / tp / zdiv) * (2 * mom_b + 2 * F32 + F32 + BF16)
        c.add("optimizer", bytes_=opt_bytes)
        # activations: checkpoint in/out per layer
        c.add("activations",
              bytes_=m_act * len(layers) * tokens_dev * d * BF16)
        # attention K/V streaming (flash): each q-chunk re-reads K,V ctx
        for spec in layers:
            if spec.mixer not in ("attn", "attn_local"):
                continue
            ctx = _attn_ctx(spec, cfg, s_ctx, prof.k_chunk)
            n_q = math.ceil(s_ctx / prof.q_chunk)
            kvh = (cfg.n_kv_heads if cfg.mla is None else 1)
            hdd = (cfg.head_dim if cfg.mla is None
                   else cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim)
            per_seq = (n_q * min(ctx, s_ctx) * kvh * hdd * BF16 * 2) / tp
            nseq = tokens_dev / s_ctx
            c.add("attn_kv_stream", bytes_=2.0 * per_seq * nseq)  # fwd+bwd
        # logits write+read (bwd)
        c.add("logits", bytes_=2.0 * tokens_dev * v_sh * BF16)
        # embedding gather read
        c.add("embed", bytes_=tokens_dev * d * BF16)
    else:
        # serving: params read once per step
        c.add("param_reads", bytes_=stored_total / tp * BF16)
        if decode:
            # KV cache read per generated token + write of the new entry
            cache_b = 0.0
            seq_shards = 1
            for ax in getattr(prof, "cache_seq_axes", ()) or ():
                seq_shards *= sizes.get(ax, 1)
            b_dev = shape.global_batch / (dp if shape.global_batch >= dp else 1)
            for spec in layers:
                if spec.mixer == "mamba":
                    mc = cfg.mamba
                    cache_b += b_dev * cfg.n_mamba_heads * mc.head_dim * mc.d_state * BF16 / tp
                elif spec.mixer in ("attn", "attn_local"):
                    s_c = s_ctx if spec.mixer == "attn" else min(cfg.window or s_ctx, s_ctx)
                    if cfg.mla is not None:
                        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
                    else:
                        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim / tp
                    cache_b += b_dev * s_c * per_tok * BF16
            c.add("kv_cache", bytes_=cache_b)
        else:
            c.add("activations", bytes_=len(layers) * tokens_dev * d * BF16)
            for spec in layers:
                if spec.mixer not in ("attn", "attn_local"):
                    continue
                ctx = _attn_ctx(spec, cfg, s_ctx, prof.k_chunk)
                n_q = math.ceil(s_ctx / prof.q_chunk)
                kvh = cfg.n_kv_heads if cfg.mla is None else 1
                hdd = (cfg.head_dim if cfg.mla is None
                       else cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim)
                per_seq = (n_q * min(ctx, s_ctx) * kvh * hdd * BF16 * 2) / tp
                nseq = tokens_dev / s_ctx
                c.add("attn_kv_stream", bytes_=per_seq * nseq)
            c.add("logits", bytes_=shape.global_batch / dp * v_sh * BF16)
    return c
