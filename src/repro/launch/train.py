"""Production training driver.

Trains any assigned architecture (or a reduced variant) with the SwitchAgg
gradient exchange, fault-tolerant loop (checkpoint/restart, straggler
monitor), deterministic data pipeline, and the mesh factorization of the
available devices.

CPU examples (the same code path a pod launch takes):

  # 100M-class model, tree exchange, checkpoints every 20 steps
  PYTHONPATH=src python -m repro.launch.train --arch phi4-mini-3.8b \\
      --reduce --d-model 512 --layers 8 --steps 200 --batch 8 --seq 256 \\
      --mode tree --ckpt-dir /tmp/run1

  # multi-device tree exchange (8 fake devices, mesh 4x2)
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \\
      python -m repro.launch.train --arch olmoe-1b-7b --reduce \\
      --mesh 4,2 --steps 50 --mode tree_compress
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.configs.reduced import reduced_config
from repro.core.collectives import GradAggMode
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import LMModel
from repro.optim import AdamWConfig, adamw_init, make_lr_schedule
from repro.runtime.fault_tolerance import TrainLoop, TrainLoopConfig
from repro.train.compressed import build_compressed_train_step
from repro.train.step import TrainProfile, build_train_step

log = logging.getLogger("repro.launch.train")


def parse_mesh(spec: str | None):
    n = jax.device_count()
    if spec:
        dims = tuple(int(x) for x in spec.split(","))
    else:
        dims = (n, 1)
    names = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}[len(dims)]
    assert np.prod(dims) == n, f"mesh {dims} != devices {n}"
    return jax.make_mesh(dims, names)


def build_config(args):
    cfg = (reduced_config(args.arch) if args.reduce
           else configs.get_config(args.arch))
    over = {}
    if args.d_model:
        hd = max(16, args.d_model // max(cfg.n_heads, 1))
        over.update(d_model=args.d_model, head_dim=hd, d_ff=4 * args.d_model)
    if args.layers:
        per = len(cfg.pattern)
        groups = max(1, args.layers // per)
        over["n_layers"] = len(cfg.prefix) + groups * per
    if args.fp32:
        over["dtype"] = "float32"
    return dataclasses.replace(cfg, **over) if over else cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=configs.ARCH_IDS)
    ap.add_argument("--reduce", action="store_true",
                    help="use the reduced (CPU-scale) config")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--fp32", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default=None, help="e.g. 4,2 or 2,2,2")
    ap.add_argument("--mode", default="tree",
                    choices=[m.value for m in GradAggMode] + ["tree_compress"])
    ap.add_argument("--k-fraction", type=float, default=0.01)
    ap.add_argument("--fpe-capacity", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--q-chunk", type=int, default=128)
    ap.add_argument("--k-chunk", type=int, default=128)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    cfg = build_config(args)
    mesh = parse_mesh(args.mesh)
    log.info("config %s: %.1fM params (%.1fM active), mesh %s",
             cfg.name, cfg.param_count() / 1e6, cfg.active_param_count() / 1e6,
             dict(zip(mesh.axis_names, mesh.devices.shape)))

    mode = GradAggMode(args.mode)
    dp_axes = tuple(a for a in ("data", "pod") if a in mesh.axis_names)
    if mode != GradAggMode.TREE_COMPRESS:
        # exchange schedules order scarce-last in specs; step.py handles it
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    prof = TrainProfile(
        dp_axes=dp_axes, tp_axis="model",
        q_chunk=args.q_chunk, k_chunk=args.k_chunk,
        moe_token_chunk=max(64, args.batch * args.seq // 8),
        remat="none", mode=mode,
    )
    data = SyntheticLMData(cfg, DataConfig(seq_len=args.seq,
                                           global_batch=args.batch))
    opt_cfg = AdamWConfig(master_fp32=not args.fp32)
    lr_fn = make_lr_schedule(args.lr, min(20, args.steps // 10 + 1), args.steps)

    model = LMModel(cfg)
    params0 = model.init(jax.random.PRNGKey(0))
    batch0 = data.batch_at(0)

    if mode == GradAggMode.TREE_COMPRESS:
        step_fn, sh = build_compressed_train_step(
            cfg, mesh, prof, opt_cfg, lr_fn,
            batch_example=batch0, params_example=params0,
            k_fraction=args.k_fraction, fpe_capacity=args.fpe_capacity)
        params = jax.device_put(params0, sh["params"])
        opt = jax.jit(lambda p: adamw_init(p, opt_cfg),
                      out_shardings=sh["opt"])(params)
        res = jax.device_put(sh["res_example"], sh["residuals"])
        state = {"params": params, "opt": opt, "res": res}

        def loop_step(state, batch, i):
            p, o, r, m = step_fn(state["params"], state["opt"], state["res"],
                                 batch, jnp.asarray(i, jnp.int32))
            return {"params": p, "opt": o, "res": r}, m
    else:
        step_fn, sh, _ = build_train_step(
            cfg, mesh, prof, opt_cfg, lr_fn,
            batch_example=batch0, params_example=params0)
        params = jax.device_put(params0, sh["params"])
        opt = jax.jit(lambda p: adamw_init(p, opt_cfg),
                      out_shardings=sh["opt"])(params)
        state = {"params": params, "opt": opt}

        def loop_step(state, batch, i):
            p, o, m = step_fn(state["params"], state["opt"], batch,
                              jnp.asarray(i, jnp.int32))
            return {"params": p, "opt": o}, m

    loop = TrainLoop(
        TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every, log_every=args.log_every),
        loop_step, data.batch_at, state,
    )
    t0 = time.time()
    final = loop.run()
    dt = time.time() - t0
    done = args.steps - loop.monitor._seen if False else len(loop.metrics_history)
    tok_s = done * args.batch * args.seq / max(dt, 1e-9)
    losses = [m["loss"] for m in loop.metrics_history]
    log.info("done: %d steps in %.1fs (%.0f tok/s); loss %.4f -> %.4f; "
             "stragglers=%d", done, dt, tok_s,
             losses[0] if losses else float("nan"),
             losses[-1] if losses else float("nan"),
             len(loop.monitor.events))
    return final, loop


if __name__ == "__main__":
    main()
