"""Pure-jnp oracles for the Pallas kernels.

Each kernel in this package has an exact reference here; kernel tests sweep
shapes/dtypes and assert_allclose kernel-vs-ref (interpret=True on CPU).

Op semantics are NOT defined here: the ``repro.core.aggops`` registry
(DESIGN.md §6) is the one source of truth for combine/identity/segment
reductions, re-exported below so kernel callers and tests resolve ops
through the same table the kernels compile against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggops
from repro.core import kvagg as _kvagg
from repro.core.aggops import AggOp, get as get_aggop, names as aggop_names
from repro.core.aggops import hash_key  # THE shared bucket hash (noqa: F401)

EMPTY_KEY = _kvagg.EMPTY_KEY


def fpe_aggregate_ref(keys, values, *, capacity: int, ways: int = 4,
                      op: str = "sum", exact_stream: bool = True):
    """Oracle for the FPE hash-combine kernel: the core.kvagg scan impl.

    The Pallas kernel processes the stream block-by-block with a persistent
    VMEM table — semantically identical to this element-sequential scan.
    ``exact_stream=False`` is the batched-block fast path oracle
    (DESIGN.md §8) matching the kernel wrapper's pre-combined mode: the
    resident tables are bit-identical, but the eviction STREAM SHAPES
    differ ([n + capacity] here vs the kernel's [n]) — compare fast modes
    by table and grouped totals, not elementwise eviction slots.
    """
    return _kvagg.fpe_aggregate(keys, values, capacity=capacity, ways=ways,
                                op=op, exact_stream=exact_stream)


def sorted_combine_ref(keys, values, *, op: str = "sum"):
    return _kvagg.sorted_combine(keys, values, op=op)


def topk_ref(x: jnp.ndarray, k: int):
    """Oracle for the per-row magnitude top-k kernel.

    x: [rows, cols] -> (values [rows,k], indices [rows,k]) where values are
    the originals (signed) at the k largest-|.| positions, ordered by
    descending magnitude; ties broken by lower index (matches the kernel's
    iterative argmax).
    """
    rows = x.shape[0]
    mag = jnp.abs(x.astype(jnp.float32))

    def step(m, _):
        am = jnp.argmax(m, axis=-1)  # first max on ties, like the kernel
        v = jnp.take_along_axis(x, am[:, None], axis=-1)[:, 0]
        m = m.at[jnp.arange(rows), am].set(-jnp.inf)
        return m, (v, am.astype(jnp.int32))

    _, (vs, ams) = jax.lax.scan(step, mag, None, length=k)
    return vs.T, ams.T


def segment_sum_ref(values: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int):
    return jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)
