"""Pallas TPU kernel: per-row magnitude top-k (the KV payload producer).

Gradient blocks are compressed to (index, value) pairs — the SwitchAgg
aggregation-packet payload.  Each grid step loads a ``[block_rows, cols]``
tile into VMEM and runs k iterative argmax sweeps:

  * the argmax/one-hot/select of each sweep is a pair of full-lane VPU
    reductions over the tile — no data-dependent control flow, so the
    pipeline never stalls (the kernel-level analogue of the paper's
    line-rate requirement);
  * k is small (1-2% of cols), so the k sweeps stay VPU-bound and the tile
    is read from HBM exactly once (arithmetic intensity k·rows·cols /
    rows·cols·4B — compute-cheap, bandwidth-bound, roofline-optimal for a
    selection kernel).

Tie-breaking: equal magnitudes pick the lower column index (matches
``ref.topk_ref``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k: int):
    x = x_ref[...]  # (rows, cols)
    rows, cols = x.shape
    mag = jnp.abs(x.astype(jnp.float32))
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, cols), 1)

    def body(j, mag_cur):
        am = jnp.argmax(mag_cur, axis=-1).astype(jnp.int32)  # (rows,)
        onehot = col == am[:, None]
        v = jnp.sum(jnp.where(onehot, x, jnp.zeros_like(x)), axis=-1)
        pl.store(vals_ref, (slice(None), pl.ds(j, 1)), v[:, None])
        pl.store(idx_ref, (slice(None), pl.ds(j, 1)), am[:, None])
        return jnp.where(onehot, -jnp.inf, mag_cur)

    jax.lax.fori_loop(0, k, body, mag)


@functools.partial(jax.jit, static_argnames=("k", "block_rows", "interpret"))
def topk_rows_pallas(
    x: jnp.ndarray,
    *,
    k: int,
    block_rows: int = 8,
    interpret: bool | None = None,
):
    """Top-k by |.| per row of x [rows, cols] -> (values, indices) [rows, k]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rows, cols = x.shape
    if k > cols:
        raise ValueError(f"k={k} > cols={cols}")
    pad = (-rows) % block_rows
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, cols), x.dtype)])
    total = x.shape[0]

    vals, idx = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(total // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((total, k), x.dtype),
            jax.ShapeDtypeStruct((total, k), jnp.int32),
        ),
        interpret=interpret,
    )(x)
    return vals[:rows], idx[:rows]
