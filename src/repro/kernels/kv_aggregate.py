"""Pallas TPU kernel: the SwitchAgg FPE hash-combine engine.

TPU adaptation of the paper's front-end processing engine (§4.2.4):

  * The hash table lives in **VMEM** (the switch's SRAM analogue): keys
    ``[n_buckets, ways]`` int32 + values ``[n_buckets, ways]``, allocated as
    Pallas scratch so it persists across grid steps while the input stream
    is tiled through HBM->VMEM block by block (BlockSpec pipeline = the
    paper's line-rate packet flow).
  * ``ways`` is the **lane dimension**: one bucket probe is a single VPU
    compare over the (1, ways) row — the hardware's parallel slot compare.
    Use ways=128 on real TPUs for full-lane utilization; tests sweep small
    widths in interpret mode.
  * On collision the resident way-0 pair is **evicted to the output stream**
    (never a stall/retry — the paper's no-penalty miss), the row shifts
    left, and the new pair occupies the last way (LRU-ish, as in the paper
    where the previously stored key is replaced).
  * The eviction stream (the BPE feed) leaves through a second output, one
    slot per input element, EMPTY_KEY where nothing was evicted.  The BPE
    combine itself is a bulk sort+segment-sum on the eviction stream
    (``ops.two_level_aggregate``) whose latency overlaps the next FPE block
    exactly as the paper overlaps DRAM latency.

Semantics are bit-identical to ``repro.core.kvagg.fpe_aggregate`` (the
pure-jnp oracle re-exported via ``ref.py``).

Op semantics come from the ``core.aggops`` registry (DESIGN.md §6): the
``op`` string is resolved to its ``combine`` at trace time, so each
compiled kernel stays specialized to one op — exactly like the string
dispatch it replaces, but with one source of truth.  Multi-lane ops
(``mean``'s paired (sum, count) lanes) are handled in the wrapper: eviction
decisions are key-driven, so running the single-lane kernel once per lane
with the same key stream yields bit-aligned tables and eviction streams.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import aggops

EMPTY_KEY = -1  # plain int so kernels inline it as a literal
_HASH_MULT = 0x9E3779B1


def _hash(k: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    h = k.astype(jnp.uint32) * jnp.uint32(_HASH_MULT)
    h = h ^ (h >> jnp.uint32(15))
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


def _fpe_kernel(
    keys_ref,  # [block_n] int32 (VMEM, streamed)
    vals_ref,  # [block_n] float (VMEM, streamed)
    evk_ref,  # [block_n] int32 out — eviction stream block
    evv_ref,  # [block_n] float out
    otk_ref,  # [n_buckets, ways] int32 out — final table (written at flush)
    otv_ref,  # [n_buckets, ways] float out
    tk_ref,  # scratch: resident keys
    tv_ref,  # scratch: resident values
    *,
    n_buckets: int,
    ways: int,
    op: str,
    n_blocks: int,
):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        tk_ref[...] = jnp.full((n_buckets, ways), EMPTY_KEY, dtype=jnp.int32)
        tv_ref[...] = jnp.zeros((n_buckets, ways), dtype=tv_ref.dtype)

    block_n = keys_ref.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, ways), 1)

    def body(i, _):
        k = keys_ref[i]
        v = vals_ref[i]
        is_pad = k == EMPTY_KEY
        b = _hash(k, n_buckets)

        row_k = pl.load(tk_ref, (pl.ds(b, 1), slice(None)))  # (1, ways)
        row_v = pl.load(tv_ref, (pl.ds(b, 1), slice(None)))

        hit = row_k == k  # (1, ways) — one VPU compare = the bucket probe
        any_hit = jnp.any(hit) & ~is_pad
        empty = row_k == EMPTY_KEY
        any_empty = jnp.any(empty) & ~is_pad
        empty_idx = jnp.argmax(empty.astype(jnp.int32))  # first empty way

        # hit: aggregate into the matching way (op resolved at trace time)
        agg_v = jnp.where(hit, aggops.get(op).combine(row_v, v), row_v)

        # miss+empty: insert at first empty way
        at_empty = lane == empty_idx
        ins_k = jnp.where(at_empty, k, row_k)
        ins_v = jnp.where(at_empty, v, row_v)

        # miss+full: evict way 0, shift left, insert at last way
        ev_k = row_k[0, 0]
        ev_v = row_v[0, 0]
        sh_k = jnp.where(lane == ways - 1, k, jnp.roll(row_k, -1, axis=1))
        sh_v = jnp.where(lane == ways - 1, v, jnp.roll(row_v, -1, axis=1))

        new_k = jnp.where(any_hit, row_k, jnp.where(any_empty, ins_k, sh_k))
        new_v = jnp.where(any_hit, agg_v, jnp.where(any_empty, ins_v, sh_v))
        new_k = jnp.where(is_pad, row_k, new_k)
        new_v = jnp.where(is_pad, row_v, new_v)

        evicted = (~any_hit) & (~any_empty) & (~is_pad)
        out_k = jnp.where(evicted, ev_k, EMPTY_KEY)
        out_v = jnp.where(evicted, ev_v, jnp.zeros((), tv_ref.dtype))

        pl.store(tk_ref, (pl.ds(b, 1), slice(None)), new_k)
        pl.store(tv_ref, (pl.ds(b, 1), slice(None)), new_v)
        pl.store(evk_ref, (pl.ds(i, 1),), out_k[None])
        pl.store(evv_ref, (pl.ds(i, 1),), out_v[None])
        return 0

    jax.lax.fori_loop(0, block_n, body, 0)

    # End-of-task flush (paper's EoT): emit the resident table once.
    @pl.when(pid == n_blocks - 1)
    def _flush():
        otk_ref[...] = tk_ref[...]
        otv_ref[...] = tv_ref[...]


@functools.partial(
    jax.jit, static_argnames=("capacity", "ways", "op", "block_n", "interpret")
)
def fpe_aggregate_pallas(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    *,
    capacity: int,
    ways: int = 4,
    op: str = "sum",
    block_n: int = 512,
    interpret: bool | None = None,
):
    """Run the FPE kernel over a KV stream.

    Returns (table_keys [capacity], table_values [capacity, *lanes],
             evict_keys [n], evict_values [n, *lanes]) — same contract as
    ``core.kvagg.fpe_aggregate``.  Values with a trailing lane dim (multi-
    lane carried ops, e.g. ``mean``) run the kernel once per lane over the
    shared key stream; key outputs are lane-invariant by construction.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if values.ndim == 2:
        lanes = values.shape[1]
        tks, tvs, eks, evs = zip(*(
            fpe_aggregate_pallas(
                keys, values[:, l], capacity=capacity, ways=ways, op=op,
                block_n=block_n, interpret=interpret)
            for l in range(lanes)))
        return (tks[0], jnp.stack(tvs, axis=-1), eks[0],
                jnp.stack(evs, axis=-1))
    n = keys.shape[0]
    ways = max(1, min(ways, capacity))
    n_buckets = max(1, capacity // ways)
    cap = n_buckets * ways

    pad = (-n) % block_n
    if pad:
        keys = jnp.concatenate([keys, jnp.full((pad,), EMPTY_KEY, jnp.int32)])
        values = jnp.concatenate([values, jnp.zeros((pad,), values.dtype)])
    total = keys.shape[0]
    n_blocks = total // block_n

    kernel = functools.partial(
        _fpe_kernel, n_buckets=n_buckets, ways=ways, op=op, n_blocks=n_blocks
    )
    out_shapes = (
        jax.ShapeDtypeStruct((total,), jnp.int32),  # evict keys
        jax.ShapeDtypeStruct((total,), values.dtype),  # evict values
        jax.ShapeDtypeStruct((n_buckets, ways), jnp.int32),  # table keys
        jax.ShapeDtypeStruct((n_buckets, ways), values.dtype),  # table values
    )
    grid = (n_blocks,)
    evk, evv, otk, otv = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((n_buckets, ways), lambda i: (0, 0)),
            pl.BlockSpec((n_buckets, ways), lambda i: (0, 0)),
        ],
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((n_buckets, ways), jnp.int32),
            pltpu.VMEM((n_buckets, ways), values.dtype),
        ],
        interpret=interpret,
    )(keys, values)
    return otk.reshape(cap), otv.reshape(cap), evk[:n], evv[:n]
