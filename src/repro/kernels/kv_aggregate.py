"""Pallas TPU kernel: the SwitchAgg FPE hash-combine engine.

TPU adaptation of the paper's front-end processing engine (§4.2.4):

  * The hash table lives in **VMEM** (the switch's SRAM analogue): keys
    ``[n_buckets, ways]`` int32 + values ``[n_buckets, ways, lanes]``,
    allocated as Pallas scratch so it persists across grid steps while the
    input stream is tiled through HBM->VMEM block by block (BlockSpec
    pipeline = the paper's line-rate packet flow).
  * ``ways`` is the **lane dimension** of the bucket probe: one probe is a
    single VPU compare over the (1, ways) row — the hardware's parallel
    slot compare.  Use ways=128 on real TPUs for full-lane utilization;
    tests sweep small widths in interpret mode.
  * On collision the resident way-0 pair is **evicted to the output stream**
    (never a stall/retry — the paper's no-penalty miss), the row shifts
    left, and the new pair occupies the last way (LRU-ish, as in the paper
    where the previously stored key is replaced).
  * The eviction stream (the BPE feed) leaves through a second output, one
    slot per input element, EMPTY_KEY where nothing was evicted.  The BPE
    combine itself is a bulk sort+segment-sum on the eviction stream
    (``ops.two_level_aggregate``) whose latency overlaps the next FPE block
    exactly as the paper overlaps DRAM latency.

Semantics are bit-identical to ``repro.core.kvagg.fpe_aggregate`` (the
pure-jnp oracle re-exported via ``ref.py``).

Op semantics come from the ``core.aggops`` registry (DESIGN.md §6): the
``op`` string is resolved to its ``combine`` ONCE at trace time, before the
kernel body is built, so each compiled kernel stays specialized to one op.
Multi-lane carried ops (``mean``'s paired (sum, count) lanes) run in the
SAME single ``pallas_call``: the value stream is ``[block_n, lanes]`` and
the VMEM table carries a trailing lane dimension — eviction decisions are
key-driven, so all lanes ride one probe/update per element instead of the
one-kernel-launch-per-lane wrapper this replaced (DESIGN.md §8).

``exact_stream=False`` runs the batched-block fast path (DESIGN.md §8):
the block is pre-combined to distinct keys by the jnp ``sorted_combine``
(vectorized VPU work) and only the surviving distinct keys stream through
the sequential VMEM engine — same grouped-combine result, shorter
effective stream, non-paper-faithful eviction pattern.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import aggops
from repro.core import kvagg as _kvagg

EMPTY_KEY = -1  # plain int so kernels inline it as a literal

# THE key hash (core.aggops.hash_key): one copy shared with the jnp engine
# so the kernel's bucket function can never drift from the oracle's.
_hash = aggops.hash_key


def _fpe_kernel(
    keys_ref,  # [block_n] int32 (VMEM, streamed)
    vals_ref,  # [block_n, lanes] (VMEM, streamed)
    evk_ref,  # [block_n] int32 out — eviction stream block
    evv_ref,  # [block_n, lanes] out
    otk_ref,  # [n_buckets, ways] int32 out — final table (written at flush)
    otv_ref,  # [n_buckets, ways, lanes] out
    tk_ref,  # scratch: resident keys
    tv_ref,  # scratch: resident values (lane dim trailing)
    *,
    n_buckets: int,
    ways: int,
    lanes: int,
    combine,  # aggops combine fn, resolved ONCE before the body is traced
    n_blocks: int,
):
    pid = pl.program_id(0)

    @pl.when(pid == 0)
    def _init():
        tk_ref[...] = jnp.full((n_buckets, ways), EMPTY_KEY, dtype=jnp.int32)
        tv_ref[...] = jnp.zeros((n_buckets, ways, lanes), dtype=tv_ref.dtype)

    block_n = keys_ref.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, ways), 1)

    def body(i, _):
        k = keys_ref[i]
        v = vals_ref[i, :]  # [lanes] — every value lane of this element
        is_pad = k == EMPTY_KEY
        b = _hash(k, n_buckets)

        row_k = pl.load(tk_ref, (pl.ds(b, 1), slice(None)))  # (1, ways)
        row_v = pl.load(
            tv_ref, (pl.ds(b, 1), slice(None), slice(None)))  # (1, ways, L)

        hit = row_k == k  # (1, ways) — one VPU compare = the bucket probe
        any_hit = jnp.any(hit) & ~is_pad
        empty = row_k == EMPTY_KEY
        any_empty = jnp.any(empty) & ~is_pad
        empty_idx = jnp.argmax(empty.astype(jnp.int32))  # first empty way

        v_row = v[None, None, :]  # (1, 1, lanes) — broadcasts over ways

        # hit: aggregate every lane into the matching way
        agg_v = jnp.where(hit[..., None], combine(row_v, v_row), row_v)

        # miss+empty: insert at first empty way
        at_empty = lane == empty_idx
        ins_k = jnp.where(at_empty, k, row_k)
        ins_v = jnp.where(at_empty[..., None], v_row, row_v)

        # miss+full: evict way 0, shift left, insert at last way
        ev_k = row_k[0, 0]
        ev_v = row_v[0, 0, :]  # [lanes]
        at_last = lane == ways - 1
        sh_k = jnp.where(at_last, k, jnp.roll(row_k, -1, axis=1))
        sh_v = jnp.where(at_last[..., None], v_row,
                         jnp.roll(row_v, -1, axis=1))

        new_k = jnp.where(any_hit, row_k, jnp.where(any_empty, ins_k, sh_k))
        new_v = jnp.where(any_hit, agg_v, jnp.where(any_empty, ins_v, sh_v))
        new_k = jnp.where(is_pad, row_k, new_k)
        new_v = jnp.where(is_pad, row_v, new_v)

        evicted = (~any_hit) & (~any_empty) & (~is_pad)
        out_k = jnp.where(evicted, ev_k, EMPTY_KEY)
        out_v = jnp.where(evicted, ev_v, jnp.zeros((lanes,), tv_ref.dtype))

        pl.store(tk_ref, (pl.ds(b, 1), slice(None)), new_k)
        pl.store(tv_ref, (pl.ds(b, 1), slice(None), slice(None)), new_v)
        pl.store(evk_ref, (pl.ds(i, 1),), out_k[None])
        pl.store(evv_ref, (pl.ds(i, 1), slice(None)), out_v[None])
        return 0

    jax.lax.fori_loop(0, block_n, body, 0)

    # End-of-task flush (paper's EoT): emit the resident table once.
    @pl.when(pid == n_blocks - 1)
    def _flush():
        otk_ref[...] = tk_ref[...]
        otv_ref[...] = tv_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("capacity", "ways", "op", "block_n", "exact_stream",
                     "interpret"),
)
def fpe_aggregate_pallas(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    *,
    capacity: int,
    ways: int = 4,
    op: str = "sum",
    block_n: int = 512,
    exact_stream: bool = True,
    interpret: bool | None = None,
):
    """Run the FPE kernel over a KV stream.

    Returns (table_keys [capacity], table_values [capacity, *lanes],
             evict_keys [n], evict_values [n, *lanes]) — same contract as
    ``core.kvagg.fpe_aggregate``.  Values with a trailing lane dim (multi-
    lane carried ops, e.g. ``mean``) run in the SAME kernel launch: the
    VMEM table carries a lane dimension and each element's probe updates
    every lane at once.

    ``exact_stream=False`` pre-combines the block to distinct keys
    (``kvagg.sorted_combine`` — vectorized) before streaming it through
    the kernel, so the sequential engine touches each distinct key once;
    the eviction *pattern* then differs from the paper-faithful trace
    (DESIGN.md §8) while the grouped-combine result is identical.  NOTE:
    in that mode the eviction stream stays [n] (slot d = the d-th sorted
    distinct key) whereas the jnp fast path emits [n + capacity]
    (displaced residents appended) — compare the two fast modes by
    resident table and grouped totals, not elementwise stream shape.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = keys.shape[0]
    squeeze = values.ndim == 1
    if exact_stream is False:
        c = _kvagg.sorted_combine(keys, values, op=op)
        keys, values = c.unique_keys, c.combined_values
    if values.ndim == 1:
        values = values[:, None]
    lanes = values.shape[1]
    ways, n_buckets, cap = _kvagg._fpe_geometry(capacity, ways)

    pad = (-n) % block_n
    if pad:
        keys = jnp.concatenate([keys, jnp.full((pad,), EMPTY_KEY, jnp.int32)])
        values = jnp.concatenate(
            [values, jnp.zeros((pad, lanes), values.dtype)])
    total = keys.shape[0]
    n_blocks = total // block_n

    kernel = functools.partial(
        _fpe_kernel, n_buckets=n_buckets, ways=ways, lanes=lanes,
        combine=aggops.get(op).combine, n_blocks=n_blocks,
    )
    out_shapes = (
        jax.ShapeDtypeStruct((total,), jnp.int32),  # evict keys
        jax.ShapeDtypeStruct((total, lanes), values.dtype),  # evict values
        jax.ShapeDtypeStruct((n_buckets, ways), jnp.int32),  # table keys
        jax.ShapeDtypeStruct((n_buckets, ways, lanes), values.dtype),
    )
    grid = (n_blocks,)
    evk, evv, otk, otv = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, lanes), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n, lanes), lambda i: (i, 0)),
            pl.BlockSpec((n_buckets, ways), lambda i: (0, 0)),
            pl.BlockSpec((n_buckets, ways, lanes), lambda i: (0, 0, 0)),
        ],
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((n_buckets, ways), jnp.int32),
            pltpu.VMEM((n_buckets, ways, lanes), values.dtype),
        ],
        interpret=interpret,
    )(keys, values)
    tv = otv.reshape(cap, lanes)
    ek, ev = evk[:n], evv[:n]
    if squeeze:
        return otk.reshape(cap), tv[:, 0], ek, ev[:, 0]
    return otk.reshape(cap), tv, ek, ev
