"""jit'd public wrappers for the Pallas kernels.

``two_level_aggregate`` is the full SwitchAgg node: the Pallas FPE kernel
(VMEM hash table, evict-on-collision) feeding a BPE bulk combine
(sort + segment reduce over the eviction stream — the large/slow memory
level, overlapped with the next FPE block on real hardware).

Op semantics resolve through the ``core.aggops`` registry (DESIGN.md §6);
any registered op — including multi-lane carried ops like ``mean`` — works
here, and ``n_out`` follows the forwarded-pairs traffic invariant
documented on ``core.kvagg.TwoLevelResult``.  Multi-level plans run via
``core.dataplane.run_cascade(backend="pallas")``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kvagg as _kvagg
from .kv_aggregate import fpe_aggregate_pallas
from .topk_compress import topk_rows_pallas

EMPTY_KEY = _kvagg.EMPTY_KEY


class TwoLevelOut(NamedTuple):
    out_keys: jnp.ndarray
    out_values: jnp.ndarray
    n_out: jnp.ndarray
    n_in: jnp.ndarray
    n_evict: jnp.ndarray


@functools.partial(
    jax.jit,
    static_argnames=("capacity", "ways", "op", "block_n", "bpe",
                     "exact_stream", "interpret"),
)
def two_level_aggregate(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    *,
    capacity: int,
    ways: int = 4,
    op: str = "sum",
    block_n: int = 512,
    bpe: bool = True,
    exact_stream: bool = True,
    interpret: bool | None = None,
) -> TwoLevelOut:
    """SwitchAgg node with the Pallas FPE (kernel) + BPE (bulk combine).

    Node assembly/accounting delegates to ``kvagg.assemble_node`` — the one
    copy of the policy shared with the jnp node and the cascade executor.
    ``exact_stream=False`` pre-combines each block before the kernel
    (DESIGN.md §8 fast path): identical grouped output, different
    eviction pattern.
    """
    tk, tv, ek, ev = fpe_aggregate_pallas(
        keys, values, capacity=capacity, ways=ways, op=op, block_n=block_n,
        exact_stream=exact_stream, interpret=interpret,
    )
    return TwoLevelOut(*_kvagg.assemble_node(keys, tk, tv, ek, ev,
                                             op=op, bpe=bpe))


@functools.partial(jax.jit, static_argnames=("k", "chunk", "block_rows", "interpret"))
def compress_grad(
    grad: jnp.ndarray,
    residual: jnp.ndarray,
    *,
    k: int,
    chunk: int = 4096,
    block_rows: int = 8,
    interpret: bool | None = None,
):
    """Blockwise top-k gradient -> KV payload using the Pallas kernel.

    Returns (keys [rows*k] int32 global flat indices, values [rows*k],
    new_residual) with error feedback.  ``chunk`` is the per-FPE-group
    working set (cols per row).
    """
    acc = grad.astype(residual.dtype).reshape(-1) + residual.reshape(-1)
    n = acc.shape[0]
    if n % chunk != 0:
        raise ValueError(f"grad size {n} not divisible by chunk {chunk}")
    mat = acc.reshape(-1, chunk)
    vals, idx = topk_rows_pallas(mat, k=k, block_rows=block_rows, interpret=interpret)
    rows = mat.shape[0]
    gkeys = (idx + jnp.arange(rows, dtype=jnp.int32)[:, None] * chunk).reshape(-1)
    new_res = acc.at[gkeys].set(0.0).reshape(residual.shape)
    return gkeys, vals.reshape(-1), new_res
