"""Decoder assembly: heterogeneous layer patterns, scan-over-groups, caches.

The layer stack is ``prefix + pattern * n_groups``.  Parameters of each
pattern position are stacked on a leading ``n_groups`` axis and the stack is
driven by one ``lax.scan`` — HLO contains each distinct layer *once*, which
keeps CPU compile time bounded for 46-72-layer, 100B+-param configs (the
whole point of scan-over-layers).

Modes:
  dense   — training forward / loss (no cache)
  prefill — dense forward that also emits the full KV/SSM caches + last-pos x
  decode  — single-token step threading caches (KVCache / MLACache / MambaState)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import LayerSpec, ModelConfig
from . import attention as attn
from . import mamba as mamba_mod
from . import moe as moe_mod
from .attention import KVCache, MLACache, ShardingPolicy
from .layers import gated_mlp, rms_norm


@dataclasses.dataclass(frozen=True)
class ApplyOptions:
    q_chunk: int = 512
    k_chunk: int = 1024
    moe_token_chunk: int = 4096
    remat: str = "full"  # none | full | dots
    prefix_len: int = 0  # bidirectional prefix (PaliGemma)


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------


def _init_ffn(key, cfg: ModelConfig, kind: str, dtype) -> dict:
    if kind == "dense":
        d, f = cfg.d_model, cfg.d_ff
        k1, k2, k3 = jax.random.split(key, 3)
        s = d ** -0.5
        return {
            "w_gate": jax.random.normal(k1, (d, f), dtype) * s,
            "w_up": jax.random.normal(k2, (d, f), dtype) * s,
            "w_down": jax.random.normal(k3, (f, d), dtype) * (f ** -0.5),
        }
    if kind == "moe":
        return moe_mod.init_moe_params(key, cfg, dtype)
    return {}


def init_layer(key, spec: LayerSpec, cfg: ModelConfig, dtype) -> dict:
    km, kf = jax.random.split(key)
    p: dict[str, Any] = {
        "norm_mixer": jnp.zeros((cfg.d_model,), dtype),
        "norm_ffn": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.post_norms:
        p["post_norm_mixer"] = jnp.zeros((cfg.d_model,), dtype)
        p["post_norm_ffn"] = jnp.zeros((cfg.d_model,), dtype)
    if spec.mixer in ("attn", "attn_local"):
        p["mixer"] = (
            attn.init_mla_params(km, cfg, dtype)
            if cfg.mla is not None
            else attn.init_attn_params(km, cfg, dtype)
        )
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_mod.init_mamba_params(km, cfg, dtype)
    if spec.ffn != "none":
        p["ffn"] = _init_ffn(kf, cfg, spec.ffn, dtype)
    return p


def init_params(key, cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    k_embed, k_head, k_prefix, k_groups, k_final = jax.random.split(key, 5)
    params: dict[str, Any] = {
        "embed": jax.random.normal(k_embed, (cfg.padded_vocab, cfg.d_model), dtype)
        * (cfg.d_model ** -0.5),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = jax.random.normal(
            k_head, (cfg.padded_vocab, cfg.d_model), dtype
        ) * (cfg.d_model ** -0.5)
    if cfg.prefix:
        params["prefix"] = tuple(
            init_layer(k, spec, cfg, dtype)
            for k, spec in zip(jax.random.split(k_prefix, len(cfg.prefix)), cfg.prefix)
        )
    stacked = []
    for i, spec in enumerate(cfg.pattern):
        ks = jax.random.split(jax.random.fold_in(k_groups, i), cfg.n_groups)
        stacked.append(jax.vmap(lambda kk: init_layer(kk, spec, cfg, dtype))(ks))
    params["groups"] = tuple(stacked)
    return params


# ---------------------------------------------------------------------------
# One layer.
# ---------------------------------------------------------------------------


def apply_layer_dense(
    x, spec: LayerSpec, p, cfg: ModelConfig, policy: ShardingPolicy,
    opt: ApplyOptions, *, collect_cache: bool, cache_len: int = 0,
):
    """Dense pass; returns (x, cache|None, aux)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    h = rms_norm(x, p["norm_mixer"], cfg.norm_eps)
    if spec.mixer in ("attn", "attn_local"):
        window = cfg.window if spec.mixer == "attn_local" else 0
        if cfg.mla is not None:
            y = attn.mla_dense(h, p["mixer"], cfg, q_chunk=opt.q_chunk, k_chunk=opt.k_chunk)
            if collect_cache:
                cache = _mla_cache_from_dense(h, p["mixer"], cfg, cache_len)
        else:
            y = attn.attn_dense(
                h, p["mixer"], cfg, window=window, q_chunk=opt.q_chunk,
                k_chunk=opt.k_chunk,
            )
            if collect_cache:
                cache = _kv_cache_from_dense(h, p["mixer"], cfg, window, cache_len)
    elif spec.mixer == "mamba":
        y = mamba_mod.mamba_dense(h, p["mixer"], cfg)
        if collect_cache:
            cache = _mamba_state_from_dense(h, p["mixer"], cfg)
    else:
        y = jnp.zeros_like(x)
    if cfg.post_norms and spec.mixer != "none":
        y = rms_norm(y, p["post_norm_mixer"], cfg.norm_eps)
    x = x + y

    if spec.ffn != "none":
        h2 = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
        if spec.ffn == "dense":
            f = gated_mlp(h2, p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"], cfg.act)
        else:
            f, moe_aux = moe_mod.moe_apply(
                h2, p["ffn"], cfg, policy, token_chunk=opt.moe_token_chunk
            )
            aux = aux + moe_aux.load_balance + moe_aux.router_z
        if cfg.post_norms:
            f = rms_norm(f, p["post_norm_ffn"], cfg.norm_eps)
        x = x + f
    return x, cache, aux


def apply_layer_decode(
    x, spec: LayerSpec, p, cache, cur_pos, cfg: ModelConfig,
    policy: ShardingPolicy, opt: ApplyOptions,
):
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm_mixer"], cfg.norm_eps)
    if spec.mixer in ("attn", "attn_local"):
        window = cfg.window if spec.mixer == "attn_local" else 0
        if cfg.mla is not None:
            y, cache = attn.mla_decode(h, p["mixer"], cache, cur_pos, cfg, policy)
        else:
            y, cache = attn.decode_attn(h, p["mixer"], cache, cur_pos, cfg, policy,
                                        window=window)
    elif spec.mixer == "mamba":
        y, cache = mamba_mod.mamba_decode(h, p["mixer"], cache, cfg)
    else:
        y = jnp.zeros_like(x)
    if cfg.post_norms and spec.mixer != "none":
        y = rms_norm(y, p["post_norm_mixer"], cfg.norm_eps)
    x = x + y

    if spec.ffn != "none":
        h2 = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
        if spec.ffn == "dense":
            f = gated_mlp(h2, p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"], cfg.act)
        else:
            f, moe_aux = moe_mod.moe_apply(h2, p["ffn"], cfg, policy, token_chunk=x.shape[0] * x.shape[1])
            aux = aux + moe_aux.load_balance + moe_aux.router_z
        if cfg.post_norms:
            f = rms_norm(f, p["post_norm_ffn"], cfg.norm_eps)
        x = x + f
    return x, cache, aux


# ---- cache construction from a dense (prefill) pass ------------------------


def _kv_cache_from_dense(h, pm, cfg, window, cache_len) -> KVCache:
    b, s, _ = h.shape
    pos = jnp.arange(s)
    k = jnp.einsum("bsd,dhk->bshk", h, pm["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, pm["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, pm["k_norm"], cfg.norm_eps)
    k = attn.apply_rope(k, pos, cfg.rope_theta)
    s_cache = cache_len if not window else min(window, cache_len)
    if s <= s_cache:
        padk = jnp.zeros((b, s_cache - s, *k.shape[2:]), k.dtype)
        kc = jnp.concatenate([k, padk], 1)
        vc = jnp.concatenate([v, jnp.zeros_like(padk)], 1)
        pc = jnp.concatenate([pos, jnp.full((s_cache - s,), -1, jnp.int32)])
    else:  # window cache keeps the ring-buffer layout: slot = pos % window
        idx = jnp.arange(s_cache)
        src = s - s_cache + ((idx - (s % s_cache)) % s_cache)
        kc, vc, pc = k[:, src], v[:, src], pos[src]
    return KVCache(k=kc, v=vc, pos=pc)


def _mla_cache_from_dense(h, pm, cfg, cache_len) -> MLACache:
    m = cfg.mla
    b, s, _ = h.shape
    pos = jnp.arange(s)
    kv_a = jnp.einsum("bsd,dr->bsr", h, pm["wkv_a"])
    c_kv, k_rope_raw = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, pm["kv_a_norm"], cfg.norm_eps)
    k_rope = attn.apply_rope(k_rope_raw[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
    pad = cache_len - s
    return MLACache(
        c_kv=jnp.concatenate([c_kv, jnp.zeros((b, pad, m.kv_lora_rank), c_kv.dtype)], 1),
        k_rope=jnp.concatenate([k_rope, jnp.zeros((b, pad, m.qk_rope_dim), k_rope.dtype)], 1),
        pos=jnp.concatenate([pos, jnp.full((pad,), -1, jnp.int32)]),
    )


def _mamba_state_from_dense(h, pm, cfg) -> mamba_mod.MambaState:
    # Run the recurrent form over the sequence to get the final state.
    # (Prefill cost of the state is already paid in the dense pass; this is
    # the exact state without storing per-step values: scan, keep last.)
    b, s, _ = h.shape
    st = mamba_mod.init_mamba_state(b, cfg, h.dtype)

    def step(carry, t):
        _, carry_st = mamba_mod.mamba_decode(
            jax.lax.dynamic_slice_in_dim(h, t, 1, axis=1), pm, carry, cfg
        )
        return carry_st, None

    st, _ = jax.lax.scan(step, st, jnp.arange(s))
    return st


# ---------------------------------------------------------------------------
# Cache init (for decode-only lowering).
# ---------------------------------------------------------------------------


def init_cache_for_spec(spec: LayerSpec, cfg: ModelConfig, b: int, cache_len: int, dtype):
    if spec.mixer in ("attn", "attn_local"):
        s_cache = cache_len if spec.mixer == "attn" else min(cfg.window or cache_len, cache_len)
        if cfg.mla is not None:
            return attn.init_mla_cache(b, cache_len, cfg.mla, dtype)
        return attn.init_kv_cache(b, s_cache, cfg.n_kv_heads, cfg.head_dim, dtype)
    if spec.mixer == "mamba":
        return mamba_mod.init_mamba_state(b, cfg, dtype)
    return None


def init_caches(cfg: ModelConfig, b: int, cache_len: int, dtype) -> dict:
    """Cache pytree matching the param structure (stacked per group)."""
    caches: dict[str, Any] = {}
    if cfg.prefix:
        caches["prefix"] = tuple(
            init_cache_for_spec(s, cfg, b, cache_len, dtype) for s in cfg.prefix
        )
    grp = []
    for spec in cfg.pattern:
        one = init_cache_for_spec(spec, cfg, b, cache_len, dtype)
        if one is None:
            grp.append(None)
        else:
            grp.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_groups, *a.shape)), one))
    caches["groups"] = tuple(grp)
    return caches


# ---------------------------------------------------------------------------
# Full stacks.
# ---------------------------------------------------------------------------


def _remat_wrap(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def run_stack_dense(
    x: jnp.ndarray,
    params: dict,
    cfg: ModelConfig,
    policy: ShardingPolicy,
    opt: ApplyOptions,
    *,
    collect_cache: bool = False,
    cache_len: int = 0,
):
    """Apply prefix + scanned groups.  Returns (x, caches|None, aux)."""
    aux_total = jnp.zeros((), jnp.float32)
    prefix_caches = []
    for spec, p in zip(cfg.prefix, params.get("prefix", ())):
        x, c, aux = apply_layer_dense(
            x, spec, p, cfg, policy, opt, collect_cache=collect_cache, cache_len=cache_len
        )
        aux_total += aux
        prefix_caches.append(c)

    def group_body(carry, gp):
        x, aux_acc = carry
        caches = []
        for spec, p in zip(cfg.pattern, gp):
            x, c, aux = apply_layer_dense(
                x, spec, p, cfg, policy, opt,
                collect_cache=collect_cache, cache_len=cache_len,
            )
            aux_acc = aux_acc + aux
            caches.append(c)
        if policy.distributed and policy.batch_axes:
            from jax.sharding import NamedSharding

            # SP: sequence-shard the inter-layer activation (and with it the
            # remat checkpoint / scan carry) over the TP axis — 1/tp_size the
            # activation residency; GSPMD inserts the Megatron-SP
            # all-gather/reduce-scatter pair around each layer body.
            seq_ax = policy.tp_axis if policy.seq_shard else None
            x = jax.lax.with_sharding_constraint(
                x, NamedSharding(policy.mesh, P(policy.batch_axes, seq_ax, None))
            )
        return (x, aux_acc), tuple(caches)

    body = _remat_wrap(group_body, opt.remat)
    (x, aux_total), group_caches = jax.lax.scan(
        body, (x, aux_total), params["groups"]
    )
    caches = None
    if collect_cache:
        caches = {"groups": group_caches}
        if cfg.prefix:
            caches["prefix"] = tuple(prefix_caches)
    return x, caches, aux_total


def run_stack_decode(
    x: jnp.ndarray,
    params: dict,
    caches: dict,
    cur_pos: jnp.ndarray,
    cfg: ModelConfig,
    policy: ShardingPolicy,
    opt: ApplyOptions,
):
    aux_total = jnp.zeros((), jnp.float32)
    new_prefix = []
    for spec, p, c in zip(cfg.prefix, params.get("prefix", ()), caches.get("prefix", ())):
        x, c, aux = apply_layer_decode(x, spec, p, c, cur_pos, cfg, policy, opt)
        aux_total += aux
        new_prefix.append(c)

    def group_body(carry, inp):
        x, aux_acc = carry
        gp, gc = inp
        new_caches = []
        for spec, p, c in zip(cfg.pattern, gp, gc):
            x, c, aux = apply_layer_decode(x, spec, p, c, cur_pos, cfg, policy, opt)
            aux_acc = aux_acc + aux
            new_caches.append(c)
        return (x, aux_acc), tuple(new_caches)

    (x, aux_total), new_group_caches = jax.lax.scan(
        group_body, (x, aux_total), (params["groups"], caches["groups"])
    )
    out_caches = {"groups": new_group_caches}
    if cfg.prefix:
        out_caches["prefix"] = tuple(new_prefix)
    return x, out_caches, aux_total
