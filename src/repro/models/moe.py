"""Mixture-of-Experts with expert parallelism (EP over the model axis).

Production layout (DeepSeek-V2 / OLMoE / Jamba style):

  * experts sharded over ``model`` (EP): each model shard owns E/M experts;
  * expert weights additionally stored F-sharded over the data axes
    (ZeRO-3); they are all-gathered once per layer inside the manual region
    (explicit, overlappable with the token-chunk scan);
  * tokens stay in their data-parallel row; dispatch crosses only the
    ``model`` axis via two all_to_alls (out and back);
  * dispatch is scatter/gather based (NO one-hot dispatch einsums — those
    inflate HLO FLOPs by the capacity factor and wreck the roofline);
  * token-chunked scan bounds the transient send/recv/expert buffers;
  * capacity overflow drops choices (standard GShard token dropping) with
    the slack controlled by ``capacity_factor``.

The same code path runs on a single device (ep_size=1: all_to_alls are
identity) so unit tests exercise the identical dispatch math.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.collectives import shard_map_compat
from .attention import ShardingPolicy
from .layers import activation, gated_mlp


def init_moe_params(key, cfg: ModelConfig, dtype) -> dict:
    mo = cfg.moe
    d, f, e = cfg.d_model, mo.d_ff_expert, mo.n_experts
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s,
        "w_gate": jax.random.normal(ks[1], (e, d, f), dtype) * s,
        "w_up": jax.random.normal(ks[2], (e, d, f), dtype) * s,
        "w_down": jax.random.normal(ks[3], (e, f, d), dtype) * (f ** -0.5),
    }
    if mo.n_shared:
        fs = mo.n_shared * f
        p["ws_gate"] = jax.random.normal(ks[4], (d, fs), dtype) * s
        p["ws_up"] = jax.random.normal(ks[5], (d, fs), dtype) * s
        p["ws_down"] = jax.random.normal(ks[6], (fs, d), dtype) * (fs ** -0.5)
    return p


class MoEAux(NamedTuple):
    load_balance: jnp.ndarray  # scalar aux loss
    router_z: jnp.ndarray  # scalar z loss


def _dispatch_ffn(
    xf: jnp.ndarray,  # [T, D] local tokens
    gates: jnp.ndarray,  # [T, k]
    eidx: jnp.ndarray,  # [T, k] global expert ids
    wg, wu, wd,  # local experts [E_l, D, F]
    *,
    n_experts: int,
    ep_axis: str | None,
    ep_size: int,
    capacity_factor: float,
    act: str,
    token_chunk: int,
) -> jnp.ndarray:
    t, d = xf.shape
    k = gates.shape[-1]
    e_local = n_experts // ep_size
    token_chunk = min(token_chunk, t)
    assert t % token_chunk == 0, (t, token_chunk)
    n_chunks = t // token_chunk
    cap_send = int(-(-token_chunk * k * capacity_factor // ep_size))
    cap_exp = int(-(-token_chunk * k * capacity_factor // e_local))

    def chunk_fn(carry, j):
        xs = jax.lax.dynamic_slice_in_dim(xf, j * token_chunk, token_chunk, axis=0)
        gs = jax.lax.dynamic_slice_in_dim(gates, j * token_chunk, token_chunk, axis=0)
        es = jax.lax.dynamic_slice_in_dim(eidx, j * token_chunk, token_chunk, axis=0)
        n = token_chunk * k
        e_flat = es.reshape(n)
        g_flat = gs.reshape(n)
        tok_of = jnp.repeat(jnp.arange(token_chunk), k)

        dest = e_flat // e_local  # [n] target model shard
        oh_dest = (dest[:, None] == jnp.arange(ep_size)[None, :]).astype(jnp.int32)
        rank = jnp.take_along_axis(jnp.cumsum(oh_dest, axis=0) - 1, dest[:, None], 1)[:, 0]
        # overflow -> rank >= cap_send -> scatter drops it
        send_x = jnp.zeros((ep_size, cap_send, d), xf.dtype).at[dest, rank].set(
            xs[tok_of], mode="drop"
        )
        send_e = jnp.full((ep_size, cap_send), -1, jnp.int32).at[dest, rank].set(
            (e_flat % e_local).astype(jnp.int32), mode="drop"
        )
        if ep_axis is not None and ep_size > 1:
            recv_x = jax.lax.all_to_all(send_x, ep_axis, split_axis=0, concat_axis=0, tiled=False)
            recv_e = jax.lax.all_to_all(send_e, ep_axis, split_axis=0, concat_axis=0, tiled=False)
        else:
            recv_x, recv_e = send_x, send_e

        rx = recv_x.reshape(ep_size * cap_send, d)
        re = recv_e.reshape(ep_size * cap_send)
        valid = re >= 0
        re_safe = jnp.where(valid, re, 0)
        oh_e = (jnp.where(valid, re, -1)[:, None] == jnp.arange(e_local)[None, :]).astype(jnp.int32)
        erank = jnp.take_along_axis(jnp.cumsum(oh_e, axis=0) - 1, re_safe[:, None], 1)[:, 0]
        erank = jnp.where(valid, erank, cap_exp)  # invalid -> dropped
        buf = jnp.zeros((e_local, cap_exp, d), xf.dtype).at[re_safe, erank].set(
            rx, mode="drop"
        )

        g = activation(
            jnp.einsum("ecd,edf->ecf", buf, wg, preferred_element_type=jnp.float32), act
        )
        u = jnp.einsum("ecd,edf->ecf", buf, wu, preferred_element_type=jnp.float32)
        h = jnp.einsum("ecf,efd->ecd", (g * u).astype(xf.dtype), wd,
                       preferred_element_type=jnp.float32).astype(xf.dtype)

        # gather results back into recv layout, a2a home, weighted-combine
        back = h[re_safe, jnp.clip(erank, 0, cap_exp - 1)]
        back = jnp.where((valid & (erank < cap_exp))[:, None], back, 0.0)
        back = back.reshape(ep_size, cap_send, d)
        if ep_axis is not None and ep_size > 1:
            ret = jax.lax.all_to_all(back, ep_axis, split_axis=0, concat_axis=0, tiled=False)
        else:
            ret = back
        y_choice = ret[dest, jnp.clip(rank, 0, cap_send - 1)]
        y_choice = jnp.where((rank < cap_send)[:, None], y_choice, 0.0)
        y_choice = y_choice * g_flat[:, None].astype(y_choice.dtype)
        y = jnp.zeros((token_chunk, d), xf.dtype).at[tok_of].add(y_choice.astype(xf.dtype))
        return carry, y

    _, ys = jax.lax.scan(chunk_fn, 0, jnp.arange(n_chunks))
    return ys.reshape(t, d)


def moe_apply(
    x: jnp.ndarray,  # [B, S, D]
    p: dict,
    cfg: ModelConfig,
    policy: ShardingPolicy,
    *,
    token_chunk: int = 4096,
) -> tuple[jnp.ndarray, MoEAux]:
    mo = cfg.moe
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, mo.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # aux losses (computed over the local logical batch; psum-free, the
    # mean is already a fine estimator and stays SPMD-friendly)
    me = jnp.mean(probs.reshape(-1, mo.n_experts), axis=0)
    ce = jnp.mean(
        (jax.nn.one_hot(eidx, mo.n_experts).sum(-2) > 0).astype(jnp.float32).reshape(
            -1, mo.n_experts
        ),
        axis=0,
    )
    aux = MoEAux(
        load_balance=mo.n_experts * jnp.sum(me * ce) * mo.aux_loss_weight,
        router_z=jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2) * mo.router_z_weight,
    )

    ep_axis = policy.tp_axis if policy.distributed else None
    ep_size = policy.tp_size() if policy.distributed else 1

    fn = functools.partial(
        _dispatch_ffn,
        n_experts=mo.n_experts,
        ep_axis=ep_axis,
        ep_size=ep_size,
        capacity_factor=mo.capacity_factor,
        act=cfg.act,
        token_chunk=token_chunk,
    )

    if policy.distributed and ep_size > 1:
        dpw = policy.dp_axes if policy.dp_axes else None  # weight storage
        dpb = policy.batch_axes if policy.batch_axes else None  # activations
        dp_lead = (dpb,) if dpb else ()
        tp = policy.tp_axis

        def region(xl, gl, el, wg, wu, wd):
            # ZeRO-3: gather the F-shard of expert weights over data axes
            if policy.dp_axes:
                wg = jax.lax.all_gather(wg, policy.dp_axes, axis=2, tiled=True)
                wu = jax.lax.all_gather(wu, policy.dp_axes, axis=2, tiled=True)
                wd = jax.lax.all_gather(wd, policy.dp_axes, axis=1, tiled=True)
            t_l = xl.shape[0] * xl.shape[1]
            xf = xl.reshape(t_l, d)
            gf = gl.reshape(t_l, -1)
            ef = el.reshape(t_l, -1)
            if t_l % ep_size == 0:
                # sequence-shard the tokens over the EP axis: each model
                # shard routes its own T/ep slice (SP x EP — no replicated
                # dispatch compute), outputs all-gathered back.
                t_m = t_l // ep_size
                start = jax.lax.axis_index(ep_axis) * t_m
                y = fn(
                    jax.lax.dynamic_slice_in_dim(xf, start, t_m, 0),
                    jax.lax.dynamic_slice_in_dim(gf, start, t_m, 0),
                    jax.lax.dynamic_slice_in_dim(ef, start, t_m, 0),
                    wg, wu, wd,
                )
                y = jax.lax.all_gather(y, ep_axis, axis=0, tiled=True)
            else:
                # tiny token counts (decode): replicated dispatch is cheaper
                # than padding to divisibility
                y = fn(xf, gf, ef, wg, wu, wd)
            return y.reshape(xl.shape)

        y = shard_map_compat(
            region,
            mesh=policy.mesh,
            in_specs=(
                P(*dp_lead, None, None),
                P(*dp_lead, None, None),
                P(*dp_lead, None, None),
                P(tp, None, dpw),
                P(tp, None, dpw),
                P(tp, dpw, None),
            ),
            out_specs=P(*dp_lead, None, None),
            axis_names=set((*policy.dp_axes, tp)),
            check_vma=False,
        )(x, gates.astype(x.dtype), eidx, p["w_gate"], p["w_up"], p["w_down"])
    else:
        y = fn(
            x.reshape(b * s, d),
            gates.astype(x.dtype).reshape(b * s, -1),
            eidx.reshape(b * s, -1),
            p["w_gate"], p["w_up"], p["w_down"],
        ).reshape(b, s, d)

    if mo.n_shared:
        y = y + gated_mlp(x, p["ws_gate"], p["ws_up"], p["ws_down"], cfg.act)
    return y, aux


def moe_ref(x, p, cfg) -> jnp.ndarray:
    """Dense per-expert reference (no capacity drops) for unit tests."""
    mo = cfg.moe
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, mo.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    y = jnp.zeros_like(x)
    for e in range(mo.n_experts):
        w = jnp.sum(jnp.where(eidx == e, gates, 0.0), axis=-1)  # [B,S]
        h = gated_mlp(x, p["w_gate"][e], p["w_up"][e], p["w_down"][e], cfg.act)
        y = y + h * w[..., None].astype(x.dtype)
    if mo.n_shared:
        y = y + gated_mlp(x, p["ws_gate"], p["ws_up"], p["ws_down"], cfg.act)
    return y
