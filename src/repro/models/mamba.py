"""Mamba2 (SSD — state-space duality) mixer: chunked train, recurrent decode.

TPU mapping: the SSD chunked form is used for training/prefill — all the
heavy work is batched matmuls (intra-chunk attention-like products and
chunk-state outer products) that map onto the MXU; the O(S) recurrence only
runs across chunk boundaries (S/chunk scan steps).  Heads are independent,
so tensor parallelism shards the head dimension over the model axis
(B/C groups are small and replicated).

Decode is the O(1) recurrent update over a [B, H, P, N] state — no KV
cache, which is why the SSM/hybrid archs own the 500k-token decode cells.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import rms_norm


def init_mamba_params(key, cfg: ModelConfig, dtype) -> dict:
    mc = cfg.mamba
    d = cfg.d_model
    d_in = cfg.d_inner_mamba
    h = cfg.n_mamba_heads
    gn = mc.n_groups * mc.d_state
    conv_dim = d_in + 2 * gn
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * d_in + 2 * gn + h), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (mc.conv_width, conv_dim), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01))).astype(jnp.float32),
        "gate_norm": jnp.zeros((d_in,), dtype),
        "out_proj": jax.random.normal(ks[2], (d_in, d), dtype) * (d_in ** -0.5),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    mc = cfg.mamba
    d_in = cfg.d_inner_mamba
    gn = mc.n_groups * mc.d_state
    z, xin, bc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in, 2 * d_in + 2 * gn], axis=-1)
    b_, c_ = jnp.split(bc, 2, axis=-1)
    return z, xin, b_, c_, dt


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Per-channel causal conv, u [B,S,C], w [W,C]."""
    width = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(width):  # width is 4 — unrolled taps stay fused
        out = out + pad[:, i : i + u.shape[1], :] * w[i]
    return out + b


class MambaState(NamedTuple):
    ssm: jnp.ndarray  # [B, H, P, N] f32
    conv: jnp.ndarray  # [B, W-1, conv_dim]


def init_mamba_state(b, cfg: ModelConfig, dtype) -> MambaState:
    mc = cfg.mamba
    h, p_, n = cfg.n_mamba_heads, mc.head_dim, mc.d_state
    conv_dim = cfg.d_inner_mamba + 2 * mc.n_groups * mc.d_state
    return MambaState(
        ssm=jnp.zeros((b, h, p_, n), jnp.float32),
        conv=jnp.zeros((b, mc.conv_width - 1, conv_dim), dtype),
    )


def mamba_dense(x: jnp.ndarray, p: dict, cfg: ModelConfig) -> jnp.ndarray:
    """Full-sequence SSD pass.  x [B, S, D] -> [B, S, D]."""
    mc = cfg.mamba
    bsz, s, _ = x.shape
    h, pd, n, g, q = cfg.n_mamba_heads, mc.head_dim, mc.d_state, mc.n_groups, mc.chunk
    q = min(q, s)
    assert s % q == 0, (s, q)
    nc = s // q
    hpg = h // g  # heads per group

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xin, b_, c_, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([xin, b_, c_], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xin, b_, c_ = jnp.split(xbc, [cfg.d_inner_mamba, cfg.d_inner_mamba + g * n], axis=-1)

    xh = xin.reshape(bsz, s, h, pd).astype(jnp.float32)
    bh = b_.reshape(bsz, s, g, n).astype(jnp.float32)
    ch = c_.reshape(bsz, s, g, n).astype(jnp.float32)
    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    a = -jnp.exp(p["A_log"])  # [H]
    la = dt_f * a  # log decay per step [B,S,H]

    # chunk views; expand B/C groups to heads (head h lives in group h // hpg)
    xc = xh.reshape(bsz, nc, q, h, pd)
    bc_ = jnp.repeat(bh, hpg, axis=2).reshape(bsz, nc, q, h, n)
    cc = jnp.repeat(ch, hpg, axis=2).reshape(bsz, nc, q, h, n)
    dtc = dt_f.reshape(bsz, nc, q, h)
    lac = la.reshape(bsz, nc, q, h)
    csum = jnp.cumsum(lac, axis=2)  # [B,nc,Q,H]

    # ---- chunk states: S_c = sum_j exp(csum_end - csum_j) dt_j B_j x_j^T
    decay_end = jnp.exp(csum[:, :, -1:, :] - csum)  # [B,nc,Q,H]
    bx = jnp.einsum(
        "bcqhn,bcqhp,bcqh->bchpn",
        bc_, xc, dtc * decay_end,
        preferred_element_type=jnp.float32,
    )  # [B,nc,H,P,N]

    # ---- inter-chunk recurrence
    chunk_decay = jnp.exp(csum[:, :, -1, :])  # [B,nc,H]

    def scan_fn(s_run, inp):
        bx_c, dec_c = inp  # [B,H,P,N], [B,H]
        s_prev = s_run
        s_new = s_run * dec_c[..., None, None] + bx_c
        return s_new, s_prev

    s0 = jnp.zeros((bsz, h, pd, n), jnp.float32)
    _, s_prevs = jax.lax.scan(
        scan_fn,
        s0,
        (bx.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )  # s_prevs [nc, B, H, P, N] = state entering each chunk
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    # ---- intra-chunk (diagonal) + inter-chunk (off-diagonal) outputs
    cb = jnp.einsum("bcihn,bcjhn->bchij", cc, bc_, preferred_element_type=jnp.float32)
    # decay matrix per head: exp(csum_i - csum_j), causal (i >= j)
    dmat = jnp.exp(csum[:, :, :, None, :] - csum[:, :, None, :, :])  # [B,nc,Qi,Qj,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    dmat = jnp.where(mask[None, None, :, :, None], dmat, 0.0)
    att = cb * jnp.moveaxis(dmat, -1, 2)  # [B,nc,H,Qi,Qj]
    att = att * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]  # dt_j on the j axis
    y_diag = jnp.einsum(
        "bchij,bcjhp->bcihp", att, xc, preferred_element_type=jnp.float32
    )  # [B,nc,Q,H,P]

    decay_start = jnp.exp(csum)  # [B,nc,Q,H]
    y_off = jnp.einsum(
        "bcqhn,bchpn,bcqh->bcqhp",
        cc, s_prevs, decay_start,
        preferred_element_type=jnp.float32,
    )

    y = (y_diag + y_off).reshape(bsz, s, h, pd) + xh * p["D"][None, None, :, None]
    y = y.reshape(bsz, s, cfg.d_inner_mamba).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["gate_norm"], cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"])


def mamba_decode(
    x: jnp.ndarray,  # [B, 1, D]
    p: dict,
    state: MambaState,
    cfg: ModelConfig,
) -> tuple[jnp.ndarray, MambaState]:
    mc = cfg.mamba
    bsz = x.shape[0]
    h, pd, n, g = cfg.n_mamba_heads, mc.head_dim, mc.d_state, mc.n_groups
    hpg = h // g

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])[:, 0]  # [B, K]
    d_in = cfg.d_inner_mamba
    gn = g * n
    z, xin, b_, c_, dt = (
        zxbcdt[:, :d_in],
        zxbcdt[:, d_in : 2 * d_in],
        zxbcdt[:, 2 * d_in : 2 * d_in + gn],
        zxbcdt[:, 2 * d_in + gn : 2 * d_in + 2 * gn],
        zxbcdt[:, 2 * d_in + 2 * gn :],
    )
    xbc = jnp.concatenate([xin, b_, c_], axis=-1)  # [B, conv_dim]
    conv_in = jnp.concatenate([state.conv, xbc[:, None, :]], axis=1)  # [B, W, C]
    conv_out = jnp.einsum("bwc,wc->bc", conv_in, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv = conv_in[:, 1:, :]

    xin, b_, c_ = (
        conv_out[:, :d_in],
        conv_out[:, d_in : d_in + gn],
        conv_out[:, d_in + gn :],
    )
    xh = xin.reshape(bsz, h, pd).astype(jnp.float32)
    bh = b_.reshape(bsz, g, n).astype(jnp.float32)
    ch = c_.reshape(bsz, g, n).astype(jnp.float32)
    dt_f = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    decay = jnp.exp(dt_f * (-jnp.exp(p["A_log"])))  # [B,H]

    bh_h = jnp.repeat(bh, hpg, axis=1)  # [B,H,N]
    ch_h = jnp.repeat(ch, hpg, axis=1)
    upd = jnp.einsum("bhp,bhn->bhpn", xh * dt_f[..., None], bh_h)
    new_ssm = state.ssm * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, ch_h) + xh * p["D"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)[:, None, :],
                 p["gate_norm"], cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"]), MambaState(new_ssm, new_conv)
