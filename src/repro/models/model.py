"""LMModel facade: init / loss / prefill / decode for every assigned arch.

Modality frontends are stubs per the brief: ``vision_stub`` prepends
precomputed patch embeddings (PaliGemma/SigLIP), ``audio_stub`` consumes
precomputed EnCodec frame embeddings (MusicGen).  The transformer backbone
is always the real thing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import transformer as tfm
from .attention import ShardingPolicy
from .layers import cross_entropy, embed_tokens, lm_logits, rms_norm


@dataclasses.dataclass(frozen=True)
class LMModel:
    cfg: ModelConfig
    policy: ShardingPolicy = ShardingPolicy()
    opt: tfm.ApplyOptions = tfm.ApplyOptions()

    # -- init ---------------------------------------------------------------
    def init(self, key) -> dict:
        return tfm.init_params(key, self.cfg)

    # -- input embedding (modality stubs) ------------------------------------
    def _embed_inputs(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.frontend == "audio_stub":
            return batch["frame_embeds"].astype(params["embed"].dtype)
        x = embed_tokens(batch["tokens"], params["embed"], cfg.scale_embeddings)
        if cfg.frontend == "vision_stub":
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
        return x

    # -- training loss --------------------------------------------------------
    def loss_fn(self, params, batch) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (loss, aux_loss). Labels are next-token targets."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        opt = dataclasses.replace(self.opt, prefix_len=cfg.prefix_tokens)
        x, _, aux = tfm.run_stack_dense(x, params, cfg, self.policy, opt)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if cfg.frontend == "vision_stub":
            x = x[:, cfg.prefix_tokens :]  # loss only on text positions
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = lm_logits(x, table, cfg.logit_softcap, cfg.vocab_size)
        if self.policy.distributed and self.policy.tp_axis:
            from jax.sharding import NamedSharding, PartitionSpec as P

            # keep the fp32 logits vocab-sharded through the loss
            logits = jax.lax.with_sharding_constraint(
                logits, NamedSharding(self.policy.mesh,
                                      P(self.policy.batch_axes or None, None,
                                        self.policy.tp_axis)))
        loss = cross_entropy(logits, batch["labels"], cfg.vocab_size)
        return loss + aux, aux

    # -- prefill --------------------------------------------------------------
    def prefill(self, params, batch, cache_len: int):
        """Dense pass over the prompt; returns (last_logits, caches)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        opt = dataclasses.replace(self.opt, prefix_len=cfg.prefix_tokens)
        x, caches, _ = tfm.run_stack_dense(
            x, params, cfg, self.policy, opt, collect_cache=True, cache_len=cache_len
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = lm_logits(x[:, -1:], table, cfg.logit_softcap, cfg.vocab_size)
        return logits, caches

    # -- decode ---------------------------------------------------------------
    def decode_step(self, params, token, caches, cur_pos):
        """token [B,1] int32 (or [B,1,D] embeds for audio_stub)."""
        cfg = self.cfg
        if cfg.frontend == "audio_stub" and token.ndim == 3:
            x = token.astype(params["embed"].dtype)
        else:
            x = embed_tokens(token, params["embed"], cfg.scale_embeddings)
        opt = dataclasses.replace(self.opt, remat="none")
        x, caches, _ = tfm.run_stack_decode(
            x, params, caches, cur_pos, cfg, self.policy, opt
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = lm_logits(x, table, cfg.logit_softcap, cfg.vocab_size)
        return logits, caches

    def init_caches(self, b: int, cache_len: int, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.dtype)
        return tfm.init_caches(self.cfg, b, cache_len, dtype)
