"""Shared model building blocks: norms, RoPE, gated MLP, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def activation(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(kind)


# --- RoPE -------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, n, head_dim]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- Gated MLP (SwiGLU / GeGLU) ---------------------------------------------


def gated_mlp(x: jnp.ndarray, w_gate, w_up, w_down, act: str) -> jnp.ndarray:
    g = activation(jnp.einsum("...d,df->...f", x, w_gate), act)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


# --- Embedding / LM head ----------------------------------------------------


def embed_tokens(tokens: jnp.ndarray, table: jnp.ndarray, scale: bool) -> jnp.ndarray:
    y = jnp.take(table, tokens, axis=0)
    if scale:
        y = y * jnp.asarray(table.shape[-1] ** 0.5, y.dtype)
    return y


def lm_logits(
    x: jnp.ndarray, table: jnp.ndarray, cap: float = 0.0, real_vocab: int | None = None
) -> jnp.ndarray:
    """x [..., D] @ table [V, D]^T with optional softcap + pad masking."""
    logits = jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)
    logits = softcap(logits, cap)
    if real_vocab is not None and real_vocab < table.shape[0]:
        neg = jnp.finfo(jnp.float32).min
        pad_mask = jnp.arange(table.shape[0]) >= real_vocab
        logits = jnp.where(pad_mask, neg, logits)
    return logits


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, real_vocab: int
) -> jnp.ndarray:
    """Mean token NLL; logits [B, S, V] (already fp32), labels [B, S].

    The gold logit is picked with a one-hot reduction, NOT take_along_axis:
    a gather along the vocab axis forces GSPMD to materialize the full
    fp32 logits on every device (vocab is TP-sharded), which was the
    dominant memory consumer of every train cell (EXPERIMENTS.md §Perf H1
    iteration 4). The masked reduction keeps the vocab axis sharded.
    """
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(
        labels.dtype, (1,) * labels.ndim + (logits.shape[-1],), labels.ndim)
    onehot = vocab_iota == labels[..., None]
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.mean(logz - gold)
