"""PartitionSpec assignment for params, optimizer state, caches, batches.

Rules (Megatron TP + ZeRO over data axes):
  * embeddings / LM head: vocab over `model`
  * attention projections: heads over `model` when divisible, else replicated
  * dense FFN: hidden (F) over `model`
  * MoE experts: E over `model` (EP) and F over data axes (ZeRO-3 storage
    matching the explicit gather in the MoE manual region)
  * mamba: d_inner-shaped dims over `model` (heads are independent)
  * optimizer state / fp32 masters: param spec + the largest remaining
    unsharded dim additionally over the data axes (ZeRO-1)
Stacked group params carry a leading `n_groups` dim -> prepend None.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def _divisible(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def param_specs(
    params: Any,
    cfg: ModelConfig,
    *,
    tp: str = "model",
    tp_size: int,
    dp_axes: tuple[str, ...] = (),
    dp_size: int = 1,
) -> Any:
    """Spec tree matching ``params`` (works on arrays or ShapeDtypeStructs)."""

    heads_ok = _divisible(cfg.n_heads, tp_size)
    kv_ok = _divisible(cfg.n_kv_heads, tp_size)

    def leaf_spec(path: tuple, leaf) -> P:
        names = [getattr(x, "key", getattr(x, "name", str(x))) for x in path]
        name = names[-1]
        stacked = "groups" in names  # leading n_groups dim
        lead = (None,) if stacked else ()

        def sp(*dims):
            return P(*lead, *dims)

        if name == "embed" or name == "head":
            return P(tp, None)
        if name in ("final_norm",):
            return P(None)
        # --- attention
        if name == "wq":
            return sp(None, tp if heads_ok else None, None)
        if name in ("wk", "wv"):
            return sp(None, tp if kv_ok else None, None)
        if name == "wo":
            return sp(tp if heads_ok else None, None, None)
        if name in ("wq_b",):  # [r, H, qd] — MLA heads
            return sp(None, tp if heads_ok else None, None)
        if name in ("w_uk", "w_uv"):  # [H, c, n]
            return sp(tp if heads_ok else None, None, None)
        if name in ("wq_a", "wkv_a"):
            return sp(None, None)
        # --- dense ffn
        if name in ("w_gate", "w_up") and len(leaf.shape) - len(lead) == 2:
            return sp(None, tp)
        if name == "w_down" and len(leaf.shape) - len(lead) == 2:
            return sp(tp, None)
        # --- moe experts [E, D, F] / [E, F, D]
        if name in ("w_gate", "w_up") and len(leaf.shape) - len(lead) == 3:
            return sp(tp, None, dp_axes if dp_axes else None)
        if name == "w_down" and len(leaf.shape) - len(lead) == 3:
            return sp(tp, dp_axes if dp_axes else None, None)
        if name in ("ws_gate", "ws_up"):
            return sp(None, tp)
        if name == "ws_down":
            return sp(tp, None)
        if name == "router":
            return sp(None, None)
        # --- mamba
        if name == "in_proj":
            return sp(None, tp)
        if name == "out_proj":
            return sp(tp, None)
        if name == "conv_w":
            return sp(None, tp)
        if name in ("conv_b", "gate_norm"):
            return sp(tp)
        if name in ("A_log", "D", "dt_bias"):
            return sp(None)
        # --- norms and leftovers: replicated
        return P(*((None,) * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def zero1_specs(params: Any, specs: Any, *, dp_axes: tuple[str, ...], dp_size: int) -> Any:
    """Optimizer-state specs: param spec + data axes on the biggest free dim."""
    if not dp_axes:
        return specs

    def one(leaf, spec: P) -> P:
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = set()
        for e in dims:
            for a in (e if isinstance(e, (tuple, list)) else (e,)):
                if a is not None:
                    used.add(a)
        if used & set(dp_axes):  # dp axes already placed (FSDP/MoE storage)
            return P(*dims)
        # find the largest dim that is unsharded and divisible by dp_size
        best, best_size = -1, 0
        for i, (d, s) in enumerate(zip(leaf.shape, dims)):
            if s is None and d % dp_size == 0 and d > best_size and d >= dp_size:
                best, best_size = i, d
        if best >= 0:
            dims[best] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        return P(*dims)

    return jax.tree.map(one, params, specs)


def cache_specs(
    caches: Any,
    cfg: ModelConfig,
    *,
    tp: str = "model",
    tp_size: int,
    dp_axes: tuple[str, ...] = (),
    cache_seq_axes: tuple[str, ...] = (),
    batch_shardable: bool = True,
) -> Any:
    """Specs for decode caches (stacked leading n_groups dim handled)."""
    kv_ok = _divisible(cfg.n_kv_heads, tp_size) and not cache_seq_axes
    dp = dp_axes if (dp_axes and batch_shardable) else None

    def leaf_spec(path: tuple, leaf) -> P:
        names = [getattr(x, "key", getattr(x, "name", str(x))) for x in path]
        name = names[-1]
        stacked = "groups" in names
        lead = (None,) if stacked else ()
        nd = len(leaf.shape) - len(lead)

        def sp(*dims):
            return P(*lead, *dims)

        if name in ("k", "v"):  # [B, S, KV, hd]
            if cache_seq_axes:
                return sp(dp, cache_seq_axes, None, None)
            return sp(dp, None, tp if kv_ok else None, None)
        if name in ("c_kv", "k_rope"):  # [B, S, r]
            return sp(dp, cache_seq_axes if cache_seq_axes else None, None)
        if name == "pos":  # [S]
            return sp(cache_seq_axes if cache_seq_axes else None)
        if name == "ssm":  # [B, H, P, N]
            return sp(dp, tp if _divisible(cfg.n_mamba_heads if cfg.mamba else 0, tp_size) else None, None, None)
        if name == "conv":  # [B, W-1, conv_dim]
            return sp(dp, None, tp)
        return P(*((None,) * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(leaf_spec, caches)


def batch_specs(batch: Any, dp_axes: tuple[str, ...], batch_shardable: bool = True) -> Any:
    dp = dp_axes if (dp_axes and batch_shardable) else None

    def one(leaf):
        return P(dp, *((None,) * (len(leaf.shape) - 1)))

    return jax.tree.map(one, batch)
