"""Attention: GQA/MQA/MHA + local(sliding) windows + MLA, train & decode.

Memory discipline (TPU): full score matrices are never materialized —
training/prefill uses chunked flash-style accumulation (nested lax.scan,
f32 running max/sum), decode uses either head-sharded einsums (when
n_kv_heads divides the model axis) or a shard_map flash-decode over a
sequence-sharded KV cache (partial softmax + psum combine) — the SP path
that makes 500k-token caches feasible.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MLAConfig, ModelConfig
from repro.core.collectives import shard_map_compat
from .layers import apply_rope, rms_norm, softcap

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ---------------------------------------------------------------------------
# Sharding policy threaded through model apply.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """How activations/caches are laid out on the mesh at apply time."""

    mesh: object | None = None
    dp_axes: tuple[str, ...] = ()  # batch axes ('pod','data')
    tp_axis: str | None = None  # 'model'
    # decode: shard the KV-cache sequence dim over these axes (flash-decode)
    cache_seq_axes: tuple[str, ...] = ()
    # False when the global batch is too small to shard over dp_axes
    # (e.g. long_500k has batch=1): activations replicate over dp, but
    # weight storage/gather still uses dp_axes.
    batch_sharded: bool = True
    # sequence-parallel: shard inter-layer activations over tp_axis (SP)
    seq_shard: bool = False

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return self.dp_axes if self.batch_sharded else ()

    @property
    def distributed(self) -> bool:
        return self.mesh is not None

    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[self.tp_axis]


# ---------------------------------------------------------------------------
# Parameter init.
# ---------------------------------------------------------------------------


def init_attn_params(key, cfg: ModelConfig, dtype) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, hq, hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, hkv, hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, hkv, hd), dtype) * s,
        "wo": jax.random.normal(k4, (hq, hd, d), dtype) * ((hq * hd) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def init_mla_params(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    assert m is not None
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    qd = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": jax.random.normal(ks[0], (d, m.q_lora_rank), dtype) * s,
        "q_a_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": jax.random.normal(ks[1], (m.q_lora_rank, h, qd), dtype)
        * (m.q_lora_rank ** -0.5),
        "wkv_a": jax.random.normal(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim), dtype) * s,
        "kv_a_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "w_uk": jax.random.normal(ks[3], (h, m.kv_lora_rank, m.qk_nope_dim), dtype)
        * (m.kv_lora_rank ** -0.5),
        "w_uv": jax.random.normal(ks[4], (h, m.kv_lora_rank, m.v_head_dim), dtype)
        * (m.kv_lora_rank ** -0.5),
        "wo": jax.random.normal(ks[5], (h, m.v_head_dim, d), dtype)
        * ((h * m.v_head_dim) ** -0.5),
    }


# ---------------------------------------------------------------------------
# Chunked flash-style attention (training / prefill).
# ---------------------------------------------------------------------------


def _mask_bias(q_pos, k_pos, window: int) -> jnp.ndarray:
    """[..., q, k] additive mask: causal + optional sliding window."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, NEG_INF)


class _KVChunks(NamedTuple):
    """Provider of K/V chunks — lets MLA expand lazily per chunk."""

    n_chunks: int
    chunk_len: int
    get: Callable  # j -> (k [B,c,KV,hdk], v [B,c,KV,hdv])


def _flash_over_kv(
    q: jnp.ndarray,  # [B, qc, KV, rep, hdk]  (f32-scaled already)
    kv: _KVChunks,
    q_pos: jnp.ndarray,  # [qc]
    *,
    window: int,
    cap: float,
    hdv: int,
) -> jnp.ndarray:
    b, qc, n_kv, rep, hdk = q.shape

    def step(carry, j):
        m, l, acc = carry
        k, v = kv.get(j)  # [B, c, KV, hdk/hdv]
        k_pos = j * kv.chunk_len + jnp.arange(kv.chunk_len)
        s = jnp.einsum(
            "bqkrh,bckh->bkrqc", q, k.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        s = softcap(s, cap)
        s = s + _mask_bias(q_pos, k_pos, window)  # [qc, c] broadcast
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bkrqc,bckh->bkrqh", p, v.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, n_kv, rep, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, rep, qc), jnp.float32)
    a0 = jnp.zeros((b, n_kv, rep, qc, hdv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(kv.n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # [B, KV, rep, qc, hdv] -> [B, qc, KV*rep, hdv]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, qc, n_kv * rep, hdv)


def chunked_attention(
    q: jnp.ndarray,  # [B, S, H, hdk]
    kv: _KVChunks,
    *,
    n_kv_heads: int,
    scale: float,
    window: int = 0,
    cap: float = 0.0,
    q_chunk: int = 512,
    hdv: int | None = None,
) -> jnp.ndarray:
    b, s, h, hdk = q.shape
    hdv = hdv if hdv is not None else hdk
    rep = h // n_kv_heads
    q_chunk = min(q_chunk, s)
    assert s % q_chunk == 0, (s, q_chunk)
    nq = s // q_chunk
    qs = (q.astype(jnp.float32) * scale).reshape(b, nq, q_chunk, n_kv_heads, rep, hdk)

    def one_q(j):
        qp = j * q_chunk + jnp.arange(q_chunk)
        return _flash_over_kv(
            qs[:, j], kv, qp, window=window, cap=cap, hdv=hdv
        )

    if nq == 1:
        out = one_q(0)[:, None]
    else:
        out = jax.lax.map(one_q, jnp.arange(nq)).transpose(1, 0, 2, 3, 4)
    return out.reshape(b, s, h, hdv)


def kv_chunks_from_arrays(k: jnp.ndarray, v: jnp.ndarray, chunk: int) -> _KVChunks:
    b, s, n_kv, hd = k.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)

    def get(j):
        kj = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, axis=1)
        return kj, vj

    return _KVChunks(n_chunks=s // chunk, chunk_len=chunk, get=get)


# ---------------------------------------------------------------------------
# Standard (GQA) attention layer — dense pass.
# ---------------------------------------------------------------------------


def attn_dense(
    x: jnp.ndarray,  # [B, S, D]
    p: dict,
    cfg: ModelConfig,
    *,
    window: int,
    q_chunk: int = 512,
    k_chunk: int = 1024,
) -> jnp.ndarray:
    b, s, d = x.shape
    pos = jnp.arange(s)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    scale = cfg.attn_scale if cfg.attn_scale is not None else cfg.head_dim ** -0.5
    out = chunked_attention(
        q,
        kv_chunks_from_arrays(k, v, k_chunk),
        n_kv_heads=cfg.n_kv_heads,
        scale=scale,
        window=window,
        cap=cfg.attn_softcap,
        q_chunk=q_chunk,
    )
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — dense pass with lazy per-chunk KV expansion.
# ---------------------------------------------------------------------------


def mla_dense(
    x: jnp.ndarray,
    p: dict,
    cfg: ModelConfig,
    *,
    q_chunk: int = 512,
    k_chunk: int = 512,
) -> jnp.ndarray:
    m = cfg.mla
    b, s, d = x.shape
    pos = jnp.arange(s)
    h = cfg.n_heads

    qa = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_a_norm"], cfg.norm_eps)
    qb = jnp.einsum("bsr,rhk->bshk", qa, p["wq_b"])
    q_nope, q_rope = jnp.split(qb, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)  # [B,S,H,nope+rope]

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv, k_rope_raw = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_a_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope_raw[:, :, None, :], pos, cfg.rope_theta)  # [B,S,1,rope]

    chunk = min(k_chunk, s)
    assert s % chunk == 0

    def get(j):
        c = jax.lax.dynamic_slice_in_dim(c_kv, j * chunk, chunk, axis=1)
        kr = jax.lax.dynamic_slice_in_dim(k_rope, j * chunk, chunk, axis=1)
        k_nope = jnp.einsum("bsc,hcn->bshn", c, p["w_uk"])
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr, (b, chunk, h, m.qk_rope_dim))], axis=-1
        )
        v = jnp.einsum("bsc,hcv->bshv", c, p["w_uv"])
        return k_full, v

    kv = _KVChunks(n_chunks=s // chunk, chunk_len=chunk, get=get)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    out = chunked_attention(
        q, kv, n_kv_heads=h, scale=scale, window=0, cap=cfg.attn_softcap,
        q_chunk=q_chunk, hdv=m.v_head_dim,
    )
    return jnp.einsum("bshv,hvd->bsd", out.astype(x.dtype), p["wo"])


# ---------------------------------------------------------------------------
# Decode: KV caches + single-token attention.
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jnp.ndarray  # [B, S_cache, KV, hd]
    v: jnp.ndarray  # [B, S_cache, KV, hd]
    pos: jnp.ndarray  # [S_cache] int32 absolute positions, -1 = empty


class MLACache(NamedTuple):
    c_kv: jnp.ndarray  # [B, S_cache, kv_lora]
    k_rope: jnp.ndarray  # [B, S_cache, rope_dim]
    pos: jnp.ndarray  # [S_cache]


def init_kv_cache(b, s_cache, n_kv, hd, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((b, s_cache, n_kv, hd), dtype),
        v=jnp.zeros((b, s_cache, n_kv, hd), dtype),
        pos=jnp.full((s_cache,), -1, jnp.int32),
    )


def init_mla_cache(b, s_cache, m: MLAConfig, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((b, s_cache, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((b, s_cache, m.qk_rope_dim), dtype),
        pos=jnp.full((s_cache,), -1, jnp.int32),
    )


def _flash_decode_local(q, kc, vc, kpos, cur_pos, *, scale, cap, window, axes):
    """Per-shard partial attention + cross-shard softmax combine.

    q [B,1,KV,rep,hd]; kc/vc [B,S_loc,KV,hd]; kpos [S_loc].
    Valid keys: pos in [cur_pos-window+1, cur_pos], pos >= 0.
    """
    s = jnp.einsum(
        "bqkrh,bskh->bkrqs", q.astype(jnp.float32) * scale, kc.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    s = softcap(s, cap)
    ok = (kpos >= 0) & (kpos <= cur_pos)
    if window:
        ok &= kpos > cur_pos - window
    s = jnp.where(ok, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    if axes:
        m = jax.lax.pmax(m, axes)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkrqs,bskh->bkrqh", p, vc.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    if axes:
        l = jax.lax.psum(l, axes)
        o = jax.lax.psum(o, axes)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    b, n_kv, rep, one, hd = out.shape
    return out.transpose(0, 3, 1, 2, 4).reshape(b, 1, n_kv * rep, hd)


def decode_attn(
    x: jnp.ndarray,  # [B, 1, D]
    p: dict,
    cache: KVCache,
    cur_pos: jnp.ndarray,  # [] int32: position of the new token
    cfg: ModelConfig,
    policy: ShardingPolicy,
    *,
    window: int,
) -> tuple[jnp.ndarray, KVCache]:
    b = x.shape[0]
    n_kv, hd = cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k_new = rms_norm(k_new, p["k_norm"], cfg.norm_eps)
    posv = cur_pos[None]
    q = apply_rope(q, jnp.broadcast_to(posv, (b, 1)), cfg.rope_theta)
    k_new = apply_rope(k_new, jnp.broadcast_to(posv, (b, 1)), cfg.rope_theta)

    s_cache = cache.k.shape[1]
    slot = (cur_pos % s_cache) if window else jnp.clip(cur_pos, 0, s_cache - 1)
    new_cache = KVCache(
        k=jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, axis=1),
        v=jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, axis=1),
        pos=jax.lax.dynamic_update_slice_in_dim(cache.pos, posv.astype(jnp.int32), slot, axis=0),
    )

    scale = cfg.attn_scale if cfg.attn_scale is not None else hd ** -0.5
    rep = cfg.n_heads // n_kv
    qr = q.reshape(b, 1, n_kv, rep, hd)

    axes = policy.cache_seq_axes
    if policy.distributed and axes:
        fn = functools.partial(
            _flash_decode_local, scale=scale, cap=cfg.attn_softcap,
            window=window, axes=axes,
        )
        # only the manual (cache-seq) axes appear in specs; batch sharding
        # over the dp axes stays auto and flows through untouched
        out = shard_map_compat(
            fn,
            mesh=policy.mesh,
            in_specs=(
                P(None, None, None, None, None),
                P(None, axes, None, None),
                P(None, axes, None, None),
                P(axes),
                P(),
            ),
            out_specs=P(None, None, None, None),
            axis_names=set(axes),
            check_vma=False,
        )(qr, new_cache.k, new_cache.v, new_cache.pos, cur_pos)
    else:
        out = _flash_decode_local(
            qr, new_cache.k, new_cache.v, new_cache.pos, cur_pos,
            scale=scale, cap=cfg.attn_softcap, window=window, axes=(),
        )
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return y, new_cache


def mla_decode(
    x: jnp.ndarray,  # [B, 1, D]
    p: dict,
    cache: MLACache,
    cur_pos: jnp.ndarray,
    cfg: ModelConfig,
    policy: ShardingPolicy,
) -> tuple[jnp.ndarray, MLACache]:
    """Absorbed-form MLA decode: attends directly over the compressed cache."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    posv = cur_pos[None]

    qa = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_a_norm"], cfg.norm_eps)
    qb = jnp.einsum("bsr,rhk->bshk", qa, p["wq_b"])
    q_nope, q_rope = jnp.split(qb, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, jnp.broadcast_to(posv, (b, 1)), cfg.rope_theta)
    # absorb W_UK into the query: [B,1,H,C]
    q_c = jnp.einsum("bshn,hcn->bshc", q_nope, p["w_uk"])

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_new, k_rope_raw = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_new = rms_norm(c_new, p["kv_a_norm"], cfg.norm_eps)
    k_rope_new = apply_rope(k_rope_raw[:, :, None, :], jnp.broadcast_to(posv, (b, 1)),
                            cfg.rope_theta)[:, :, 0, :]

    s_cache = cache.c_kv.shape[1]
    slot = jnp.clip(cur_pos, 0, s_cache - 1)
    new_cache = MLACache(
        c_kv=jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new.astype(cache.c_kv.dtype), slot, axis=1),
        k_rope=jax.lax.dynamic_update_slice_in_dim(cache.k_rope, k_rope_new.astype(cache.k_rope.dtype), slot, axis=1),
        pos=jax.lax.dynamic_update_slice_in_dim(cache.pos, posv.astype(jnp.int32), slot, axis=0),
    )

    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5

    def local_fn(q_c, q_r, ckv, krope, kpos, cur):
        s = jnp.einsum("bqhc,bsc->bhqs", q_c.astype(jnp.float32),
                       ckv.astype(jnp.float32), preferred_element_type=jnp.float32)
        s += jnp.einsum("bqhr,bsr->bhqs", q_r.astype(jnp.float32),
                        krope.astype(jnp.float32), preferred_element_type=jnp.float32)
        s *= scale
        ok = (kpos >= 0) & (kpos <= cur)
        s = jnp.where(ok, s, NEG_INF)
        mx = jnp.max(s, axis=-1)
        axes = policy.cache_seq_axes if policy.distributed else ()
        if axes:
            mx = jax.lax.pmax(mx, axes)
        pr = jnp.exp(s - mx[..., None])
        l = jnp.sum(pr, axis=-1)
        o = jnp.einsum("bhqs,bsc->bqhc", pr, ckv.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        if axes:
            l = jax.lax.psum(l, axes)
            o = jax.lax.psum(o, axes)
        return o / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]

    axes = policy.cache_seq_axes
    if policy.distributed and axes:
        o_c = shard_map_compat(
            local_fn,
            mesh=policy.mesh,
            in_specs=(
                P(None, None, None, None),
                P(None, None, None, None),
                P(None, axes, None),
                P(None, axes, None),
                P(axes),
                P(),
            ),
            out_specs=P(None, None, None, None),
            axis_names=set(axes),
            check_vma=False,
        )(q_c, q_rope, new_cache.c_kv, new_cache.k_rope, new_cache.pos, cur_pos)
    else:
        o_c = local_fn(q_c, q_rope, new_cache.c_kv, new_cache.k_rope, new_cache.pos, cur_pos)

    out_heads = jnp.einsum("bqhc,hcv->bqhv", o_c, p["w_uv"].astype(jnp.float32))
    y = jnp.einsum("bshv,hvd->bsd", out_heads.astype(x.dtype), p["wo"])
    return y, new_cache
