"""Model substrate: layers, attention (GQA/MLA/local), MoE, Mamba2, stacks."""

from .model import LMModel  # noqa: F401
