"""Blockwise int8 quantization for optimizer moments (8-bit Adam).

Blocks run along the LAST dim (padded), so ``scale`` has shape
``(*leading, ceil(last/BLOCK))`` — it shards with the same leading-dim
specs as the parameter and never forces a flatten/reshard of a big
sharded array during the optimizer update.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class QTensor(NamedTuple):
    q: jnp.ndarray  # int8, original shape
    scale: jnp.ndarray  # f32 [*leading, n_blocks]


BLOCK = 256


def quantize(x: jnp.ndarray) -> QTensor:
    x32 = x.astype(jnp.float32)
    if x32.ndim == 0:
        x32 = x32[None]
        scalar = True
    else:
        scalar = False
    *lead, last = x32.shape
    pad = (-last) % BLOCK
    if pad:
        x32 = jnp.concatenate(
            [x32, jnp.zeros((*lead, pad), jnp.float32)], axis=-1
        )
    nb = x32.shape[-1] // BLOCK
    blocks = x32.reshape(*lead, nb, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe[..., None]), -127, 127).astype(jnp.int8)
    q = q.reshape(*lead, nb * BLOCK)[..., :last]
    if scalar:
        q = q[0]
    return QTensor(q=q, scale=scale)


def dequantize(t: QTensor, shape=None) -> jnp.ndarray:
    q = t.q
    if q.ndim == 0:
        q = q[None]
        scalar = True
    else:
        scalar = False
    *lead, last = q.shape
    pad = (-last) % BLOCK
    q32 = q.astype(jnp.float32)
    if pad:
        q32 = jnp.concatenate([q32, jnp.zeros((*lead, pad), jnp.float32)], axis=-1)
    nb = q32.shape[-1] // BLOCK
    out = (q32.reshape(*lead, nb, BLOCK) * t.scale[..., None]).reshape(
        *lead, nb * BLOCK
    )[..., :last]
    if scalar:
        out = out[0]
    return out
