"""Optimizers: AdamW with optional 8-bit (blockwise-quantized) moments.

The 8-bit moment store is a distributed-optimization feature: for the
100B+-param assigned configs, fp32 (m, v) at 8 bytes/param exceeds the
per-chip HBM budget even fully ZeRO-sharded; blockwise int8 moments cut
optimizer state to ~2.1 bytes/param (DESIGN.md §4).
"""

from .adamw import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    global_norm,
    make_lr_schedule,
)
from .quant import QTensor, dequantize, quantize

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "global_norm",
    "make_lr_schedule",
    "QTensor",
    "quantize",
    "dequantize",
]
