"""AdamW with fp32 or blockwise-int8 moments and fp32 master weights.

Shardable by construction: state leaves mirror param shapes, so ZeRO specs
from ``models.sharding.zero1_specs`` apply directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .quant import QTensor, dequantize, quantize


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized: bool = False  # int8 moments
    master_fp32: bool = True  # keep an fp32 master copy of bf16 params


class AdamWState(NamedTuple):
    count: jnp.ndarray
    m: Any  # tree of arrays or QTensors
    v: Any
    master: Any  # fp32 master params or None


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    def zeros(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return quantize(z) if cfg.quantized else z

    master = None
    if cfg.master_fp32:
        # copy=True: fp32 params would otherwise alias the master buffers,
        # breaking donation (donate(params) + donate(opt.master) same buffer)
        master = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        master=master,
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(
    grads, state: AdamWState, params, cfg: AdamWConfig, lr: jnp.ndarray
):
    """Returns (new_params, new_state, stats)."""
    count = state.count + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p, master):
        g32 = g.astype(jnp.float32)
        m32 = dequantize(m) if isinstance(m, QTensor) else m
        v32 = dequantize(v) if isinstance(v, QTensor) else v
        m32 = cfg.b1 * m32 + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v32 + (1 - cfg.b2) * g32 * g32
        mh = m32 / c1
        vh = v32 / c2
        base = master if master is not None else p.astype(jnp.float32)
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * base
        new_master = base - lr * step
        new_p = new_master.astype(p.dtype)
        m_out = quantize(m32) if isinstance(m, QTensor) else m32
        v_out = quantize(v32) if isinstance(v, QTensor) else v32
        return new_p, m_out, v_out, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    flat_master = (
        treedef.flatten_up_to(state.master) if state.master is not None else [None] * len(flat_p)
    )
    outs = [upd(g, m, v, p, ms) for g, m, v, p, ms in zip(flat_g, flat_m, flat_v, flat_p, flat_master)]
    new_params = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    new_master = treedef.unflatten([o[3] for o in outs]) if cfg.master_fp32 else None
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(count, new_m, new_v, new_master), stats


def make_lr_schedule(base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def lr_at(step):
        s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = base_lr * jnp.minimum(1.0, (s + 1) / max(warmup, 1))
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, base_lr * cos)

    return lr_at
