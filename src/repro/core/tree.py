"""Aggregation-tree construction over a device mesh (paper §3 "Controller").

The paper's controller knows (1) the worker count and (2) the physical
topology, builds an aggregation tree, and disseminates it to the switches.
Our controller knows the JAX mesh and builds a `AggregationTree`: an ordered
list of levels, leaf -> root, each level being one mesh axis.  Reducing over
a level = one in-network aggregation hop; the scarcest link (inter-pod) is
the root level, so it sees only data that every lower level has already
reduced — the paper's on-path reduction.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from .reduction_model import TreeTrafficModel


@dataclasses.dataclass(frozen=True)
class TreeLevel:
    axis: str  # mesh axis name
    fanin: int  # number of children per node at this level
    link_gbps: float  # per-direction bandwidth of this level's links (GB/s)


@dataclasses.dataclass(frozen=True)
class AggregationTree:
    """Leaf-to-root reduction schedule over mesh axes."""

    levels: tuple[TreeLevel, ...]

    @property
    def axes(self) -> tuple[str, ...]:
        return tuple(l.axis for l in self.levels)

    @property
    def fanin(self) -> int:
        return math.prod(l.fanin for l in self.levels)

    def traffic_model(self, grad_bytes: int) -> TreeTrafficModel:
        return TreeTrafficModel(grad_bytes=grad_bytes, fanins=tuple(l.fanin for l in self.levels))

    def describe(self) -> str:
        parts = [f"{l.axis}(x{l.fanin} @ {l.link_gbps:g} GB/s)" for l in self.levels]
        return " -> ".join(parts) + " -> root"


# Default link bandwidths for the production target (TPU v5e-like).
ICI_GBPS = 50.0  # intra-pod ICI per link
DCN_GBPS = 6.25  # inter-pod per-chip share (25 GbE-class DCN x2)


def from_mesh(
    mesh,
    *,
    reduce_axes: Sequence[str] = ("data", "pod"),
    link_gbps: dict[str, float] | None = None,
) -> AggregationTree:
    """Build the aggregation tree from a mesh, leaf->root = cheap->scarce.

    Axes missing from the mesh are skipped, so the same call works for
    single-pod (no 'pod' axis) and multi-pod meshes.
    """
    link_gbps = link_gbps or {"data": ICI_GBPS, "model": ICI_GBPS, "pod": DCN_GBPS}
    levels = []
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    for ax in reduce_axes:
        if ax in sizes and sizes[ax] > 1:
            levels.append(TreeLevel(axis=ax, fanin=sizes[ax], link_gbps=link_gbps.get(ax, ICI_GBPS)))
    if not levels:
        # degenerate single-device mesh — one trivial level keeps APIs total
        levels.append(TreeLevel(axis=names[0], fanin=1, link_gbps=ICI_GBPS))
    return AggregationTree(levels=tuple(levels))


def worker_tree(n_workers: int, fanin: int, link_gbps: float = ICI_GBPS) -> AggregationTree:
    """Paper-style tree for N workers with a fixed switch radix (Fig. 1).

    Used by the MapReduce example: ceil(log_fanin(n)) levels of ``fanin``.
    """
    if n_workers < 1:
        raise ValueError("need at least one worker")
    levels = []
    remaining = n_workers
    i = 0
    while remaining > 1:
        f = min(fanin, remaining)
        levels.append(TreeLevel(axis=f"lvl{i}", fanin=f, link_gbps=link_gbps))
        remaining = math.ceil(remaining / f)
        i += 1
    if not levels:
        levels.append(TreeLevel(axis="lvl0", fanin=1, link_gbps=link_gbps))
    return AggregationTree(levels=tuple(levels))
