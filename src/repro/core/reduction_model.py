"""SwitchAgg analytic reduction model — Eq. (1)-(3) and Theorems 2.1/2.2.

This module is the paper-faithful analytic layer.  It is pure Python/NumPy
(no jax) so the planner can call it at trace time without entering a jit.
Byte-size assumptions (pair size, Ethernet-domain header, per-pair
metadata) come from ``repro.net.wire`` — the single wire-format source
shared with the packet simulator (DESIGN.md §7), itself jax-free.

Paper quantities (all in units of one average KV pair unless noted):
    M  — data amount arriving at an aggregation node
    N  — key variety (number of distinct keys), N <= M
    C  — aggregation-node memory capacity (number of resident pairs)
    R  — reduction ratio: fraction of input traffic removed by the node

Eq. (3) of the paper, uniform key distribution:

    R = 1 - N/M          if N <= C
    R = (1/N - 1/M) * C  if N >  C

The attainable reduction is bounded by C/N — single-node memory capacity is
the dominant limit (paper §2.2.2, Fig. 2a).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

from repro.net import wire

# ---------------------------------------------------------------------------
# Eq. (1): extra-traffic ratio of fixed-format KV encapsulation (RMT/DAIET).
# ---------------------------------------------------------------------------


def fixed_format_extra_traffic(slot_bytes: int, pair_bytes: Sequence[int]) -> float:
    """Eq. (1): T = M / sum(P_i).

    ``slot_bytes`` is the fixed slot size N each pair is padded to; the packet
    carries ``len(pair_bytes)`` slots, so M = len(pair_bytes) * slot_bytes.
    Returns the multiplicative traffic factor (1.0 == no waste).
    """
    if not pair_bytes:
        raise ValueError("need at least one pair")
    if any(p <= 0 or p > slot_bytes for p in pair_bytes):
        raise ValueError("pair lengths must be in (0, slot_bytes]")
    total_payload = float(sum(pair_bytes))
    packet = float(len(pair_bytes) * slot_bytes)
    return packet / total_payload


def switchagg_extra_traffic(
    pair_bytes: Sequence[int],
    metadata_bytes: int = wire.PAIR_META_BYTES,
) -> float:
    """SwitchAgg's variable-length encoding: per-pair metadata instead of padding."""
    total_payload = float(sum(pair_bytes))
    encoded = total_payload + metadata_bytes * len(pair_bytes)
    return encoded / total_payload


# ---------------------------------------------------------------------------
# Eq. (2): header overhead of small-packet transport.
# ---------------------------------------------------------------------------


def header_overhead_bytes(
    data_bytes: int,
    max_payload: int,
    header_bytes: int = wire.ETH_HEADER_BYTES,
) -> int:
    """Eq. (2): T = D + floor(D / M) * H  (paper's formula, Ethernet domain)."""
    if max_payload <= 0:
        raise ValueError("max_payload must be positive")
    return data_bytes + (data_bytes // max_payload) * header_bytes


def header_overhead_ratio(
    max_payload: int,
    header_bytes: int = wire.ETH_HEADER_BYTES,
) -> float:
    """Asymptotic overhead ratio H/M (paper: 58/229 ≈ 25.3% for 200B RMT)."""
    return header_bytes / float(max_payload)


# ---------------------------------------------------------------------------
# Eq. (3): single-node reduction ratio, uniform keys.
# ---------------------------------------------------------------------------


def reduction_ratio(data_amount: float, key_variety: float, capacity: float) -> float:
    """Eq. (3).  All arguments in units of one KV pair."""
    m, n, c = float(data_amount), float(key_variety), float(capacity)
    if m <= 0 or n <= 0 or c < 0:
        raise ValueError("M, N must be positive; C non-negative")
    if n > m:
        raise ValueError("key variety N cannot exceed data amount M")
    if n <= c:
        return 1.0 - n / m
    return (1.0 / n - 1.0 / m) * c


def reduction_ratio_bound(key_variety: float, capacity: float) -> float:
    """Upper bound C/N when N > C (paper §2.2.2), else the N<=C ideal bound."""
    n, c = float(key_variety), float(capacity)
    return min(1.0, c / n)


# ---------------------------------------------------------------------------
# Stream simulators — used to *verify* Eq. (3) and Theorems 2.1 / 2.2.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class NodeStats:
    """Traffic accounting for one simulated aggregation node."""

    input_pairs: int = 0
    output_pairs: int = 0  # evictions + final flush

    @property
    def reduction(self) -> float:
        if self.input_pairs == 0:
            return 0.0
        return 1.0 - self.output_pairs / self.input_pairs


class HashAggregationNode:
    """Faithful simulator of one SwitchAgg processing engine.

    Direct-mapped hash table of ``capacity`` slots (the paper uses buckets of
    a few slots; ``ways`` models that).  On collision the resident pair is
    EVICTED downstream (paper §4.2.4) — the engine never stalls.
    """

    def __init__(self, capacity: int, ways: int = 4, seed: int = 0x9E3779B9):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.ways = max(1, min(ways, capacity))
        self.buckets = max(1, capacity // self.ways)
        self.capacity = self.buckets * self.ways
        self._mult = (0x9E3779B97F4A7C15 ^ seed) & 0xFFFFFFFFFFFFFFFF
        # key -1 marks an empty slot
        self.keys = np.full((self.buckets, self.ways), -1, dtype=np.int64)
        self.values = np.zeros((self.buckets, self.ways), dtype=np.float64)
        self.stats = NodeStats()

    def _bucket(self, key: int) -> int:
        h = ((key & 0xFFFFFFFFFFFFFFFF) * self._mult) & 0xFFFFFFFFFFFFFFFF
        return int((h >> 33) % self.buckets)

    def push(self, key: int, value: float) -> tuple[int, float] | None:
        """Process one pair; returns an evicted (key, value) or None."""
        self.stats.input_pairs += 1
        b = self._bucket(key)
        row_keys = self.keys[b]
        hit = np.nonzero(row_keys == key)[0]
        if hit.size:  # aggregate (SUM)
            self.values[b, hit[0]] += value
            return None
        empty = np.nonzero(row_keys == -1)[0]
        if empty.size:  # insert
            self.keys[b, empty[0]] = key
            self.values[b, empty[0]] = value
            return None
        # collision: evict slot 0 (paper evicts the previously stored key),
        # shift remaining, insert the new pair in the last way.
        evicted = (int(row_keys[0]), float(self.values[b, 0]))
        self.keys[b, :-1] = self.keys[b, 1:]
        self.values[b, :-1] = self.values[b, 1:]
        self.keys[b, -1] = key
        self.values[b, -1] = value
        self.stats.output_pairs += 1
        return evicted

    def flush(self) -> list[tuple[int, float]]:
        """End-of-task flush (EoT) of all resident pairs."""
        out = []
        occ = self.keys != -1
        for b, w in zip(*np.nonzero(occ)):
            out.append((int(self.keys[b, w]), float(self.values[b, w])))
        self.stats.output_pairs += len(out)
        self.keys[:] = -1
        self.values[:] = 0.0
        return out


def simulate_node(
    keys: np.ndarray, values: np.ndarray | None, capacity: int, ways: int = 4
) -> tuple[NodeStats, list[tuple[int, float]]]:
    """Run one stream through one node; returns stats + full output stream."""
    node = HashAggregationNode(capacity, ways=ways)
    if values is None:
        values = np.ones_like(keys, dtype=np.float64)
    out: list[tuple[int, float]] = []
    for k, v in zip(keys.tolist(), values.tolist()):
        ev = node.push(int(k), float(v))
        if ev is not None:
            out.append(ev)
    out.extend(node.flush())
    return node.stats, out


def simulate_chain(
    keys: np.ndarray,
    values: np.ndarray | None,
    capacities: Sequence[int],
    ways: int = 4,
) -> tuple[float, list[NodeStats]]:
    """Multi-hop aggregation (paper Fig. 2b): a streamline of nodes.

    Each node's output stream (evictions + flush) feeds the next node.
    Returns (end-to-end reduction ratio, per-node stats).
    """
    if values is None:
        values = np.ones_like(keys, dtype=np.float64)
    stream = list(zip(keys.tolist(), values.tolist()))
    n_in = len(stream)
    stats: list[NodeStats] = []
    for cap in capacities:
        node = HashAggregationNode(cap, ways=ways)
        nxt: list[tuple[int, float]] = []
        for k, v in stream:
            ev = node.push(int(k), float(v))
            if ev is not None:
                nxt.append(ev)
        nxt.extend(node.flush())
        stats.append(node.stats)
        stream = nxt
    if n_in == 0:
        return 0.0, stats
    return 1.0 - len(stream) / n_in, stats


def merge_flows(flows: Iterable[np.ndarray]) -> np.ndarray:
    """Theorem 2.1 helper: interleave several flows into one (round-robin,
    matching a switch serving input ports fairly)."""
    arrs = [np.asarray(f) for f in flows]
    total = sum(a.size for a in arrs)
    out = np.empty(total, dtype=np.int64)
    idx = 0
    cursors = [0] * len(arrs)
    while idx < total:
        for i, a in enumerate(arrs):
            if cursors[i] < a.size:
                out[idx] = a[cursors[i]]
                cursors[i] += 1
                idx += 1
    return out


# ---------------------------------------------------------------------------
# Workload generators (paper §6.1: uniform and Zipf-0.99).
# ---------------------------------------------------------------------------


def uniform_keys(data_amount: int, key_variety: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, key_variety, size=data_amount, dtype=np.int64)


def zipf_keys(
    data_amount: int, key_variety: int, skew: float = 0.99, seed: int = 0
) -> np.ndarray:
    """Zipf(skew) over a finite key universe (paper uses skew 0.99)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, key_variety + 1, dtype=np.float64)
    probs = ranks ** (-skew)
    probs /= probs.sum()
    return rng.choice(key_variety, size=data_amount, p=probs).astype(np.int64)


# ---------------------------------------------------------------------------
# TPU-domain byte model: what the tree schedule moves per link level.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TreeTrafficModel:
    """Bytes each topology level carries for one gradient exchange.

    ``flat``  — single all-reduce over all chips: every link level carries
                2·(w-1)/w · bytes (ring), including the scarce inter-pod level.
    ``tree``  — SwitchAgg schedule: reduce-scatter at level i happens on
                1/prod(upper fan-ins) of the bytes only after lower levels
                reduced; inter-pod traffic shrinks by the intra-pod fan-in.
    """

    grad_bytes: int
    fanins: tuple[int, ...]  # leaf -> root, e.g. (16, 2) = data axis, pod axis

    def flat_bytes_per_level(self) -> list[float]:
        w = math.prod(self.fanins)
        return [2.0 * (w - 1) / w * self.grad_bytes for _ in self.fanins]

    def tree_bytes_per_level(self) -> list[float]:
        out = []
        shard = float(self.grad_bytes)
        for i, f in enumerate(self.fanins):
            # reduce-scatter + all-gather at this level on the current shard
            out.append(2.0 * (f - 1) / f * shard)
            shard /= f
        return out

    def tree_reduction_at_root(self) -> float:
        """Traffic reduction on the topmost (scarcest) level vs flat."""
        flat = self.flat_bytes_per_level()[-1]
        tree = self.tree_bytes_per_level()[-1]
        return 1.0 - tree / flat
