"""Plan-driven multi-level aggregation dataplane (DESIGN.md §6).

The paper's data reduction ratio is governed by how much aggregation state
each hop holds (Eq. 3's ``C``) and what reduction function it runs; the
FPE/BPE hierarchy exists to lift that bound at EVERY level of the tree.
This module is the execution layer that honors a controller plan end to
end: it takes the per-tree memory partition the planner emitted
(``ConfigureMsg`` / ``ExchangePlan``, DESIGN.md §3) and runs the full
multi-level cascade —

    level 0 FPE/BPE node  --evictions+flush-->  level 1 node  --> ... root

— each level a bounded-memory SwitchAgg node sized by its slice of the
plan's combiner budget, with per-level telemetry (records in/out,
evictions, reduction ratio: the paper's key metric, Fig. 2b/Fig. 9).

Two backends execute the same plan: ``jnp`` (the ``core.kvagg`` scan
oracle) and ``pallas`` (the VMEM FPE kernel, ``kernels.kv_aggregate``).
Op semantics come from the ``core.aggops`` registry; cascades carry the
op's *carried* representation between levels (e.g. ``mean``'s (sum, count)
lanes) and finalize only at the root, which is what makes multi-level
mean/logsumexp exact.

A ``LevelSpec`` with ``capacity == 0`` is the exact unbounded node (pure
sorted combine, no FPE) — the planner's ``fpe_capacity=0`` convention.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from . import aggops, kvagg
from . import reduction_model as rm

EMPTY_KEY = kvagg.EMPTY_KEY


@dataclasses.dataclass(frozen=True)
class LevelSpec:
    """One cascade hop: an FPE/BPE node's geometry.

    capacity == 0 means the exact unbounded combine (no FPE, no evictions).
    ``enabled == False`` is a forward-only hop (DESIGN.md §9): the level's
    switches have no aggregation capability (or the placement search left
    them out) and relay every record unaggregated — the per-level knob the
    fat-tree placement uses to express host-only / ToR-only / full-tree
    deployments inside one cascade.
    """

    capacity: int
    ways: int = 4
    bpe: bool = True
    enabled: bool = True


@dataclasses.dataclass(frozen=True)
class CascadePlan:
    """The dataplane's view of a controller plan: op + per-level nodes."""

    op: str
    levels: tuple[LevelSpec, ...]

    def __post_init__(self):
        if not self.levels:
            raise ValueError("a cascade needs at least one level")
        aggops.get(self.op)  # fail fast on unknown ops

    @property
    def capacities(self) -> tuple[int, ...]:
        return tuple(l.capacity for l in self.levels)

    def describe(self) -> str:
        caps = " -> ".join(str(c) for c in self.capacities)
        return f"{self.op} cascade [{caps}]"


def even_split_levels(budget: int, n_levels: int, *, ways: int = 4,
                      bpe: bool = True) -> tuple[LevelSpec, ...]:
    """THE per-level memory partition rule: a tree's combiner budget split
    evenly among its levels (each slice >= 1 pair); budget 0 means every
    level is the exact unbounded node.  Both plan builders below use this —
    change the partition policy here and nowhere else."""
    n_levels = max(1, n_levels)
    cap = max(1, budget // n_levels) if budget > 0 else 0
    return tuple(LevelSpec(capacity=cap, ways=ways, bpe=bpe)
                 for _ in range(n_levels))


def uniform_levels(capacity: int, n_levels: int, *, ways: int = 4,
                   bpe: bool = True) -> tuple[LevelSpec, ...]:
    """Per-NODE sizing: every level gets the full ``capacity`` (each switch
    owns its own memory — the paper's testbed view, and the legacy
    ``fpe_capacity=`` call convention)."""
    return tuple(LevelSpec(capacity=max(0, capacity), ways=ways, bpe=bpe)
                 for _ in range(max(1, n_levels)))


def placement_levels(capacities: Sequence[int], enabled: Sequence[bool],
                     *, ways: int = 4, bpe: bool = True
                     ) -> tuple[LevelSpec, ...]:
    """Per-level specs from a fat-tree placement (DESIGN.md §9): each level
    gets its own per-switch capacity, and unplaced levels are forward-only
    hops — the per-switch knob replacing the uniform-budget split."""
    capacities = tuple(int(c) for c in capacities)
    enabled = tuple(bool(e) for e in enabled)
    if len(capacities) != len(enabled):
        raise ValueError("level_capacities and level_enabled differ in length")
    if not capacities:
        raise ValueError("a placement needs at least one level")
    return tuple(LevelSpec(capacity=c, ways=ways, bpe=bpe, enabled=e)
                 for c, e in zip(capacities, enabled))


def plan_from_placement(placement, *, op: str = "sum", ways: int = 4,
                        bpe: bool = True) -> CascadePlan:
    """Cascade for a ``planner.TreePlacement`` (duck-typed on
    ``level_capacities``/``level_enabled``): one node per tree level, each
    sized by the placed switch's own table budget."""
    return CascadePlan(op=op, levels=placement_levels(
        placement.level_capacities, placement.level_enabled,
        ways=ways, bpe=bpe))


def plan_from_configure(cfg, *, ways: int = 4, bpe: bool = True) -> CascadePlan:
    """Per-level memory partition of a controller ``ConfigureMsg``.

    ``cfg.fpe_capacity`` is the whole tree's combiner budget (the §4.2.2
    per-job partition); each of the tree's levels gets an even slice — the
    per-LEVEL partition the cascade executes.  A fat-tree placement
    (DESIGN.md §9) overrides that: when ``cfg.level_capacities`` is
    non-empty, every level runs at its placed switch's own capacity and
    unplaced levels forward.  ``cfg`` is duck-typed (``level_axes``,
    ``fpe_capacity``, ``op``) to avoid importing planner.
    """
    cfg = getattr(cfg, "configure", cfg)  # accept a JobPlan directly
    caps = tuple(getattr(cfg, "level_capacities", ()) or ())
    if caps:
        enabled = tuple(getattr(cfg, "level_enabled", ()) or
                        (True,) * len(caps))
        return CascadePlan(op=cfg.op, levels=placement_levels(
            caps, enabled, ways=ways, bpe=bpe))
    return CascadePlan(
        op=cfg.op,
        levels=even_split_levels(cfg.fpe_capacity, len(cfg.level_axes),
                                 ways=ways, bpe=bpe),
    )


def cascade_from_exchange_plan(xplan, *, ways: int = 4,
                               bpe: bool = True, op: str | None = None
                               ) -> CascadePlan:
    """Cascade for a gradient ``ExchangePlan``: one node per upper (scarce)
    axis hop.  A placement-carrying plan (``level_capacities`` set,
    DESIGN.md §9) sizes each hop from its placed switch's table; otherwise
    the plan's combiner budget is split evenly among the hops."""
    op = op if op is not None else getattr(xplan, "op", "sum")
    n = max(1, len(xplan.upper_axes))
    caps = tuple(getattr(xplan, "level_capacities", ()) or ())
    if len(caps) >= n:  # trailing entries = the upper (scarce) hops
        enabled = tuple(getattr(xplan, "level_enabled", ()) or
                        (True,) * len(caps))
        return CascadePlan(op=op, levels=placement_levels(
            caps[-n:], enabled[-n:], ways=ways, bpe=bpe))
    return CascadePlan(
        op=op,
        levels=even_split_levels(xplan.fpe_capacity, len(xplan.upper_axes),
                                 ways=ways, bpe=bpe),
    )


class LevelStats(NamedTuple):
    n_in: jnp.ndarray  # [] int32 — real pairs entering the node
    n_out: jnp.ndarray  # [] int32 — forwarded pairs leaving the node
    n_evict: jnp.ndarray  # [] int32 — FPE evictions at the node


def run_level(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    spec: LevelSpec,
    op: str,
    *,
    backend: str = "jnp",
    block_n: int = 512,
    interpret: bool | None = None,
    exact_stream: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, LevelStats]:
    """One cascade hop on carried values; traceable inside jit/shard_map.

    Returns (out_keys, out_values, stats).  With ``capacity > 0`` the
    output is [capacity + n(+capacity)] (table flush + eviction stream,
    BPE-combined when ``spec.bpe``); with ``capacity == 0`` it is the
    exact packed combine of shape [n].  A disabled spec (``enabled ==
    False``, DESIGN.md §9) forwards the stream untouched: out == in,
    no evictions — the placement search's "this tier has no aggregation
    capability" hop.  ``exact_stream=False`` runs the node's FPE on the
    batched-block fast path (DESIGN.md §8): identical grouped totals,
    non-paper-faithful eviction pattern.
    """
    if not spec.enabled:
        n_real = jnp.sum(keys != EMPTY_KEY).astype(jnp.int32)
        return keys, values, LevelStats(
            n_in=n_real, n_out=n_real, n_evict=jnp.zeros((), jnp.int32))
    if spec.capacity == 0:
        n_in = jnp.sum(keys != EMPTY_KEY).astype(jnp.int32)
        c = kvagg.sorted_combine(keys, values, op=op)
        return c.unique_keys, c.combined_values, LevelStats(
            n_in=n_in, n_out=c.n_unique, n_evict=jnp.zeros((), jnp.int32))
    if backend == "pallas":
        from repro.kernels.kv_aggregate import fpe_aggregate_pallas

        tk, tv, ek, ev = fpe_aggregate_pallas(
            keys, values, capacity=spec.capacity, ways=spec.ways, op=op,
            block_n=block_n, interpret=interpret, exact_stream=exact_stream)
    elif backend == "jnp":
        tk, tv, ek, ev = kvagg.fpe_aggregate(
            keys, values, capacity=spec.capacity, ways=spec.ways, op=op,
            exact_stream=exact_stream)
    else:
        raise ValueError(f"unknown dataplane backend: {backend!r}")
    # one node-assembly policy for all paths (kvagg.assemble_node)
    res = kvagg.assemble_node(keys, tk, tv, ek, ev, op=op, bpe=spec.bpe)
    return res.out_keys, res.out_values, LevelStats(
        n_in=res.n_in, n_out=res.n_out, n_evict=res.n_evict)


class CascadeResult(NamedTuple):
    """Root output + per-level telemetry of one cascade execution.

    ``keys``/``values`` are the root stream (packed unique + finalized when
    run with the defaults).  ``n_in``/``n_out`` are the cascade's traffic
    endpoints: pairs entering level 0 and pairs leaving the last level
    (BEFORE any final packing — the wire metric).  The ``level_*`` arrays
    are leaf->root telemetry.
    """

    keys: jnp.ndarray
    values: jnp.ndarray
    n_in: jnp.ndarray  # [] int32
    n_out: jnp.ndarray  # [] int32
    level_in: jnp.ndarray  # [n_levels] int32
    level_out: jnp.ndarray  # [n_levels] int32
    level_evict: jnp.ndarray  # [n_levels] int32


@functools.partial(
    jax.jit,
    static_argnames=("plan", "backend", "block_n", "interpret",
                     "final_combine", "prepare", "finalize", "exact_stream"),
)
def run_cascade(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    plan: CascadePlan,
    *,
    backend: str = "jnp",
    block_n: int = 512,
    interpret: bool | None = None,
    final_combine: bool = True,
    prepare: bool = True,
    finalize: bool = True,
    exact_stream: bool = True,
) -> CascadeResult:
    """Execute a full multi-level cascade plan over one KV stream.

    The eviction-plus-flush stream of level *i* feeds level *i+1* (the
    paper's multi-hop streamline, Fig. 2b / ``reduction_model.simulate_chain``).
    ``prepare``/``finalize`` apply the op's carried-representation
    conversions at the edges; ``final_combine`` packs the root stream into
    unique keys (exact grouped result) without affecting ``n_out``, which
    always measures the traffic leaving the last level.  ``exact_stream=
    False`` runs every FPE on the batched-block fast path (DESIGN.md §8):
    grouped totals are identical, per-level eviction *traffic* may differ
    from the paper-faithful scan — keep the default for Fig. 9 curves.
    """
    op = aggops.get(plan.op)
    k = keys
    v = op.prepare_values(values) if prepare else values
    li, lo, le = [], [], []
    for spec in plan.levels:
        k, v, stats = run_level(k, v, spec, plan.op, backend=backend,
                                block_n=block_n, interpret=interpret,
                                exact_stream=exact_stream)
        li.append(stats.n_in)
        lo.append(stats.n_out)
        le.append(stats.n_evict)
    n_out = lo[-1]
    if final_combine:
        c = kvagg.sorted_combine(k, v, op=plan.op)
        k, v = c.unique_keys, c.combined_values
    if finalize:
        v = op.finalize_values(v)
    return CascadeResult(
        keys=k, values=v, n_in=li[0], n_out=n_out,
        level_in=jnp.stack(li), level_out=jnp.stack(lo),
        level_evict=jnp.stack(le),
    )


# ---------------------------------------------------------------------------
# Streaming (packet-batched) ingest — DESIGN.md §7.
# ---------------------------------------------------------------------------

_EMPTY = int(EMPTY_KEY)


class LevelState:
    """One cascade node ingesting packet-sized batches (DESIGN.md §7).

    The stateful, eager counterpart of :func:`run_level`: the FPE table
    persists *across* ``ingest`` calls — exactly a switch whose resident
    pairs survive between packets and leave only as evictions or in the
    end-of-task ``flush``.  ``net.sim`` runs one ``LevelState`` per switch;
    :func:`run_cascade_stream` chains one per level.

    ``batch_pad`` pads every ingest to a fixed length (the packet record
    capacity) so the underlying jitted FPE compiles once; batches longer
    than ``batch_pad`` are chunked.  Without ``batch_pad``, ingests are
    padded to the next power of two (min ``MIN_PAD``) — the shape-stable
    size buckets that keep the trace count O(log max_batch) across
    arbitrary packet lengths instead of one retrace per distinct length
    (DESIGN.md §8).  A ``capacity == 0`` spec is the exact unbounded
    node: it absorbs every record (no evictions) and emits its whole
    table at ``flush`` — ingests just buffer rows, compacted to the
    unique-key combine by a bulk ``sorted_combine`` (pow2-padded so the
    jit compiles once per size bucket) whenever the buffer tops
    ``COMPACT_THRESHOLD`` and at flush.

    ``exact_stream=False`` runs each ingest's FPE on the batched-block
    fast path (DESIGN.md §8) — same grouped totals and resident table
    geometry, eviction pattern not paper-faithful.  A disabled spec
    (``enabled == False``, DESIGN.md §9) makes the node a pure relay:
    every ingest forwards its real records verbatim and the flush is
    empty — how an unplaced fat-tree switch behaves.

    Telemetry mirrors :class:`LevelStats`: ``n_in`` real pairs ingested,
    ``n_evict`` FPE evictions, ``n_out`` pairs forwarded downstream
    (per-batch BPE-combined evictions when ``spec.bpe``, plus the flush).
    """

    #: pending-row count above which the capacity-0 node compacts its
    #: buffer with one bulk sorted_combine (keeps memory ~O(variety))
    COMPACT_THRESHOLD = 8192

    #: smallest shape-stable ingest pad (no batch_pad): packets shorter
    #: than this share one trace instead of one per tiny length
    MIN_PAD = 8

    def __init__(self, spec: LevelSpec, op: str, *,
                 batch_pad: int | None = None, exact_stream: bool = True):
        self.spec = spec
        self.op = op
        self._aggop = aggops.get(op)
        self.batch_pad = batch_pad
        self.exact_stream = exact_stream
        self._tk: jnp.ndarray | None = None
        self._tv: jnp.ndarray | None = None
        # capacity == 0: buffered rows, bulk-combined lazily — per-record
        # combine() calls would pay a jax dispatch per record for jnp ops
        self._exact: list[tuple[np.ndarray, np.ndarray]] | None = (
            [] if spec.capacity == 0 and spec.enabled else None)
        self._exact_rows = 0
        self._value_sample: np.ndarray | None = None  # dtype/lane template
        self.n_in = 0
        self.n_evict = 0
        self.n_out = 0
        self._flushed = False

    def _empty_out(self) -> tuple[np.ndarray, np.ndarray]:
        if self._value_sample is None:
            return (np.zeros((0,), np.int32), np.zeros((0,), np.float32))
        v = self._value_sample
        return (np.zeros((0,), np.int32),
                np.zeros((0,) + v.shape, v.dtype))

    def ingest(self, keys, values) -> tuple[np.ndarray, np.ndarray]:
        """Feed one batch of carried-representation records; returns the
        packed (keys, values) this node forwards downstream right now."""
        if self._flushed:
            raise RuntimeError("LevelState already flushed")
        keys = np.asarray(keys, np.int32)
        values = np.asarray(values)
        if keys.shape[0] != values.shape[0]:
            raise ValueError("keys/values leading dims differ")
        if self._value_sample is None and values.shape[0]:
            self._value_sample = np.zeros(values.shape[1:], values.dtype)
        real = keys != _EMPTY
        self.n_in += int(real.sum())
        if not real.any():
            return self._empty_out()
        if not self.spec.enabled:  # forward-only hop (DESIGN.md §9)
            fk, fv = keys[real].astype(np.int32), values[real]
            self.n_out += int(fk.shape[0])
            return fk, fv
        if self._exact is not None:  # capacity == 0: exact unbounded node
            self._exact.append((keys[real], values[real]))
            self._exact_rows += int(real.sum())
            if self._exact_rows > self.COMPACT_THRESHOLD:
                self._compact_exact()
            return self._empty_out()
        if self.batch_pad:
            pad = self.batch_pad
        else:
            # shape-stable size bucket: next pow2 >= len (min MIN_PAD), so
            # varying packet lengths reuse O(log n) compiled traces
            pad = max(self.MIN_PAD,
                      1 << (int(keys.shape[0]) - 1).bit_length())
        out_k, out_v = [], []
        for lo in range(0, keys.shape[0], pad):
            ek, ev = self._ingest_chunk(keys[lo:lo + pad],
                                        values[lo:lo + pad], pad)
            if ek.size:
                out_k.append(ek)
                out_v.append(ev)
        if not out_k:
            return self._empty_out()
        fk, fv = np.concatenate(out_k), np.concatenate(out_v)
        self.n_out += fk.shape[0]
        return fk, fv

    def _ingest_chunk(self, keys: np.ndarray, values: np.ndarray,
                      pad: int) -> tuple[np.ndarray, np.ndarray]:
        if keys.shape[0] < pad:
            fill = pad - keys.shape[0]
            keys = np.concatenate(
                [keys, np.full((fill,), _EMPTY, np.int32)])
            values = np.concatenate(
                [values, np.zeros((fill,) + values.shape[1:], values.dtype)])
        res = kvagg.fpe_aggregate(
            jnp.asarray(keys), jnp.asarray(values),
            capacity=self.spec.capacity, ways=self.spec.ways, op=self.op,
            exact_stream=self.exact_stream,
            table_keys=self._tk, table_values=self._tv)
        self._tk, self._tv = res.table_keys, res.table_values
        self.n_evict += int(np.sum(np.asarray(res.evict_keys) != _EMPTY))
        ek, ev = res.evict_keys, res.evict_values
        if self.spec.bpe:  # combine this packet's evictions (fixed shape)
            c = kvagg.sorted_combine(ek, ev, op=self.op)
            ek, ev = c.unique_keys, c.combined_values
        ek, ev = np.asarray(ek), np.asarray(ev)
        mask = ek != _EMPTY
        return ek[mask], ev[mask]

    def _compact_exact(self) -> None:
        """Collapse the capacity-0 buffer to its unique-key combine (one
        bulk sorted_combine instead of per-record combine dispatches).
        Input is padded to a power-of-two length so the jitted combine
        compiles once per size bucket, not once per compaction."""
        k = np.concatenate([k for k, _ in self._exact])
        v = np.concatenate([v for _, v in self._exact])
        pad = max(1, 1 << (int(k.shape[0]) - 1).bit_length()) - k.shape[0]
        if pad:
            k = np.concatenate([k, np.full((pad,), _EMPTY, np.int32)])
            v = np.concatenate([v, np.zeros((pad,) + v.shape[1:], v.dtype)])
        c = kvagg.sorted_combine(jnp.asarray(k), jnp.asarray(v), op=self.op)
        nu = int(c.n_unique)
        ck = np.asarray(c.unique_keys)[:nu]
        cv = np.asarray(c.combined_values)[:nu]
        self._exact = [(ck, cv)]
        self._exact_rows = nu

    def flush(self) -> tuple[np.ndarray, np.ndarray]:
        """End-of-task flush: pack and emit every resident pair."""
        self._flushed = True
        if self._exact is not None:
            if not self._exact_rows:
                return self._empty_out()
            self._compact_exact()
            fk, fv = self._exact[0]
        elif self._tk is None:
            return self._empty_out()
        else:
            tk, tv = np.asarray(self._tk), np.asarray(self._tv)
            mask = tk != _EMPTY
            fk, fv = tk[mask].astype(np.int32), tv[mask]
        self.n_out += fk.shape[0]
        return fk, fv


def run_cascade_stream(
    batches: Iterable[tuple[jnp.ndarray, jnp.ndarray]],
    plan: CascadePlan,
    *,
    batch_pad: int | None = None,
    final_combine: bool = True,
    prepare: bool = True,
    finalize: bool = True,
    exact_stream: bool = True,
) -> CascadeResult:
    """Packet-batched counterpart of :func:`run_cascade` (DESIGN.md §7).

    ``batches`` is an iterator of (keys, values) ingests — packets off the
    wire instead of one monolithic array.  Per-level node state persists
    across batches and each batch's evictions cascade downstream
    immediately (the paper's streamline, batch- rather than task-clocked);
    the end-of-stream flush then drains the tables leaf to root.  Grouping
    the root stream by key equals :func:`run_cascade`'s exact result for
    every registered op — packetization changes *traffic* (what ``n_out``
    measures), never totals.

    Ingest is shape-stable: without ``batch_pad`` every packet is padded
    to a pow2 size bucket (``LevelState.MIN_PAD`` floor), so streaming
    arbitrary packet lengths compiles O(log max_len) FPE traces, not one
    per distinct length (DESIGN.md §8).  ``exact_stream=False`` runs all
    node FPEs on the batched-block fast path.
    """
    op = aggops.get(plan.op)
    states = [LevelState(spec, plan.op, batch_pad=batch_pad,
                         exact_stream=exact_stream)
              for spec in plan.levels]
    root_k: list[np.ndarray] = []
    root_v: list[np.ndarray] = []

    def push(i: int, k, v) -> None:
        if np.asarray(k).shape[0] == 0:
            return
        if i == len(states):
            root_k.append(np.asarray(k, np.int32))
            root_v.append(np.asarray(v))
            return
        ek, ev = states[i].ingest(k, v)
        push(i + 1, ek, ev)

    with obs_trace.get_tracer().span("dataplane.run_cascade_stream",
                                     cat="dataplane"):
        for k, v in batches:
            v = np.asarray(op.prepare_values(jnp.asarray(v))) if prepare \
                else np.asarray(v)
            push(0, np.asarray(k, np.int32), v)
        for i, st in enumerate(states):
            fk, fv = st.flush()
            push(i + 1, fk, fv)

    if root_k:
        rk = np.concatenate(root_k)
        rv = np.concatenate(root_v)
    else:
        rk = np.zeros((0,), np.int32)
        # empty root still needs the op's carried lane shape (mean carries
        # (sum, count)) or finalize below would index a missing lane axis
        tmpl = states[0]._value_sample
        if tmpl is not None:
            rv = np.zeros((0,) + tmpl.shape, tmpl.dtype)
        elif prepare:
            rv = np.asarray(op.prepare_values(jnp.zeros((0,), jnp.float32)))
        else:
            rv = np.zeros((0,), np.float32)
    k_out, v_out = jnp.asarray(rk), jnp.asarray(rv)
    if final_combine and rk.size:
        c = kvagg.sorted_combine(k_out, v_out, op=plan.op)
        k_out, v_out = c.unique_keys, c.combined_values
    if finalize:
        v_out = op.finalize_values(v_out)
    i32 = lambda xs: jnp.asarray(np.asarray(xs, np.int32))  # noqa: E731
    _publish_levels(
        plan.op,
        [{"level": i, "records_in": int(s.n_in),
          "records_out": int(s.n_out), "evictions": int(s.n_evict),
          "reduction": round(1.0 - int(s.n_out) / max(int(s.n_in), 1), 4)}
         for i, s in enumerate(states)],
        end_to_end=round(1.0 - int(states[-1].n_out)
                         / max(int(states[0].n_in), 1), 4),
        source="stream")
    return CascadeResult(
        keys=k_out, values=v_out,
        n_in=i32(states[0].n_in), n_out=i32(states[-1].n_out),
        level_in=i32([s.n_in for s in states]),
        level_out=i32([s.n_out for s in states]),
        level_evict=i32([s.n_evict for s in states]),
    )


def level_reductions(res: CascadeResult) -> jnp.ndarray:
    """Per-hop measured reduction ratio R_i = 1 - out_i/in_i (paper's R)."""
    return 1.0 - res.level_out / jnp.maximum(res.level_in, 1)


def end_to_end_reduction(res: CascadeResult) -> jnp.ndarray:
    """Whole-cascade reduction: traffic leaving the root vs entering leaf."""
    return 1.0 - res.n_out / jnp.maximum(res.n_in, 1)


def predicted_level_reductions(
    plan: CascadePlan, data_amount: int, key_variety: int
) -> list[float]:
    """Eq. 3 applied hop by hop: level *i* sees the (modeled) survivor
    stream of level *i-1*; key variety is preserved by aggregation."""
    preds = []
    m = float(max(1, data_amount))
    n = float(max(1, min(key_variety, data_amount)))
    for spec in plan.levels:
        if spec.capacity == 0:  # exact node: ideal reduction
            r = 1.0 - min(n, m) / m
        else:
            r = rm.reduction_ratio(m, min(n, m), spec.capacity)
        preds.append(r)
        m = max(n, m * (1.0 - r))
    return preds


def _publish_levels(op_name: str, levels: list, *, end_to_end: float,
                    source: str) -> None:
    """Per-level cascade telemetry into the obs registry (DESIGN.md §11).

    ``run_cascade`` is jitted, so publishing happens at its observation
    points — :func:`telemetry` (post device_get, ``source="cascade"``)
    and the eager :func:`run_cascade_stream` (``source="stream"``).
    """
    reg = obs_metrics.get_registry()
    base = {"op": op_name, "source": source}
    for lvl in levels:
        lbl = dict(base, level=lvl["level"])
        reg.counter("dataplane.level.records_in_total",
                    **lbl).inc(lvl["records_in"])
        reg.counter("dataplane.level.records_out_total",
                    **lbl).inc(lvl["records_out"])
        reg.counter("dataplane.level.evictions_total",
                    **lbl).inc(lvl["evictions"])
        reg.gauge("dataplane.level.reduction", **lbl).set(lvl["reduction"])
        if "predicted_reduction" in lvl:
            reg.gauge("dataplane.level.predicted_reduction",
                      **lbl).set(lvl["predicted_reduction"])
    reg.gauge("dataplane.end_to_end_reduction", **base).set(end_to_end)


def telemetry(res: CascadeResult, plan: CascadePlan) -> dict:
    """JSON-able per-level report (the dry-run / bench record)."""
    li = [int(x) for x in jax.device_get(res.level_in)]
    lo = [int(x) for x in jax.device_get(res.level_out)]
    le = [int(x) for x in jax.device_get(res.level_evict)]
    levels = []
    for i, spec in enumerate(plan.levels):
        levels.append({
            "level": i,
            "capacity": spec.capacity,
            "records_in": li[i],
            "records_out": lo[i],
            "evictions": le[i],
            "reduction": round(1.0 - lo[i] / max(li[i], 1), 4),
        })
    report = {
        "op": plan.op,
        "levels": levels,
        "n_in": int(res.n_in),
        "n_out": int(res.n_out),
        "end_to_end_reduction": round(float(end_to_end_reduction(res)), 4),
    }
    _publish_levels(plan.op, levels,
                    end_to_end=report["end_to_end_reduction"],
                    source="cascade")
    return report


def simulate_plan(
    plan: CascadePlan,
    *,
    data_amount: int = 4096,
    key_variety: int = 512,
    dist: str = "uniform",
    seed: int = 0,
    backend: str = "jnp",
    interpret: bool | None = None,
) -> dict:
    """Run a synthetic stream through the cascade and report per-level
    predicted (Eq. 3) vs simulated reduction — the dry-run's dataplane
    validation record.
    """
    gen = rm.uniform_keys if dist == "uniform" else rm.zipf_keys
    keys = jnp.asarray(gen(data_amount, key_variety, seed=seed).astype("int32"))
    values = jnp.ones((data_amount,), jnp.float32)
    res = run_cascade(keys, values, plan, backend=backend, interpret=interpret)
    report = telemetry(res, plan)
    preds = predicted_level_reductions(plan, data_amount, key_variety)
    reg = obs_metrics.get_registry()
    for lvl, p in zip(report["levels"], preds):
        lvl["predicted_reduction"] = round(p, 4)
        # same label set telemetry() used, so the dashboard can join the
        # Eq.3 prediction against the measured reduction per level
        reg.gauge("dataplane.level.predicted_reduction", op=plan.op,
                  source="cascade",
                  level=lvl["level"]).set(lvl["predicted_reduction"])
    report["dist"] = dist
    report["data_amount"] = data_amount
    report["key_variety"] = key_variety
    return report
