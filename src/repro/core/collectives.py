"""Gradient/KV aggregation collectives — the SwitchAgg dataplane on a mesh.

Three exchange modes (the paper's comparison axis):

  * ``flat``          — one all-reduce over every reduction axis at once.
                        This is the no-in-network-aggregation baseline: the
                        scarce inter-pod links carry full gradient bytes.
  * ``tree``          — SwitchAgg schedule: reduce-scatter over the cheap
                        intra-pod axis first, all-reduce only the 1/fanin
                        shard over the scarce pod axis, all-gather back.
                        Inter-pod traffic drops by the intra-pod fanin —
                        in-network aggregation realized as a collective
                        schedule (DESIGN.md §2 insight (a)).
  * ``tree_compress`` — additionally top-k compress the shard before it
                        crosses the pod axis; the KV streams are combined by
                        the bounded-memory FPE/BPE aggregator (insight (b)).

All functions here are *manual-collective* code meant to run inside
``jax.shard_map`` over the reduction axes (model axis stays auto/SPMD).
Use :func:`make_grad_exchange` to get a jit-ready pytree-level exchanger.
"""

from __future__ import annotations

import enum
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import aggops
from . import compressor as comp
from . import dataplane
from . import kvagg


def axis_size_compat(axis_name: str) -> int:
    """Static size of a bound mesh axis across jax versions.

    ``jax.lax.axis_size`` is recent; older releases expose the bound axis
    environment through ``jax.core.axis_frame``.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax import core as _core

    # axis_frame returns the size directly on some releases, a frame with
    # a .size attribute on others
    frame = _core.axis_frame(axis_name)
    return int(getattr(frame, "size", frame))


def shard_map_compat(fn, *, mesh, in_specs, out_specs, axis_names=None,
                     check_vma=False):
    """`jax.shard_map` across jax versions.

    Newer jax exposes ``jax.shard_map`` with ``axis_names``/``check_vma``;
    older releases only have ``jax.experimental.shard_map.shard_map`` where
    the manual/auto split is expressed through the ``auto`` frozenset and
    replication checking through ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(fn, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)


class GradAggMode(str, enum.Enum):
    GATHER = "gather"  # parameter-server: raw flows to the reducer (paper's no-agg baseline)
    FLAT = "flat"  # one flat all-reduce over every chip (single-switch / DAIET-like)
    TREE = "tree"  # SwitchAgg: hierarchical on-path reduction
    TREE_COMPRESS = "tree_compress"  # + bounded-memory KV compression on the scarce link


# ---------------------------------------------------------------------------
# Single-array exchanges (inside shard_map; axes are bound axis names).
# ---------------------------------------------------------------------------


def flat_allreduce(x: jnp.ndarray, axes: tuple[str, ...]) -> jnp.ndarray:
    """Baseline: one flat psum over all reduction axes."""
    return jax.lax.psum(x, axes)


def tree_allreduce(x: jnp.ndarray, leaf_axis: str, upper_axes: tuple[str, ...]) -> jnp.ndarray:
    """SwitchAgg tree schedule on a 1-D-reshapeable array.

    reduce-scatter(leaf) -> psum(upper, on the shard) -> all-gather(leaf).
    Equivalent to flat psum (tested) but the upper (scarce) axes carry only
    ``1/fanin(leaf)`` of the bytes.
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    fanin = axis_size_compat(leaf_axis)
    pad = (-n) % fanin
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    shard = jax.lax.psum_scatter(flat, leaf_axis, scatter_dimension=0, tiled=True)
    if upper_axes:
        shard = jax.lax.psum(shard, upper_axes)
    full = jax.lax.all_gather(shard, leaf_axis, axis=0, tiled=True)
    if pad:
        full = full[:n]
    return full.reshape(x.shape)


class CompressedExchangeState(NamedTuple):
    residual: jnp.ndarray  # error-feedback memory for the local shard [flat]


def tree_compress_allreduce(
    x: jnp.ndarray,
    residual: jnp.ndarray,
    leaf_axis: str,
    upper_axes: tuple[str, ...],
    *,
    k: int,
    fpe_capacity: int = 0,
    cascade: dataplane.CascadePlan | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Compressed SwitchAgg exchange for one flat-reshapeable array.

    1. exact reduce-scatter over the cheap leaf axis (intra-pod);
    2. top-k compress the local shard (+ error feedback residual);
    3. the KV stream crosses the scarce upper axes as a multi-level
       CASCADE (``core.dataplane``): hop *i* all-gathers over upper axis
       *i* and pushes the merged stream through that level's bounded-memory
       FPE/BPE node, whose eviction-plus-flush stream feeds hop *i+1*;
    4. decompress to the dense shard; all-gather over the leaf axis.

    ``cascade`` carries the planner's per-level node specs (capacity per
    hop — DESIGN.md §6); when None, every hop gets ``fpe_capacity`` (0 =
    the exact unbounded node).  Gradient exchange is a SUM dataplane:
    non-sum cascades are rejected because decompression scatter-adds.

    Returns (result, new_residual).  Result is *approximate* (top-k), with
    error feedback making the bias vanish across steps.
    """
    if cascade is None:
        cascade = dataplane.CascadePlan(
            op="sum",
            levels=dataplane.uniform_levels(fpe_capacity,
                                            len(upper_axes)))
    if cascade.op != "sum":
        raise ValueError(f"gradient exchange needs a sum cascade, got {cascade.op!r}")
    if upper_axes and len(cascade.levels) != len(upper_axes):
        raise ValueError(
            f"cascade has {len(cascade.levels)} level(s) for "
            f"{len(upper_axes)} upper axis hop(s)")
    flat = x.reshape(-1)
    n = flat.shape[0]
    fanin = axis_size_compat(leaf_axis)
    pad = (-n) % fanin
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    shard = jax.lax.psum_scatter(flat, leaf_axis, scatter_dimension=0, tiled=True)
    shard_n = shard.shape[0]

    acc = shard + residual
    kk = min(k, shard_n)
    _, idx = jax.lax.top_k(jnp.abs(acc), kk)
    vals = acc[idx]
    new_residual = acc.at[idx].set(0.0)

    if upper_axes:
        keys = idx.astype(jnp.int32)
        # The scarce links carry only the KV stream, one cascade level per hop.
        for ax, spec in zip(upper_axes, cascade.levels):
            gk = jax.lax.all_gather(keys, ax, axis=0, tiled=True)
            gv = jax.lax.all_gather(vals, ax, axis=0, tiled=True)
            keys, vals, _ = dataplane.run_level(gk, gv, spec, cascade.op)
        dense = comp.decompress_sum(keys, vals, size=shard_n)
    else:
        dense = comp.decompress_sum(idx.astype(jnp.int32), vals, size=shard_n)

    full = jax.lax.all_gather(dense, leaf_axis, axis=0, tiled=True)
    if pad:
        full = full[:n]
    return full.reshape(x.shape), new_residual


# ---------------------------------------------------------------------------
# Pytree-level exchange builders (shard_map wrappers).
# ---------------------------------------------------------------------------


def exchange_in_shardmap(
    grads,
    mode: GradAggMode,
    leaf_axis: str,
    upper_axes: tuple[str, ...],
    *,
    k_fraction: float = 0.01,
    fpe_capacity: int = 0,
    residuals=None,
    cascade: dataplane.CascadePlan | None = None,
):
    """Apply the chosen exchange to every leaf of a gradient pytree.

    Must be called from inside a shard_map whose manual axes include
    ``leaf_axis`` and ``upper_axes``.  Returns (new_grads, new_residuals).
    """
    all_axes = (leaf_axis, *upper_axes)
    if mode == GradAggMode.FLAT:
        return jax.tree.map(lambda g: flat_allreduce(g, all_axes), grads), residuals
    if mode == GradAggMode.TREE:
        return (
            jax.tree.map(lambda g: tree_allreduce(g, leaf_axis, upper_axes), grads),
            residuals,
        )
    if mode == GradAggMode.TREE_COMPRESS:
        if residuals is None:
            raise ValueError("TREE_COMPRESS needs residual state")
        outs = []
        leaves, treedef = jax.tree.flatten(grads)
        res_leaves = treedef.flatten_up_to(residuals)
        new_res = []
        for g, r in zip(leaves, res_leaves):
            k = max(1, int(g.size / axis_size_compat(leaf_axis) * k_fraction))
            o, nr = tree_compress_allreduce(
                g, r, leaf_axis, upper_axes, k=k, fpe_capacity=fpe_capacity,
                cascade=cascade,
            )
            outs.append(o)
            new_res.append(nr)
        return treedef.unflatten(outs), treedef.unflatten(new_res)
    raise ValueError(mode)


def init_residuals(grads_shape_tree, leaf_axis_size: int, world_size: int = 1):
    """Residual (error-feedback) state per gradient leaf.

    Each device holds the residual of its scattered shard:
    ``shard_n = ceil(param_size / leaf_fanin)``.  The *global* array is
    ``world_size * shard_n`` long and enters the shard_map with spec
    ``P((pod, data))`` so every device sees exactly its own shard's state.
    """

    def one(leaf):
        import numpy as np

        n = int(np.prod(leaf.shape)) if hasattr(leaf, "shape") else int(leaf)
        padded = n + ((-n) % leaf_axis_size)
        return jnp.zeros((world_size * (padded // leaf_axis_size),), jnp.float32)

    return jax.tree.map(one, grads_shape_tree)


def cascade_for_plan(plan) -> dataplane.CascadePlan | None:
    """The one plan->cascade policy for the compressed gradient exchange:
    TREE_COMPRESS plans with upper hops run the per-hop cascade (budget
    split per level), everything else runs cascade-free.  plan.op flows
    through so a non-sum plan trips the sum-only guard in
    :func:`tree_compress_allreduce` instead of silently running as SUM.
    Used by both :func:`exchange_from_plan` and
    ``train.compressed.build_compressed_train_step``.
    """
    if plan.mode == GradAggMode.TREE_COMPRESS and plan.upper_axes:
        return dataplane.cascade_from_exchange_plan(plan)
    return None


def exchange_from_plan(grads, plan, *, residuals=None):
    """Run the exchange a planner ``ExchangePlan`` describes.

    Mode, level ordering, top-k fraction, and FPE capacity all come from the
    plan (the controller's decision for this job under current tenancy) —
    callers stop hardcoding them.  The compressed mode executes the plan as
    a multi-level CASCADE: the plan's combiner budget is partitioned across
    its upper-axis hops (``dataplane.cascade_from_exchange_plan``) so every
    hop runs a bounded node at its own memory slice — DESIGN.md §6.
    Must be called inside a shard_map whose manual axes include the plan's
    axes.  ``plan`` is duck-typed to avoid a circular import with
    ``planner``.
    """
    cascade = cascade_for_plan(plan)
    return exchange_in_shardmap(
        grads, plan.mode, plan.leaf_axis, tuple(plan.upper_axes),
        k_fraction=plan.k_fraction, fpe_capacity=plan.fpe_capacity,
        residuals=residuals, cascade=cascade,
    )


# ---------------------------------------------------------------------------
# KV-stream tree aggregation — the word-count / MapReduce dataplane.
# ---------------------------------------------------------------------------


class KVTreeResult(NamedTuple):
    keys: jnp.ndarray
    values: jnp.ndarray
    level_in: jnp.ndarray  # [n_levels] pairs entering each level's node
    level_out: jnp.ndarray  # [n_levels] pairs leaving each level's node
    level_evict: jnp.ndarray  # [n_levels] FPE evictions at each level's node


def kv_tree_aggregate(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    level_axes: tuple[str, ...],
    *,
    fpe_capacity: int,
    ways: int = 4,
    bpe: bool = True,
    op: str = "sum",
    plan: dataplane.CascadePlan | None = None,
) -> KVTreeResult:
    """Aggregate per-worker KV streams up an aggregation tree.

    At each level the streams of that level's group are merged (Theorem 2.1:
    all-gather over the level axis == the node receiving all child flows) and
    pushed through that level's bounded-memory SwitchAgg node
    (``dataplane.run_level``).  Output stream feeds the next level.
    Per-level in/out/eviction counts give the measured reduction ratio of
    every hop (paper Fig. 2b / Fig. 9).

    ``op`` is any registered AggOp (DESIGN.md §6): carried values enter the
    tree via ``prepare`` and the root stream is ``finalize``d, so e.g.
    ``mean`` stays exact across levels.  ``plan`` overrides the uniform
    (fpe_capacity, ways, bpe) node geometry with the controller's per-level
    memory partition.

    Runs inside shard_map over ``level_axes``.
    """
    if plan is None:
        plan = dataplane.CascadePlan(
            op=op,
            levels=dataplane.uniform_levels(fpe_capacity, len(level_axes),
                                            ways=ways, bpe=bpe))
    elif op not in ("sum", plan.op):
        # plan.op drives the cascade; a conflicting explicit op is a caller
        # bug ("sum" is indistinguishable from the default and defers)
        raise ValueError(f"op={op!r} conflicts with plan.op={plan.op!r}")
    if len(plan.levels) != len(level_axes):
        raise ValueError(f"plan has {len(plan.levels)} level(s) for "
                         f"{len(level_axes)} tree axes")
    aggop = aggops.get(plan.op)
    lvl_in, lvl_out, lvl_ev = [], [], []
    k, v = keys, aggop.prepare_values(values)
    for ax, spec in zip(level_axes, plan.levels):
        gk = jax.lax.all_gather(k, ax, axis=0, tiled=True)
        gv = jax.lax.all_gather(v, ax, axis=0, tiled=True)
        # Compact the stream: keep a fixed-size output per level to bound
        # downstream shapes (real switches flush variable traffic; fixed
        # shapes are the TPU adaptation — sized at capacity + input).
        k, v, stats = dataplane.run_level(gk, gv, spec, plan.op)
        lvl_in.append(stats.n_in)
        lvl_out.append(stats.n_out)
        lvl_ev.append(stats.n_evict)
    # Root packing: the last node's stream may hold duplicate keys (table +
    # BPE overlap — see kvagg.TwoLevelResult); combine exactly on carried
    # values BEFORE finalize (a finalized mean cannot be re-combined).
    packed = kvagg.sorted_combine(k, v, op=plan.op)
    return KVTreeResult(packed.unique_keys,
                        aggop.finalize_values(packed.combined_values),
                        jnp.stack(lvl_in), jnp.stack(lvl_out),
                        jnp.stack(lvl_ev))


def make_kv_tree_aggregator(
    mesh,
    level_axes: tuple[str, ...],
    *,
    fpe_capacity: int,
    ways: int = 4,
    bpe: bool = True,
    op: str = "sum",
    plan: dataplane.CascadePlan | None = None,
) -> Callable:
    """jit-ready word-count aggregator: per-worker streams in, root stream out."""

    fn = functools.partial(
        kv_tree_aggregate,
        level_axes=level_axes,
        fpe_capacity=fpe_capacity,
        ways=ways,
        bpe=bpe,
        op=op,
        plan=plan,
    )
    spec = P(level_axes)
    mapped = shard_map_compat(
        fn,
        mesh=mesh,
        in_specs=(spec, spec),
        out_specs=KVTreeResult(P(), P(), P(), P(), P()),
        axis_names=set(level_axes),
        check_vma=False,
    )
    return jax.jit(mapped)
