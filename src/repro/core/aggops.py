"""AggOp registry — the single source of truth for aggregation-operator
semantics (DESIGN.md §6).

The paper's processing engine is parameterized by its reduction function
(§2: SUM/MAX/MIN word-count-style combines); Flare-style flexible reduction
support means the op set must be pluggable, not string-dispatched in every
execution layer.  Every aggregation path in this repo — the pure-jnp FPE
scan and BPE sorted combine (``core.kvagg``), the Pallas FPE kernel
(``kernels.kv_aggregate``), and the plan-driven cascade executor
(``core.dataplane``) — resolves its op HERE, statically at trace time, so
kernels stay specialized while op semantics live in exactly one place.

An :class:`AggOp` carries:

  * ``combine(a, b)``     — the elementwise merge applied when two values of
                            the same key meet (per carried lane).
  * ``identity(dtype)``   — the dtype-aware neutral element.  max/min use
                            ``jnp.finfo``/``jnp.iinfo`` bounds, NOT ±inf,
                            which does not exist for integer value dtypes.
  * ``lanes``             — carried value lanes.  ``mean`` carries paired
                            (sum, count) lanes: the paper's word-count
                            semantics generalized, combined lane-wise by a
                            plain add and divided only at ``finalize``.
  * ``prepare(values)``   — user values -> carried representation (e.g.
                            ``count`` maps every record to 1, ``mean``
                            stacks (value, 1) lanes).
  * ``finalize(values)``  — carried representation -> user-visible result
                            at the root of the cascade.
  * ``segment_reduce``    — the bulk (BPE / sorted-combine) form of
                            ``combine`` over sorted segments.

Associativity + commutativity of ``combine`` is the contract every
registered op must honor — it is what makes multi-level cascades exact
(Theorem 2.1) — and is what the property tests in
``tests/test_dataplane.py`` check for every registered op.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shared key hash.
# ---------------------------------------------------------------------------

#: Knuth/Fibonacci multiplicative hash constant — THE one copy shared by the
#: jnp FPE (``core.kvagg``) and the Pallas kernel (``kernels.kv_aggregate``),
#: so the two bucket functions cannot drift apart.
HASH_MULT = 0x9E3779B1


def hash_key(key: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    """Multiplicative hash of int32 keys into [0, n_buckets).

    Pure jnp, traceable both in regular jax programs and inside Pallas
    kernel bodies (``n_buckets`` is a trace-time python int in both).
    """
    h = key.astype(jnp.uint32) * jnp.uint32(HASH_MULT)
    h = h ^ (h >> jnp.uint32(15))
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


def _bound_identity(dtype, kind: str) -> jnp.ndarray:
    """Dtype-aware max/min identity: finfo/iinfo bounds, never ±inf.

    ``-inf`` cast to an integer dtype is undefined (and wrong even where it
    "works": it wraps to implementation-defined garbage), so int32 MAX
    aggregation with a ±inf identity silently corrupts padding slots.
    """
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        info = jnp.finfo(dtype)
    elif jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
    else:
        raise TypeError(f"unsupported value dtype for max/min: {dtype}")
    return jnp.array(info.min if kind == "max" else info.max, dtype)


def _as_float(values: jnp.ndarray) -> jnp.ndarray:
    """Carried dtype for ops whose algebra needs a field (mean, logsumexp)."""
    if jnp.issubdtype(values.dtype, jnp.floating):
        return values
    return values.astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class AggOp:
    """One registered aggregation operator; see the module docstring."""

    name: str
    combine: Callable  # (a, b) -> merged, elementwise per carried lane
    identity: Callable  # dtype -> scalar neutral element (carried dtype)
    segment_reduce: Callable  # (values, segment_ids, num_segments) -> [S,...]
    lanes: int = 1
    prepare: Callable | None = None  # user values -> carried values
    finalize: Callable | None = None  # carried values -> user values

    def prepare_values(self, values: jnp.ndarray) -> jnp.ndarray:
        """Map raw values [n] to the carried representation.

        lanes == 1 ops carry [n]; lanes > 1 ops carry [n, lanes] — the
        declared ``lanes`` is validated against what ``prepare`` produced,
        so a registration whose metadata and prepare disagree fails loudly.
        """
        out = values if self.prepare is None else self.prepare(values)
        want = values.shape[:1] + ((self.lanes,) if self.lanes > 1 else ())
        if out.shape != want:
            raise ValueError(
                f"op {self.name!r} declares lanes={self.lanes} but prepare "
                f"produced shape {out.shape} (expected {want})")
        return out

    def finalize_values(self, values: jnp.ndarray) -> jnp.ndarray:
        """Collapse the carried representation back to user values."""
        return values if self.finalize is None else self.finalize(values)


_REGISTRY: dict[str, AggOp] = {}


def register(op: AggOp) -> AggOp:
    """Add an op to the registry (last registration wins, enabling tests to
    shadow an op); returns it so definitions read as assignments."""
    _REGISTRY[op.name] = op
    return op


def get(name: str) -> AggOp:
    """Resolve an op by name; raises ValueError listing what IS registered."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unsupported aggregation op: {name!r} (registered: {names()})"
        ) from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Registered ops.
# ---------------------------------------------------------------------------


def _segment_logsumexp(values, segment_ids, num_segments):
    """Numerically stable segmented logsumexp (two-pass max-shift)."""
    m = jax.ops.segment_max(values, segment_ids, num_segments=num_segments)
    m_safe = jnp.where(jnp.isfinite(m), m, jnp.zeros_like(m))
    s = jax.ops.segment_sum(
        jnp.exp(values - m_safe[segment_ids]), segment_ids,
        num_segments=num_segments)
    out = m_safe + jnp.log(s)
    neg_inf = jnp.array(-jnp.inf, values.dtype)
    return jnp.where(s > 0, out, neg_inf)


def _mean_prepare(values: jnp.ndarray) -> jnp.ndarray:
    v = _as_float(values)
    return jnp.stack([v, jnp.ones_like(v)], axis=-1)


def _mean_finalize(carried: jnp.ndarray) -> jnp.ndarray:
    total, count = carried[..., 0], carried[..., 1]
    safe = jnp.where(count != 0, count, jnp.ones_like(count))
    return jnp.where(count != 0, total / safe, jnp.zeros_like(total))


SUM = register(AggOp(
    name="sum",
    combine=lambda a, b: a + b,
    identity=lambda dtype: jnp.zeros((), dtype),
    segment_reduce=jax.ops.segment_sum,
))

MAX = register(AggOp(
    name="max",
    combine=jnp.maximum,
    identity=lambda dtype: _bound_identity(dtype, "max"),
    segment_reduce=jax.ops.segment_max,
))

MIN = register(AggOp(
    name="min",
    combine=jnp.minimum,
    identity=lambda dtype: _bound_identity(dtype, "min"),
    segment_reduce=jax.ops.segment_min,
))

COUNT = register(AggOp(
    name="count",
    combine=lambda a, b: a + b,
    identity=lambda dtype: jnp.zeros((), dtype),
    segment_reduce=jax.ops.segment_sum,
    # every record carries weight 1; the values' own payload is irrelevant
    prepare=lambda values: jnp.ones(values.shape[:1], jnp.int32),
))

MEAN = register(AggOp(
    name="mean",
    combine=lambda a, b: a + b,  # (sum, count) lanes both merge by add
    identity=lambda dtype: jnp.zeros((), dtype),
    segment_reduce=jax.ops.segment_sum,
    lanes=2,
    prepare=_mean_prepare,
    finalize=_mean_finalize,
))

LOGSUMEXP = register(AggOp(
    name="logsumexp",
    combine=jnp.logaddexp,
    # -inf IS the logaddexp identity and exists for every float dtype;
    # integer inputs are lifted to f32 by prepare, so no iinfo case arises
    identity=lambda dtype: jnp.array(-jnp.inf, jnp.dtype(dtype)),
    segment_reduce=_segment_logsumexp,
    prepare=_as_float,
))
