"""Online multi-tenant admission controller for the fat-tree fabric
(DESIGN.md §13).

The :class:`~repro.core.planner.JobScheduler` plans a *static* batch: it
re-scores the world on every admit.  A datacenter fabric sees Poisson
arrivals and departures from many tenants, and re-planning every active
job per event costs ``O(n_active)`` placement searches each time.  The
:class:`OnlineController` admits each arrival *incrementally*:

* **residual-capacity placement** — one ``place_aggregation_tree``
  search per arrival, on a copy of the fat-tree whose per-tier
  ``table_pairs`` are capped at what the active jobs left over (the
  SOAR bounded-capability model applied to the *residual*, not the
  whole switch);
* **weighted max-min fairness** — tenants share the scarce uplink;
  :meth:`fair_shares` water-fills the scarce-byte budget across tenants
  by weight, and tenants above their share are first in line when
  capacity must be reclaimed;
* **value-based preemption** — when a higher-value job arrives and a
  placeable tier has no residual table at all, the lowest-value jobs
  below the arrival's value are evicted from that tier.  An evicted
  job *degrades, never dies*: its placement is repaired around the lost
  tier with the same ``repair_placement`` machinery the failure plane
  uses (DESIGN.md §12), and :meth:`eviction_failure_events` renders the
  eviction as a switch-crash schedule so an in-flight job rides the
  epoch-restart driver and stays exactly-once;
* **re-expansion** — a departure frees capacity; degraded jobs (highest
  value first) re-run their restricted search and take the better
  placement when the model says it is strictly better.

Every event publishes ``controller.*`` metrics through the unified
schema (``net.schema.publish_controller_report``) and a wall span per
admit/release, so the churn dashboard section renders straight from the
registry.

``plan()`` — also in this module — is the single planning front door
(DESIGN.md §13): one call that routes to ``plan_grad_exchange``,
``plan_fat_tree_job``, ``JobScheduler``, or :class:`OnlineController`
based on the input shape, so this controller lands behind a stable
public API instead of an eighth ad-hoc entry point.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional, Sequence

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from .planner import (
    FAT_TREE_TIERS,
    FatTreeTopology,
    JobScheduler,
    LaunchRequest,
    Topology,
    TreePlacement,
    _AXIS_TIER,
    place_aggregation_tree,
    plan_fat_tree_job,
    plan_grad_exchange,
    repair_placement,
)

__all__ = [
    "OnlineJobRequest",
    "Admission",
    "Eviction",
    "Expansion",
    "ControllerReport",
    "OnlineController",
    "weighted_max_min",
    "plan",
]


@dataclasses.dataclass(frozen=True)
class OnlineJobRequest:
    """One arrival in the churn stream."""

    job_id: int
    expected_pairs: int  # per-host mapper output (pairs)
    key_variety: int  # N — also the useful per-switch table bound
    tenant: str = "default"
    value: float = 1.0  # preemption priority: higher value evicts lower
    op: str = "sum"

    def __post_init__(self):
        if self.expected_pairs < 1 or self.key_variety < 1:
            raise ValueError("expected_pairs and key_variety must be >= 1")
        if self.value < 0:
            raise ValueError("value must be >= 0")


@dataclasses.dataclass(frozen=True)
class Eviction:
    """One value-based table eviction: ``job_id`` lost ``tier`` to
    ``by_job``; its placement degraded from ``before`` to ``after``."""

    job_id: int
    by_job: int
    tenant: str
    tier: str
    freed_pairs: int  # per-switch table pairs reclaimed
    before: TreePlacement
    after: TreePlacement


@dataclasses.dataclass(frozen=True)
class Expansion:
    """A departure freed capacity and ``job_id`` re-expanded."""

    job_id: int
    tenant: str
    before: TreePlacement
    after: TreePlacement

    @property
    def scarce_bytes_saved(self) -> float:
        return self.before.scarce_uplink_bytes - self.after.scarce_uplink_bytes


@dataclasses.dataclass(frozen=True)
class Admission:
    """What one arrival got: its placement, table grants, and the
    preemptions it triggered."""

    request: OnlineJobRequest
    placement: TreePlacement
    grants: tuple[tuple[str, int], ...]  # (tier, per-switch pairs) reserved
    caps: tuple[tuple[str, int], ...]  # capability map the search ran under
    degraded: bool  # got less capability than an empty fabric would give
    preempted: tuple[int, ...]  # job ids evicted to make room
    candidates_scored: int  # placement work this admission cost

    @property
    def job_id(self) -> int:
        return self.request.job_id


@dataclasses.dataclass(frozen=True)
class ControllerReport:
    """Snapshot over the active set (the churn bench / dashboard view)."""

    n_active: int
    n_degraded: int
    admitted_total: int
    evictions_total: int
    expansions_total: int
    candidates_scored_total: int
    scarce_axis: str
    total_scarce_bytes: float
    scarce_budget_bytes: float | None
    tenants: dict[str, dict]  # tenant -> {n_jobs, weight, demand, share}

    @property
    def scarce_utilization(self) -> float:
        if not self.scarce_budget_bytes:
            return 0.0
        return self.total_scarce_bytes / self.scarce_budget_bytes

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["scarce_utilization"] = self.scarce_utilization
        return d

    def summary(self) -> str:
        return (f"{self.n_active} active ({self.n_degraded} degraded), "
                f"{self.admitted_total} admitted / "
                f"{self.evictions_total} evicted / "
                f"{self.expansions_total} re-expanded; "
                f"scarce {self.scarce_axis}="
                f"{self.total_scarce_bytes/2**20:.2f}MiB "
                f"({self.candidates_scored_total} placements scored)")


def weighted_max_min(demands: dict[str, float], weights: dict[str, float],
                     capacity: float) -> dict[str, float]:
    """Weighted max-min (water-filling) allocation of ``capacity`` across
    tenants.  A tenant whose demand fits under its weighted fair share
    keeps its demand; the slack is re-filled over the rest by weight,
    until everyone is either satisfied or saturated at their share."""
    alloc: dict[str, float] = {t: 0.0 for t in demands}
    active = {t: d for t, d in demands.items() if d > 0}
    remaining = float(capacity)
    while active and remaining > 0:
        wsum = sum(weights.get(t, 1.0) for t in active)
        fitting = {t: d for t, d in active.items()
                   if d <= remaining * weights.get(t, 1.0) / wsum}
        if not fitting:  # everyone saturates at the weighted share
            for t in active:
                alloc[t] = remaining * weights.get(t, 1.0) / wsum
            return alloc
        for t, d in fitting.items():
            alloc[t] = d
            remaining -= d
            del active[t]
    return alloc


@dataclasses.dataclass
class _Active:
    """Mutable per-job controller state."""

    request: OnlineJobRequest
    placement: TreePlacement
    grants: dict[str, int]  # tier -> per-switch pairs reserved
    caps: dict[str, int]  # capability map the current placement ran under
    degraded: bool
    evicted_tiers: tuple[str, ...] = ()


class OnlineController:
    """Incremental multi-tenant admission onto one fat-tree (§13).

    Unlike :class:`~repro.core.planner.JobScheduler` (a static batch
    planner), this controller never re-plans the world: each arrival
    costs one placement search on the residual capability, each
    departure at most one repair search per degraded job.  The churn
    bench (``benchmarks/bench_churn.py``) holds it to within 10% of the
    full-replan oracle's scarce-link bytes at >= 10x less placement
    work.
    """

    def __init__(
        self,
        ft: FatTreeTopology,
        *,
        policy: str = "auto",
        tenant_weights: dict[str, float] | None = None,
        preemption: bool = True,
        scarce_budget_bytes: float | None = None,
        drain_calibration: dict[str, float] | None = None,
    ):
        self.ft = ft
        self.policy = policy
        self.tenant_weights = dict(tenant_weights or {})
        self.preemption = preemption
        self.scarce_budget_bytes = scarce_budget_bytes
        self.drain_calibration = dict(drain_calibration or {})
        self.jobs: dict[int, _Active] = {}
        self.evictions: list[Eviction] = []
        self.expansions: list[Expansion] = []
        self.admitted_total = 0
        self.candidates_scored_total = 0

    # -- capability accounting ----------------------------------------------

    def placeable_tiers(self) -> tuple[str, ...]:
        return tuple(t for t in self.ft.present_tiers()
                     if self.ft.switch_table(t) > 0)

    def used_pairs(self, tier: str) -> int:
        return sum(a.grants.get(tier, 0) for a in self.jobs.values())

    def residual_pairs(self, tier: str) -> int:
        return max(0, self.ft.switch_table(tier) - self.used_pairs(tier))

    def _full_want(self, req: OnlineJobRequest) -> dict[str, int]:
        """Per-tier table an empty fabric would grant: capability capped
        at the key variety (more table than keys is dead reservation)."""
        return {t: min(req.key_variety, self.ft.switch_table(t))
                for t in self.placeable_tiers()}

    def _restricted(self, caps: dict[str, int]) -> FatTreeTopology:
        """The fat-tree as one job sees it: per-tier capability clamped
        to its grant — the same ``tier_table_pairs`` override the repair
        path uses (DESIGN.md §12)."""
        return dataclasses.replace(
            self.ft, table_pairs=0, tier_table_pairs=tuple(
                (t, int(caps.get(t, 0))) for t in FAT_TREE_TIERS))

    def _tier_level(self, tier: str) -> int:
        for i, l in enumerate(self.ft.link_tiers()):
            if _AXIS_TIER.get(l.axis, l.axis) == tier:
                return i
        raise KeyError(tier)

    def _scored(self) -> float:
        reg = obs_metrics.get_registry()
        return sum(v for _, v in reg.find(
            "planner.placement.candidates_scored_total"))

    def _place(self, req: OnlineJobRequest,
               caps: dict[str, int]) -> TreePlacement:
        return place_aggregation_tree(
            self._restricted(caps), per_host_pairs=req.expected_pairs,
            key_variety=req.key_variety, policy=self.policy,
            drain_calibration=self.drain_calibration or None)

    # -- admission ----------------------------------------------------------

    def admit(self, req: OnlineJobRequest) -> Admission:
        """Admit one arrival on the residual capability, preempting
        lower-value jobs when a placeable tier is exhausted."""
        if req.job_id in self.jobs:
            raise ValueError(f"job {req.job_id} already active")
        t0_wall = time.perf_counter()
        scored0 = self._scored()
        want = self._full_want(req)
        avail = {t: min(want[t], self.residual_pairs(t)) for t in want}
        preempted: list[int] = []
        if self.preemption:
            for tier in want:
                if avail[tier] > 0:
                    continue  # some table available: degrade, don't evict
                freed, victims = self._preempt_tier(tier, req)
                if freed:
                    avail[tier] = min(want[tier], freed)
                    preempted.extend(v for v in victims
                                     if v not in preempted)
        placement = self._place(req, avail)
        grants = {t: avail[t] for t in placement.tiers}
        degraded = any(avail[t] < want[t] for t in want)
        self.jobs[req.job_id] = _Active(
            request=req, placement=placement, grants=grants,
            caps=dict(avail), degraded=degraded)
        self.admitted_total += 1
        scored = self._scored() - scored0
        self.candidates_scored_total += scored
        reg = obs_metrics.get_registry()
        reg.counter("controller.admitted_total", tenant=req.tenant).inc()
        reg.counter("controller.candidates_scored_total").inc(scored)
        if degraded:
            reg.counter("controller.degraded_admissions_total",
                        tenant=req.tenant).inc()
        self._publish()
        obs_trace.get_tracer().add_wall_span(
            f"controller.admit[{req.job_id}]", t0_wall, time.perf_counter(),
            cat="controller",
            args={"job": req.job_id, "tenant": req.tenant,
                  "value": req.value, "tiers": list(placement.tiers),
                  "degraded": degraded, "preempted": preempted})
        return Admission(
            request=req, placement=placement,
            grants=tuple(sorted(grants.items())),
            caps=tuple(sorted(avail.items())), degraded=degraded,
            preempted=tuple(preempted), candidates_scored=int(scored))

    def _preempt_tier(self, tier: str,
                      req: OnlineJobRequest) -> tuple[int, list[int]]:
        """Evict ``tier`` table from lower-value jobs until the arrival
        has a grant (or no victims remain).  Victims go lowest value
        first; within a value, tenants above their fair share first.
        Returns (per-switch pairs reclaimed, victim job ids)."""
        shares = self.fair_shares()
        demands = self._tenant_demands()
        over = {t for t, d in demands.items() if d > shares.get(t, 0.0)}
        victims = sorted(
            (a for a in self.jobs.values()
             if a.grants.get(tier, 0) > 0 and a.request.value < req.value),
            key=lambda a: (a.request.value,
                           0 if a.request.tenant in over else 1,
                           a.request.job_id))
        evicted: list[int] = []
        freed = 0
        for victim in victims:
            freed += self._evict(victim, tier, by=req)
            evicted.append(victim.request.job_id)
            if freed >= min(req.key_variety, self.ft.switch_table(tier)):
                break
        return freed, evicted

    def _evict(self, victim: _Active, tier: str,
               by: OnlineJobRequest) -> int:
        """Take ``tier``'s table from one job and degrade its placement
        via the failure plane's ``repair_placement`` — the evicted tier
        is every-switch-dead, so the repair drops it wholesale and
        re-places over the job's remaining grants."""
        freed = victim.grants.pop(tier)
        victim.caps[tier] = 0
        links = self.ft.link_tiers()
        fanins = [l.fanin for l in links]
        lvl = self._tier_level(tier)
        failed = [(lvl, s) for s in range(math.prod(fanins[lvl + 1:]))]
        before = victim.placement
        rep = repair_placement(
            self._restricted(victim.caps), before, failed=failed,
            per_host_pairs=victim.request.expected_pairs,
            key_variety=victim.request.key_variety,
            drain_calibration=self.drain_calibration or None)
        victim.placement = rep.placement
        victim.grants = {t: victim.caps.get(t, 0)
                         for t in rep.placement.tiers}
        victim.degraded = True
        victim.evicted_tiers = tuple(
            dict.fromkeys((*victim.evicted_tiers, tier)))
        ev = Eviction(
            job_id=victim.request.job_id, by_job=by.job_id,
            tenant=victim.request.tenant, tier=tier, freed_pairs=freed,
            before=before, after=rep.placement)
        self.evictions.append(ev)
        reg = obs_metrics.get_registry()
        reg.counter("controller.evictions_total",
                    tenant=victim.request.tenant, tier=tier).inc()
        reg.counter("controller.evicted_pairs_total", tier=tier).inc(freed)
        return freed

    def eviction_failure_events(self, eviction: Eviction, *,
                                t_s: float) -> tuple:
        """One eviction as a data-plane failure schedule: every switch of
        the evicted tier crashes (for the victim's tree) at ``t_s``.  An
        in-flight victim runs the schedule through the epoch-restart
        driver (``repro.net.simulate(spec, faults=...)``), which is what
        keeps its delivered table exactly-once across the mid-run
        degrade (DESIGN.md §12)."""
        from repro.runtime.fault_tolerance import FailureEvent

        links = self.ft.link_tiers()
        fanins = [l.fanin for l in links]
        lvl = self._tier_level(eviction.tier)
        return tuple(
            FailureEvent(kind="switch_crash", t_s=float(t_s), level=lvl,
                         switch=s)
            for s in range(math.prod(fanins[lvl + 1:])))

    # -- departure + re-expansion -------------------------------------------

    def release(self, job_id: int) -> list[Expansion]:
        """Remove a job; re-expand degraded survivors (highest value
        first) into whatever capability the departure freed."""
        if job_id not in self.jobs:
            return []
        t0_wall = time.perf_counter()
        scored0 = self._scored()
        self.jobs.pop(job_id)
        expanded: list[Expansion] = []
        for a in sorted((a for a in self.jobs.values() if a.degraded),
                        key=lambda a: (-a.request.value, a.request.job_id)):
            want = self._full_want(a.request)
            avail = {
                t: min(want[t],
                       self.residual_pairs(t) + a.grants.get(t, 0))
                for t in want}
            if all(avail[t] <= a.caps.get(t, 0) for t in want):
                continue  # nothing new to take: skip the search
            trial = self._place(a.request, avail)
            if trial.scarce_uplink_bytes >= a.placement.scarce_uplink_bytes:
                # remember the tried capability; at full capability with no
                # win, the current placement is already the optimum
                a.caps = dict(avail)
                a.degraded = any(avail[t] < want[t] for t in want)
                continue
            exp = Expansion(job_id=a.request.job_id,
                            tenant=a.request.tenant,
                            before=a.placement, after=trial)
            a.placement = trial
            a.grants = {t: avail[t] for t in trial.tiers}
            a.caps = dict(avail)
            a.degraded = any(avail[t] < want[t] for t in want)
            a.evicted_tiers = tuple(t for t in a.evicted_tiers
                                    if t not in trial.tiers)
            expanded.append(exp)
            self.expansions.append(exp)
            obs_metrics.get_registry().counter(
                "controller.expansions_total", tenant=exp.tenant).inc()
        scored = self._scored() - scored0
        self.candidates_scored_total += scored
        obs_metrics.get_registry().counter(
            "controller.candidates_scored_total").inc(scored)
        self._publish()
        obs_trace.get_tracer().add_wall_span(
            f"controller.release[{job_id}]", t0_wall, time.perf_counter(),
            cat="controller",
            args={"job": job_id,
                  "expanded": [e.job_id for e in expanded]})
        return expanded

    # -- fairness -----------------------------------------------------------

    def _tenant_demands(self) -> dict[str, float]:
        demands: dict[str, float] = {}
        for a in self.jobs.values():
            demands[a.request.tenant] = (
                demands.get(a.request.tenant, 0.0)
                + a.placement.scarce_uplink_bytes)
        return demands

    def fair_shares(self) -> dict[str, float]:
        """Weighted max-min shares of the scarce uplink across tenants
        with active demand.  Capacity is ``scarce_budget_bytes`` when
        set, else total demand (everyone satisfied)."""
        demands = self._tenant_demands()
        cap = (self.scarce_budget_bytes
               if self.scarce_budget_bytes is not None
               else sum(demands.values()))
        return weighted_max_min(demands, self.tenant_weights, cap)

    # -- reporting ----------------------------------------------------------

    def total_scarce_bytes(self) -> float:
        return sum(a.placement.scarce_uplink_bytes
                   for a in self.jobs.values())

    def report(self) -> ControllerReport:
        demands = self._tenant_demands()
        shares = self.fair_shares()
        tenants = {
            t: {"n_jobs": sum(1 for a in self.jobs.values()
                              if a.request.tenant == t),
                "weight": self.tenant_weights.get(t, 1.0),
                "demand_bytes": d,
                "share_bytes": shares.get(t, 0.0)}
            for t, d in sorted(demands.items())}
        return ControllerReport(
            n_active=len(self.jobs),
            n_degraded=sum(1 for a in self.jobs.values() if a.degraded),
            admitted_total=self.admitted_total,
            evictions_total=len(self.evictions),
            expansions_total=len(self.expansions),
            candidates_scored_total=int(self.candidates_scored_total),
            scarce_axis=self.ft.scarce_uplink_axis(),
            total_scarce_bytes=self.total_scarce_bytes(),
            scarce_budget_bytes=self.scarce_budget_bytes,
            tenants=tenants)

    def _publish(self) -> None:
        from repro.net import schema as schema_lib

        schema_lib.publish_controller_report(self.report().to_dict())


# ---------------------------------------------------------------------------
# plan(): the single planning front door (DESIGN.md §13).
# ---------------------------------------------------------------------------


def _is_mesh(x) -> bool:
    return hasattr(x, "axis_names") and hasattr(x, "devices")


def _is_sequence(x) -> bool:
    return isinstance(x, Sequence) and not isinstance(x, (str, bytes))


def plan(job_or_jobs, topology, **kw):
    """Plan anything the control plane knows how to plan (DESIGN.md §13).

    Routing, by ``(job_or_jobs, topology)`` shape:

    =========================  ========================  ===================
    job_or_jobs                topology                  routed to
    =========================  ========================  ===================
    ``LaunchRequest``          jax ``Mesh``              ``plan_grad_exchange``
    ``LaunchRequest``          ``FatTreeTopology``       ``plan_fat_tree_job``
    ``LaunchRequest``          ``Topology``              ``JobScheduler.admit``
    ``[LaunchRequest, ...]``   ``Topology``              ``JobScheduler.plan_all``
    ``OnlineJobRequest``       ``FatTreeTopology``       a fresh ``OnlineController``
    ``[OnlineJobRequest,...]`` ``FatTreeTopology``       one controller, admitted in order
    any request                ``JobScheduler`` /        that instance's own
                               ``OnlineController``      ``admit`` (incremental)
    =========================  ========================  ===================

    Single-request forms return that request's plan/admission; a request
    list over a fresh ``Topology``/``FatTreeTopology`` returns the
    ``SchedulerReport`` / the :class:`OnlineController` holding the
    admitted set.  Extra keywords go to the matched constructor or call
    (``policy=``, ``combiner_budget_pairs=``, ``tenant_weights=``, ...).
    """
    x, topo = job_or_jobs, topology

    # live scheduler/controller instances: incremental admission
    if isinstance(topo, OnlineController):
        if _is_sequence(x):
            return [topo.admit(r) for r in x]
        return topo.admit(x)
    if isinstance(topo, JobScheduler):
        if _is_sequence(x):
            return topo.plan_all(list(x))
        return topo.admit(x)

    if _is_mesh(topo):
        if _is_sequence(x):
            raise TypeError("plan() over a mesh takes one LaunchRequest")
        return plan_grad_exchange(
            topo, mode=x.mode, grad_bytes=x.grad_bytes,
            key_variety=x.key_variety, k_fraction=x.k_fraction, op=x.op,
            **kw)

    if isinstance(topo, FatTreeTopology):
        if _is_sequence(x) or isinstance(x, OnlineJobRequest):
            reqs = list(x) if _is_sequence(x) else [x]
            if all(isinstance(r, OnlineJobRequest) for r in reqs):
                ctl = OnlineController(topo, **kw)
                admissions = [ctl.admit(r) for r in reqs]
                if not _is_sequence(x):
                    return admissions[0]
                return ctl
            raise TypeError("plan() over a FatTreeTopology takes "
                            "OnlineJobRequest(s) for online admission or "
                            "one LaunchRequest for a static placement")
        return plan_fat_tree_job(topo, x, **kw)

    if isinstance(topo, Topology):
        sched = JobScheduler(topo, **kw)
        if _is_sequence(x):
            return sched.plan_all(list(x))
        return sched.admit(x)

    raise TypeError(f"plan() cannot dispatch on topology "
                    f"{type(topology).__name__!r}; expected a Mesh, "
                    "Topology, FatTreeTopology, JobScheduler, or "
                    "OnlineController")
