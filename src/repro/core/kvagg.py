"""Two-level bounded-memory KV aggregation — the SwitchAgg FPE/BPE hierarchy.

Semantics (paper §4.2.4):

  * The **FPE** is a hash table of ``capacity`` slots held in fast memory
    (SRAM on the switch, VMEM in the Pallas kernel).  For each incoming
    (key, value) pair: hash the key, probe the bucket; on hit aggregate
    (SUM/MAX/MIN); on empty slot insert; on collision EVICT the resident
    pair downstream and insert the new pair.  The engine never stalls.
  * The **BPE** digests the eviction stream with a much larger (HBM/DRAM)
    table; we realize it as an exact sort-based combine, which on TPU is the
    natural "large slow memory" aggregation (sort + segment-sum is MXU/VPU
    friendly, and its latency is overlapped with the next FPE block exactly
    like the paper overlaps DRAM latency).

Invariant (checked by property tests): grouping the *outputs* (FPE flush +
BPE output) by key and combining gives exactly the input grouped-by-key
combine — aggregation never loses or double-counts data.

Op semantics (combine / identity / segment reduce) come from the
``core.aggops`` registry (DESIGN.md §6) — the one source of truth shared
with the Pallas kernels; this module never hardcodes an op.  Values may
carry trailing lane dimensions (e.g. ``mean``'s paired (sum, count) lanes):
eviction decisions are key-driven, so lanes ride along untouched.

This module is the pure-jnp implementation; ``repro.kernels.kv_aggregate``
is the Pallas/TPU version of the FPE loop with identical semantics.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import aggops

EMPTY_KEY = jnp.int32(-1)

_HASH_MULT = jnp.uint32(0x9E3779B1)  # Knuth/Fibonacci multiplicative hash


def hash_key(key: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    """Multiplicative hash of int32 keys into [0, n_buckets)."""
    h = key.astype(jnp.uint32) * _HASH_MULT
    h = h ^ (h >> jnp.uint32(15))
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


class FPEResult(NamedTuple):
    table_keys: jnp.ndarray  # [capacity] int32, EMPTY_KEY where vacant
    table_values: jnp.ndarray  # [capacity, *lanes]
    evict_keys: jnp.ndarray  # [n] int32, EMPTY_KEY where no eviction
    evict_values: jnp.ndarray  # [n, *lanes]


@functools.partial(jax.jit, static_argnames=("capacity", "ways", "op"))
def fpe_aggregate(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    *,
    capacity: int,
    ways: int = 4,
    op: str = "sum",
    table_keys: jnp.ndarray | None = None,
    table_values: jnp.ndarray | None = None,
) -> FPEResult:
    """Paper-faithful FPE: sequential hash-probe-aggregate-or-evict.

    keys: [n] int32 (EMPTY_KEY entries are skipped — allows padded streams)
    values: [n] or [n, lanes] (carried lane dims, e.g. mean's (sum, count))
    Returns the resident table plus an eviction stream aligned with the
    input (evict_keys[i] is the pair evicted while processing input i).

    ``table_keys``/``table_values`` (the flat ``[capacity]`` layout a prior
    call returned) resume from an existing resident table — the streaming
    ingest used by ``core.dataplane.LevelState`` and the packet simulator
    (``net.sim``), where a switch's table persists across packets and is
    flushed only at end-of-task.
    """
    aggop = aggops.get(op)
    n = keys.shape[0]
    ways = max(1, min(ways, capacity))
    n_buckets = max(1, capacity // ways)
    cap = n_buckets * ways
    lane_shape = values.shape[1:]  # () for scalar values
    lane_nd = len(lane_shape)

    if table_keys is None:
        tk0 = jnp.full((n_buckets, ways), EMPTY_KEY, dtype=jnp.int32)
        tv0 = jnp.zeros((n_buckets, ways) + lane_shape, dtype=values.dtype)
    else:
        tk0 = table_keys.reshape(n_buckets, ways)
        tv0 = table_values.reshape((n_buckets, ways) + lane_shape)

    def step(carry, inp):
        tk, tv = carry
        k, v = inp
        b = hash_key(k, n_buckets)
        row_k = tk[b]  # [ways]
        row_v = tv[b]  # [ways, *lanes]
        is_pad = k == EMPTY_KEY

        hit = row_k == k  # [ways]
        any_hit = jnp.any(hit) & ~is_pad
        empty = row_k == EMPTY_KEY
        any_empty = jnp.any(empty) & ~is_pad
        # first empty way
        empty_idx = jnp.argmax(empty)
        hit_l = hit.reshape(hit.shape + (1,) * lane_nd)  # broadcast over lanes

        # --- hit: aggregate into the matching way
        agg_row_v = jnp.where(hit_l, aggop.combine(row_v, v), row_v)

        # --- miss+empty: insert at first empty way
        ins_row_k = row_k.at[empty_idx].set(k)
        ins_row_v = row_v.at[empty_idx].set(v)

        # --- miss+full: evict way 0, shift left, insert at last way (paper:
        # the previously stored key is evicted and forwarded to the BPE)
        ev_k, ev_v = row_k[0], row_v[0]
        sh_row_k = jnp.concatenate([row_k[1:], k[None]])
        sh_row_v = jnp.concatenate([row_v[1:], v[None]])

        new_row_k = jnp.where(any_hit, row_k, jnp.where(any_empty, ins_row_k, sh_row_k))
        new_row_v = jnp.where(
            any_hit, agg_row_v, jnp.where(any_empty, ins_row_v, sh_row_v)
        )
        evicted = (~any_hit) & (~any_empty) & (~is_pad)
        out_k = jnp.where(evicted, ev_k, EMPTY_KEY)
        out_v = jnp.where(evicted, ev_v, jnp.zeros_like(ev_v))

        new_row_k = jnp.where(is_pad, row_k, new_row_k)
        new_row_v = jnp.where(is_pad, row_v, new_row_v)
        tk = tk.at[b].set(new_row_k)
        tv = tv.at[b].set(new_row_v)
        return (tk, tv), (out_k, out_v)

    (tk, tv), (ek, ev) = jax.lax.scan(step, (tk0, tv0), (keys, values))
    return FPEResult(tk.reshape(cap), tv.reshape((cap,) + lane_shape), ek, ev)


class CombineResult(NamedTuple):
    unique_keys: jnp.ndarray  # [n] int32, EMPTY_KEY past n_unique
    combined_values: jnp.ndarray  # [n, *lanes]
    n_unique: jnp.ndarray  # [] int32


@functools.partial(jax.jit, static_argnames=("op",))
def sorted_combine(keys: jnp.ndarray, values: jnp.ndarray, *, op: str = "sum") -> CombineResult:
    """Exact combine-by-key via sort + segment reduction (the BPE / the
    beyond-paper vectorized aggregator).  EMPTY_KEY inputs are ignored.

    Output is fixed-shape [n]: unique keys packed to the front in ascending
    order, EMPTY_KEY padding after ``n_unique`` (padding value slots hold
    the op's dtype-aware identity).  Values may carry trailing lane dims.
    """
    aggop = aggops.get(op)
    n = keys.shape[0]
    lane_nd = values.ndim - 1
    pad = keys == EMPTY_KEY
    # Sort padding to the end lexicographically by (is_pad, key) — no
    # sentinel remap, so INT32_MAX stays a legal, distinct key.
    order = jnp.lexsort((keys, pad))
    sk = keys[order]
    sv = values[order]

    # Segment ids: increment where the key changes.
    change = jnp.concatenate([jnp.ones((1,), jnp.int32), (sk[1:] != sk[:-1]).astype(jnp.int32)])
    seg = jnp.cumsum(change) - 1  # [n] in [0, n)

    ident = aggop.identity(values.dtype)
    comb = aggop.segment_reduce(sv, seg, n)

    # First occurrence of each segment gives its key.
    first_idx = jax.ops.segment_min(jnp.arange(n), seg, num_segments=n)
    n_pad = jnp.sum(pad)
    n_seg = seg[-1] + 1  # segments including a possible padding segment
    n_unique = jnp.where(n_pad > 0, n_seg - 1, n_seg).astype(jnp.int32)
    n_unique = jnp.where(n == n_pad, 0, n_unique)

    slot = jnp.arange(n)
    valid = slot < n_unique
    valid_l = valid.reshape(valid.shape + (1,) * lane_nd)
    uk = jnp.where(valid, sk[jnp.clip(first_idx, 0, n - 1)], EMPTY_KEY)
    cv = jnp.where(valid_l, comb, ident)
    return CombineResult(uk.astype(jnp.int32), cv, n_unique)


class TwoLevelResult(NamedTuple):
    """Full SwitchAgg node output: FPE flush + BPE combine, plus traffic stats.

    INVARIANT (traffic semantics, paper Fig. 9): ``out_keys`` is a traffic
    stream, not a key set — the same key may appear more than once.  With
    ``bpe=False`` the raw eviction stream is forwarded unaggregated (the
    SRAM-only "S-*" switch), so every re-eviction of a key is a distinct
    forwarded pair; with ``bpe=True`` the evictions are combined but a key
    resident in the FPE table at flush may ALSO appear in the BPE output.
    ``n_out`` therefore counts forwarded pairs (the bytes a downstream link
    carries), NOT distinct keys — use :func:`n_distinct_keys` for the
    latter.  Grouping ``out`` by key always reproduces the exact input
    combine (the conservation property tests).
    """

    out_keys: jnp.ndarray  # [capacity + n]
    out_values: jnp.ndarray  # [capacity + n, *lanes]
    n_out: jnp.ndarray  # [] int32 — number of forwarded output pairs
    n_in: jnp.ndarray  # [] int32 — number of real input pairs
    n_evict: jnp.ndarray  # [] int32 — FPE evictions (pre-BPE traffic)


@functools.partial(jax.jit, static_argnames=("capacity", "ways", "op", "bpe"))
def two_level_aggregate(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    *,
    capacity: int,
    ways: int = 4,
    op: str = "sum",
    bpe: bool = True,
) -> TwoLevelResult:
    """One SwitchAgg aggregation node: FPE hash stage + optional BPE stage.

    With ``bpe=False`` this models the SRAM-only programmable switch
    (DAIET-like): evictions leave the node unaggregated — the paper's Fig. 9
    "S-*" curves.  With ``bpe=True`` evictions are combined in the back-end
    ("M-*" curves).  See :class:`TwoLevelResult` for the ``n_out``
    duplicate-key invariant.  Ops operate on *carried* values (see
    ``aggops.AggOp.prepare_values``); multi-lane ops pass [n, lanes] values.
    """
    fpe = fpe_aggregate(keys, values, capacity=capacity, ways=ways, op=op)
    return assemble_node(keys, fpe.table_keys, fpe.table_values,
                         fpe.evict_keys, fpe.evict_values, op=op, bpe=bpe)


def assemble_node(keys, table_keys, table_values, evict_keys, evict_values,
                  *, op: str, bpe: bool) -> TwoLevelResult:
    """THE node-assembly policy (flush + eviction stream -> output stream),
    shared by the jnp node above, the Pallas node (``kernels.ops``), and the
    cascade executor (``core.dataplane.run_level``) — one copy of the
    n_out/n_in/n_evict accounting and the BPE-vs-raw forwarding choice."""
    n_evict = jnp.sum(evict_keys != EMPTY_KEY).astype(jnp.int32)
    if bpe:
        bpe_out = sorted_combine(evict_keys, evict_values, op=op)
        ok = jnp.concatenate([table_keys, bpe_out.unique_keys])
        ov = jnp.concatenate([table_values, bpe_out.combined_values])
    else:
        ok = jnp.concatenate([table_keys, evict_keys])
        ov = jnp.concatenate([table_values, evict_values])
    n_out = jnp.sum(ok != EMPTY_KEY).astype(jnp.int32)
    n_in = jnp.sum(keys != EMPTY_KEY).astype(jnp.int32)
    return TwoLevelResult(ok, ov, n_out, n_in, n_evict)


def n_distinct_keys(keys: jnp.ndarray) -> jnp.ndarray:
    """Number of distinct non-EMPTY keys in a stream (telemetry helper).

    Counts segment starts in sorted order — the set-size counterpart to the
    pair-count ``n_out`` (which may exceed it; see TwoLevelResult).  No
    sentinel remapping: every key value except EMPTY_KEY itself is legal,
    including INT32_MAX.
    """
    n = keys.shape[0]
    if n == 0:
        return jnp.zeros((), jnp.int32)
    sk = jnp.sort(keys)
    starts = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    return jnp.sum(starts & (sk != EMPTY_KEY)).astype(jnp.int32)


def reduction_ratio(res: TwoLevelResult) -> jnp.ndarray:
    """Traffic reduction achieved by the node (paper's R)."""
    return 1.0 - res.n_out / jnp.maximum(res.n_in, 1)


# ---------------------------------------------------------------------------
# Length-grouped dispatch — the payload analyzer (paper §4.2.3).
# ---------------------------------------------------------------------------


def length_group(key_lengths: jnp.ndarray, base: int = 8, n_groups: int = 8) -> jnp.ndarray:
    """Payload-analyzer binning: key length L -> group index.

    The paper divides key lengths [8B, 64B] into 8 groups of base B=8; each
    group is served by a dedicated FPE.  Returns clip(ceil(L/base)-1, 0, G-1).
    """
    g = (key_lengths + base - 1) // base - 1
    return jnp.clip(g, 0, n_groups - 1).astype(jnp.int32)
