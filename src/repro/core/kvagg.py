"""Two-level bounded-memory KV aggregation — the SwitchAgg FPE/BPE hierarchy.

Semantics (paper §4.2.4):

  * The **FPE** is a hash table of ``capacity`` slots held in fast memory
    (SRAM on the switch, VMEM in the Pallas kernel).  For each incoming
    (key, value) pair: hash the key, probe the bucket; on hit aggregate
    (SUM/MAX/MIN); on empty slot insert; on collision EVICT the resident
    pair downstream and insert the new pair.  The engine never stalls.
  * The **BPE** digests the eviction stream with a much larger (HBM/DRAM)
    table; we realize it as an exact sort-based combine, which on TPU is the
    natural "large slow memory" aggregation (sort + segment-sum is MXU/VPU
    friendly, and its latency is overlapped with the next FPE block exactly
    like the paper overlaps DRAM latency).

Invariant (checked by property tests): grouping the *outputs* (FPE flush +
BPE output) by key and combining gives exactly the input grouped-by-key
combine — aggregation never loses or double-counts data.

This module is the pure-jnp implementation; ``repro.kernels.kv_aggregate``
is the Pallas/TPU version of the FPE loop with identical semantics.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

EMPTY_KEY = jnp.int32(-1)

_HASH_MULT = jnp.uint32(0x9E3779B1)  # Knuth/Fibonacci multiplicative hash


def hash_key(key: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    """Multiplicative hash of int32 keys into [0, n_buckets)."""
    h = key.astype(jnp.uint32) * _HASH_MULT
    h = h ^ (h >> jnp.uint32(15))
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


def _combine(op: str, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    if op == "sum":
        return a + b
    if op == "max":
        return jnp.maximum(a, b)
    if op == "min":
        return jnp.minimum(a, b)
    raise ValueError(f"unsupported aggregation op: {op}")


def _identity(op: str, dtype) -> jnp.ndarray:
    if op == "sum":
        return jnp.zeros((), dtype)
    if op == "max":
        return jnp.array(-jnp.inf, dtype)
    if op == "min":
        return jnp.array(jnp.inf, dtype)
    raise ValueError(f"unsupported aggregation op: {op}")


class FPEResult(NamedTuple):
    table_keys: jnp.ndarray  # [capacity] int32, EMPTY_KEY where vacant
    table_values: jnp.ndarray  # [capacity]
    evict_keys: jnp.ndarray  # [n] int32, EMPTY_KEY where no eviction
    evict_values: jnp.ndarray  # [n]


@functools.partial(jax.jit, static_argnames=("capacity", "ways", "op"))
def fpe_aggregate(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    *,
    capacity: int,
    ways: int = 4,
    op: str = "sum",
) -> FPEResult:
    """Paper-faithful FPE: sequential hash-probe-aggregate-or-evict.

    keys: [n] int32 (EMPTY_KEY entries are skipped — allows padded streams)
    values: [n]
    Returns the resident table plus an eviction stream aligned with the
    input (evict_keys[i] is the pair evicted while processing input i).
    """
    n = keys.shape[0]
    ways = max(1, min(ways, capacity))
    n_buckets = max(1, capacity // ways)
    cap = n_buckets * ways

    tk0 = jnp.full((n_buckets, ways), EMPTY_KEY, dtype=jnp.int32)
    tv0 = jnp.zeros((n_buckets, ways), dtype=values.dtype)

    def step(carry, inp):
        tk, tv = carry
        k, v = inp
        b = hash_key(k, n_buckets)
        row_k = tk[b]  # [ways]
        row_v = tv[b]
        is_pad = k == EMPTY_KEY

        hit = row_k == k  # [ways]
        any_hit = jnp.any(hit) & ~is_pad
        empty = row_k == EMPTY_KEY
        any_empty = jnp.any(empty) & ~is_pad
        # first empty way
        empty_idx = jnp.argmax(empty)

        # --- hit: aggregate into the matching way
        agg_row_v = jnp.where(hit, _combine(op, row_v, v), row_v)

        # --- miss+empty: insert at first empty way
        ins_row_k = row_k.at[empty_idx].set(k)
        ins_row_v = row_v.at[empty_idx].set(v)

        # --- miss+full: evict way 0, shift left, insert at last way (paper:
        # the previously stored key is evicted and forwarded to the BPE)
        ev_k, ev_v = row_k[0], row_v[0]
        sh_row_k = jnp.concatenate([row_k[1:], k[None]])
        sh_row_v = jnp.concatenate([row_v[1:], v[None]])

        new_row_k = jnp.where(any_hit, row_k, jnp.where(any_empty, ins_row_k, sh_row_k))
        new_row_v = jnp.where(
            any_hit, agg_row_v, jnp.where(any_empty, ins_row_v, sh_row_v)
        )
        evicted = (~any_hit) & (~any_empty) & (~is_pad)
        out_k = jnp.where(evicted, ev_k, EMPTY_KEY)
        out_v = jnp.where(evicted, ev_v, jnp.zeros((), tv.dtype))

        new_row_k = jnp.where(is_pad, row_k, new_row_k)
        new_row_v = jnp.where(is_pad, row_v, new_row_v)
        tk = tk.at[b].set(new_row_k)
        tv = tv.at[b].set(new_row_v)
        return (tk, tv), (out_k, out_v)

    (tk, tv), (ek, ev) = jax.lax.scan(step, (tk0, tv0), (keys, values))
    return FPEResult(tk.reshape(cap), tv.reshape(cap), ek, ev)


class CombineResult(NamedTuple):
    unique_keys: jnp.ndarray  # [n] int32, EMPTY_KEY past n_unique
    combined_values: jnp.ndarray  # [n]
    n_unique: jnp.ndarray  # [] int32


@functools.partial(jax.jit, static_argnames=("op",))
def sorted_combine(keys: jnp.ndarray, values: jnp.ndarray, *, op: str = "sum") -> CombineResult:
    """Exact combine-by-key via sort + segment reduction (the BPE / the
    beyond-paper vectorized aggregator).  EMPTY_KEY inputs are ignored.

    Output is fixed-shape [n]: unique keys packed to the front in ascending
    order, EMPTY_KEY padding after ``n_unique``.
    """
    n = keys.shape[0]
    pad = keys == EMPTY_KEY
    # Sort padding to the end: sort by (is_pad, key).
    sort_key = jnp.where(pad, jnp.iinfo(jnp.int32).max, keys)
    order = jnp.argsort(sort_key)
    sk = sort_key[order]
    sv = values[order]

    # Segment ids: increment where the key changes.
    change = jnp.concatenate([jnp.ones((1,), jnp.int32), (sk[1:] != sk[:-1]).astype(jnp.int32)])
    seg = jnp.cumsum(change) - 1  # [n] in [0, n)

    ident = _identity(op, values.dtype)
    if op == "sum":
        comb = jax.ops.segment_sum(sv, seg, num_segments=n)
    elif op == "max":
        comb = jax.ops.segment_max(sv, seg, num_segments=n)
    else:
        comb = jax.ops.segment_min(sv, seg, num_segments=n)

    # First occurrence of each segment gives its key.
    first_idx = jax.ops.segment_min(jnp.arange(n), seg, num_segments=n)
    n_pad = jnp.sum(pad)
    n_seg = seg[-1] + 1  # segments including a possible padding segment
    n_unique = jnp.where(n_pad > 0, n_seg - 1, n_seg).astype(jnp.int32)
    n_unique = jnp.where(n == n_pad, 0, n_unique)

    slot = jnp.arange(n)
    valid = slot < n_unique
    uk = jnp.where(valid, sk[jnp.clip(first_idx, 0, n - 1)], EMPTY_KEY)
    cv = jnp.where(valid, comb, ident)
    return CombineResult(uk.astype(jnp.int32), cv, n_unique)


class TwoLevelResult(NamedTuple):
    """Full SwitchAgg node output: FPE flush + BPE combine, plus traffic stats."""

    out_keys: jnp.ndarray  # [capacity + n]
    out_values: jnp.ndarray  # [capacity + n]
    n_out: jnp.ndarray  # [] int32 — number of real output pairs
    n_in: jnp.ndarray  # [] int32 — number of real input pairs


@functools.partial(jax.jit, static_argnames=("capacity", "ways", "op", "bpe"))
def two_level_aggregate(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    *,
    capacity: int,
    ways: int = 4,
    op: str = "sum",
    bpe: bool = True,
) -> TwoLevelResult:
    """One SwitchAgg aggregation node: FPE hash stage + optional BPE stage.

    With ``bpe=False`` this models the SRAM-only programmable switch
    (DAIET-like): evictions leave the node unaggregated — the paper's Fig. 9
    "S-*" curves.  With ``bpe=True`` evictions are combined in the back-end
    ("M-*" curves).
    """
    fpe = fpe_aggregate(keys, values, capacity=capacity, ways=ways, op=op)
    n = keys.shape[0]
    cap = fpe.table_keys.shape[0]
    if bpe:
        bpe_out = sorted_combine(fpe.evict_keys, fpe.evict_values, op=op)
        ok = jnp.concatenate([fpe.table_keys, bpe_out.unique_keys])
        ov = jnp.concatenate([fpe.table_values, bpe_out.combined_values])
    else:
        ok = jnp.concatenate([fpe.table_keys, fpe.evict_keys])
        ov = jnp.concatenate([fpe.table_values, fpe.evict_values])
    n_out = jnp.sum(ok != EMPTY_KEY).astype(jnp.int32)
    n_in = jnp.sum(keys != EMPTY_KEY).astype(jnp.int32)
    return TwoLevelResult(ok, ov, n_out, n_in)


def reduction_ratio(res: TwoLevelResult) -> jnp.ndarray:
    """Traffic reduction achieved by the node (paper's R)."""
    return 1.0 - res.n_out / jnp.maximum(res.n_in, 1)


# ---------------------------------------------------------------------------
# Length-grouped dispatch — the payload analyzer (paper §4.2.3).
# ---------------------------------------------------------------------------


def length_group(key_lengths: jnp.ndarray, base: int = 8, n_groups: int = 8) -> jnp.ndarray:
    """Payload-analyzer binning: key length L -> group index.

    The paper divides key lengths [8B, 64B] into 8 groups of base B=8; each
    group is served by a dedicated FPE.  Returns clip(ceil(L/base)-1, 0, G-1).
    """
    g = (key_lengths + base - 1) // base - 1
    return jnp.clip(g, 0, n_groups - 1).astype(jnp.int32)
