"""Two-level bounded-memory KV aggregation — the SwitchAgg FPE/BPE hierarchy.

Semantics (paper §4.2.4):

  * The **FPE** is a hash table of ``capacity`` slots held in fast memory
    (SRAM on the switch, VMEM in the Pallas kernel).  For each incoming
    (key, value) pair: hash the key, probe the bucket; on hit aggregate
    (SUM/MAX/MIN); on empty slot insert; on collision EVICT the resident
    pair downstream and insert the new pair.  The engine never stalls.
  * The **BPE** digests the eviction stream with a much larger (HBM/DRAM)
    table; we realize it as an exact sort-based combine, which on TPU is the
    natural "large slow memory" aggregation (sort + segment-sum is MXU/VPU
    friendly, and its latency is overlapped with the next FPE block exactly
    like the paper overlaps DRAM latency).

Invariant (checked by property tests): grouping the *outputs* (FPE flush +
BPE output) by key and combining gives exactly the input grouped-by-key
combine — aggregation never loses or double-counts data.

Op semantics (combine / identity / segment reduce) come from the
``core.aggops`` registry (DESIGN.md §6) — the one source of truth shared
with the Pallas kernels; this module never hardcodes an op.  Values may
carry trailing lane dimensions (e.g. ``mean``'s paired (sum, count) lanes):
eviction decisions are key-driven, so lanes ride along untouched.

This module is the pure-jnp implementation; ``repro.kernels.kv_aggregate``
is the Pallas/TPU version of the FPE loop with identical semantics.

Every FPE entry point takes ``exact_stream`` (DESIGN.md §8): True is the
paper-faithful sequential scan with a bit-reproducible eviction trace;
False is the batched-block fast path — within-block pre-combine plus a
closed-form vectorized bucket update — with identical grouped-combine
totals but a different eviction pattern, ~5-8x the scan's pairs/sec.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import aggops

EMPTY_KEY = jnp.int32(-1)

# THE key hash lives in core.aggops (one copy for the jnp engine and the
# Pallas kernel); re-exported here for existing callers.
hash_key = aggops.hash_key


class FPEResult(NamedTuple):
    table_keys: jnp.ndarray  # [capacity] int32, EMPTY_KEY where vacant
    table_values: jnp.ndarray  # [capacity, *lanes]
    evict_keys: jnp.ndarray  # [n] int32, EMPTY_KEY where no eviction
    evict_values: jnp.ndarray  # [n, *lanes]


def _fpe_geometry(capacity: int, ways: int) -> tuple[int, int, int]:
    """(ways, n_buckets, cap) — THE table-geometry clamp, shared by the
    scan path, the batched fast path, and the Pallas wrapper."""
    ways = max(1, min(ways, capacity))
    n_buckets = max(1, capacity // ways)
    return ways, n_buckets, n_buckets * ways


@functools.partial(
    jax.jit, static_argnames=("capacity", "ways", "op", "exact_stream"))
def fpe_aggregate(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    *,
    capacity: int,
    ways: int = 4,
    op: str = "sum",
    exact_stream: bool = True,
    table_keys: jnp.ndarray | None = None,
    table_values: jnp.ndarray | None = None,
) -> FPEResult:
    """The FPE hash engine: hash-probe-aggregate-or-evict (DESIGN.md §8).

    keys: [n] int32 (EMPTY_KEY entries are skipped — allows padded streams)
    values: [n] or [n, lanes] (carried lane dims, e.g. mean's (sum, count))
    Returns the resident table plus an eviction stream of n slots,
    EMPTY_KEY where nothing was evicted.

    ``exact_stream=True`` is the paper-faithful sequential scan: pairs are
    processed one at a time in stream order, so the eviction stream is
    bit-reproducible against the switch model (the Fig. 9 traffic curves).
    ``exact_stream=False`` is the batched-block fast path: duplicate keys
    in the block are pre-combined (sort + segment reduce), then the
    surviving distinct keys update the table via vectorized bucket rounds.
    The grouped-by-key combine of (flush + evictions) is IDENTICAL in both
    modes — only the eviction *order/pattern* (which pair left when) may
    differ; see DESIGN.md §8 for the contract.

    ``table_keys``/``table_values`` (the flat ``[capacity]`` layout a prior
    call returned) resume from an existing resident table — the streaming
    ingest used by ``core.dataplane.LevelState`` and the packet simulator
    (``net.sim``), where a switch's table persists across packets and is
    flushed only at end-of-task.
    """
    if exact_stream:
        return _fpe_scan(keys, values, capacity=capacity, ways=ways, op=op,
                         table_keys=table_keys, table_values=table_values)
    return _fpe_batched(keys, values, capacity=capacity, ways=ways, op=op,
                        table_keys=table_keys, table_values=table_values)


def _fpe_scan(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    *,
    capacity: int,
    ways: int,
    op: str,
    table_keys: jnp.ndarray | None,
    table_values: jnp.ndarray | None,
) -> FPEResult:
    """Paper-faithful FPE: sequential hash-probe-aggregate-or-evict.

    evict_keys[i] is the pair evicted while processing input i.
    """
    aggop = aggops.get(op)
    n = keys.shape[0]
    ways, n_buckets, cap = _fpe_geometry(capacity, ways)
    lane_shape = values.shape[1:]  # () for scalar values
    lane_nd = len(lane_shape)

    if table_keys is None:
        tk0 = jnp.full((n_buckets, ways), EMPTY_KEY, dtype=jnp.int32)
        tv0 = jnp.zeros((n_buckets, ways) + lane_shape, dtype=values.dtype)
    else:
        tk0 = table_keys.reshape(n_buckets, ways)
        tv0 = table_values.reshape((n_buckets, ways) + lane_shape)

    def step(carry, inp):
        tk, tv = carry
        k, v = inp
        b = hash_key(k, n_buckets)
        row_k = tk[b]  # [ways]
        row_v = tv[b]  # [ways, *lanes]
        is_pad = k == EMPTY_KEY

        hit = row_k == k  # [ways]
        any_hit = jnp.any(hit) & ~is_pad
        empty = row_k == EMPTY_KEY
        any_empty = jnp.any(empty) & ~is_pad
        # first empty way
        empty_idx = jnp.argmax(empty)
        hit_l = hit.reshape(hit.shape + (1,) * lane_nd)  # broadcast over lanes

        # --- hit: aggregate into the matching way
        agg_row_v = jnp.where(hit_l, aggop.combine(row_v, v), row_v)

        # --- miss+empty: insert at first empty way
        ins_row_k = row_k.at[empty_idx].set(k)
        ins_row_v = row_v.at[empty_idx].set(v)

        # --- miss+full: evict way 0, shift left, insert at last way (paper:
        # the previously stored key is evicted and forwarded to the BPE)
        ev_k, ev_v = row_k[0], row_v[0]
        sh_row_k = jnp.concatenate([row_k[1:], k[None]])
        sh_row_v = jnp.concatenate([row_v[1:], v[None]])

        new_row_k = jnp.where(any_hit, row_k, jnp.where(any_empty, ins_row_k, sh_row_k))
        new_row_v = jnp.where(
            any_hit, agg_row_v, jnp.where(any_empty, ins_row_v, sh_row_v)
        )
        evicted = (~any_hit) & (~any_empty) & (~is_pad)
        out_k = jnp.where(evicted, ev_k, EMPTY_KEY)
        out_v = jnp.where(evicted, ev_v, jnp.zeros_like(ev_v))

        new_row_k = jnp.where(is_pad, row_k, new_row_k)
        new_row_v = jnp.where(is_pad, row_v, new_row_v)
        tk = tk.at[b].set(new_row_k)
        tv = tv.at[b].set(new_row_v)
        return (tk, tv), (out_k, out_v)

    (tk, tv), (ek, ev) = jax.lax.scan(step, (tk0, tv0), (keys, values))
    return FPEResult(tk.reshape(cap), tv.reshape((cap,) + lane_shape), ek, ev)


def _group_reduce(keys, values, *, op):
    """THE bulk group-by-key reduction (DESIGN.md §8): one radix key sort +
    a binary-search segment-id map + one unsorted segment reduce.

    Returns (k_s, real_start, comb):
      k_s        [n] keys sorted ascending (EMPTY_KEY is just another value
                 in the sort; any key except EMPTY_KEY itself is legal),
      real_start [n] True at the first sorted occurrence of each real key,
      comb       [n, *lanes] combined value of each key's group, indexed by
                 the key's FIRST SORTED POSITION (entries that are not a
                 real first occurrence hold garbage — never read).

    Why this shape: on XLA:CPU the single-operand int sort takes the fast
    radix path while the variadic comparator sort that would co-sort
    values with keys is ~10x slower, and scatters cost ~30x a gather.  So
    values never ride a sort: each ORIGINAL element finds its group with
    one searchsorted pass and the reduce runs over unsorted segment ids.
    """
    aggop = aggops.get(op)
    n = keys.shape[0]
    k_s = jnp.sort(keys)
    change = jnp.concatenate([jnp.ones((1,), bool), k_s[1:] != k_s[:-1]])
    real_start = change & (k_s != EMPTY_KEY)
    seg = jnp.searchsorted(k_s, keys, method="scan")
    comb = aggop.segment_reduce(values, seg, num_segments=n)
    return k_s, real_start, comb


def _fpe_batched(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    *,
    capacity: int,
    ways: int,
    op: str,
    table_keys: jnp.ndarray | None,
    table_values: jnp.ndarray | None,
) -> FPEResult:
    """Batched-block FPE fast path (DESIGN.md §8): within-block pre-combine
    + one closed-form vectorized bucket update instead of one sequential
    step per pair.

    1. Duplicate keys in the block collapse to one carried value each
       (``_group_reduce``: sort + ``aggops.segment_reduce``).  Eviction
       decisions are key-driven, so combining same-key pairs *before*
       table insertion preserves the grouped-combine conservation
       invariant.
    2. One more radix sort orders the distinct keys bucket-major, and the
       whole block's table update collapses to closed form: each bucket
       row is a FIFO queue — [residents, new distinct keys] — of which the
       last ``ways`` survive and the prefix is evicted.  Hits combine into
       their resident way; every survivor's (slot, key) write rides one
       int32 composite sort (``slot * n + index``), so the scatter that
       applies the block touches at most ``capacity`` slots.  No
       per-element loop, no per-conflict rounds: intra-block bucket
       conflicts are resolved analytically by the queue arithmetic.

    The eviction stream is [n + capacity] (block evictions in distinct-key
    order, then residents displaced by the block) instead of the scan
    path's input-aligned [n]; slots hold EMPTY_KEY where nothing was
    evicted.  Callers treat both as masked streams, but the *pattern* is
    not the paper's per-arrival trace — use ``exact_stream=True`` for
    that.  Requires ``n * max(n_buckets, capacity) < 2**31`` (int32
    composites); larger calls fall back to the exact scan.
    """
    aggop = aggops.get(op)
    combine = aggop.combine  # resolved once, outside all vector math
    n = keys.shape[0]
    ways, n_buckets, cap = _fpe_geometry(capacity, ways)
    imax = jnp.iinfo(jnp.int32).max
    if n == 0 or n * max(n_buckets, cap) >= imax:
        res = _fpe_scan(keys, values, capacity=capacity, ways=ways, op=op,
                        table_keys=table_keys, table_values=table_values)
        pad_ev = jnp.full((cap,), EMPTY_KEY, jnp.int32)
        pad_vv = jnp.zeros((cap,) + values.shape[1:], values.dtype)
        return FPEResult(  # keep the fast path's [n + cap] stream shape
            res.table_keys, res.table_values,
            jnp.concatenate([res.evict_keys, pad_ev]),
            jnp.concatenate([res.evict_values, pad_vv]))
    lane_shape = values.shape[1:]
    lane_nd = len(lane_shape)

    def lanes(m):  # broadcast a mask over trailing lane dims
        return m.reshape(m.shape + (1,) * lane_nd)

    if table_keys is None:
        tk = jnp.full((n_buckets, ways), EMPTY_KEY, jnp.int32)
        tv = jnp.zeros((n_buckets, ways) + lane_shape, values.dtype)
    else:
        tk = table_keys.reshape(n_buckets, ways)
        tv = table_values.reshape((n_buckets, ways) + lane_shape)

    # --- stage 1: within-block pre-combine -------------------------------
    k_s, real_start, comb = _group_reduce(keys, values, op=op)
    pos = jnp.arange(n, dtype=jnp.int32)

    # --- stage 2: bucket-major distinct stream (one radix sort) ----------
    bucket_s = hash_key(k_s, n_buckets)
    c1 = jnp.sort(jnp.where(real_start, bucket_s * n + pos, imax))
    valid_d = c1 != imax
    fp = jnp.where(valid_d, c1 % n, 0)  # first sorted position of key d
    b_d = jnp.where(valid_d, c1 // n, n_buckets)  # ascending buckets
    uk = jnp.where(valid_d, k_s[fp], EMPTY_KEY)
    cv = jnp.where(lanes(valid_d), comb[fp],
                   jnp.zeros((), values.dtype))

    # --- stage 3: hit detection + FIFO queue arithmetic ------------------
    b_c = jnp.clip(b_d, 0, n_buckets - 1)
    rows_k = tk[b_c]  # [n, ways]
    rows_v = tv[b_c]  # [n, ways, *lanes]
    hit = (rows_k == uk[:, None]) & valid_d[:, None]
    is_hit = jnp.any(hit, axis=1)
    hit_way = jnp.argmax(hit, axis=1).astype(jnp.int32)
    # resident rows are front-contiguous (both engines insert at the first
    # empty way and shift full rows left), so the count locates the queue
    r_d = jnp.sum(rows_k != EMPTY_KEY, axis=1).astype(jnp.int32)
    nh = valid_d & ~is_hit  # distinct new keys joining the queue

    # per-bucket totals / per-key queue rank from prefix sums over the
    # bucket-major layout (run starts found by a tiny n_buckets-query
    # binary search — b_d is sorted)
    sx = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                          jnp.cumsum(nh.astype(jnp.int32))])
    rs = jnp.searchsorted(b_d, jnp.arange(n_buckets + 1, dtype=jnp.int32),
                          method="scan").astype(jnp.int32)
    q_arr = sx[rs[1:]] - sx[rs[:-1]]  # [n_buckets] new keys per bucket
    j_d = sx[pos] - sx[rs[b_c]]  # rank of d among its bucket's new keys
    q_d = q_arr[b_c]
    # queue [r residents, q new keys]: evict the first E, keep the last W
    e_d = r_d + q_d - ways  # evictions this bucket must make
    er_d = jnp.clip(jnp.minimum(r_d, e_d), 0, ways)  # evicted residents

    hit_surv = is_hit & (hit_way >= er_d)
    hit_evic = is_hit & (hit_way < er_d)  # resident dies before the merge
    new_surv = nh & (j_d >= jnp.maximum(e_d - r_d, 0))
    # a key whose resident was shift-evicted re-enters the stream as its
    # own pair (the resident pair leaves separately): same grouped total
    self_evict = (nh & ~new_surv) | hit_evic

    way_tgt = jnp.where(
        hit_surv, hit_way - er_d,
        r_d + j_d - jnp.maximum(e_d, 0))  # post-shift way of each writer
    writer = hit_surv | new_surv
    rows_v_hit = jnp.take_along_axis(
        rows_v, lanes(hit_way[:, None]), axis=1)[:, 0]
    wval = jnp.where(lanes(is_hit), combine(rows_v_hit, cv), cv)

    # --- stage 4: apply — shift rows, then scatter the <= cap writers ----
    r_b = jnp.sum(tk != EMPTY_KEY, axis=1).astype(jnp.int32)
    e_b = jnp.clip(jnp.minimum(r_b, r_b + q_arr - ways), 0, ways)
    wi = jnp.arange(ways, dtype=jnp.int32)[None, :]
    src = jnp.clip(wi + e_b[:, None], 0, ways - 1)
    keep = (wi + e_b[:, None]) < ways
    sh_tk = jnp.where(keep, jnp.take_along_axis(tk, src, axis=1), EMPTY_KEY)
    sh_tv = jnp.where(lanes(keep),
                      jnp.take_along_axis(tv, lanes(src), axis=1),
                      jnp.zeros((), values.dtype))

    # every write target (bucket, way) is unique, so there are at most
    # cap writers: one composite sort packs them for a cap-sized scatter
    c2 = jnp.sort(jnp.where(writer, (b_d * ways + way_tgt) * n + pos,
                            imax))[:cap]
    w2 = c2 != imax
    slot2 = jnp.where(w2, c2 // n, cap)  # cap = out of bounds -> dropped
    d2 = jnp.where(w2, c2 % n, 0)
    flat_k = sh_tk.reshape(cap).at[slot2].set(uk[d2], mode="drop")
    flat_v = sh_tv.reshape((cap,) + lane_shape).at[slot2].set(
        wval[d2], mode="drop")

    # --- eviction stream: block self-evictions + displaced residents -----
    ev_k = jnp.where(self_evict, uk, EMPTY_KEY)
    ev_v = jnp.where(lanes(self_evict), cv, jnp.zeros((), values.dtype))
    res_ev = wi < e_b[:, None]  # [n_buckets, ways]
    rv_k = jnp.where(res_ev, tk, EMPTY_KEY).reshape(cap)
    rv_v = jnp.where(lanes(res_ev), tv,
                     jnp.zeros((), values.dtype)).reshape(
        (cap,) + lane_shape)
    return FPEResult(flat_k, flat_v,
                     jnp.concatenate([ev_k, rv_k]),
                     jnp.concatenate([ev_v, rv_v]))


class CombineResult(NamedTuple):
    unique_keys: jnp.ndarray  # [n] int32, EMPTY_KEY past n_unique
    combined_values: jnp.ndarray  # [n, *lanes]
    n_unique: jnp.ndarray  # [] int32


@functools.partial(jax.jit, static_argnames=("op",))
def sorted_combine(keys: jnp.ndarray, values: jnp.ndarray, *, op: str = "sum") -> CombineResult:
    """Exact combine-by-key via sort + segment reduction (the BPE / the
    beyond-paper vectorized aggregator).  EMPTY_KEY inputs are ignored.

    Output is fixed-shape [n]: unique keys packed to the front in ascending
    order, EMPTY_KEY padding after ``n_unique`` (padding value slots hold
    the op's dtype-aware identity).  Values may carry trailing lane dims.
    """
    aggop = aggops.get(op)
    n = keys.shape[0]
    lane_nd = values.ndim - 1
    if n == 0:
        return CombineResult(keys.astype(jnp.int32), values,
                             jnp.zeros((), jnp.int32))
    # One radix sort + searchsorted + unsorted segment reduce
    # (_group_reduce) — values never ride a comparator sort, and no
    # sentinel remap, so INT32_MAX stays a legal, distinct key.
    k_s, real_start, comb = _group_reduce(keys, values, op=op)
    pos = jnp.arange(n, dtype=jnp.int32)
    # k_s is ascending, so first positions sort to ascending-key order
    fp = jnp.sort(jnp.where(real_start, pos, jnp.iinfo(jnp.int32).max))
    n_unique = jnp.sum(real_start).astype(jnp.int32)
    valid = pos < n_unique
    valid_l = valid.reshape(valid.shape + (1,) * lane_nd)
    fp_c = jnp.clip(fp, 0, n - 1)
    ident = aggop.identity(values.dtype)
    uk = jnp.where(valid, k_s[fp_c], EMPTY_KEY)
    cv = jnp.where(valid_l, comb[fp_c], ident)
    return CombineResult(uk.astype(jnp.int32), cv, n_unique)


class TwoLevelResult(NamedTuple):
    """Full SwitchAgg node output: FPE flush + BPE combine, plus traffic stats.

    INVARIANT (traffic semantics, paper Fig. 9): ``out_keys`` is a traffic
    stream, not a key set — the same key may appear more than once.  With
    ``bpe=False`` the raw eviction stream is forwarded unaggregated (the
    SRAM-only "S-*" switch), so every re-eviction of a key is a distinct
    forwarded pair; with ``bpe=True`` the evictions are combined but a key
    resident in the FPE table at flush may ALSO appear in the BPE output.
    ``n_out`` therefore counts forwarded pairs (the bytes a downstream link
    carries), NOT distinct keys — use :func:`n_distinct_keys` for the
    latter.  Grouping ``out`` by key always reproduces the exact input
    combine (the conservation property tests).
    """

    out_keys: jnp.ndarray  # [capacity + n]
    out_values: jnp.ndarray  # [capacity + n, *lanes]
    n_out: jnp.ndarray  # [] int32 — number of forwarded output pairs
    n_in: jnp.ndarray  # [] int32 — number of real input pairs
    n_evict: jnp.ndarray  # [] int32 — FPE evictions (pre-BPE traffic)


@functools.partial(
    jax.jit, static_argnames=("capacity", "ways", "op", "bpe", "exact_stream"))
def two_level_aggregate(
    keys: jnp.ndarray,
    values: jnp.ndarray,
    *,
    capacity: int,
    ways: int = 4,
    op: str = "sum",
    bpe: bool = True,
    exact_stream: bool = True,
) -> TwoLevelResult:
    """One SwitchAgg aggregation node: FPE hash stage + optional BPE stage.

    With ``bpe=False`` this models the SRAM-only programmable switch
    (DAIET-like): evictions leave the node unaggregated — the paper's Fig. 9
    "S-*" curves.  With ``bpe=True`` evictions are combined in the back-end
    ("M-*" curves).  See :class:`TwoLevelResult` for the ``n_out``
    duplicate-key invariant.  Ops operate on *carried* values (see
    ``aggops.AggOp.prepare_values``); multi-lane ops pass [n, lanes] values.
    ``exact_stream=False`` runs the batched-block FPE fast path (DESIGN.md
    §8): same grouped-combine result, different eviction pattern — keep the
    default for paper-faithful Fig. 9 traffic curves.
    """
    fpe = fpe_aggregate(keys, values, capacity=capacity, ways=ways, op=op,
                        exact_stream=exact_stream)
    return assemble_node(keys, fpe.table_keys, fpe.table_values,
                         fpe.evict_keys, fpe.evict_values, op=op, bpe=bpe)


def assemble_node(keys, table_keys, table_values, evict_keys, evict_values,
                  *, op: str, bpe: bool) -> TwoLevelResult:
    """THE node-assembly policy (flush + eviction stream -> output stream),
    shared by the jnp node above, the Pallas node (``kernels.ops``), and the
    cascade executor (``core.dataplane.run_level``) — one copy of the
    n_out/n_in/n_evict accounting and the BPE-vs-raw forwarding choice."""
    n_evict = jnp.sum(evict_keys != EMPTY_KEY).astype(jnp.int32)
    if bpe:
        bpe_out = sorted_combine(evict_keys, evict_values, op=op)
        ok = jnp.concatenate([table_keys, bpe_out.unique_keys])
        ov = jnp.concatenate([table_values, bpe_out.combined_values])
    else:
        ok = jnp.concatenate([table_keys, evict_keys])
        ov = jnp.concatenate([table_values, evict_values])
    n_out = jnp.sum(ok != EMPTY_KEY).astype(jnp.int32)
    n_in = jnp.sum(keys != EMPTY_KEY).astype(jnp.int32)
    return TwoLevelResult(ok, ov, n_out, n_in, n_evict)


def n_distinct_keys(keys: jnp.ndarray) -> jnp.ndarray:
    """Number of distinct non-EMPTY keys in a stream (telemetry helper).

    Counts segment starts in sorted order — the set-size counterpart to the
    pair-count ``n_out`` (which may exceed it; see TwoLevelResult).  No
    sentinel remapping: every key value except EMPTY_KEY itself is legal,
    including INT32_MAX.
    """
    n = keys.shape[0]
    if n == 0:
        return jnp.zeros((), jnp.int32)
    sk = jnp.sort(keys)
    starts = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    return jnp.sum(starts & (sk != EMPTY_KEY)).astype(jnp.int32)


def reduction_ratio(res: TwoLevelResult) -> jnp.ndarray:
    """Traffic reduction achieved by the node (paper's R)."""
    return 1.0 - res.n_out / jnp.maximum(res.n_in, 1)


# ---------------------------------------------------------------------------
# Length-grouped dispatch — the payload analyzer (paper §4.2.3).
# ---------------------------------------------------------------------------


def length_group(key_lengths: jnp.ndarray, base: int = 8, n_groups: int = 8) -> jnp.ndarray:
    """Payload-analyzer binning: key length L -> group index.

    The paper divides key lengths [8B, 64B] into 8 groups of base B=8; each
    group is served by a dedicated FPE.  Returns clip(ceil(L/base)-1, 0, G-1).
    """
    g = (key_lengths + base - 1) // base - 1
    return jnp.clip(g, 0, n_groups - 1).astype(jnp.int32)
