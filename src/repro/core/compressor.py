"""Gradient -> KV-pair compression (the SwitchAgg payload producer).

The paper's aggregation packets carry variable-length (key, value) pairs.
In the TPU adaptation the workers' "intermediate results" are gradient
shards; the KV payload is produced by magnitude top-k selection:

    key   = flat index of a retained gradient coordinate
    value = the gradient value at that coordinate

Error feedback (memory of the unsent residual) keeps the compression
unbiased over time — standard for top-k SGD and required for convergence.
This is the paper-compatible payload: aggregation nodes combine values of
equal keys with SUM, exactly the word-count/SUM semantics of the paper.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class CompressedGrad(NamedTuple):
    keys: jnp.ndarray  # [k] int32 flat indices
    values: jnp.ndarray  # [k] float
    shape: tuple  # original shape (static)


class CompressorState(NamedTuple):
    residual: jnp.ndarray  # error-feedback memory, same shape as grad


def init_state(shape, dtype=jnp.float32) -> CompressorState:
    return CompressorState(residual=jnp.zeros(shape, dtype))


@functools.partial(jax.jit, static_argnames=("k",))
def topk_compress(
    grad: jnp.ndarray, state: CompressorState, *, k: int
) -> tuple[CompressedGrad, CompressorState]:
    """Select the k largest-|.| coordinates of (grad + residual)."""
    acc = grad.astype(state.residual.dtype) + state.residual
    flat = acc.reshape(-1)
    mag = jnp.abs(flat)
    vals, idx = jax.lax.top_k(mag, k)
    picked = flat[idx]
    new_res = flat.at[idx].set(0.0).reshape(acc.shape)
    return (
        CompressedGrad(idx.astype(jnp.int32), picked, tuple(grad.shape)),
        CompressorState(residual=new_res),
    )


@functools.partial(jax.jit, static_argnames=("size",))
def decompress_sum(keys: jnp.ndarray, values: jnp.ndarray, *, size: int) -> jnp.ndarray:
    """Scatter-add a KV stream back to a dense flat vector of ``size``.

    EMPTY (-1) keys are dropped.  Duplicate keys accumulate — so a stream
    that was only *partially* combined by the aggregation tree still
    decompresses to the exact sum (SwitchAgg correctness invariant).
    """
    valid = keys >= 0
    safe = jnp.where(valid, keys, 0)
    contrib = jnp.where(valid, values, 0.0)
    return jnp.zeros((size,), values.dtype).at[safe].add(contrib)


@functools.partial(jax.jit, static_argnames=("k", "chunk"))
def blockwise_topk_compress(
    grad: jnp.ndarray, state: CompressorState, *, k: int, chunk: int
) -> tuple[CompressedGrad, CompressorState]:
    """Top-k per contiguous chunk — bounded working set per FPE group.

    Mirrors the paper's payload analyzer: each chunk is one "length group"
    served by its own processing engine; global top-k would need global
    state, per-chunk top-k needs only VMEM-resident state (and is the form
    the Pallas kernel implements).
    """
    acc = grad.astype(state.residual.dtype) + state.residual
    flat = acc.reshape(-1)
    n = flat.shape[0]
    if n % chunk != 0:
        raise ValueError(f"size {n} not divisible by chunk {chunk}")
    rows = n // chunk
    mat = flat.reshape(rows, chunk)
    vals, idx = jax.lax.top_k(jnp.abs(mat), k)  # [rows, k]
    picked = jnp.take_along_axis(mat, idx, axis=1)
    gkeys = idx + (jnp.arange(rows)[:, None] * chunk)
    new_flat = flat.at[gkeys.reshape(-1)].set(0.0)
    return (
        CompressedGrad(gkeys.reshape(-1).astype(jnp.int32), picked.reshape(-1), tuple(grad.shape)),
        CompressorState(residual=new_flat.reshape(acc.shape)),
    )


def compression_ratio(shape, k_total: int, key_bytes: int = 4, val_bytes: int = 4,
                      dense_bytes: int = 4) -> float:
    """Payload bytes of the KV stream vs the dense gradient."""
    import numpy as np

    dense = float(np.prod(shape)) * dense_bytes
    kv = float(k_total) * (key_bytes + val_bytes)
    return kv / dense
