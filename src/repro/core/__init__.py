"""SwitchAgg core: in-network aggregation as a composable JAX feature.

Public surface:
  reduction_model — paper Eq. 1-3, Theorems 2.1/2.2, simulators
  kvagg           — FPE/BPE bounded-memory KV combine (pure jnp semantics)
  compressor      — gradient -> KV payload (top-k + error feedback)
  tree            — aggregation-tree construction over a mesh
  collectives     — flat / tree / compressed gradient exchanges (shard_map)
  planner         — the controller: job config, memory partitioning, plans,
                    and the multi-job congestion-aware JobScheduler
"""

from . import collectives, compressor, kvagg, planner, reduction_model, tree
from .collectives import GradAggMode
from .planner import (
    ExchangePlan,
    JobScheduler,
    LaunchRequest,
    Topology,
    plan_grad_exchange,
)

__all__ = [
    "collectives",
    "compressor",
    "kvagg",
    "planner",
    "reduction_model",
    "tree",
    "GradAggMode",
    "ExchangePlan",
    "JobScheduler",
    "LaunchRequest",
    "Topology",
    "plan_grad_exchange",
]
