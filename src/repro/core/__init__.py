"""SwitchAgg core: in-network aggregation as a composable JAX feature.

Public surface:
  reduction_model — paper Eq. 1-3, Theorems 2.1/2.2, simulators
  aggops          — the AggOp registry: one source of op semantics
                    (combine/identity/segment reduce; DESIGN.md §6)
  kvagg           — FPE/BPE bounded-memory KV combine (pure jnp semantics)
  dataplane       — plan-driven multi-level cascade executor + telemetry
  compressor      — gradient -> KV payload (top-k + error feedback)
  tree            — aggregation-tree construction over a mesh
  collectives     — flat / tree / compressed gradient exchanges (shard_map)
  planner         — the controller: job config, memory partitioning, plans,
                    and the multi-job congestion-aware JobScheduler
  controller      — OnlineController: incremental multi-tenant admission
                    under churn, and the plan() front door (DESIGN.md §13)
"""

from . import (
    aggops,
    collectives,
    compressor,
    controller,
    dataplane,
    kvagg,
    planner,
    reduction_model,
    tree,
)
from .aggops import AggOp
from .collectives import GradAggMode
from .controller import OnlineController, OnlineJobRequest, plan
from .dataplane import CascadePlan, LevelSpec, run_cascade
from .planner import (
    ExchangePlan,
    JobScheduler,
    LaunchRequest,
    Topology,
    plan_grad_exchange,
)

__all__ = [
    "aggops",
    "collectives",
    "compressor",
    "controller",
    "dataplane",
    "kvagg",
    "planner",
    "reduction_model",
    "tree",
    "AggOp",
    "CascadePlan",
    "GradAggMode",
    "ExchangePlan",
    "JobScheduler",
    "LaunchRequest",
    "LevelSpec",
    "OnlineController",
    "OnlineJobRequest",
    "Topology",
    "plan",
    "plan_grad_exchange",
    "run_cascade",
]
