"""The SwitchAgg controller, in-process (paper §3 "Controller", §4.1 protocol).

The paper's controller receives a Launch request (worker count), knows the
topology, builds the aggregation tree, Configures every switch (memory
partitioning per tree, child counts, forwarding ports), and Acks the master.
Our planner does the same trace-time work for a JAX mesh:

  * builds the `AggregationTree` from the mesh,
  * partitions combiner memory among concurrent jobs (paper §4.2.2 divides
    switch memory evenly among trees; the weighted policy skews it by each
    job's key variety),
  * sizes the FPE capacity from the reduction model (Eq. 3) given the
    expected key variety,
  * and emits an `ExchangePlan` the training/serving step consumes.

The multi-job layer (`JobScheduler`, DESIGN.md §3) admits N concurrent
launch requests against one shared `Topology`: every job's tree is chosen
by searching candidate level orderings against `TreeTrafficModel` plus a
shared-link congestion term (SOAR-style bounded per-level byte budget),
and jobs that would blow the scarce-link budget are escalated to the
compressed exchange with `k_fraction` sized to fit.

The paper's wire protocol (Launch / Configure / Ack / Aggregation packets,
Table 1) survives as the dataclasses below.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Sequence

from repro.net import wire
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from . import reduction_model as rm
from . import tree as tree_lib
from .collectives import GradAggMode


# --- Table 1 packet types, as planner datatypes -----------------------------


@dataclasses.dataclass(frozen=True)
class LaunchRequest:
    """<n_mappers, n_reducers, reducer_addrs, mapper_addrs> -> mesh terms."""

    job_id: int
    n_workers: int
    expected_pairs: int  # data amount M (pairs) per worker
    key_variety: int  # N
    op: str = "sum"
    # multi-job scheduling terms (DESIGN.md §3); zero/default = KV-only job
    grad_bytes: int = 0  # dense gradient bytes per exchange (0: pure KV job)
    mode: GradAggMode = GradAggMode.TREE  # requested exchange mode
    k_fraction: float = 0.01  # top-k fraction if the job compresses


@dataclasses.dataclass(frozen=True)
class ConfigureMsg:
    """<n_trees, [tree_id, n_children]> per aggregation node.

    ``level_capacities``/``level_enabled`` are the fat-tree placement
    override (DESIGN.md §9): when non-empty, level *i*'s switches run an
    FPE of exactly ``level_capacities[i]`` pairs, and a level with
    ``level_enabled[i] == False`` is a forward-only hop (its switches
    relay records unaggregated).  Empty tuples keep the legacy behavior:
    ``fpe_capacity`` is the whole tree's budget, split evenly per level
    by ``dataplane.plan_from_configure``.
    """

    tree_id: int
    level_axes: tuple[str, ...]
    fanins: tuple[int, ...]
    fpe_capacity: int  # pairs resident per node for THIS tree
    op: str
    level_capacities: tuple[int, ...] = ()  # per-level per-switch pairs
    level_enabled: tuple[bool, ...] = ()  # False = forward-only level


@dataclasses.dataclass(frozen=True)
class Ack:
    tree_id: int
    ok: bool
    detail: str = ""


@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """Everything a train/serve step needs to run the exchange."""

    mode: GradAggMode
    leaf_axis: str
    upper_axes: tuple[str, ...]
    k_fraction: float
    fpe_capacity: int
    # analytics
    predicted_root_reduction: float  # traffic cut on the scarcest level vs flat
    predicted_kv_reduction: float  # Eq. 3 prediction for the KV combine
    # multi-job analytics (DESIGN.md §3); defaults keep single-job callers total
    op: str = "sum"  # AggOp the job's dataplane cascade runs (aggops registry)
    job_id: int = -1
    fanins: tuple[int, ...] = ()  # leaf -> root, matches (leaf_axis, *upper_axes)
    level_bytes: tuple[float, ...] = ()  # modeled bytes per level, same order
    scarce_link_bytes: float = 0.0  # this job's bytes on the scarcest level
    # fat-tree placement terms (DESIGN.md §9); empty = uniform legacy knob
    level_capacities: tuple[int, ...] = ()  # per-switch pairs from placement
    level_enabled: tuple[bool, ...] = ()  # False = forward-only level
    placement_policy: str = ""  # search policy that chose the placement

    def describe(self) -> str:
        axes = (self.leaf_axis, *self.upper_axes)
        order = " -> ".join(f"{a}(x{f})" for a, f in zip(axes, self.fanins)) \
            if self.fanins else " -> ".join(axes)
        return (f"job {self.job_id}: {self.mode.value} [{order}] "
                f"k={self.k_fraction:g} fpe={self.fpe_capacity} "
                f"scarce={self.scarce_link_bytes/2**20:.2f}MiB")


class Controller:
    """Holds switch memory budget and active trees; sizes new jobs."""

    def __init__(self, combiner_budget_pairs: int = 1 << 20):
        self.budget = combiner_budget_pairs
        self.active: dict[int, ConfigureMsg] = {}

    def configure(self, req: LaunchRequest, tree: tree_lib.AggregationTree) -> ConfigureMsg:
        """Partition combiner memory evenly among active trees (paper §4.2.2)."""
        n_trees = len(self.active) + 1
        cap = max(1, self.budget // n_trees)
        msg = ConfigureMsg(
            tree_id=req.job_id,
            level_axes=tree.axes,
            fanins=tuple(l.fanin for l in tree.levels),
            fpe_capacity=cap,
            op=req.op,
        )
        # re-partition already-active trees
        self.active[req.job_id] = msg
        self.active = {
            tid: dataclasses.replace(m, fpe_capacity=max(1, self.budget // len(self.active)))
            for tid, m in self.active.items()
        }
        return self.active[req.job_id]

    def release(self, job_id: int) -> None:
        self.active.pop(job_id, None)
        if self.active:
            cap = max(1, self.budget // len(self.active))
            self.active = {
                tid: dataclasses.replace(m, fpe_capacity=cap) for tid, m in self.active.items()
            }


def plan_grad_exchange(
    mesh,
    *,
    mode: GradAggMode = GradAggMode.TREE,
    grad_bytes: int = 0,
    key_variety: int = 0,
    k_fraction: float = 0.01,
    combiner_budget_pairs: int = 1 << 20,
    reduce_axes: Sequence[str] = ("data", "pod"),
    op: str = "sum",
) -> ExchangePlan:
    """Build the exchange plan for gradient aggregation on this mesh."""
    tree = tree_lib.from_mesh(mesh, reduce_axes=reduce_axes)
    leaf = tree.levels[0].axis
    uppers = tuple(l.axis for l in tree.levels[1:])

    root_red = 0.0
    if grad_bytes and len(tree.levels) > 1:
        root_red = tree.traffic_model(grad_bytes).tree_reduction_at_root()

    kv_red = 0.0
    if key_variety:
        # data amount at the node = fanin * k pairs; Eq. 3 with C = budget
        fanin = tree.fanin
        m = max(key_variety, int(fanin * max(1, key_variety * k_fraction)))
        kv_red = rm.reduction_ratio(m, key_variety, combiner_budget_pairs)

    fanins = tuple(l.fanin for l in tree.levels)
    lvl_bytes = modeled_level_bytes(grad_bytes, fanins, mode=mode,
                                    k_fraction=k_fraction) if grad_bytes else ()
    scarce_bytes = 0.0
    if lvl_bytes:
        scarce_lvl = min(range(len(tree.levels)),
                         key=lambda i: tree.levels[i].link_gbps)
        scarce_bytes = lvl_bytes[scarce_lvl]

    return ExchangePlan(
        mode=mode,
        leaf_axis=leaf,
        upper_axes=uppers,
        k_fraction=k_fraction,
        fpe_capacity=combiner_budget_pairs,
        predicted_root_reduction=root_red,
        predicted_kv_reduction=kv_red,
        op=op,
        fanins=fanins,
        level_bytes=lvl_bytes,
        scarce_link_bytes=scarce_bytes,
    )


# ---------------------------------------------------------------------------
# Multi-job, congestion-aware scheduling (paper §3/§4.2.2; DESIGN.md §3).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkBudget:
    """One shared topology level: group size, bandwidth, byte bound.

    ``byte_budget`` is the SOAR-style per-exchange-round cap on the bytes this
    level may carry across ALL jobs; ``inf`` disables the bound.
    """

    axis: str
    fanin: int
    gbps: float
    byte_budget: float = math.inf


@dataclasses.dataclass(frozen=True)
class Topology:
    """The shared network every concurrent job's tree is placed on.

    ``links`` is canonical cheap->scarce order; candidate tree orderings are
    permutations of it.  The scarcest level is the one with minimum gbps —
    for the production mesh that is the inter-pod DCN level.
    """

    links: tuple[LinkBudget, ...]

    @property
    def axes(self) -> tuple[str, ...]:
        return tuple(l.axis for l in self.links)

    @property
    def scarce_axis(self) -> str:
        return min(self.links, key=lambda l: (l.gbps, l.axis)).axis

    def link(self, axis: str) -> LinkBudget:
        for l in self.links:
            if l.axis == axis:
                return l
        raise KeyError(axis)

    @classmethod
    def from_mesh(
        cls,
        mesh,
        *,
        reduce_axes: Sequence[str] = ("data", "pod"),
        link_gbps: dict[str, float] | None = None,
        scarce_budget_bytes: float = math.inf,
    ) -> "Topology":
        """Mirror of tree.from_mesh: absent / size-1 axes are skipped."""
        gbps = link_gbps or {"data": tree_lib.ICI_GBPS, "model": tree_lib.ICI_GBPS,
                             "pod": tree_lib.DCN_GBPS}
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        links = [
            LinkBudget(axis=ax, fanin=sizes[ax],
                       gbps=gbps.get(ax, tree_lib.ICI_GBPS))
            for ax in reduce_axes if sizes.get(ax, 1) > 1
        ]
        if not links:
            links = [LinkBudget(axis=mesh.axis_names[0], fanin=1,
                                gbps=tree_lib.ICI_GBPS)]
        topo = cls(links=tuple(links))
        return topo.with_scarce_budget(scarce_budget_bytes)

    @classmethod
    def production(cls, *, multi_pod: bool = True,
                   scarce_budget_bytes: float = math.inf) -> "Topology":
        """The 512-chip target: data=16 intra-pod ICI, pod=2 inter-pod DCN."""
        links = [LinkBudget(axis="data", fanin=16, gbps=tree_lib.ICI_GBPS)]
        if multi_pod:
            links.append(LinkBudget(axis="pod", fanin=2, gbps=tree_lib.DCN_GBPS))
        return cls(links=tuple(links)).with_scarce_budget(scarce_budget_bytes)

    def with_scarce_budget(self, byte_budget: float) -> "Topology":
        scarce = self.scarce_axis
        return Topology(links=tuple(
            dataclasses.replace(l, byte_budget=byte_budget) if l.axis == scarce
            else l for l in self.links))

    def tree_for(self, ordering: Sequence[LinkBudget]) -> tree_lib.AggregationTree:
        return tree_lib.AggregationTree(levels=tuple(
            tree_lib.TreeLevel(axis=l.axis, fanin=l.fanin, link_gbps=l.gbps)
            for l in ordering))


def modeled_level_bytes(
    grad_bytes: float,
    fanins: Sequence[int],
    *,
    mode: GradAggMode = GradAggMode.TREE,
    k_fraction: float = 0.01,
) -> tuple[float, ...]:
    """Bytes each level (leaf->root order) carries for one exchange.

    TREE matches ``TreeTrafficModel.tree_bytes_per_level``; FLAT/GATHER put
    the full ring all-reduce bytes on every level (no on-path reduction);
    TREE_COMPRESS replaces the payload above the leaf level with the top-k
    KV stream — 8 bytes (key+value) per retained 4-byte element, i.e. a
    ``2*k_fraction`` payload factor — which the bounded-memory combine keeps
    from regrowing across upper levels.
    """
    fanins = tuple(fanins)
    model = rm.TreeTrafficModel(grad_bytes=grad_bytes, fanins=fanins)
    if mode in (GradAggMode.FLAT, GradAggMode.GATHER):
        return tuple(model.flat_bytes_per_level())
    dense = model.tree_bytes_per_level()
    if mode != GradAggMode.TREE_COMPRESS or len(fanins) < 2:
        return tuple(dense)
    # leaf reduce-scatter stays exact; above it the KV payload replaces the
    # dense shard and the bounded-memory combine keeps it from regrowing
    shard = float(grad_bytes) / fanins[0]
    payload = min(shard, 2.0 * k_fraction * shard)
    out = [dense[0]]
    out.extend(2.0 * (f - 1) / f * payload for f in fanins[1:])
    return tuple(out)


def flat_scarce_bytes(grad_bytes: float, topology: Topology) -> float:
    """Scarce-level bytes of the naive flat all-reduce over every chip."""
    w = math.prod(l.fanin for l in topology.links)
    if w <= 1:
        return 0.0
    return 2.0 * (w - 1) / w * grad_bytes


def partition_memory(
    budget_pairs: int,
    requests: Sequence[LaunchRequest],
    policy: str = "even",
) -> dict[int, int]:
    """Split combiner memory among concurrent trees (paper §4.2.2).

    ``even``     — the paper's policy: budget // n_trees each.
    ``weighted`` — proportional to each job's key variety N: a job whose
                   working set is larger needs more resident pairs to hit
                   the same Eq. 3 reduction ratio (R <= C/N when N > C).
    Every job gets >= 1 pair, so partitions sum to
    <= max(budget_pairs, n_jobs); with budget_pairs >= n_jobs (every real
    configuration) they sum to <= budget_pairs.
    """
    if not requests:
        return {}
    n = len(requests)
    if policy == "even":
        cap = max(1, budget_pairs // n)
        return {r.job_id: cap for r in requests}
    if policy != "weighted":
        raise ValueError(f"unknown partition policy {policy!r}")
    weights = {r.job_id: float(max(1, r.key_variety)) for r in requests}
    total_w = sum(weights.values())
    caps = {j: max(1, int(budget_pairs * w / total_w)) for j, w in weights.items()}
    # the max(1,) floor can push the sum past the budget (skewed weights
    # flooring several jobs up); shave the largest partitions, keeping >= 1
    overflow = sum(caps.values()) - budget_pairs
    for j in sorted(caps, key=lambda j: (-caps[j], j)):
        if overflow <= 0:
            break
        take = min(overflow, caps[j] - 1)
        caps[j] -= take
        overflow -= take
    return caps


@dataclasses.dataclass(frozen=True)
class JobPlan:
    """One admitted job: its tree, switch config, and exchange plan."""

    request: LaunchRequest
    tree: tree_lib.AggregationTree
    configure: ConfigureMsg
    exchange: ExchangePlan
    bytes_by_axis: dict[str, float]
    flat_scarce_bytes: float
    over_budget: bool = False  # admitted despite exceeding the byte budget


@dataclasses.dataclass(frozen=True)
class SchedulerReport:
    """Aggregate view over every active job (the bench/dry-run report)."""

    jobs: tuple[JobPlan, ...]
    link_totals: dict[str, float]
    scarce_axis: str
    total_scarce_bytes: float
    baseline_flat_scarce_bytes: float
    max_drain_s: float  # congestion: slowest level's time to drain one round

    @property
    def scarce_traffic_cut(self) -> float:
        if self.baseline_flat_scarce_bytes <= 0:
            return 0.0
        return 1.0 - self.total_scarce_bytes / self.baseline_flat_scarce_bytes

    def summary(self) -> str:
        lines = [
            f"{len(self.jobs)} job(s); scarce axis '{self.scarce_axis}': "
            f"{self.total_scarce_bytes/2**20:.2f} MiB vs flat "
            f"{self.baseline_flat_scarce_bytes/2**20:.2f} MiB "
            f"(cut {self.scarce_traffic_cut:.1%}); "
            f"max drain {self.max_drain_s*1e3:.3f} ms"
        ]
        for jp in self.jobs:
            lines.append("  " + jp.exchange.describe()
                         + (" [over-budget]" if jp.over_budget else ""))
        return "\n".join(lines)


class JobScheduler:
    """Admit N concurrent jobs onto one topology, congestion-aware.

    For each `LaunchRequest` the scheduler searches candidate level
    orderings of the shared topology (every permutation of the link levels)
    and scores the resulting `AggregationTree` by the congestion it adds:
    the drain time of the most-loaded level given the bytes already placed
    by active jobs, tie-broken by total bytes, then by ordering.  A dense
    TREE job whose best placement still violates the scarce level's byte
    budget is escalated to TREE_COMPRESS with the largest ``k_fraction``
    that fits (halving ladder, bounded below by ``min_k_fraction``).

    Combiner memory is re-partitioned among all active trees on every
    admit/release (policy ``even`` or ``weighted``; see
    :func:`partition_memory`), so each job's `ConfigureMsg`/`ExchangePlan`
    always reflects the current tenancy — the paper's §4.2.2 behavior.

    The drain term is calibratable: the packet-level simulator
    (``net.sim``, DESIGN.md §7) runs an admitted `JobPlan` end to end and
    its measured per-axis drain factors feed back via :meth:`calibrate`,
    closing the model-vs-measurement loop.
    """

    def __init__(
        self,
        topology: Topology,
        *,
        combiner_budget_pairs: int = 1 << 20,
        partition_policy: str = "even",
        min_k_fraction: float = 1e-4,
        drain_calibration: dict[str, float] | None = None,
    ):
        self.topology = topology
        self.budget = combiner_budget_pairs
        self.partition_policy = partition_policy
        self.min_k_fraction = min_k_fraction
        self.drain_calibration = dict(drain_calibration or {})
        self.jobs: dict[int, JobPlan] = {}

    # -- load accounting ----------------------------------------------------

    def link_loads(self) -> dict[str, float]:
        loads = {l.axis: 0.0 for l in self.topology.links}
        for jp in self.jobs.values():
            for ax, b in jp.bytes_by_axis.items():
                loads[ax] += b
        return loads

    def _drain_s(self, loads: dict[str, float]) -> float:
        return max(
            (loads[l.axis] / (l.gbps * 1e9)
             * self.drain_calibration.get(l.axis, 1.0)
             for l in self.topology.links),
            default=0.0,
        )

    def calibrate(self, factors: dict[str, float]) -> None:
        """Feed measured drain time back into the congestion scoring.

        ``factors`` maps axis -> measured/modeled drain ratio — what the
        packet-level simulator reports via ``net.sim.drain_calibration``
        (headers, retransmissions, and queueing that the payload-only byte
        model cannot see).  Subsequent placement scoring and
        ``report().max_drain_s`` use the calibrated drain.
        """
        for ax, f in factors.items():
            if f <= 0:
                raise ValueError(f"calibration factor for {ax!r} must be > 0")
            self.drain_calibration[ax] = float(f)

    # -- candidate search ---------------------------------------------------

    def _score_candidates(self, req: LaunchRequest, mode: GradAggMode,
                          k_fraction: float):
        """Yield (score, ordering, bytes_by_axis) for every level ordering."""
        loads = self.link_loads()
        for perm in itertools.permutations(self.topology.links):
            fanins = tuple(l.fanin for l in perm)
            lvl = modeled_level_bytes(req.grad_bytes, fanins, mode=mode,
                                      k_fraction=k_fraction)
            by_axis = {l.axis: b for l, b in zip(perm, lvl)}
            trial = {ax: loads[ax] + by_axis.get(ax, 0.0) for ax in loads}
            feasible = all(trial[l.axis] <= l.byte_budget
                           for l in self.topology.links)
            score = (
                not feasible,  # feasible placements first
                self._drain_s(trial),  # then least congestion
                sum(by_axis.values()),  # then fewest total bytes
                tuple(l.axis for l in perm),  # then deterministic order
            )
            yield score, perm, by_axis, feasible

    def _best(self, req: LaunchRequest, mode: GradAggMode, k_fraction: float):
        return min(self._score_candidates(req, mode, k_fraction),
                   key=lambda t: t[0])

    # -- admission ----------------------------------------------------------

    def admit(self, req: LaunchRequest) -> JobPlan:
        if req.job_id in self.jobs:
            raise ValueError(f"job {req.job_id} already active")
        mode, k = req.mode, req.k_fraction
        score, perm, by_axis, feasible = self._best(req, mode, k)
        if (not feasible and req.grad_bytes
                and mode in (GradAggMode.TREE, GradAggMode.TREE_COMPRESS)):
            # congestion escalation: compress across the scarce levels,
            # walking k down a halving ladder until the placement fits
            # (jobs that already requested compression keep their mode but
            # still walk the ladder)
            mode = GradAggMode.TREE_COMPRESS
            while True:
                score, perm, by_axis, feasible = self._best(req, mode, k)
                if feasible or k <= self.min_k_fraction:
                    break
                k = max(self.min_k_fraction, k / 2.0)
        tree = self.topology.tree_for(perm)
        self.jobs[req.job_id] = self._make_plan(req, tree, by_axis, mode, k,
                                                over_budget=not feasible)
        self._repartition()
        return self.jobs[req.job_id]

    def release(self, job_id: int) -> None:
        self.jobs.pop(job_id, None)
        self._repartition()

    def plan_all(self, requests: Sequence[LaunchRequest]) -> SchedulerReport:
        """Admit a batch (largest gradient first — the placements that
        matter most pick first) and return the aggregate report."""
        for r in sorted(requests, key=lambda r: (-r.grad_bytes, r.job_id)):
            self.admit(r)
        return self.report()

    # -- plan construction --------------------------------------------------

    def _make_plan(self, req, tree, by_axis, mode, k_fraction, over_budget):
        axes = tree.axes
        fanins = tuple(l.fanin for l in tree.levels)
        lvl_bytes = tuple(by_axis[a] for a in axes)
        scarce = self.topology.scarce_axis
        flat = flat_scarce_bytes(req.grad_bytes, self.topology)
        scarce_bytes = by_axis.get(scarce, 0.0)
        root_red = 1.0 - scarce_bytes / flat if flat > 0 else 0.0
        cfg = ConfigureMsg(tree_id=req.job_id, level_axes=axes, fanins=fanins,
                           fpe_capacity=self.budget, op=req.op)
        plan = ExchangePlan(
            mode=mode, leaf_axis=axes[0], upper_axes=axes[1:],
            k_fraction=k_fraction, fpe_capacity=self.budget,
            predicted_root_reduction=root_red, predicted_kv_reduction=0.0,
            op=req.op, job_id=req.job_id, fanins=fanins,
            level_bytes=lvl_bytes, scarce_link_bytes=scarce_bytes,
        )
        return JobPlan(request=req, tree=tree, configure=cfg, exchange=plan,
                       bytes_by_axis=dict(by_axis), flat_scarce_bytes=flat,
                       over_budget=over_budget)

    def _repartition(self) -> None:
        reqs = [jp.request for jp in self.jobs.values()]
        caps = partition_memory(self.budget, reqs, self.partition_policy)
        for jid, jp in list(self.jobs.items()):
            cap = caps[jid]
            req = jp.request
            # Eq. 3 at the leaf node: data arriving = leaf fanin * per-worker
            # pairs (KV jobs) or the job's retained top-k stream (grad jobs)
            if req.expected_pairs:
                m = jp.tree.levels[0].fanin * req.expected_pairs
            else:
                m = jp.tree.levels[0].fanin * max(
                    1, int(req.grad_bytes / 4 * jp.exchange.k_fraction))
            kv_red = 0.0
            if req.key_variety:
                m = max(m, req.key_variety)
                kv_red = rm.reduction_ratio(m, req.key_variety, cap)
            self.jobs[jid] = dataclasses.replace(
                jp,
                configure=dataclasses.replace(jp.configure, fpe_capacity=cap),
                exchange=dataclasses.replace(jp.exchange, fpe_capacity=cap,
                                             predicted_kv_reduction=kv_red),
            )

    # -- reporting ----------------------------------------------------------

    def report(self) -> SchedulerReport:
        loads = self.link_loads()
        scarce = self.topology.scarce_axis
        jobs = tuple(self.jobs[j] for j in sorted(self.jobs))
        return SchedulerReport(
            jobs=jobs,
            link_totals=loads,
            scarce_axis=scarce,
            total_scarce_bytes=loads.get(scarce, 0.0),
            baseline_flat_scarce_bytes=sum(jp.flat_scarce_bytes for jp in jobs),
            max_drain_s=self._drain_s(loads),
        )


# ---------------------------------------------------------------------------
# Rack-scale fat-tree topology + aggregation-tree placement (DESIGN.md §9).
# ---------------------------------------------------------------------------

#: switch tiers, leaf -> root, and the link tier each one terminates:
#: a ToR terminates host "edge" links, a pod-aggregation switch terminates
#: ToR "aggr" uplinks, the core switch terminates per-pod "core" uplinks.
FAT_TREE_TIERS = ("tor", "agg", "core")
FAT_TREE_AXES = ("edge", "aggr", "core")
_AXIS_TIER = dict(zip(FAT_TREE_AXES, FAT_TREE_TIERS))
_TIER_AXIS = dict(zip(FAT_TREE_TIERS, FAT_TREE_AXES))


@dataclasses.dataclass(frozen=True)
class SwitchSpec:
    """One physical switch: where it sits and how much table it has."""

    name: str  # e.g. "pod0.tor1", "pod2.agg", "core"
    tier: str  # "tor" | "agg" | "core"
    pod: int  # -1 for the core switch
    table_pairs: int  # FPE pairs this switch can dedicate to one job


@dataclasses.dataclass(frozen=True)
class FatTreeTopology:
    """A k-ary-pod datacenter fat-tree the incast job must cross.

    ``pods`` pods, each with ``tors_per_pod`` racks of ``hosts_per_tor``
    mapper hosts; three link tiers, leaf -> root:

      * ``edge``  — host -> ToR,        ``hosts_per_tor`` links per ToR
                    at ``edge_gbps`` each (paper testbed: 10 GbE),
      * ``aggr``  — ToR -> pod switch,  one logical uplink per ToR at
                    ``hosts_per_tor * edge_gbps / oversubscription``,
      * ``core``  — pod -> core,        one logical uplink per pod,
                    oversubscribed again by ``core_oversubscription``.

    ``oversubscription`` is the classic downlink:uplink ratio — 1.0 is a
    non-blocking fabric, 4.0 the common datacenter 4:1.  Degenerate
    (fan-in 1) tiers are skipped everywhere, so a single-rack fat-tree
    collapses to exactly the flat single-level :class:`Topology` the
    pre-rack-scale planner used.

    ``table_pairs`` is the per-switch capability budget: how many FPE
    pairs one switch can hold for one job (0 = the switch cannot
    aggregate at all); ``tier_table_pairs`` overrides it per tier, e.g.
    ``(("core", 8192),)`` for a big-table core switch.
    """

    pods: int
    tors_per_pod: int
    hosts_per_tor: int
    edge_gbps: float = 1.25  # 10 GbE host links (net.sim.TEN_GBE)
    oversubscription: float = 4.0  # ToR downlink:uplink ratio
    core_oversubscription: float | None = None  # default: same as ToR tier
    table_pairs: int = 2048  # per-switch FPE pairs; 0 = no capability
    tier_table_pairs: tuple[tuple[str, int], ...] = ()

    def __post_init__(self):
        if min(self.pods, self.tors_per_pod, self.hosts_per_tor) < 1:
            raise ValueError("pods/tors_per_pod/hosts_per_tor must be >= 1")
        if self.edge_gbps <= 0:
            raise ValueError("edge_gbps must be > 0")
        if self.oversubscription < 1.0 or (
                self.core_oversubscription is not None
                and self.core_oversubscription < 1.0):
            raise ValueError("oversubscription is downlink:uplink, >= 1")
        if self.table_pairs < 0:
            raise ValueError("table_pairs must be >= 0")
        bad = [t for t, _ in self.tier_table_pairs if t not in FAT_TREE_TIERS]
        if bad:
            raise ValueError(f"unknown switch tier(s) {bad}")

    # -- derived geometry ---------------------------------------------------

    @property
    def n_hosts(self) -> int:
        return self.pods * self.tors_per_pod * self.hosts_per_tor

    @property
    def n_tors(self) -> int:
        return self.pods * self.tors_per_pod

    @property
    def uplink_gbps(self) -> float:
        """ToR -> pod-switch logical uplink rate (after oversubscription)."""
        return self.hosts_per_tor * self.edge_gbps / self.oversubscription

    @property
    def core_gbps(self) -> float:
        """pod -> core logical uplink rate."""
        o = (self.core_oversubscription if self.core_oversubscription
             is not None else self.oversubscription)
        return self.tors_per_pod * self.uplink_gbps / o

    def switch_table(self, tier: str) -> int:
        return dict(self.tier_table_pairs).get(tier, self.table_pairs)

    def tier_switches(self, tier: str) -> tuple[SwitchSpec, ...]:
        """Every physical switch of one tier (explicit placement targets)."""
        cap = self.switch_table(tier)
        if tier == "tor":
            return tuple(
                SwitchSpec(name=f"pod{p}.tor{t}", tier="tor", pod=p,
                           table_pairs=cap)
                for p in range(self.pods) for t in range(self.tors_per_pod))
        if tier == "agg":
            return tuple(SwitchSpec(name=f"pod{p}.agg", tier="agg", pod=p,
                                    table_pairs=cap)
                         for p in range(self.pods))
        if tier == "core":
            return (SwitchSpec(name="core", tier="core", pod=-1,
                               table_pairs=cap),)
        raise KeyError(tier)

    # -- the LinkBudget view (what the existing planner machinery consumes) -

    def link_tiers(self) -> tuple[LinkBudget, ...]:
        """Leaf->root link tiers as `LinkBudget`s, degenerate tiers skipped."""
        cand = (("edge", self.hosts_per_tor, self.edge_gbps),
                ("aggr", self.tors_per_pod, self.uplink_gbps),
                ("core", self.pods, self.core_gbps))
        links = [LinkBudget(axis=a, fanin=f, gbps=g)
                 for a, f, g in cand if f > 1]
        if not links:  # one host, one rack: keep APIs total
            links = [LinkBudget(axis="edge", fanin=1, gbps=self.edge_gbps)]
        return tuple(links)

    def to_topology(self) -> Topology:
        """The flat `Topology` view — the single-rack degenerate fat-tree is
        exactly the pre-§9 flat topology, and the JobScheduler's byte/drain
        machinery consumes fat-trees through this."""
        return Topology(links=self.link_tiers())

    def tree(self) -> tree_lib.AggregationTree:
        """Leaf->root `AggregationTree` in physical (non-permutable) order."""
        return self.to_topology().tree_for(self.link_tiers())

    @property
    def axes(self) -> tuple[str, ...]:
        return tuple(l.axis for l in self.link_tiers())

    @property
    def fanins(self) -> tuple[int, ...]:
        return tuple(l.fanin for l in self.link_tiers())

    def present_tiers(self) -> tuple[str, ...]:
        """Switch tiers that actually fan in (leaf->root)."""
        return tuple(_AXIS_TIER.get(l.axis, l.axis)
                     for l in self.link_tiers())

    def scarce_uplink_axis(self) -> str:
        """The scarcest *uplink* tier: min-gbps fabric level above the host
        ingress (ties -> the higher tier, where more reduction has had a
        chance to happen).  Host "edge" links carry raw mapper output that
        no placement can shrink, so they never count; a single-rack tree
        has no fabric uplinks and falls back to the reducer in-link."""
        links = self.link_tiers()
        ups = [(i, l) for i, l in enumerate(links) if l.axis != "edge"]
        if not ups:
            return "reducer"
        return min(ups, key=lambda t: (t[1].gbps, -t[0]))[1].axis

    def describe(self) -> str:
        links = " -> ".join(f"{l.axis}(x{l.fanin} @ {l.gbps:g} GB/s)"
                            for l in self.link_tiers())
        return (f"{self.pods} pod(s) x {self.tors_per_pod} ToR(s) x "
                f"{self.hosts_per_tor} host(s) [{links}] "
                f"oversub {self.oversubscription:g}:1")


def _node_out_pairs(m_in: float, key_variety: int, capacity: int) -> float:
    """Eq. 3 survivor stream of one bounded-memory node (0 = forward)."""
    if capacity <= 0 or m_in <= 0:
        return m_in
    n = float(max(1, min(key_variety, m_in)))
    r = rm.reduction_ratio(m_in, n, capacity)
    return m_in * (1.0 - r)


def fat_tree_tier_bytes(
    ft: FatTreeTopology,
    placed_tiers: Sequence[str],
    *,
    per_host_pairs: int,
    key_variety: int,
    pair_bytes: float | None = None,
) -> dict[str, float]:
    """Modeled wire bytes per link tier (plus the reducer in-link) for one
    incast job under a placement.

    Every mapper host emits ``per_host_pairs`` pairs; each link tier
    carries, per link, the survivor stream of the switch below it — Eq. 3
    applied hop by hop, with a placed tier's switches reducing at their
    ``table_pairs`` capacity and an unplaced tier forwarding verbatim.
    Key variety visible at a node is bounded by its inflow.
    """
    if pair_bytes is None:
        pair_bytes = float(wire.PAIR_BYTES)
    links = ft.link_tiers()
    fanins = [l.fanin for l in links]
    placed = set(placed_tiers)
    m = float(per_host_pairs)  # per-link stream entering tier i
    out: dict[str, float] = {}
    for i, l in enumerate(links):
        n_links = math.prod(fanins[i:])
        out[l.axis] = n_links * m * pair_bytes
        tier = _AXIS_TIER.get(l.axis, l.axis)
        cap = ft.switch_table(tier) if tier in placed else 0
        m = _node_out_pairs(l.fanin * m, key_variety, cap)
    out["reducer"] = m * pair_bytes
    return out


def placement_drain_s(
    ft: FatTreeTopology,
    tier_bytes: dict[str, float],
    *,
    drain_calibration: dict[str, float] | None = None,
) -> float:
    """Slowest per-link drain across the tiers (plus the reducer in-link),
    through the same calibration factors ``JobScheduler.calibrate`` feeds
    from the packet simulator (``net.sim.drain_calibration``)."""
    cal = drain_calibration or {}
    links = ft.link_tiers()
    fanins = [l.fanin for l in links]
    worst = 0.0
    for i, l in enumerate(links):
        per_link = tier_bytes.get(l.axis, 0.0) / math.prod(fanins[i:])
        worst = max(worst, per_link / (l.gbps * 1e9) * cal.get(l.axis, 1.0))
    red = tier_bytes.get("reducer", 0.0)
    worst = max(worst, red / (ft.edge_gbps * 1e9) * cal.get("reducer", 1.0))
    return worst


@dataclasses.dataclass(frozen=True)
class TreePlacement:
    """Which switches run aggregation (`dataplane.LevelState`) nodes, and
    what the byte model says that placement costs."""

    policy: str  # search policy that produced this placement
    tiers: tuple[str, ...]  # placed switch tiers, leaf->root
    switches: tuple[str, ...]  # every switch running an aggregation node
    axes: tuple[str, ...]  # link tiers, leaf->root (the tree levels)
    level_capacities: tuple[int, ...]  # per-switch FPE pairs per level
    level_enabled: tuple[bool, ...]  # False = forward-only level
    scarce_axis: str
    scarce_uplink_bytes: float  # modeled bytes on the scarce uplink tier
    tier_bytes: dict[str, float]  # per link tier + "reducer"
    total_bytes: float
    reducer_bytes: float
    max_drain_s: float

    @property
    def n_agg_switches(self) -> int:
        return len(self.switches)

    def describe(self) -> str:
        placed = "+".join(self.tiers) if self.tiers else "host-only"
        return (f"{self.policy}: [{placed}] {self.n_agg_switches} switch(es), "
                f"scarce {self.scarce_axis}="
                f"{self.scarce_uplink_bytes/2**20:.2f}MiB, "
                f"reducer {self.reducer_bytes/2**20:.2f}MiB")


#: fixed placement policies (the bench/sim comparison axes) + the searches
PLACEMENT_POLICIES = ("host_only", "tor_only", "full", "greedy",
                      "exhaustive", "auto")


def _score_tiers(ft, tiers, *, per_host_pairs, key_variety):
    """(scarce_bytes, n_agg_switches, total_bytes) + the byte map."""
    b = fat_tree_tier_bytes(ft, tiers, per_host_pairs=per_host_pairs,
                            key_variety=key_variety)
    scarce = ft.scarce_uplink_axis()
    n_sw = sum(len(ft.tier_switches(t)) for t in tiers)
    return (b[scarce], n_sw, sum(b.values())), b


def place_aggregation_tree(
    ft: FatTreeTopology,
    *,
    per_host_pairs: int,
    key_variety: int,
    policy: str = "auto",
    drain_calibration: dict[str, float] | None = None,
) -> TreePlacement:
    """Choose which switches run aggregation nodes (SOAR-style, DESIGN.md §9).

    The objective is lexicographic: minimize modeled bytes on the scarce
    uplink tier first (the bounded-capability congestion term), then the
    number of switches holding table state (deployment cost), then total
    network bytes.  Only tiers whose switches have a positive
    ``table_pairs`` budget are placeable — a budget of zero everywhere
    degrades to host-only aggregation.

    Policies: ``host_only`` / ``tor_only`` / ``full`` are the fixed
    comparison points; ``exhaustive`` scores every placeable tier subset
    (exact, small-N); ``greedy`` adds one tier at a time while the scarce
    bytes strictly improve (SOAR's marginal-benefit rule, scales to deeper
    hierarchies); ``auto`` picks exhaustive when the subset space is small.
    """
    if policy not in PLACEMENT_POLICIES:
        raise ValueError(f"unknown placement policy {policy!r}; "
                         f"choose from {PLACEMENT_POLICIES}")
    t0_wall = time.perf_counter()
    present = ft.present_tiers()
    placeable = [t for t in present if ft.switch_table(t) > 0]
    n_scored = [0]

    def score(tiers):
        n_scored[0] += 1
        return _score_tiers(ft, tiers, per_host_pairs=per_host_pairs,
                            key_variety=key_variety)

    if policy == "auto":
        policy_run = "exhaustive" if 2 ** len(placeable) <= 64 else "greedy"
    else:
        policy_run = policy

    if policy_run == "host_only":
        chosen: tuple[str, ...] = ()
    elif policy_run == "tor_only":
        chosen = tuple(t for t in placeable if t == "tor")
    elif policy_run == "full":
        chosen = tuple(placeable)
    elif policy_run == "exhaustive":
        best = None
        for r in range(len(placeable) + 1):
            for combo in itertools.combinations(placeable, r):
                s, _ = score(combo)
                key = (*s, combo)
                if best is None or key < best[0]:
                    best = (key, combo)
        chosen = best[1]
    else:  # greedy
        chosen_l: list[str] = []
        cur, _ = score(chosen_l)
        while True:
            cands = []
            for t in placeable:
                if t in chosen_l:
                    continue
                trial = sorted(chosen_l + [t], key=present.index)
                s, _ = score(trial)
                cands.append((s, tuple(trial)))
            if not cands:
                break
            s, trial = min(cands)
            if s[0] >= cur[0]:  # no strict scarce-byte improvement
                break
            chosen_l, cur = list(trial), s
        chosen = tuple(chosen_l)

    chosen = tuple(t for t in present if t in chosen)  # leaf->root order
    (scarce_b, _, total_b), tier_b = score(chosen)
    reg = obs_metrics.get_registry()
    lbl = {"policy": policy, "scarce_axis": ft.scarce_uplink_axis()}
    reg.counter("planner.placement.candidates_scored_total",
                **lbl).inc(n_scored[0])
    reg.gauge("planner.placement.scarce_uplink_bytes", **lbl).set(scarce_b)
    reg.gauge("planner.placement.total_bytes", **lbl).set(total_b)
    reg.gauge("planner.placement.n_agg_tiers", **lbl).set(len(chosen))
    for tier, b in tier_b.items():
        reg.gauge("planner.placement.tier_bytes", tier=tier, **lbl).set(b)
    obs_trace.get_tracer().add_wall_span(
        f"place_aggregation_tree[{policy}]", t0_wall, time.perf_counter(),
        cat="planner", args={"policy": policy, "scored": n_scored[0],
                             "tiers": list(chosen)})
    links = ft.link_tiers()
    caps, enabled = [], []
    for l in links:
        tier = _AXIS_TIER.get(l.axis, l.axis)
        on = tier in chosen
        caps.append(ft.switch_table(tier) if on else 0)
        enabled.append(on)
    switches = tuple(sw.name for t in chosen for sw in ft.tier_switches(t))
    return TreePlacement(
        policy=policy,
        tiers=chosen,
        switches=switches,
        axes=tuple(l.axis for l in links),
        level_capacities=tuple(caps),
        level_enabled=tuple(enabled),
        scarce_axis=ft.scarce_uplink_axis(),
        scarce_uplink_bytes=scarce_b,
        tier_bytes=tier_b,
        total_bytes=total_b,
        reducer_bytes=tier_b["reducer"],
        max_drain_s=placement_drain_s(ft, tier_b,
                                      drain_calibration=drain_calibration),
    )


def plan_fat_tree_job(
    ft: FatTreeTopology,
    req: LaunchRequest,
    *,
    policy: str = "auto",
    drain_calibration: dict[str, float] | None = None,
) -> JobPlan:
    """Admit one incast job onto the fat-tree: run the placement search and
    emit the full controller artifact set (`ConfigureMsg` with per-level
    placement capacities, `ExchangePlan`, `JobPlan`) so the packet
    simulator consumes it unchanged via ``repro.net.simulate(plan, ...)``.

    ``flat_scarce_bytes`` on the returned plan is the host-only baseline's
    scarce-uplink bytes (everything forwarded unaggregated) — the incast
    analogue of the gradient path's flat all-reduce baseline.
    """
    placement = place_aggregation_tree(
        ft, per_host_pairs=req.expected_pairs, key_variety=req.key_variety,
        policy=policy, drain_calibration=drain_calibration)
    tree = ft.tree()
    axes = tree.axes
    fanins = tuple(l.fanin for l in tree.levels)
    host = fat_tree_tier_bytes(ft, (), per_host_pairs=req.expected_pairs,
                               key_variety=req.key_variety)
    flat_scarce = host[placement.scarce_axis]
    budget = sum(placement.level_capacities)
    cfg = ConfigureMsg(
        tree_id=req.job_id, level_axes=axes, fanins=fanins,
        fpe_capacity=budget, op=req.op,
        level_capacities=placement.level_capacities,
        level_enabled=placement.level_enabled,
    )
    kv_red = 0.0
    if req.key_variety and placement.level_capacities[0] > 0:
        m = max(req.key_variety, fanins[0] * max(1, req.expected_pairs))
        kv_red = rm.reduction_ratio(m, req.key_variety,
                                    placement.level_capacities[0])
    xplan = ExchangePlan(
        mode=req.mode, leaf_axis=axes[0], upper_axes=axes[1:],
        k_fraction=req.k_fraction, fpe_capacity=budget,
        predicted_root_reduction=(
            1.0 - placement.scarce_uplink_bytes / flat_scarce
            if flat_scarce > 0 else 0.0),
        predicted_kv_reduction=kv_red,
        op=req.op, job_id=req.job_id, fanins=fanins,
        level_bytes=tuple(placement.tier_bytes[a] for a in axes),
        scarce_link_bytes=placement.scarce_uplink_bytes,
        level_capacities=placement.level_capacities,
        level_enabled=placement.level_enabled,
        placement_policy=placement.policy,
    )
    return JobPlan(request=req, tree=tree, configure=cfg, exchange=xplan,
                   bytes_by_axis={a: placement.tier_bytes[a] for a in axes},
                   flat_scarce_bytes=flat_scarce, over_budget=False)


def fat_tree_tier_bytes_with_bypass(
    ft: FatTreeTopology,
    placed_tiers: Sequence[str],
    bypass: Sequence[tuple[int, int]],
    *,
    per_host_pairs: int,
    key_variety: int,
    pair_bytes: float | None = None,
) -> dict[str, float]:
    """:func:`fat_tree_tier_bytes` generalized to per-switch streams so a
    subset of a placed tier's switches can be forward-only (``bypass`` =
    ``(level, switch)`` coordinates, the simulator's leaf->root indexing).
    A bypassed switch relays its children's streams unaggregated — the
    failure-recovery re-route (DESIGN.md §12) — so the uplink above it
    carries the unreduced subtree.  With an empty ``bypass`` this reduces
    exactly to the uniform per-link walk (the repair test pins that)."""
    if pair_bytes is None:
        pair_bytes = float(wire.PAIR_BYTES)
    links = ft.link_tiers()
    fanins = [l.fanin for l in links]
    placed = set(placed_tiers)
    dead = set((int(l), int(s)) for l, s in bypass)
    # per-link pair streams entering tier i (leaf tier: one per host)
    m = [float(per_host_pairs)] * math.prod(fanins)
    out: dict[str, float] = {}
    for i, l in enumerate(links):
        out[l.axis] = sum(m) * pair_bytes
        tier = _AXIS_TIER.get(l.axis, l.axis)
        cap = ft.switch_table(tier) if tier in placed else 0
        f = fanins[i]
        nxt = []
        for s in range(math.prod(fanins[i + 1:])):
            m_in = sum(m[s * f:(s + 1) * f])
            sw_cap = 0 if (i, s) in dead else cap
            nxt.append(_node_out_pairs(m_in, key_variety, sw_cap))
        m = nxt
    out["reducer"] = m[0] * pair_bytes
    return out


@dataclasses.dataclass(frozen=True)
class PlacementRepair:
    """A placement repaired around failed switches (DESIGN.md §12)."""

    placement: TreePlacement  # post-repair placement + byte model
    failed: tuple[tuple[int, int], ...]  # dead (level, switch) positions
    bypass: tuple[tuple[int, int], ...]  # positions now forward-only relays
    dropped_tiers: tuple[str, ...]  # tiers the repair un-placed wholesale
    degraded_axes: tuple[str, ...]  # link tiers with >=1 bypassed switch
    extra_scarce_bytes: float  # scarce-axis bytes added by the repair
    extra_reducer_bytes: float

    def describe(self) -> str:
        return (f"repair: {len(self.failed)} dead, "
                f"dropped [{'+'.join(self.dropped_tiers) or '-'}], "
                f"+{self.extra_scarce_bytes/2**20:.2f}MiB scarce")


def repair_placement(
    ft: FatTreeTopology,
    placement: TreePlacement,
    *,
    failed: Sequence[tuple[int, int]],
    per_host_pairs: int,
    key_variety: int,
    drain_calibration: dict[str, float] | None = None,
) -> PlacementRepair:
    """Incrementally re-place aggregation around dead switches.

    ``failed`` lists ``(level, switch)`` positions (leaf->root level into
    ``placement.axes``, switch index within the tier — the coordinates
    :class:`runtime.fault_tolerance.FailureVerdict` carries).  Policy:

      * a tier with *some* dead switches stays placed — the dead positions
        become forward-only relays (the simulator's aggregation bypass)
        and the byte model charges their unreduced subtrees hop by hop;
      * a tier whose *every* switch died is removed from the placeable set
        and the placement search re-runs over the survivors — the same
        ``place_aggregation_tree`` machinery, so the repair inherits the
        search policy's lexicographic objective.

    The repaired placement's byte model (``tier_bytes`` etc.) reflects the
    degraded tree, so ``extra_scarce_bytes`` is the modeled congestion
    price of the failure — what the recovery-JCT measurement should echo.
    """
    links = ft.link_tiers()
    fanins = [l.fanin for l in links]
    axes = tuple(l.axis for l in links)
    failed = tuple(sorted(set((int(l), int(s)) for l, s in failed)))
    for l, s in failed:
        if not 0 <= l < len(links):
            raise ValueError(f"failed level {l} out of range")
        if not 0 <= s < math.prod(fanins[l + 1:]):
            raise ValueError(f"failed switch ({l}, {s}) out of range")
    t0_wall = time.perf_counter()
    # tiers that lost every switch can no longer aggregate at all
    dead_tiers = []
    for i, l in enumerate(links):
        tier = _AXIS_TIER.get(l.axis, l.axis)
        n_sw = math.prod(fanins[i + 1:])
        if (tier in placement.tiers
                and sum(1 for fl, fs in failed if fl == i) >= n_sw):
            dead_tiers.append(tier)
    if dead_tiers:
        ft_search = dataclasses.replace(
            ft, tier_table_pairs=tuple(
                (t, 0) if t in dead_tiers else (t, ft.switch_table(t))
                for t in FAT_TREE_TIERS))
        base = place_aggregation_tree(
            ft_search, per_host_pairs=per_host_pairs,
            key_variety=key_variety,
            policy=placement.policy if placement.policy
            in PLACEMENT_POLICIES else "auto",
            drain_calibration=drain_calibration)
        tiers = base.tiers
    else:
        tiers = placement.tiers
    # dead positions in still-placed tiers aggregate nothing: bypass them
    bypass = tuple((l, s) for l, s in failed
                   if _AXIS_TIER.get(axes[l], axes[l]) in tiers)
    tier_b = fat_tree_tier_bytes_with_bypass(
        ft, tiers, bypass, per_host_pairs=per_host_pairs,
        key_variety=key_variety)
    scarce = ft.scarce_uplink_axis()
    dead_names = {
        ft.tier_switches(_AXIS_TIER.get(axes[l], axes[l]))[s].name
        for l, s in failed if _AXIS_TIER.get(axes[l], axes[l]) in tiers}
    caps, enabled = [], []
    for l in links:
        tier = _AXIS_TIER.get(l.axis, l.axis)
        on = tier in tiers
        caps.append(ft.switch_table(tier) if on else 0)
        enabled.append(on)
    repaired = TreePlacement(
        policy=f"repair({placement.policy})",
        tiers=tiers,
        switches=tuple(sw.name for t in tiers for sw in ft.tier_switches(t)
                       if sw.name not in dead_names),
        axes=axes,
        level_capacities=tuple(caps),
        level_enabled=tuple(enabled),
        scarce_axis=scarce,
        scarce_uplink_bytes=tier_b[scarce],
        tier_bytes=tier_b,
        total_bytes=sum(tier_b.values()),
        reducer_bytes=tier_b["reducer"],
        max_drain_s=placement_drain_s(ft, tier_b,
                                      drain_calibration=drain_calibration),
    )
    degraded = tuple(sorted({axes[l] for l, s in bypass}, key=axes.index))
    # bypass can only ADD bytes; tiny negatives are per-switch-walk
    # float noise vs the uniform pre-failure model
    extra_scarce = max(
        0.0, tier_b[scarce] - placement.tier_bytes.get(scarce, 0.0))
    extra_red = max(0.0, tier_b["reducer"] - placement.reducer_bytes)
    reg = obs_metrics.get_registry()
    lbl = {"policy": placement.policy, "scarce_axis": scarce}
    reg.counter("planner.repair.failed_switches_total", **lbl).inc(len(failed))
    reg.gauge("planner.repair.extra_scarce_bytes", **lbl).set(extra_scarce)
    reg.gauge("planner.repair.n_dropped_tiers", **lbl).set(len(dead_tiers))
    reg.gauge("planner.repair.n_bypassed", **lbl).set(len(bypass))
    obs_trace.get_tracer().add_wall_span(
        f"repair_placement[{placement.policy}]", t0_wall,
        time.perf_counter(), cat="planner",
        args={"failed": [list(p) for p in failed],
              "dropped": dead_tiers, "degraded": list(degraded)})
    return PlacementRepair(
        placement=repaired, failed=failed, bypass=bypass,
        dropped_tiers=tuple(dead_tiers), degraded_axes=degraded,
        extra_scarce_bytes=extra_scarce, extra_reducer_bytes=extra_red)


def size_fpe_capacity(key_variety: int, target_reduction: float, data_amount: int) -> int:
    """Invert Eq. 3: the capacity needed to hit a target reduction ratio."""
    if key_variety <= 0:
        return 1
    ideal = 1.0 - key_variety / max(data_amount, key_variety)
    if target_reduction >= ideal:
        return key_variety  # need to hold every key
    denom = (1.0 / key_variety - 1.0 / data_amount)
    if denom <= 0:
        return key_variety
    return max(1, math.ceil(target_reduction / denom))

def tier_batch_key(configure, level: int, *, ways: int = 4,
                   bpe: bool = True) -> tuple | None:
    """The kernel-static signature of one tier of an admitted job, or
    ``None`` when the tier issues no kernel (disabled/forwarding hop, or
    capacity-0 exact level).

    Two jobs' tiers with equal keys run in ONE batched ``tier_ingest``
    call under the vectorized simulator — the plan-derived half of the
    batcher's grouping (the shared-``NetConfig`` half — exact_stream,
    records_per_packet, value lanes — is constant across a batch run
    with one config).
    """
    from . import dataplane  # local import: dataplane is downstream

    plan = dataplane.plan_from_configure(configure, ways=ways, bpe=bpe)
    if level >= len(plan.levels):
        return None
    spec = plan.levels[level]
    if not (spec.enabled and spec.capacity > 0):
        return None
    return (spec.capacity, spec.ways, plan.op, spec.bpe)


def batch_tier_groups(job_plans, *, ways: int = 4,
                      bpe: bool = True) -> dict[int, dict[tuple, list[int]]]:
    """Predict the vectorized simulator's multi-job tier batching:
    ``{level: {tier_batch_key: [job indices]}}`` over an admitted batch.

    A batched ``repro.net.simulate`` packs, per level, each key group's
    switches into one ``tier_ingest`` dispatch, so the number of jitted
    kernel calls at a level equals the number of key groups here — the
    invariant the batching tests pin.  Jobs whose tier is kernel-free
    (``tier_batch_key`` ``None``) appear in no group.
    """
    groups: dict[int, dict[tuple, list[int]]] = {}
    for i, jp in enumerate(job_plans):
        configure = getattr(jp, "configure", jp)
        for level in range(len(configure.level_axes)):
            key = tier_batch_key(configure, level, ways=ways, bpe=bpe)
            if key is None:
                continue
            groups.setdefault(level, {}).setdefault(key, []).append(i)
    return groups
