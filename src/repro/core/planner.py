"""The SwitchAgg controller, in-process (paper §3 "Controller", §4.1 protocol).

The paper's controller receives a Launch request (worker count), knows the
topology, builds the aggregation tree, Configures every switch (memory
partitioning per tree, child counts, forwarding ports), and Acks the master.
Our planner does the same trace-time work for a JAX mesh:

  * builds the `AggregationTree` from the mesh,
  * partitions combiner memory among concurrent jobs (paper §4.2.2 divides
    switch memory evenly among trees),
  * sizes the FPE capacity from the reduction model (Eq. 3) given the
    expected key variety,
  * and emits an `ExchangePlan` the training/serving step consumes.

The paper's wire protocol (Launch / Configure / Ack / Aggregation packets,
Table 1) survives as the dataclasses below.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from . import reduction_model as rm
from . import tree as tree_lib
from .collectives import GradAggMode


# --- Table 1 packet types, as planner datatypes -----------------------------


@dataclasses.dataclass(frozen=True)
class LaunchRequest:
    """<n_mappers, n_reducers, reducer_addrs, mapper_addrs> -> mesh terms."""

    job_id: int
    n_workers: int
    expected_pairs: int  # data amount M (pairs) per worker
    key_variety: int  # N
    op: str = "sum"


@dataclasses.dataclass(frozen=True)
class ConfigureMsg:
    """<n_trees, [tree_id, n_children]> per aggregation node."""

    tree_id: int
    level_axes: tuple[str, ...]
    fanins: tuple[int, ...]
    fpe_capacity: int  # pairs resident per node for THIS tree
    op: str


@dataclasses.dataclass(frozen=True)
class Ack:
    tree_id: int
    ok: bool
    detail: str = ""


@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """Everything a train/serve step needs to run the exchange."""

    mode: GradAggMode
    leaf_axis: str
    upper_axes: tuple[str, ...]
    k_fraction: float
    fpe_capacity: int
    # analytics
    predicted_root_reduction: float  # traffic cut on the scarcest level vs flat
    predicted_kv_reduction: float  # Eq. 3 prediction for the KV combine


class Controller:
    """Holds switch memory budget and active trees; sizes new jobs."""

    def __init__(self, combiner_budget_pairs: int = 1 << 20):
        self.budget = combiner_budget_pairs
        self.active: dict[int, ConfigureMsg] = {}

    def configure(self, req: LaunchRequest, tree: tree_lib.AggregationTree) -> ConfigureMsg:
        """Partition combiner memory evenly among active trees (paper §4.2.2)."""
        n_trees = len(self.active) + 1
        cap = max(1, self.budget // n_trees)
        msg = ConfigureMsg(
            tree_id=req.job_id,
            level_axes=tree.axes,
            fanins=tuple(l.fanin for l in tree.levels),
            fpe_capacity=cap,
            op=req.op,
        )
        # re-partition already-active trees
        self.active[req.job_id] = msg
        self.active = {
            tid: dataclasses.replace(m, fpe_capacity=max(1, self.budget // len(self.active)))
            for tid, m in self.active.items()
        }
        return self.active[req.job_id]

    def release(self, job_id: int) -> None:
        self.active.pop(job_id, None)
        if self.active:
            cap = max(1, self.budget // len(self.active))
            self.active = {
                tid: dataclasses.replace(m, fpe_capacity=cap) for tid, m in self.active.items()
            }


def plan_grad_exchange(
    mesh,
    *,
    mode: GradAggMode = GradAggMode.TREE,
    grad_bytes: int = 0,
    key_variety: int = 0,
    k_fraction: float = 0.01,
    combiner_budget_pairs: int = 1 << 20,
    reduce_axes: Sequence[str] = ("data", "pod"),
) -> ExchangePlan:
    """Build the exchange plan for gradient aggregation on this mesh."""
    tree = tree_lib.from_mesh(mesh, reduce_axes=reduce_axes)
    leaf = tree.levels[0].axis
    uppers = tuple(l.axis for l in tree.levels[1:])

    root_red = 0.0
    if grad_bytes and len(tree.levels) > 1:
        root_red = tree.traffic_model(grad_bytes).tree_reduction_at_root()

    kv_red = 0.0
    if key_variety:
        # data amount at the node = fanin * k pairs; Eq. 3 with C = budget
        fanin = tree.fanin
        m = max(key_variety, int(fanin * max(1, key_variety * k_fraction)))
        kv_red = rm.reduction_ratio(m, key_variety, combiner_budget_pairs)

    return ExchangePlan(
        mode=mode,
        leaf_axis=leaf,
        upper_axes=uppers,
        k_fraction=k_fraction,
        fpe_capacity=combiner_budget_pairs,
        predicted_root_reduction=root_red,
        predicted_kv_reduction=kv_red,
    )


def size_fpe_capacity(key_variety: int, target_reduction: float, data_amount: int) -> int:
    """Invert Eq. 3: the capacity needed to hit a target reduction ratio."""
    if key_variety <= 0:
        return 1
    ideal = 1.0 - key_variety / max(data_amount, key_variety)
    if target_reduction >= ideal:
        return key_variety  # need to hold every key
    denom = (1.0 / key_variety - 1.0 / data_amount)
    if denom <= 0:
        return key_variety
    return max(1, math.ceil(target_reduction / denom))
