from .fault_tolerance import StragglerMonitor, TrainLoop, TrainLoopConfig

__all__ = ["TrainLoop", "TrainLoopConfig", "StragglerMonitor"]
