"""Fault-tolerant training loop: checkpoint/restart, stragglers, elasticity.

At 1000+-node scale the assumptions are: (a) some host WILL fail during a
run — recovery must be automatic and cheap; (b) some host WILL be slow —
detection must be online; (c) the replacement pool may be smaller — the
job must restart on a different mesh.

Realization here (single-process container, same control flow as multi-host):

  * **checkpoint/restart** — atomic async checkpoints every ``ckpt_every``
    steps (checkpoint.manager); on construction the loop auto-resumes from
    the newest valid checkpoint; the data pipeline is a pure function of
    the step index, so restarts replay identical batches.
  * **straggler mitigation** — per-step wall-time EWMA; a step slower than
    ``straggler_factor`` x EWMA is logged as a straggler event.  On a real
    pod this signal gates the synchronous collective (drop-and-continue or
    backup-instance dispatch); here the monitor additionally supports an
    injectable delay hook so tests can fault-inject.
  * **elastic scaling** — checkpoints are mesh-agnostic full arrays; the
    restore path re-applies whatever shardings the *new* mesh dictates
    (tests restart a 4-way job on 2 devices and continue bit-exactly).
  * **crash consistency** — the manager writes tmp+rename with checksums;
    a checkpoint truncated by a crash is detected and the previous one is
    used (tested by corrupting files).
"""

from __future__ import annotations

import dataclasses
import logging
import statistics
import time
from typing import Any, Callable, Optional, Sequence

import jax
import numpy as np

from repro.checkpoint import CheckpointCorruptError, CheckpointManager

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    ckpt_keep: int = 3
    ckpt_async: bool = True
    straggler_factor: float = 3.0
    straggler_window: float = 0.9  # EWMA decay
    log_every: int = 10


@dataclasses.dataclass(frozen=True)
class StragglerInjector:
    """Deterministic fault-injection delays, keyed by an integer index.

    One injector serves both clocks: as a ``TrainLoop`` ``delay_hook`` the
    index is the step; as a sim ``JobSpec``'s ``mapper_delay`` the
    index is the mapper rank — so the same injected slowdown that trips the
    :class:`StragglerMonitor` in the training loop shows up as JCT tail
    inflation in the packet-level simulator (DESIGN.md §7).
    """

    delays: dict[int, float]
    default_s: float = 0.0

    def __call__(self, idx: int) -> float:
        return float(self.delays.get(int(idx), self.default_s))


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One scheduled device/link failure at a simulated instant.

    Coordinates are the packet simulator's: ``level`` is the switch tier
    leaf->root (0 = the tier fed by mappers), ``switch`` the switch index
    within that tier, ``child`` (for ``link_down``) the child-edge index
    under that switch.  ``t_s`` is *absolute* simulated time: the event
    fires in whichever restart epoch's timeline first reaches it, which
    is what makes a schedule replayable regardless of how many restarts
    precede it (DESIGN.md §12).
    """

    kind: str  # "switch_crash" | "link_down" | "table_wipe"
    t_s: float
    level: int
    switch: int
    child: int | None = None  # link_down only; None = every child edge
    duration_s: float = 0.0  # link_down window length

    KINDS = ("switch_crash", "link_down", "table_wipe")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown failure kind {self.kind!r}; "
                             f"choose from {self.KINDS}")
        if self.t_s < 0 or self.duration_s < 0:
            raise ValueError("failure times/durations must be >= 0")
        if self.kind == "link_down" and self.duration_s <= 0:
            raise ValueError("link_down needs a positive duration_s")


@dataclasses.dataclass(frozen=True)
class FailureInjector(StragglerInjector):
    """Deterministic, replayable failure schedule (DESIGN.md §12).

    Generalizes :class:`StragglerInjector`: the inherited ``delays`` map
    still serves as a ``mapper_delay`` / ``delay_hook`` (per-index start
    delays), and ``events`` adds device/link failures at simulated times
    — switch crashes (table state lost, position dead until repaired
    around), transient link-down windows, and table-memory wipes (state
    lost, switch survives).  The schedule is plain data: replaying the
    same injector over the same job reproduces the same verdicts,
    epochs, and delivered table bit for bit.

    ``from_seed`` derives a schedule from a PRNG seed so property tests
    and benches can sweep failure counts without hand-writing events;
    the draw is a pure function of the seed (``numpy`` Generator), never
    of wall clock.
    """

    events: tuple[FailureEvent, ...] = ()

    def events_for(self, level: int, switch: int) -> tuple[FailureEvent, ...]:
        return tuple(e for e in self.events
                     if e.level == level and e.switch == switch)

    @property
    def n_events(self) -> int:
        return len(self.events)

    @classmethod
    def from_seed(
        cls,
        seed: int,
        *,
        n_events: int,
        fanins: Sequence[int],
        t_max_s: float,
        kinds: Sequence[str] = FailureEvent.KINDS,
        down_s: float = 0.0,
        delays: dict[int, float] | None = None,
    ) -> "FailureInjector":
        """A seeded random schedule over a ``fanins`` tree: each event
        picks a kind, a tier, a switch in it, a child edge, and a fire
        time in ``[0, t_max_s)``.  ``down_s`` scales link-down windows
        (default: ``t_max_s / 4``)."""
        rng = np.random.default_rng(seed)
        fanins = tuple(int(f) for f in fanins)
        n_levels = len(fanins)
        if down_s <= 0:
            down_s = t_max_s / 4.0
        events = []
        for _ in range(int(n_events)):
            kind = str(rng.choice(list(kinds)))
            level = int(rng.integers(n_levels))
            n_switches = int(np.prod(fanins[level + 1:], dtype=np.int64))
            switch = int(rng.integers(n_switches))
            child = int(rng.integers(fanins[level]))
            events.append(FailureEvent(
                kind=kind, t_s=float(rng.uniform(0.0, t_max_s)),
                level=level, switch=switch,
                child=child if kind == "link_down" else None,
                duration_s=float(rng.uniform(0.5, 1.5) * down_s)
                if kind == "link_down" else 0.0))
        return cls(delays=dict(delays or {}),
                   events=tuple(sorted(events, key=lambda e: e.t_s)))


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Detection and restart knobs of the failure-recovery runtime.

    Detection is timeout-driven (DESIGN.md §12): on an edge with an
    active fault the sender's RTO backs off ``backoff``x per consecutive
    no-progress timeout (capped at ``max_timeout_s``) and after
    ``max_timeouts`` of them the peer is declared dead; a parent whose
    child stream was cut without end-of-task declares the child dead
    ``liveness_timeout_s`` after its last arrival (default: derived from
    the link's conservative RTO).  ``restart_delay_s`` is the control
    plane's pause between a verdict and the next epoch's mappers
    replaying; ``max_epochs`` bounds the restart cascade (a schedule
    that keeps killing switches cannot loop forever).
    """

    backoff: float = 2.0
    max_timeouts: int = 5
    max_timeout_s: float | None = None
    liveness_timeout_s: float | None = None
    restart_delay_s: float = 0.0
    max_epochs: int = 8

    def __post_init__(self):
        if self.backoff < 1.0:
            raise ValueError("backoff must be >= 1")
        if self.max_timeouts < 1:
            raise ValueError("max_timeouts must be >= 1")
        if self.max_epochs < 1:
            raise ValueError("max_epochs must be >= 1")


@dataclasses.dataclass(frozen=True)
class FailureVerdict:
    """One detected failure: who died, when the runtime knew, and how.

    ``t_detect_s`` is absolute simulated time; ``detected_by`` is
    ``"sender"`` (a child's retry budget ran dry — transport's
    ``PeerDeadError``), ``"parent"`` (liveness timeout on an EoT-less
    truncated uplink), or ``"self"`` (a table wipe is locally visible
    the instant it happens).
    """

    kind: str  # FailureEvent kind (or "link_down" false-positive crash)
    level: int
    switch: int
    epoch: int  # the epoch that died
    t_detect_s: float
    detected_by: str  # "sender" | "parent" | "self"


class StragglerMonitor:
    """Online per-step latency EWMA with outlier detection.

    The first ``warmup`` observations are buffered and the EWMA is seeded
    from their *median* once the window fills.  Seeding from the first
    observation alone would bake the step-0 jit compile time into the
    baseline — a 10x-slow first step then masks real stragglers until the
    decay washes it out, many steps later (the regression test pins this).
    """

    def __init__(self, factor: float = 3.0, decay: float = 0.9, warmup: int = 3):
        self.factor = factor
        self.decay = decay
        self.warmup = max(1, warmup)
        self.ewma: Optional[float] = None
        self.events: list[tuple[int, float, float]] = []  # (step, t, ewma)
        self._seen = 0
        self._warmup_dts: list[float] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        self._seen += 1
        if self._seen <= self.warmup:
            self._warmup_dts.append(dt)
            if self._seen == self.warmup:
                self.ewma = statistics.median(self._warmup_dts)
            return False
        flagged = dt > self.factor * self.ewma
        if flagged:
            self.events.append((step, dt, self.ewma))
            log.warning("straggler: step %d took %.3fs (EWMA %.3fs)", step, dt, self.ewma)
        else:
            # stragglers don't poison the EWMA
            self.ewma = self.decay * self.ewma + (1 - self.decay) * dt
        return flagged


class TrainLoop:
    """Drives (step_fn, state) with checkpointing + monitoring.

    ``state`` is any pytree (params, opt_state, ...); ``step_fn(state,
    batch, step) -> (state, metrics)``.  ``batch_fn(step)`` must be pure in
    the step index (restart reproducibility).
    """

    def __init__(
        self,
        cfg: TrainLoopConfig,
        step_fn: Callable,
        batch_fn: Callable[[int], Any],
        state: Any,
        *,
        delay_hook: Optional[Callable[[int], float]] = None,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.state = state
        self.monitor = StragglerMonitor(cfg.straggler_factor, cfg.straggler_window)
        self.manager = CheckpointManager(cfg.ckpt_dir, keep=cfg.ckpt_keep)
        self.start_step = 0
        self.metrics_history: list[dict] = []
        self._delay_hook = delay_hook
        self._maybe_resume()

    def _maybe_resume(self):
        step = None
        while True:
            step = self.manager.latest_step()
            if step is None:
                return
            try:
                self.state, manifest = self.manager.restore(self.state, step)
                self.start_step = manifest["step"] + 1
                log.info("resumed from checkpoint step %d", manifest["step"])
                return
            except CheckpointCorruptError as e:
                # VERIFIED corruption (checksum/format mismatch from the
                # manager): this checkpoint can never restore — drop it
                # and fall back to the previous one
                log.warning("checkpoint step %d corrupt (%s); trying previous", step, e)
                import shutil, os

                shutil.rmtree(
                    os.path.join(self.cfg.ckpt_dir, f"step_{step:08d}"), ignore_errors=True
                )
            except Exception as e:
                # anything else — a transient OSError, a mesh/shape
                # mismatch (KeyError/ValueError from unflatten_like) — may
                # be recoverable or operator error; deleting the
                # checkpoint would destroy good state, so surface it
                log.error(
                    "checkpoint step %d failed to restore (%s: %s); "
                    "not deleting — fix the environment or remove the "
                    "checkpoint manually", step, type(e).__name__, e)
                raise

    def run(self, until: Optional[int] = None) -> Any:
        end = min(until or self.cfg.total_steps, self.cfg.total_steps)
        for step in range(self.start_step, end):
            batch = self.batch_fn(step)
            t0 = time.monotonic()
            if self._delay_hook is not None:
                extra = self._delay_hook(step)
                if extra:
                    time.sleep(extra)
            self.state, metrics = self.step_fn(self.state, batch, step)
            jax.block_until_ready(jax.tree.leaves(self.state)[0])
            dt = time.monotonic() - t0
            self.monitor.observe(step, dt)
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            metrics["step_time_s"] = dt
            self.metrics_history.append({"step": step, **metrics})
            if step % self.cfg.log_every == 0:
                log.info("step %d: %s", step, metrics)
            if (step + 1) % self.cfg.ckpt_every == 0 or step + 1 == end:
                self.manager.save(step, self.state, blocking=not self.cfg.ckpt_async)
        self.manager.wait()
        self.start_step = end
        return self.state
