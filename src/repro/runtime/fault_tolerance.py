"""Fault-tolerant training loop: checkpoint/restart, stragglers, elasticity.

At 1000+-node scale the assumptions are: (a) some host WILL fail during a
run — recovery must be automatic and cheap; (b) some host WILL be slow —
detection must be online; (c) the replacement pool may be smaller — the
job must restart on a different mesh.

Realization here (single-process container, same control flow as multi-host):

  * **checkpoint/restart** — atomic async checkpoints every ``ckpt_every``
    steps (checkpoint.manager); on construction the loop auto-resumes from
    the newest valid checkpoint; the data pipeline is a pure function of
    the step index, so restarts replay identical batches.
  * **straggler mitigation** — per-step wall-time EWMA; a step slower than
    ``straggler_factor`` x EWMA is logged as a straggler event.  On a real
    pod this signal gates the synchronous collective (drop-and-continue or
    backup-instance dispatch); here the monitor additionally supports an
    injectable delay hook so tests can fault-inject.
  * **elastic scaling** — checkpoints are mesh-agnostic full arrays; the
    restore path re-applies whatever shardings the *new* mesh dictates
    (tests restart a 4-way job on 2 devices and continue bit-exactly).
  * **crash consistency** — the manager writes tmp+rename with checksums;
    a checkpoint truncated by a crash is detected and the previous one is
    used (tested by corrupting files).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    ckpt_keep: int = 3
    ckpt_async: bool = True
    straggler_factor: float = 3.0
    straggler_window: float = 0.9  # EWMA decay
    log_every: int = 10


@dataclasses.dataclass(frozen=True)
class StragglerInjector:
    """Deterministic fault-injection delays, keyed by an integer index.

    One injector serves both clocks: as a ``TrainLoop`` ``delay_hook`` the
    index is the step; as ``net.sim.simulate_job``'s ``mapper_delay`` the
    index is the mapper rank — so the same injected slowdown that trips the
    :class:`StragglerMonitor` in the training loop shows up as JCT tail
    inflation in the packet-level simulator (DESIGN.md §7).
    """

    delays: dict[int, float]
    default_s: float = 0.0

    def __call__(self, idx: int) -> float:
        return float(self.delays.get(int(idx), self.default_s))


class StragglerMonitor:
    """Online per-step latency EWMA with outlier detection."""

    def __init__(self, factor: float = 3.0, decay: float = 0.9, warmup: int = 3):
        self.factor = factor
        self.decay = decay
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.events: list[tuple[int, float, float]] = []  # (step, t, ewma)
        self._seen = 0

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        self._seen += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        flagged = self._seen > self.warmup and dt > self.factor * self.ewma
        if flagged:
            self.events.append((step, dt, self.ewma))
            log.warning("straggler: step %d took %.3fs (EWMA %.3fs)", step, dt, self.ewma)
        else:
            # stragglers don't poison the EWMA
            self.ewma = self.decay * self.ewma + (1 - self.decay) * dt
        return flagged


class TrainLoop:
    """Drives (step_fn, state) with checkpointing + monitoring.

    ``state`` is any pytree (params, opt_state, ...); ``step_fn(state,
    batch, step) -> (state, metrics)``.  ``batch_fn(step)`` must be pure in
    the step index (restart reproducibility).
    """

    def __init__(
        self,
        cfg: TrainLoopConfig,
        step_fn: Callable,
        batch_fn: Callable[[int], Any],
        state: Any,
        *,
        delay_hook: Optional[Callable[[int], float]] = None,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.state = state
        self.monitor = StragglerMonitor(cfg.straggler_factor, cfg.straggler_window)
        self.manager = CheckpointManager(cfg.ckpt_dir, keep=cfg.ckpt_keep)
        self.start_step = 0
        self.metrics_history: list[dict] = []
        self._delay_hook = delay_hook
        self._maybe_resume()

    def _maybe_resume(self):
        step = None
        while True:
            step = self.manager.latest_step()
            if step is None:
                return
            try:
                self.state, manifest = self.manager.restore(self.state, step)
                self.start_step = manifest["step"] + 1
                log.info("resumed from checkpoint step %d", manifest["step"])
                return
            except Exception as e:  # corrupt checkpoint -> try the previous
                log.warning("checkpoint step %d unusable (%s); trying previous", step, e)
                import shutil, os

                shutil.rmtree(
                    os.path.join(self.cfg.ckpt_dir, f"step_{step:08d}"), ignore_errors=True
                )

    def run(self, until: Optional[int] = None) -> Any:
        end = min(until or self.cfg.total_steps, self.cfg.total_steps)
        for step in range(self.start_step, end):
            batch = self.batch_fn(step)
            t0 = time.monotonic()
            if self._delay_hook is not None:
                extra = self._delay_hook(step)
                if extra:
                    time.sleep(extra)
            self.state, metrics = self.step_fn(self.state, batch, step)
            jax.block_until_ready(jax.tree.leaves(self.state)[0])
            dt = time.monotonic() - t0
            self.monitor.observe(step, dt)
            metrics = {k: float(np.asarray(v)) for k, v in metrics.items()}
            metrics["step_time_s"] = dt
            self.metrics_history.append({"step": step, **metrics})
            if step % self.cfg.log_every == 0:
                log.info("step %d: %s", step, metrics)
            if (step + 1) % self.cfg.ckpt_every == 0 or step + 1 == end:
                self.manager.save(step, self.state, blocking=not self.cfg.ckpt_async)
        self.manager.wait()
        self.start_step = end
        return self.state
