"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (MHA) d_ff=6144 vocab=2048 [arXiv:2306.05284; hf]
The EnCodec frontend is a STUB per the brief: input_specs() provides
precomputed frame embeddings; the backbone decodes codebook tokens.
Adaptation: MusicGen uses LayerNorm + sinusoidal embeddings; the framework
applies RMSNorm + RoPE uniformly (noted for fidelity).
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        n_layers=48,
        d_model=1536,
        n_heads=24,
        n_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        pattern=(LayerSpec("attn"),),
        tie_embeddings=False,
        act="gelu",
        frontend="audio_stub",
        source="arXiv:2306.05284",
    )
