"""gemma3-4b [dense] — 5:1 local:global attention, 128k context, qk-norm.

34L d_model=2560 8H (GQA kv=4) head_dim=256 d_ff=10240 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]
34 = 4 leading local layers + 5 x (5 local + 1 global).
"""
from .base import LayerSpec, ModelConfig

_L = LayerSpec("attn_local")
_G = LayerSpec("attn")


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        prefix=(_L, _L, _L, _L),
        pattern=(_L, _L, _L, _L, _L, _G),  # 5 groups
        window=1024,
        qk_norm=True,
        rope_theta=1_000_000.0,
        post_norms=True,
        scale_embeddings=True,
        tie_embeddings=True,
        act="gelu",
        source="hf:google/gemma-3-1b-pt (scaled per brief); unverified",
    )
