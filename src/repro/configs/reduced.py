"""Reduced (smoke-test scale) configs — same family/feature set, tiny dims.

Every assigned architecture gets a CPU-runnable miniature preserving its
distinguishing structure: layer pattern (local:global ratios, hybrid
interleave, dense prefix), GQA grouping, MoE routing (fewer/smaller
experts), MLA latents, SSD state, modality stubs, softcaps, qk-norm.
Full configs are exercised only via the dry-run (ShapeDtypeStruct).
"""

from __future__ import annotations

import dataclasses

from . import get_config
from .base import MLAConfig, MambaConfig, ModelConfig, MoEConfig


def reduced_config(arch_id: str, *, vocab: int = 512) -> ModelConfig:
    """Miniature of ``arch_id`` preserving the family's structure."""
    full = get_config(arch_id)
    # one pattern repetition x 2 groups (keeps heterogeneous stacks honest)
    n_layers = len(full.prefix) + 2 * len(full.pattern)
    overrides: dict = dict(
        name=full.name + "-reduced",
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(4, max(1, full.n_kv_heads * 4 // max(full.n_heads, 1))),
        head_dim=16,
        d_ff=128,
        vocab_size=vocab,
        vocab_pad_multiple=64,
        window=min(full.window, 16) if full.window else 0,
        attn_scale=None,
        prefix_tokens=8 if full.frontend == "vision_stub" else 0,
    )
    if full.moe is not None:
        overrides["moe"] = MoEConfig(
            n_experts=8,
            top_k=min(full.moe.top_k, 2),
            d_ff_expert=32,
            n_shared=min(full.moe.n_shared, 1),
            capacity_factor=full.moe.capacity_factor,
        )
    if full.mla is not None:
        overrides["mla"] = MLAConfig(
            kv_lora_rank=32, q_lora_rank=48, qk_nope_dim=16, qk_rope_dim=8,
            v_head_dim=16,
        )
    if full.mamba is not None:
        overrides["mamba"] = MambaConfig(
            d_state=16, head_dim=16, expand=2, conv_width=4, chunk=8,
            n_groups=1,
        )
    return dataclasses.replace(full, **overrides)
