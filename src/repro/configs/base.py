"""Model/architecture configuration schema.

One ``ModelConfig`` describes any of the assigned architectures: dense,
MoE, SSM, hybrid, VLM/audio-backbone.  The layer stack is a repeating
``pattern`` of ``LayerSpec``s (scanned over groups for compile-time
boundedness), optionally preceded by ``prefix`` layers (e.g. DeepSeek's
dense first layer).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal, Optional

Mixer = Literal["attn", "attn_local", "mamba", "none"]
Ffn = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer = "attn"
    ffn: Ffn = "dense"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # shared (always-on) experts, DeepSeek-style
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    """Mamba2 (SSD) mixer."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1  # B/C groups


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    prefix: tuple[LayerSpec, ...] = ()  # non-repeating leading layers
    # attention features
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attn_softcap: float = 0.0  # gemma2: 50.0
    logit_softcap: float = 0.0  # gemma2: 30.0
    window: int = 0  # sliding window for attn_local layers
    attn_scale: float | None = None  # override 1/sqrt(head_dim)
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # embeddings / head
    tie_embeddings: bool = True
    scale_embeddings: bool = False  # gemma: * sqrt(d_model)
    vocab_pad_multiple: int = 256  # pad vocab so it shards over the mesh
    act: str = "silu"
    norm_eps: float = 1e-6
    post_norms: bool = False  # gemma2: post-attn/post-ffn RMSNorms
    # modality frontend (stub per brief): embeddings arrive precomputed
    frontend: str = "text"  # text | vision_stub | audio_stub
    prefix_tokens: int = 0  # vision patches prepended (paligemma: 256)
    # numerics
    dtype: str = "bfloat16"
    # citation / provenance
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.n_layers % max(len(self.pattern), 1) and not self.prefix:
            n_rep = self.n_layers - len(self.prefix)
            if n_rep % len(self.pattern):
                raise ValueError(
                    f"{self.name}: {self.n_layers} layers not divisible by "
                    f"pattern of {len(self.pattern)}"
                )

    @property
    def n_groups(self) -> int:
        return (self.n_layers - len(self.prefix)) // len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner_mamba(self) -> int:
        assert self.mamba is not None
        return self.mamba.expand * self.d_model

    @property
    def n_mamba_heads(self) -> int:
        assert self.mamba is not None
        return self.d_inner_mamba // self.mamba.head_dim

    # -- parameter counting (for roofline MODEL_FLOPS = 6 N D) -------------
    def param_count(self, active_only: bool = False) -> int:
        """Exact parameter count of this config (embeddings included)."""
        d, hd = self.d_model, self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                q = d * m.q_lora_rank + m.q_lora_rank * n_q * (m.qk_nope_dim + m.qk_rope_dim)
                kv = d * (m.kv_lora_rank + m.qk_rope_dim)
                kv += m.kv_lora_rank * n_q * (m.qk_nope_dim + m.v_head_dim)
                o = n_q * m.v_head_dim * d
                return q + kv + o
            return d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d

        def dense_ffn(dff: int) -> int:
            return 3 * d * dff  # gated (gate, up, down)

        def moe_ffn() -> tuple[int, int]:
            assert self.moe is not None
            mo = self.moe
            routed = mo.n_experts * 3 * d * mo.d_ff_expert + d * mo.n_experts
            shared = mo.n_shared * 3 * d * mo.d_ff_expert
            active = (mo.top_k + mo.n_shared) * 3 * d * mo.d_ff_expert + d * mo.n_experts
            return routed + shared, active + shared * 0

        def mamba_params() -> int:
            assert self.mamba is not None
            mc = self.mamba
            din = self.d_inner_mamba
            nheads = self.n_mamba_heads
            conv_dim = din + 2 * mc.n_groups * mc.d_state
            p = d * (2 * din + 2 * mc.n_groups * mc.d_state + nheads)  # in_proj
            p += conv_dim * mc.conv_width  # conv1d
            p += 3 * nheads  # A_log, D, dt_bias
            p += din  # gated norm
            p += din * d  # out_proj
            return p

        total = 0
        layers = list(self.prefix) + list(self.pattern) * self.n_groups
        for spec in layers:
            if spec.mixer in ("attn", "attn_local"):
                total += attn_params()
            elif spec.mixer == "mamba":
                total += mamba_params()
            total += 2 * d  # pre-norms (mixer + ffn)
            if self.post_norms:
                total += 2 * d
            if spec.ffn == "dense":
                total += dense_ffn(self.d_ff)
            elif spec.ffn == "moe":
                full, act = moe_ffn()
                total += act if active_only else full
        total += d  # final norm
        total += self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        return total

    def active_param_count(self) -> int:
        return self.param_count(active_only=True)


# ---------------------------------------------------------------------------
# Input shapes (assigned to every architecture).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> InputShape:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
