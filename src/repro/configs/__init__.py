"""Architecture registry: --arch <id> resolves here."""

from . import base
from .base import ALL_SHAPES, InputShape, ModelConfig, shape_by_name

_MODULES = {
    "gemma2-27b": "gemma2_27b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "gemma3-4b": "gemma3_4b",
    "qwen3-32b": "qwen3_32b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "paligemma-3b": "paligemma_3b",
    "mamba2-780m": "mamba2_780m",
    "musicgen-medium": "musicgen_medium",
}

ARCH_IDS = tuple(_MODULES)

# archs whose long_500k cell runs (sub-quadratic / windowed); the rest are
# pure full-attention and skip it per the brief (DESIGN.md §5)
LONG_CONTEXT_ARCHS = ("mamba2-780m", "jamba-1.5-large-398b", "gemma2-27b", "gemma3-4b")


def get_config(arch_id: str) -> ModelConfig:
    import importlib

    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.get_config()


def cells(include_skipped: bool = False):
    """All (arch, shape) cells; 40 nominal, 34 live."""
    out = []
    for a in ARCH_IDS:
        for s in ALL_SHAPES:
            live = s.name != "long_500k" or a in LONG_CONTEXT_ARCHS
            if live or include_skipped:
                out.append((a, s, live))
    return out
