"""paligemma-3b [vlm] — SigLIP frontend (stub) + gemma decoder.

18L d_model=2048 8H (MQA kv=1) head_dim=256 d_ff=16384 vocab=257216
[arXiv:2407.07726; hf]
The SigLIP tower is a STUB per the brief: input_specs() provides 256
precomputed patch embeddings prepended to the text sequence.
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        pattern=(LayerSpec("attn"),),
        scale_embeddings=True,
        tie_embeddings=True,
        act="gelu",
        frontend="vision_stub",
        prefix_tokens=256,
        source="arXiv:2407.07726",
    )
