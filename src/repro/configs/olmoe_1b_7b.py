"""olmoe-1b-7b [moe] — 64 experts top-8, qk-norm.

16L d_model=2048 16H (MHA) d_ff(expert)=1024 vocab=50304 [arXiv:2409.02060; hf]
"""
from .base import LayerSpec, MoEConfig, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50304,
        pattern=(LayerSpec("attn", "moe"),),
        moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
        qk_norm=True,
        tie_embeddings=False,
        act="silu",
        source="arXiv:2409.02060",
    )
