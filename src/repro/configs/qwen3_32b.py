"""qwen3-32b [dense] — qk_norm, GQA.

64L d_model=5120 64H (GQA kv=8) head_dim=128 d_ff=25600 vocab=151936
[hf:Qwen/Qwen3-8B family; hf]
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=25600,
        vocab_size=151936,
        pattern=(LayerSpec("attn"),),
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        act="silu",
        source="hf:Qwen/Qwen3-8B (scaled)",
    )
