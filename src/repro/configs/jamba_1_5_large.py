"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536 [arXiv:2403.19887; hf]
Block of 8: attention at index 0, mamba elsewhere; MoE on odd layers.
Adaptation notes: Jamba uses Mamba-1 internally; we use the Mamba2/SSD
mixer (TPU/MXU-friendly chunked form — DESIGN.md §2).  Jamba has no
positional embedding; the framework applies RoPE uniformly (harmless for
dry-run/roofline purposes, noted for fidelity).
"""
from .base import LayerSpec, MambaConfig, MoEConfig, ModelConfig


def _block():
    specs = []
    for i in range(8):
        mixer = "attn" if i == 0 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        specs.append(LayerSpec(mixer, ffn))
    return tuple(specs)


def get_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        pattern=_block(),  # 9 groups
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
        mamba=MambaConfig(d_state=64, head_dim=64, expand=2, conv_width=4,
                          chunk=256, n_groups=8),
        tie_embeddings=False,
        act="silu",
        source="arXiv:2403.19887",
    )
