"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.

46L d_model=4608 32H (GQA kv=16) head_dim=128 d_ff=36864 vocab=256000
[arXiv:2408.00118; hf]
"""
from .base import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        family="dense",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256000,
        pattern=(LayerSpec("attn_local"), LayerSpec("attn")),  # 23 groups
        window=4096,
        attn_softcap=50.0,
        logit_softcap=30.0,
        attn_scale=(4608 / 32) ** -0.5,  # query_pre_attn_scalar = d/H
        post_norms=True,
        scale_embeddings=True,
        tie_embeddings=True,
        act="gelu",
        source="arXiv:2408.00118",
    )
