"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400 [arXiv:2405.04434; hf]
First layer is a dense-FFN layer (d_ff=12288), the rest are MoE.
"""
from .base import LayerSpec, MLAConfig, MoEConfig, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=12288,  # dense first layer
        vocab_size=102400,
        prefix=(LayerSpec("attn", "dense"),),
        pattern=(LayerSpec("attn", "moe"),),  # 59 groups
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
                      qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2),
        tie_embeddings=False,
        act="silu",
        source="arXiv:2405.04434",
    )
