"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1536 ssm_state=128 vocab=50280 [arXiv:2405.21060; unverified]
d_inner = 2*d = 3072, head_dim=64 -> 48 SSD heads. No MLP (pure Mamba2 stack).
"""
from .base import LayerSpec, MambaConfig, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50280,
        pattern=(LayerSpec("mamba", "none"),),
        mamba=MambaConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                          chunk=256, n_groups=1),
        tie_embeddings=True,
        act="silu",
        source="arXiv:2405.21060; unverified",
    )
