"""``repro.net.simulate`` — the one front door of the packet simulator
(DESIGN.md §13).

The sim grew seven entry points (single job, batch, planned job, planned
batch, fat-tree, and the two fault-driver variants); every one of them
was the same engine behind a different argument spelling.  This facade
dispatches on what you hand it:

===========================  =============================================
``spec_or_plan``             runs as
===========================  =============================================
``sim.JobSpec``              one job (``keys``/``values`` ride the spec)
``[JobSpec, ...]``           a lockstep batch (+ mid-run ``admissions``)
``planner.JobPlan``          a scheduler-admitted job over ``keys/values``
``[JobPlan, ...]``           the admitted batch over key/value lists
``planner.FatTreeTopology``  a multi-rack incast (``placement``/``policy``)
===========================  =============================================

``faults=`` (a ``runtime.fault_tolerance.FailureInjector``) routes any
single-job form through the epoch-restart recovery driver and returns a
``FaultSimResult``; ``fault_policy=`` tunes detection/restart.
``engine=`` overrides ``NetConfig.engine`` without rebuilding the config
("node" or "vectorized" — results are bit-identical either way).

The seven legacy names still exist as thin shims that emit
``DeprecationWarning`` and delegate here; new code should only ever call
``repro.net.simulate`` (or ``repro.core.plan`` on the planning side).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from . import sim as sim_lib

__all__ = ["simulate"]


def _is_job_plan(x) -> bool:
    """Duck-typed ``planner.JobPlan`` (carries configure + tree)."""
    return hasattr(x, "configure") and hasattr(x, "tree")


def _is_fat_tree(x) -> bool:
    """Duck-typed ``planner.FatTreeTopology``."""
    return hasattr(x, "tier_switches") and hasattr(x, "link_tiers")


def _spec_with(spec: sim_lib.JobSpec, cfg, engine) -> sim_lib.JobSpec:
    if cfg is not None:
        spec = dataclasses.replace(spec, cfg=cfg)
    if engine is not None:
        spec = dataclasses.replace(spec, cfg=dataclasses.replace(
            spec.cfg or sim_lib.NetConfig(), engine=engine))
    return spec


def _cfg_with(cfg, engine):
    if engine is None:
        return cfg
    return dataclasses.replace(cfg or sim_lib.NetConfig(), engine=engine)


def _reject_unknown(kw: dict, *, path: str) -> None:
    if kw:
        raise TypeError(f"simulate() got unexpected keyword argument(s) "
                        f"{sorted(kw)} for a {path} input")


def simulate(spec_or_plan, keys=None, values=None, *, faults=None,
             fault_policy=None, engine=None, cfg=None, admissions=None,
             **kw):
    """Run anything the packet simulator knows how to run (DESIGN.md §13).

    Returns a ``SimResult`` (single job), a ``list[SimResult]`` (batch),
    or a ``FaultSimResult`` (``faults=`` given).  See the module
    docstring for the dispatch table; extra keywords are forwarded to the
    matched path (``placement``/``policy``/``op``/``mapper_delay``/... on
    the fat-tree path, ``aggregate``/``mapper_delay`` on plan paths).
    """
    x = spec_or_plan
    is_batch = (isinstance(x, Sequence)
                and not isinstance(x, (str, bytes)))
    if admissions is not None and not is_batch:
        raise TypeError("admissions= applies to a batch (a sequence of "
                        "JobSpec) — single-job forms have no lockstep to "
                        "join mid-run")

    # -- fat-tree incast ----------------------------------------------------
    if _is_fat_tree(x):
        if keys is None or values is None:
            raise TypeError("simulate(fat_tree, keys, values, ...) needs "
                            "the mapper stream")
        run_cfg = _cfg_with(cfg, engine)
        if faults is not None:
            return sim_lib._fat_tree_job_with_faults(
                x, keys, values, injector=faults,
                fault_policy=fault_policy, cfg=run_cfg, **kw)
        return sim_lib._fat_tree_job(x, keys, values, cfg=run_cfg, **kw)

    # -- single JobSpec -----------------------------------------------------
    if isinstance(x, sim_lib.JobSpec):
        _reject_unknown(kw, path="JobSpec")
        if keys is not None or values is not None:
            raise TypeError("a JobSpec carries its own keys/values")
        spec = _spec_with(x, cfg, engine)
        if faults is not None:
            return sim_lib._simulate_spec_with_faults(spec, faults,
                                                      fault_policy)
        return sim_lib._simulate_jobs([spec])[0]

    # -- single JobPlan -----------------------------------------------------
    if _is_job_plan(x):
        if keys is None or values is None:
            raise TypeError("simulate(job_plan, keys, values, ...) needs "
                            "the mapper stream")
        spec = sim_lib._job_plan_spec(
            x, keys, values, cfg=_cfg_with(cfg, engine),
            aggregate=kw.pop("aggregate", True),
            mapper_delay=kw.pop("mapper_delay", None))
        _reject_unknown(kw, path="JobPlan")
        if faults is not None:
            return sim_lib._simulate_spec_with_faults(spec, faults,
                                                      fault_policy)
        return sim_lib._simulate_jobs([spec])[0]

    # -- sequences: a lockstep batch of specs or plans ----------------------
    if is_batch:
        items = list(x)
        if faults is not None:
            raise ValueError("faults= is per-job: pass a single JobSpec / "
                             "JobPlan / fat-tree, not a batch")
        if items and all(_is_job_plan(p) for p in items):
            specs = sim_lib._job_plan_specs(
                items, keys, values, cfg=_cfg_with(cfg, engine),
                aggregate=kw.pop("aggregate", True),
                mapper_delays=kw.pop("mapper_delays", None))
        elif all(isinstance(s, sim_lib.JobSpec) for s in items):
            if keys is not None or values is not None:
                raise TypeError("JobSpecs carry their own keys/values")
            specs = [_spec_with(s, cfg, engine) for s in items]
        else:
            raise TypeError("simulate() batch must be all JobSpec or all "
                            "JobPlan")
        _reject_unknown(kw, path="batch")
        adm = [(step, _spec_with(s, cfg, engine))
               for step, s in (admissions or ())]
        return sim_lib._simulate_jobs(specs, admissions=adm)

    raise TypeError(f"simulate() cannot dispatch on "
                    f"{type(spec_or_plan).__name__!r}; expected JobSpec, "
                    "JobPlan, FatTreeTopology, or a sequence of "
                    "JobSpec/JobPlan")
