"""Packet-level network emulation + job-completion-time simulator
(DESIGN.md §7).

Submodules:
  wire      — MTU framing of KV records; THE byte-size constants
              (pure numpy: importable from ``core.reduction_model``)
  links     — per-link bandwidth / latency / FIFO-queue model
  transport — seeded loss injection + go-back-N retransmit
  schema    — unified sim report schema + metrics publishing
  sim       — discrete-event engine: mappers -> switch cascade -> reducer
  vsim      — vectorized tier engine behind ``NetConfig.engine``

Submodules load lazily: ``core.reduction_model`` imports ``net.wire`` for
its byte constants while ``net.sim`` imports ``core.dataplane`` — eager
package imports here would close that cycle.
"""

from __future__ import annotations

import importlib

_SUBMODULES = ("wire", "links", "transport", "schema", "sim", "vsim")


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
