"""Packet-level network emulation + job-completion-time simulator
(DESIGN.md §7).

Submodules:
  wire      — MTU framing of KV records; THE byte-size constants
              (pure numpy: importable from ``core.reduction_model``)
  links     — per-link bandwidth / latency / FIFO-queue model
  transport — seeded loss injection + go-back-N retransmit
  schema    — unified sim report schema + metrics publishing
  sim       — discrete-event engine: mappers -> switch cascade -> reducer
  vsim      — vectorized tier engine behind ``NetConfig.engine``

  facade    — ``repro.net.simulate``: THE public entry point over every
              sim form (DESIGN.md §13); the seven legacy ``sim.*`` entry
              points are deprecation shims onto it

Submodules load lazily: ``core.reduction_model`` imports ``net.wire`` for
its byte constants while ``net.sim`` imports ``core.dataplane`` — eager
package imports here would close that cycle.  ``repro.net.simulate`` is
re-exported the same lazy way.
"""

from __future__ import annotations

import importlib

_SUBMODULES = ("wire", "links", "transport", "schema", "sim", "vsim",
               "facade")

__all__ = [*_SUBMODULES, "simulate"]


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"{__name__}.{name}")
    if name == "simulate":
        return importlib.import_module(f"{__name__}.facade").simulate
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES) | {"simulate"})
