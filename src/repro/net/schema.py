"""Unified sim report schema + telemetry publishing (DESIGN.md §11).

Before this module, the node engine (``net/sim.py``) and the vectorized
engine (``net/vsim.py``) each assembled per-level telemetry dicts by
hand, and ``SimResult.report()`` silently dropped fields the dataclass
carried (``gap_discards`` / ``duplicate_discards`` never made it into
the report even though transport counted them).  Everything now goes
through one schema:

* :func:`level_report` — the per-level record, built from duck-typed
  switch nodes (``_Node`` from the node walk, ``_VNode`` from the fast
  tier path expose the same telemetry fields);
* :func:`report_dict` — the full job report (``SimResult.report()``
  delegates here), including the previously-dropped discard counters and
  the mapper-finish tail;
* :func:`publish_report` — the same record pushed into the
  :mod:`repro.obs.metrics` registry as labeled series.  Both engines
  publish through this one function from ``_JobRun.finalize``, which is
  what makes "node and vectorized emit identical metric series" true by
  construction *and* still meaningful: the inputs come from each
  engine's own nodes/links/flows, so any engine drift shows up as a
  series mismatch (the parity contract extended to telemetry,
  ``tests/test_obs.py``).
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.obs import metrics as obs_metrics

#: every key a job report carries (``SimResult.report()`` output)
REPORT_KEYS = (
    "aggregate", "op", "fanins", "jct_s",
    "delivered_records", "delivered_bytes", "arrived_records",
    "retransmissions", "timeouts", "packets_dropped",
    "gap_discards", "duplicate_discards", "mapper_finish_max_s",
    "link_bytes", "link_drain_s", "per_level",
)

#: every key a per-level record carries
LEVEL_KEYS = (
    "level", "axis", "switches", "records_in", "records_out",
    "evictions", "bytes_out", "agg_proc_s", "queue_peak",
)


def level_report(level: int, axis: str, nodes: Sequence) -> dict:
    """One tier's record from its switch nodes (either engine's)."""
    return {
        "level": level,
        "axis": axis,
        "switches": len(nodes),
        "records_in": sum(n.records_in for n in nodes),
        "records_out": sum(n.records_out for n in nodes),
        "evictions": sum(n.state.n_evict if n.state is not None else 0
                         for n in nodes),
        # disabled (forward-only) hops do no aggregation-engine work but
        # still move every byte: zero agg_proc_s, nonzero bytes_out —
        # and the queue depth is tracked for relays too
        "bytes_out": sum(n.bytes_out for n in nodes),
        "agg_proc_s": sum(n.agg_proc_s for n in nodes),
        "queue_peak": max((n.queue_peak for n in nodes), default=0),
    }


def report_dict(result) -> dict:
    """The canonical JSON-able job report from a ``SimResult``."""
    return {
        "aggregate": result.aggregate,
        "op": result.op,
        "fanins": list(result.fanins),
        "jct_s": result.jct_s,
        "delivered_records": result.delivered_records,
        "delivered_bytes": result.delivered_bytes,
        "arrived_records": result.arrived_records,
        "retransmissions": result.retransmissions,
        "timeouts": result.timeouts,
        "packets_dropped": result.packets_dropped,
        "gap_discards": result.gap_discards,
        "duplicate_discards": result.duplicate_discards,
        "mapper_finish_max_s": (max(result.mapper_finish_s)
                                if result.mapper_finish_s else 0.0),
        "link_bytes": {ax: s["bytes"]
                       for ax, s in result.link_stats.items()},
        "link_drain_s": {ax: s["drain_s"]
                         for ax, s in result.link_stats.items()},
        "per_level": result.per_level,
    }


def publish_report(report: dict, *, job: str, engine: str,
                   registry: Optional[object] = None) -> None:
    """Push one job report into the metrics registry as labeled series.

    Label taxonomy (DESIGN.md §11): ``job`` is the caller-chosen tag
    (placement policy, jct-comparison leg, ...), ``engine`` the sim
    engine that produced it, ``agg`` whether in-network aggregation was
    on, plus ``level``/``axis`` on per-tier series.
    """
    reg = registry if registry is not None else obs_metrics.get_registry()
    base = {"job": job, "engine": engine,
            "agg": "1" if report["aggregate"] else "0"}
    op = report["op"]

    g = reg.gauge
    c = reg.counter
    g("sim.job.jct_s", op=op, **base).set(report["jct_s"])
    g("sim.job.mapper_finish_max_s", **base).set(
        report["mapper_finish_max_s"])
    c("sim.job.delivered_records_total", **base).inc(
        report["delivered_records"])
    c("sim.job.delivered_bytes_total", **base).inc(
        report["delivered_bytes"])
    c("sim.job.arrived_records_total", **base).inc(
        report["arrived_records"])
    c("transport.retransmissions_total", **base).inc(
        report["retransmissions"])
    c("transport.timeouts_total", **base).inc(report["timeouts"])
    c("transport.packets_dropped_total", **base).inc(
        report["packets_dropped"])
    c("transport.gap_discards_total", **base).inc(report["gap_discards"])
    c("transport.duplicate_discards_total", **base).inc(
        report["duplicate_discards"])

    for lv in report["per_level"]:
        lbl = dict(base, level=lv["level"], axis=lv["axis"])
        g("sim.level.switches", **lbl).set(lv["switches"])
        c("sim.level.records_in_total", **lbl).inc(lv["records_in"])
        c("sim.level.records_out_total", **lbl).inc(lv["records_out"])
        c("sim.level.evictions_total", **lbl).inc(lv["evictions"])
        c("sim.level.bytes_out_total", **lbl).inc(lv["bytes_out"])
        g("sim.level.agg_proc_s", **lbl).set(lv["agg_proc_s"])
        g("sim.level.queue_peak", **lbl).set(lv["queue_peak"])

    for ax, b in report["link_bytes"].items():
        c("sim.link.wire_bytes_total", axis=ax, **base).inc(b)
    for ax, d in report["link_drain_s"].items():
        g("sim.link.drain_s", axis=ax, **base).set(d)


#: every key a fault report carries (``fault_report_dict`` output)
FAULT_REPORT_KEYS = (
    "epochs", "n_verdicts", "n_applied", "n_bypassed", "jct_s",
    "final_jct_s", "recovery_overhead_s", "degraded_levels", "verdicts",
    "epoch_log",
)


def fault_report_dict(fsr) -> dict:
    """The canonical JSON-able failure/recovery record from a
    ``net.sim.FaultSimResult`` (DESIGN.md §12).  ``recovery_overhead_s``
    is the absolute time spent on dead incarnations and restart delays —
    total JCT minus the surviving epoch's own run time; the *penalty* vs
    a pristine (never-degraded) run additionally includes the bypass
    relays' slower final epoch, which needs a baseline run to measure."""
    return {
        "epochs": fsr.epochs,
        "n_verdicts": len(fsr.verdicts),
        "n_applied": len(fsr.applied),
        "n_bypassed": len(fsr.bypass),
        "jct_s": fsr.jct_s,
        "final_jct_s": fsr.result.jct_s,
        "recovery_overhead_s": fsr.jct_s - fsr.result.jct_s,
        "degraded_levels": sorted({int(l) for l, _ in fsr.bypass}),
        "verdicts": [
            {"kind": v.kind, "level": v.level, "switch": v.switch,
             "epoch": v.epoch, "t_detect_s": v.t_detect_s,
             "detected_by": v.detected_by}
            for v in fsr.verdicts],
        "epoch_log": [dict(rec) for rec in fsr.epoch_log],
    }


def publish_fault_report(report: dict, *, job: str, engine: str,
                         registry: Optional[object] = None) -> None:
    """Push one failure/recovery record into the metrics registry.

    Series (same ``job``/``engine`` label taxonomy as
    :func:`publish_report`): ``sim.fault.epochs`` / ``.jct_s`` /
    ``.recovery_overhead_s`` scalars, ``sim.fault.verdicts_total``
    counters per (kind, detected_by), a ``sim.fault.event_t_s`` gauge per
    verdict (the failure timeline the dashboard renders), and
    ``sim.fault.degraded`` markers per bypassed tree level."""
    reg = registry if registry is not None else obs_metrics.get_registry()
    base = {"job": job, "engine": engine}
    g = reg.gauge
    c = reg.counter
    g("sim.fault.epochs", **base).set(report["epochs"])
    g("sim.fault.jct_s", **base).set(report["jct_s"])
    g("sim.fault.final_jct_s", **base).set(report["final_jct_s"])
    g("sim.fault.recovery_overhead_s", **base).set(
        report["recovery_overhead_s"])
    g("sim.fault.n_bypassed", **base).set(report["n_bypassed"])
    for v in report["verdicts"]:
        lbl = dict(base, kind=v["kind"], detected_by=v["detected_by"])
        c("sim.fault.verdicts_total", **lbl).inc(1)
        g("sim.fault.event_t_s", level=v["level"], switch=v["switch"],
          epoch=v["epoch"], **lbl).set(v["t_detect_s"])
    for lv in report["degraded_levels"]:
        g("sim.fault.degraded", level=lv, **base).set(1)


#: every key a controller snapshot carries
#: (``core.controller.ControllerReport.to_dict()`` output)
CONTROLLER_REPORT_KEYS = (
    "n_active", "n_degraded", "admitted_total", "evictions_total",
    "expansions_total", "candidates_scored_total", "scarce_axis",
    "total_scarce_bytes", "scarce_budget_bytes", "scarce_utilization",
    "tenants",
)


def publish_controller_report(report: dict, *,
                              registry: Optional[object] = None) -> None:
    """Push one online-controller snapshot into the metrics registry
    (DESIGN.md §13).

    Snapshot state goes to gauges here (``controller.active_jobs`` /
    ``.degraded_jobs`` / ``.scarce_bytes`` / ``.scarce_utilization`` and
    the per-tenant ``controller.tenant.*`` fairness series); *event*
    counters (``controller.admitted_total``, ``.evictions_total``,
    ``.expansions_total``, ``.candidates_scored_total``) are incremented
    by the controller at event time, since re-publishing a running total
    through a counter would double-count it.  The "Churn" dashboard
    section renders from exactly these series.
    """
    reg = registry if registry is not None else obs_metrics.get_registry()
    g = reg.gauge
    axis = report["scarce_axis"]
    g("controller.active_jobs").set(report["n_active"])
    g("controller.degraded_jobs").set(report["n_degraded"])
    g("controller.scarce_bytes", axis=axis).set(
        report["total_scarce_bytes"])
    g("controller.scarce_utilization", axis=axis).set(
        report["scarce_utilization"])
    for tenant, row in report["tenants"].items():
        lbl = {"tenant": tenant}
        g("controller.tenant.jobs", **lbl).set(row["n_jobs"])
        g("controller.tenant.weight", **lbl).set(row["weight"])
        g("controller.tenant.demand_bytes", **lbl).set(row["demand_bytes"])
        g("controller.tenant.share_bytes", **lbl).set(row["share_bytes"])
