"""Wire format: KV records packed into MTU-sized aggregation packets
(DESIGN.md §7; paper §4.1 Table 1 aggregation packets, Eq. 1/2 framing).

THE single source of byte-size constants.  ``PAIR_BYTES`` used to live as a
literal in ``examples/wordcount_switchagg.py`` and the 58 B Ethernet-domain
header / 2 B per-pair metadata as literals in ``core/reduction_model.py``;
every byte model now imports them from here so the analytic layer, the
packet simulator, and the examples cannot drift apart.

This module is pure Python/numpy (no jax) so ``core.reduction_model`` —
itself jax-free by design — can depend on it at import time.

A packet is an aggregation header riding the usual Ethernet/IP/UDP stack
(Eq. 2's 58 B ``H``) plus up to ``RECORDS_PER_PACKET`` variable-length
pairs.  The aggregation header carries what the switch needs to combine
exactly once: job id (which tree), tree level, per-flow PSN (the
transport's dedupe key), record count, and an end-of-task flag that
triggers the downstream flush.  Under failure recovery (DESIGN.md §12)
the header also carries the job's ``epoch`` — the restart incarnation
number — so a receiver can tell a retransmission of the same
incarnation (duplicate, discard) from a replay after a restart (new
incarnation, accept from PSN 0).  The epoch rides in header bits the
12 B aggregation header already reserves (flags/PSN space), so the
byte-model constants below are unchanged.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

# --- pair encoding (paper §2.1 / Eq. 1) ------------------------------------

KEY_BYTES = 4  # our keys are int32 word ids; real keys are 16-64 B strings
VALUE_BYTES = 4
PAIR_META_BYTES = 2  # SwitchAgg variable-length encoding: per-pair length tag
#: Average on-wire bytes of one variable-length (key, value) pair including
#: its metadata (paper workloads: 16-64 B keys).  The repo-wide byte unit.
PAIR_BYTES = 24

# --- packet framing (Eq. 2 domain) ------------------------------------------

ETH_HEADER_BYTES = 58  # Eq. (2)'s H: Ethernet + IP + UDP headers
#: job_id(2) + flow_id(2) + level(1) + psn(4) + n_records(2) + flags(1)
AGG_HEADER_BYTES = 12
HEADER_BYTES = ETH_HEADER_BYTES + AGG_HEADER_BYTES
MTU_BYTES = 1500
MAX_PAYLOAD_BYTES = MTU_BYTES - HEADER_BYTES
#: Records one MTU-sized aggregation packet carries.
RECORDS_PER_PACKET = MAX_PAYLOAD_BYTES // PAIR_BYTES


@dataclasses.dataclass(frozen=True)
class PacketHeader:
    """The aggregation header (paper Table 1 "aggregation packet")."""

    job_id: int
    flow_id: int  # sender edge within the job's tree (transport flow key)
    level: int  # tree level of the RECEIVING node; mappers send level 0
    psn: int  # per-flow packet sequence number (go-back-N / dedupe key)
    n_records: int
    eot: bool = False  # end-of-task: sender has no more records
    epoch: int = 0  # restart incarnation (DESIGN.md §12); 0 = never restarted


@dataclasses.dataclass(frozen=True)
class Packet:
    """One framed packet: header + a slice of the KV record stream.

    ``values`` may carry trailing lane dims (an op's carried representation,
    e.g. ``mean``'s (sum, count)); the byte model always charges the average
    ``PAIR_BYTES`` per record — lanes are a semantic, not a wire, detail.
    """

    header: PacketHeader
    keys: np.ndarray  # [n_records] int32
    values: np.ndarray  # [n_records] or [n_records, lanes]

    @property
    def payload_bytes(self) -> int:
        return self.header.n_records * PAIR_BYTES

    @property
    def wire_bytes(self) -> int:
        return HEADER_BYTES + self.payload_bytes


def pack_records(
    keys,
    values,
    *,
    job_id: int = 0,
    flow_id: int = 0,
    level: int = 0,
    start_psn: int = 0,
    records_per_packet: int = RECORDS_PER_PACKET,
    eot: bool = False,
    epoch: int = 0,
) -> list[Packet]:
    """Split a record stream into MTU-framed packets, PSNs consecutive from
    ``start_psn``.  With ``eot`` the last packet carries the end-of-task
    flag; an empty stream with ``eot`` still emits one empty EoT packet (the
    flush trigger must cross the wire)."""
    if records_per_packet < 1:
        raise ValueError("records_per_packet must be >= 1")
    keys = np.asarray(keys)
    values = np.asarray(values)
    if keys.shape[0] != values.shape[0]:
        raise ValueError("keys/values leading dims differ")
    n = keys.shape[0]
    packets: list[Packet] = []
    n_packets = max(1, math.ceil(n / records_per_packet)) if (n or eot) else 0
    for i in range(n_packets):
        lo, hi = i * records_per_packet, min(n, (i + 1) * records_per_packet)
        packets.append(Packet(
            header=PacketHeader(
                job_id=job_id, flow_id=flow_id, level=level,
                psn=start_psn + i, n_records=hi - lo,
                eot=eot and i == n_packets - 1, epoch=epoch),
            keys=keys[lo:hi], values=values[lo:hi]))
    return packets


def stream_wire_bytes(n_records: int,
                      records_per_packet: int = RECORDS_PER_PACKET) -> int:
    """Total on-wire bytes of a record stream: payload plus one header per
    packet — Eq. (2) with ceil framing (the paper floors because it counts
    only *full* extra packets; a framed stream pays for its tail too)."""
    if n_records <= 0:
        return 0
    n_packets = math.ceil(n_records / records_per_packet)
    return n_records * PAIR_BYTES + n_packets * HEADER_BYTES
