"""Lossy transport: seeded loss injection + go-back-N retransmit
(DESIGN.md §7; P4COM-style end-host loss recovery).

The paper's switches aggregate — they consume records — so loss recovery
cannot be end-to-end: each tree edge runs its own reliable flow between
the sending end host (a mapper, or an upstream switch re-emitting its
eviction stream) and the receiving node.  The sender is go-back-N: it
streams a window of packets back-to-back; on a loss it times out and
rewinds to the lost PSN, resending everything from there.  The receiver
(``net.sim``'s switch ingest) delivers records to the cascade only for the
packet whose PSN it expects next — a gap (an earlier loss in flight) or a
duplicate (a retransmission of something already combined) is discarded
*before* touching the aggregation state, which is what makes every record
combine exactly once under any loss pattern (the transport property test).

Failure detection rides the same machinery (DESIGN.md §12): a
:class:`RetryPolicy` turns the constant RTO into capped exponential
backoff with a finite consecutive-timeout budget, after which the sender
raises :class:`PeerDeadError` — the timeout-driven "peer dead" verdict —
and an :class:`EdgeFault` injects time-based drops (a crashed receiving
switch, transient link-down windows).  Packets carry a restart epoch, and
the :class:`Receiver` dedupes across incarnations as well as PSNs.

Loss is a pure function of (seed, flow, psn, attempt): reproducible, and
independent retransmissions re-roll the dice.  :func:`loss_uniform` IS
that function — a vectorizable integer hash, not a stateful RNG — so the
per-packet node sender and the array-form vectorized sender (``net.vsim``)
consume identical draws by construction: one calls it with scalars, the
other with whole ``[links, window]`` batches, and the values cannot drift.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from . import links as links_lib
from . import wire

# splitmix64 finalizer constants (Steele et al.; the standard 64-bit mix)
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer on uint64 arrays (wrap-around arithmetic)."""
    x = (x ^ (x >> np.uint64(30))) * _MIX1
    x = (x ^ (x >> np.uint64(27))) * _MIX2
    return x ^ (x >> np.uint64(31))


def loss_uniform(seed, flow_id, psn, attempt):
    """THE seeded per-(flow, psn, attempt) loss draw, as a pure function.

    Returns uniforms in [0, 1) — scalar in, scalar out; array in
    (broadcasting), array out — computed by absorbing the four words into
    a splitmix64 sponge.  Both transport engines MUST draw through here:
    the go-back-N node sender calls it one packet at a time, the
    vectorized tier sender (``net.vsim``) calls it on whole
    ``[links, window]`` burst batches, and because it is the same pure
    function there is no seed-drift risk between them.
    """
    with np.errstate(over="ignore"):  # wrap-around is the hash
        h = _mix64(np.asarray(seed).astype(np.uint64) + _GOLDEN)
        for word in (flow_id, psn, attempt):
            h = _mix64(h + np.asarray(word).astype(np.uint64) + _GOLDEN)
    return h.astype(np.float64) * 2.0**-64


class LossModel:
    """Deterministic seeded packet-loss oracle.

    ``drop`` (scalar, the node sender's call) and ``drop_array`` (batched,
    the vectorized sender's call) evaluate the same :func:`loss_uniform`
    draw, so the two engines see identical loss patterns by construction.
    Subclasses overriding the pair (e.g. an explicit drop-mask model in
    the property tests) must keep them elementwise-consistent.
    """

    def __init__(self, rate: float = 0.0, seed: int = 0):
        if not 0.0 <= rate < 1.0:
            raise ValueError("loss rate must be in [0, 1)")
        self.rate = rate
        self.seed = seed

    def drop(self, flow_id: int, psn: int, attempt: int) -> bool:
        if self.rate <= 0.0:
            return False
        return bool(loss_uniform(self.seed, flow_id, psn, attempt)
                    < self.rate)

    def drop_array(self, flow_ids, psns, attempts) -> np.ndarray:
        """Batched ``drop``: bool array over broadcast (flow, psn, attempt)."""
        if self.rate <= 0.0:
            return np.zeros(np.broadcast(
                np.asarray(flow_ids), np.asarray(psns),
                np.asarray(attempts)).shape, bool)
        return loss_uniform(self.seed, flow_ids, psns, attempts) < self.rate


@dataclasses.dataclass
class FlowStats:
    """One flow's transport accounting."""

    packets_sent: int = 0  # transmissions, including retransmissions
    packets_dropped: int = 0
    retransmissions: int = 0
    timeouts: int = 0
    wire_bytes: int = 0


#: deliver(packet, t_arrive) — called for every packet that physically
#: arrives (i.e. was not dropped), including out-of-order ones the
#: receiver will discard on its PSN check.
DeliverFn = Callable[[wire.Packet, float], None]

MAX_ATTEMPTS = 10_000


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry/backoff policy of one go-back-N sender (DESIGN.md §12).

    The default reproduces the legacy sender bit-for-bit: constant RTO
    (``backoff=1.0`` — ``rto * 1.0**k == rto`` exactly in floats) and no
    retry budget (retry forever, up to the ``MAX_ATTEMPTS`` backstop).
    A failure-detection policy sets ``backoff > 1`` (each consecutive
    timeout without progress waits ``backoff``x longer, capped at
    ``max_timeout_s``) and a finite ``max_timeouts``: once that many
    consecutive timeouts pass without the window advancing, the sender
    declares the peer dead and raises :class:`PeerDeadError` — the
    timeout-driven crash verdict the fault plane turns into an epoch
    restart.
    """

    timeout_s: float | None = None  # base RTO; None = per-link conservative
    backoff: float = 1.0  # RTO multiplier per consecutive no-progress timeout
    max_timeout_s: float | None = None  # cap on the backed-off RTO
    max_timeouts: int | None = None  # consecutive-timeout budget; None = infinite

    def rto(self, base_rto: float, consecutive: int) -> float:
        """The RTO after ``consecutive`` prior no-progress timeouts."""
        v = base_rto * self.backoff ** consecutive
        if self.max_timeout_s is not None:
            v = min(v, self.max_timeout_s)
        return v


DEFAULT_RETRY = RetryPolicy()


class PeerDeadError(RuntimeError):
    """A sender exhausted its retry budget: the receiving node is declared
    dead.  ``t_s`` is the sender's clock at the verdict — the detection
    time the fault plane dates the epoch restart from."""

    def __init__(self, msg: str, *, t_s: float, flow_id: int, psn: int,
                 timeouts: int, stats: "FlowStats | None" = None):
        super().__init__(msg)
        self.t_s = t_s
        self.flow_id = flow_id
        self.psn = psn
        self.timeouts = timeouts
        self.stats = stats  # accounting up to the verdict (telemetry)


@dataclasses.dataclass(frozen=True)
class EdgeFault:
    """Time-based failure of one tree edge's receiving end (DESIGN.md §12).

    ``dead_from_s`` models a crashed receiving switch: every packet
    arriving at or after that instant is lost (nobody is listening).
    ``down_windows`` are transient link outages: arrivals inside any
    ``[t0, t1)`` window die on the wire.  Both compose with the random
    ``LossModel`` — a packet must survive the dice *and* the fault to be
    delivered.
    """

    dead_from_s: float | None = None
    down_windows: tuple[tuple[float, float], ...] = ()

    def lost(self, t_arrive: float) -> bool:
        if self.dead_from_s is not None and t_arrive >= self.dead_from_s:
            return True
        return any(t0 <= t_arrive < t1 for t0, t1 in self.down_windows)


def send_stream(
    packets: Sequence[tuple[float, wire.Packet]],
    link: links_lib.Link,
    loss: LossModel,
    *,
    flow_id: int,
    window: int = 16,
    timeout_s: float | None = None,
    deliver: DeliverFn,
    retry: RetryPolicy | None = None,
    fault: EdgeFault | None = None,
) -> tuple[float, FlowStats]:
    """Reliably deliver ``packets`` — a PSN-ordered list of
    ``(t_ready, Packet)`` — over one link with go-back-N.

    ``t_ready`` is when the sender *has* the packet (a switch cannot resend
    an eviction before producing it).  Returns (time the sender finished,
    i.e. the whole stream is known-delivered, stats).  Dropped packets still
    occupy the link — the wire carried them before they died.

    ``retry`` arms the backoff/verdict policy (default: legacy constant
    RTO, retry forever); ``fault`` injects time-based drops (dead peer,
    link-down windows).  Against a peer that is dead — or a window that
    outlives the retry budget — a finite ``retry.max_timeouts`` makes
    this raise :class:`PeerDeadError` instead of spinning to the
    ``MAX_ATTEMPTS`` backstop.
    """
    if retry is None:
        retry = DEFAULT_RETRY
    if timeout_s is None:
        timeout_s = retry.timeout_s
    if timeout_s is None:
        # conservative RTO: a full window's serialization plus one RTT
        timeout_s = 2.0 * (window * link.serialize_s(wire.MTU_BYTES)
                           + 2.0 * link.propagation_s)
    stats = FlowStats()
    attempts = [0] * len(packets)
    base = 0
    t = 0.0
    consecutive = 0  # timeouts since the window last advanced
    while base < len(packets):
        upto = min(base + window, len(packets))
        first_lost: int | None = None
        for psn in range(base, upto):
            t_ready, pkt = packets[psn]
            assert pkt.header.psn == psn, "packets must be PSN-ordered"
            attempts[psn] += 1
            if attempts[psn] > MAX_ATTEMPTS:
                raise RuntimeError(
                    f"flow {flow_id}: psn {psn} exceeded {MAX_ATTEMPTS} "
                    f"attempts (loss rate too close to 1?)")
            if attempts[psn] > 1:
                stats.retransmissions += 1
            # payload is credited once per PSN; retransmissions add wire
            # bytes only, so wire/payload drain calibration sees the loss
            depart, arrive = link.transmit(
                max(t, t_ready), pkt.wire_bytes,
                pkt.payload_bytes if attempts[psn] == 1 else 0)
            t = depart  # sender streams back-to-back
            stats.packets_sent += 1
            stats.wire_bytes += pkt.wire_bytes
            if (loss.drop(flow_id, psn, attempts[psn])
                    or (fault is not None and fault.lost(arrive))):
                stats.packets_dropped += 1
                if first_lost is None:
                    first_lost = psn
            else:
                deliver(pkt, arrive)
        if first_lost is None:
            base = upto
            consecutive = 0
        else:
            # sender discovers the loss one RTO after it stopped sending,
            # rewinds to the lost PSN (go-back-N), and resends from there
            stats.timeouts += 1
            if first_lost > base:
                consecutive = 0  # the window advanced: progress was made
            t += retry.rto(timeout_s, consecutive)
            consecutive += 1
            base = first_lost
            if (retry.max_timeouts is not None
                    and consecutive > retry.max_timeouts):
                raise PeerDeadError(
                    f"flow {flow_id}: psn {first_lost} undeliverable after "
                    f"{consecutive} consecutive timeouts — peer declared "
                    f"dead at t={t:.6f}s",
                    t_s=t, flow_id=flow_id, psn=first_lost,
                    timeouts=consecutive, stats=stats)
    return t, stats


class Receiver:
    """PSN-dedupe gate in front of an aggregation node.

    Tracks the next expected PSN per flow; :meth:`accept` returns True
    exactly once per (flow, psn) and only in order — the switch-side
    incomplete-aggregation handling: records of a lost packet re-enter the
    cascade via retransmission without ever double-combining.

    The gate also dedupes across restart *incarnations* (DESIGN.md §12):
    each packet carries its job epoch, and the receiver tracks the
    highest epoch it has seen.  A packet from an older epoch is an
    in-flight leftover of an aborted incarnation — discarded (counted in
    ``stale_epoch_discards``) before it can touch aggregation state.  A
    packet from a *newer* epoch announces a restart: the per-flow PSN map
    resets, so the children's epoch-tagged replays (which restart at
    PSN 0) are accepted rather than misread as duplicates of the dead
    incarnation's stream.  Within one epoch the behavior is exactly the
    pre-epoch gate.
    """

    def __init__(self):
        self.expected: dict[int, int] = {}
        self.epoch = 0
        self.gap_discards = 0
        self.duplicate_discards = 0
        self.stale_epoch_discards = 0

    def accept(self, header: wire.PacketHeader) -> bool:
        epoch = getattr(header, "epoch", 0)
        if epoch < self.epoch:
            self.stale_epoch_discards += 1
            return False
        if epoch > self.epoch:  # restart: new incarnation, PSNs reset
            self.epoch = epoch
            self.expected.clear()
        exp = self.expected.get(header.flow_id, 0)
        if header.psn == exp:
            self.expected[header.flow_id] = exp + 1
            return True
        if header.psn < exp:
            self.duplicate_discards += 1
        else:
            self.gap_discards += 1
        return False
