"""Discrete-event job simulator: mappers -> switch cascade -> reducer
(DESIGN.md §7; paper §6 Figs. 9-10).

The missing layer between the planner and the dataplane: the planner
*models* per-level bytes and drain times, the dataplane *computes* exact
aggregation — this module runs a whole job over an emulated network and
measures what the paper measures: job completion time, per-link wire
bytes, and drain time, with or without in-network aggregation.

Topology: ``fanins`` leaf->root (e.g. ``(4, 2)`` = 8 mappers, two level-0
switches of fan-in 4, one root of fan-in 2).  Every tree edge is its own
FIFO :class:`~repro.net.links.Link`; every edge runs a reliable go-back-N
flow (``net.transport``) whose receiver dedupes on PSN before the records
touch aggregation state.  Each switch owns one ``dataplane.LevelState``
node (its slice of the job's ``CascadePlan``), charges line-rate
processing per packet, re-packs its eviction stream into MTU frames
(``net.wire``) as it goes, and flushes downstream once every child has
sent end-of-task.  The root's stream crosses the reducer in-link — the
paper testbed's 10 GbE bottleneck — and JCT is the arrival of the final
end-of-task byte at the reducer.

Because links are FIFO and flows are per-edge, the engine runs level by
level: a node's full arrival schedule is known once its children finished,
so no global event heap is needed — arrivals are merged in time order and
ingested sequentially, which keeps the hash-table dynamics honest.

Concurrency: :func:`simulate_jobs` steps a whole batch of independent
jobs level by level in lockstep, so tiers at the same depth that share a
kernel-static signature run as ONE batched ``vsim.tier_ingest`` call
(multi-job tier batching, DESIGN.md §10) — results are bit-identical to
running each job alone.

``aggregate=False`` is the host-only baseline: switches forward records
unaggregated and the reducer in-link carries the entire map output — the
configuration the paper's Fig. 10 JCT comparison is measured against.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import aggops, dataplane, kvagg
from repro.obs import trace as obs_trace
from . import links as links_lib
from . import schema as schema_lib
from . import transport, vsim, wire

_EMPTY = int(kvagg.EMPTY_KEY)

#: paper-testbed defaults, in the planner's 1e9-bytes/s unit
TEN_GBE = 1.25  # 10 GbE link
LINE_RATE = 5.0  # 40 Gb/s processing engine


@dataclasses.dataclass(frozen=True)
class NetConfig:
    """Knobs of the emulated network (defaults: the paper's testbed)."""

    link_gbps: tuple[float, ...] | None = None  # per tree level, leaf->root
    reducer_gbps: float | None = None  # reducer in-link; default root level
    processing_gbps: float = LINE_RATE  # switch line-rate processing charge
    propagation_s: float = 1e-6
    loss_rate: float = 0.0
    seed: int = 0
    window: int = 16  # go-back-N window
    timeout_s: float | None = None  # None: per-link conservative RTO
    records_per_packet: int = wire.RECORDS_PER_PACKET
    #: False runs every switch FPE on the batched-block fast path
    #: (DESIGN.md §8): same delivered totals, eviction traffic not
    #: paper-faithful — keep True for Fig. 9/10 reproductions
    exact_stream: bool = True
    #: "node" steps one Python node per switch (the oracle);
    #: "vectorized" batches each tier's per-packet FPE work into one
    #: jitted call (DESIGN.md §10) — bit-identical results at any loss
    #: rate, orders of magnitude more simulated switch-steps per second
    engine: str = "node"
    #: optional ``transport.LossModel`` override (e.g. an explicit
    #: drop-mask model in the property tests); ``None`` builds
    #: ``LossModel(loss_rate, seed)``.  The model's ``rate`` must be > 0
    #: for the lossy transport path to engage, and its ``drop`` /
    #: ``drop_array`` must stay elementwise-consistent so both engines
    #: see the same loss pattern.
    loss_model: transport.LossModel | None = None
    #: restart incarnation stamped on every packet header (DESIGN.md §12).
    #: 0 everywhere outside the fault driver; ``simulate_job_with_faults``
    #: bumps it per epoch so receivers dedupe across incarnations.
    epoch: int = 0

    def __post_init__(self):
        if self.engine not in ("node", "vectorized"):
            raise ValueError(f"unknown sim engine {self.engine!r} "
                             "(expected 'node' or 'vectorized')")
        if not 0.0 <= self.loss_rate < 1.0:
            raise ValueError(f"loss_rate {self.loss_rate!r} outside [0, 1)")


class _Node:
    """One switch: PSN-dedupe gate + one cascade level + output packetizer."""

    def __init__(self, *, level: int, n_children: int,
                 spec: dataplane.LevelSpec | None, op: str, aggregate: bool,
                 cfg: NetConfig, job_id: int, flow_id: int):
        self.level = level
        self.n_children = n_children
        # a disabled spec (placement left this tier out, DESIGN.md §9) is a
        # forward-only switch — same path as the host-only baseline
        self.aggregate = aggregate and (spec is None or spec.enabled)
        self.state = (dataplane.LevelState(
            spec, op, batch_pad=cfg.records_per_packet,
            exact_stream=cfg.exact_stream)
            if self.aggregate else None)
        self.receiver = transport.Receiver()
        self.proc_free = 0.0
        self.proc_rate = cfg.processing_gbps * 1e9
        self.rpp = cfg.records_per_packet
        self.job_id = job_id
        self.epoch = int(cfg.epoch)
        self.flow_id = flow_id  # of the uplink flow this node sends
        self.out: list[tuple[float, wire.Packet]] = []  # (t_ready, pkt)
        self._psn = 0
        self._pend_k: np.ndarray | None = None
        self._pend_v: np.ndarray | None = None
        self._eot_seen = 0
        self.records_in = 0
        self.records_out = 0
        self.bytes_out = 0  # wire bytes of every packet this node emits
        self.agg_proc_s = 0.0  # aggregation-engine busy seconds (0 if relay)
        self.queue_peak = 0  # deepest the output pending queue ever got
        self.finished = False
        # fault-plane timing: when the table first held state and when the
        # EoT flush completed — the window a table_wipe can corrupt (§12)
        self.t_first_ingest = math.inf
        self.t_finish = math.inf

    def _append(self, keys: np.ndarray, values: np.ndarray) -> None:
        if self._pend_k is None:
            self._pend_k, self._pend_v = keys, values
        else:
            self._pend_k = np.concatenate([self._pend_k, keys])
            self._pend_v = np.concatenate([self._pend_v, values])
        self.queue_peak = max(self.queue_peak, int(self._pend_k.shape[0]))

    def _emit_packet(self, t: float, keys: np.ndarray, values: np.ndarray,
                     eot: bool) -> None:
        hdr = wire.PacketHeader(
            job_id=self.job_id, flow_id=self.flow_id, level=self.level + 1,
            psn=self._psn, n_records=int(keys.shape[0]), eot=eot,
            epoch=self.epoch)
        self._psn += 1
        self.records_out += int(keys.shape[0])
        pkt = wire.Packet(header=hdr, keys=keys, values=values)
        self.bytes_out += pkt.wire_bytes
        self.out.append((t, pkt))

    def _emit_full(self, t: float) -> None:
        while self._pend_k is not None and self._pend_k.shape[0] >= self.rpp:
            k, self._pend_k = self._pend_k[:self.rpp], self._pend_k[self.rpp:]
            v, self._pend_v = self._pend_v[:self.rpp], self._pend_v[self.rpp:]
            self._emit_packet(t, k, v, eot=False)

    def receive(self, pkt: wire.Packet, t_arrive: float) -> None:
        """Ingest one arrival: dedupe on PSN, charge line-rate processing,
        cascade the records, and re-frame whatever leaves the node."""
        if not self.receiver.accept(pkt.header):
            return  # gap or duplicate: discarded before aggregation state
        t = t_arrive
        if pkt.header.n_records:
            start = max(t_arrive, self.proc_free)
            busy = pkt.wire_bytes / self.proc_rate
            self.proc_free = start + busy
            t = self.proc_free
            if self.aggregate:  # a relay's charge is store-and-forward,
                self.agg_proc_s += busy  # not aggregation-engine work
            self.records_in += pkt.header.n_records
            self.t_first_ingest = min(self.t_first_ingest, t_arrive)
            if self.aggregate:
                ek, ev = self.state.ingest(pkt.keys, pkt.values)
            else:  # host-only baseline: forward unaggregated
                ek = np.asarray(pkt.keys, np.int32)
                ev = np.asarray(pkt.values)
            if ek.shape[0]:
                self._append(ek, ev)
                self._emit_full(t)
        if pkt.header.eot:
            self._eot_seen += 1
            if self._eot_seen == self.n_children:
                self._finish(max(t, self.proc_free))

    def _finish(self, t: float) -> None:
        if self.aggregate:
            fk, fv = self.state.flush()
            if fk.shape[0]:
                # EoT flush streams out at the processing line rate too
                busy = fk.shape[0] * wire.PAIR_BYTES / self.proc_rate
                self.agg_proc_s += busy
                self.proc_free = max(t, self.proc_free) + busy
                t = self.proc_free
                self._append(fk, fv)
        self._emit_full(t)
        if self._pend_k is not None and self._pend_k.shape[0]:
            self._emit_packet(t, self._pend_k, self._pend_v, eot=True)
            self._pend_k = self._pend_v = None
        else:  # the flush trigger must cross the wire even when empty
            self._emit_packet(
                t, np.zeros((0,), np.int32),
                np.zeros((0,), np.float32), eot=True)
        self.t_finish = t
        self.finished = True


@dataclasses.dataclass
class SimResult:
    """Everything one simulated job run measured."""

    jct_s: float
    aggregate: bool
    op: str
    fanins: tuple[int, ...]
    axes: tuple[str, ...]
    delivered_keys: np.ndarray  # reducer's final table, packed + finalized
    delivered_values: np.ndarray
    delivered_records: int  # records the reducer hands the application
    delivered_bytes: int  # wire bytes of the delivered stream
    arrived_records: int  # records arriving at the reducer pre-merge
    link_stats: dict[str, dict]  # per axis (+ "reducer"), links.stats_by_axis
    per_level: list[dict]
    retransmissions: int
    timeouts: int
    packets_dropped: int
    gap_discards: int
    duplicate_discards: int
    mapper_finish_s: list[float]

    def delivered_table(self) -> dict[int, float]:
        return {int(k): np.asarray(v).tolist() if np.ndim(v) else float(v)
                for k, v in zip(self.delivered_keys, self.delivered_values)}

    def report(self) -> dict:
        """JSON-able record in the unified schema (``net.schema``) —
        identical keys from both engines, bench/dry-run/dashboard shape."""
        return schema_lib.report_dict(self)


def _default_axes(n: int) -> tuple[str, ...]:
    return tuple(f"lvl{i}" for i in range(n))


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One job's inputs to :func:`simulate_jobs` — exactly
    :func:`simulate_job`'s signature as data, so a batch of concurrent
    jobs can run through the level-lockstep engine together."""

    keys: object
    values: object
    fanins: Sequence[int]
    plan: dataplane.CascadePlan | None = None
    op: str = "sum"
    aggregate: bool = True
    cfg: NetConfig | None = None
    axes: Sequence[str] | None = None
    mapper_delay: Callable[[int], float] | None = None
    job_id: int = 0
    #: telemetry tag: labels this job's metric series and names its trace
    #: track (placement policy, comparison leg, ...); default "job<id>"
    tag: str = ""

    def __post_init__(self):
        fanins = tuple(int(f) for f in self.fanins)
        if not fanins or any(f < 1 for f in fanins):
            raise ValueError(f"bad fanins {fanins}: every level needs a "
                             "positive mapper/child count")


class _FaultCtx:
    """One restart epoch's view of the failure plane (DESIGN.md §12).

    Built by the epoch driver (:func:`simulate_job_with_faults`) and
    threaded through ``_JobRun``: maps the injector's *absolute*-time
    events onto this epoch's relative timeline (``rel = t_s - t_start_s``,
    clamped at 0 for failures that predate the epoch), carries the
    positions already known dead (``bypass`` — forward-only relays), the
    persistent per-position :class:`transport.Receiver` gates that survive
    restarts, and collects the epoch's :class:`FailureVerdict`s.
    """

    def __init__(self, *, injector, policy, epoch: int, t_start_s: float,
                 bypass: frozenset, fired_wipes: set, receivers: dict):
        self.injector = injector
        self.policy = policy
        self.epoch = int(epoch)
        self.t_start_s = float(t_start_s)
        self.bypass = bypass  # {(level, switch)} dead -> relay positions
        self.fired_wipes = fired_wipes  # indices into injector.events
        self.receivers = receivers  # {(level, switch) | ("reducer", 0)}
        self.verdicts: list = []
        self.retry = transport.RetryPolicy(
            backoff=policy.backoff, max_timeouts=policy.max_timeouts,
            max_timeout_s=policy.max_timeout_s)
        self._at: dict[tuple[int, int], list] = {}
        for i, e in enumerate(injector.events):
            self._at.setdefault((e.level, e.switch), []).append((i, e))

    def _events_at(self, level: int, switch: int) -> list:
        return self._at.get((level, switch), [])

    def _level_active(self, level: int, kinds=None) -> bool:
        for (l, s), evs in self._at.items():
            if l != level or (l, s) in self.bypass:
                continue
            for i, e in evs:
                if kinds is not None and e.kind not in kinds:
                    continue
                if e.kind == "table_wipe" and i in self.fired_wipes:
                    continue
                if (e.kind == "link_down"
                        and e.t_s + e.duration_s <= self.t_start_s):
                    continue  # window fully in a previous incarnation
                return True
        return False

    def tier_faulted(self, level: int) -> bool:
        """Does tier ``level`` need the fault-aware node path?  Yes when a
        switch of the tier is a bypass relay or has a pending event, or
        when the tier below has a pending crash (this tier is the parent
        that must liveness-detect the truncated child stream)."""
        if any(p[0] == level for p in self.bypass):
            return True
        if self._level_active(level):
            return True
        return level >= 1 and self._level_active(
            level - 1, kinds=("switch_crash",))

    def crash_rel(self, level: int, switch: int) -> float | None:
        """This epoch's crash instant of (level, switch), relative to the
        epoch start — 0 if the (undetected) crash predates it."""
        if (level, switch) in self.bypass:
            return None
        ts = [e.t_s for _, e in self._events_at(level, switch)
              if e.kind == "switch_crash"]
        if not ts:
            return None
        return max(0.0, min(ts) - self.t_start_s)

    def edge_fault(self, level: int, switch: int, child: int, *,
                   crash_rel: float | None,
                   bypassed: bool) -> transport.EdgeFault | None:
        if bypassed:
            return None  # the recovery re-route is assumed healthy
        windows = []
        for _, e in self._events_at(level, switch):
            if e.kind != "link_down" or (e.child is not None
                                         and e.child != child):
                continue
            t0 = e.t_s - self.t_start_s
            t1 = t0 + e.duration_s
            if t1 > 0:
                windows.append((max(0.0, t0), t1))
        if crash_rel is None and not windows:
            return None
        return transport.EdgeFault(dead_from_s=crash_rel,
                                   down_windows=tuple(sorted(windows)))

    def wipe_rel(self, level: int, switch: int, t_first_ingest: float,
                 t_finish: float) -> float | None:
        """A pending table wipe that lands while the switch's table holds
        state (first ingest <= t < EoT flush) — locally visible, so the
        switch self-reports the instant it happens.  Wipes outside the
        state window are harmless and fire silently."""
        for i, e in self._events_at(level, switch):
            if e.kind != "table_wipe" or i in self.fired_wipes:
                continue
            rel = e.t_s - self.t_start_s
            if rel >= 0 and t_first_ingest <= rel < t_finish:
                return rel
        return None

    def liveness_s(self, link, window: int, timeout_s: float | None) -> float:
        """Parent-side liveness timeout: how long a node waits past its
        last arrival before declaring an EoT-less child dead.  Default:
        the time a sender needs to exhaust its own retry budget on this
        link (base RTO through the full backoff ladder), so both
        detection paths date verdicts comparably."""
        if self.policy.liveness_timeout_s is not None:
            return self.policy.liveness_timeout_s
        if timeout_s is None:
            timeout_s = 2.0 * (window * link.serialize_s(wire.MTU_BYTES)
                               + 2.0 * link.propagation_s)
        return sum(self.retry.rto(timeout_s, i)
                   for i in range(self.policy.max_timeouts + 1))

    def attach_receiver(self, pos) -> transport.Receiver:
        """The persistent PSN/epoch gate of one position; created on first
        use, reused across epochs (discard counters are per-epoch)."""
        rcv = self.receivers.get(pos)
        if rcv is None:
            rcv = transport.Receiver()
            self.receivers[pos] = rcv
        rcv.gap_discards = 0
        rcv.duplicate_discards = 0
        rcv.stale_epoch_discards = 0
        return rcv

    def add_verdict(self, kind: str, level: int, switch: int, *,
                    t_detect_rel: float, detected_by: str) -> None:
        from repro.runtime.fault_tolerance import FailureVerdict

        self.verdicts.append(FailureVerdict(
            kind=kind, level=level, switch=switch, epoch=self.epoch,
            t_detect_s=self.t_start_s + t_detect_rel,
            detected_by=detected_by))


class _JobRun:
    """Mutable per-job state while :func:`simulate_jobs` steps the batch
    level by level.  Jobs never interact — each owns its links, flows,
    and streams; the lockstep exists only so same-depth tiers can share
    batched kernel calls."""

    def __init__(self, spec: JobSpec, faults: _FaultCtx | None = None):
        cfg = spec.cfg or NetConfig()
        # engine/loss-rate/fanin validity is a dataclass invariant now:
        # NetConfig.__post_init__ and JobSpec.__post_init__ raise at
        # construction, before any simulation state exists
        fanins = tuple(int(f) for f in spec.fanins)
        n_levels = len(fanins)
        axes = (tuple(spec.axes) if spec.axes is not None
                else _default_axes(n_levels))
        if len(axes) != n_levels:
            raise ValueError("axes must match fanins")
        op, plan, aggregate = spec.op, spec.plan, spec.aggregate
        if plan is not None:
            op = plan.op  # the plan owns the op even for the baseline
        if aggregate:
            if plan is None:
                plan = dataplane.CascadePlan(op=op, levels=tuple(
                    dataplane.LevelSpec(capacity=0) for _ in fanins))
            if len(plan.levels) != n_levels:
                raise ValueError(
                    f"plan has {len(plan.levels)} levels, tree has "
                    f"{n_levels}")
        link_gbps = (tuple(cfg.link_gbps) if cfg.link_gbps is not None
                     else (TEN_GBE,) * n_levels)
        if len(link_gbps) != n_levels:
            raise ValueError("link_gbps must match fanins")
        self.cfg = cfg
        self.fanins = fanins
        self.n_levels = n_levels
        self.axes = axes
        self.op = op
        self.plan = plan
        self.aggregate = aggregate
        self.aggop = aggops.get(op)
        self.link_gbps = link_gbps
        self.reducer_gbps = (cfg.reducer_gbps if cfg.reducer_gbps is not None
                             else link_gbps[-1])
        self.job_id = spec.job_id
        self.tag = spec.tag or f"job{spec.job_id}"
        self.faults = faults
        # one virtual-time trace track per run (DESIGN.md §11): per-level
        # ingest/transport lanes on their own pid so repeated runs and
        # concurrent jobs never interleave on one lane
        tracer = obs_trace.get_tracer()
        self._pid: int | None = None
        if tracer.enabled:
            leg = "" if spec.aggregate else " (host-only)"
            if faults is not None:
                leg += f" e{faults.epoch}"
            self._pid = tracer.new_track(f"sim {self.tag}{leg}")

        n_mappers = math.prod(fanins)
        self.keys = np.asarray(spec.keys, np.int32)
        self.carried = np.asarray(self.aggop.prepare_values(
            jnp.asarray(np.asarray(spec.values))))
        self.loss = (cfg.loss_model if cfg.loss_model is not None
                     else transport.LossModel(cfg.loss_rate, cfg.seed))
        self.all_links: list[links_lib.Link] = []
        self.flows = transport.FlowStats()
        self.mapper_finish = [0.0] * n_mappers
        self.fast_engine = cfg.engine == "vectorized"
        self.next_flow_id = n_mappers
        self.per_level_nodes: list[list] = []
        self.reducer_gap = 0
        self.reducer_dup = 0

        # mapper output flows (flow ids 0..n_mappers-1); streams live as
        # Packet lists (node path) or array-form PacketStreams (fast path)
        t0s = [float(spec.mapper_delay(m)) if spec.mapper_delay is not None
               else 0.0 for m in range(n_mappers)]
        if self.fast_engine:
            self.current: list = vsim.streams_from_mapper_records(
                self.keys, self.carried, t0s, n_mappers=n_mappers,
                job_id=self.job_id, level=0, rpp=cfg.records_per_packet,
                epoch=int(cfg.epoch))
        else:
            key_chunks = np.array_split(self.keys, n_mappers)
            val_chunks = np.array_split(self.carried, n_mappers)
            self.current = []
            for m in range(n_mappers):
                pkts = wire.pack_records(
                    key_chunks[m], val_chunks[m], job_id=self.job_id,
                    flow_id=m, level=0, eot=True,
                    records_per_packet=cfg.records_per_packet,
                    epoch=int(cfg.epoch))
                self.current.append([(t0s[m], p) for p in pkts])

    def _note_tier(self, l: int, *, t0: float, t1: float,
                   kind: str) -> None:
        """Replay one tier interval onto this run's virtual-time track
        (span taxonomy: ``sim.transport`` = child flows draining,
        ``sim.ingest`` = switch accept/aggregate/re-frame window)."""
        tracer = obs_trace.get_tracer()
        if not tracer.enabled or self._pid is None:
            return
        tid = 2 * l + (1 if kind == "ingest" else 0)
        name = f"L{l} {self.axes[l]} {kind}"
        tracer.name_thread(self._pid, tid, name)
        tracer.add_span(name, t0, t1, cat=f"sim.{kind}", pid=self._pid,
                        tid=tid, args={"level": l, "axis": self.axes[l]})

    def _add_flow(self, st: transport.FlowStats) -> None:
        self.flows.packets_sent += st.packets_sent
        self.flows.packets_dropped += st.packets_dropped
        self.flows.retransmissions += st.retransmissions
        self.flows.timeouts += st.timeouts
        self.flows.wire_bytes += st.wire_bytes

    def _run_flow(self, stream, link, sink) -> float:
        arrivals: list[tuple[float, wire.Packet]] = []
        fid = stream[0][1].header.flow_id
        t_done, st = transport.send_stream(
            stream, link, self.loss, flow_id=fid, window=self.cfg.window,
            timeout_s=self.cfg.timeout_s,
            deliver=lambda p, t: arrivals.append((t, p)))
        self._add_flow(st)
        sink.extend(arrivals)
        return t_done

    def start_tier(self, l: int) -> vsim.TierWork | None:
        """Run tier *l*'s front half.  Fast-path tiers return a
        ``TierWork`` for the shared kernel dispatch; node-path tiers
        (host-only engine, or capacity-0 exact levels) run to completion
        here and return ``None``."""
        if self.faults is not None and self.faults.tier_faulted(l):
            # fault-affected tiers walk the node path: per-edge faults,
            # backoff senders, and persistent receivers have no array
            # form — clean tiers keep the fast path, so the vectorized
            # engine stays bit-identical where nothing is broken (§12)
            self._run_tier_node_faulted(l)
            return None
        spec = self.plan.levels[l] if self.aggregate else None
        # forward-only tiers (host-only baseline, placement-disabled hops)
        # have no aggregation state at all, so the fast path covers them
        # with pure array re-framing — no kernel call
        fast_forward = self.fast_engine and (
            not self.aggregate or (spec is not None and not spec.enabled))
        if fast_forward or (self.fast_engine and self.aggregate
                            and vsim.supports(spec)):
            # fast path (DESIGN.md §10): the whole tier — transport (any
            # loss rate), acceptance, processing, re-framing, telemetry —
            # as array passes plus at most one jitted kernel call,
            # bit-identical to the node walk
            streams = [
                s if isinstance(s, vsim.PacketStream)
                else vsim.stream_from_packets(
                    s, value_template=self.carried[:0])
                for s in self.current]
            return vsim.tier_start(
                streams, level=l, fanin=self.fanins[l],
                spec=None if fast_forward else spec, op=self.op,
                cfg=self.cfg, axis=self.axes[l], gbps=self.link_gbps[l],
                job_id=self.job_id, first_flow_id=self.next_flow_id,
                value_template=self.carried[:0], loss=self.loss)
        self._run_tier_node(l)
        return None

    def finish_tier(self, l: int, work: vsim.TierWork) -> None:
        """Consume tier *l*'s dispatched kernel slice and advance."""
        nodes, out_streams, tier_links, tier_flow, t_done = \
            vsim.tier_finish(work)
        self.next_flow_id += work.n_switches
        self.all_links.extend(tier_links)
        self._add_flow(tier_flow)
        if l == 0:
            self.mapper_finish = list(t_done)
        self.per_level_nodes.append(nodes)
        self.current = out_streams
        if self._pid is not None and obs_trace.get_tracer().enabled:
            t0 = float(work.t_m.min()) if work.t_m.size else 0.0
            t_tx = max(t_done, default=t0)
            self._note_tier(l, t0=t0, t1=t_tx, kind="transport")
            t_out = max((float(s.times[-1]) for s in out_streams
                         if s.times.size), default=t_tx)
            self._note_tier(l, t0=t0, t1=t_out, kind="ingest")

    def _run_tier_node(self, l: int) -> None:
        # node path tiers (host-only engine, capacity-0 exact levels)
        # walk materialized packets
        fanin = self.fanins[l]
        n_switches = math.prod(self.fanins[l + 1:])
        spec = self.plan.levels[l] if self.aggregate else None
        current = [
            vsim.stream_to_packets(s) if isinstance(s, vsim.PacketStream)
            else s for s in self.current]
        nodes: list[_Node] = []
        nxt: list[list[tuple[float, wire.Packet]]] = []
        t_first, t_tx, t_out = math.inf, 0.0, 0.0
        for s in range(n_switches):
            # phase A — transport: run every child-edge flow; links are
            # FIFO and flows per-edge, so the switch's full arrival
            # schedule is known before its node steps
            arrivals: list[tuple[float, wire.Packet]] = []
            for c in range(fanin):
                ci = s * fanin + c
                link = links_lib.Link(
                    name=f"{self.axes[l]}.s{s}.c{c}", axis=self.axes[l],
                    gbps=self.link_gbps[l],
                    propagation_s=self.cfg.propagation_s)
                self.all_links.append(link)
                t_done = self._run_flow(current[ci], link, arrivals)
                t_tx = max(t_tx, t_done)
                if l == 0:
                    self.mapper_finish[ci] = t_done
            arrivals.sort(key=lambda a: (a[0], a[1].header.flow_id,
                                         a[1].header.psn))
            # phase B — host walk: acceptance, aggregation, timing,
            # packetization, and telemetry through the node code
            node = _Node(level=l, n_children=fanin, spec=spec, op=self.op,
                         aggregate=self.aggregate, cfg=self.cfg,
                         job_id=self.job_id, flow_id=self.next_flow_id)
            self.next_flow_id += 1
            for t, p in arrivals:
                node.receive(p, t)
            assert node.finished, "reliable transport must complete the node"
            nodes.append(node)
            nxt.append(node.out)
            if arrivals:
                t_first = min(t_first, arrivals[0][0])
            if node.out:
                t_out = max(t_out, max(t for t, _ in node.out))
        self.per_level_nodes.append(nodes)
        self.current = nxt
        if self._pid is not None and obs_trace.get_tracer().enabled:
            t0 = 0.0 if math.isinf(t_first) else t_first
            self._note_tier(l, t0=t0, t1=max(t_tx, t0), kind="transport")
            self._note_tier(l, t0=t0, t1=max(t_out, t0), kind="ingest")

    def _run_tier_node_faulted(self, l: int) -> None:
        """Tier *l* under the fault plane (DESIGN.md §12): per-edge
        ``EdgeFault``s with the armed backoff/verdict retry policy on
        faulted edges (clean edges keep the legacy constant-RTO sender,
        bit for bit), persistent receivers across restart epochs, crash
        truncation of arrivals and in-flight output, and all three
        detection paths — sender retry exhaustion, parent liveness on an
        EoT-less child stream, and self-reported table wipes."""
        fx = self.faults
        cfg = self.cfg
        fanin = self.fanins[l]
        n_switches = math.prod(self.fanins[l + 1:])
        spec = self.plan.levels[l] if self.aggregate else None
        current = [
            vsim.stream_to_packets(s) if isinstance(s, vsim.PacketStream)
            else s for s in self.current]
        nodes: list[_Node] = []
        nxt: list[list[tuple[float, wire.Packet]]] = []
        t_first, t_tx, t_out = math.inf, 0.0, 0.0
        for s in range(n_switches):
            pos = (l, s)
            bypassed = pos in fx.bypass
            crash_rel = fx.crash_rel(l, s)
            arrivals: list[tuple[float, wire.Packet]] = []
            silent: list[int] = []  # child edges whose stream was cut short
            link = None
            for c in range(fanin):
                ci = s * fanin + c
                link = links_lib.Link(
                    name=f"{self.axes[l]}.s{s}.c{c}", axis=self.axes[l],
                    gbps=self.link_gbps[l],
                    propagation_s=cfg.propagation_s)
                self.all_links.append(link)
                stream = current[ci]
                if not stream:  # the child died before emitting anything
                    silent.append(c)
                    continue
                fault = fx.edge_fault(l, s, c, crash_rel=crash_rel,
                                      bypassed=bypassed)
                retry = (fx.retry if fault is not None
                         else transport.DEFAULT_RETRY)
                try:
                    t_done, st = transport.send_stream(
                        stream, link, self.loss,
                        flow_id=stream[0][1].header.flow_id,
                        window=cfg.window, timeout_s=cfg.timeout_s,
                        deliver=lambda p, t: arrivals.append((t, p)),
                        retry=retry, fault=fault)
                    self._add_flow(st)
                    if not stream[-1][1].header.eot:
                        silent.append(c)  # truncated upstream: no EoT to send
                except transport.PeerDeadError as e:
                    # sender-side verdict: this switch is declared dead
                    # (really dead, or a link-down window outlived the
                    # retry budget — the false-positive the bypass must
                    # also survive)
                    t_done = e.t_s
                    if e.stats is not None:
                        self._add_flow(e.stats)
                    fx.add_verdict(
                        "switch_crash" if crash_rel is not None
                        else "link_down",
                        l, s, t_detect_rel=e.t_s, detected_by="sender")
                t_tx = max(t_tx, t_done)
                if l == 0:
                    self.mapper_finish[ci] = t_done
            arrivals.sort(key=lambda a: (a[0], a[1].header.flow_id,
                                         a[1].header.psn))
            node = _Node(level=l, n_children=fanin, spec=spec, op=self.op,
                         aggregate=self.aggregate and not bypassed, cfg=cfg,
                         job_id=self.job_id, flow_id=self.next_flow_id)
            self.next_flow_id += 1
            node.receiver = fx.attach_receiver(pos)
            for t, p in arrivals:
                node.receive(p, t)
            if crash_rel is not None:
                # the crash loses the in-flight table: output the switch
                # would have produced at or after the instant never made
                # the wire, and the EoT it owed its parent dies with it
                node.out = [(t, p) for t, p in node.out if t < crash_rel]
            elif not node.finished:
                # a child went silent (dead switch below, or a sender that
                # gave this node up): declare EoT-less children dead by
                # liveness timeout, then flush what did arrive so the
                # epoch's timeline completes without cascading false
                # verdicts up the tree
                t_last = arrivals[-1][0] if arrivals else 0.0
                t_detect = t_last + fx.liveness_s(link, cfg.window,
                                                  cfg.timeout_s)
                if l >= 1:
                    for c in silent:
                        fx.add_verdict(
                            "switch_crash", l - 1, s * fanin + c,
                            t_detect_rel=t_detect, detected_by="parent")
                node._finish(max(t_detect, node.proc_free))
            if crash_rel is None and not bypassed and node.aggregate:
                w_rel = fx.wipe_rel(l, s, node.t_first_ingest,
                                    node.t_finish)
                if w_rel is not None:
                    fx.add_verdict("table_wipe", l, s, t_detect_rel=w_rel,
                                   detected_by="self")
            nodes.append(node)
            nxt.append(node.out)
            if arrivals:
                t_first = min(t_first, arrivals[0][0])
            if node.out:
                t_out = max(t_out, max(t for t, _ in node.out))
        self.per_level_nodes.append(nodes)
        self.current = nxt
        if self._pid is not None and obs_trace.get_tracer().enabled:
            t0 = 0.0 if math.isinf(t_first) else t_first
            self._note_tier(l, t0=t0, t1=max(t_tx, t0), kind="transport")
            self._note_tier(l, t0=t0, t1=max(t_out, t0), kind="ingest")

    def finalize(self) -> SimResult:
        """Root -> reducer over the reducer in-link, then assemble."""
        cfg = self.cfg
        red_link = links_lib.Link(name="reducer", axis="reducer",
                                  gbps=self.reducer_gbps,
                                  propagation_s=cfg.propagation_s)
        self.all_links.append(red_link)
        root = self.current[0]
        if self.faults is not None:
            # fault mode: the reducer is a real host that survives every
            # epoch — its PSN/epoch gate persists across incarnations, and
            # a root that went silent without EoT is liveness-detected
            # here (the reducer is the root's "parent")
            fx = self.faults
            pkts = (vsim.stream_to_packets(root)
                    if isinstance(root, vsim.PacketStream) else root)
            recv = fx.attach_receiver(("reducer", 0))
            arrivals = []
            if pkts:
                _, st = transport.send_stream(
                    pkts, red_link, self.loss,
                    flow_id=pkts[0][1].header.flow_id, window=cfg.window,
                    timeout_s=cfg.timeout_s,
                    deliver=lambda p, t: arrivals.append((t, p)))
                self._add_flow(st)
            arrivals.sort(key=lambda a: (a[0], a[1].header.psn))
            jct = 0.0
            got_eot = False
            rec_k, rec_v = [], []
            for t, p in arrivals:
                if recv.accept(p.header):
                    jct = max(jct, t)
                    got_eot = got_eot or p.header.eot
                    if p.header.n_records:
                        rec_k.append(np.asarray(p.keys, np.int32))
                        rec_v.append(np.asarray(p.values))
            if not got_eot:
                t_last = arrivals[-1][0] if arrivals else 0.0
                fx.add_verdict(
                    "switch_crash", self.n_levels - 1, 0,
                    t_detect_rel=t_last + fx.liveness_s(
                        red_link, cfg.window, cfg.timeout_s),
                    detected_by="parent")
            arrived_k = (np.concatenate(rec_k) if rec_k
                         else np.zeros((0,), np.int32))
            arrived_v = (np.concatenate(rec_v) if rec_v
                         else np.zeros((0,) + self.carried.shape[1:],
                                       self.carried.dtype))
            self.reducer_gap = recv.gap_discards
            self.reducer_dup = recv.duplicate_discards
        elif isinstance(root, vsim.PacketStream):
            # fast path: acceptance falls out of the window algebra, so
            # the reducer's pre-merge stream is the root stream verbatim
            # and the JCT is the last accepted arrival
            if self.loss.rate > 0.0:
                arrive, _, st, self.reducer_gap = vsim.transmit_stream_lossy(
                    root, red_link, self.loss, window=cfg.window,
                    timeout_s=cfg.timeout_s)
                self._add_flow(st)
            else:
                arrive, _ = vsim.transmit_stream(root, red_link)
                self.flows.packets_sent += root.n_packets
                self.flows.wire_bytes += (
                    wire.HEADER_BYTES * root.n_packets
                    + wire.PAIR_BYTES * int(root.sizes.sum()))
            jct = max(0.0, float(arrive.max()))
            arrived_k, arrived_v = root.keys, root.values
        else:
            recv = transport.Receiver()
            arrivals: list[tuple[float, wire.Packet]] = []
            self._run_flow(root, red_link, arrivals)
            arrivals.sort(key=lambda a: (a[0], a[1].header.psn))
            jct = 0.0
            rec_k: list[np.ndarray] = []
            rec_v: list[np.ndarray] = []
            for t, p in arrivals:
                if recv.accept(p.header):
                    jct = max(jct, t)
                    if p.header.n_records:
                        rec_k.append(np.asarray(p.keys, np.int32))
                        rec_v.append(np.asarray(p.values))
            arrived_k = (np.concatenate(rec_k) if rec_k
                         else np.zeros((0,), np.int32))
            arrived_v = (np.concatenate(rec_v) if rec_v
                         else np.zeros((0,) + self.carried.shape[1:],
                                       self.carried.dtype))
            self.reducer_gap = recv.gap_discards
            self.reducer_dup = recv.duplicate_discards
        if arrived_k.size:  # the reducer host's final exact merge
            c = kvagg.sorted_combine(jnp.asarray(arrived_k),
                                     jnp.asarray(arrived_v), op=self.op)
            n_unique = int(c.n_unique)
            dk = np.asarray(c.unique_keys)[:n_unique]
            dv = np.asarray(self.aggop.finalize_values(
                c.combined_values))[:n_unique]
        else:
            n_unique, dk = 0, np.zeros((0,), np.int32)
            dv = np.zeros((0,), np.float32)

        gap = sum(n.receiver.gap_discards
                  for lvl in self.per_level_nodes for n in lvl) \
            + self.reducer_gap
        dup = sum(n.receiver.duplicate_discards
                  for lvl in self.per_level_nodes for n in lvl) \
            + self.reducer_dup
        per_level = [schema_lib.level_report(l, self.axes[l], nodes)
                     for l, nodes in enumerate(self.per_level_nodes)]
        result = SimResult(
            jct_s=jct,
            aggregate=self.aggregate,
            op=self.op,
            fanins=self.fanins,
            axes=self.axes,
            delivered_keys=dk,
            delivered_values=dv,
            delivered_records=n_unique,
            delivered_bytes=wire.stream_wire_bytes(
                n_unique, cfg.records_per_packet),
            arrived_records=int(arrived_k.shape[0]),
            link_stats=links_lib.stats_by_axis(self.all_links),
            per_level=per_level,
            retransmissions=self.flows.retransmissions,
            timeouts=self.flows.timeouts,
            packets_dropped=self.flows.packets_dropped,
            gap_discards=gap,
            duplicate_discards=dup,
            mapper_finish_s=self.mapper_finish,
        )
        # telemetry out (DESIGN.md §11): both engines publish through the
        # one schema path, so their metric series are comparable 1:1.
        # Under the fault driver an epoch that dies is discarded — the
        # driver publishes the surviving epoch's report itself.
        if self.faults is None:
            schema_lib.publish_report(result.report(), job=self.tag,
                                      engine=self.cfg.engine)
        tracer = obs_trace.get_tracer()
        if tracer.enabled and self._pid is not None:
            root_t0 = 0.0
            if isinstance(root, vsim.PacketStream):
                if root.times.size:
                    root_t0 = float(root.times[0])
            elif root:
                root_t0 = float(root[0][0])
            tid = 2 * self.n_levels
            tracer.name_thread(self._pid, tid, "reducer drain")
            tracer.add_span("reducer drain", root_t0, max(jct, root_t0),
                            cat="sim.transport", pid=self._pid, tid=tid,
                            args={"axis": "reducer"})
        return result


def _warn_deprecated(old: str) -> None:
    """Shim-emitted deprecation pointing at the unified facade
    (DESIGN.md §13).  ``stacklevel=3`` attributes the warning to the
    shim's caller, not the shim."""
    warnings.warn(
        f"{old} is deprecated; use repro.net.simulate() — the unified "
        "facade over every sim entry point (DESIGN.md §13)",
        DeprecationWarning, stacklevel=3)


def _simulate_jobs(
    specs: Sequence[JobSpec],
    admissions: Sequence[tuple[int, JobSpec]] = (),
) -> list[SimResult]:
    """The level-lockstep batch engine, with event-driven mid-run
    admission.  ``specs`` start at lockstep step 0; each ``(step, spec)``
    in ``admissions`` joins the running batch at that lockstep step —
    i.e. between tier levels of the jobs already in flight — and a job
    leaves the batch the step its last tier completes.  Jobs never
    interact (each owns its links, flows, and streams), so every result
    is bit-identical to running that spec alone on either engine: the
    batching — and therefore mid-run admission — changes kernel dispatch
    count, never results.  Results come back in ``specs`` order followed
    by ``admissions`` order."""
    entries: list[tuple[int, JobSpec]] = [(0, s) for s in specs]
    for step, s in admissions:
        step = int(step)
        if step < 0:
            raise ValueError(f"admission step {step} must be >= 0")
        entries.append((step, s))
    runs: list[_JobRun | None] = [None] * len(entries)
    results: list[SimResult | None] = [None] * len(entries)
    n_done = 0
    step = 0
    while n_done < len(entries):
        pending = []
        for i, (t0, spec) in enumerate(entries):
            if results[i] is not None or step < t0:
                continue
            if runs[i] is None:  # this step's arrivals enter the batch
                runs[i] = _JobRun(spec)
            r = runs[i]
            pending.append((i, r, step - t0, r.start_tier(step - t0)))
        works = [w for _, _, _, w in pending if w is not None]
        if works:
            vsim.dispatch_tier_ingest(works)
        for i, r, l, w in pending:
            if w is not None:
                r.finish_tier(l, w)
            if l == r.n_levels - 1:  # departure: finalize and free the slot
                results[i] = r.finalize()
                runs[i] = None
                n_done += 1
        step += 1
    return list(results)


def simulate_jobs(specs: Sequence[JobSpec]) -> list[SimResult]:
    """Deprecated: use :func:`repro.net.simulate` with a list of
    :class:`JobSpec` (DESIGN.md §13).

    Runs a batch of independent jobs, tiers stepped level by level in
    lockstep so same-depth fast-path tiers share batched kernel calls
    (``vsim.dispatch_tier_ingest``; ``planner.batch_tier_groups``
    predicts the packing).  Returns one :class:`SimResult` per spec,
    bit-identical to running each spec alone — the batching changes
    kernel dispatch count, never results.
    """
    _warn_deprecated("simulate_jobs")
    return _simulate_jobs(specs)


def simulate_job(
    keys,
    values,
    *,
    fanins: Sequence[int],
    plan: dataplane.CascadePlan | None = None,
    op: str = "sum",
    aggregate: bool = True,
    cfg: NetConfig | None = None,
    axes: Sequence[str] | None = None,
    mapper_delay: Callable[[int], float] | None = None,
    job_id: int = 0,
    tag: str = "",
) -> SimResult:
    """Deprecated: use :func:`repro.net.simulate` with a single
    :class:`JobSpec` (DESIGN.md §13).

    Runs one job end to end over the emulated network.  ``keys``/
    ``values`` are the global mapper output (split contiguously among
    ``prod(fanins)`` mappers); ``plan`` gives each tree level its node
    geometry (default: exact capacity-0 nodes).  ``mapper_delay(m)``
    adds per-mapper start delay — the straggler-injection hook shared with
    ``runtime.fault_tolerance``.  ``tag`` names the run's metric series
    and trace track (DESIGN.md §11; default ``job<job_id>``).
    """
    _warn_deprecated("simulate_job")
    return _simulate_jobs([JobSpec(
        keys=keys, values=values, fanins=fanins, plan=plan, op=op,
        aggregate=aggregate, cfg=cfg, axes=axes, mapper_delay=mapper_delay,
        job_id=job_id, tag=tag)])[0]


# ---------------------------------------------------------------------------
# Failure-recovery runtime: epoch-restart driver (DESIGN.md §12).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultSimResult:
    """One job survived its failure schedule: the clean final epoch plus
    the whole recovery history.

    ``result`` is the surviving incarnation's :class:`SimResult` — its
    delivered table is THE job output, and the exactly-once invariant
    says it equals the no-failure grouped-combine.  ``jct_s`` is absolute:
    every aborted epoch's detection latency, every restart delay, and the
    final epoch's completion — the recovery JCT penalty is
    ``jct_s - <no-failure jct>``.
    """

    result: SimResult  # the final (clean) epoch's run
    jct_s: float  # absolute completion time across all epochs
    epochs: int  # incarnations run (1 = no restart was needed)
    verdicts: list  # every FailureVerdict, in detection order
    applied: list  # the verdicts that actually triggered restarts
    bypass: tuple[tuple[int, int], ...]  # positions degraded to relays
    epoch_log: list[dict]  # per epoch: start, detect/jct, verdict counts
    repair: object | None = None  # planner.PlacementRepair (fat-tree runs)

    def delivered_table(self) -> dict[int, float]:
        return self.result.delivered_table()


def _run_fault_epochs(spec: JobSpec, injector, policy,
                      on_restart=None) -> FaultSimResult:
    """The epoch-restart loop (DESIGN.md §12).  Runs the job; when any
    failure verdict lands, dates the restart from the *earliest* verdict
    (later ones had not been detected yet — they re-detect in the next
    incarnation), turns crash/link verdicts into forward-only bypass
    relays, bumps the epoch, and replays every mapper (the pipeline is a
    pure function of the mapper index).  Surviving switches keep their
    PSN gates across epochs; the packet epoch tag is what lets them
    accept the replay instead of discarding it as duplicates.  Terminates
    because every applied verdict removes a failure from play and clean
    epochs return — ``policy.max_epochs`` is the storm backstop."""
    from repro.runtime import fault_tolerance as ft_lib

    if policy is None:
        policy = ft_lib.FaultPolicy()
    fanins = tuple(int(f) for f in spec.fanins)
    for e in getattr(injector, "events", ()):
        if not 0 <= e.level < len(fanins):
            raise ValueError(f"failure event targets level {e.level}; the "
                             f"tree has levels 0..{len(fanins) - 1}")
        n_sw = int(np.prod(fanins[e.level + 1:], dtype=np.int64))
        if not 0 <= e.switch < n_sw:
            raise ValueError(
                f"failure event targets switch {e.switch} at level "
                f"{e.level}, which has {n_sw} switch(es) — an out-of-range "
                f"event would silently never fire")
        if e.child is not None and not 0 <= e.child < fanins[e.level]:
            raise ValueError(f"failure event child {e.child} out of range "
                             f"for fan-in {fanins[e.level]} at level "
                             f"{e.level}")
    base_cfg = spec.cfg or NetConfig()
    tag = spec.tag or f"job{spec.job_id}"
    receivers: dict = {}
    bypass: set = set()
    fired_wipes: set = set()
    t_start = 0.0
    all_verdicts: list = []
    applied: list = []
    epoch_log: list[dict] = []
    for epoch in range(policy.max_epochs + 1):
        ctx = _FaultCtx(
            injector=injector, policy=policy, epoch=epoch,
            t_start_s=t_start, bypass=frozenset(bypass),
            fired_wipes=fired_wipes, receivers=receivers)
        run = _JobRun(dataclasses.replace(
            spec, cfg=dataclasses.replace(base_cfg, epoch=epoch), tag=tag),
            faults=ctx)
        for l in range(run.n_levels):
            w = run.start_tier(l)
            if w is not None:
                vsim.dispatch_tier_ingest([w])
                run.finish_tier(l, w)
        result = run.finalize()
        if not ctx.verdicts:
            epoch_log.append({"epoch": epoch, "t_start_s": t_start,
                              "jct_s": result.jct_s, "n_verdicts": 0,
                              "n_applied": 0})
            schema_lib.publish_report(result.report(), job=tag,
                                      engine=base_cfg.engine)
            fsr = FaultSimResult(
                result=result, jct_s=t_start + result.jct_s,
                epochs=epoch + 1, verdicts=all_verdicts, applied=applied,
                bypass=tuple(sorted(bypass)), epoch_log=epoch_log)
            schema_lib.publish_fault_report(
                schema_lib.fault_report_dict(fsr), job=tag,
                engine=base_cfg.engine)
            _trace_fault_timeline(tag, fsr)
            return fsr
        vs = sorted(ctx.verdicts, key=lambda v: v.t_detect_s)
        all_verdicts.extend(vs)
        t_detect = vs[0].t_detect_s  # absolute
        now = [v for v in vs if v.t_detect_s <= t_detect]
        for v in now:
            applied.append(v)
            if v.kind in ("switch_crash", "link_down"):
                # dead (or unreachable) position: re-route its subtree
                # forward-only; the replacement relay is a new incarnation
                bypass.add((v.level, v.switch))
                receivers.pop((v.level, v.switch), None)
        epoch_log.append({"epoch": epoch, "t_start_s": t_start,
                          "t_detect_s": t_detect,
                          "n_verdicts": len(vs), "n_applied": len(now)})
        t_start = t_detect + policy.restart_delay_s
        # wipes scheduled before the restart boundary corrupted state the
        # replay rebuilds from scratch anyway — they have fired
        for i, e in enumerate(injector.events):
            if (e.kind == "table_wipe" and i not in fired_wipes
                    and e.t_s < t_start):
                fired_wipes.add(i)
        if on_restart is not None:
            new_plan = on_restart(tuple(sorted(bypass)), epoch)
            if new_plan is not None:
                spec = dataclasses.replace(spec, plan=new_plan)
    raise RuntimeError(
        f"failure schedule did not quiesce within {policy.max_epochs} "
        f"restarts ({len(all_verdicts)} verdicts); raise max_epochs or "
        f"thin the schedule")


def _trace_fault_timeline(tag: str, fsr: FaultSimResult) -> None:
    """The failure/recovery timeline as virtual-time trace spans: one
    lane of epochs, one lane of verdicts (detection -> restart)."""
    tracer = obs_trace.get_tracer()
    if not tracer.enabled:
        return
    pid = tracer.new_track(f"faults {tag}")
    tracer.name_thread(pid, 0, "epochs")
    tracer.name_thread(pid, 1, "verdicts")
    for rec in fsr.epoch_log:
        t0 = rec["t_start_s"]
        t1 = rec.get("t_detect_s", t0 + rec.get("jct_s", 0.0))
        tracer.add_span(f"epoch {rec['epoch']}", t0, max(t1, t0),
                        cat="sim.fault", pid=pid, tid=0, args=dict(rec))
    for v in fsr.verdicts:
        end = next((r.get("t_detect_s", v.t_detect_s)
                    for r in fsr.epoch_log if r["epoch"] == v.epoch),
                   v.t_detect_s)
        tracer.add_span(
            f"{v.kind} L{v.level}.s{v.switch} ({v.detected_by})",
            v.t_detect_s, max(end, v.t_detect_s), cat="sim.fault",
            pid=pid, tid=1,
            args={"kind": v.kind, "level": v.level, "switch": v.switch,
                  "epoch": v.epoch, "detected_by": v.detected_by})


def _simulate_spec_with_faults(spec: JobSpec, injector,
                               policy=None) -> FaultSimResult:
    """One :class:`JobSpec` under a failure schedule: epoch-restart
    driver with the injector's own straggler delays as the default
    ``mapper_delay`` and ``"faulted"`` as the default telemetry tag."""
    if spec.mapper_delay is None and getattr(injector, "delays", None):
        spec = dataclasses.replace(spec, mapper_delay=injector)
    if not spec.tag:
        spec = dataclasses.replace(spec, tag="faulted")
    return _run_fault_epochs(spec, injector, policy)


def simulate_job_with_faults(
    keys,
    values,
    *,
    fanins: Sequence[int],
    injector,
    policy=None,
    plan: dataplane.CascadePlan | None = None,
    op: str = "sum",
    aggregate: bool = True,
    cfg: NetConfig | None = None,
    axes: Sequence[str] | None = None,
    mapper_delay: Callable[[int], float] | None = None,
    job_id: int = 0,
    tag: str = "",
) -> FaultSimResult:
    """Deprecated: use :func:`repro.net.simulate` with ``faults=``
    (DESIGN.md §13).

    One job under a failure schedule (DESIGN.md §12).  ``injector`` is a
    ``runtime.fault_tolerance.FailureInjector`` — switch crashes,
    link-down windows, and table wipes at absolute simulated times;
    ``policy`` a ``FaultPolicy`` (detection backoff / retry budget /
    liveness / restart delay).  The job restarts as epochs until an
    incarnation completes clean; the returned :class:`FaultSimResult`
    carries that incarnation's delivered table (exactly-once: equal to
    the no-failure grouped-combine), the total absolute JCT, and the
    full verdict history.  ``mapper_delay`` defaults to the injector's
    own straggler delays."""
    _warn_deprecated("simulate_job_with_faults")
    return _simulate_spec_with_faults(
        JobSpec(keys=keys, values=values, fanins=fanins, plan=plan, op=op,
                aggregate=aggregate, cfg=cfg, axes=axes,
                mapper_delay=mapper_delay, job_id=job_id, tag=tag),
        injector, policy)


def _job_plan_spec(
    job_plan,
    keys,
    values,
    *,
    cfg: NetConfig | None,
    aggregate: bool,
    mapper_delay: Callable[[int], float] | None,
) -> JobSpec:
    """A controller-admitted job (``planner.JobPlan``) as a
    :class:`JobSpec`: cascade geometry from its ``ConfigureMsg``, link
    rates from its ``AggregationTree`` levels."""
    cfg = cfg or NetConfig()
    cascade = dataplane.plan_from_configure(job_plan.configure)
    tree = job_plan.tree
    cfg = dataclasses.replace(
        cfg, link_gbps=tuple(l.link_gbps for l in tree.levels))
    return JobSpec(
        keys=keys, values=values, fanins=job_plan.configure.fanins,
        plan=cascade, op=job_plan.configure.op, aggregate=aggregate,
        cfg=cfg, axes=tree.axes, mapper_delay=mapper_delay,
        job_id=job_plan.configure.tree_id)


def _job_plan_specs(
    job_plans: Sequence,
    keys_list: Sequence,
    values_list: Sequence,
    *,
    cfg: NetConfig | None = None,
    aggregate: bool = True,
    mapper_delays: Sequence[Callable[[int], float] | None] | None = None,
) -> list[JobSpec]:
    """An admitted batch (``JobScheduler.plan_all`` output) as specs."""
    if not len(job_plans) == len(keys_list) == len(values_list):
        raise ValueError("job_plans, keys_list, values_list must align")
    if mapper_delays is not None and len(mapper_delays) != len(job_plans):
        raise ValueError("mapper_delays must align with job_plans")
    return [
        _job_plan_spec(
            jp, keys_list[i], values_list[i], cfg=cfg, aggregate=aggregate,
            mapper_delay=mapper_delays[i] if mapper_delays is not None
            else None)
        for i, jp in enumerate(job_plans)]


def simulate_job_plan(
    job_plan,
    keys,
    values,
    *,
    cfg: NetConfig | None = None,
    aggregate: bool = True,
    mapper_delay: Callable[[int], float] | None = None,
) -> SimResult:
    """Deprecated: use :func:`repro.net.simulate` with a
    ``planner.JobPlan`` (DESIGN.md §13).

    Runs a controller-admitted job (``planner.JobPlan``) end to end.
    The cascade geometry comes from the plan's ``ConfigureMsg`` (the §4.2.2
    per-tree memory partition split across levels), the link rates from its
    ``AggregationTree`` levels — the simulator consuming exactly what the
    ``JobScheduler`` emitted, so measured drain can be fed back via
    :func:`drain_calibration` + ``JobScheduler.calibrate``.
    """
    _warn_deprecated("simulate_job_plan")
    return _simulate_jobs([_job_plan_spec(
        job_plan, keys, values, cfg=cfg, aggregate=aggregate,
        mapper_delay=mapper_delay)])[0]


def simulate_job_plans(
    job_plans: Sequence,
    keys_list: Sequence,
    values_list: Sequence,
    *,
    cfg: NetConfig | None = None,
    aggregate: bool = True,
    mapper_delays: Sequence[Callable[[int], float] | None] | None = None,
) -> list[SimResult]:
    """Deprecated: use :func:`repro.net.simulate` with a list of
    ``planner.JobPlan`` (DESIGN.md §13).

    Runs a whole admitted batch (``JobScheduler.plan_all`` output)
    concurrently in one lockstep batch, so tiers of different jobs that
    share a kernel-static signature ride ONE batched ``tier_ingest``
    dispatch under the vectorized engine.  Results are bit-identical to
    per-job :func:`simulate_job_plan` runs.
    """
    _warn_deprecated("simulate_job_plans")
    return _simulate_jobs(_job_plan_specs(
        job_plans, keys_list, values_list, cfg=cfg, aggregate=aggregate,
        mapper_delays=mapper_delays))


def drain_calibration(result: SimResult) -> dict[str, float]:
    """Measured-vs-modeled drain factors for ``JobScheduler.calibrate``.

    The planner's drain model charges payload bytes at line rate; the wire
    also carries headers and retransmissions.  The factor per axis is
    ``wire_bytes / payload_bytes`` (>= 1), i.e. how much longer the level
    really takes to drain than the payload-only model claims.
    """
    out = {}
    for axis, s in result.link_stats.items():
        if axis == "reducer":
            continue
        payload = s["payload_bytes"]
        out[axis] = (s["bytes"] / payload) if payload > 0 else 1.0
    return out


def jct_comparison(
    keys,
    values,
    *,
    fanins: Sequence[int],
    plan: dataplane.CascadePlan | None = None,
    op: str = "sum",
    cfg: NetConfig | None = None,
    axes: Sequence[str] | None = None,
) -> dict:
    """The Fig. 10 measurement: JCT with in-network aggregation vs the
    host-only baseline on the same network, same loss pattern.

    The returned dict is JSON-able except for ``_results``, the raw
    ``(switchagg, host_only)`` SimResult pair for callers (the JCT bench)
    that need more than the report scalars — drop the key before dumping.
    """
    sw, host = _simulate_jobs([
        JobSpec(keys=keys, values=values, fanins=fanins, plan=plan, op=op,
                aggregate=True, cfg=cfg, axes=axes, tag="switchagg"),
        JobSpec(keys=keys, values=values, fanins=fanins, plan=plan, op=op,
                aggregate=False, cfg=cfg, axes=axes, tag="host_only")])
    return {
        "switchagg": sw.report(),
        "host_only": host.report(),
        "jct_switchagg_s": sw.jct_s,
        "jct_host_only_s": host.jct_s,
        "jct_saved": 1.0 - sw.jct_s / host.jct_s if host.jct_s > 0 else 0.0,
        "reduction": 1.0 - (sw.arrived_records
                            / max(1, host.arrived_records)),
        "_results": (sw, host),
    }


def _fat_tree_spec(
    ft,
    keys,
    values,
    *,
    placement,
    op: str,
    cfg: NetConfig | None,
    mapper_delay: Callable[[int], float] | None = None,
    job_id: int = 0,
    tag: str = "",
) -> JobSpec:
    """One fat-tree incast as a :class:`JobSpec`: the topology's own
    per-tier links, aggregation only where ``placement`` put nodes."""
    plan = dataplane.plan_from_placement(placement, op=op)
    topo_links = ft.link_tiers()
    cfg = cfg or NetConfig()
    cfg = dataclasses.replace(
        cfg, link_gbps=tuple(l.gbps for l in topo_links),
        reducer_gbps=(cfg.reducer_gbps if cfg.reducer_gbps is not None
                      else ft.edge_gbps))
    return JobSpec(
        keys=keys, values=values,
        fanins=tuple(l.fanin for l in topo_links), plan=plan, op=op,
        aggregate=True, cfg=cfg, axes=tuple(l.axis for l in topo_links),
        mapper_delay=mapper_delay, job_id=job_id, tag=tag)


def _fat_tree_job(
    ft,
    keys,
    values,
    *,
    placement=None,
    policy: str = "auto",
    op: str = "sum",
    cfg: NetConfig | None = None,
    mapper_delay: Callable[[int], float] | None = None,
    job_id: int = 0,
    tag: str = "",
) -> SimResult:
    """One multi-rack incast over a ``planner.FatTreeTopology``."""
    from repro.core import planner  # local import: core.planner is upstream

    if placement is None:
        n_mappers = ft.n_hosts
        keys_arr = np.asarray(keys)
        per_host = -(-keys_arr.shape[0] // max(1, n_mappers))
        placement = planner.place_aggregation_tree(
            ft, per_host_pairs=per_host,
            key_variety=int(keys_arr.max(initial=0)) + 1, policy=policy)
    return _simulate_jobs([_fat_tree_spec(
        ft, keys, values, placement=placement, op=op, cfg=cfg,
        mapper_delay=mapper_delay, job_id=job_id, tag=tag)])[0]


def simulate_fat_tree_job(
    ft,
    keys,
    values,
    *,
    placement=None,
    policy: str = "auto",
    op: str = "sum",
    cfg: NetConfig | None = None,
    mapper_delay: Callable[[int], float] | None = None,
    job_id: int = 0,
) -> SimResult:
    """Deprecated: use :func:`repro.net.simulate` with a
    ``planner.FatTreeTopology`` (DESIGN.md §13).

    Runs one multi-rack incast over a ``planner.FatTreeTopology``.  The
    emulated network is the fat-tree's own per-tier links — host "edge"
    links at ``edge_gbps``, oversubscribed ToR "aggr" uplinks, pod
    "core" uplinks — with the reducer in-link at the host rate (the
    reducer is just another host).  Each tier's switches run aggregation
    only where the ``placement`` (or a fresh ``policy`` search) put nodes;
    unplaced tiers forward, so host-only / ToR-only / full-tree deployments
    are all the same simulation with different `LevelSpec.enabled` rows.
    """
    _warn_deprecated("simulate_fat_tree_job")
    return _fat_tree_job(
        ft, keys, values, placement=placement, policy=policy, op=op,
        cfg=cfg, mapper_delay=mapper_delay, job_id=job_id)


def _fat_tree_job_with_faults(
    ft,
    keys,
    values,
    *,
    injector,
    fault_policy=None,
    placement=None,
    policy: str = "auto",
    op: str = "sum",
    cfg: NetConfig | None = None,
    mapper_delay: Callable[[int], float] | None = None,
    job_id: int = 0,
    tag: str = "",
) -> FaultSimResult:
    """Fat-tree incast under a failure schedule with the control plane in
    the recovery loop (``planner.repair_placement`` per restart)."""
    from repro.core import planner  # local import: core.planner is upstream

    keys_arr = np.asarray(keys)
    per_host = -(-keys_arr.shape[0] // max(1, ft.n_hosts))
    key_variety = int(keys_arr.max(initial=0)) + 1
    if placement is None:
        placement = planner.place_aggregation_tree(
            ft, per_host_pairs=per_host, key_variety=key_variety,
            policy=policy)
    spec = _fat_tree_spec(
        ft, keys, values, placement=placement, op=op, cfg=cfg,
        mapper_delay=mapper_delay, job_id=job_id, tag=tag or "faulted")
    state: dict = {"repair": None}

    def on_restart(bypass, epoch):
        rep = planner.repair_placement(
            ft, placement, failed=bypass, per_host_pairs=per_host,
            key_variety=key_variety)
        state["repair"] = rep
        return dataplane.plan_from_placement(rep.placement, op=op)

    fsr = _run_fault_epochs(spec, injector, fault_policy,
                            on_restart=on_restart)
    fsr.repair = state["repair"]
    return fsr


def simulate_fat_tree_job_with_faults(
    ft,
    keys,
    values,
    *,
    injector,
    fault_policy=None,
    placement=None,
    policy: str = "auto",
    op: str = "sum",
    cfg: NetConfig | None = None,
    mapper_delay: Callable[[int], float] | None = None,
    job_id: int = 0,
    tag: str = "",
) -> FaultSimResult:
    """Deprecated: use :func:`repro.net.simulate` with a
    ``planner.FatTreeTopology`` and ``faults=`` (DESIGN.md §13).

    The fat-tree incast under a failure schedule, with the control plane
    in the recovery loop: after each restart the driver calls
    ``planner.repair_placement`` on the positions declared dead, and
    the next epoch runs the *repaired* placement — dead switches become
    forward-only relays, and a tier that lost every switch is re-placed
    around entirely (DESIGN.md §12).  The final ``PlacementRepair`` (its
    degraded byte model is the modeled JCT-penalty source) rides on
    ``FaultSimResult.repair``."""
    _warn_deprecated("simulate_fat_tree_job_with_faults")
    return _fat_tree_job_with_faults(
        ft, keys, values, injector=injector, fault_policy=fault_policy,
        placement=placement, policy=policy, op=op, cfg=cfg,
        mapper_delay=mapper_delay, job_id=job_id, tag=tag)


def fat_tree_jct_comparison(
    ft,
    keys,
    values,
    *,
    per_host_pairs: int | None = None,
    key_variety: int | None = None,
    op: str = "sum",
    policies: Sequence[str] = ("host_only", "tor_only", "full"),
    cfg: NetConfig | None = None,
) -> dict:
    """The rack-scale Fig. 10: one mapper stream, one fat-tree network,
    JCT and per-tier wire bytes for each placement policy side by side.

    All policies run as ONE :func:`simulate_jobs` batch, so under the
    vectorized engine their same-depth aggregating tiers share kernel
    dispatches (e.g. full's ToR tier batches with tor_only's).  The
    returned dict maps each policy to its report plus a ``placement``
    record (placed tiers, modeled scarce bytes); ``jct_s`` collects the
    headline JCTs.  ``_results`` holds the raw SimResults (drop before
    JSON-dumping).  For any aggregating placement the delivered table is
    exact, so host-only vs ToR-only vs full-tree differ only in where
    bytes die — what the placement search optimizes.
    """
    from repro.core import planner  # local import: core.planner is upstream

    keys_arr = np.asarray(keys)
    if per_host_pairs is None:
        per_host_pairs = -(-keys_arr.shape[0] // max(1, ft.n_hosts))
    if key_variety is None:
        key_variety = int(keys_arr.max(initial=0)) + 1
    out: dict = {"policies": list(policies), "jct_s": {},
                 "scarce_axis": ft.scarce_uplink_axis(), "_results": {}}
    placements = {
        pol: planner.place_aggregation_tree(
            ft, per_host_pairs=per_host_pairs, key_variety=key_variety,
            policy=pol)
        for pol in policies}
    results = _simulate_jobs([
        _fat_tree_spec(ft, keys, values, placement=placements[pol], op=op,
                       cfg=cfg, tag=pol)
        for pol in policies])
    for pol, res in zip(policies, results):
        placement = placements[pol]
        rep = res.report()
        rep["placement"] = {
            "policy": pol,
            "tiers": list(placement.tiers),
            "n_agg_switches": placement.n_agg_switches,
            "modeled_scarce_bytes": placement.scarce_uplink_bytes,
            "modeled_reducer_bytes": placement.reducer_bytes,
        }
        out[pol] = rep
        out["jct_s"][pol] = res.jct_s
        out["_results"][pol] = res
    return out
