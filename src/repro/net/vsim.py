"""JAX-vectorized tier engine for the packet simulator (DESIGN.md §10).

The node engine (``net.sim``) pays ~two jitted dispatches per packet per
switch (``fpe_aggregate`` + the per-packet BPE combine inside
``dataplane.LevelState``), which caps it at a few pods.  This module
collapses all of a tier's per-packet device work into ONE jitted call:
every switch at the tier is stepped through its full accepted-packet
sequence by ``tier_ingest`` — a ``vmap`` over switches of a ``lax.scan``
over packets, each step the same resumed-table ``kvagg.fpe_aggregate``
(+ per-packet ``sorted_combine`` when the level runs BPE) the node engine
issues eagerly.  Because the per-step computation is literally the same
jitted graph on the same operands in the same order, the per-packet
eviction streams and final tables are BIT-identical to the node engine's,
not merely equal when grouped — the property the differential harness
(``tests/test_sim_parity.py``) pins.

Host/device boundary: transport, link timing, packetization, and PSN
acceptance stay on the host (they are cheap arithmetic; the node engine's
cost is dispatch count, not math).  The host path is the
``tier_start`` → ``dispatch_tier_ingest`` → ``tier_finish`` trio
(``run_tier_fast`` bundles the three for one tier), and it covers ANY
loss rate:

* at loss=0, go-back-N never rewinds and transport reduces to the FIFO
  chain ``depart_i = max(depart_{i-1}, ready_i) + ser_i`` (no timeouts
  fire, so the window adds no waiting) — one numpy pass per packet rank
  over every link at the tier.
* under loss the go-back-N window itself runs in array form
  (:func:`_windowed_transport`): every link steps its burst rounds in
  lockstep — one vectorized ``loss.drop_array`` draw per round over the
  ``[links, window]`` rectangle, retransmit/timeout state as per-link
  lanes — until a fixed point (every sender done).  The same window
  algebra that drives the sender yields the receiver side for free:
  within a burst from ``base``, packets before the first loss are the
  accepted ones (PSN == expected, exactly once), later survivors are
  gap discards, so acceptance needs no per-packet Receiver walk.
  Timing replays the node sender's float ops transmission by
  transmission (one pass per (round, slot) over ``[links]`` lanes),
  so accepted-arrival times, retransmit byte/queue telemetry, and JCT
  stay BIT-identical to ``transport.send_stream``.
* ``dispatch_tier_ingest`` packs the kernel work of MANY tiers — the
  concurrent jobs of a batched ``repro.net.simulate`` — into as few
  ``tier_ingest`` calls as possible: works sharing a kernel-static
  signature (capacity, ways, op, bpe, exact_stream, packet geometry)
  concatenate their switch lanes into ONE batch.  ``vmap`` lanes are
  independent, so each job's slice is bit-identical to its solo run.

Shape policy: ``S`` (switches) and ``P`` (packets) pad to the next power
of two, ``R`` (records) to the config's fixed packet capacity — the same
pad-to-pow2 bucketing as the streaming ingest (DESIGN.md §8), so pod and
mapper counts retrace O(log) times, not O(n).  Padding packets are
all-``EMPTY_KEY`` and provably leave a resumed table untouched on both
FPE paths.

Scope: a tier qualifies when it aggregates with ``capacity > 0``.
Capacity-0 (exact unbounded) and placement-disabled (forward-only)
levels keep their existing host paths — they issue no per-packet FPE
dispatches, so there is nothing to batch, and reusing ``LevelState``
keeps them parity-by-construction.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataplane, kvagg
from . import links as links_lib
from . import transport, wire

_EMPTY = int(kvagg.EMPTY_KEY)


def _pow2(n: int, floor: int = 1) -> int:
    """Next power of two >= max(n, floor) — the batch-shape bucket."""
    return max(floor, 1 << max(0, int(n) - 1).bit_length())


def supports(spec: dataplane.LevelSpec | None) -> bool:
    """True when a tier's per-packet FPE work can be batched on device.

    ``None`` (host-only baseline), disabled (forward-only relay), and
    capacity-0 (exact unbounded) levels do no per-packet FPE and keep
    the node engine's host paths.
    """
    return spec is not None and spec.enabled and spec.capacity > 0


@functools.partial(
    jax.jit,
    static_argnames=("capacity", "ways", "op", "bpe", "exact_stream"))
def tier_ingest(keys, values, *, capacity: int, ways: int, op: str,
                bpe: bool, exact_stream: bool):
    """Step every switch of one tier through its packet sequence at once.

    ``keys`` is ``[S, P, R]`` int32 (``EMPTY_KEY``-padded), ``values``
    ``[S, P, R, *lanes]`` in the op's carried representation.  Returns
    ``(table_keys [S, C], table_values [S, C, *lanes], evict_keys
    [S, P, R], evict_values [S, P, R, *lanes], n_evict [S, P],
    n_out [S, P])`` where ``C`` is the effective flat table size.  Step
    *p* of switch *s* is exactly ``fpe_aggregate(keys[s, p],
    values[s, p], ..., table_keys=<table after step p-1>)`` followed by
    the per-packet BPE combine — the node engine's eager sequence,
    batched.

    A packet of ``R`` records evicts at most ``R`` entries (table
    occupancy never decreases), so the batched path's ``[R + cap]``
    eviction stream compacts losslessly to ``[R]`` *before* the BPE
    combine: ``nonzero(size=R)`` gathers the real entries front-packed in
    order — a pure permutation, no float op touches the values — and the
    combine then runs on ``[R]`` instead of ``[R + cap]`` (at capacity
    2048 that is the difference between sorting 2112 slots per packet and
    sorting 59).  Bit-parity holds because ``sorted_combine`` reduces each
    key's occurrences by scatter in ascending index order: dropping EMPTY
    slots elsewhere in the stream changes neither a key's value sequence
    nor its order.  ``n_evict`` (the pre-combine real-eviction count) lets
    the host verify the ``<= R`` invariant actually held.
    """
    w, n_buckets, cap = kvagg._fpe_geometry(capacity, ways)
    lane_shape = values.shape[3:]
    if exact_stream and values.dtype == jnp.float32:
        return _tier_ingest_packed(keys, values, capacity=capacity,
                                   ways=ways, op=op, bpe=bpe)

    def one_switch(ks, vs):
        def step(carry, pkt):
            tk, tv = carry
            pk, pv = pkt
            res = kvagg.fpe_aggregate(
                pk, pv, capacity=capacity, ways=ways, op=op,
                exact_stream=exact_stream, table_keys=tk, table_values=tv)
            n_ev = jnp.sum(res.evict_keys != kvagg.EMPTY_KEY
                           ).astype(jnp.int32)
            ek, ev = res.evict_keys, res.evict_values
            if ek.shape[0] > pk.shape[0]:  # compact [R + cap] -> [R]
                real = ek != kvagg.EMPTY_KEY
                (idx,) = jnp.nonzero(real, size=pk.shape[0],
                                     fill_value=ek.shape[0])
                ek = jnp.concatenate(
                    [ek, jnp.full((1,), kvagg.EMPTY_KEY, ek.dtype)])[idx]
                ev = jnp.concatenate(
                    [ev, jnp.zeros((1,) + ev.shape[1:], ev.dtype)])[idx]
            if bpe:  # per-packet eviction combine, fixed shape
                c = kvagg.sorted_combine(ek, ev, op=op)
                ek, ev = c.unique_keys, c.combined_values
            n_out = jnp.sum(ek != kvagg.EMPTY_KEY).astype(jnp.int32)
            return (res.table_keys, res.table_values), (ek, ev, n_ev, n_out)

        init = (jnp.full((cap,), kvagg.EMPTY_KEY, jnp.int32),
                jnp.zeros((cap,) + lane_shape, values.dtype))
        (tk, tv), (ek, ev, ne, no) = jax.lax.scan(step, init, (ks, vs))
        return tk, tv, ek, ev, ne, no

    return jax.vmap(one_switch)(keys, values)


def _tier_ingest_packed(keys, values, *, capacity: int, ways: int, op: str,
                        bpe: bool):
    """``tier_ingest``'s exact-stream body with keys and value lanes
    packed into ONE table array.

    ``kvagg._fpe_scan``'s per-record step costs two gathers and two
    scatters per record (separate key/value tables); under ``vmap`` those
    batched gathers/scatters dominate the kernel on CPU.  Bitcasting keys
    (int32 -> float32, ``lax.bitcast_convert_type``) into lane 0 of the
    value table halves them.  The selection logic (hit / first-empty /
    evict-shift) is replicated branch for branch, and no arithmetic ever
    touches the bitcast key lane — every float is moved or combined by
    exactly the expressions of the reference step, so tables and eviction
    streams stay BIT-identical to ``kvagg.fpe_aggregate``.
    """
    aggop = kvagg.aggops.get(op)
    w, n_buckets, cap = kvagg._fpe_geometry(capacity, ways)
    lane_shape = values.shape[3:]
    lane_nd = len(lane_shape)
    lanes = 1
    for d in lane_shape:
        lanes *= d
    rpp = keys.shape[2]
    vals_flat = values.reshape(values.shape[:3] + (lanes,))
    empty_f = jax.lax.bitcast_convert_type(kvagg.EMPTY_KEY, jnp.float32)

    def one_switch(ks, vs):  # ks [P, R], vs [P, R, lanes]
        def rec_step(tab, inp):  # tab [n_buckets, w, 1 + lanes]
            k, v = inp  # k scalar int32, v [lanes] float32
            b = kvagg.hash_key(k, n_buckets)
            row = tab[b]  # [w, 1 + lanes] — ONE gather
            row_k = jax.lax.bitcast_convert_type(row[:, 0], jnp.int32)
            row_v = row[:, 1:].reshape((w,) + lane_shape)
            v_l = v.reshape(lane_shape)
            is_pad = k == kvagg.EMPTY_KEY

            hit = row_k == k  # [w]
            any_hit = jnp.any(hit) & ~is_pad
            empty = row_k == kvagg.EMPTY_KEY
            any_empty = jnp.any(empty) & ~is_pad
            empty_idx = jnp.argmax(empty)  # first empty way
            hit_l = hit.reshape(hit.shape + (1,) * lane_nd)

            # --- hit: aggregate into the matching way (key lane kept)
            agg_v = jnp.where(hit_l, aggop.combine(row_v, v_l), row_v)
            agg_row = jnp.concatenate(
                [row[:, :1], agg_v.reshape(w, lanes)], axis=1)

            # packed (key, value) record for insert / shift-in
            kv = jnp.concatenate(
                [jax.lax.bitcast_convert_type(k, jnp.float32)[None], v])

            # --- miss+empty: insert at first empty way
            ins_row = row.at[empty_idx].set(kv)

            # --- miss+full: evict way 0, shift left, insert at last way
            ev_k, ev_v = row_k[0], row_v[0]
            sh_row = jnp.concatenate([row[1:], kv[None]])

            new_row = jnp.where(
                any_hit, agg_row, jnp.where(any_empty, ins_row, sh_row))
            evicted = (~any_hit) & (~any_empty) & (~is_pad)
            out_k = jnp.where(evicted, ev_k, kvagg.EMPTY_KEY)
            out_v = jnp.where(evicted, ev_v, jnp.zeros_like(ev_v))

            new_row = jnp.where(is_pad, row, new_row)
            tab = tab.at[b].set(new_row)  # ONE scatter
            return tab, (out_k, out_v.reshape(lanes))

        def pkt_step(tab, pkt):
            pk, pv = pkt
            # modest unroll trims scan-iteration overhead on CPU without
            # the compile-time blowup of a full R-way unroll
            tab, (ek, ev) = jax.lax.scan(rec_step, tab, (pk, pv),
                                         unroll=min(4, rpp))
            n_ev = jnp.sum(ek != kvagg.EMPTY_KEY).astype(jnp.int32)
            if bpe:  # per-packet eviction combine, fixed shape
                c = kvagg.sorted_combine(
                    ek, ev.reshape((rpp,) + lane_shape), op=op)
                ek = c.unique_keys
                ev = c.combined_values.reshape(rpp, lanes)
            n_out = jnp.sum(ek != kvagg.EMPTY_KEY).astype(jnp.int32)
            return tab, (ek, ev, n_ev, n_out)

        tab0 = jnp.concatenate(
            [jnp.full((n_buckets, w, 1), empty_f, jnp.float32),
             jnp.zeros((n_buckets, w, lanes), jnp.float32)], axis=2)
        tab, (ek, ev, ne, no) = jax.lax.scan(pkt_step, tab0, (ks, vs))
        tk = jax.lax.bitcast_convert_type(
            tab[:, :, 0], jnp.int32).reshape(cap)
        tv = tab[:, :, 1:].reshape((cap,) + lane_shape)
        return tk, tv, ek, ev.reshape((ek.shape[0], rpp) + lane_shape), ne, no

    return jax.vmap(one_switch)(keys, vals_flat)


# --------------------------------------------------------------------------
# fast path: packet streams as arrays, whole tiers as numpy passes
# --------------------------------------------------------------------------


@dataclasses.dataclass
class PacketStream:
    """One sender edge's packet stream in array form (DESIGN.md §10).

    Packet ``i`` carries ``sizes[i]`` records under PSN ``i`` and is ready
    to transmit at ``times[i]``; the last packet always carries the
    end-of-task flag (every emitter in this simulator closes its stream
    with EoT, on an empty packet if need be).  ``keys``/``values`` are the
    concatenated payloads — ``values`` always has the op's canonical
    ``[N, *lanes]`` carried shape, even when ``N == 0``.
    """

    job_id: int
    flow_id: int
    level: int  # the receiving tier (header ``level`` field)
    times: np.ndarray  # [P] float64 per-packet ready times
    sizes: np.ndarray  # [P] int64 records per packet
    keys: np.ndarray  # [sum(sizes)] int32
    values: np.ndarray  # [sum(sizes), *lanes]
    epoch: int = 0  # restart incarnation stamped on every header (§12)

    @property
    def n_packets(self) -> int:
        return int(self.sizes.shape[0])


def stream_from_records(keys, values, *, t0: float, job_id: int,
                        flow_id: int, level: int, rpp: int,
                        epoch: int = 0) -> PacketStream:
    """A mapper's output stream: ``wire.pack_records`` framing (ceil
    chunks of ``rpp``, trailing EoT, one empty EoT packet for an empty
    stream), all ready at ``t0``."""
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values)
    n = int(keys.shape[0])
    n_pkts = max(1, -(-n // rpp))
    sizes = np.full((n_pkts,), rpp, np.int64)
    sizes[-1] = n - rpp * (n_pkts - 1)
    return PacketStream(job_id=job_id, flow_id=flow_id, level=level,
                        times=np.full((n_pkts,), float(t0)),
                        sizes=sizes, keys=keys, values=values, epoch=epoch)


def streams_from_mapper_records(keys, values, t0s, *, n_mappers: int,
                                job_id: int, level: int, rpp: int,
                                epoch: int = 0) -> list[PacketStream]:
    """All mapper output streams at once: ``np.array_split`` chunking plus
    per-mapper :func:`stream_from_records`, built from three batched
    arrays instead of ``2 * n_mappers`` numpy calls.  Chunk boundaries,
    packet sizes, and ready times are exactly the per-mapper path's —
    the streams hold views into the same ``keys``/``values`` storage.
    """
    keys = np.asarray(keys, np.int32)
    values = np.asarray(values)
    n = int(keys.shape[0])
    # np.array_split: the first n % m chunks get the extra record
    base, extra = divmod(n, n_mappers)
    chunk = np.full((n_mappers,), base, np.int64)
    chunk[:extra] += 1
    offs = np.concatenate([[0], np.cumsum(chunk)])
    n_pkts = np.maximum(1, -(-chunk // rpp))
    p_offs = np.concatenate([[0], np.cumsum(n_pkts)])
    sizes = np.full((int(p_offs[-1]),), rpp, np.int64)
    sizes[p_offs[1:] - 1] = chunk - rpp * (n_pkts - 1)
    times = np.repeat(np.asarray(t0s, np.float64), n_pkts)
    return [
        PacketStream(job_id=job_id, flow_id=m, level=level,
                     times=times[p_offs[m]:p_offs[m + 1]],
                     sizes=sizes[p_offs[m]:p_offs[m + 1]],
                     keys=keys[offs[m]:offs[m + 1]],
                     values=values[offs[m]:offs[m + 1]], epoch=epoch)
        for m in range(n_mappers)]


def stream_from_packets(stream, *, value_template: np.ndarray) -> PacketStream:
    """Array form of a node-path ``[(t_ready, wire.Packet), ...]`` stream
    (PSN order, trailing EoT).  ``value_template`` supplies the carried
    lane shape/dtype when the stream has no payload at all."""
    hdr0 = stream[0][1].header
    times = np.array([t for t, _ in stream], np.float64)
    sizes = np.array([p.header.n_records for _, p in stream], np.int64)
    ks = [np.asarray(p.keys, np.int32) for _, p in stream
          if p.header.n_records]
    vs = [np.asarray(p.values) for _, p in stream if p.header.n_records]
    keys = (np.concatenate(ks) if ks else np.zeros((0,), np.int32))
    values = (np.concatenate(vs) if vs else value_template[:0])
    return PacketStream(job_id=hdr0.job_id, flow_id=hdr0.flow_id,
                        level=hdr0.level, times=times, sizes=sizes,
                        keys=keys, values=values,
                        epoch=getattr(hdr0, "epoch", 0))


def stream_to_packets(ps: PacketStream) -> list[tuple[float, wire.Packet]]:
    """Materialize ``wire.Packet`` objects — the node-path representation —
    for tiers (disabled/capacity-0) that walk packets one by one."""
    offs = np.concatenate([[0], np.cumsum(ps.sizes)])
    n = ps.n_packets
    out = []
    for i in range(n):
        lo, hi = int(offs[i]), int(offs[i + 1])
        hdr = wire.PacketHeader(
            job_id=ps.job_id, flow_id=ps.flow_id, level=ps.level, psn=i,
            n_records=hi - lo, eot=(i == n - 1), epoch=ps.epoch)
        out.append((float(ps.times[i]),
                    wire.Packet(header=hdr, keys=ps.keys[lo:hi],
                                values=ps.values[lo:hi])))
    return out


def transmit_stream(ps: PacketStream,
                    link: links_lib.Link) -> tuple[np.ndarray, float]:
    """``transport.send_stream`` collapsed to its loss=0 closed form.

    With no drops the window never rewinds and go-back-N is a FIFO chain:
    ``depart_i = max(depart_{i-1}, ready_i) + ser_i`` — evaluated here
    with exactly the node engine's float expressions and order, so depart
    / arrive times and link telemetry are bit-identical.  Returns
    (per-packet arrival times, sender-finished time).
    """
    denom = link.gbps * 1e9  # Link.serialize_s's denominator, precomputed
    prop = link.propagation_s
    t = link.busy_until
    busy_s = link.busy_s
    wire_list = (wire.HEADER_BYTES + ps.sizes * wire.PAIR_BYTES).tolist()
    arrive = np.empty((ps.n_packets,), np.float64)
    i = 0
    for r, wb in zip(ps.times.tolist(), wire_list):
        if t < r:
            t = r
        ser = wb / denom
        t += ser  # start + ser, start = max(prev depart, ready)
        busy_s += ser
        arrive[i] = t + prop
        i += 1
    link.busy_until = t
    link.busy_s = busy_s
    link.bytes_sent += sum(wire_list)
    link.payload_bytes += int(ps.sizes.sum()) * wire.PAIR_BYTES
    link.packets_sent += ps.n_packets
    return arrive, t


def default_timeout_s(gbps: float, propagation_s: float,
                      window: int) -> float:
    """``send_stream``'s conservative RTO — a full window's serialization
    plus one RTT — replicated float op for float op."""
    denom = gbps * 1e9  # Link.serialize_s's denominator
    return 2.0 * (window * (wire.MTU_BYTES / denom) + 2.0 * propagation_s)


@dataclasses.dataclass
class _LinkTransport:
    """One tier's lossy transport leg in array form: accepted-arrival
    times plus the per-link telemetry ``send_stream`` would have accrued
    (all shapes ``[n_links]`` except ``arr``)."""

    arr: np.ndarray  # [n_links, pm] accepted-arrival time per PSN
    dep: np.ndarray  # sender-finished time (= final depart)
    busy: np.ndarray  # serialization occupancy, retransmissions included
    tx: np.ndarray  # transmissions, retransmissions included
    wire_b: np.ndarray  # wire bytes, retransmissions included (int64)
    dropped: np.ndarray
    retx: np.ndarray
    timeouts: np.ndarray
    gaps: np.ndarray  # receiver gap discards (burst survivors past a loss)


def _windowed_transport(*, ready: np.ndarray, wbi: np.ndarray,
                        p_link: np.ndarray, flow_ids: np.ndarray,
                        denom: float, prop: float,
                        loss: transport.LossModel, window: int,
                        timeout_s: float) -> _LinkTransport:
    """Go-back-N under loss for every link of a tier at once.

    ``ready [n_links, pm]`` / ``wbi [n_links, pm]`` are per-PSN ready
    times and wire bytes (padded past ``p_link``); ``denom`` is the
    shared ``gbps * 1e9`` serialization denominator.  Two phases:

    * **control** — a fixed-point loop over burst rounds, every live link
      stepped in lockstep.  A round transmits the ``[n_links, window]``
      rectangle from each link's ``base``; one batched ``drop_array``
      draw (same pure hash as the node sender's per-packet ``drop``)
      decides losses; ``base`` advances to the first loss (go-back-N
      rewind) or past the burst.  Because the transmission schedule
      depends only on the draws — never on timing — acceptance is decided
      here too: slots before the first loss are accepted (they arrive
      with PSN == expected), later survivors are gap discards, and
      duplicates cannot occur (the sender never rewinds past an accepted
      PSN).  Counter telemetry accrues per round.
    * **timing** — replays the recorded rounds transmission by
      transmission with the node sender's float expressions in its
      evaluation order: ``depart = max(depart, ready) + wire/denom`` per
      slot, ``+= timeout_s`` after a lossy burst, accepted arrivals at
      ``depart + prop``.  One vectorized pass per (round, slot) over
      ``[n_links]`` lanes.
    """
    n_links, pm = ready.shape
    w = int(window)
    n_pkts = np.asarray(p_link, np.int64)
    attempts = np.zeros((n_links, pm), np.int64)
    base = np.zeros((n_links,), np.int64)
    live = base < n_pkts
    fl = np.asarray(flow_ids, np.int64)[:, None]
    rows = np.arange(n_links)
    lidx = np.broadcast_to(rows[:, None], (n_links, w))
    slot = np.arange(w)[None, :]
    tx = np.zeros((n_links,), np.int64)
    wire_b = np.zeros((n_links,), np.int64)
    dropped = np.zeros((n_links,), np.int64)
    retx = np.zeros((n_links,), np.int64)
    timeouts = np.zeros((n_links,), np.int64)
    gaps = np.zeros((n_links,), np.int64)
    rounds: list[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]] = []
    while live.any():
        upto = np.minimum(base + w, n_pkts)
        psn = base[:, None] + slot
        valid = live[:, None] & (psn < upto[:, None])
        psn_c = np.minimum(psn, pm - 1)  # clipped for safe gathers
        # a (link, psn) pair appears at most once per round, so the
        # unbuffered scatter-add increments each attempt exactly once
        np.add.at(attempts, (lidx[valid], psn[valid]), 1)
        att = np.take_along_axis(attempts, psn_c, axis=1)
        if int(att[valid].max(initial=0)) > transport.MAX_ATTEMPTS:
            raise RuntimeError(
                f"a psn exceeded {transport.MAX_ATTEMPTS} attempts "
                "(loss rate too close to 1?)")
        drop = valid & loss.drop_array(fl, psn_c, att)
        anyd = drop.any(axis=1)
        first = np.where(anyd, drop.argmax(axis=1), w)
        tx += valid.sum(axis=1)
        wire_b += np.where(valid, np.take_along_axis(wbi, psn_c, axis=1),
                           0).sum(axis=1)
        dropped += drop.sum(axis=1)
        retx += (valid & (att > 1)).sum(axis=1)
        timeouts += anyd
        gaps += (valid & ~drop & (slot > first[:, None])).sum(axis=1)
        rounds.append((psn_c, valid, first, anyd))
        base = np.where(live, np.where(anyd, base + first, upto), base)
        live = base < n_pkts
    t = np.zeros((n_links,))
    busy = np.zeros((n_links,))
    arr = np.zeros((n_links, pm))
    for psn_c, valid, first, anyd in rounds:
        for j in range(w):
            v = valid[:, j]
            if not v.any():
                continue
            p = psn_c[:, j]
            ser = wbi[rows, p] / denom
            t = np.where(v, np.maximum(t, ready[rows, p]) + ser, t)
            busy = np.where(v, busy + ser, busy)
            acc = v & (j < first)
            if acc.any():
                arr[acc, p[acc]] = t[acc] + prop
        t = np.where(anyd, t + timeout_s, t)
    return _LinkTransport(arr=arr, dep=t, busy=busy, tx=tx, wire_b=wire_b,
                          dropped=dropped, retx=retx, timeouts=timeouts,
                          gaps=gaps)


def transmit_stream_lossy(
        ps: PacketStream, link: links_lib.Link, loss: transport.LossModel,
        *, window: int, timeout_s: float | None,
) -> tuple[np.ndarray, float, transport.FlowStats, int]:
    """``transport.send_stream`` over one array-form stream under loss:
    :func:`_windowed_transport` with a single link lane.  Fills ``link``
    telemetry, returns (per-PSN accepted-arrival times, sender-finished
    time, flow stats, receiver gap discards)."""
    denom = link.gbps * 1e9
    if timeout_s is None:
        timeout_s = default_timeout_s(link.gbps, link.propagation_s, window)
    sizes = ps.sizes
    lt = _windowed_transport(
        ready=ps.times[None, :],
        wbi=(wire.HEADER_BYTES + sizes * wire.PAIR_BYTES)[None, :],
        p_link=np.array([ps.n_packets], np.int64),
        flow_ids=np.array([ps.flow_id], np.int64), denom=denom,
        prop=link.propagation_s, loss=loss, window=window,
        timeout_s=timeout_s)
    link.busy_until = float(lt.dep[0])
    link.busy_s += float(lt.busy[0])
    link.bytes_sent += int(lt.wire_b[0])
    link.payload_bytes += int(sizes.sum()) * wire.PAIR_BYTES
    link.packets_sent += int(lt.tx[0])
    stats = transport.FlowStats(
        packets_sent=int(lt.tx[0]), packets_dropped=int(lt.dropped[0]),
        retransmissions=int(lt.retx[0]), timeouts=int(lt.timeouts[0]),
        wire_bytes=int(lt.wire_b[0]))
    return lt.arr[0], float(lt.dep[0]), stats, int(lt.gaps[0])


@dataclasses.dataclass
class _Gate:
    """Receiver stand-in: the vectorized transport decides acceptance in
    the window algebra, so only the discard counters survive here.  At
    loss=0 every packet arrives in PSN order and both stay zero; under
    loss the burst survivors past a rewind point land as gap discards.
    Duplicates cannot occur (the sender never rewinds past an accepted
    PSN), matching the node engine's always-zero duplicate counter."""

    gap_discards: int = 0
    duplicate_discards: int = 0


@dataclasses.dataclass
class _TierStats:
    """``LevelState``-shaped telemetry carrier for the fast path."""

    n_evict: int = 0


@dataclasses.dataclass
class _VNode:
    """``_Node``-shaped per-switch result of the fast tier path: same
    telemetry fields, no event-loop state (the arrays already ran)."""

    records_in: int
    records_out: int
    bytes_out: int
    agg_proc_s: float
    queue_peak: int
    state: _TierStats | None  # None on forward-only (relay) tiers
    receiver: _Gate = dataclasses.field(default_factory=_Gate)
    finished: bool = True


@dataclasses.dataclass
class TierWork:
    """One tier's state between :func:`tier_start` and :func:`tier_finish`.

    ``kernel_key`` is the kernel-static signature
    ``(capacity, ways, op, bpe, exact_stream, rpp, lane_shape, dtype)``;
    works sharing it can run in ONE batched ``tier_ingest`` call
    (``None`` on forward-only tiers — they issue no kernel).
    :func:`dispatch_tier_ingest` fills ``kernel_out`` with this work's
    ``(tk, tv, ek, ev, ne, no)`` switch-lane slice.
    """

    forward: bool
    level: int
    fanin: int
    job_id: int
    first_flow_id: int
    n_switches: int
    rpp: int
    proc_rate: float
    kernel_key: tuple | None
    # kernel batch scatter (record packets in merged order)
    s_rec: np.ndarray
    dst: np.ndarray
    rows_k: np.ndarray
    rows_v: np.ndarray
    p_counts: np.ndarray
    rec_start: np.ndarray
    # merged arrival schedule (all packets, per-switch (t, flow, psn) order)
    s_m: np.ndarray
    t_m: np.ndarray
    sizes_m: np.ndarray
    eot_m: np.ndarray
    # transport results
    links: list
    flow: transport.FlowStats
    t_done: list[float]
    gaps_sw: np.ndarray  # [n_switches] receiver gap discards
    kernel_out: tuple | None = None
    epoch: int = 0  # restart incarnation stamped on the out streams (§12)


def tier_start(streams: list[PacketStream], *, level: int, fanin: int,
               spec: dataplane.LevelSpec | None, op: str, cfg, axis: str,
               gbps: float, job_id: int, first_flow_id: int,
               value_template: np.ndarray,
               loss: transport.LossModel | None = None) -> TierWork:
    """Run one tier's host-side front half: transport (any loss rate),
    PSN acceptance, the merged arrival schedule, and the kernel batch
    scatter.  Returns a :class:`TierWork` for :func:`dispatch_tier_ingest`
    + :func:`tier_finish`.

    ``streams`` holds the child streams in child-index order (child *c* of
    switch *s* at ``streams[s * fanin + c]``).  All per-link transport
    state lives in tier-wide arrays (DESIGN.md §10): at loss=0 the
    serialization recurrence runs once per packet *rank* vectorized over
    every link at the tier; under loss :func:`_windowed_transport` steps
    the go-back-N rounds in lockstep instead.  ``spec=None`` runs the
    tier forward-only (host-only baseline or a placement-disabled hop):
    no kernel, records re-framed unchanged, store-and-forward charged to
    the clock but not to ``agg_proc_s``.  Every float replicates the node
    engine bitwise.
    """
    forward = spec is None
    n_links = len(streams)
    n_switches = n_links // fanin
    rpp = int(cfg.records_per_packet)
    proc_rate = cfg.processing_gbps * 1e9
    lane_shape = value_template.shape[1:]
    vdtype = value_template.dtype
    lossy = loss is not None and loss.rate > 0.0

    # --- transport: every link's go-back-N, batched over the tier ------
    # padded ranks carry ready=-inf, bytes=0 so dead lanes reproduce
    # their last state bit-for-bit
    p_link = np.array([ps.n_packets for ps in streams], np.int64)
    pm_link = int(p_link.max())
    sizes_flat = np.concatenate([ps.sizes for ps in streams])
    big = int(sizes_flat.max(initial=0))
    if big > rpp:
        raise ValueError(f"packet carries {big} records > "
                         f"records_per_packet {rpp}")
    ready = np.full((n_links, pm_link), -np.inf)
    wb = np.zeros((n_links, pm_link))
    lmask = np.arange(pm_link)[None, :] < p_link[:, None]
    ready[lmask] = np.concatenate([ps.times for ps in streams])
    wb[lmask] = wire.HEADER_BYTES + sizes_flat * wire.PAIR_BYTES
    denom = gbps * 1e9  # Link.serialize_s's denominator, precomputed
    starts = np.concatenate([[0], np.cumsum(p_link)[:-1]])
    # every stream has >= 1 packet (an empty stream is one EoT packet),
    # so each reduceat segment is non-empty
    pay_bytes = np.add.reduceat(sizes_flat, starts) * wire.PAIR_BYTES
    flow = transport.FlowStats()
    if lossy:
        window = int(cfg.window)
        timeout_s = (cfg.timeout_s if cfg.timeout_s is not None else
                     default_timeout_s(gbps, cfg.propagation_s, window))
        lt = _windowed_transport(
            ready=ready, wbi=np.where(lmask, wb, 0).astype(np.int64),
            p_link=p_link,
            flow_ids=np.array([ps.flow_id for ps in streams], np.int64),
            denom=denom, prop=cfg.propagation_s, loss=loss, window=window,
            timeout_s=timeout_s)
        dep, busy, arr = lt.dep, lt.busy, lt.arr
        tx_link, wire_link = lt.tx, lt.wire_b
        flow.packets_dropped = int(lt.dropped.sum())
        flow.retransmissions = int(lt.retx.sum())
        flow.timeouts = int(lt.timeouts.sum())
        gaps_sw = lt.gaps.reshape(n_switches, fanin).sum(axis=1)
    else:
        # loss=0: go-back-N never rewinds — the FIFO chain
        # depart_i = max(depart_{i-1}, ready_i) + ser_i per packet rank
        dep = np.zeros((n_links,))
        busy = np.zeros((n_links,))
        arr = np.empty((n_links, pm_link))
        for j in range(pm_link):
            ser = wb[:, j] / denom
            dep = np.maximum(dep, ready[:, j]) + ser
            busy = busy + ser
            arr[:, j] = dep + cfg.propagation_s
        tx_link = p_link
        wire_link = wire.HEADER_BYTES * p_link + pay_bytes
        gaps_sw = np.zeros((n_switches,), np.int64)
    links: list[links_lib.Link] = []
    for c, ps in enumerate(streams):
        link = links_lib.Link(
            name=f"{axis}.s{c // fanin}.c{c % fanin}", axis=axis, gbps=gbps,
            propagation_s=cfg.propagation_s)
        link.busy_until = float(dep[c])
        link.busy_s = float(busy[c])
        link.bytes_sent = int(wire_link[c])
        link.payload_bytes = int(pay_bytes[c])
        link.packets_sent = int(tx_link[c])
        links.append(link)
    flow.packets_sent = int(tx_link.sum())
    flow.wire_bytes = int(wire_link.sum())
    t_done = dep.tolist()

    # --- merge: one global sort keyed (switch, t, flow, psn) — per
    # switch this is the node engine's (t, flow_id, psn) stable order of
    # the ACCEPTED packets (discarded arrivals have no state effects) ---
    s_all = np.repeat(np.arange(n_links) // fanin, p_link)
    t_all = arr[lmask]
    flow_all = np.repeat(np.array([ps.flow_id for ps in streams]), p_link)
    psn_all = np.arange(p_link.sum()) - np.repeat(starts, p_link)
    eot_all = np.zeros(t_all.shape, bool)
    eot_all[np.cumsum(p_link) - 1] = True
    order = np.lexsort((psn_all, flow_all, t_all, s_all))
    s_m, t_m = s_all[order], t_all[order]
    sizes_m = sizes_flat[order]
    eot_m = eot_all[order]

    # payload rows [P_total, rpp] in merged order (record packets only)
    fill = np.arange(rpp)[None, :] < sizes_flat[:, None]
    mat_k = np.full((t_all.shape[0], rpp), _EMPTY, np.int32)
    mat_k[fill] = np.concatenate([ps.keys for ps in streams])
    mat_v = np.zeros((t_all.shape[0], rpp) + lane_shape, vdtype)
    mat_v[fill] = np.concatenate(
        [ps.values for ps in streams if ps.values.shape[0]]
        or [value_template[:0]])
    rec_m = sizes_m > 0
    sel = order[rec_m]  # record packets in merged order, one gather each
    rows_k, rows_v = mat_k[sel], mat_v[sel]
    s_rec = s_m[rec_m]
    p_counts = np.bincount(s_rec, minlength=n_switches)
    rec_start = np.concatenate([[0], np.cumsum(p_counts)[:-1]])
    dst = np.arange(s_rec.shape[0]) - np.repeat(rec_start, p_counts)
    kernel_key = None if forward else (
        spec.capacity, spec.ways, op, spec.bpe, bool(cfg.exact_stream),
        rpp, lane_shape, str(vdtype))
    return TierWork(
        forward=forward, level=level, fanin=fanin, job_id=job_id,
        first_flow_id=first_flow_id, n_switches=n_switches, rpp=rpp,
        proc_rate=proc_rate, kernel_key=kernel_key, s_rec=s_rec, dst=dst,
        rows_k=rows_k, rows_v=rows_v, p_counts=p_counts,
        rec_start=rec_start, s_m=s_m, t_m=t_m, sizes_m=sizes_m,
        eot_m=eot_m, links=links, flow=flow, t_done=t_done,
        gaps_sw=gaps_sw, epoch=int(getattr(cfg, "epoch", 0)))


#: jitted tier_ingest dispatches issued so far (tests assert the
#: multi-job batcher's call count against planner.batch_tier_groups)
ingest_calls = 0


def dispatch_tier_ingest(works: list[TierWork]) -> int:
    """Run the kernel work of many tiers in as few jitted calls as
    possible (multi-job tier batching, DESIGN.md §10).

    Works sharing a ``kernel_key`` concatenate their switch lanes along
    the batch axis of ONE ``tier_ingest`` call; each work gets back its
    own slice in ``kernel_out``.  ``vmap`` lanes are independent and the
    pad shapes are the same pow2 buckets a solo run would pick, so every
    slice is bit-identical to the work's standalone kernel call.
    Returns the number of jitted calls issued.
    """
    global ingest_calls
    groups: dict[tuple, list[TierWork]] = {}
    for wk in works:
        if wk.kernel_key is not None:
            groups.setdefault(wk.kernel_key, []).append(wk)
    for key, ws in groups.items():
        capacity, ways, op, bpe, exact_stream, rpp, lane_shape, dt = key
        s_pad = _pow2(sum(wk.n_switches for wk in ws))
        p_pad = _pow2(max(int(wk.p_counts.max(initial=0)) for wk in ws),
                      floor=1)
        keys_b = np.full((s_pad, p_pad, rpp), _EMPTY, np.int32)
        vals_b = np.zeros((s_pad, p_pad, rpp) + lane_shape, np.dtype(dt))
        off = 0
        for wk in ws:
            keys_b[wk.s_rec + off, wk.dst] = wk.rows_k
            vals_b[wk.s_rec + off, wk.dst] = wk.rows_v
            off += wk.n_switches
        out = jax.device_get(tier_ingest(
            jnp.asarray(keys_b), jnp.asarray(vals_b), capacity=capacity,
            ways=ways, op=op, bpe=bpe, exact_stream=exact_stream))
        ingest_calls += 1
        ne = out[4]
        if int(ne.max(initial=0)) > rpp:
            raise AssertionError(
                "tier_ingest eviction compaction dropped real entries "
                f"(a packet evicted {int(ne.max())} > {rpp} pairs)")
        off = 0
        for wk in ws:
            wk.kernel_out = tuple(
                a[off:off + wk.n_switches] for a in out)
            off += wk.n_switches
    return len(groups)


def tier_finish(work: TierWork):
    """Run one tier's host-side back half — the processing-time
    recurrence, EoT flush, MTU re-framing, and telemetry — from a
    :class:`TierWork` whose kernel slice has been dispatched.  Returns
    ``(nodes, out_streams, links, flow_stats, t_done)``: :class:`_VNode`
    telemetry carriers, the per-switch uplink :class:`PacketStream`s, the
    per-edge :class:`~repro.net.links.Link` objects (telemetry filled),
    and each child flow's sender-finished time (the mapper finish times
    at tier 0).
    """
    forward = work.forward
    n_switches = work.n_switches
    fanin = work.fanin
    rpp = work.rpp
    proc_rate = work.proc_rate
    s_m, t_m = work.s_m, work.t_m
    sizes_m, eot_m = work.sizes_m, work.eot_m
    rows_k, rows_v = work.rows_k, work.rows_v
    p_counts, rec_start = work.p_counts, work.rec_start
    if not forward:
        tk, tv, ek, ev, ne, no = work.kernel_out

    # --- processing-time recurrence (the _Node.receive float ops),
    # batched over switches: one pass per merged-arrival rank -----------
    m_counts = np.bincount(s_m, minlength=n_switches)
    seg_start = np.concatenate([[0], np.cumsum(m_counts)[:-1]])
    psm = int(m_counts.max(initial=0))
    rank = np.arange(s_m.shape[0]) - np.repeat(seg_start, m_counts)
    t_as = np.zeros((n_switches, psm))
    nrec = np.zeros((n_switches, psm), np.int64)
    eots = np.zeros((n_switches, psm), bool)
    t_as[s_m, rank] = t_m
    nrec[s_m, rank] = sizes_m
    eots[s_m, rank] = eot_m
    pf = np.zeros((n_switches,))
    agg_s = np.zeros((n_switches,))
    t_fin = np.zeros((n_switches,))
    tp = np.empty((n_switches, psm))
    if n_switches >= 32:
        # wide tier: one pass per rank, [n_switches]-wide lanes
        cnt = np.zeros((n_switches,), np.int64)
        for j in range(psm):
            live = nrec[:, j] > 0
            busy_j = (wire.HEADER_BYTES + nrec[:, j] * wire.PAIR_BYTES) \
                / proc_rate
            pf = np.where(live, np.maximum(pf, t_as[:, j]) + busy_j, pf)
            if not forward:  # a relay's charge is store-and-forward
                agg_s = np.where(live, agg_s + busy_j, agg_s)
            tp[:, j] = pf
            t_j = np.where(live, pf, t_as[:, j])
            cnt = cnt + eots[:, j]
            hit = eots[:, j] & (cnt == fanin)
            t_fin = np.where(hit, np.maximum(t_j, pf), t_fin)
    else:
        # narrow tier (few switches, long streams): python scalars beat
        # width-1 numpy lanes by ~10x; identical float ops either way
        for s in range(n_switches):
            m = int(m_counts[s])
            pf_s = 0.0
            agg = 0.0
            eots_s = 0
            fin = 0.0
            tp_row = tp[s]
            for j, (t_a, nr, eot) in enumerate(zip(
                    t_as[s, :m].tolist(), nrec[s, :m].tolist(),
                    eots[s, :m].tolist())):
                t = t_a
                if nr:
                    start = pf_s if pf_s > t_a else t_a
                    busy_j = (wire.HEADER_BYTES + nr * wire.PAIR_BYTES) \
                        / proc_rate
                    pf_s = start + busy_j
                    if not forward:
                        agg += busy_j
                    t = pf_s
                tp_row[j] = pf_s
                if eot:
                    eots_s += 1
                    if eots_s == fanin:
                        fin = pf_s if pf_s > t else t
            pf[s] = pf_s
            agg_s[s] = agg
            t_fin[s] = fin
    # --- EoT flush (the _Node._finish float ops; relays hold no table)
    if forward:
        flush_ns = np.zeros((n_switches,), np.int64)
        t_end_v = t_fin
    else:
        flush_m = tk[:n_switches] != _EMPTY
        flush_ns = flush_m.sum(axis=1).astype(np.int64)
        busy_f = flush_ns * wire.PAIR_BYTES / proc_rate
        flushed = flush_ns > 0
        agg_s = np.where(flushed, agg_s + busy_f, agg_s)
        t_end_v = np.where(flushed, np.maximum(t_fin, pf) + busy_f, t_fin)

    nodes: list[_VNode] = []
    out_streams: list[PacketStream] = []
    for s in range(n_switches):
        pc = int(p_counts[s])
        mrow = slice(int(seg_start[s]), int(seg_start[s]) + int(m_counts[s]))
        live_row = nrec[s, :m_counts[s]] > 0
        if forward:
            out_counts = nrec[s, :m_counts[s]][live_row]
        else:
            out_counts = no[s, :pc].astype(np.int64)
        flush_n = int(flush_ns[s])
        t_end = float(t_end_v[s])
        # --- MTU re-framing: frame j closes at the arrival whose output
        # pushed the pending queue past (j+1)*rpp; the rest flush at EoT
        cumout = np.cumsum(out_counts)
        total = int(cumout[-1]) if pc else 0
        total_after = total + flush_n
        k1 = total // rpp
        k_total = total_after // rpp
        rem = total_after - k_total * rpp
        frame_t = np.full((k_total + 1,), t_end, np.float64)
        if k1:
            idx = np.searchsorted(cumout,
                                  np.arange(1, k1 + 1) * rpp, side="left")
            frame_t[:k1] = tp[s, :m_counts[s]][live_row][idx]
        frame_sizes = np.full((k_total + 1,), rpp, np.int64)
        frame_sizes[-1] = rem  # the EoT frame (empty when rem == 0)
        # --- payload: forwarded records, or per-packet eviction streams
        # followed by the table flush ---
        seg = slice(int(rec_start[s]), int(rec_start[s]) + pc)
        if forward:
            fwd = np.arange(rpp)[None, :] < out_counts[:, None]
            out_k, out_v = rows_k[seg][fwd], rows_v[seg][fwd]
        else:
            emask = ek[s, :pc] != _EMPTY
            out_k = ek[s, :pc][emask]
            out_v = ev[s, :pc][emask]
            if flush_n:
                out_k = np.concatenate([out_k, tk[s][flush_m[s]]])
                out_v = np.concatenate([out_v, tv[s][flush_m[s]]])
        assert out_k.shape[0] == total_after
        # --- telemetry (matches _Node counter for counter) ---
        pend_before = (cumout - out_counts) % rpp
        peaks = (pend_before + out_counts)[out_counts > 0]
        peak = int(peaks.max()) if peaks.size else 0
        if flush_n:
            peak = max(peak, total % rpp + flush_n)
        nodes.append(_VNode(
            records_in=int(sizes_m[mrow].sum()),
            records_out=total_after,
            bytes_out=((k_total + 1) * wire.HEADER_BYTES
                       + total_after * wire.PAIR_BYTES),
            agg_proc_s=float(agg_s[s]),
            queue_peak=peak,
            state=None if forward else _TierStats(
                n_evict=int(ne[s, :pc].sum())),
            receiver=_Gate(gap_discards=int(work.gaps_sw[s])),
        ))
        out_streams.append(PacketStream(
            job_id=work.job_id, flow_id=work.first_flow_id + s,
            level=work.level + 1, times=frame_t, sizes=frame_sizes,
            keys=out_k.astype(np.int32), values=out_v, epoch=work.epoch))
    return nodes, out_streams, work.links, work.flow, work.t_done


def run_tier_fast(streams: list[PacketStream], *, level: int, fanin: int,
                  spec: dataplane.LevelSpec | None, op: str, cfg, axis: str,
                  gbps: float, job_id: int, first_flow_id: int,
                  value_template: np.ndarray,
                  loss: transport.LossModel | None = None):
    """Run one whole tier — transport (any loss rate), acceptance,
    processing, MTU re-framing, telemetry — arrays plus (at most) one
    kernel call: :func:`tier_start` → :func:`dispatch_tier_ingest` →
    :func:`tier_finish` for a single tier.  See those for the contract;
    the sim's lockstep batch driver runs the trio directly so concurrent
    jobs' tiers can share kernel batches."""
    work = tier_start(
        streams, level=level, fanin=fanin, spec=spec, op=op, cfg=cfg,
        axis=axis, gbps=gbps, job_id=job_id, first_flow_id=first_flow_id,
        value_template=value_template, loss=loss)
    dispatch_tier_ingest([work])
    return tier_finish(work)
