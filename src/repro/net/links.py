"""Per-link bandwidth / latency / FIFO-queue model (DESIGN.md §7).

One :class:`Link` is a point-to-point edge of the aggregation tree (mapper
-> level-0 switch, switch -> parent switch, root -> reducer).  It is a
serialization resource: a packet occupies the link for ``bytes / rate``
seconds, FIFO, plus a fixed propagation delay — the classic
store-and-forward pipe the drain-time scoring in ``core.planner`` models
as ``bytes / (gbps * 1e9)``.

``gbps`` follows the repo-wide planner convention (``JobScheduler._drain_s``,
``core.tree.ICI_GBPS``): units of 1e9 **bytes**/s, so 1.25 ≈ a 10 GbE link.

Links accumulate telemetry (wire bytes, payload bytes, serialization
occupancy, queueing delay) that ``net.sim`` aggregates per tree level —
the measured counterpart of the planner's modeled level bytes, and the
input to its drain-time calibration.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Iterable


@dataclasses.dataclass
class Link:
    """A FIFO serialization pipe with accounting."""

    name: str
    axis: str  # tree level / topology axis this link belongs to
    gbps: float  # 1e9 bytes per second (planner convention)
    propagation_s: float = 1e-6
    # -- state + telemetry ---------------------------------------------------
    busy_until: float = 0.0
    bytes_sent: int = 0
    payload_bytes: int = 0
    packets_sent: int = 0
    busy_s: float = 0.0
    queue_delay_s: float = 0.0

    def __post_init__(self):
        if self.gbps <= 0:
            raise ValueError(f"link {self.name}: gbps must be positive")

    def serialize_s(self, n_bytes: int) -> float:
        return n_bytes / (self.gbps * 1e9)

    def transmit(self, t_ready: float, n_bytes: int,
                 payload_bytes: int = 0) -> tuple[float, float]:
        """Serialize one packet; returns (t_departed, t_arrived).

        ``t_ready`` is when the sender has the packet; the link starts when
        both the packet and the pipe are ready (FIFO queueing), occupies the
        pipe for the serialization time, and the far end sees the packet one
        propagation delay after the last byte left.
        """
        start = max(t_ready, self.busy_until)
        self.queue_delay_s += start - t_ready
        ser = self.serialize_s(n_bytes)
        self.busy_until = start + ser
        self.busy_s += ser
        self.bytes_sent += n_bytes
        self.payload_bytes += payload_bytes
        self.packets_sent += 1
        return self.busy_until, self.busy_until + self.propagation_s


def from_budget(budget, *, name: str | None = None,
                propagation_s: float = 1e-6) -> Link:
    """Build a Link from a ``planner.LinkBudget``-shaped object (duck-typed
    on ``axis``/``gbps`` so this module never imports the planner)."""
    return Link(name=name or budget.axis, axis=budget.axis,
                gbps=budget.gbps, propagation_s=propagation_s)


def stats_by_axis(links: Iterable[Link]) -> dict[str, dict]:
    """Aggregate per-link telemetry into per-axis (tree level) totals.

    ``drain_s`` is the busiest single link's serialization occupancy — the
    measured counterpart of the planner's modeled ``load / rate`` drain.
    """
    out: dict[str, dict] = defaultdict(lambda: {
        "links": 0, "bytes": 0, "payload_bytes": 0, "packets": 0,
        "busy_s": 0.0, "drain_s": 0.0, "queue_delay_s": 0.0,
    })
    for l in links:
        s = out[l.axis]
        s["links"] += 1
        s["bytes"] += l.bytes_sent
        s["payload_bytes"] += l.payload_bytes
        s["packets"] += l.packets_sent
        s["busy_s"] += l.busy_s
        s["drain_s"] = max(s["drain_s"], l.busy_s)
        s["queue_delay_s"] += l.queue_delay_s
    return dict(out)
