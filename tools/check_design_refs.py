#!/usr/bin/env python
"""Verify every `DESIGN.md §N` reference in the source tree resolves.

Docstrings cite design sections as ``DESIGN.md §3``; this checker fails
(exit 1) if a cited section has no matching ``## §N`` heading in
DESIGN.md — the doc contract CI enforces.  Coverage spans ``src/``,
``tests/``, ``benchmarks/``, and ``examples/`` (tests and benches cite
sections too, e.g. the §7 network-sim suite).

    python tools/check_design_refs.py [--root .]
"""

from __future__ import annotations

import argparse
import pathlib
import re
import sys

REF_RE = re.compile(r"DESIGN\.md\s*§\s*(\d+)")
HEADING_RE = re.compile(r"^#+\s*§(\d+)\b", re.MULTILINE)
SCAN_DIRS = ("src", "tests", "benchmarks", "examples")


def collect_refs(root: pathlib.Path) -> list[tuple[pathlib.Path, int, int]]:
    """(file, line, section) for every DESIGN.md §N reference under the
    scanned trees (``SCAN_DIRS``)."""
    refs = []
    for d in SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            for lineno, line in enumerate(py.read_text().splitlines(), 1):
                for m in REF_RE.finditer(line):
                    refs.append(
                        (py.relative_to(root), lineno, int(m.group(1))))
    return refs


def check(root: pathlib.Path) -> int:
    design = root / "DESIGN.md"
    if not design.exists():
        print("FAIL: DESIGN.md does not exist")
        return 1
    sections = {int(n) for n in HEADING_RE.findall(design.read_text())}
    refs = collect_refs(root)
    if not refs:
        print("WARNING: no DESIGN.md §N references found under src/")
    bad = [(f, ln, n) for f, ln, n in refs if n not in sections]
    for f, ln, n in bad:
        print(f"FAIL: {f}:{ln} cites DESIGN.md §{n}, "
              f"but DESIGN.md has sections {sorted(sections)}")
    if not bad:
        print(f"OK: {len(refs)} reference(s) across "
              f"{len({f for f, _, _ in refs})} file(s) all resolve "
              f"(sections {sorted(sections)})")
    return 1 if bad else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=pathlib.Path(__file__).resolve().parents[1],
                    type=pathlib.Path)
    args = ap.parse_args()
    sys.exit(check(args.root))


if __name__ == "__main__":
    main()
