#!/usr/bin/env python
"""Render the observability dashboard from saved artifacts (DESIGN.md §11).

Joins a ``metrics.json`` (metrics-registry dump) and optionally a
``trace.json`` (Chrome trace) into the self-contained HTML + markdown
dashboard — the offline twin of what ``launch/dryrun.py --trace`` and
``benchmarks/run.py --smoke`` emit inline:

    python tools/dashboard.py --metrics out/metrics.json \
        --trace out/trace.json --out out/

Writes ``dashboard.html`` and ``dashboard.md`` into ``--out``.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.obs import report as obs_report  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--metrics", required=True,
                    help="metrics.json (MetricsRegistry dump)")
    ap.add_argument("--trace", default=None,
                    help="trace.json (Chrome trace-event JSON)")
    ap.add_argument("--out", default=".", help="output directory")
    ap.add_argument("--title", default="repro observability")
    args = ap.parse_args(argv)

    with open(args.metrics) as f:
        metrics = json.load(f)["metrics"]
    tracer = None
    if args.trace:
        tracer = obs_trace.Tracer()
        with open(args.trace) as f:
            tracer.events = [e for e in json.load(f)["traceEvents"]
                             if e.get("ph") != "M"]

    os.makedirs(args.out, exist_ok=True)
    md = os.path.join(args.out, "dashboard.md")
    with open(md, "w") as f:
        f.write(obs_report.dashboard_markdown(metrics, tracer,
                                              title=args.title))
    html = os.path.join(args.out, "dashboard.html")
    with open(html, "w") as f:
        f.write(obs_report.dashboard_html(metrics, tracer,
                                          title=args.title))
    print(f"wrote {md} {html}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
