#!/usr/bin/env python
"""Perf-regression gate for the CI bench smoke step.

Compares the smoke-run ``BENCH_fpe.json`` / ``BENCH_dataplane.json`` /
``BENCH_sim.json`` in ``--out-dir`` against the checked-in
``benchmarks/baselines/*.json``:

  * throughput (FPE scan/fast pairs-per-second, dataplane pairs-per-
    second derived from ``n / wall_us``) is gated on the GEOMETRIC MEAN
    of the per-cell current/baseline ratios, per bench file: a drop of
    more than ``--tolerance`` (default 0.30, the ">30% regression fails
    the job" bar) fails.  Gating the aggregate — not each cell — is
    deliberate: smoke cells are tiny (reps=1, some in Pallas interpret
    mode), so any single cell can swing 30%+ on a loaded CI runner,
    while a real regression moves the whole suite.  Per-cell swings
    beyond the band are still printed as notes;
  * semantic metrics (dataplane end-to-end reduction ratio, sim-engine
    parity flags) are gated per cell within an absolute
    ``--semantic-tolerance`` band — these are deterministic, so drift
    means the aggregation semantics moved, not the machine;
  * ``floor:<x>`` metrics (the vectorized simulator's node-vs-tier
    speedup, DESIGN.md §10) are gated against an ABSOLUTE bar carried in
    the bench rows themselves — the baseline only feeds the note, so
    re-baselining a slow run cannot lower the bar;
  * ``ratio`` cells (the ``obs_overhead`` observability-tax row,
    DESIGN.md §11) are pure in-process throughput ratios and carry their
    own ``floor:<x>`` bars — they never join the machine-speed geomean;
  * a SCHEMA gate runs before any ratio is computed: every row in a
    gated file must still carry the fields its registered metrics are
    extracted from (``ROW_SCHEMAS``).  A bench row that silently stops
    emitting a metric is a telemetry regression, not a perf one, and
    fails with the missing field names;
  * a config row present in the baseline but missing from the current
    run fails too (silent coverage shrink is a regression).

    python tools/check_bench_regression.py
    python tools/check_bench_regression.py --tolerance 0.5   # noisy runner
    python tools/check_bench_regression.py --update          # re-baseline

Baselines are smoke-config numbers from a 2-core CI-class CPU; they gate
relative movement, not absolute speed, which is why the band is wide.
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import shutil
import sys

#: files the gate covers, with their metric extractors (see below)
GATED = ("BENCH_fpe.json", "BENCH_dataplane.json", "BENCH_sim.json",
         "BENCH_faults.json", "BENCH_churn.json")


def _load_rows(path: pathlib.Path) -> list[dict]:
    with open(path) as f:
        doc = json.load(f)
    return doc["rows"] if isinstance(doc, dict) else doc


def fpe_metrics(rows: list[dict]) -> dict[str, tuple[float, str]]:
    """name -> (value, kind); kind 'throughput' = higher is better."""
    out = {}
    for r in rows:
        key = f"{r['backend']}/{r['op']}/n{r['n']}/w{r['ways']}"
        out[f"fpe:{key}:scan_pairs_per_s"] = (r["scan_pairs_per_s"],
                                              "throughput")
        out[f"fpe:{key}:fast_pairs_per_s"] = (r["fast_pairs_per_s"],
                                              "throughput")
    return out


def dataplane_metrics(rows: list[dict]) -> dict[str, tuple[float, str]]:
    out = {}
    for r in rows:
        key = f"{r['backend']}/{r['op']}/L{r['levels']}/C{r['capacity_per_node']}"
        out[f"dataplane:{key}:pairs_per_s"] = (
            r["n"] / max(r["wall_us"], 1e-9) * 1e6, "throughput")
        out[f"dataplane:{key}:end_to_end_reduction"] = (
            r["end_to_end_reduction"], "semantic")
    return out


def sim_metrics(rows: list[dict]) -> dict[str, tuple[float, str]]:
    """Engine-vs-engine simulator cells (DESIGN.md §10): per-engine
    steps/s ride the throughput geomean; the parity flag is semantic
    (the engines either agreed exactly or the cell is broken); the
    flagship cell's node-vs-vectorized speedup carries an absolute
    ``floor:<x>`` bar — the tier engine must stay >= that many times
    faster than the node oracle no matter what the baseline says."""
    out = {}
    for r in rows:
        key = r["cell"]
        if key == "obs_overhead":
            # the observability-tax cell: both bars are in-process
            # RATIOS (machine speed cancels), so they carry absolute
            # floors and never join the throughput geomean
            out[f"sim:{key}:off_on_ratio"] = (
                r["off_on_ratio"], f"floor:{r['off_on_floor']}")
            out[f"sim:{key}:vs_base_ratio"] = (
                r["vs_base_ratio"], f"floor:{r['vs_base_floor']}")
            out[f"sim:{key}:parity"] = (r["parity"], "semantic")
            continue
        out[f"sim:{key}:node_steps_per_s"] = (r["node_steps_per_s"],
                                              "throughput")
        out[f"sim:{key}:vec_steps_per_s"] = (r["vec_steps_per_s"],
                                             "throughput")
        out[f"sim:{key}:parity"] = (r["parity"], "semantic")
        if "speedup_floor" in r:
            out[f"sim:{key}:speedup"] = (r["speedup"],
                                         f"floor:{r['speedup_floor']}")
    return out


def faults_metrics(rows: list[dict]) -> dict[str, tuple[float, str]]:
    """Failure-recovery cells (DESIGN.md §12): exactly-once and engine
    parity are semantic (the recovery either preserved the table bit for
    bit or the cell is broken), the epoch count is semantic (a schedule
    suddenly needing more restarts means detection moved), and the
    degraded reduction ratio carries the absolute host-only floor — a
    bypassed cascade must never move more reducer bytes than pure
    forwarding, no matter what the baseline says."""
    out = {}
    for r in rows:
        key = r["cell"]
        out[f"faults:{key}:exactly_once"] = (r["exactly_once"], "semantic")
        out[f"faults:{key}:parity"] = (r["parity"], "semantic")
        out[f"faults:{key}:epochs"] = (r["epochs"], "semantic")
        out[f"faults:{key}:reduction"] = (
            r["reduction"], f"floor:{r['reduction_floor']}")
    return out


def churn_metrics(rows: list[dict]) -> dict[str, tuple[float, str]]:
    """Online-controller churn cells (DESIGN.md §13): both acceptance
    ratios are in-process (machine speed cancels), so they carry the
    absolute floors the bench rows declare — scarce-link load within
    ~10% of the full-replan oracle, at >= 10x less placement work — and
    never join the throughput geomean; the packet-level cross-checks
    (mid-run-admission engine parity, exactly-once eviction under loss)
    and the eviction/expansion counts are semantic."""
    out = {}
    for r in rows:
        key = r["cell"]
        out[f"churn:{key}:oracle_to_online"] = (
            r["oracle_to_online"], f"floor:{r['oracle_to_online_floor']}")
        out[f"churn:{key}:work_speedup"] = (
            r["work_speedup"], f"floor:{r['work_speedup_floor']}")
        out[f"churn:{key}:admit_parity"] = (r["admit_parity"], "semantic")
        out[f"churn:{key}:evict_exactly_once"] = (
            r["evict_exactly_once"], "semantic")
        out[f"churn:{key}:evictions"] = (r["evictions"], "semantic")
        out[f"churn:{key}:expansions"] = (r["expansions"], "semantic")
    return out


EXTRACTORS = {
    "BENCH_fpe.json": fpe_metrics,
    "BENCH_dataplane.json": dataplane_metrics,
    "BENCH_sim.json": sim_metrics,
    "BENCH_faults.json": faults_metrics,
    "BENCH_churn.json": churn_metrics,
}

#: the schema gate (DESIGN.md §11): per gated file, the row fields the
#: registered metrics above are extracted from.  Callable so a file can
#: vary required fields by row shape (the sim obs_overhead cell emits
#: ratio bars instead of engine legs).
ROW_SCHEMAS = {
    "BENCH_fpe.json": lambda r: {
        "backend", "op", "n", "ways",
        "scan_pairs_per_s", "fast_pairs_per_s"},
    "BENCH_dataplane.json": lambda r: {
        "backend", "op", "levels", "capacity_per_node", "n", "wall_us",
        "end_to_end_reduction"},
    "BENCH_sim.json": lambda r: (
        {"cell", "switch_steps", "parity",
         "obs_off_steps_per_s", "obs_on_steps_per_s",
         "off_on_ratio", "vs_base_ratio", "off_on_floor", "vs_base_floor"}
        if r.get("cell") == "obs_overhead" else
        {"cell", "switch_steps", "parity",
         "node_steps_per_s", "vec_steps_per_s", "speedup"}),
    "BENCH_faults.json": lambda r: {
        "cell", "n_failures", "epochs", "jct_faulted_s", "jct_penalty_s",
        "reduction", "reduction_floor", "exactly_once", "parity"},
    "BENCH_churn.json": lambda r: {
        "cell", "n_jobs", "n_events", "evictions", "expansions",
        "online_scarce_mb", "oracle_scarce_mb",
        "oracle_to_online", "oracle_to_online_floor",
        "online_scored", "oracle_scored",
        "work_speedup", "work_speedup_floor",
        "admit_parity", "evict_exactly_once"},
}


def schema_failures(fname: str, rows: list[dict]) -> list[str]:
    """Rows that stopped emitting a registered metric field."""
    fails = []
    required = ROW_SCHEMAS[fname]
    for i, r in enumerate(rows):
        missing = sorted(required(r) - r.keys())
        if missing:
            label = r.get("cell") or r.get("op") or f"row{i}"
            fails.append(
                f"{fname} row '{label}': stopped emitting registered "
                f"metric field(s): {', '.join(missing)}")
    return fails


def compare(
    baseline: dict[str, tuple[float, str]],
    current: dict[str, tuple[float, str]],
    *,
    tolerance: float,
    semantic_tolerance: float,
) -> tuple[list[str], list[str]]:
    """Returns (failures, notes)."""
    fails, notes = [], []
    ratios: list[float] = []  # current/baseline per throughput cell
    for name, (base, kind) in sorted(baseline.items()):
        if name not in current:
            fails.append(f"{name}: present in baseline but missing from the "
                         f"current run (coverage shrank)")
            continue
        cur, cur_kind = current[name]
        if kind == "throughput":
            if base <= 0:
                continue
            ratios.append(max(cur / base, 1e-9))
            rel = (cur - base) / base
            if abs(rel) > tolerance:  # informational: one cell is noise
                notes.append(f"{name}: {rel:+.1%} vs baseline (cell-level, "
                             f"not gated)")
        elif kind.startswith("floor:"):
            # an absolute bar, independent of the baseline: the metric
            # must stay >= the floor the CURRENT run declares (the bar is
            # versioned with the bench code, and re-baselining a slow run
            # cannot lower it)
            floor = float((cur_kind if cur_kind.startswith("floor:")
                           else kind).split(":", 1)[1])
            if cur < floor:
                fails.append(f"{name}: {cur:.1f} below the absolute "
                             f"floor {floor:.1f}")
            else:
                notes.append(f"{name}: {cur:.1f} >= floor {floor:.1f} "
                             f"(baseline {base:.1f})")
        else:  # semantic: deterministic, tight absolute band per cell
            if abs(cur - base) > semantic_tolerance:
                fails.append(f"{name}: {cur:.4f} vs baseline {base:.4f} "
                             f"(|delta| > {semantic_tolerance})")
    if ratios:
        geo = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
        if geo < 1.0 - tolerance:
            fails.append(f"throughput geomean {geo:.3f}x of baseline "
                         f"across {len(ratios)} cell(s) "
                         f"(< {1.0 - tolerance:.2f}x allowed)")
        else:
            notes.append(f"throughput geomean {geo:.3f}x of baseline "
                         f"across {len(ratios)} cell(s)")
    return fails, notes


def check(out_dir: pathlib.Path, base_dir: pathlib.Path, *,
          tolerance: float, semantic_tolerance: float) -> int:
    any_checked = False
    all_fails: list[str] = []
    for fname in GATED:
        base_path, cur_path = base_dir / fname, out_dir / fname
        if not base_path.exists():
            print(f"SKIP {fname}: no baseline at {base_path}")
            continue
        if not cur_path.exists():
            all_fails.append(f"{fname}: baseline exists but the smoke run "
                             f"produced no {cur_path}")
            continue
        any_checked = True
        cur_rows = _load_rows(cur_path)
        schema_fails = schema_failures(fname, cur_rows)
        if schema_fails:  # extraction would KeyError on these rows anyway
            all_fails.extend(schema_fails)
            continue
        extract = EXTRACTORS[fname]
        fails, notes = compare(
            extract(_load_rows(base_path)), extract(cur_rows),
            tolerance=tolerance, semantic_tolerance=semantic_tolerance)
        for n in notes:
            print(f"NOTE {n}")
        if fails:
            all_fails.extend(fails)
        else:
            print(f"OK {fname}: within {tolerance:.0%} of baseline")
    for f in all_fails:
        print(f"FAIL {f}")
    if not any_checked and not all_fails:
        print("WARNING: nothing checked (no baselines found)")
    return 1 if all_fails else 0


def update(out_dir: pathlib.Path, base_dir: pathlib.Path) -> int:
    base_dir.mkdir(parents=True, exist_ok=True)
    for fname in GATED:
        src = out_dir / fname
        if not src.exists():
            print(f"SKIP {fname}: no smoke output to baseline from")
            continue
        shutil.copyfile(src, base_dir / fname)
        print(f"baselined {fname} -> {base_dir / fname}")
    return 0


def main() -> None:
    repo = pathlib.Path(__file__).resolve().parents[1]
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out-dir", type=pathlib.Path,
                    default=repo / "benchmarks" / "out",
                    help="where the smoke run wrote BENCH_*.json")
    ap.add_argument("--baselines", type=pathlib.Path,
                    default=repo / "benchmarks" / "baselines")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="max relative throughput drop (default 0.30)")
    ap.add_argument("--semantic-tolerance", type=float, default=0.02,
                    help="max absolute drift of reduction ratios")
    ap.add_argument("--update", action="store_true",
                    help="copy the current smoke outputs over the baselines")
    args = ap.parse_args()
    if args.update:
        sys.exit(update(args.out_dir, args.baselines))
    sys.exit(check(args.out_dir, args.baselines, tolerance=args.tolerance,
                   semantic_tolerance=args.semantic_tolerance))


if __name__ == "__main__":
    main()
