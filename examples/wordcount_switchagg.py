"""The paper's MapReduce word-count over a SwitchAgg aggregation tree.

Eight mapper workers (devices) emit (word, 1) KV pairs with a Zipf-0.99
skew (paper §6.1); the aggregation tree combines them hop by hop through
bounded-memory FPE/BPE nodes.  Reports per-level reduction ratios, traffic
with vs without in-network aggregation, and a modeled job-completion-time —
the paper's Fig. 9 / Fig. 10 story end to end.

    PYTHONPATH=src python examples/wordcount_switchagg.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import collectives as coll
from repro.core import planner, reduction_model as rm, tree as tree_lib

PAIR_BYTES = 24  # avg variable-length pair incl. metadata (paper: 16-64B keys)


def main():
    n_workers = 8
    pairs_per_worker = 4096
    key_variety = 2048
    mesh = jax.make_mesh((4, 2, 1), ("data", "pod", "model"))

    # --- the controller configures the job (paper §3/§4.1 protocol) -------
    tree = tree_lib.from_mesh(mesh, reduce_axes=("data", "pod"))
    ctl = planner.Controller(combiner_budget_pairs=1024)
    msg = ctl.configure(
        planner.LaunchRequest(job_id=1, n_workers=n_workers,
                              expected_pairs=pairs_per_worker,
                              key_variety=key_variety), tree)
    print(f"aggregation tree: {tree.describe()}")
    print(f"controller config: fpe_capacity={msg.fpe_capacity} pairs/node, "
          f"fanins={msg.fanins}")
    pred = rm.reduction_ratio(n_workers * pairs_per_worker, key_variety,
                              msg.fpe_capacity)
    print(f"Eq.(3) predicted reduction at root: {pred:.3f}")

    # --- mappers emit Zipf word streams -----------------------------------
    keys = rm.zipf_keys(n_workers * pairs_per_worker, key_variety,
                        skew=0.99, seed=0).astype(np.int32)
    vals = np.ones_like(keys, dtype=np.float32)
    spec = NamedSharding(mesh, P(("data", "pod")))
    agg = coll.make_kv_tree_aggregator(
        mesh, ("data", "pod"), fpe_capacity=msg.fpe_capacity, ways=4, bpe=True)
    res = agg(jax.device_put(jnp.asarray(keys), spec),
              jax.device_put(jnp.asarray(vals), spec))

    li, lo = np.asarray(res.level_in), np.asarray(res.level_out)
    print("\nper-hop traffic (pairs):")
    total_in = n_workers * pairs_per_worker
    for i, (ax, fin) in enumerate(zip(tree.axes, msg.fanins)):
        print(f"  level {i} ({ax:5s} x{fin}): in={li[i]:6d} out={lo[i]:6d} "
              f"reduction={1 - lo[i]/max(li[i],1):.3f}")
    root_red = 1 - lo[-1] / total_in
    print(f"end-to-end reduction: {root_red:.3f} (predicted {pred:.3f})")

    # verify against exact ground truth
    got = {}
    for k, v in zip(np.asarray(res.keys).tolist(), np.asarray(res.values).tolist()):
        if k != -1:
            got[k] = got.get(k, 0.0) + v
    want = np.bincount(keys, minlength=key_variety)
    ok = all(abs(got.get(k, 0.0) - c) < 1e-3 for k, c in enumerate(want) if c)
    print(f"word counts exact: {ok}")

    # --- modeled JCT with vs without in-network aggregation (Fig. 10) -----
    print("\nmodeled job-completion-time (reducer in-link is the bottleneck):")
    for wl_gb in (2, 4, 8, 16):
        total_bytes = wl_gb * (1 << 30)
        link = 10e9 / 8  # 10 Gbps reducer in-link, as the paper's testbed
        t_no = total_bytes / link
        t_sw = total_bytes * (1 - root_red) / link
        print(f"  workload {wl_gb:2d} GB: no-agg {t_no:6.1f}s  "
              f"switchagg {t_sw:6.1f}s  saved {1 - t_sw/t_no:.0%}")
    ctl.release(1)


if __name__ == "__main__":
    main()
