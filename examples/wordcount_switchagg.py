"""The paper's MapReduce word-count over a SwitchAgg aggregation tree.

Eight mapper workers (devices) emit (word, 1) KV pairs with a Zipf-0.99
skew (paper §6.1); the aggregation tree combines them hop by hop through
bounded-memory FPE/BPE nodes.  Reports per-level reduction ratios, traffic
with vs without in-network aggregation, and a packet-level *measured*
job-completion-time (``repro.net.sim``: MTU framing, per-link
serialization, go-back-N loss recovery) against the host-only baseline —
the paper's Fig. 9 / Fig. 10 story end to end.

    PYTHONPATH=src python examples/wordcount_switchagg.py
"""

import dataclasses
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import collectives as coll
from repro.core import dataplane, planner, reduction_model as rm, tree as tree_lib
from repro.net import sim as netsim


def main():
    n_workers = 8
    pairs_per_worker = 4096
    key_variety = 2048
    mesh = jax.make_mesh((4, 2, 1), ("data", "pod", "model"))

    # --- the controller configures the job (paper §3/§4.1 protocol) -------
    tree = tree_lib.from_mesh(mesh, reduce_axes=("data", "pod"))
    ctl = planner.Controller(combiner_budget_pairs=1024)
    msg = ctl.configure(
        planner.LaunchRequest(job_id=1, n_workers=n_workers,
                              expected_pairs=pairs_per_worker,
                              key_variety=key_variety), tree)
    print(f"aggregation tree: {tree.describe()}")
    print(f"controller config: fpe_capacity={msg.fpe_capacity} pairs/node, "
          f"fanins={msg.fanins}")
    pred = rm.reduction_ratio(n_workers * pairs_per_worker, key_variety,
                              msg.fpe_capacity)
    print(f"Eq.(3) predicted reduction at root: {pred:.3f}")

    # --- mappers emit Zipf word streams -----------------------------------
    keys = rm.zipf_keys(n_workers * pairs_per_worker, key_variety,
                        skew=0.99, seed=0).astype(np.int32)
    vals = np.ones_like(keys, dtype=np.float32)
    spec = NamedSharding(mesh, P(("data", "pod")))
    agg = coll.make_kv_tree_aggregator(
        mesh, ("data", "pod"), fpe_capacity=msg.fpe_capacity, ways=4, bpe=True)
    res = agg(jax.device_put(jnp.asarray(keys), spec),
              jax.device_put(jnp.asarray(vals), spec))

    li, lo = np.asarray(res.level_in), np.asarray(res.level_out)
    print("\nper-hop traffic (pairs):")
    total_in = n_workers * pairs_per_worker
    for i, (ax, fin) in enumerate(zip(tree.axes, msg.fanins)):
        print(f"  level {i} ({ax:5s} x{fin}): in={li[i]:6d} out={lo[i]:6d} "
              f"reduction={1 - lo[i]/max(li[i],1):.3f}")
    root_red = 1 - lo[-1] / total_in
    print(f"end-to-end reduction: {root_red:.3f} (predicted {pred:.3f})")

    # verify against exact ground truth
    got = {}
    for k, v in zip(np.asarray(res.keys).tolist(), np.asarray(res.values).tolist()):
        if k != -1:
            got[k] = got.get(k, 0.0) + v
    want = np.bincount(keys, minlength=key_variety)
    ok = all(abs(got.get(k, 0.0) - c) < 1e-3 for k, c in enumerate(want) if c)
    print(f"word counts exact: {ok}")

    # --- measured JCT with vs without in-network aggregation (Fig. 10) ----
    # The packet-level simulator streams the same mapper output through the
    # tree: MTU-framed packets, 10 GbE links (the paper's testbed), line-rate
    # switch processing, and the reducer in-link as the host-only bottleneck.
    print("\nsimulated job-completion-time (packet-level, 10 GbE):")
    cascade = dataplane.plan_from_configure(msg)
    net_cfg = netsim.NetConfig(link_gbps=(netsim.TEN_GBE,) * len(msg.fanins),
                               reducer_gbps=netsim.TEN_GBE)
    jct = netsim.jct_comparison(keys, vals, fanins=msg.fanins, plan=cascade,
                                cfg=net_cfg, axes=tree.axes)
    sw, host = jct["switchagg"], jct["host_only"]
    print(f"  host-only: JCT {jct['jct_host_only_s']*1e3:8.3f} ms  "
          f"({host['arrived_records']} records over the reducer in-link)")
    print(f"  switchagg: JCT {jct['jct_switchagg_s']*1e3:8.3f} ms  "
          f"({sw['arrived_records']} records reach the reducer)")
    print(f"  JCT saved: {jct['jct_saved']:.0%}  "
          f"(reducer-traffic cut {jct['reduction']:.0%})")
    print("  per-level wire bytes (switchagg): "
          + ", ".join(f"{ax}={sw['link_bytes'][ax]/1024:.1f}KiB"
                      for ax in (*tree.axes, "reducer")))

    # loss resilience: 1% packet loss, go-back-N recovery, PSN dedupe —
    # the delivered word counts stay exact while JCT pays for retransmits
    lossy_cfg = dataclasses.replace(net_cfg, loss_rate=0.01, seed=7)
    from repro.net import simulate
    lossy = simulate(netsim.JobSpec(keys=keys, values=vals,
                                    fanins=msg.fanins, plan=cascade,
                                    cfg=lossy_cfg, axes=tree.axes))
    still_exact = all(
        abs(lossy.delivered_table().get(k, 0.0) - c) < 1e-3
        for k, c in enumerate(want) if c)
    print(f"\n1% packet loss: JCT {lossy.jct_s*1e3:.3f} ms "
          f"({lossy.retransmissions} retransmits, "
          f"{lossy.packets_dropped} drops), counts exact: {still_exact}")
    ctl.release(1)


if __name__ == "__main__":
    main()
