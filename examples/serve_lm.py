"""Serve a small model with batched requests: TP-sharded weights,
model-axis-sharded KV cache (flash-decode partial-softmax combine).

    PYTHONPATH=src python examples/serve_lm.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as serve_launch


def main():
    serve_launch.main([
        "--arch", "gemma2-27b", "--reduce", "--fp32",
        "--mesh", "2,4", "--batch", "4", "--prompt-len", "32", "--gen", "12",
    ])


if __name__ == "__main__":
    main()
