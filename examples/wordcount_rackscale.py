"""The paper's MapReduce word-count, scaled to a multi-rack fat-tree.

Where ``wordcount_switchagg.py`` runs eight mappers under one switch, this
variant spreads 128 mappers across a 4-pod, 4:1-oversubscribed fat-tree
(DESIGN.md §9) and asks the question that decides whether in-network
aggregation deploys on real datacenter infrastructure: *where* should the
bounded-capability aggregation nodes go?  The placement search scores each
deployment by modeled scarce-uplink bytes; the packet-level simulator then
measures wire bytes and job-completion time for host-only, ToR-only, and
full-tree placements of the SAME Zipf word stream — every placement stays
exact, they differ only in where traffic dies.

    PYTHONPATH=src python examples/wordcount_rackscale.py

Env knobs (the examples test uses the defaults): RACK_PODS, RACK_TORS,
RACK_HOSTS, RACK_PAIRS, RACK_VARIETY; RACK_OBS_DIR overrides where the
observability artifacts (Perfetto trace + dashboard, DESIGN.md §11) land.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import planner
from repro.core import reduction_model as rm
from repro.net import sim as netsim
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace

MiB = float(1 << 20)


def main():
    obs_trace.enable()
    pods = int(os.environ.get("RACK_PODS", "4"))
    tors = int(os.environ.get("RACK_TORS", "4"))
    hosts = int(os.environ.get("RACK_HOSTS", "8"))
    per_host = int(os.environ.get("RACK_PAIRS", "256"))
    variety = int(os.environ.get("RACK_VARIETY", "2048"))

    ft = planner.FatTreeTopology(pods=pods, tors_per_pod=tors,
                                 hosts_per_tor=hosts,
                                 oversubscription=4.0, table_pairs=2048)
    print(f"fat-tree: {ft.describe()}")
    print(f"{ft.n_hosts} mappers, {per_host} pairs each, "
          f"key variety {variety}, scarce uplink tier "
          f"'{ft.scarce_uplink_axis()}'\n")

    # --- the controller's placement search (modeled bytes) ----------------
    print("placement search (modeled scarce-uplink bytes):")
    for pol in ("host_only", "tor_only", "full", "auto"):
        p = planner.place_aggregation_tree(
            ft, per_host_pairs=per_host, key_variety=variety, policy=pol)
        tiers = "+".join(p.tiers) if p.tiers else "none"
        print(f"  {pol:>9}: tiers={tiers:<14} switches={p.n_agg_switches:>2} "
              f"scarce={p.scarce_uplink_bytes/MiB:6.3f} MiB "
              f"reducer={p.reducer_bytes/MiB:6.3f} MiB")
    chosen = planner.place_aggregation_tree(
        ft, per_host_pairs=per_host, key_variety=variety, policy="auto")
    print(f"search picks: {chosen.describe()}\n")

    # --- mappers emit Zipf word streams; simulate each placement ----------
    n = ft.n_hosts * per_host
    keys = rm.zipf_keys(n, variety, skew=0.99, seed=0).astype(np.int32)
    vals = np.ones_like(keys, dtype=np.float32)
    cmp = netsim.fat_tree_jct_comparison(
        ft, keys, vals, per_host_pairs=per_host, key_variety=variety,
        cfg=netsim.NetConfig(exact_stream=False))
    scarce = cmp["scarce_axis"]

    print(f"measured (packet-level, {ft.edge_gbps*8:g} Gb/s host links):")
    want = np.bincount(keys, minlength=variety)
    for pol in cmp["policies"]:
        r = cmp[pol]
        got = cmp["_results"][pol].delivered_table()
        exact = all(abs(got.get(k, 0.0) - c) < 1e-3
                    for k, c in enumerate(want) if c)
        print(f"  {pol:>9}: JCT {cmp['jct_s'][pol]*1e3:8.3f} ms  "
              f"scarce({scarce}) {r['link_bytes'][scarce]/MiB:6.3f} MiB  "
              f"reducer {r['link_bytes']['reducer']/MiB:6.3f} MiB  "
              f"counts exact: {exact}")

    j = cmp["jct_s"]
    cut = 1.0 - (cmp["full"]["link_bytes"][scarce]
                 / cmp["tor_only"]["link_bytes"][scarce])
    saved = 1.0 - j["full"] / j["host_only"]
    print(f"\nfull-tree cuts scarce-uplink bytes {cut:.0%} vs ToR-only")
    print(f"rack-scale JCT saved vs host-only: {saved:.0%}")
    ordered = j["full"] <= j["tor_only"] <= j["host_only"]
    print(f"JCT ordering full-tree <= ToR-only <= host-only: {ordered}")

    # --- observability artifacts: Perfetto trace + dashboard --------------
    obs_dir = os.environ.get("RACK_OBS_DIR", os.path.join(
        os.path.dirname(__file__), "..", "benchmarks", "artifacts",
        "rackscale_obs"))
    paths = obs_report.write_obs_artifacts(
        obs_dir, title="rack-scale wordcount observability")
    print("\nobs artifacts (trace.json loads in Perfetto):")
    for name in sorted(paths):
        print(f"  {name}: {os.path.relpath(paths[name])}")


if __name__ == "__main__":
    main()
