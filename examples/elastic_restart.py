"""Fault-tolerance demo: kill a training job mid-run, restart it on a
DIFFERENT mesh, and verify the loss curve continues exactly.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_launch

BASE = ["--arch", "olmoe-1b-7b", "--reduce", "--fp32", "--batch", "8",
        "--seq", "32", "--mode", "tree", "--ckpt-every", "10",
        "--log-every", "5"]


def main():
    ckpt = tempfile.mkdtemp(prefix="elastic_")
    try:
        print("=== phase 1: train on mesh 4x2, 'crash' at step 20 ===")
        _, loop1 = train_launch.main(
            BASE + ["--mesh", "4,2", "--steps", "20", "--ckpt-dir", ckpt])
        l1 = [m["loss"] for m in loop1.metrics_history]

        print("\n=== phase 2: restart on mesh 2,2,2 (elastic re-mesh), to 40 ===")
        _, loop2 = train_launch.main(
            BASE + ["--mesh", "2,2,2", "--steps", "40", "--ckpt-dir", ckpt])
        # resumed at 20: phase 2 executed exactly steps 20..39
        assert len(loop2.metrics_history) == 20, len(loop2.metrics_history)
        assert loop2.metrics_history[0]["step"] == 20
        l2 = [m["loss"] for m in loop2.metrics_history]
        print(f"\nphase-1 last losses: {[round(x, 4) for x in l1[-3:]]}")
        print(f"phase-2 first losses: {[round(x, 4) for x in l2[:3]]}")
        assert l2[0] < l1[0], "restart lost progress"
        print("elastic restart OK: job resumed at step 20 on a different mesh")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
