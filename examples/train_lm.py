"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

The deliverable-(b) driver.  Uses the production launcher code path
(fault-tolerant loop, checkpointing, SwitchAgg tree exchange).  With
--preset smoke it finishes on one CPU in a couple of minutes; --preset full
is the real ~100M x 300-step run (expect ~CPU-hours; on a pod it is the
same command with a real mesh).

    PYTHONPATH=src python examples/train_lm.py --preset smoke
    PYTHONPATH=src python examples/train_lm.py --preset full
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_launch

PRESETS = {
    # ~10M params, 60 steps — CI-sized proof of the full path
    "smoke": ["--arch", "phi4-mini-3.8b", "--reduce", "--d-model", "256",
              "--layers", "4", "--steps", "60", "--batch", "8", "--seq", "64",
              "--mode", "tree", "--ckpt-every", "25", "--fp32"],
    # ~100M params, 300 steps — the deliverable run
    "full": ["--arch", "phi4-mini-3.8b", "--reduce", "--d-model", "768",
             "--layers", "12", "--steps", "300", "--batch", "8", "--seq", "256",
             "--mode", "tree", "--ckpt-every", "50"],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="smoke")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args, extra = ap.parse_known_args()
    argv = PRESETS[args.preset] + ["--ckpt-dir", args.ckpt_dir] + extra
    print(f"launching: repro.launch.train {' '.join(argv)}")
    final, loop = train_launch.main(argv)
    losses = [m["loss"] for m in loop.metrics_history]
    print(f"\nloss curve: start={losses[0]:.4f} "
          f"mid={losses[len(losses)//2]:.4f} end={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "training did not make progress"
    print("OK")


if __name__ == "__main__":
    main()
