"""Quickstart: train a tiny LM with the SwitchAgg tree exchange, then decode.

Runs on 1 CPU in ~a minute:
    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.reduced import reduced_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.models.model import LMModel
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, adamw_init, make_lr_schedule
from repro.train.step import TrainProfile, build_train_step


def main():
    # a miniature gemma2 (local+global attention, softcaps) in float32
    cfg = dataclasses.replace(reduced_config("gemma2-27b"), dtype="float32")
    print(f"model: {cfg.name} | {cfg.param_count()/1e6:.2f}M params | "
          f"pattern {[s.mixer for s in cfg.pattern]}")

    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    prof = TrainProfile(q_chunk=16, k_chunk=16, moe_token_chunk=64, remat="none")
    data = SyntheticLMData(cfg, DataConfig(seq_len=32, global_batch=8))
    opt_cfg = AdamWConfig()
    model = LMModel(cfg)
    params = model.init(jax.random.PRNGKey(0))

    step_fn, sh, _ = build_train_step(
        cfg, mesh, prof, opt_cfg, make_lr_schedule(3e-3, 5, 60),
        batch_example=data.batch_at(0), params_example=params)
    opt = adamw_init(params, opt_cfg)

    # QUICKSTART_STEPS lets the CI smoke test run a short budget
    n_steps = int(os.environ.get("QUICKSTART_STEPS", "60"))
    print(f"training {n_steps} steps...")
    for i in range(n_steps):
        params, opt, m = step_fn(params, opt, data.batch_at(i),
                                 jnp.asarray(i, jnp.int32))
        if i % 10 == 0:
            print(f"  step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}")
    print(f"  final loss {float(m['loss']):.4f}")

    # greedy decode from a prompt (prefill + KV-cache steps)
    model_d = LMModel(cfg, opt=tfm.ApplyOptions(q_chunk=8, k_chunk=8, remat="none"))
    prompt = data.batch_at(0)["tokens"][:1, :8]
    logits, caches = jax.jit(
        lambda p, t: model_d.prefill(p, {"tokens": t}, 24))(params, prompt)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [int(tok[0, 0])]
    step = jax.jit(lambda p, t, c, i: model_d.decode_step(p, t, c, i))
    for i in range(8):
        lg, caches = step(params, tok, caches, jnp.asarray(8 + i, jnp.int32))
        tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
        out.append(int(tok[0, 0]))
    print(f"prompt ids: {np.asarray(prompt[0]).tolist()}")
    print(f"greedy continuation: {out}")


if __name__ == "__main__":
    main()
