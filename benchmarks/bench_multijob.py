"""Multi-job congestion-aware controller benchmark (DESIGN.md §3).

Sweeps N concurrent aggregation jobs over the shared production topology
(data=16 intra-pod ICI @ 50 GB/s, pod=2 inter-pod DCN @ 6.25 GB/s) and
compares, per job and in total, the bytes placed on the scarce inter-pod
level by:

  * ``flat``      — N independent flat all-reduces (no in-network
                    aggregation; the paper's baseline),
  * ``scheduled`` — the `JobScheduler`'s congestion-aware trees, with a
                    SOAR-style byte budget on the scarce level that
                    escalates over-budget jobs to the compressed exchange.

Pure analytic (no jax) — runs on any CPU in milliseconds:

    PYTHONPATH=src python benchmarks/bench_multijob.py --jobs 4
    PYTHONPATH=src python benchmarks/bench_multijob.py --sweep
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # package import (benchmarks.run) or standalone CLI
    from benchmarks._util import write_bench_json
except ImportError:  # `python benchmarks/bench_*.py`: sys.path[0] is here
    from _util import write_bench_json

from repro.core import planner as pl
from repro.core.collectives import GradAggMode

MiB = float(1 << 20)


def make_requests(n_jobs: int, *, base_mb: float = 256.0) -> list:
    """N tenants with heterogeneous gradient sizes and key varieties.

    Sizes follow a deterministic geometric spread (largest job = base_mb);
    key variety grows with job id so the weighted memory policy has
    something to weigh.
    """
    reqs = []
    for i in range(n_jobs):
        grad_bytes = int(base_mb * MiB / (1 << (i % 4)))
        reqs.append(pl.LaunchRequest(
            job_id=i, n_workers=32,
            expected_pairs=10_000,
            key_variety=1_000 * (1 + i),
            grad_bytes=grad_bytes,
            mode=GradAggMode.TREE,
        ))
    return reqs


def run_once(n_jobs: int, *, budget_mb: float, partition: str,
             base_mb: float) -> dict:
    budget = budget_mb * MiB if budget_mb > 0 else math.inf
    topo = pl.Topology.production(scarce_budget_bytes=budget)
    sched = pl.JobScheduler(topo, combiner_budget_pairs=1 << 20,
                            partition_policy=partition)
    report = sched.plan_all(make_requests(n_jobs, base_mb=base_mb))

    rows = []
    for jp in report.jobs:
        x = jp.exchange
        rows.append({
            "job": x.job_id,
            "mode": x.mode.value,
            "order": " -> ".join((x.leaf_axis, *x.upper_axes)),
            "fpe_capacity": x.fpe_capacity,
            "k_fraction": x.k_fraction,
            "scarce_mb": x.scarce_link_bytes / MiB,
            "flat_scarce_mb": jp.flat_scarce_bytes / MiB,
            "scarce_cut": x.predicted_root_reduction,
            "kv_reduction": x.predicted_kv_reduction,
            "over_budget": jp.over_budget,
        })
    return {
        "n_jobs": n_jobs,
        "partition": partition,
        "budget_mb": budget_mb,
        "jobs": rows,
        "total_scarce_mb": report.total_scarce_bytes / MiB,
        "flat_total_scarce_mb": report.baseline_flat_scarce_bytes / MiB,
        "scarce_traffic_cut": report.scarce_traffic_cut,
        "max_drain_ms": report.max_drain_s * 1e3,
        "link_totals_mb": {a: b / MiB for a, b in report.link_totals.items()},
    }


def smoke_rows() -> list[dict]:
    """The CI cell: 4 tenants, weighted partition, 128 MiB scarce budget —
    asserts the congestion-aware plans beat independent flat all-reduces."""
    res = run_once(4, budget_mb=128.0, partition="weighted", base_mb=256.0)
    assert res["total_scarce_mb"] < res["flat_total_scarce_mb"], (
        "congestion-aware plans must beat independent flat all-reduces")
    return [res]


def write_out(rows: list[dict], out_path: str) -> None:
    write_bench_json(rows, out_path, bench="multijob")


def print_rows(rows: list[dict]) -> None:
    for res in rows:
        print_report(res)


def print_report(res: dict) -> None:
    budget = "inf" if res["budget_mb"] <= 0 else f"{res['budget_mb']:g}MiB"
    print(f"\n== {res['n_jobs']} concurrent job(s) | "
          f"partition={res['partition']} | scarce budget={budget} ==")
    hdr = (f"{'job':>3} {'mode':<13} {'order':<16} {'fpe_cap':>8} "
           f"{'k':>7} {'scarce MiB':>10} {'flat MiB':>9} {'cut':>7} "
           f"{'kv_red':>7}")
    print(hdr)
    for r in res["jobs"]:
        flag = " *over-budget*" if r["over_budget"] else ""
        print(f"{r['job']:>3} {r['mode']:<13} {r['order']:<16} "
              f"{r['fpe_capacity']:>8} {r['k_fraction']:>7.4f} "
              f"{r['scarce_mb']:>10.2f} {r['flat_scarce_mb']:>9.2f} "
              f"{r['scarce_cut']:>6.1%} {r['kv_reduction']:>7.3f}{flag}")
    print(f"total scarce-link bytes: {res['total_scarce_mb']:.2f} MiB "
          f"(flat baseline {res['flat_total_scarce_mb']:.2f} MiB, "
          f"cut {res['scarce_traffic_cut']:.1%}); "
          f"max link drain {res['max_drain_ms']:.3f} ms")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--jobs", type=int, default=4,
                    help="number of concurrent jobs (default 4)")
    ap.add_argument("--sweep", action="store_true",
                    help="sweep 1..8 concurrent jobs instead of one count")
    ap.add_argument("--budget-mb", type=float, default=128.0,
                    help="scarce-level byte budget per round; <=0 disables")
    ap.add_argument("--base-mb", type=float, default=256.0,
                    help="gradient bytes of the largest job")
    ap.add_argument("--partition", choices=["even", "weighted"],
                    default="weighted")
    ap.add_argument("--out", default=None,
                    help="optional JSON output path")
    args = ap.parse_args()
    if not args.sweep and args.jobs < 1:
        ap.error("--jobs must be >= 1")

    counts = range(1, 9) if args.sweep else [args.jobs]
    results = []
    for n in counts:
        res = run_once(n, budget_mb=args.budget_mb,
                       partition=args.partition, base_mb=args.base_mb)
        print_report(res)
        results.append(res)

    worst = max(results, key=lambda r: r["total_scarce_mb"])
    assert worst["total_scarce_mb"] < worst["flat_total_scarce_mb"], (
        "congestion-aware plans must beat independent flat all-reduces "
        "on the scarce link")
    print(f"\ncongestion-aware scheduling beats flat in every case "
          f"(worst case: {worst['total_scarce_mb']:.2f} vs "
          f"{worst['flat_total_scarce_mb']:.2f} MiB)")

    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
