"""Churn benchmark: online incremental admission vs the offline
full-replan oracle (DESIGN.md §13).

A Poisson trace of job arrivals/departures (>= 100 jobs over an 8-pod
fabric in the gated cell) is driven twice:

  * **online** — one ``OnlineController``: each arrival costs a single
    placement search on the residual switch-table capability (plus the
    occasional preemption repair / post-departure re-expansion);
  * **oracle** — the offline full-replan bound: at *every* event it
    re-places *every* active job from scratch, highest value first, with
    no incremental constraint (no stale placements, no preemption
    collateral, no grant it cannot revisit).

Both legs are scored on the same clock: ``*_scarce_mb`` is the
time-averaged scarce-uplink byte load of the active placements, and
``placements scored`` (the planner's own ``candidates_scored_total``
counter) is the placement work.  The CI gate holds two ratios:

  * ``oracle_to_online`` (floor 0.90) — the online controller's
    scarce-link load stays within ~10% of the oracle's;
  * ``work_speedup`` (floor 10.0) — at >= 10x fewer candidate
    placements scored than the replan-the-world oracle.

Two packet-level cross-checks ride each row as semantic cells:
``admit_parity`` (a mid-run admission joining the lockstep batch gives
bit-identical results on the node and vectorized engines) and
``evict_exactly_once`` (a value-based eviction rendered as failure
events and replayed through the epoch-restart driver under packet loss
still delivers the aggregate table bit-identically to a clean run).

    PYTHONPATH=src python benchmarks/bench_churn.py
    PYTHONPATH=src python benchmarks/bench_churn.py --smoke \
        --out benchmarks/out/BENCH_churn.json
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # package import (benchmarks.run) or standalone CLI
    from benchmarks._util import write_bench_json
except ImportError:  # `python benchmarks/bench_*.py`: sys.path[0] is here
    from _util import write_bench_json

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "BENCH_churn.json")

#: online scarce-link load within ~10% of the offline full-replan oracle
ORACLE_TO_ONLINE_FLOOR = 0.90
#: and at >= 10x less placement work (candidate placements scored)
WORK_SPEEDUP_FLOOR = 10.0

TENANTS = (("t0", 2.0), ("t1", 1.0), ("t2", 1.0))


def _scored() -> float:
    from repro.obs import metrics as obs_metrics

    return sum(v for _, v in obs_metrics.get_registry().find(
        "planner.placement.candidates_scored_total"))


def poisson_trace(n_jobs: int, *, rng, arrival_rate: float = 1.0,
                  mean_duration: float = 12.0) -> list[tuple]:
    """``(time, "arrive"/"depart", job_id, request)`` events, time-sorted.
    Exponential inter-arrivals and service times; per-job variety/pairs/
    value/tenant drawn from the same seeded stream."""
    from repro.core.controller import OnlineJobRequest

    events = []
    t = 0.0
    for j in range(n_jobs):
        t += rng.exponential(1.0 / arrival_rate)
        dur = rng.exponential(mean_duration)
        tenant, _ = TENANTS[int(rng.integers(len(TENANTS)))]
        req = OnlineJobRequest(
            job_id=j,
            expected_pairs=int(rng.integers(500, 4000)),
            key_variety=int(rng.integers(64, 257)),
            tenant=tenant,
            value=float(rng.integers(1, 6)),
        )
        events.append((t, "arrive", j, req))
        events.append((t + dur, "depart", j, req))
    events.sort(key=lambda e: (e[0], e[1] == "arrive", e[2]))
    return events


def _oracle_replan(ft, active: dict, placeable) -> float:
    """The offline full-replan bound for one instant: the whole active
    set re-placed from scratch, highest value first, each job granted
    table greedily from what the better jobs left (the controller's own
    grant rule, minus every incremental constraint — no stale
    placements, no preemption collateral, no grant it cannot revisit)."""
    from repro.core.planner import FAT_TREE_TIERS, place_aggregation_tree

    residual = {t: ft.switch_table(t) for t in placeable}
    total = 0.0
    for req in sorted(active.values(),
                      key=lambda r: (-r.value, r.job_id)):
        caps = {t: min(req.key_variety, residual[t]) for t in placeable}
        ft_r = dataclasses.replace(
            ft, table_pairs=0, tier_table_pairs=tuple(
                (t, caps.get(t, 0)) for t in FAT_TREE_TIERS))
        p = place_aggregation_tree(
            ft_r, per_host_pairs=req.expected_pairs,
            key_variety=req.key_variety)
        for t in p.tiers:
            residual[t] -= caps[t]
        total += p.scarce_uplink_bytes
    return total


def _check_admit_parity(seed: int) -> bool:
    """A job admitted mid-run (between lockstep levels) must leave every
    job's delivered table and JCT bit-identical across engines."""
    from repro.core import dataplane
    from repro.core import reduction_model as rm
    from repro.net import simulate
    from repro.net import sim as netsim

    def spec(i, cfg):
        n = 64
        keys = rm.zipf_keys(n, 32, skew=0.9, seed=seed + i).astype(np.int32)
        plan = dataplane.CascadePlan(op="sum", levels=(
            dataplane.LevelSpec(capacity=16),
            dataplane.LevelSpec(capacity=16)))
        return netsim.JobSpec(
            keys=keys, values=np.ones((n,), np.float32), fanins=(4, 2),
            plan=plan, cfg=cfg, job_id=i, tag=f"churn-adm{i}")

    outs = {}
    for engine in ("node", "vectorized"):
        cfg = netsim.NetConfig(seed=seed, engine=engine)
        base = [spec(0, cfg), spec(1, cfg)]
        outs[engine] = simulate(base, admissions=[(1, spec(2, cfg)),
                                                  (3, spec(3, cfg))])
    a, b = outs["node"], outs["vectorized"]
    return (len(a) == len(b)
            and all(x.delivered_table() == y.delivered_table()
                    and x.jct_s == y.jct_s for x, y in zip(a, b)))


def _check_evict_exactly_once(seed: int) -> bool:
    """Drive a real controller eviction through the epoch-restart driver
    under packet loss: the victim degrades mid-run (its evicted tier's
    switches die), yet the delivered table matches a clean run bit for
    bit."""
    from repro.core import reduction_model as rm
    from repro.core.controller import OnlineController, OnlineJobRequest
    from repro.core.planner import FatTreeTopology
    from repro.net import simulate
    from repro.net import sim as netsim
    from repro.runtime.fault_tolerance import FailureInjector

    ft = FatTreeTopology(pods=2, tors_per_pod=2, hosts_per_tor=2,
                         table_pairs=64)
    ctl = OnlineController(ft)
    victim = ctl.admit(OnlineJobRequest(job_id=0, expected_pairs=64,
                                        key_variety=64, value=1.0))
    ctl.admit(OnlineJobRequest(job_id=1, expected_pairs=64, key_variety=64,
                               value=5.0))
    assert ctl.evictions, "high-value arrival should have evicted job 0"

    n = ft.n_hosts * 48
    keys = rm.zipf_keys(n, 64, skew=0.99, seed=seed).astype(np.int32)
    vals = np.ones((n,), np.float32)
    clean = simulate(ft, keys, vals, placement=victim.placement,
                     cfg=netsim.NetConfig(seed=seed))
    events = ctl.eviction_failure_events(ctl.evictions[0],
                                         t_s=clean.jct_s * 0.02)
    faulted = simulate(
        ft, keys, vals, placement=victim.placement,
        faults=FailureInjector({}, events=events),
        cfg=netsim.NetConfig(seed=seed, loss_rate=0.05))
    return faulted.delivered_table() == clean.delivered_table()


def run_config(*, n_jobs: int = 120, pods: int = 8, tors_per_pod: int = 4,
               hosts_per_tor: int = 4, table_pairs: int = 2048,
               arrival_rate: float = 1.0, mean_duration: float = 12.0,
               seed: int = 0) -> dict:
    """One trace cell: online controller vs full-replan oracle."""
    from repro.core.controller import OnlineController
    from repro.core.planner import FatTreeTopology

    ft = FatTreeTopology(pods=pods, tors_per_pod=tors_per_pod,
                         hosts_per_tor=hosts_per_tor,
                         table_pairs=table_pairs)
    rng = np.random.default_rng(seed)
    events = poisson_trace(n_jobs, rng=rng, arrival_rate=arrival_rate,
                           mean_duration=mean_duration)
    ctl = OnlineController(ft, tenant_weights=dict(TENANTS))
    placeable = ctl.placeable_tiers()

    t0 = time.perf_counter()
    active: dict[int, object] = {}
    t_prev = events[0][0]
    online_int = oracle_int = 0.0  # time-integrated scarce bytes
    peak_active = peak_degraded = 0
    oracle_scored0 = None
    online_scarce = oracle_scarce = 0.0
    oracle_work = 0.0
    for t, kind, jid, req in events:
        dt = t - t_prev
        online_int += online_scarce * dt
        oracle_int += oracle_scarce * dt
        t_prev = t
        if kind == "arrive":
            ctl.admit(req)
            active[jid] = req
        else:
            ctl.release(jid)
            active.pop(jid, None)
        online_scarce = ctl.total_scarce_bytes()
        s0 = _scored()
        oracle_scarce = _oracle_replan(ft, active, placeable)
        oracle_work += _scored() - s0
        rep = ctl.report()
        peak_active = max(peak_active, rep.n_active)
        peak_degraded = max(peak_degraded, rep.n_degraded)
    wall_us = (time.perf_counter() - t0) * 1e6

    horizon = events[-1][0] - events[0][0]
    online_mb = online_int / horizon / 2**20
    oracle_mb = oracle_int / horizon / 2**20
    # lower scarce-link load is better; the oracle is the bound, so the
    # ratio is <= ~1 and the floor holds online within ~10% of it
    oracle_to_online = oracle_mb / online_mb if online_mb else 1.0
    work_speedup = oracle_work / max(ctl.candidates_scored_total, 1)
    admit_parity = _check_admit_parity(seed)
    evict_once = _check_evict_exactly_once(seed)

    rep = ctl.report()
    assert rep.n_active == 0, "trace should drain to an empty fabric"
    assert oracle_to_online >= ORACLE_TO_ONLINE_FLOOR, (
        f"online scarce load {online_mb:.2f}MiB strays >10% from the "
        f"oracle's {oracle_mb:.2f}MiB (ratio {oracle_to_online:.3f})")
    assert work_speedup >= WORK_SPEEDUP_FLOOR, (
        f"online planned only {work_speedup:.1f}x cheaper than the "
        f"replan-the-world oracle")
    assert admit_parity, "mid-run admission diverged across engines"
    assert evict_once, "eviction under loss broke exactly-once delivery"
    return {
        "cell": f"p{pods}/j{n_jobs}",
        "n_jobs": n_jobs,
        "pods": pods,
        "n_events": len(events),
        "peak_active": peak_active,
        "peak_degraded": peak_degraded,
        "evictions": len(ctl.evictions),
        "expansions": len(ctl.expansions),
        "online_scarce_mb": round(online_mb, 3),
        "oracle_scarce_mb": round(oracle_mb, 3),
        "oracle_to_online": round(oracle_to_online, 4),
        "oracle_to_online_floor": ORACLE_TO_ONLINE_FLOOR,
        "online_scored": int(ctl.candidates_scored_total),
        "oracle_scored": int(oracle_work),
        "work_speedup": round(work_speedup, 2),
        "work_speedup_floor": WORK_SPEEDUP_FLOOR,
        "admit_parity": 1.0,
        "evict_exactly_once": 1.0,
        "wall_us": round(wall_us, 1),
    }


def sweep(*, n_jobs=(40, 120), pods: int = 8, seed: int = 0,
          **kw) -> list[dict]:
    return [run_config(n_jobs=n, pods=pods, seed=seed, **kw)
            for n in n_jobs]


def smoke_rows() -> list[dict]:
    """The gated cell: >= 100 Poisson jobs over an 8-pod fabric, plus a
    smaller 4-pod shape check (the CI job)."""
    return [run_config(n_jobs=40, pods=4, seed=0),
            run_config(n_jobs=120, pods=8, seed=0)]


def write_out(rows: list[dict], out_path: str) -> None:
    write_bench_json(rows, out_path, bench="churn")


def print_rows(rows: list[dict]) -> None:
    print(f"{'cell':<10} {'events':>6} {'peak':>5} {'evict':>5} "
          f"{'expand':>6} {'onl_mb':>8} {'ora_mb':>8} {'ratio':>6} "
          f"{'speedup':>8}")
    for r in rows:
        print(f"{r['cell']:<10} {r['n_events']:>6} {r['peak_active']:>5} "
              f"{r['evictions']:>5} {r['expansions']:>6} "
              f"{r['online_scarce_mb']:>8.2f} {r['oracle_scarce_mb']:>8.2f} "
              f"{r['oracle_to_online']:>6.3f} {r['work_speedup']:>7.1f}x")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--n-jobs", default="40,120")
    ap.add_argument("--pods", type=int, default=8)
    ap.add_argument("--table-pairs", type=int, default=2048)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="the gated >=100-job 8-pod cell + a 4-pod shape "
                         "check (the CI job)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    if args.smoke:
        rows = smoke_rows()
    else:
        rows = sweep(n_jobs=tuple(int(x) for x in args.n_jobs.split(",")),
                     pods=args.pods, table_pairs=args.table_pairs,
                     seed=args.seed)
    print_rows(rows)
    write_out(rows, args.out)


if __name__ == "__main__":
    main()
