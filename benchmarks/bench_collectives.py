"""Collective-schedule byte accounting: flat vs tree vs tree+compress on the
production mesh topology (the TPU-domain version of the paper's traffic cut).

Pure analytic + HLO-free: uses the same TreeTrafficModel the planner uses,
plus a measured small-mesh HLO cross-check when run with fake devices.
"""

from __future__ import annotations

import numpy as np

from repro.core import compressor, reduction_model as rm, tree as tree_lib


def traffic_table(grad_mb: float = 1024.0):
    """Per-exchange bytes on each link level, 512-chip mesh (2,16,16)."""
    g = grad_mb * (1 << 20)
    rows = []
    fanins = (16, 2)  # data=16 (x16 model-sharded already), pod=2
    m = rm.TreeTrafficModel(grad_bytes=int(g), fanins=fanins)
    flat, tree = m.flat_bytes_per_level(), m.tree_bytes_per_level()
    for k_frac in (1.0, 0.05, 0.01):
        kv_bytes = g * k_frac * 2  # key(4B)+value(4B) per retained fp32
        rows.append({
            "exchange": f"tree+compress(k={k_frac:g})" if k_frac < 1 else "dense",
            "ici_data_level_mb": round(tree[0] / 2**20, 1),
            "dcn_pod_level_mb": round(
                (tree[1] if k_frac == 1 else min(tree[1], kv_bytes / 16)) / 2**20, 3),
            "flat_dcn_mb": round(flat[1] / 2**20, 1),
            "dcn_cut_vs_flat": round(
                1 - (tree[1] if k_frac == 1 else min(tree[1], kv_bytes / 16)) / flat[1], 4),
        })
    return rows


def compression_payload_table():
    """KV payload cost of the compressed exchange (paper Table-1 packets)."""
    rows = []
    for shape, k_frac in ((( 4096, 4096), 0.01), ((8192, 8192), 0.01),
                          ((4096, 4096), 0.05)):
        n = int(np.prod(shape))
        k = int(n * k_frac)
        rows.append({
            "param_shape": str(shape), "k": k,
            "payload_ratio": round(compressor.compression_ratio(shape, k), 4),
        })
    return rows
