"""Failure-recovery benchmark: JCT and reduction ratio vs failure count
(DESIGN.md §12).

Each cell runs the Zipf word-count incast under a deterministic failure
schedule (switch crashes / long link-down windows scheduled inside the
tier-0 busy window) through the epoch-restart recovery driver and
records:

  * ``jct_penalty_s`` — total faulted JCT (dead incarnations + restarts
    included) minus the clean run's JCT: the measured price of recovery;
  * ``reduction`` — the reducer-link traffic cut of the *surviving*
    epoch vs the host-only baseline.  Dead switches are bypassed as
    forward-only relays, so the degraded cascade reduces less — but it
    must never do worse than pure forwarding, which is the absolute
    ``reduction_floor`` (0.0) the CI gate enforces;
  * ``exactly_once`` / ``parity`` — the delivered table still equals the
    no-failure run bit for bit, on both engines, with identical JCT and
    epoch count (cross-checked here so a recovery regression fails the
    bench, not just the unit suite).

    PYTHONPATH=src python benchmarks/bench_faults.py
    PYTHONPATH=src python benchmarks/bench_faults.py --smoke \
        --out benchmarks/out/BENCH_faults.json
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # package import (benchmarks.run) or standalone CLI
    from benchmarks._util import write_bench_json
except ImportError:  # `python benchmarks/bench_*.py`: sys.path[0] is here
    from _util import write_bench_json

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "BENCH_faults.json")

#: degraded-mode absolute bar: a cascade with bypassed (forward-only)
#: switches must never move MORE reducer bytes than host-only forwarding
REDUCTION_FLOOR = 0.0


def _schedule(n_failures: int, fanins, t_busy_s: float):
    """The first ``n_failures`` of a fixed fault sequence.  Coordinates
    are leaf->root (level l has ``prod(fanins[l+1:])`` switches); times
    sit at the very start of the job — the clean JCT is reducer-drain
    dominated, so "mid-transfer" for a switch tier means early."""
    from repro.runtime.fault_tolerance import FailureEvent

    n_tier0 = math.prod(fanins[1:])
    menu = (
        dict(kind="switch_crash", level=0, switch=n_tier0 - 1),
        dict(kind="link_down", level=0, switch=0, child=0,
             # outlasts the retry budget AND the first restart, so it is
             # still dark when the next incarnation replays
             duration_s=5e5 * t_busy_s),
        dict(kind="switch_crash", level=len(fanins) - 1, switch=0),
        dict(kind="table_wipe", level=0, switch=0),
    )
    if n_failures > len(menu):
        raise ValueError(f"schedule menu has {len(menu)} entries")
    return tuple(FailureEvent(t_s=t_busy_s * (0.02 + 0.01 * i), **m)
                 for i, m in enumerate(menu[:n_failures]))


def run_config(fanins, n_failures: int, *, variety: int = 256,
               per_mapper: int = 128, capacity: int = 128,
               loss_rate: float = 0.0, records_per_packet: int = 32,
               seed: int = 0) -> dict:
    """One cell: clean + host-only + faulted (both engines) on one net."""
    from repro.core import dataplane
    from repro.core import reduction_model as rm
    from repro.net import sim as netsim
    from repro.runtime.fault_tolerance import FailureInjector

    fanins = tuple(fanins)
    n = math.prod(fanins) * per_mapper
    keys = rm.zipf_keys(n, variety, skew=0.99, seed=seed).astype(np.int32)
    vals = np.ones((n,), np.float32)
    plan = dataplane.CascadePlan(op="sum", levels=tuple(
        dataplane.LevelSpec(capacity=capacity) for _ in fanins))
    cfg = netsim.NetConfig(loss_rate=loss_rate, seed=seed,
                           records_per_packet=records_per_packet)
    kw = dict(fanins=fanins, plan=plan)

    from repro.net import simulate
    clean = simulate(netsim.JobSpec(keys=keys, values=vals, cfg=cfg, **kw))
    host = simulate(netsim.JobSpec(keys=keys, values=vals, cfg=cfg,
                                   aggregate=False, **kw))
    host_red_bytes = host.link_stats["reducer"]["bytes"]
    inj = FailureInjector({}, events=_schedule(n_failures, fanins,
                                               clean.jct_s))
    t0 = time.perf_counter()
    runs = {}
    cell = f"{'x'.join(str(f) for f in fanins)}/f{n_failures}"
    for engine in ("node", "vectorized"):
        runs[engine] = simulate(
            netsim.JobSpec(keys=keys, values=vals, tag=f"faults:{cell}",
                           cfg=dataclasses.replace(cfg, engine=engine),
                           **kw),
            faults=inj)
    wall_us = (time.perf_counter() - t0) * 1e6
    fn, fv = runs["node"], runs["vectorized"]

    exactly_once = (fn.delivered_table() == clean.delivered_table())
    parity = (fn.delivered_table() == fv.delivered_table()
              and fn.jct_s == fv.jct_s and fn.epochs == fv.epochs)
    red_bytes = fn.result.link_stats["reducer"]["bytes"]
    reduction = 1.0 - red_bytes / max(host_red_bytes, 1)
    assert exactly_once, (
        f"recovery broke exactly-once at {n_failures} failure(s)")
    assert parity, f"engines diverged under faults at {n_failures}"
    return {
        "cell": cell,
        "fanins": list(fanins),
        "n_failures": n_failures,
        "n_verdicts": len(fn.verdicts),
        "epochs": fn.epochs,
        "n_bypassed": len(fn.bypass),
        "jct_clean_s": clean.jct_s,
        "jct_faulted_s": fn.jct_s,
        "jct_penalty_s": fn.jct_s - clean.jct_s,
        "jct_host_only_s": host.jct_s,
        "reduction": round(reduction, 4),
        "reduction_floor": REDUCTION_FLOOR,
        "exactly_once": 1.0,
        "parity": 1.0,
        "wall_us": round(wall_us, 1),
    }


def sweep(*, fanins=(4, 2), failure_counts=(0, 1, 2, 3), **kw) -> list[dict]:
    return [run_config(fanins, nf, **kw) for nf in failure_counts]


def smoke_rows() -> list[dict]:
    """Three small cells (0, 1, 2 injected failures) + the recovery
    cross-checks (the CI job)."""
    return sweep(fanins=(4, 2), failure_counts=(0, 1, 2),
                 per_mapper=64, variety=128, capacity=64)


def write_out(rows: list[dict], out_path: str) -> None:
    write_bench_json(rows, out_path, bench="faults")


def print_rows(rows: list[dict]) -> None:
    print(f"{'cell':<10} {'fail':>4} {'epochs':>6} {'jct_us':>9} "
          f"{'penalty_us':>10} {'reduction':>9} {'bypass':>6}")
    for r in rows:
        print(f"{r['cell']:<10} {r['n_failures']:>4} {r['epochs']:>6} "
              f"{r['jct_faulted_s']*1e6:>9.1f} "
              f"{r['jct_penalty_s']*1e6:>10.1f} "
              f"{r['reduction']:>9.1%} {r['n_bypassed']:>6}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fanins", default="4x2")
    ap.add_argument("--failure-counts", default="0,1,2,3")
    ap.add_argument("--per-mapper", type=int, default=128)
    ap.add_argument("--variety", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="three small cells + recovery cross-checks "
                         "(the CI job)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    if args.smoke:
        rows = smoke_rows()
    else:
        rows = sweep(
            fanins=tuple(int(x) for x in args.fanins.split("x")),
            failure_counts=[int(x) for x in args.failure_counts.split(",")],
            per_mapper=args.per_mapper, variety=args.variety)
    print_rows(rows)
    write_out(rows, args.out)


if __name__ == "__main__":
    main()
