"""Benchmark harness orchestrator.

Runs every paper-table/figure reproduction plus the TPU-domain collective
accounting, prints a ``name,us_per_call,derived`` CSV, and writes the full
JSON to benchmarks/out/results.json (EXPERIMENTS.md §Paper-validation reads
from it).

    PYTHONPATH=src python -m benchmarks.run

``--smoke`` is THE consolidated CI entry: every bench suite's smoke
configuration (multijob, dataplane, FPE, JCT, placement) runs in one
process and every ``BENCH_*.json`` lands in one output directory for a
single artifact upload — replacing the per-bench copy-pasted CI steps.
Each suite keeps its own cross-checks (conservation, exactly-once,
placement acceptance), so a semantics regression still fails the step.
``--ci`` additionally keeps stdout terse (one line per suite).

    PYTHONPATH=src python benchmarks/run.py --smoke --ci
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

# runnable both as `python -m benchmarks.run` and `python benchmarks/run.py`
_REPO = os.path.join(os.path.dirname(__file__), "..")
for _p in (_REPO, os.path.join(_REPO, "src")):
    if os.path.abspath(_p) not in (os.path.abspath(q) for q in sys.path):
        sys.path.insert(0, _p)

#: every smoke suite the consolidated CI step runs: (name, module, out file)
SMOKE_SUITES = ("multijob", "dataplane", "fpe", "jct", "placement", "sim",
                "faults", "churn")


def run_smoke(out_dir: str, *, ci: bool = False) -> dict:
    """Run every bench suite's smoke config; write all BENCH_*.json plus
    the observability artifacts (trace.json / metrics.json / dashboard,
    DESIGN.md §11) for the CI artifact upload."""
    import importlib

    from repro.obs import report as obs_report
    from repro.obs import trace as obs_trace

    os.makedirs(out_dir, exist_ok=True)
    obs_trace.enable()
    tracer = obs_trace.get_tracer()
    results = {}
    for name in SMOKE_SUITES:
        mod = importlib.import_module(f"benchmarks.bench_{name}")
        t0 = time.perf_counter()
        with tracer.span(f"smoke:{name}", cat="bench"):
            rows = mod.smoke_rows()
        dt = time.perf_counter() - t0
        if not ci:
            mod.print_rows(rows)
        mod.write_out(rows, os.path.join(out_dir, f"BENCH_{name}.json"))
        print(f"smoke_{name},{dt*1e6:.0f},{len(rows)}rows")
        results[name] = rows
    paths = obs_report.write_obs_artifacts(
        out_dir, title="bench smoke observability")
    print("smoke_obs_artifacts,0," + ";".join(
        os.path.basename(p) for p in sorted(paths.values())))
    return results


def _timeit(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="consolidated CI smoke: every bench suite's smoke "
                         "config, all BENCH_*.json into --out-dir")
    ap.add_argument("--ci", action="store_true",
                    help="terse per-suite output (implies --smoke)")
    ap.add_argument("--out-dir",
                    default=os.path.join(os.path.dirname(__file__), "out"))
    args = ap.parse_args()
    if args.smoke or args.ci:
        run_smoke(args.out_dir, ci=args.ci)
        return

    from benchmarks import bench_collectives, paper_figs

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    results: dict = {}
    print("name,us_per_call,derived")

    # --- paper figures/tables ---------------------------------------------
    fig2a, us = _timeit(paper_figs.fig2a, reps=1)
    results["fig2a"] = fig2a
    cliff = next(r for r in fig2a if r["key_variety"] > r["capacity"] * 10)
    print(f"fig2a_reduction_cliff,{us:.0f},N=10C->R={cliff['simulated']}")

    fig2b, us = _timeit(paper_figs.fig2b, reps=1)
    results["fig2b"] = fig2b
    gain = fig2b[-1]["end_to_end_reduction"] - fig2b[0]["end_to_end_reduction"]
    print(f"fig2b_multihop_gain,{us:.0f},4hops-1hop={gain:.4f}")

    eq, us = _timeit(paper_figs.eq1_eq2, reps=1)
    results["eq1_eq2"] = eq
    print(f"eq1_fixed_format_waste,{us:.0f},{eq['eq1_fixed20_random_pairs']}x_vs_"
          f"{eq['switchagg_encoding_random_pairs']}x")
    print(f"eq2_header_overhead,{us:.0f},rmt={eq['eq2_rmt200B_overhead']}")

    fig9, us = _timeit(paper_figs.fig9, reps=1)
    results["fig9"] = fig9
    m_best = max(r["reduction"] for r in fig9 if r["mode"] == "M-multilevel"
                 and r["dist"] == "zipf")
    s_best = max(r["reduction"] for r in fig9 if r["mode"].startswith("S")
                 and r["dist"] == "uniform")
    print(f"fig9_multilevel_zipf_best,{us:.0f},R={m_best}")
    print(f"fig9_sram_uniform_best,{us:.0f},R={s_best}")

    t2, us = _timeit(paper_figs.table2, reps=1)
    results["table2"] = t2
    print(f"table2_evict_rate,{us:.0f},max={max(r['evict_rate'] for r in t2)}")

    results["table3"] = paper_figs.table3()
    print("table3_stage_delays,0,analytic")

    f10, us = _timeit(paper_figs.fig10_11, reps=1)
    results["fig10_11"] = f10
    print(f"fig10_jct_saved,{us:.0f},{f10[-1]['jct_saved']:.0%}@16GB")

    # --- TPU-domain collective accounting ---------------------------------
    tt, us = _timeit(bench_collectives.traffic_table, reps=1)
    results["collective_traffic"] = tt
    print(f"collective_dcn_cut,{us:.0f},dense_tree={tt[0]['dcn_cut_vs_flat']:.4f}")
    results["compression_payload"] = bench_collectives.compression_payload_table()

    # --- kernel micro-benchmarks (CPU walltime; TPU perf is §Roofline) ----
    import jax.numpy as jnp

    from repro.core import kvagg

    keys = jnp.asarray(np.random.default_rng(0).integers(0, 512, 4096),
                       jnp.int32)
    vals = jnp.ones((4096,), jnp.float32)

    def node():
        return kvagg.two_level_aggregate(keys, vals, capacity=128, ways=4
                                         ).n_out.block_until_ready()

    _, us = _timeit(node, reps=3)
    print(f"kvagg_node_4096pairs,{us:.0f},{4096 / us:.2f}pairs_per_us")

    from repro.kernels import ops

    def pallas_node():
        return ops.two_level_aggregate(keys, vals, capacity=128, ways=4,
                                       block_n=512, interpret=True
                                       ).n_out.block_until_ready()

    _, us = _timeit(pallas_node, reps=1)
    print(f"kvagg_pallas_interpret,{us:.0f},correctness_mode")

    # --- cascade dataplane: capacity x levels x op (DESIGN.md §6) ---------
    from benchmarks import bench_dataplane

    dp_rows = bench_dataplane.sweep(
        ops=("sum", "max", "count", "mean", "logsumexp"),
        capacities=(32, 128), levels=(1, 2), n=2048, variety=512,
        dist="zipf", backend="jnp", reps=1)
    results["dataplane"] = dp_rows
    bench_dataplane.write_out(
        dp_rows, os.path.join(out_dir, "BENCH_dataplane.json"))
    best = max(dp_rows, key=lambda r: r["end_to_end_reduction"])
    print(f"dataplane_best_reduction,{best['wall_us']:.0f},"
          f"{best['op']}xL{best['levels']}xC{best['capacity_per_node']}"
          f"=R{best['end_to_end_reduction']:.3f}")

    # --- FPE throughput: scan oracle vs batched fast path (DESIGN.md §8) --
    from benchmarks import bench_fpe

    fpe_rows = bench_fpe.sweep(
        ops=("sum", "mean"), lengths=(8192,), ways_list=(4,),
        backends=("jnp",), variety=1024, capacity=256, dist="zipf", reps=2)
    fpe_rows.append(bench_fpe.headline_row(reps=2, check=False))
    results["fpe"] = fpe_rows
    bench_fpe.write_out(fpe_rows, os.path.join(out_dir, "BENCH_fpe.json"))
    hl = fpe_rows[-1]
    print(f"fpe_fast_path,{hl['fast_us']:.0f},"
          f"{hl['speedup']}x_vs_scan@100k_zipf")

    # --- packet-level JCT: switchagg vs host-only (DESIGN.md §7) ----------
    from benchmarks import bench_jct

    jct_rows = bench_jct.sweep(
        fanouts=[(4, 2)], loss_rates=(0.0, 0.01), varieties=(512,),
        per_mapper=128, capacity=128, records_per_packet=32)
    results["jct"] = jct_rows
    bench_jct.write_out(jct_rows, os.path.join(out_dir, "BENCH_jct.json"))
    best_jct = max(jct_rows, key=lambda r: r["jct_saved"])
    print(f"jct_saved,{best_jct['wall_us']:.0f},"
          f"{best_jct['jct_saved']:.1%}@loss{best_jct['loss_rate']}")

    # --- multi-job congestion-aware controller (DESIGN.md §3) -------------
    from benchmarks import bench_multijob

    mj, us = _timeit(lambda: bench_multijob.run_once(
        4, budget_mb=128.0, partition="weighted", base_mb=256.0), reps=1)
    results["multijob_4"] = mj
    print(f"multijob_scarce_cut,{us:.0f},{mj['total_scarce_mb']:.1f}MiB_vs_"
          f"flat_{mj['flat_total_scarce_mb']:.1f}MiB")

    # --- roofline summary (from dry-run artifacts, if present) ------------
    try:
        from benchmarks import roofline

        rows = roofline.load(pod="1", mode="tree")
        if rows:
            worst = min(rows, key=lambda r: r["fraction"])
            print(f"roofline_cells_pod1,{0},{len(rows)}")
            print(f"roofline_worst_fraction,0,{worst['arch']}x{worst['shape']}"
                  f"={worst['fraction']:.4f}")
            results["roofline_pod1"] = rows
    except Exception as e:  # artifacts absent on a fresh checkout
        print(f"roofline_summary,0,skipped({e})")

    with open(os.path.join(out_dir, "results.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"# full results -> {os.path.join(out_dir, 'results.json')}")


if __name__ == "__main__":
    main()
