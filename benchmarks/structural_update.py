"""Recompute the structural roofline block for existing dry-run artifacts
(no recompilation: structural costs need only cfg x shape x mesh x profile;
collective bytes are kept from the artifact's HLO walk).

    PYTHONPATH=src python -m benchmarks.structural_update
"""

from __future__ import annotations

import glob
import json
import os

import repro.configs as configs
from repro.configs.base import shape_by_name
from repro.core.collectives import GradAggMode
from repro.launch import hlo_analysis as ha
from repro.launch import profiles
from repro.launch.structural import structural_cost

ART = os.path.join(os.path.dirname(__file__), "artifacts")


class _MeshLike:
    """Axis metadata stand-in (no jax device allocation needed)."""

    def __init__(self, multi_pod: bool):
        self.axis_names = (("pod", "data", "model") if multi_pod
                           else ("data", "model"))
        shape = (2, 16, 16) if multi_pod else (16, 16)
        import numpy as np

        self.devices = np.zeros(shape)


def main():
    n = 0
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if not d.get("ok"):
            continue
        arch, shape_name = d["arch"], d["shape"]
        mesh = _MeshLike(d["multi_pod"])
        shape = shape_by_name(shape_name)
        cfg = configs.get_config(arch)
        prof = profiles.make_profile(arch, shape, mesh,
                                     mode=GradAggMode(d.get("mode", "tree")))
        if d.get("accum"):
            import dataclasses

            prof = dataclasses.replace(prof, accum_steps=d["accum"])
        sc = structural_cost(cfg, shape, mesh, prof)
        coll = ha.CollectiveStats(
            ici_bytes=d["collectives"]["ici_bytes"],
            dcn_bytes=d["collectives"]["dcn_bytes"])
        n_chips = d["n_chips"]
        roof = ha.roofline_terms(
            hlo_flops=sc.flops, hlo_bytes=sc.bytes, coll=coll,
            n_chips=n_chips, model_flops=d["model_flops_global"] / n_chips)
        d["roofline_structural"] = roof.to_dict()
        d["structural_detail"] = {k: [float(f), float(b)]
                                  for k, (f, b) in sc.detail.items()}
        with open(path, "w") as f:
            json.dump(d, f, indent=1)
        n += 1
    print(f"updated {n} artifacts")


if __name__ == "__main__":
    main()
