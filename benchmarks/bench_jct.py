"""JCT network-sim benchmark: fanout × loss-rate × key-variety sweep
(DESIGN.md §7).

For each configuration the packet-level simulator (``repro.net.sim``) runs
the Zipf word-count job twice on the same emulated 10 GbE network — with
the in-network cascade and as the host-only baseline — and records the
paper's Fig. 10 metric (JCT with vs without aggregation) plus transport
telemetry (retransmissions, per-level wire bytes) into a stable JSON
(``BENCH_jct.json``) CI regenerates every run.

    PYTHONPATH=src python benchmarks/bench_jct.py
    PYTHONPATH=src python benchmarks/bench_jct.py --smoke \
        --out benchmarks/out/BENCH_jct.json

``--smoke`` runs one tiny lossy config — the CI job — and cross-checks the
delivered table against the lossless run so an exactly-once regression
fails the bench, not just the unit suite.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # package import (benchmarks.run) or standalone CLI
    from benchmarks._util import write_bench_json
except ImportError:  # `python benchmarks/bench_*.py`: sys.path[0] is here
    from _util import write_bench_json

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "BENCH_jct.json")


def run_config(fanins, loss_rate: float, variety: int, *,
               per_mapper: int = 256, capacity: int = 128, op: str = "sum",
               records_per_packet: int | None = None, seed: int = 0,
               check: bool = False) -> dict:
    """One cell: both JCT runs (switchagg + host-only) on one network."""
    import math

    from repro.core import dataplane
    from repro.core import reduction_model as rm
    from repro.net import sim as netsim, wire

    fanins = tuple(fanins)
    n = math.prod(fanins) * per_mapper
    keys = rm.zipf_keys(n, variety, skew=0.99, seed=seed).astype(np.int32)
    vals = np.ones((n,), np.float32)
    plan = dataplane.CascadePlan(op=op, levels=tuple(
        dataplane.LevelSpec(capacity=capacity) for _ in fanins))
    cfg = netsim.NetConfig(
        link_gbps=(netsim.TEN_GBE,) * len(fanins),
        reducer_gbps=netsim.TEN_GBE, loss_rate=loss_rate, seed=seed,
        records_per_packet=records_per_packet or wire.RECORDS_PER_PACKET)
    t0 = time.perf_counter()
    jct = netsim.jct_comparison(keys, vals, fanins=fanins, plan=plan, cfg=cfg)
    wall_us = (time.perf_counter() - t0) * 1e6
    sw, _ = jct["_results"]
    if check:  # exactly-once cross-check vs the lossless network
        from repro.net import simulate
        lossless = sw if loss_rate == 0.0 else simulate(netsim.JobSpec(
            keys=keys, values=vals, fanins=fanins, plan=plan,
            cfg=dataclasses.replace(cfg, loss_rate=0.0)))
        got = sw.delivered_table()
        want = lossless.delivered_table()
        assert got.keys() == want.keys(), "loss changed the delivered key set"
        for k in want:
            np.testing.assert_allclose(got[k], want[k], rtol=1e-4,
                                       err_msg=f"key {k}")
    return {
        "fanins": list(fanins),
        "loss_rate": loss_rate,
        "key_variety": variety,
        "per_mapper": per_mapper,
        "capacity_per_node": capacity,
        "op": op,
        "jct_switchagg_s": jct["jct_switchagg_s"],
        "jct_host_only_s": jct["jct_host_only_s"],
        "jct_saved": round(jct["jct_saved"], 4),
        "reducer_traffic_cut": round(jct["reduction"], 4),
        "retransmissions": sw.retransmissions,
        "packets_dropped": sw.packets_dropped,
        "scarce_wire_bytes": sw.link_stats.get(
            "reducer", {}).get("bytes", 0),
        "wall_us": round(wall_us, 1),
    }


def sweep(*, fanouts, loss_rates, varieties, per_mapper: int = 256,
          capacity: int = 128, records_per_packet: int | None = None,
          check: bool = False) -> list[dict]:
    rows = []
    for fanins in fanouts:
        for loss in loss_rates:
            for variety in varieties:
                rows.append(run_config(
                    fanins, loss, variety, per_mapper=per_mapper,
                    capacity=capacity,
                    records_per_packet=records_per_packet, check=check))
    rows.sort(key=lambda r: (r["fanins"], r["loss_rate"], r["key_variety"]))
    return rows


def smoke_rows() -> list[dict]:
    """One tiny lossy config + exactly-once cross-check (the CI job)."""
    return sweep(fanouts=[(2, 2)], loss_rates=[0.0, 0.1], varieties=[64],
                 per_mapper=64, capacity=32, records_per_packet=16,
                 check=True)


def write_out(rows: list[dict], out_path: str) -> None:
    write_bench_json(rows, out_path, bench="jct")


def print_rows(rows: list[dict]) -> None:
    hdr = (f"{'fanins':<8} {'loss':>5} {'N':>6} {'jct_sw_us':>10} "
           f"{'jct_host_us':>11} {'saved':>6} {'retx':>5} {'us':>9}")
    print(hdr)
    for r in rows:
        fan = "x".join(str(f) for f in r["fanins"])
        print(f"{fan:<8} {r['loss_rate']:>5.2f} {r['key_variety']:>6} "
              f"{r['jct_switchagg_s']*1e6:>10.1f} "
              f"{r['jct_host_only_s']*1e6:>11.1f} "
              f"{r['jct_saved']:>6.1%} {r['retransmissions']:>5} "
              f"{r['wall_us']:>9.0f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fanouts", default="4x2,8,4x2x2")
    ap.add_argument("--loss-rates", default="0,0.001,0.01")
    ap.add_argument("--varieties", default="256,2048")
    ap.add_argument("--per-mapper", type=int, default=256)
    ap.add_argument("--capacity", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="one tiny lossy config + exactly-once cross-check "
                         "(the CI job)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    if args.smoke:
        rows = smoke_rows()
    else:
        fanouts = [tuple(int(x) for x in f.split("x"))
                   for f in args.fanouts.split(",")]
        rows = sweep(fanouts=fanouts,
                     loss_rates=[float(x) for x in args.loss_rates.split(",")],
                     varieties=[int(x) for x in args.varieties.split(",")],
                     per_mapper=args.per_mapper, capacity=args.capacity)
    print_rows(rows)
    write_out(rows, args.out)


if __name__ == "__main__":
    main()
