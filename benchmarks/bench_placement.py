"""Rack-scale placement benchmark: pods x oversubscription x policy
(DESIGN.md §9).

For each fat-tree configuration the aggregation-tree placement search
(``core.planner.place_aggregation_tree``) is run under every policy and we
record the modeled scarce-uplink bytes, total network bytes, reducer-link
bytes, and switch count — the SOAR-style question of *where* bounded
aggregation capability buys the most on an oversubscribed fabric.  One
configuration (the 4-pod, 128-mapper Zipf job) also runs end to end
through the packet-level simulator so the JCT story is measured, not
modeled.

    PYTHONPATH=src python benchmarks/bench_placement.py
    PYTHONPATH=src python benchmarks/bench_placement.py --smoke \
        --out benchmarks/out/BENCH_placement.json

``--smoke`` is the CI job: a reduced sweep plus the acceptance
assertions — full-tree placement must cut measured scarce-uplink bytes by
>= 30% vs ToR-only on the 4-pod 128-mapper Zipf job, and simulated JCT
must order full-tree <= ToR-only <= host-only.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # package import (benchmarks.run) or standalone CLI
    from benchmarks._util import write_bench_json
except ImportError:  # `python benchmarks/bench_*.py`: sys.path[0] is here
    from _util import write_bench_json

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "BENCH_placement.json")

MiB = float(1 << 20)

#: the acceptance fabric: 4 pods x 4 ToRs x 8 hosts = 128 mappers, 4:1
ACCEPTANCE = dict(pods=4, tors_per_pod=4, hosts_per_tor=8,
                  oversubscription=4.0, table_pairs=2048)

POLICIES = ("host_only", "tor_only", "full", "greedy", "exhaustive")


def placement_row(*, pods: int, oversub: float, policy: str,
                  tors_per_pod: int = 4, hosts_per_tor: int = 8,
                  per_host_pairs: int = 512, key_variety: int = 2048,
                  table_pairs: int = 2048) -> dict:
    """One analytic cell: run the placement search, record the byte model."""
    from repro.core import planner as pl

    ft = pl.FatTreeTopology(pods=pods, tors_per_pod=tors_per_pod,
                            hosts_per_tor=hosts_per_tor,
                            oversubscription=oversub,
                            table_pairs=table_pairs)
    t0 = time.perf_counter()
    p = pl.place_aggregation_tree(ft, per_host_pairs=per_host_pairs,
                                  key_variety=key_variety, policy=policy)
    wall_us = (time.perf_counter() - t0) * 1e6
    return {
        "pods": pods,
        "tors_per_pod": tors_per_pod,
        "hosts_per_tor": hosts_per_tor,
        "n_mappers": ft.n_hosts,
        "oversubscription": oversub,
        "policy": policy,
        "placed_tiers": list(p.tiers),
        "n_agg_switches": p.n_agg_switches,
        "scarce_axis": p.scarce_axis,
        "scarce_uplink_mb": p.scarce_uplink_bytes / MiB,
        "total_mb": p.total_bytes / MiB,
        "reducer_mb": p.reducer_bytes / MiB,
        "max_drain_ms": p.max_drain_s * 1e3,
        "wall_us": round(wall_us, 1),
    }


def sweep(*, pods_list, oversubs, policies=POLICIES, **kw) -> list[dict]:
    rows = []
    for pods in pods_list:
        for o in oversubs:
            for pol in policies:
                rows.append(placement_row(pods=pods, oversub=o, policy=pol,
                                          **kw))
    rows.sort(key=lambda r: (r["pods"], r["oversubscription"], r["policy"]))
    return rows


def jct_rows(*, per_host_pairs: int = 256, key_variety: int = 2048,
             seed: int = 0, exact_stream: bool = False,
             check: bool = False) -> list[dict]:
    """The measured leg: the acceptance fabric end to end through the
    packet simulator, one row per placement policy.  ``exact_stream=False``
    runs switch FPEs on the batched fast path (identical delivered totals,
    DESIGN.md §8) so the 128-mapper sim stays CI-sized."""
    from repro.core import planner as pl
    from repro.core import reduction_model as rm
    from repro.net import sim as netsim

    ft = pl.FatTreeTopology(**ACCEPTANCE)
    n = ft.n_hosts * per_host_pairs
    keys = rm.zipf_keys(n, key_variety, skew=0.99, seed=seed).astype(np.int32)
    vals = np.ones((n,), np.float32)
    t0 = time.perf_counter()
    cmp = netsim.fat_tree_jct_comparison(
        ft, keys, vals, per_host_pairs=per_host_pairs,
        key_variety=key_variety,
        cfg=netsim.NetConfig(exact_stream=exact_stream))
    wall_us = (time.perf_counter() - t0) * 1e6
    if check:  # every placement must deliver the exact grouped counts
        want = np.bincount(keys, minlength=key_variety)
        for pol, res in cmp["_results"].items():
            got = res.delivered_table()
            assert all(abs(got.get(k, 0.0) - c) < 1e-3
                       for k, c in enumerate(want) if c), \
                f"{pol}: delivered table is not exact"
    scarce = cmp["scarce_axis"]
    rows = []
    for pol in cmp["policies"]:
        r = cmp[pol]
        rows.append({
            "pods": ft.pods,
            "n_mappers": ft.n_hosts,
            "oversubscription": ft.oversubscription,
            "policy": pol,
            "placed_tiers": r["placement"]["tiers"],
            "n_agg_switches": r["placement"]["n_agg_switches"],
            "scarce_axis": scarce,
            "jct_s": cmp["jct_s"][pol],
            "arrived_records": r["arrived_records"],
            "scarce_wire_bytes": r["link_bytes"][scarce],
            "reducer_wire_bytes": r["link_bytes"]["reducer"],
            "wall_us": round(wall_us / len(cmp["policies"]), 1),
        })
    return rows


def assert_acceptance(sim_rows: list[dict]) -> None:
    """The §9 acceptance bar, on MEASURED wire bytes and JCT."""
    by = {r["policy"]: r for r in sim_rows}
    full, tor, host = by["full"], by["tor_only"], by["host_only"]
    cut = 1.0 - full["scarce_wire_bytes"] / tor["scarce_wire_bytes"]
    assert cut >= 0.30, (
        f"full-tree placement must cut scarce-uplink bytes >= 30% vs "
        f"ToR-only (got {cut:.1%})")
    assert full["jct_s"] <= tor["jct_s"] <= host["jct_s"], (
        f"JCT must order full-tree <= ToR-only <= host-only, got "
        f"{full['jct_s']:.6f} / {tor['jct_s']:.6f} / {host['jct_s']:.6f}")
    print(f"acceptance ok: scarce-uplink cut {cut:.1%} (>= 30%), "
          f"JCT {full['jct_s']*1e3:.3f} <= {tor['jct_s']*1e3:.3f} <= "
          f"{host['jct_s']*1e3:.3f} ms")


def smoke_rows() -> list[dict]:
    """The CI job: reduced analytic sweep + the measured acceptance leg."""
    rows = sweep(pods_list=[1, 4], oversubs=[1.0, 4.0],
                 policies=("host_only", "tor_only", "full", "greedy"))
    sim = jct_rows(check=True)
    assert_acceptance(sim)
    for r in sim:
        r["measured"] = True
    return rows + sim


def write_out(rows: list[dict], out_path: str) -> None:
    write_bench_json(rows, out_path, bench="placement")


def print_rows(rows: list[dict]) -> None:
    hdr = (f"{'pods':>4} {'ovsb':>5} {'policy':<10} {'tiers':<14} "
           f"{'n_sw':>4} {'scarce MiB':>10} {'total MiB':>9} "
           f"{'reducer MiB':>11} {'jct_ms':>8}")
    print(hdr)
    for r in rows:
        tiers = "+".join(r["placed_tiers"]) or "-"
        jct = f"{r['jct_s']*1e3:8.3f}" if "jct_s" in r else f"{'-':>8}"
        scarce = r.get("scarce_uplink_mb",
                       r.get("scarce_wire_bytes", 0) / MiB)
        red = r.get("reducer_mb", r.get("reducer_wire_bytes", 0) / MiB)
        print(f"{r['pods']:>4} {r['oversubscription']:>5.1f} "
              f"{r['policy']:<10} {tiers:<14} {r['n_agg_switches']:>4} "
              f"{scarce:>10.3f} {r.get('total_mb', 0):>9.2f} "
              f"{red:>11.3f} {jct}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--pods", default="1,2,4,8")
    ap.add_argument("--oversubs", default="1,2,4,8")
    ap.add_argument("--policies", default=",".join(POLICIES))
    ap.add_argument("--per-host-pairs", type=int, default=256)
    ap.add_argument("--variety", type=int, default=2048)
    ap.add_argument("--table-pairs", type=int, default=2048)
    ap.add_argument("--jct", action="store_true",
                    help="also run the measured JCT leg (packet simulator)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep + measured acceptance leg (CI job)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    if args.smoke:
        rows = smoke_rows()
    else:
        rows = sweep(
            pods_list=[int(p) for p in args.pods.split(",")],
            oversubs=[float(o) for o in args.oversubs.split(",")],
            policies=tuple(args.policies.split(",")),
            per_host_pairs=args.per_host_pairs, key_variety=args.variety,
            table_pairs=args.table_pairs)
        if args.jct:
            sim = jct_rows(per_host_pairs=args.per_host_pairs,
                           key_variety=args.variety, check=True)
            assert_acceptance(sim)
            for r in sim:
                r["measured"] = True
            rows += sim
    print_rows(rows)
    write_out(rows, args.out)


if __name__ == "__main__":
    main()
