"""FPE throughput benchmark: pairs/sec, scan oracle vs batched-block fast
path (DESIGN.md §8).

The FPE is the hot loop under every cascade, the packet simulator, and
``train/compressed`` — this bench is its first throughput trajectory.
Each cell runs one FPE call over a synthetic stream twice: the
paper-faithful sequential scan (``exact_stream=True``) and the batched
fast path (``exact_stream=False``; pre-combine + closed-form vectorized
bucket update), and records pairs/sec for both plus the speedup, into a
stable JSON (``BENCH_fpe.json``) CI regenerates every run.

    PYTHONPATH=src python benchmarks/bench_fpe.py
    PYTHONPATH=src python benchmarks/bench_fpe.py --smoke \
        --out benchmarks/out/BENCH_fpe.json

``--smoke`` runs one small config per registered op on both backends
(Pallas in interpret mode — the CI job) and cross-checks that both modes'
(flush + evictions) grouped by key equal the exact input combine, so a
fast-path semantics regression fails the bench, not just the unit suite.

The headline acceptance cell (run by default, asserted with ``--check``):
a 100k-pair Zipf stream on the jnp backend must clear >= 5x pairs/sec for
the fast path over the scan oracle.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # package import (benchmarks.run) or standalone CLI
    from benchmarks._util import write_bench_json
except ImportError:  # `python benchmarks/bench_*.py`: sys.path[0] is here
    from _util import write_bench_json

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "BENCH_fpe.json")
EMPTY = -1


def _stream(n: int, variety: int, dist: str, seed: int = 0):
    import jax.numpy as jnp

    from repro.core import reduction_model as rm

    gen = rm.uniform_keys if dist == "uniform" else rm.zipf_keys
    keys = jnp.asarray(gen(n, variety, seed=seed).astype(np.int32))
    vals = jnp.asarray(np.random.default_rng(seed)
                       .standard_normal(n).astype(np.float32))
    return keys, vals


def _fpe_call(backend: str, interpret: bool | None):
    """One (keys, values, op, geometry, exact_stream) -> FPE 4-tuple."""
    if backend == "pallas":
        from repro.kernels.kv_aggregate import fpe_aggregate_pallas

        def call(keys, vals, *, op, capacity, ways, exact_stream):
            return fpe_aggregate_pallas(
                keys, vals, op=op, capacity=capacity, ways=ways,
                exact_stream=exact_stream, interpret=interpret)
    elif backend == "jnp":
        from repro.core import kvagg

        def call(keys, vals, *, op, capacity, ways, exact_stream):
            return tuple(kvagg.fpe_aggregate(
                keys, vals, op=op, capacity=capacity, ways=ways,
                exact_stream=exact_stream))
    else:
        raise ValueError(f"unknown backend: {backend!r}")
    return call


def _time_mode(call, keys, vals, *, op, capacity, ways, exact_stream,
               reps: int) -> float:
    """Best-of-reps wall time — min is the standard noise-robust estimator
    for a deterministic computation on a shared machine."""
    import jax

    def once():
        return jax.block_until_ready(call(
            keys, vals, op=op, capacity=capacity, ways=ways,
            exact_stream=exact_stream))

    once()  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        once()
        best = min(best, time.perf_counter() - t0)
    return best


def _conservation_check(call, keys, vals, *, op, capacity, ways,
                        exact_stream) -> None:
    """(flush + evictions) grouped by key must equal the exact combine."""
    import jax.numpy as jnp

    from repro.core import aggops, kvagg

    aggop = aggops.get(op)
    carried = aggop.prepare_values(vals)
    tk, tv, ek, ev = call(keys, carried, op=op, capacity=capacity,
                          ways=ways, exact_stream=exact_stream)
    got = kvagg.sorted_combine(jnp.concatenate([tk, ek]),
                               jnp.concatenate([tv, ev]), op=op)
    want = kvagg.sorted_combine(keys, carried, op=op)
    nu = int(want.n_unique)
    mode = "fast" if not exact_stream else "scan"
    assert int(got.n_unique) == nu, \
        f"{op}/{mode}: {int(got.n_unique)} unique keys, expected {nu}"
    np.testing.assert_array_equal(
        np.asarray(got.unique_keys)[:nu], np.asarray(want.unique_keys)[:nu])
    np.testing.assert_allclose(
        np.asarray(got.combined_values)[:nu],
        np.asarray(want.combined_values)[:nu], rtol=1e-4, atol=1e-5,
        err_msg=f"op={op} mode={mode} conservation broken")


def run_config(op: str, *, n: int = 100_000, variety: int = 4096,
               capacity: int = 1024, ways: int = 4, dist: str = "zipf",
               backend: str = "jnp", interpret: bool | None = None,
               reps: int = 3, scan_reps: int = 1,
               check: bool = False) -> dict:
    """One cell: time the scan oracle and the fast path on one stream."""
    from repro.core import aggops

    keys, vals = _stream(n, variety, dist)
    carried = aggops.get(op).prepare_values(vals)
    call = _fpe_call(backend, interpret)

    fast_s = _time_mode(call, keys, carried, op=op, capacity=capacity,
                        ways=ways, exact_stream=False, reps=reps)
    scan_s = _time_mode(call, keys, carried, op=op, capacity=capacity,
                        ways=ways, exact_stream=True, reps=scan_reps)
    if check:
        for exact in (True, False):
            _conservation_check(call, keys, vals, op=op, capacity=capacity,
                                ways=ways, exact_stream=exact)
    return {
        "op": op,
        "n": n,
        "key_variety": variety,
        "capacity": capacity,
        "ways": ways,
        "dist": dist,
        "backend": backend,
        "scan_us": round(scan_s * 1e6, 1),
        "fast_us": round(fast_s * 1e6, 1),
        "scan_pairs_per_s": round(n / scan_s, 1),
        "fast_pairs_per_s": round(n / fast_s, 1),
        "speedup": round(scan_s / fast_s, 2),
    }


def sweep(*, ops, lengths, ways_list, backends, variety: int,
          capacity: int, dist: str, reps: int, interpret: bool | None = None,
          check: bool = False) -> list[dict]:
    rows = []
    for backend in backends:
        for op in ops:
            for n in lengths:
                for ways in ways_list:
                    rows.append(run_config(
                        op, n=n, variety=variety, capacity=capacity,
                        ways=ways, dist=dist, backend=backend,
                        interpret=interpret, reps=reps, check=check))
    rows.sort(key=lambda r: (r["backend"], r["op"], r["n"], r["ways"]))
    return rows


def smoke_rows() -> list[dict]:
    """One small config per registered op, both backends; Pallas runs in
    interpret mode; every cell cross-checks conservation in both modes."""
    from repro.core import aggops

    rows = sweep(ops=aggops.names(), lengths=[2048], ways_list=[4],
                 backends=["jnp"], variety=256, capacity=128, dist="zipf",
                 reps=1, check=True)
    rows += sweep(ops=aggops.names(), lengths=[512], ways_list=[4],
                  backends=["pallas"], variety=64, capacity=32, dist="zipf",
                  reps=1, interpret=True, check=True)
    return rows


def headline_row(*, reps: int = 3, check: bool = True) -> dict:
    """THE acceptance cell: 100k-pair Zipf, jnp backend, sum."""
    return run_config("sum", n=100_000, variety=4096, capacity=1024,
                      ways=4, dist="zipf", backend="jnp", reps=reps,
                      scan_reps=2, check=check)


def write_out(rows: list[dict], out_path: str) -> None:
    write_bench_json(rows, out_path, bench="fpe")


def print_rows(rows: list[dict]) -> None:
    hdr = (f"{'op':<10} {'backend':<7} {'n':>7} {'ways':>4} "
           f"{'scan pairs/s':>13} {'fast pairs/s':>13} {'speedup':>8}")
    print(hdr)
    for r in rows:
        print(f"{r['op']:<10} {r['backend']:<7} {r['n']:>7} {r['ways']:>4} "
              f"{r['scan_pairs_per_s']:>13.0f} {r['fast_pairs_per_s']:>13.0f} "
              f"{r['speedup']:>7.1f}x")


def main() -> None:
    from repro.core import aggops

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ops", default=",".join(aggops.names()))
    ap.add_argument("--lengths", default="8192,100000")
    ap.add_argument("--ways", default="4,16")
    ap.add_argument("--backends", default="jnp")
    ap.add_argument("--variety", type=int, default=4096)
    ap.add_argument("--capacity", type=int, default=1024)
    ap.add_argument("--dist", choices=["uniform", "zipf"], default="zipf")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="small config per op + pallas interpret, with the "
                         "both-modes conservation cross-check (the CI job)")
    ap.add_argument("--check", action="store_true",
                    help="assert the headline 100k Zipf cell clears 5x")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    if args.smoke:
        rows = smoke_rows()
    else:
        rows = sweep(ops=args.ops.split(","),
                     lengths=[int(x) for x in args.lengths.split(",")],
                     ways_list=[int(x) for x in args.ways.split(",")],
                     backends=args.backends.split(","),
                     variety=args.variety, capacity=args.capacity,
                     dist=args.dist, reps=args.reps)
        hl = headline_row()
        rows.append(hl)
        print(f"headline: 100k Zipf jnp sum -> {hl['speedup']}x "
              f"({hl['fast_pairs_per_s']:.0f} pairs/s fast vs "
              f"{hl['scan_pairs_per_s']:.0f} scan)")
        if args.check:
            assert hl["speedup"] >= 5.0, \
                f"fast path speedup {hl['speedup']}x < 5x acceptance bar"
    print_rows(rows)
    write_out(rows, args.out)


if __name__ == "__main__":
    main()
