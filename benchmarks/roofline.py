"""§Roofline aggregator: reads dry-run artifacts, emits the roofline table.

    PYTHONPATH=src python -m benchmarks.roofline [--pod 1|2] [--mode tree]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts")

COLS = ("arch", "shape", "pods", "mode", "mem_gib", "compute_s", "memory_s",
        "coll_ici_s", "coll_dcn_s", "dominant", "useful", "fraction")


def load(pod: str | None = None, mode: str | None = None, tag: str = ""):
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        if len(parts) != 4:
            continue
        arch, shape, pods, mode_tag = parts
        if tag and not mode_tag.endswith(tag):
            continue
        if not tag and ("_" in mode_tag.replace("tree_compress", "treecompress")
                        and mode_tag not in ("tree", "flat", "gather")):
            continue  # skip tagged (hillclimb) artifacts in the default table
        if pod and pods != f"pod{pod}":
            continue
        if mode and not mode_tag.startswith(mode):
            continue
        with open(path) as f:
            d = json.load(f)
        if not d.get("ok"):
            continue
        # headline terms: structural (model-derived) flops/bytes + HLO-walk
        # collectives; the raw walker block stays in the artifact as a bound.
        r = d.get("roofline_structural", d["roofline"])
        rows.append({
            "arch": arch, "shape": shape, "pods": pods, "mode": mode_tag,
            "mem_gib": d["memory"]["total_per_device"] / 2**30,
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "coll_ici_s": r["collective_ici_s"], "coll_dcn_s": r["collective_dcn_s"],
            "dominant": r["dominant"], "useful": r["useful_flops_ratio"],
            "fraction": r["roofline_fraction"],
        })
    return rows


def render(rows, fmt="md"):
    if fmt == "md":
        out = ["| " + " | ".join(COLS) + " |",
               "|" + "|".join("---" for _ in COLS) + "|"]
        for r in rows:
            out.append("| " + " | ".join(
                f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
                for c in COLS) + " |")
        return "\n".join(out)
    import csv
    import io

    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=COLS)
    w.writeheader()
    for r in rows:
        w.writerow({c: r[c] for c in COLS})
    return buf.getvalue()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pod", default=None)
    ap.add_argument("--mode", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--fmt", default="md", choices=("md", "csv"))
    ap.add_argument("--sort", default="fraction")
    args = ap.parse_args()
    rows = load(args.pod, args.mode, args.tag)
    rows.sort(key=lambda r: (r[args.sort] if args.sort in ("fraction", "useful")
                             else str(r[args.sort])))
    print(render(rows, args.fmt))
    if rows:
        worst = rows[0] if args.sort == "fraction" else min(rows, key=lambda r: r["fraction"])
        most_coll = max(rows, key=lambda r: r["coll_ici_s"] + r["coll_dcn_s"])
        print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
              f"x {worst['pods']} ({worst['fraction']:.4f})")
        print(f"most collective-bound: {most_coll['arch']} x {most_coll['shape']} "
              f"x {most_coll['pods']} "
              f"(coll {most_coll['coll_ici_s'] + most_coll['coll_dcn_s']:.3f}s)")


if __name__ == "__main__":
    main()
