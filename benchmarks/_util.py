"""Shared helpers for the bench suites.

One place owns the ``BENCH_*.json`` schema (``{"bench": name, "rows":
[...]}``) that the CI artifact upload and ``tools/check_bench_regression``
parse — each suite's ``write_out`` delegates here, so a schema change
cannot drift per suite.
"""

from __future__ import annotations

import json
import os


def write_bench_json(rows: list[dict], out_path: str, *, bench: str) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"bench": bench, "rows": rows}, f, indent=1)
    print(f"wrote {out_path} ({len(rows)} rows)")
