"""Shared helpers for the bench suites.

One place owns the ``BENCH_*.json`` schema (``{"bench": name, "rows":
[...]}``) that the CI artifact upload and ``tools/check_bench_regression``
parse — each suite's ``write_out`` delegates here, so a schema change
cannot drift per suite.

**baselines/ vs out/ policy.**  ``benchmarks/out/`` is where every run
(local or CI) writes its ``BENCH_*.json`` plus the observability
artifacts (``metrics.json`` / ``trace.json`` / ``dashboard.*``,
DESIGN.md §11); it is generated output, gitignored, and safe to delete
— never commit anything from it by hand.  ``benchmarks/baselines/``
holds the CHECKED-IN reference rows the perf gate compares against; it
changes only via ``tools/check_bench_regression.py --update`` (run the
smoke first), so a baseline always reflects one complete, parity-clean
smoke run rather than hand-edited cells.  Absolute bars (``*_floor``
fields) live in the bench rows themselves and are read from the CURRENT
run, which is why re-baselining a slow run can never lower a floor.
"""

from __future__ import annotations

import json
import os


def write_bench_json(rows: list[dict], out_path: str, *, bench: str) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"bench": bench, "rows": rows}, f, indent=1)
    print(f"wrote {out_path} ({len(rows)} rows)")
