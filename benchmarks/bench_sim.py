"""Simulator-engine throughput: node oracle vs vectorized tier engine
(DESIGN.md §10).

Each cell runs the SAME job through both engines and reports
simulated-switch-steps per second (a step = one record entering a switch,
``sum(per_level records_in)``), with an in-bench cross-check that the two
engines' reports and delivered tables are exactly equal — a cell only
counts if parity held.  Three cells ladder up the scale the tier engine
exists for:

  * ``jct_smoke``       — the ``bench_jct`` smoke geometry (fanins (2,2),
                          64 pairs/mapper, capacity 32);
  * ``placement_accept``— the ``bench_placement`` acceptance fabric
                          (4-pod fat tree, 128 mappers, full placement);
  * ``fat16_tor``       — the first 16-pod / 2048-mapper run (ToR-tier
                          aggregation), far past where the per-switch
                          event loop was usable.  This cell's speedup is
                          floor-gated at >= 50x in
                          ``tools/check_bench_regression.py``;
  * ``multijob``        — a plan_all-admitted 4-job batch: the node leg
                          steps jobs one by one, the vectorized leg runs
                          ONE batched ``simulate([plans], ...)`` whose
                          same-signature tiers share kernel dispatches
                          (floor-gated >= 4x);
  * ``fat64_lossy``     — 64 pods / 8192 mappers, full-tree aggregation
                          at 1% loss: the vectorized go-back-N window
                          algebra vs the per-packet node sender
                          (floor-gated >= 20x);
  * ``obs_overhead``    — the fat16_tor vectorized leg with the tracer
                          disabled vs enabled (DESIGN.md §11): gates that
                          the no-op tracer really is free and that full
                          tracing stays within a bounded tax.  Both bars
                          are in-process throughput RATIOS, so the gate
                          carries no machine dependence.

    PYTHONPATH=src python benchmarks/bench_sim.py --smoke \
        --out benchmarks/out/BENCH_sim.json
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # package import (benchmarks.run) or standalone CLI
    from benchmarks._util import write_bench_json
except ImportError:  # `python benchmarks/bench_*.py`: sys.path[0] is here
    from _util import write_bench_json

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "BENCH_sim.json")

#: the fat16_tor cell must beat the node engine by this factor (gated)
SPEEDUP_FLOOR = 50.0
#: the 64-pod lossy cell's bar: the vectorized go-back-N sender must stay
#: >= this many times faster than the per-packet node sender
LOSSY_FLOOR = 20.0
#: the multi-job batch's bar: one batched dispatch per tier group must
#: beat stepping the jobs through the node engine one by one
MULTIJOB_FLOOR = 8.0
#: obs_overhead bars: tracing ENABLED must keep >= this fraction of the
#: tracing-disabled throughput (the observability tax is bounded) ...
OBS_ON_OFF_FLOOR = 0.5
#: ... and the tracing-DISABLED leg must keep >= this fraction of the
#: same run's gated fat16_tor vectorized throughput (the no-op tracer's
#: zero-overhead contract, DESIGN.md §11, as a perf bar rather than an
#: allocation test)
OBS_VS_BASE_FLOOR = 0.7


def _steps(res) -> int:
    return sum(lvl["records_in"] for lvl in res.per_level)


def _cell(name: str, run, *, vec_reps: int = 2, node_warmup=None,
          floor: float | None = None, **meta) -> dict:
    """Time ``run(engine)`` on both engines; cross-check parity.

    Both engines get a jit-warmup before timing so compile time never
    pollutes a cell (it would inflate the node leg and flatter the gated
    speedup).  ``node_warmup`` replaces the full node warmup run with a
    cheap shape-matched one for the multi-second cells.
    """
    rv = run("vectorized")  # warm the tier kernel's jit cache
    if node_warmup is None:
        run("node")
    else:
        node_warmup()
    t0 = time.perf_counter()
    rn = run("node")
    node_us = (time.perf_counter() - t0) * 1e6
    vec_us = float("inf")
    for _ in range(vec_reps):
        t0 = time.perf_counter()
        rv = run("vectorized")
        vec_us = min(vec_us, (time.perf_counter() - t0) * 1e6)
    parity = (rn.report() == rv.report()
              and rn.delivered_table() == rv.delivered_table())
    steps = _steps(rv)
    row = {
        "cell": name,
        **meta,
        "switch_steps": steps,
        "node_wall_us": round(node_us, 1),
        "vec_wall_us": round(vec_us, 1),
        "node_steps_per_s": round(steps / node_us * 1e6, 1),
        "vec_steps_per_s": round(steps / vec_us * 1e6, 1),
        "speedup": round(node_us / vec_us, 2),
        "parity": 1.0 if parity else 0.0,
    }
    if floor is not None:
        row["speedup_floor"] = floor
    return row


def jct_smoke_cell() -> dict:
    """The bench_jct smoke geometry through both engines."""
    from repro.core import dataplane
    from repro.core import reduction_model as rm
    from repro.net import sim as netsim

    fanins, per_mapper, variety, cap, rpp = (2, 2), 64, 64, 32, 16
    n = per_mapper * 4
    keys = rm.zipf_keys(n, variety, skew=0.99, seed=0).astype(np.int32)
    vals = np.ones((n,), np.float32)
    plan = dataplane.CascadePlan(op="sum", levels=tuple(
        dataplane.LevelSpec(capacity=cap) for _ in fanins))
    cfg = netsim.NetConfig(records_per_packet=rpp, exact_stream=True)

    def run(engine):
        from repro.net import simulate
        return simulate(netsim.JobSpec(
            keys=keys, values=vals, fanins=fanins, plan=plan,
            cfg=dataclasses.replace(cfg, engine=engine)))

    return _cell("jct_smoke", run, fanins=list(fanins), n_mappers=4,
                 records=n, records_per_packet=rpp, policy="-")


def _fat_tree_cell(name: str, *, pods: int, tors_per_pod: int,
                   hosts_per_tor: int, per_host_pairs: int, variety: int,
                   rpp: int, policy: str, table_pairs: int,
                   loss_rate: float = 0.0,
                   floor: float | None = None) -> dict:
    from repro.core import dataplane, planner
    from repro.core import reduction_model as rm
    from repro.net import sim as netsim

    ft = planner.FatTreeTopology(pods=pods, tors_per_pod=tors_per_pod,
                                 hosts_per_tor=hosts_per_tor,
                                 oversubscription=4.0,
                                 table_pairs=table_pairs)
    n = ft.n_hosts * per_host_pairs
    keys = rm.zipf_keys(n, variety, skew=0.99, seed=0).astype(np.int32)
    vals = np.ones((n,), np.float32)
    placement = planner.place_aggregation_tree(
        ft, per_host_pairs=per_host_pairs, key_variety=variety,
        policy=policy)
    cfg = netsim.NetConfig(records_per_packet=rpp, exact_stream=True,
                           loss_rate=loss_rate, seed=1, window=8)

    from repro.net import simulate

    def run(engine):
        return simulate(ft, keys, vals, placement=placement,
                        cfg=dataclasses.replace(cfg, engine=engine))

    def node_warmup():
        # compile the node path's per-packet kernels for THIS cell's
        # (rpp, capacity) shapes without paying a full node leg
        simulate(netsim.JobSpec(
            keys=keys[:4 * rpp], values=vals[:4 * rpp], fanins=(2, 2),
            plan=dataplane.CascadePlan(op="sum", levels=(
                dataplane.LevelSpec(capacity=table_pairs),
                dataplane.LevelSpec(capacity=table_pairs))),
            cfg=dataclasses.replace(cfg, engine="node")))

    return _cell(name, run, floor=floor, node_warmup=node_warmup,
                 pods=pods, n_mappers=ft.n_hosts, records=n,
                 records_per_packet=rpp, policy=policy,
                 loss_rate=loss_rate)


def multijob_cell(*, n_jobs: int = 4, floor: float | None = None) -> dict:
    """A ``JobScheduler.plan_all`` batch through both engines.

    The node leg steps each job alone (the node engine has no batching);
    the vectorized leg runs the whole batch as ONE batched ``simulate``
    call, so same-depth tiers sharing a kernel-static signature collapse
    into one ``tier_ingest`` dispatch each (DESIGN.md §10).  Parity is
    per-job bit-equality between the legs.
    """
    from repro.core import planner
    from repro.core import reduction_model as rm
    from repro.net import sim as netsim

    topo = planner.Topology(links=(
        planner.LinkBudget(axis="data", fanin=4, gbps=netsim.TEN_GBE),
        planner.LinkBudget(axis="pod", fanin=2, gbps=netsim.TEN_GBE / 4)))
    sched = planner.JobScheduler(topo, combiner_budget_pairs=4096)
    jplans = list(sched.plan_all([
        planner.LaunchRequest(job_id=j + 1, n_workers=8,
                              expected_pairs=1024, key_variety=512,
                              grad_bytes=1 << 20)
        for j in range(n_jobs)]).jobs)
    n = 8 * 1024
    keys_list = [rm.zipf_keys(n, 512, skew=0.99, seed=j).astype(np.int32)
                 for j in range(n_jobs)]
    vals_list = [np.ones((n,), np.float32) for _ in range(n_jobs)]
    cfg = netsim.NetConfig(records_per_packet=16, exact_stream=True)

    def run(engine):
        from repro.net import simulate
        return simulate(jplans, keys_list, vals_list,
                        cfg=dataclasses.replace(cfg, engine=engine))

    rvs = run("vectorized")  # warm the tier kernel's jit cache
    run("node")
    t0 = time.perf_counter()
    rns = run("node")
    node_us = (time.perf_counter() - t0) * 1e6
    vec_us = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        rvs = run("vectorized")
        vec_us = min(vec_us, (time.perf_counter() - t0) * 1e6)
    parity = all(rn.report() == rv.report()
                 and rn.delivered_table() == rv.delivered_table()
                 for rn, rv in zip(rns, rvs))
    steps = sum(_steps(rv) for rv in rvs)
    row = {
        "cell": "multijob",
        "n_jobs": n_jobs,
        "n_mappers": 8 * n_jobs,
        "records": n * n_jobs,
        "records_per_packet": 16,
        "policy": "-",
        "loss_rate": 0.0,
        "switch_steps": steps,
        "node_wall_us": round(node_us, 1),
        "vec_wall_us": round(vec_us, 1),
        "node_steps_per_s": round(steps / node_us * 1e6, 1),
        "vec_steps_per_s": round(steps / vec_us * 1e6, 1),
        "speedup": round(node_us / vec_us, 2),
        "parity": 1.0 if parity else 0.0,
    }
    if floor is not None:
        row["speedup_floor"] = floor
    return row


def obs_overhead_cell(base_row: dict, *, reps: int = 2) -> dict:
    """Tracing cost on the gated fat16_tor geometry (DESIGN.md §11).

    Runs the SAME vectorized fat16_tor job twice — once under a scoped
    DISABLED tracer (the production default) and once under a scoped
    enabled one — and reports two machine-independent ratios:

      * ``off_on_ratio``  — enabled / disabled throughput: the full
        observability tax (spans + per-run metrics publishing);
      * ``vs_base_ratio`` — disabled / this run's own ``fat16_tor``
        vectorized throughput: the no-op tracer's zero-overhead contract
        as a perf bar (both legs run in this process, so machine speed
        cancels out).

    Parity doubles as a semantics check: tracing must not change the
    simulated result bit-for-bit.
    """
    from repro.core import planner
    from repro.core import reduction_model as rm
    from repro.net import sim as netsim
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace

    ft = planner.FatTreeTopology(pods=16, tors_per_pod=8, hosts_per_tor=16,
                                 oversubscription=4.0, table_pairs=2048)
    n = ft.n_hosts * 64
    keys = rm.zipf_keys(n, 2048, skew=0.99, seed=0).astype(np.int32)
    vals = np.ones((n,), np.float32)
    placement = planner.place_aggregation_tree(
        ft, per_host_pairs=64, key_variety=2048, policy="tor_only")
    cfg = netsim.NetConfig(records_per_packet=4, exact_stream=True,
                           engine="vectorized")

    def run():
        from repro.net import simulate
        return simulate(ft, keys, vals, placement=placement, cfg=cfg)

    def best_leg():
        res, best_us = None, float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            res = run()
            best_us = min(best_us, (time.perf_counter() - t0) * 1e6)
        return res, best_us

    run()  # warm the tier kernel's jit cache (standalone-safe)
    with obs_metrics.scoped(), \
            obs_trace.scoped_tracer(obs_trace.Tracer(enabled=False)):
        r_off, off_us = best_leg()
    with obs_metrics.scoped(), obs_trace.scoped_tracer():
        r_on, on_us = best_leg()
    steps = _steps(r_off)
    off_sps = steps / off_us * 1e6
    on_sps = steps / on_us * 1e6
    return {
        "cell": "obs_overhead",
        "pods": 16,
        "n_mappers": ft.n_hosts,
        "records": n,
        "records_per_packet": 4,
        "policy": "tor_only",
        "loss_rate": 0.0,
        "switch_steps": steps,
        "obs_off_wall_us": round(off_us, 1),
        "obs_on_wall_us": round(on_us, 1),
        "obs_off_steps_per_s": round(off_sps, 1),
        "obs_on_steps_per_s": round(on_sps, 1),
        "off_on_ratio": round(on_sps / off_sps, 3),
        "vs_base_ratio": round(off_sps / base_row["vec_steps_per_s"], 3),
        "off_on_floor": OBS_ON_OFF_FLOOR,
        "vs_base_floor": OBS_VS_BASE_FLOOR,
        "parity": 1.0 if (r_off.report() == r_on.report()
                          and r_off.delivered_table()
                          == r_on.delivered_table()) else 0.0,
    }


def smoke_rows() -> list[dict]:
    """The CI job: five engine-vs-engine cells plus the observability
    overhead ratio cell, smallest first (the small cells double as jit
    warmup for the big ones' node legs)."""
    rows = [
        jct_smoke_cell(),
        _fat_tree_cell("placement_accept", pods=4, tors_per_pod=4,
                       hosts_per_tor=8, per_host_pairs=64, variety=2048,
                       rpp=16, policy="full", table_pairs=2048),
        multijob_cell(floor=MULTIJOB_FLOOR),
        _fat_tree_cell("fat16_tor", pods=16, tors_per_pod=8,
                       hosts_per_tor=16, per_host_pairs=64, variety=2048,
                       rpp=4, policy="tor_only", table_pairs=2048,
                       floor=SPEEDUP_FLOOR),
        _fat_tree_cell("fat64_lossy", pods=64, tors_per_pod=8,
                       hosts_per_tor=16, per_host_pairs=6, variety=2048,
                       rpp=4, policy="full", table_pairs=2048,
                       loss_rate=0.01, floor=LOSSY_FLOOR),
    ]
    rows.append(obs_overhead_cell(rows[3]))  # ratios vs this run's fat16
    for r in rows:  # a cell only counts if the engines/legs agreed exactly
        assert r["parity"] == 1.0, f"engine parity broke on {r['cell']}"
    for r in rows:
        if "speedup_floor" in r:
            assert r["speedup"] >= r["speedup_floor"], (
                f"{r['cell']} speedup {r['speedup']}x < "
                f"{r['speedup_floor']}x floor")
        for bar in ("off_on", "vs_base"):
            if f"{bar}_floor" in r:
                assert r[f"{bar}_ratio"] >= r[f"{bar}_floor"], (
                    f"{r['cell']} {bar}_ratio {r[f'{bar}_ratio']} < "
                    f"{r[f'{bar}_floor']} floor")
    return rows


def write_out(rows: list[dict], out_path: str) -> None:
    write_bench_json(rows, out_path, bench="sim")


def print_rows(rows: list[dict]) -> None:
    print(f"{'cell':<18} {'mappers':>7} {'records':>8} {'rpp':>3} "
          f"{'steps':>8} {'node ms':>9} {'vec ms':>8} {'speedup':>8} "
          f"{'parity':>6}")
    for r in rows:
        if r["cell"] == "obs_overhead":  # off/on legs, ratio bars
            print(f"{r['cell']:<18} {r['n_mappers']:>7} {r['records']:>8} "
                  f"{r['records_per_packet']:>3} {r['switch_steps']:>8} "
                  f"{r['obs_off_wall_us'] / 1e3:>9.1f} "
                  f"{r['obs_on_wall_us'] / 1e3:>8.1f} "
                  f"{r['off_on_ratio']:>7.2f}r {r['parity']:>6.0f}")
            continue
        print(f"{r['cell']:<18} {r['n_mappers']:>7} {r['records']:>8} "
              f"{r['records_per_packet']:>3} {r['switch_steps']:>8} "
              f"{r['node_wall_us'] / 1e3:>9.1f} "
              f"{r['vec_wall_us'] / 1e3:>8.1f} {r['speedup']:>7.1f}x "
              f"{r['parity']:>6.0f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="the CI cells (also the default full run)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    rows = smoke_rows()
    print_rows(rows)
    write_out(rows, args.out)


if __name__ == "__main__":
    main()
