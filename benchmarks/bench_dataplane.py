"""Cascade dataplane benchmark: capacity × levels × op sweep (DESIGN.md §6).

For each configuration a synthetic KV stream runs through a plan-driven
multi-level cascade (``core.dataplane.run_cascade``) and we record the
paper's key metric — per-level and end-to-end reduction ratio — plus wall
time, into a stable JSON (``BENCH_dataplane.json``) that CI regenerates
every run so the perf trajectory is tracked from this PR onward.

    PYTHONPATH=src python benchmarks/bench_dataplane.py
    PYTHONPATH=src python benchmarks/bench_dataplane.py --smoke \
        --out benchmarks/out/BENCH_dataplane.json

``--smoke`` runs the smallest config per op on the Pallas backend in
interpret mode (CPU) — the CI job — and cross-checks the cascade against
the exact grouped combine so a semantics regression fails the bench, not
just the unit suite.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # package import (benchmarks.run) or standalone CLI
    from benchmarks._util import write_bench_json
except ImportError:  # `python benchmarks/bench_*.py`: sys.path[0] is here
    from _util import write_bench_json

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "out",
                           "BENCH_dataplane.json")


def run_config(op: str, n_levels: int, capacity: int, *, n: int = 8192,
               variety: int = 1024, dist: str = "zipf", backend: str = "jnp",
               ways: int = 4, block_n: int = 256, reps: int = 3,
               check: bool = False) -> dict:
    """One cell: ``n_levels`` nodes of ``capacity`` pairs each, one op."""
    import jax
    import jax.numpy as jnp

    from repro.core import dataplane, kvagg
    from repro.core import reduction_model as rm

    plan = dataplane.CascadePlan(
        op=op, levels=tuple(dataplane.LevelSpec(capacity=capacity, ways=ways)
                            for _ in range(n_levels)))
    gen = rm.uniform_keys if dist == "uniform" else rm.zipf_keys
    keys = jnp.asarray(gen(n, variety, seed=0).astype(np.int32))
    vals = jnp.asarray(np.random.default_rng(0).standard_normal(n)
                       .astype(np.float32))

    interpret = True if backend == "pallas" else None

    def once():
        return dataplane.run_cascade(keys, vals, plan, backend=backend,
                                     block_n=block_n, interpret=interpret)

    res = once()  # warmup / compile
    res.keys.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        res = once()
        res.keys.block_until_ready()
    wall_us = (time.perf_counter() - t0) / reps * 1e6

    if check:  # smoke-mode semantics cross-check vs the exact combine
        from repro.core import aggops

        aggop = aggops.get(op)
        exact = kvagg.sorted_combine(keys, aggop.prepare_values(vals), op=op)
        ek = np.asarray(exact.unique_keys)
        ev = np.asarray(aggop.finalize_values(exact.combined_values))
        gk, gv = np.asarray(res.keys), np.asarray(res.values)
        nu = int(exact.n_unique)
        got = dict(zip(gk[gk != -1].tolist(), gv[: len(gk)][gk != -1].tolist()))
        want = dict(zip(ek[:nu].tolist(), ev[:nu].tolist()))
        assert got.keys() == want.keys(), f"{op}: key set mismatch"
        for kk in want:
            np.testing.assert_allclose(got[kk], want[kk], rtol=1e-4,
                                       atol=1e-5, err_msg=f"op={op} key={kk}")

    tele = dataplane.telemetry(res, plan)
    preds = dataplane.predicted_level_reductions(plan, n, variety)
    return {
        "op": op,
        "levels": n_levels,
        "capacity_per_node": capacity,
        "ways": ways,
        "n": n,
        "key_variety": variety,
        "dist": dist,
        "backend": backend,
        "reduction_per_level": [l["reduction"] for l in tele["levels"]],
        "evictions_per_level": [l["evictions"] for l in tele["levels"]],
        "predicted_per_level": [round(p, 4) for p in preds],
        "end_to_end_reduction": tele["end_to_end_reduction"],
        "wall_us": round(wall_us, 1),
    }


def sweep(*, ops, capacities, levels, n: int, variety: int, dist: str,
          backend: str, reps: int, check: bool = False) -> list[dict]:
    rows = []
    for op in ops:
        for nl in levels:
            for cap in capacities:
                rows.append(run_config(op, nl, cap, n=n, variety=variety,
                                       dist=dist, backend=backend, reps=reps,
                                       check=check))
    rows.sort(key=lambda r: (r["op"], r["levels"], r["capacity_per_node"]))
    return rows


def smoke_rows() -> list[dict]:
    """Smallest config per registered op, Pallas FPE in interpret mode."""
    from repro.core import aggops

    return sweep(ops=aggops.names(), capacities=[16], levels=[2], n=256,
                 variety=64, dist="zipf", backend="pallas", reps=1,
                 check=True)


def write_out(rows: list[dict], out_path: str) -> None:
    write_bench_json(rows, out_path, bench="dataplane")


def print_rows(rows: list[dict]) -> None:
    hdr = (f"{'op':<10} {'lvls':>4} {'cap':>6} {'backend':<7} "
           f"{'R end2end':>9} {'R/level':<23} {'us':>9}")
    print(hdr)
    for r in rows:
        per = "/".join(f"{x:.2f}" for x in r["reduction_per_level"])
        print(f"{r['op']:<10} {r['levels']:>4} {r['capacity_per_node']:>6} "
              f"{r['backend']:<7} {r['end_to_end_reduction']:>9.4f} "
              f"{per:<23} {r['wall_us']:>9.0f}")


def main() -> None:
    from repro.core import aggops

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ops", default=",".join(aggops.names()))
    ap.add_argument("--capacities", default="32,128,512")
    ap.add_argument("--levels", default="1,2,4")
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--variety", type=int, default=1024)
    ap.add_argument("--dist", choices=["uniform", "zipf"], default="zipf")
    ap.add_argument("--backend", choices=["jnp", "pallas"], default="jnp")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="smallest config per op, pallas interpret + "
                         "exactness cross-check (the CI job)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    if args.smoke:
        rows = smoke_rows()
    else:
        rows = sweep(ops=args.ops.split(","),
                     capacities=[int(c) for c in args.capacities.split(",")],
                     levels=[int(l) for l in args.levels.split(",")],
                     n=args.n, variety=args.variety, dist=args.dist,
                     backend=args.backend, reps=args.reps)
    print_rows(rows)
    write_out(rows, args.out)


if __name__ == "__main__":
    main()
