"""Paper-table/figure reproductions (the §Paper-validation benchmarks).

One function per table/figure of the paper:

  fig2a  — reduction ratio vs key variety (memory-capacity cliff)
  fig2b  — multi-hop aggregation does not rescue uniform data
  eq1_eq2— extra-traffic of fixed-format encapsulation + header overhead
  fig9   — reduction ratio vs workload x memory, uniform vs Zipf, S-* vs M-*
  table2 — line-rate proxy: eviction (BPE-feed) rate of the FPE engine
  table3 — stage-delay budget of the processing pipeline (analytical, cycles)
  fig10_11 — modeled JCT + reducer-CPU (combine work) with/without SwitchAgg

Scaled down from the paper's GBs to CPU-sized streams; every claim is a
RATIO so the scaling preserves the comparisons.
"""

from __future__ import annotations

import numpy as np

from repro.core import kvagg, reduction_model as rm

import jax.numpy as jnp


def fig2a(scale: int = 1 << 15):
    """Reduction ratio vs key variety at fixed memory (paper Fig. 2a)."""
    M, C = scale, scale // 20
    rows = []
    for n_frac in (0.005, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0):
        N = max(1, int(M * n_frac))
        keys = rm.uniform_keys(M, N, seed=1)
        stats, _ = rm.simulate_node(keys, None, capacity=C, ways=4)
        rows.append({
            "key_variety": N, "capacity": C, "data": M,
            "simulated": round(stats.reduction, 4),
            "analytic_eq3": round(rm.reduction_ratio(M, N, C), 4),
            "bound_C_over_N": round(rm.reduction_ratio_bound(N, C), 4),
        })
    return rows


def fig2b(scale: int = 1 << 14):
    """Multi-hop chain on uniform data (paper Fig. 2b): hops don't help."""
    M, N, C = scale, scale // 2, scale // 16
    keys = rm.uniform_keys(M, N, seed=2)
    rows = []
    for hops in (1, 2, 3, 4):
        r, stats = rm.simulate_chain(keys, None, [C] * hops)
        rows.append({"hops": hops, "end_to_end_reduction": round(r, 4),
                     "per_hop": [round(s.reduction, 4) for s in stats]})
    return rows


def eq1_eq2():
    """Extra traffic of DAIET-style fixed slots vs SwitchAgg encoding (Eq. 1)
    and small-packet header overhead (Eq. 2)."""
    rng = np.random.default_rng(3)
    pair_lens = rng.integers(10, 21, size=10).tolist()  # 10-20B pairs
    uniform20 = [20] * 10
    tiny = [1] * 10
    return {
        "eq1_fixed20_random_pairs": round(rm.fixed_format_extra_traffic(20, pair_lens), 3),
        "eq1_fixed20_exactfit": rm.fixed_format_extra_traffic(20, uniform20),
        "eq1_fixed20_1B_pairs": rm.fixed_format_extra_traffic(20, tiny),
        "switchagg_encoding_random_pairs": round(rm.switchagg_extra_traffic(pair_lens), 3),
        "eq2_rmt200B_overhead": round(rm.header_overhead_ratio(229, 58), 3),
        "eq2_eth1500B_overhead": round(rm.header_overhead_ratio(1442, 58), 3),
    }


def fig9(stream: int = 1 << 13):
    """Reduction ratio: workload x FPE memory, uniform vs Zipf(0.99),
    SRAM-only (S-*) vs multi-level (M-*).  Paper Fig. 9."""
    N = stream // 4  # key variety scales like the paper's 1GB-of-keys case
    rows = []
    for dist in ("uniform", "zipf"):
        gen = rm.uniform_keys if dist == "uniform" else rm.zipf_keys
        for wl_mult in (1, 2, 4):
            M = stream * wl_mult
            keys = jnp.asarray(gen(M, N, seed=5).astype(np.int32))
            vals = jnp.ones((M,), jnp.float32)
            for cap_frac, label in ((1 / 32, "S-small"), (1 / 8, "S-large")):
                cap = max(4, int(N * cap_frac))
                res = kvagg.two_level_aggregate(keys, vals, capacity=cap,
                                                ways=4, bpe=False)
                rows.append({"dist": dist, "workload": M, "mode": label,
                             "capacity": cap,
                             "reduction": round(float(kvagg.reduction_ratio(res)), 4)})
            res = kvagg.two_level_aggregate(keys, vals, capacity=max(4, N // 8),
                                            ways=4, bpe=True)
            rows.append({"dist": dist, "workload": M, "mode": "M-multilevel",
                         "capacity": max(4, N // 8),
                         "reduction": round(float(kvagg.reduction_ratio(res)), 4)})
    return rows


def table2(stream: int = 1 << 13):
    """Line-rate proxy (paper Table 2).  The paper counts FIFO-full events;
    the TPU analogue of 'the FPE never stalls' is structural (evictions are
    emitted, not retried), so we report the eviction rate — the fraction of
    inputs that generate BPE-feed traffic — across workload sizes."""
    rows = []
    N = stream // 4
    for wl_mult in (1, 2, 4, 8):
        M = stream * wl_mult
        keys = jnp.asarray(rm.zipf_keys(M, N, seed=7).astype(np.int32))
        vals = jnp.ones((M,), jnp.float32)
        fpe = kvagg.fpe_aggregate(keys, vals, capacity=max(4, N // 8), ways=4)
        ev_rate = float(jnp.mean(fpe.evict_keys != kvagg.EMPTY_KEY))
        rows.append({"workload_pairs": M, "evict_rate": round(ev_rate, 4),
                     "stall_free": True})  # by construction: evict, never retry
    return rows


def table3():
    """Stage-delay budget (paper Table 3, cycles @200MHz).  We keep the
    paper's Ethernet-domain numbers as the faithful record and add the TPU
    mapping of each stage."""
    return [
        {"stage": "Header Analyzer", "paper_cycles": 3, "tpu_analogue": "block metadata decode (free: static shapes)"},
        {"stage": "Crossbar", "paper_cycles": 2, "tpu_analogue": "length-group dispatch (static routing)"},
        {"stage": "FPE-Hash", "paper_cycles": 10, "tpu_analogue": "VPU multiplicative hash (vectorized)"},
        {"stage": "FPE-Aggregate", "paper_cycles": 18, "tpu_analogue": "VMEM probe+combine (lane-parallel ways)"},
        {"stage": "FPE-Forward", "paper_cycles": 5, "tpu_analogue": "eviction stream store"},
        {"stage": "BPE-Aggregate", "paper_cycles": 33, "tpu_analogue": "HBM sort+segment-sum (overlapped)"},
        {"stage": "BPE-Flush", "paper_cycles": 3.125e7, "tpu_analogue": "EoT table flush (bulk DMA)"},
    ]


def fig10_11(root_reduction: float = 0.9):
    """Modeled JCT + reducer combine-work with/without SwitchAgg (Figs 10/11).

    JCT model: reducer in-link at 10 Gb/s is the bottleneck (paper testbed);
    CPU model: reducer combine work proportional to received pairs."""
    link = 10e9 / 8
    rows = []
    for wl_gb in (2, 4, 8, 16):
        b = wl_gb * (1 << 30)
        t_no, t_sw = b / link, b * (1 - root_reduction) / link
        rows.append({
            "workload_gb": wl_gb,
            "jct_no_agg_s": round(t_no, 1),
            "jct_switchagg_s": round(t_sw, 1),
            "jct_saved": round(1 - t_sw / t_no, 3),
            "reducer_cpu_relative": round(1 - root_reduction, 3),
        })
    return rows
