"""AdamW (+ int8 moments, fp32 masters, ZeRO specs) and LR schedule."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    AdamWConfig, adamw_init, adamw_update, global_norm, make_lr_schedule,
)
from repro.optim.quant import QTensor, dequantize, quantize


def _tree(rng):
    return {
        "a": jnp.asarray(rng.standard_normal((64, 32)).astype(np.float32)),
        "b": {"w": jnp.asarray(rng.standard_normal(256).astype(np.float32))},
    }


def test_adamw_matches_manual_math(rng):
    cfg = AdamWConfig(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                      quantized=False, master_fp32=False)
    params = _tree(rng)
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1, params)
    st = adamw_init(params, cfg)
    lr = 1e-2
    new_p, new_st, _ = adamw_update(grads, st, params, cfg, jnp.asarray(lr))

    # manual first step: m=0.1g/0.1? m_hat = m/(1-b1) etc.
    g = 0.1
    m = (1 - cfg.b1) * g
    v = (1 - cfg.b2) * g * g
    m_hat = m / (1 - cfg.b1)
    v_hat = v / (1 - cfg.b2)
    for leaf, new in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)):
        want = np.asarray(leaf) - lr * (m_hat / (np.sqrt(v_hat) + cfg.eps)
                                        + cfg.weight_decay * np.asarray(leaf))
        np.testing.assert_allclose(np.asarray(new), want, rtol=1e-5, atol=1e-6)
    assert int(new_st.count) == 1


def test_adamw_quantized_moments_close_to_exact(rng):
    params = _tree(rng)
    grads = jax.tree.map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape).astype(np.float32)) * 0.01,
        params)
    exact_cfg = AdamWConfig(quantized=False, master_fp32=False)
    quant_cfg = AdamWConfig(quantized=True, master_fp32=False)
    se, sq = adamw_init(params, exact_cfg), adamw_init(params, quant_cfg)
    pe, pq = params, params
    for i in range(5):
        pe, se, _ = adamw_update(grads, se, pe, exact_cfg, jnp.asarray(1e-2))
        pq, sq, _ = adamw_update(grads, sq, pq, quant_cfg, jnp.asarray(1e-2))
    # int8 moments drift pointwise (sqrt(v) amplifies small-value error);
    # the meaningful contract is that the *update direction* is preserved.
    for p0, a, b in zip(jax.tree.leaves(params), jax.tree.leaves(pe),
                        jax.tree.leaves(pq)):
        ue = (np.asarray(a) - np.asarray(p0)).reshape(-1)
        uq = (np.asarray(b) - np.asarray(p0)).reshape(-1)
        cos = np.dot(ue, uq) / (np.linalg.norm(ue) * np.linalg.norm(uq))
        assert cos > 0.97, f"quantized update diverged: cos={cos:.4f}"
        assert np.linalg.norm(uq) == pytest.approx(np.linalg.norm(ue), rel=0.15)


def test_adamw_master_fp32_keeps_bf16_params_converging(rng):
    cfg = AdamWConfig(master_fp32=True)
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), _tree(rng))
    st = adamw_init(params, cfg)
    assert st.master is not None
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(st.master))
    grads = jax.tree.map(lambda p: jnp.full(p.shape, 1e-4, jnp.float32), params)
    p1, st, _ = adamw_update(grads, st, params, cfg, jnp.asarray(1e-5))
    # master accumulated the tiny update even where bf16 param may round
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(p1))
    m_moved = jax.tree.map(
        lambda a, b: float(np.max(np.abs(np.asarray(a, np.float32) - np.asarray(b)))),
        st.master, _tree(rng))
    assert max(jax.tree.leaves(m_moved)) > 0


def test_global_norm(rng):
    t = {"x": jnp.asarray([3.0, 4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_quantize_roundtrip_error(rng):
    x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
    q = quantize(x)
    assert isinstance(q, QTensor)
    assert q.q.dtype == jnp.int8
    y = dequantize(q, x.shape)
    # blockwise absmax int8: ~1/127 relative error per block
    denom = np.maximum(np.abs(np.asarray(x)), 1e-3)
    rel = np.abs(np.asarray(y) - np.asarray(x)) / denom
    assert np.median(rel) < 0.02
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0.05)


def test_quantize_zero_block_safe():
    x = jnp.zeros(512, jnp.float32)
    y = dequantize(quantize(x), x.shape)
    np.testing.assert_array_equal(np.asarray(y), 0.0)


def test_lr_schedule_warmup_and_decay():
    fn = make_lr_schedule(1e-3, warmup=10, total=100, min_ratio=0.1)
    assert float(fn(jnp.asarray(0))) < 2e-4
    assert float(fn(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    end = float(fn(jnp.asarray(100)))
    assert end == pytest.approx(1e-4, rel=0.05)
    mid = float(fn(jnp.asarray(55)))
    assert end < mid < 1e-3
