"""Paper-faithful analytic model: Eq. (1)-(3), Theorems 2.1/2.2.

These tests validate the reproduction against the paper's own claims
(EXPERIMENTS.md §Paper-validation reads from the benchmark versions).
"""

import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev-only dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import reduction_model as rm


# ---------------------------------------------------------------------------
# Eq. (1) — fixed-format padding waste.
# ---------------------------------------------------------------------------


def test_eq1_no_waste_when_exact():
    assert rm.fixed_format_extra_traffic(20, [20, 20, 20]) == 1.0


def test_eq1_half_length_pairs_double_traffic():
    """Paper example: 10B average pairs in 20B slots -> ~50% extra traffic."""
    t = rm.fixed_format_extra_traffic(20, [10] * 10)
    assert t == pytest.approx(2.0)


def test_eq1_extreme_case():
    """Paper: M=200, N=20, P_i=1 -> ~20x traffic ('nearly 7 times more' is
    their conservative phrasing; the formula gives M/sum(P_i) = 20/1)."""
    t = rm.fixed_format_extra_traffic(20, [1] * 10)
    assert t == pytest.approx(20.0)


def test_switchagg_encoding_beats_fixed_format():
    """Variable-length + metadata < fixed-slot padding for skewed lengths."""
    pairs = [4, 8, 12, 20, 6, 9]
    assert rm.switchagg_extra_traffic(pairs) < rm.fixed_format_extra_traffic(20, pairs)


def test_eq1_rejects_oversize_pairs():
    with pytest.raises(ValueError):
        rm.fixed_format_extra_traffic(8, [9])


# ---------------------------------------------------------------------------
# Eq. (2) — header overhead.
# ---------------------------------------------------------------------------


def test_eq2_header_overhead():
    assert rm.header_overhead_bytes(1000, 200, 58) == 1000 + 5 * 58


def test_eq2_paper_ratio():
    """Paper: 200B RMT packets -> 25.3% header overhead (58B TCP/IP)."""
    assert rm.header_overhead_ratio(229, 58) == pytest.approx(0.253, abs=0.002)
    # 1500B ethernet is ~7x cheaper
    assert rm.header_overhead_ratio(1442, 58) < 0.05


# ---------------------------------------------------------------------------
# Eq. (3) — reduction ratio model + simulation agreement (paper Fig. 2a).
# ---------------------------------------------------------------------------


def test_eq3_regimes():
    # N <= C: everything aggregates; R = 1 - N/M
    assert rm.reduction_ratio(1000, 100, 128) == pytest.approx(0.9)
    # N > C: bounded by capacity; R = (1/N - 1/M) * C
    r = rm.reduction_ratio(1000, 500, 128)
    assert r == pytest.approx((1 / 500 - 1 / 1000) * 128)
    assert r <= rm.reduction_ratio_bound(500, 128)


def test_eq3_monotone_in_capacity():
    rs = [rm.reduction_ratio(10000, 2000, c) for c in (0, 100, 1000, 2000, 4000)]
    assert all(b >= a for a, b in zip(rs, rs[1:]))


def test_eq3_validates_against_simulation_uniform():
    """Fig. 2a reproduction: simulated hash node tracks Eq. (3) closely in
    both regimes (uniform keys)."""
    M = 20000
    for N, C in [(128, 1024), (512, 1024), (4096, 1024), (8192, 512)]:
        keys = rm.uniform_keys(M, N, seed=1)
        stats, _ = rm.simulate_node(keys, None, capacity=C, ways=4)
        analytic = rm.reduction_ratio(M, N, C)
        bound = rm.reduction_ratio_bound(N, C)
        if N <= C:
            # memory suffices: simulation tracks Eq. (3) tightly (hash
            # collisions can cost a little)
            assert abs(stats.reduction - analytic) < 0.05
        else:
            # capacity-limited: Eq. (3) models a static resident set; the
            # evicting node does a bit better but never beats the C/N bound
            assert analytic * 0.55 <= stats.reduction <= bound + 0.02


def test_fig2a_cascade():
    """Paper observation: when N >> C the reduction collapses (<10% at 10x)."""
    M = 20000
    keys = rm.uniform_keys(M, 10000, seed=0)
    stats, _ = rm.simulate_node(keys, None, capacity=1000, ways=4)
    assert stats.reduction < 0.12
    keys = rm.uniform_keys(M, 500, seed=0)
    stats, _ = rm.simulate_node(keys, None, capacity=1000, ways=4)
    assert stats.reduction > 0.8  # paper: >80% when memory suffices


# ---------------------------------------------------------------------------
# Theorem 2.1 — merged flows == single flow.
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nflows=st.integers(2, 6))
def test_theorem_2_1(seed, nflows):
    rng = np.random.default_rng(seed)
    flows = [rng.integers(0, 200, size=rng.integers(150, 400)).astype(np.int64)
             for _ in range(nflows)]
    merged = rm.merge_flows(flows)
    single = np.concatenate(flows)
    s_m, _ = rm.simulate_node(merged, None, capacity=64, ways=4)
    s_s, _ = rm.simulate_node(single, None, capacity=64, ways=4)
    # same multiset of keys -> same unique-key count; reduction differs only
    # through order-dependent eviction noise (shrinks with stream length)
    assert s_m.input_pairs == s_s.input_pairs
    assert abs(s_m.reduction - s_s.reduction) < 0.08


# ---------------------------------------------------------------------------
# Theorem 2.2 — multi-hop == single-hop for uniform data (paper Fig. 2b).
# ---------------------------------------------------------------------------


def test_theorem_2_2_uniform():
    M, N, C = 20000, 8000, 1024
    keys = rm.uniform_keys(M, N, seed=3)
    r1, _ = rm.simulate_chain(keys, None, [C])
    r4, stats4 = rm.simulate_chain(keys, None, [C, C, C, C])
    # multi-hop does NOT help much for uniform keys (paper's key negative result)
    assert r4 - r1 < 0.15
    # and every extra hop helps strictly less (diminishing returns)
    per_hop = [s.reduction for s in stats4]
    assert per_hop[0] > per_hop[1] > 0.0 or per_hop[1] < 0.05


def test_theorem_2_2_bound():
    """Multi-hop reduction shares the single-hop upper bound family:
    R_total <= 1 - N/M (the information-theoretic best)."""
    M, N = 10000, 2000
    keys = rm.uniform_keys(M, N, seed=5)
    best = 1.0 - N / M
    for hops in (1, 2, 4):
        r, _ = rm.simulate_chain(keys, None, [512] * hops)
        assert r <= best + 1e-9


def test_skewed_multihop_can_help_more():
    """For Zipf data the first hop catches hot keys; later hops see the tail."""
    M, N = 20000, 8000
    keys = rm.zipf_keys(M, N, skew=0.99, seed=7)
    r1, _ = rm.simulate_chain(keys, None, [1024])
    r2, _ = rm.simulate_chain(keys, None, [1024, 1024])
    assert r2 >= r1  # never hurts


# ---------------------------------------------------------------------------
# Conservation invariant of the simulator itself.
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_simulator_conserves_sums(seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 50, size=500).astype(np.int64)
    vals = rng.standard_normal(500)
    _, out = rm.simulate_node(keys, vals, capacity=16, ways=2)
    got: dict = {}
    for k, v in out:
        got[k] = got.get(k, 0.0) + v
    want: dict = {}
    for k, v in zip(keys.tolist(), vals.tolist()):
        want[k] = want.get(k, 0.0) + v
    assert got.keys() == want.keys()
    for k in want:
        assert got[k] == pytest.approx(want[k], rel=1e-9)


# ---------------------------------------------------------------------------
# TPU-domain tree traffic model (the collective-schedule analogue).
# ---------------------------------------------------------------------------


def test_tree_traffic_reduces_root_level():
    m = rm.TreeTrafficModel(grad_bytes=1 << 30, fanins=(16, 2))
    flat = m.flat_bytes_per_level()
    tree = m.tree_bytes_per_level()
    # root (pod) level: the tree carries 2*(2-1)/2 * grad/16 = grad/16 bytes
    assert tree[-1] == pytest.approx((1 << 30) / 16)
    # vs flat's 2*(511/512)*grad — >16x more on the scarce link
    assert flat[-1] / tree[-1] > 16
    assert m.tree_reduction_at_root() > 0.9


def test_tree_traffic_totals():
    """Tree total bytes <= flat total bytes for any fanins."""
    for fanins in [(4,), (8, 2), (16, 2), (4, 4, 4)]:
        m = rm.TreeTrafficModel(grad_bytes=1000000, fanins=fanins)
        assert sum(m.tree_bytes_per_level()) <= sum(m.flat_bytes_per_level()) + 1e-6
