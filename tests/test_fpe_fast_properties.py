"""Batched-block FPE fast path hypothesis properties (DESIGN.md §8).

Kept separate from tests/test_fpe_fast.py so the deterministic coverage
runs on every environment; only THIS module skips without hypothesis.
"""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev-only dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import aggops, kvagg
from test_fpe_fast import _assert_same_grouped, _fast_stream_grouped, _grouped

EMPTY = int(kvagg.EMPTY_KEY)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 200),
    variety=st.integers(1, 64),
    capacity=st.sampled_from([1, 4, 16, 64]),
    ways=st.sampled_from([1, 2, 4]),
    n_blocks=st.integers(1, 4),
    op=st.sampled_from(sorted(aggops.names())),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_fast_path_equals_scan_grouped_combine(
        n, variety, capacity, ways, n_blocks, op, seed):
    """For ANY stream, capacity/ways geometry, block split, and EVERY
    registered AggOp (incl. multi-lane carried ops), the fast path's
    (flush + evictions) grouped by key equals the scan oracle's."""
    r = np.random.default_rng(seed)
    keys = r.integers(0, variety, size=n).astype(np.int32)
    raw = r.integers(-8, 8, size=n).astype(np.float32)
    carried = np.asarray(aggops.get(op).prepare_values(jnp.asarray(raw)))

    scan = kvagg.fpe_aggregate(
        jnp.asarray(keys), jnp.asarray(carried), capacity=capacity,
        ways=ways, op=op, exact_stream=True)
    want = _grouped(np.concatenate([scan.table_keys, scan.evict_keys]),
                    np.concatenate([scan.table_values, scan.evict_values]),
                    op)
    got = _fast_stream_grouped(keys, carried, capacity=capacity, ways=ways,
                               op=op, n_blocks=n_blocks)
    _assert_same_grouped(got, want, op)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    variety=st.integers(1, 128),
    capacity=st.sampled_from([1, 8, 64]),
    ways=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_fast_path_table_invariants(n, variety, capacity, ways, seed):
    """The fast path's resident table obeys the engine invariants the
    closed form (and any resumed call) relies on: every key sits in its
    hash bucket, rows are front-contiguous, and no key is resident twice."""
    from test_fpe_fast import assert_table_invariants

    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.integers(0, variety, size=n).astype(np.int32))
    vals = jnp.asarray(r.standard_normal(n).astype(np.float32))
    res = kvagg.fpe_aggregate(keys, vals, capacity=capacity, ways=ways,
                              op="sum", exact_stream=False)
    assert_table_invariants(res.table_keys, capacity=capacity, ways=ways)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 150),
    variety=st.integers(1, 40),
    op=st.sampled_from(sorted(aggops.names())),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_sorted_combine_matches_oracle(n, variety, op, seed):
    """sorted_combine (rebuilt on the radix-sort + searchsorted group
    reduce) still matches the brute-force oracle for every op."""
    from conftest import dict_aggregate

    r = np.random.default_rng(seed)
    keys = r.integers(0, variety, size=n).astype(np.int32)
    mask = r.random(n) < 0.2
    keys = np.where(mask, EMPTY, keys).astype(np.int32)
    raw = r.integers(-8, 8, size=n).astype(np.float32)
    aggop = aggops.get(op)
    carried = aggop.prepare_values(jnp.asarray(raw))
    c = kvagg.sorted_combine(jnp.asarray(keys), carried, op=op)
    nu = int(c.n_unique)
    uk = np.asarray(c.unique_keys)
    fin = np.asarray(aggop.finalize_values(c.combined_values))
    got = {int(k): float(fin[i]) for i, k in enumerate(uk[:nu])}
    want = dict_aggregate(keys, np.where(mask, 0, raw), op=op)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5)
    # packed ascending, EMPTY padding after n_unique
    assert np.all(np.diff(uk[:nu]) > 0)
    assert np.all(uk[nu:] == EMPTY)
