"""Multi-job congestion-aware controller (DESIGN.md §3).

Covers: memory partitioning (even + weighted), deterministic planning,
congestion-aware ordering search, SOAR-style byte-budget escalation to the
compressed exchange, and the scarce-link win over naive flat all-reduces.
"""

import dataclasses
import math

import pytest

from repro.core import planner as pl
from repro.core import tree as tree_lib
from repro.core.collectives import GradAggMode

MiB = 1 << 20


def _req(i, *, grad_mb=256, key_variety=1000, pairs=10_000,
         mode=GradAggMode.TREE):
    return pl.LaunchRequest(job_id=i, n_workers=32, expected_pairs=pairs,
                            key_variety=key_variety, grad_bytes=grad_mb * MiB,
                            mode=mode)


def _sched(*, budget_mb=math.inf, pairs=1 << 20, policy="even"):
    budget = budget_mb * MiB if budget_mb != math.inf else math.inf
    topo = pl.Topology.production(scarce_budget_bytes=budget)
    return pl.JobScheduler(topo, combiner_budget_pairs=pairs,
                           partition_policy=policy)


# ---------------------------------------------------------------------------
# Memory partitioning (paper §4.2.2).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["even", "weighted"])
@pytest.mark.parametrize("n_jobs", [1, 2, 3, 5, 8])
def test_partitions_sum_within_budget(policy, n_jobs):
    budget = 1 << 16
    reqs = [_req(i, key_variety=100 * (i + 1)) for i in range(n_jobs)]
    caps = pl.partition_memory(budget, reqs, policy)
    assert set(caps) == {r.job_id for r in reqs}
    assert sum(caps.values()) <= budget
    assert all(c >= 1 for c in caps.values())


def test_partition_even_matches_paper():
    reqs = [_req(i) for i in range(4)]
    caps = pl.partition_memory(1 << 20, reqs, "even")
    assert set(caps.values()) == {(1 << 20) // 4}


def test_partition_weighted_favors_key_variety():
    reqs = [_req(0, key_variety=100), _req(1, key_variety=900)]
    caps = pl.partition_memory(1000, reqs, "weighted")
    assert caps[1] == 9 * caps[0]


def test_partition_tiny_budget_never_overflows():
    # the >=1 floor must not push the sum past a budget smaller than n_jobs
    reqs = [_req(i, key_variety=10 * (i + 1)) for i in range(8)]
    caps = pl.partition_memory(5, reqs, "weighted")
    assert sum(caps.values()) <= 8  # every job still gets >= 1 pair
    assert all(c >= 1 for c in caps.values())


def test_scheduler_repartitions_on_admit_and_release():
    s = _sched(pairs=1 << 10, policy="even")
    s.admit(_req(0))
    assert s.jobs[0].exchange.fpe_capacity == 1 << 10
    s.admit(_req(1))
    assert s.jobs[0].exchange.fpe_capacity == 1 << 9  # re-partitioned
    assert s.jobs[1].exchange.fpe_capacity == 1 << 9
    s.release(0)
    assert s.jobs[1].exchange.fpe_capacity == 1 << 10


# ---------------------------------------------------------------------------
# Determinism.
# ---------------------------------------------------------------------------


def test_plans_are_deterministic():
    reqs = [_req(i, grad_mb=256 >> (i % 3), key_variety=500 * (i + 1))
            for i in range(6)]
    r1 = _sched(budget_mb=128, policy="weighted").plan_all(list(reqs))
    r2 = _sched(budget_mb=128, policy="weighted").plan_all(list(reversed(reqs)))
    assert [j.exchange for j in r1.jobs] == [j.exchange for j in r2.jobs]
    assert r1.link_totals == r2.link_totals
    assert r1.total_scarce_bytes == r2.total_scarce_bytes


# ---------------------------------------------------------------------------
# Congestion-aware tree selection.
# ---------------------------------------------------------------------------


def test_single_job_picks_cheap_axis_first():
    s = _sched()
    jp = s.admit(_req(0))
    # leaf must be the fat ICI level; the scarce pod level reduces last,
    # seeing only the 1/16 shard
    assert jp.exchange.leaf_axis == "data"
    assert jp.exchange.upper_axes == ("pod",)
    assert jp.exchange.scarce_link_bytes == pytest.approx(
        2 * (2 - 1) / 2 * 256 * MiB / 16)


def test_scheduled_beats_flat_on_scarce_link():
    for n in (1, 2, 4, 8):
        s = _sched(budget_mb=128)
        rep = s.plan_all([_req(i, grad_mb=256 >> (i % 4)) for i in range(n)])
        assert rep.total_scarce_bytes < rep.baseline_flat_scarce_bytes
        assert rep.scarce_traffic_cut > 0.4


def test_congestion_term_balances_link_load():
    # with the ICI level already saturated by big tenants, a small job's
    # best placement can flip leaf order to the idle level — the max-drain
    # objective must never pick a WORSE drain time than naive cheap-first
    s = _sched()
    for i in range(3):
        s.admit(_req(i, grad_mb=512))
    naive = s.link_loads()
    fanins = (16, 2)
    lvl = pl.modeled_level_bytes(64 * MiB, fanins)
    naive_trial = {"data": naive["data"] + lvl[0], "pod": naive["pod"] + lvl[1]}
    naive_drain = max(naive_trial["data"] / 50e9, naive_trial["pod"] / 6.25e9)
    jp = s.admit(_req(3, grad_mb=64))
    assert s._drain_s(s.link_loads()) <= naive_drain + 1e-12
    assert not jp.over_budget


def test_byte_budget_escalates_to_compression():
    # budget fits exactly one dense tree job; the second must compress
    dense_scarce = 2 * (2 - 1) / 2 * 256 * MiB / 16  # 16 MiB
    s = _sched(budget_mb=dense_scarce * 1.5 / MiB)
    j0 = s.admit(_req(0))
    assert j0.exchange.mode == GradAggMode.TREE
    j1 = s.admit(_req(1))
    assert j1.exchange.mode == GradAggMode.TREE_COMPRESS
    assert not j1.over_budget
    assert j1.exchange.k_fraction <= 0.01
    assert s.report().total_scarce_bytes <= dense_scarce * 1.5 + 1e-6
    # escalated jobs still carry *something* across the pod level
    assert j1.exchange.scarce_link_bytes > 0


def test_compress_requested_job_still_walks_k_ladder():
    # a job that already asked for TREE_COMPRESS with a too-large k must be
    # admitted with a smaller k, not flagged over-budget.  Headroom above
    # the first dense job is less than the k=0.01 payload (0.32 MiB), so
    # the ladder must halve k at least once.
    dense_scarce = 2 * (2 - 1) / 2 * 256 * MiB / 16
    s = _sched(budget_mb=(dense_scarce + 0.2 * MiB) / MiB)
    s.admit(_req(0))  # dense job eats most of the budget
    jp = s.admit(_req(1, mode=GradAggMode.TREE_COMPRESS))
    assert jp.exchange.mode == GradAggMode.TREE_COMPRESS
    assert not jp.over_budget
    assert jp.exchange.k_fraction < 0.01


def test_impossible_budget_flags_over_budget():
    s = _sched(budget_mb=1e-9)
    jp = s.admit(_req(0))
    assert jp.over_budget
    assert jp.exchange.mode == GradAggMode.TREE_COMPRESS
    assert jp.exchange.k_fraction == s.min_k_fraction


def test_duplicate_job_id_rejected():
    s = _sched()
    s.admit(_req(0))
    with pytest.raises(ValueError):
        s.admit(_req(0))


# ---------------------------------------------------------------------------
# Level-byte model.
# ---------------------------------------------------------------------------


def test_modeled_level_bytes_matches_traffic_model():
    from repro.core.reduction_model import TreeTrafficModel

    g, fanins = 1 << 30, (16, 2)
    want = TreeTrafficModel(grad_bytes=g, fanins=fanins).tree_bytes_per_level()
    got = pl.modeled_level_bytes(g, fanins, mode=GradAggMode.TREE)
    assert list(got) == pytest.approx(want)


def test_modeled_level_bytes_flat_is_uniform():
    g = 1 << 30
    got = pl.modeled_level_bytes(g, (16, 2), mode=GradAggMode.FLAT)
    assert got[0] == got[1] == pytest.approx(2 * 31 / 32 * g)


def test_modeled_level_bytes_compress_shrinks_uppers_only():
    g, k = 1 << 30, 0.01
    dense = pl.modeled_level_bytes(g, (16, 2), mode=GradAggMode.TREE)
    comp = pl.modeled_level_bytes(g, (16, 2), mode=GradAggMode.TREE_COMPRESS,
                                  k_fraction=k)
    assert comp[0] == dense[0]  # leaf reduce-scatter stays exact
    assert comp[1] == pytest.approx(dense[1] * 2 * k)


# ---------------------------------------------------------------------------
# Topology construction and report plumbing.
# ---------------------------------------------------------------------------


def test_topology_from_mesh_skips_absent_axes():
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    topo = pl.Topology.from_mesh(mesh)
    assert len(topo.links) == 1  # degenerate but total


def test_topology_scarce_axis_is_slowest():
    topo = pl.Topology.production()
    assert topo.scarce_axis == "pod"
    assert topo.link("pod").gbps < topo.link("data").gbps


def test_report_summary_mentions_every_job():
    s = _sched(budget_mb=128)
    rep = s.plan_all([_req(i) for i in range(3)])
    text = rep.summary()
    for i in range(3):
        assert f"job {i}:" in text


def test_plan_grad_exchange_reports_level_bytes():
    import jax

    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    plan = pl.plan_grad_exchange(mesh, grad_bytes=64 * MiB,
                                 reduce_axes=("data", "model"))
    if plan.fanins and math.prod(plan.fanins) > 1:
        assert len(plan.level_bytes) == len(plan.fanins)
        assert plan.scarce_link_bytes > 0


def test_exchange_plan_describe_is_stable():
    plan = pl.ExchangePlan(
        mode=GradAggMode.TREE, leaf_axis="data", upper_axes=("pod",),
        k_fraction=0.01, fpe_capacity=64, predicted_root_reduction=0.9,
        predicted_kv_reduction=0.5, job_id=7, fanins=(16, 2),
        level_bytes=(1.0, 2.0), scarce_link_bytes=2.0 * MiB)
    assert "job 7" in plan.describe()
    assert "data(x16) -> pod(x2)" in plan.describe()


def test_tree_for_preserves_ordering():
    topo = pl.Topology.production()
    t = topo.tree_for(tuple(reversed(topo.links)))
    assert t.axes == ("pod", "data")
    assert isinstance(t, tree_lib.AggregationTree)


def test_exchange_from_plan_drives_dataplane():
    # the plan (not hardcoded args) selects the exchange; on the degenerate
    # single-device mesh the tree exchange must be the identity sum
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import collectives as coll

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = pl.plan_grad_exchange(mesh, reduce_axes=("data", "model"))
    assert plan.mode == GradAggMode.TREE and plan.upper_axes == ()

    def region(g):
        out, _ = coll.exchange_from_plan(g, plan)
        return out

    mapped = coll.shard_map_compat(
        region, mesh=mesh, in_specs=(P(),), out_specs=P(),
        axis_names={"data", "model"}, check_vma=False)
    x = {"w": jnp.arange(8.0)}
    out = jax.jit(mapped)(x)
    np.testing.assert_allclose(np.asarray(out["w"]), np.arange(8.0))
