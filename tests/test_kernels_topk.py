"""Pallas per-row magnitude top-k kernel vs oracle (gradient compressor)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev-only dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.topk_compress import topk_rows_pallas


@pytest.mark.parametrize(
    "rows,cols,k,block_rows",
    [
        (8, 128, 4, 8),
        (16, 256, 1, 8),
        (4, 512, 16, 4),
        (24, 128, 8, 8),   # rows not divisible by block? 24/8 ok
        (8, 128, 128, 8),  # k == cols (degenerate: full selection)
    ],
)
def test_topk_matches_ref(rows, cols, k, block_rows, rng):
    x = jnp.asarray(rng.standard_normal((rows, cols)).astype(np.float32))
    vals, idx = topk_rows_pallas(x, k=k, block_rows=block_rows, interpret=True)
    rvals, ridx = ref.topk_ref(x, k)
    np.testing.assert_array_equal(idx, ridx)
    np.testing.assert_allclose(vals, rvals, rtol=1e-6)


def test_topk_signed_values(rng):
    """Selection is by |x| but returned values keep their sign."""
    x = jnp.asarray(
        np.array([[1.0, -5.0, 3.0, -2.0] + [0.0] * 124], dtype=np.float32)
    )
    vals, idx = topk_rows_pallas(x, k=3, block_rows=1, interpret=True)
    np.testing.assert_array_equal(np.asarray(idx)[0], [1, 2, 3])
    np.testing.assert_allclose(np.asarray(vals)[0], [-5.0, 3.0, -2.0])


def test_topk_ties_first_index(rng):
    """Equal magnitudes resolve to the lower index (matches iterative argmax)."""
    row = np.zeros((1, 128), np.float32)
    row[0, [7, 3, 99]] = 2.0  # three-way tie
    vals, idx = topk_rows_pallas(jnp.asarray(row), k=3, block_rows=1, interpret=True)
    np.testing.assert_array_equal(np.sort(np.asarray(idx)[0]), [3, 7, 99])
    assert np.asarray(idx)[0, 0] == 3  # lowest index first


@settings(max_examples=15, deadline=None)
@given(
    rows=st.sampled_from([1, 4, 8]),
    cols=st.sampled_from([128, 256]),
    k=st.sampled_from([1, 4, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_topk_selects_largest(rows, cols, k, seed):
    """The selected set is exactly the k largest magnitudes of each row."""
    r = np.random.default_rng(seed)
    x = r.standard_normal((rows, cols)).astype(np.float32)
    vals, idx = topk_rows_pallas(jnp.asarray(x), k=k, block_rows=rows,
                                 interpret=True)
    idx = np.asarray(idx)
    for i in range(rows):
        got = set(idx[i].tolist())
        want = set(np.argsort(-np.abs(x[i]), kind="stable")[:k].tolist())
        # ties can swap membership only between equal magnitudes
        if got != want:
            gm = sorted(np.abs(x[i])[sorted(got)].tolist())
            wm = sorted(np.abs(x[i])[sorted(want)].tolist())
            np.testing.assert_allclose(gm, wm)
