"""Data pipeline determinism + gradient->KV compressor properties."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev-only dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

import repro.configs as configs
from repro.configs.reduced import reduced_config
from repro.core import compressor as comp
from repro.data.pipeline import DataConfig, SyntheticLMData


# ---------------------------------------------------------------------------
# Data pipeline (restart reproducibility is a fault-tolerance requirement).
# ---------------------------------------------------------------------------


def _data(arch="phi4-mini-3.8b", **kw):
    cfg = reduced_config(arch)
    d = dict(seq_len=16, global_batch=4, seed=7)
    d.update(kw)
    return cfg, SyntheticLMData(cfg, DataConfig(**d))


def test_batch_pure_in_step():
    _, data = _data()
    b1, b2 = data.batch_at(3), data.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = data.batch_at(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_labels_are_next_tokens():
    _, data = _data()
    b = data.batch_at(0)
    # labels[i] continues tokens[i]: both come from one (s+1)-length stream
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_tokens_in_vocab_and_zipf_skewed():
    cfg, data = _data(seq_len=512, global_batch=8)
    b = data.batch_at(0)
    toks = b["tokens"]
    assert toks.min() >= 0 and toks.max() < cfg.vocab_size
    # Zipf: the most common token should be much more frequent than median
    counts = np.bincount(toks.reshape(-1), minlength=cfg.vocab_size)
    assert counts.max() > 10 * max(1, int(np.median(counts[counts > 0])))


def test_vision_batch_has_patches():
    cfg, data = _data("paligemma-3b")
    b = data.batch_at(0)
    assert b["patch_embeds"].shape == (4, cfg.prefix_tokens, cfg.d_model)


def test_audio_batch_has_frames_no_tokens():
    cfg, data = _data("musicgen-medium")
    b = data.batch_at(0)
    assert "tokens" not in b
    assert b["frame_embeds"].shape == (4, 16, cfg.d_model)


def test_prompt_at_slices():
    _, data = _data()
    p = data.prompt_at(0, 8)
    assert p["tokens"].shape == (4, 8)


# ---------------------------------------------------------------------------
# Compressor: top-k + error feedback.
# ---------------------------------------------------------------------------


def test_topk_compress_selects_largest(rng):
    g = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))
    state = comp.init_state(g.shape)
    cg, new_state = comp.topk_compress(g, state, k=16)
    flat = np.asarray(g).reshape(-1)
    want = set(np.argsort(-np.abs(flat))[:16].tolist())
    assert set(np.asarray(cg.keys).tolist()) == want
    # error feedback: residual holds exactly what was not sent
    dense = comp.decompress_sum(cg.keys, cg.values, size=flat.size)
    np.testing.assert_allclose(
        np.asarray(dense) + np.asarray(new_state.residual).reshape(-1),
        flat, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(k=st.sampled_from([1, 8, 64]), seed=st.integers(0, 2**31 - 1))
def test_property_error_feedback_conserves(k, seed):
    """sent + residual == grad + old_residual, always."""
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.standard_normal(128).astype(np.float32))
    state = comp.CompressorState(residual=jnp.asarray(
        r.standard_normal(128).astype(np.float32)))
    cg, ns = comp.topk_compress(g, state, k=k)
    sent = comp.decompress_sum(cg.keys, cg.values, size=128)
    np.testing.assert_allclose(
        np.asarray(sent) + np.asarray(ns.residual),
        np.asarray(g) + np.asarray(state.residual), atol=1e-5)


def test_blockwise_topk_bounded_working_set(rng):
    g = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    state = comp.init_state(g.shape)
    cg, ns = comp.blockwise_topk_compress(g, state, k=2, chunk=16)
    keys = np.asarray(cg.keys).reshape(4, 2)
    for row in range(4):  # every chunk contributed exactly k keys in-range
        assert np.all((keys[row] >= row * 16) & (keys[row] < (row + 1) * 16))
    sent = comp.decompress_sum(cg.keys, cg.values, size=64)
    np.testing.assert_allclose(
        np.asarray(sent) + np.asarray(ns.residual), np.asarray(g), atol=1e-6)


def test_decompress_sum_accumulates_duplicates():
    keys = jnp.asarray([2, 2, 5, -1], jnp.int32)
    vals = jnp.asarray([1.0, 3.0, 7.0, 99.0], jnp.float32)
    dense = comp.decompress_sum(keys, vals, size=8)
    want = np.zeros(8, np.float32)
    want[2], want[5] = 4.0, 7.0
    np.testing.assert_array_equal(np.asarray(dense), want)


def test_compression_ratio():
    # 1% top-k of fp32 with int32 keys: 2% of dense bytes
    assert comp.compression_ratio((1000,), 10) == pytest.approx(0.02)


def test_error_feedback_converges_unbiased(rng):
    """Repeatedly compressing the same gradient: total_sent + residual == n*g
    exactly, and the residual stays bounded (so mean sent -> g at rate 1/n)."""
    g = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    state = comp.init_state(g.shape)
    total = np.zeros(64, np.float32)
    n = 50
    res_norms = []
    for _ in range(n):
        cg, state = comp.topk_compress(g, state, k=4)
        total += np.asarray(comp.decompress_sum(cg.keys, cg.values, size=64))
        res_norms.append(float(np.linalg.norm(np.asarray(state.residual))))
    np.testing.assert_allclose(
        total + np.asarray(state.residual), n * np.asarray(g), rtol=1e-5, atol=1e-3)
    # bounded residual: the last 10 norms don't grow
    assert max(res_norms[-10:]) < 2.0 * max(res_norms[:20]) + 1e-6
