"""core.dataplane — the plan-driven multi-level cascade executor."""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import dict_aggregate
from repro.core import aggops, dataplane, kvagg, planner
from repro.core.dataplane import CascadePlan, LevelSpec

EMPTY = int(kvagg.EMPTY_KEY)


def _got(res):
    keys = np.asarray(res.keys)
    vals = np.asarray(res.values)
    return {int(k): float(v) for k, v in zip(keys, vals) if k != EMPTY}


# --------------------------------------------------------------------------
# plan construction
# --------------------------------------------------------------------------


def test_plan_requires_levels_and_known_op():
    with pytest.raises(ValueError):
        CascadePlan(op="sum", levels=())
    with pytest.raises(ValueError):
        CascadePlan(op="nope", levels=(LevelSpec(4),))


def test_plan_from_configure_splits_budget_per_level():
    msg = planner.ConfigureMsg(tree_id=0, level_axes=("data", "pod"),
                               fanins=(16, 2), fpe_capacity=1024, op="mean")
    plan = dataplane.plan_from_configure(msg)
    assert plan.op == "mean"
    assert plan.capacities == (512, 512)


def test_plan_from_scheduler_jobplan_end_to_end(rng):
    """Acceptance: a JobScheduler plan executes through the dataplane."""
    topo = planner.Topology.production()
    sched = planner.JobScheduler(topo, combiner_budget_pairs=64)
    jp = sched.admit(planner.LaunchRequest(
        job_id=0, n_workers=32, expected_pairs=1024, key_variety=128,
        op="mean", grad_bytes=0))
    plan = dataplane.plan_from_configure(jp)
    assert len(plan.levels) == len(jp.tree.levels)
    keys = jnp.asarray(rng.integers(0, 128, 2048).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(2048).astype(np.float32))
    res = dataplane.run_cascade(keys, vals, plan)
    got = _got(res)
    want = dict_aggregate(keys, vals, op="mean")
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5)
    tele = dataplane.telemetry(res, plan)
    assert len(tele["levels"]) == len(plan.levels)
    assert tele["n_in"] == 2048
    assert all(l["records_out"] <= l["records_in"] for l in tele["levels"])


def test_cascade_from_exchange_plan_partitions_upper_hops():
    xp = planner.ExchangePlan(
        mode=planner.GradAggMode.TREE_COMPRESS, leaf_axis="data",
        upper_axes=("pod", "dcn"), k_fraction=0.01, fpe_capacity=100,
        predicted_root_reduction=0.0, predicted_kv_reduction=0.0)
    plan = dataplane.cascade_from_exchange_plan(xp)
    assert plan.capacities == (50, 50)
    assert plan.op == "sum"


def test_even_and_uniform_level_builders():
    assert dataplane.even_split_levels(100, 2)[0].capacity == 50
    assert dataplane.even_split_levels(1, 4)[0].capacity == 1  # >= 1 floor
    assert dataplane.even_split_levels(0, 3) == (dataplane.LevelSpec(0),) * 3
    assert dataplane.uniform_levels(64, 3) == (dataplane.LevelSpec(64),) * 3


def test_non_sum_exchange_plan_raises_not_silently_sums():
    """REGRESSION: a non-sum plan must trip the sum-only exchange guard,
    not execute as SUM (workers-count-factor wrong gradients)."""
    from repro.core import collectives as coll

    xp = planner.ExchangePlan(
        mode=planner.GradAggMode.TREE_COMPRESS, leaf_axis="data",
        upper_axes=("pod",), k_fraction=0.01, fpe_capacity=16,
        predicted_root_reduction=0.0, predicted_kv_reduction=0.0, op="mean")
    cascade = dataplane.cascade_from_exchange_plan(xp)
    assert cascade.op == "mean"  # plan.op flows through...
    with pytest.raises(ValueError, match="sum cascade"):
        # ...and the dataplane-level guard rejects it before any math runs
        coll.tree_compress_allreduce(
            jnp.zeros((8,)), jnp.zeros((8,)), "data", ("pod",), k=2,
            cascade=cascade)


# --------------------------------------------------------------------------
# cascade exactness (the hypothesis property tests over arbitrary level /
# capacity splits live in tests/test_dataplane_properties.py so THIS module
# runs everywhere — hypothesis is an optional dev dep)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("op", sorted(aggops.names()))
@pytest.mark.parametrize("caps", [(1,), (4, 16), (64, 1, 4)])
def test_cascade_equals_grouped_combine_fixed_cases(op, caps, rng):
    keys = jnp.asarray(rng.integers(0, 48, size=200).astype(np.int32))
    vals = jnp.asarray(rng.integers(-8, 8, size=200).astype(np.float32))
    plan = CascadePlan(op=op, levels=tuple(LevelSpec(c) for c in caps))
    res = dataplane.run_cascade(keys, vals, plan)
    got = _got(res)
    want = dict_aggregate(keys, vals, op=op)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5)
    li, lo = np.asarray(res.level_in), np.asarray(res.level_out)
    assert li[0] == 200
    np.testing.assert_array_equal(li[1:], lo[:-1])
    assert int(res.n_out) == lo[-1]


def test_exact_capacity_zero_level_is_sorted_combine(rng):
    keys = jnp.asarray(rng.integers(0, 16, 128).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(128).astype(np.float32))
    plan = CascadePlan(op="sum", levels=(LevelSpec(0),))
    res = dataplane.run_cascade(keys, vals, plan)
    assert res.keys.shape == keys.shape  # exact node: packed [n], no flush
    got = _got(res)
    want = dict_aggregate(keys, vals)
    assert got.keys() == want.keys()
    assert int(res.level_evict[0]) == 0


# --------------------------------------------------------------------------
# node-level invariants (kvagg) the cascade builds on — kept here, NOT in
# the hypothesis-gated test_kvagg_core.py, so they run everywhere
# --------------------------------------------------------------------------


def test_bpe_false_out_counts_forwarded_pairs_not_distinct_keys():
    """INVARIANT (documented on TwoLevelResult): with bpe=False the output
    is a traffic stream — re-evicted keys appear multiple times and n_out
    counts forwarded PAIRS (what a downstream link carries), which can
    exceed the number of distinct keys; conservation still holds."""
    # ways=1, capacity=1: keys 5/9 alternate, every arrival re-evicts
    keys = jnp.asarray([5, 9, 5, 9, 5, 9], dtype=jnp.int32)
    vals = jnp.ones((6,), jnp.float32)
    res = kvagg.two_level_aggregate(keys, vals, capacity=1, ways=1, bpe=False)
    n_out = int(res.n_out)
    n_distinct = int(kvagg.n_distinct_keys(res.out_keys))
    assert n_distinct == 2
    assert n_out == 6  # 5 evictions + 1 resident pair, duplicates included
    assert n_out > n_distinct
    # conservation: grouping the duplicated stream is still exact
    got = dict_aggregate(res.out_keys, res.out_values)
    assert got == dict_aggregate(keys, vals)
    # the BPE digests the duplicates: n_out becomes <= capacity + distinct
    res_bpe = kvagg.two_level_aggregate(keys, vals, capacity=1, ways=1, bpe=True)
    assert int(res_bpe.n_out) <= 1 + n_distinct


def test_n_distinct_keys_handles_int32_max_and_padding():
    """REGRESSION: INT32_MAX is a legal key, not a sentinel."""
    keys = jnp.asarray([2147483647, 5, EMPTY, 5, 2147483647], jnp.int32)
    assert int(kvagg.n_distinct_keys(keys)) == 2
    assert int(kvagg.n_distinct_keys(jnp.full((4,), EMPTY, jnp.int32))) == 0


def test_sorted_combine_int32_max_key_with_padding():
    """REGRESSION: the old is-pad sentinel remap to INT32_MAX merged a real
    INT32_MAX key into the padding segment, silently dropping its value."""
    keys = jnp.asarray([2147483647, EMPTY, 5], jnp.int32)
    vals = jnp.asarray([-5.0, 0.0, 2.0], jnp.float32)
    res = kvagg.sorted_combine(keys, vals)
    assert int(res.n_unique) == 2
    got = dict_aggregate(res.unique_keys, res.combined_values)
    assert got == {5: 2.0, 2147483647: -5.0}
    # and through a full bounded cascade
    plan = CascadePlan(op="sum", levels=(LevelSpec(1, ways=1),))
    cres = dataplane.run_cascade(keys, vals, plan)
    assert _got(cres) == {5: 2.0, 2147483647: -5.0}


def test_kv_tree_op_conflicting_with_plan_raises():
    """REGRESSION: an explicit op that contradicts plan.op must raise, not
    silently run the plan's op."""
    from repro.core import collectives as coll

    plan = CascadePlan(op="sum", levels=(LevelSpec(4),))
    with pytest.raises(ValueError, match="conflicts with plan.op"):
        coll.kv_tree_aggregate(jnp.zeros((8,), jnp.int32),
                               jnp.zeros((8,), jnp.float32),
                               ("data",), fpe_capacity=4, op="max", plan=plan)


def test_two_level_nodes_report_evictions():
    keys = jnp.asarray([5, 9, 5, 9], jnp.int32)
    vals = jnp.ones((4,), jnp.float32)
    res = kvagg.two_level_aggregate(keys, vals, capacity=1, ways=1)
    assert int(res.n_evict) == 3
    from repro.kernels import ops as kops

    pres = kops.two_level_aggregate(keys, vals, capacity=1, ways=1,
                                    block_n=4, interpret=True)
    assert int(pres.n_evict) == 3


def test_fpe_multilane_values_share_eviction_pattern(rng):
    """Carried lane dims (mean's (sum,count)) ride the key-driven engine."""
    keys = jnp.asarray(rng.integers(0, 24, 128).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(128).astype(np.float32))
    lanes = jnp.stack([vals, jnp.ones_like(vals)], axis=-1)
    r1 = kvagg.fpe_aggregate(keys, vals, capacity=8, ways=2)
    r2 = kvagg.fpe_aggregate(keys, lanes, capacity=8, ways=2)
    np.testing.assert_array_equal(r2.table_keys, r1.table_keys)
    np.testing.assert_array_equal(r2.evict_keys, r1.evict_keys)
    np.testing.assert_allclose(r2.table_values[:, 0], r1.table_values)
    np.testing.assert_allclose(r2.evict_values[:, 0], r1.evict_values)
    # lane 1 counts multiplicity: table + evictions conserve the 128 records
    total = float(jnp.sum(jnp.where(r2.table_keys != EMPTY,
                                    r2.table_values[:, 1], 0.0))
                  + jnp.sum(jnp.where(r2.evict_keys != EMPTY,
                                      r2.evict_values[:, 1], 0.0)))
    assert total == 128.0


# --------------------------------------------------------------------------
# pallas backend parity (interpret mode on CPU)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("op", sorted(aggops.names()))
def test_pallas_backend_matches_jnp(op, rng):
    keys = jnp.asarray(rng.integers(0, 40, 256).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    plan = CascadePlan(op=op, levels=(LevelSpec(16), LevelSpec(8)))
    a = dataplane.run_cascade(keys, vals, plan, backend="jnp")
    b = dataplane.run_cascade(keys, vals, plan, backend="pallas",
                              block_n=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(a.keys), np.asarray(b.keys))
    np.testing.assert_allclose(np.asarray(a.values), np.asarray(b.values),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.level_evict),
                                  np.asarray(b.level_evict))


def test_unknown_backend_raises(rng):
    keys = jnp.zeros((8,), jnp.int32)
    vals = jnp.zeros((8,), jnp.float32)
    with pytest.raises(ValueError, match="backend"):
        dataplane.run_level(keys, vals, LevelSpec(4), "sum", backend="tpu9000")


# --------------------------------------------------------------------------
# telemetry & prediction
# --------------------------------------------------------------------------


def test_reduction_helpers_and_telemetry(rng):
    keys = jnp.asarray(rng.integers(0, 64, 1024).astype(np.int32))
    vals = jnp.ones((1024,), jnp.float32)
    plan = CascadePlan(op="sum", levels=(LevelSpec(32), LevelSpec(32)))
    res = dataplane.run_cascade(keys, vals, plan)
    lr = np.asarray(dataplane.level_reductions(res))
    assert lr.shape == (2,)
    e2e = float(dataplane.end_to_end_reduction(res))
    assert 0.0 <= e2e <= 1.0
    tele = dataplane.telemetry(res, plan)
    assert tele["end_to_end_reduction"] == pytest.approx(e2e, abs=1e-3)
    for lvl, r in zip(tele["levels"], lr):
        assert lvl["reduction"] == pytest.approx(float(r), abs=1e-3)


def test_predicted_level_reductions_eq3_regimes():
    # N <= C: ideal 1 - N/M at the first hop
    plan = CascadePlan(op="sum", levels=(LevelSpec(512),))
    [p] = dataplane.predicted_level_reductions(plan, 4096, 256)
    assert p == pytest.approx(1 - 256 / 4096)
    # N > C: bounded by C/N
    plan = CascadePlan(op="sum", levels=(LevelSpec(64),))
    [p] = dataplane.predicted_level_reductions(plan, 4096, 256)
    assert p <= 64 / 256 + 1e-9


def test_simulate_plan_report_shape():
    plan = CascadePlan(op="sum", levels=(LevelSpec(64), LevelSpec(64)))
    rep = dataplane.simulate_plan(plan, data_amount=1024, key_variety=128)
    assert len(rep["levels"]) == 2
    for lvl in rep["levels"]:
        assert {"records_in", "records_out", "evictions", "reduction",
                "predicted_reduction"} <= set(lvl)
    assert rep["n_in"] == 1024
