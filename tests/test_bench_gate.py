"""The CI perf-regression gate (tools/check_bench_regression.py).

The gate compares smoke-run BENCH_fpe/BENCH_dataplane/BENCH_sim metrics
against checked-in baselines with a tolerance band.  These tests pin its
contract on synthetic fixtures: identical runs pass, >30% throughput
drops fail, improvements pass (with a re-baseline note), semantic
(reduction-ratio / engine-parity) drift fails tightly, the sim suite's
absolute speedup floor fails regardless of the baseline, and coverage
shrink fails.
"""

import importlib.util
import json
import pathlib
import sys

import pytest

_TOOL = (pathlib.Path(__file__).resolve().parents[1] / "tools"
         / "check_bench_regression.py")
_spec = importlib.util.spec_from_file_location("check_bench_regression",
                                               _TOOL)
gate = importlib.util.module_from_spec(_spec)
sys.modules["check_bench_regression"] = gate
_spec.loader.exec_module(gate)


def _fpe_row(**kw):
    row = {"op": "sum", "n": 2048, "key_variety": 256, "capacity": 128,
           "ways": 4, "dist": "zipf", "backend": "jnp",
           "scan_us": 1000.0, "fast_us": 100.0,
           "scan_pairs_per_s": 2_048_000.0,
           "fast_pairs_per_s": 20_480_000.0, "speedup": 10.0}
    row.update(kw)
    return row


def _dp_row(**kw):
    row = {"op": "sum", "levels": 2, "capacity_per_node": 16, "ways": 4,
           "n": 256, "key_variety": 64, "dist": "zipf", "backend": "pallas",
           "end_to_end_reduction": 0.75, "wall_us": 5000.0}
    row.update(kw)
    return row


def _sim_row(**kw):
    row = {"cell": "fat16_tor", "pods": 16, "n_mappers": 2048,
           "records": 131072, "records_per_packet": 4, "policy": "tor_only",
           "switch_steps": 237220, "node_wall_us": 10_000_000.0,
           "vec_wall_us": 100_000.0, "node_steps_per_s": 23_722.0,
           "vec_steps_per_s": 2_372_200.0, "speedup": 100.0, "parity": 1.0,
           "speedup_floor": 50.0}
    row.update(kw)
    return row


def _write(dirpath, fpe_rows, dp_rows, sim_rows=None):
    dirpath.mkdir(parents=True, exist_ok=True)
    (dirpath / "BENCH_fpe.json").write_text(
        json.dumps({"bench": "fpe", "rows": fpe_rows}))
    (dirpath / "BENCH_dataplane.json").write_text(
        json.dumps({"bench": "dataplane", "rows": dp_rows}))
    (dirpath / "BENCH_sim.json").write_text(
        json.dumps({"bench": "sim",
                    "rows": sim_rows if sim_rows is not None
                    else [_sim_row()]}))


@pytest.fixture()
def dirs(tmp_path):
    base, out = tmp_path / "baselines", tmp_path / "out"
    _write(base, [_fpe_row()], [_dp_row()])
    return base, out


def _check(base, out, **kw):
    kw.setdefault("tolerance", 0.30)
    kw.setdefault("semantic_tolerance", 0.02)
    return gate.check(out, base, **kw)


def test_identical_run_passes(dirs):
    base, out = dirs
    _write(out, [_fpe_row()], [_dp_row()])
    assert _check(base, out) == 0


def test_large_throughput_drop_fails(dirs):
    # a systemic slowdown (every cell down 50%) trips the geomean gate
    base, out = dirs
    _write(out, [_fpe_row(fast_pairs_per_s=20_480_000.0 * 0.5,
                          scan_pairs_per_s=2_048_000.0 * 0.5)], [_dp_row()])
    assert _check(base, out) == 1


def test_single_noisy_cell_does_not_fail_the_gate(dirs):
    # one cell -50%, one +100%: geomean == 1.0 — smoke cells are tiny and
    # single-cell swings are runner noise, not regressions
    base, out = dirs
    _write(out, [_fpe_row(fast_pairs_per_s=20_480_000.0 * 0.5,
                          scan_pairs_per_s=2_048_000.0 * 2.0)], [_dp_row()])
    assert _check(base, out) == 0


def test_drop_within_band_passes(dirs):
    base, out = dirs
    _write(out, [_fpe_row(fast_pairs_per_s=20_480_000.0 * 0.8,
                          scan_pairs_per_s=2_048_000.0 * 0.75)],
           [_dp_row(wall_us=5000.0 * 1.2)])
    assert _check(base, out) == 0


def test_improvement_passes(dirs):
    base, out = dirs
    _write(out, [_fpe_row(fast_pairs_per_s=20_480_000.0 * 3)],
           [_dp_row(wall_us=100.0)])
    assert _check(base, out) == 0


def test_semantic_drift_fails_even_when_fast(dirs):
    base, out = dirs
    _write(out, [_fpe_row()], [_dp_row(end_to_end_reduction=0.60)])
    assert _check(base, out) == 1


def test_missing_config_row_fails(dirs):
    # the current run silently dropped the pallas dataplane cell
    base, out = dirs
    _write(out, [_fpe_row()], [_dp_row(backend="jnp")])
    assert _check(base, out) == 1


def test_missing_current_file_fails(dirs):
    base, out = dirs
    out.mkdir()
    (out / "BENCH_fpe.json").write_text(
        json.dumps({"bench": "fpe", "rows": [_fpe_row()]}))
    assert _check(base, out) == 1  # dataplane baseline has no counterpart


def test_no_baselines_is_a_warning_not_a_failure(tmp_path):
    base, out = tmp_path / "empty", tmp_path / "out"
    base.mkdir()
    _write(out, [_fpe_row()], [_dp_row()])
    assert _check(base, out) == 0


def test_update_then_check_roundtrip(tmp_path):
    base, out = tmp_path / "baselines", tmp_path / "out"
    _write(out, [_fpe_row()], [_dp_row()])
    assert gate.update(out, base) == 0
    assert _check(base, out) == 0


def test_sim_speedup_below_floor_fails(dirs):
    # the tier engine slipping under the absolute 50x bar fails, even
    # though as a throughput ratio 49x-vs-100x-baseline would only be a
    # cell-level note
    base, out = dirs
    _write(out, [_fpe_row()], [_dp_row()],
           [_sim_row(speedup=49.0, vec_wall_us=204_081.0,
                     vec_steps_per_s=1_162_477.0)])
    assert _check(base, out) == 1


def test_sim_speedup_floor_comes_from_current_run(dirs):
    # re-baselining cannot lower the bar: a stale baseline floor of 10x
    # does not save a current run that declares (and misses) 50x
    base, out = dirs
    _write(base, [_fpe_row()], [_dp_row()],
           [_sim_row(speedup_floor=10.0)])
    _write(out, [_fpe_row()], [_dp_row()],
           [_sim_row(speedup=49.0, vec_wall_us=204_081.0,
                     vec_steps_per_s=1_162_477.0)])
    assert _check(base, out) == 1


def test_sim_parity_break_fails(dirs):
    # parity is semantic: any drift from 1.0 means the engines disagreed
    base, out = dirs
    _write(out, [_fpe_row()], [_dp_row()], [_sim_row(parity=0.0)])
    assert _check(base, out) == 1


def test_repo_baselines_match_gated_files():
    # the checked-in baselines must cover exactly what the gate checks,
    # so the CI step never silently no-ops
    repo = pathlib.Path(__file__).resolve().parents[1]
    for fname in gate.GATED:
        path = repo / "benchmarks" / "baselines" / fname
        assert path.exists(), f"missing checked-in baseline {fname}"
        rows = gate._load_rows(path)
        assert rows, f"baseline {fname} has no rows"
        assert gate.EXTRACTORS[fname](rows), f"no metrics from {fname}"


def _lossy_row(**kw):
    row = _sim_row(cell="fat64_lossy", pods=64, n_mappers=8192,
                   records=49152, policy="full", loss_rate=0.01,
                   switch_steps=104642, node_wall_us=10_000_000.0,
                   vec_wall_us=300_000.0, node_steps_per_s=10_464.2,
                   vec_steps_per_s=348_806.7, speedup=33.3,
                   speedup_floor=20.0)
    row.update(kw)
    return row


def test_sim_every_floor_row_gates_independently(dirs):
    # multiple floor-carrying cells: the flagship passing its 50x bar
    # does not excuse the lossy cell missing its 20x bar
    base, out = dirs
    _write(base, [_fpe_row()], [_dp_row()], [_sim_row(), _lossy_row()])
    _write(out, [_fpe_row()], [_dp_row()],
           [_sim_row(), _lossy_row(speedup=19.0, vec_wall_us=526_315.0,
                                   vec_steps_per_s=198_819.8)])
    assert _check(base, out) == 1
    _write(out, [_fpe_row()], [_dp_row()], [_sim_row(), _lossy_row()])
    assert _check(base, out) == 0


def test_repo_sim_baseline_carries_the_floor_cells():
    # the checked-in sim baseline must keep every gated floor cell: losing
    # one (coverage shrink) must fail, not silently stop gating it
    repo = pathlib.Path(__file__).resolve().parents[1]
    rows = gate._load_rows(repo / "benchmarks" / "baselines"
                           / "BENCH_sim.json")
    floors = {r["cell"]: r["speedup_floor"] for r in rows
              if "speedup_floor" in r}
    assert floors == {"fat16_tor": 50.0, "fat64_lossy": 20.0,
                      "multijob": 8.0}
    obs = [r for r in rows if r["cell"] == "obs_overhead"]
    assert len(obs) == 1 and obs[0]["off_on_floor"] == 0.5
    assert obs[0]["vs_base_floor"] == 0.7


def _obs_row(**kw):
    row = {"cell": "obs_overhead", "pods": 16, "n_mappers": 2048,
           "records": 131072, "records_per_packet": 4,
           "policy": "tor_only", "loss_rate": 0.0, "switch_steps": 237220,
           "obs_off_wall_us": 120_000.0, "obs_on_wall_us": 125_000.0,
           "obs_off_steps_per_s": 1_976_833.3,
           "obs_on_steps_per_s": 1_897_760.0,
           "off_on_ratio": 0.96, "vs_base_ratio": 0.98,
           "off_on_floor": 0.5, "vs_base_floor": 0.7, "parity": 1.0}
    row.update(kw)
    return row


def test_obs_overhead_ratio_below_floor_fails(dirs):
    # the observability tax bar: enabled-mode throughput collapsing to
    # 40% of disabled-mode fails the absolute floor, whatever the
    # baseline said
    base, out = dirs
    _write(base, [_fpe_row()], [_dp_row()], [_sim_row(), _obs_row()])
    _write(out, [_fpe_row()], [_dp_row()],
           [_sim_row(), _obs_row(off_on_ratio=0.4)])
    assert _check(base, out) == 1
    # ... and the no-op-path bar: the tracer-disabled leg falling to 60%
    # of the fat16 base means "disabled" is no longer free
    _write(out, [_fpe_row()], [_dp_row()],
           [_sim_row(), _obs_row(vs_base_ratio=0.6)])
    assert _check(base, out) == 1
    _write(out, [_fpe_row()], [_dp_row()], [_sim_row(), _obs_row()])
    assert _check(base, out) == 0


def test_obs_overhead_ratios_skip_the_throughput_geomean(dirs):
    # the obs cell's legs are in-process ratios, not machine throughput:
    # they must not join (and so cannot rescue or sink) the geomean
    base, out = dirs
    _write(base, [_fpe_row()], [_dp_row()], [_sim_row(), _obs_row()])
    metrics = gate.sim_metrics([_sim_row(), _obs_row()])
    kinds = {k: v[1] for k, v in metrics.items()}
    assert kinds["sim:obs_overhead:off_on_ratio"] == "floor:0.5"
    assert kinds["sim:obs_overhead:vs_base_ratio"] == "floor:0.7"
    assert "sim:obs_overhead:node_steps_per_s" not in metrics
    assert not any(v[1] == "throughput" and "obs_overhead" in k
                   for k, v in metrics.items())


# -- the schema gate (DESIGN.md §11) ----------------------------------------

def test_schema_gate_fails_when_a_row_stops_emitting_a_metric(dirs):
    base, out = dirs
    row = _sim_row()
    del row["vec_steps_per_s"]  # a registered metric's source field
    _write(out, [_fpe_row()], [_dp_row()], [row])
    assert _check(base, out) == 1


def test_schema_gate_names_the_missing_fields():
    row = _fpe_row()
    del row["fast_pairs_per_s"]
    del row["scan_pairs_per_s"]
    fails = gate.schema_failures("BENCH_fpe.json", [row])
    assert len(fails) == 1
    assert "fast_pairs_per_s" in fails[0]
    assert "scan_pairs_per_s" in fails[0]
    assert gate.schema_failures("BENCH_fpe.json", [_fpe_row()]) == []


def test_schema_gate_knows_the_obs_row_shape():
    # the obs_overhead row legitimately has no node/vec legs — its own
    # schema wants the ratio fields instead
    assert gate.schema_failures("BENCH_sim.json",
                                [_sim_row(), _obs_row()]) == []
    row = _obs_row()
    del row["off_on_ratio"]
    fails = gate.schema_failures("BENCH_sim.json", [_sim_row(), row])
    assert len(fails) == 1 and "off_on_ratio" in fails[0]


def test_repo_baseline_rows_pass_the_schema_gate():
    # every checked-in baseline row still emits its registered metrics
    repo = pathlib.Path(__file__).resolve().parents[1]
    for fname in gate.GATED:
        rows = gate._load_rows(repo / "benchmarks" / "baselines" / fname)
        assert gate.schema_failures(fname, rows) == []
