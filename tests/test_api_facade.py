"""The unified public API (DESIGN.md §13): ``repro.net.simulate`` and
``repro.core.plan``.

Two contracts under test.  First, *shim equivalence*: every one of the
seven legacy ``net.sim`` entry points must emit a ``DeprecationWarning``
and return a result bit-identical to the facade's, on both engines — a
shim that drifts from the front door it points at would make the
deprecation a silent behavior change.  Second, the facade's own argument
discipline: dispatch rejects shapes it cannot route, ``admissions=`` is
batch-only, and config validation (engine names, loss rates, fanins)
raises at construction, before any simulation state exists.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import dataplane, planner
from repro.core import reduction_model as rm
from repro.net import sim as netsim
from repro.net import simulate
from repro.runtime.fault_tolerance import FailureEvent, FailureInjector

ENGINES = ("node", "vectorized")


def _job(seed=0, n=240, variety=32):
    keys = rm.zipf_keys(n, variety, seed=seed).astype(np.int32)
    vals = np.random.default_rng(seed).standard_normal(n).astype(np.float32)
    return keys, vals


def _plan(caps, op="sum"):
    return dataplane.CascadePlan(op=op, levels=tuple(
        dataplane.LevelSpec(capacity=c) for c in caps))


def _cfg(engine, **kw):
    return netsim.NetConfig(records_per_packet=16, engine=engine, **kw)


def _identical(a, b):
    assert a.report() == b.report()
    assert a.delivered_table() == b.delivered_table()
    assert a.jct_s == b.jct_s


# ---------------------------------------------------------------------------
# Shim equivalence: every legacy name warns AND matches the facade exactly.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_shim_simulate_job(engine):
    keys, vals = _job()
    kw = dict(fanins=(2, 2), plan=_plan([32, 16]), cfg=_cfg(engine))
    with pytest.warns(DeprecationWarning, match="use repro.net.simulate"):
        old = netsim.simulate_job(keys, vals, **kw)
    new = simulate(netsim.JobSpec(keys=keys, values=vals, **kw))
    _identical(old, new)


@pytest.mark.parametrize("engine", ENGINES)
def test_shim_simulate_jobs(engine):
    keys, vals = _job()
    specs = [netsim.JobSpec(keys=keys, values=vals, fanins=(2, 2),
                            plan=_plan([32, 16]), cfg=_cfg(engine),
                            job_id=j) for j in range(2)]
    with pytest.warns(DeprecationWarning, match="use repro.net.simulate"):
        old = netsim.simulate_jobs(specs)
    new = simulate(specs)
    for o, n in zip(old, new):
        _identical(o, n)


def _admitted_plan():
    topo = planner.Topology(links=(
        planner.LinkBudget(axis="data", fanin=4, gbps=netsim.TEN_GBE),
        planner.LinkBudget(axis="pod", fanin=2, gbps=netsim.TEN_GBE / 4)))
    sched = planner.JobScheduler(topo, combiner_budget_pairs=256)
    return sched.admit(planner.LaunchRequest(
        job_id=1, n_workers=8, expected_pairs=64, key_variety=32,
        grad_bytes=1 << 18))


@pytest.mark.parametrize("engine", ENGINES)
def test_shim_simulate_job_plan(engine):
    jp = _admitted_plan()
    keys, vals = _job(n=8 * 64)
    with pytest.warns(DeprecationWarning, match="use repro.net.simulate"):
        old = netsim.simulate_job_plan(jp, keys, vals, cfg=_cfg(engine))
    new = simulate(jp, keys, vals, cfg=_cfg(engine))
    _identical(old, new)


@pytest.mark.parametrize("engine", ENGINES)
def test_shim_simulate_job_plans(engine):
    jp = _admitted_plan()
    keys, vals = _job(n=8 * 64)
    with pytest.warns(DeprecationWarning, match="use repro.net.simulate"):
        old = netsim.simulate_job_plans([jp], [keys], [vals],
                                        cfg=_cfg(engine))
    new = simulate([jp], [keys], [vals], cfg=_cfg(engine))
    for o, n in zip(old, new):
        _identical(o, n)


@pytest.mark.parametrize("engine", ENGINES)
def test_shim_simulate_job_with_faults(engine):
    keys, vals = _job()
    inj = FailureInjector({}, events=(FailureEvent(
        kind="switch_crash", t_s=1e-6, level=0, switch=1),))
    kw = dict(fanins=(4, 2), cfg=_cfg(engine))
    with pytest.warns(DeprecationWarning, match="use repro.net.simulate"):
        old = netsim.simulate_job_with_faults(keys, vals, injector=inj, **kw)
    new = simulate(netsim.JobSpec(keys=keys, values=vals, **kw), faults=inj)
    assert old.delivered_table() == new.delivered_table()
    assert old.jct_s == new.jct_s and old.epochs == new.epochs


def _small_ft():
    return planner.FatTreeTopology(pods=2, tors_per_pod=2, hosts_per_tor=4,
                                   oversubscription=2.0, table_pairs=256)


@pytest.mark.parametrize("engine", ENGINES)
def test_shim_simulate_fat_tree_job(engine):
    ft = _small_ft()
    keys, vals = _job(n=ft.n_hosts * 16, variety=64)
    with pytest.warns(DeprecationWarning, match="use repro.net.simulate"):
        old = netsim.simulate_fat_tree_job(ft, keys, vals, policy="full",
                                           cfg=_cfg(engine))
    new = simulate(ft, keys, vals, policy="full", cfg=_cfg(engine))
    _identical(old, new)


@pytest.mark.parametrize("engine", ENGINES)
def test_shim_simulate_fat_tree_job_with_faults(engine):
    ft = _small_ft()
    keys, vals = _job(n=ft.n_hosts * 16, variety=64)
    inj = FailureInjector({}, events=(FailureEvent(
        kind="switch_crash", t_s=1e-6, level=0, switch=1),))
    with pytest.warns(DeprecationWarning, match="use repro.net.simulate"):
        old = netsim.simulate_fat_tree_job_with_faults(
            ft, keys, vals, injector=inj, policy="full", cfg=_cfg(engine))
    new = simulate(ft, keys, vals, faults=inj, policy="full",
                   cfg=_cfg(engine))
    assert old.delivered_table() == new.delivered_table()
    assert old.jct_s == new.jct_s and old.epochs == new.epochs


def test_engine_kwarg_overrides_without_rebuilding_cfg():
    """``engine=`` rides on top of whatever cfg the caller holds."""
    keys, vals = _job()
    spec = netsim.JobSpec(keys=keys, values=vals, fanins=(2, 2),
                          plan=_plan([32, 16]), cfg=_cfg("node"))
    rn = simulate(spec)
    rv = simulate(spec, engine="vectorized")
    _identical(rn, rv)


# ---------------------------------------------------------------------------
# Facade argument discipline.
# ---------------------------------------------------------------------------


def test_dispatch_rejects_unroutable_shapes():
    keys, vals = _job()
    with pytest.raises(TypeError, match="cannot dispatch"):
        simulate({"not": "a spec"})
    with pytest.raises(TypeError, match="all JobSpec or all JobPlan"):
        simulate([netsim.JobSpec(keys=keys, values=vals, fanins=(2,)),
                  "nope"])
    # a JobSpec carries its own stream — positional keys/values conflict
    with pytest.raises(TypeError, match="carries its own"):
        simulate(netsim.JobSpec(keys=keys, values=vals, fanins=(2,)),
                 keys, vals)
    # plan/fat-tree forms need the stream
    with pytest.raises(TypeError, match="needs\\s+the mapper stream"):
        simulate(_admitted_plan())
    with pytest.raises(TypeError, match="needs\\s+the mapper stream"):
        simulate(_small_ft())


def test_admissions_is_batch_only():
    keys, vals = _job()
    spec = netsim.JobSpec(keys=keys, values=vals, fanins=(2, 2))
    with pytest.raises(TypeError, match="admissions"):
        simulate(spec, admissions=[(1, spec)])
    # and faults are per-job, never per-batch
    inj = FailureInjector({}, events=())
    with pytest.raises(ValueError, match="faults= is per-job"):
        simulate([spec, spec], faults=inj)


def test_mid_run_admission_joins_lockstep_and_keeps_parity():
    """A job admitted mid-run finishes with the same result as running
    alone (jobs never interact), on both engines."""
    keys, vals = _job()
    runs = {}
    for engine in ENGINES:
        base = netsim.JobSpec(keys=keys, values=vals, fanins=(2, 2),
                              plan=_plan([32, 16]), cfg=_cfg(engine))
        late = dataclasses.replace(base, job_id=7, tag="late")
        got = simulate([base], admissions=[(2, late)])
        assert len(got) == 2
        solo = simulate(late)
        _identical(got[1], solo)
        runs[engine] = got
    for a, b in zip(runs["node"], runs["vectorized"]):
        _identical(a, b)


def test_config_validation_raises_at_construction():
    with pytest.raises(ValueError, match="unknown sim engine"):
        netsim.NetConfig(engine="warp_drive")
    with pytest.raises(ValueError, match="loss_rate"):
        netsim.NetConfig(loss_rate=1.0)
    with pytest.raises(ValueError, match="loss_rate"):
        netsim.NetConfig(loss_rate=-0.1)
    keys, vals = _job()
    with pytest.raises(ValueError, match="positive mapper"):
        netsim.JobSpec(keys=keys, values=vals, fanins=(0, 2))
    with pytest.raises(ValueError, match="positive mapper"):
        netsim.JobSpec(keys=keys, values=vals, fanins=())
