"""Structural cost model sanity: physical ranges + regime classification."""

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import shape_by_name
from repro.launch import hlo_analysis as ha
from repro.launch import profiles
from repro.launch.structural import structural_cost


class MeshLike:
    axis_names = ("data", "model")

    class devices:
        shape = (16, 16)
        size = 256


@pytest.mark.parametrize("arch", list(configs.ARCH_IDS))
def test_train_costs_physical(arch):
    shape = shape_by_name("train_4k")
    mesh = MeshLike()
    prof = profiles.make_profile(arch, shape, mesh)
    c = structural_cost(configs.get_config(arch), shape, mesh, prof)
    assert c.flops > 0 and c.bytes > 0
    cfg = configs.get_config(arch)
    model = ha.model_flops_for(cfg, shape) / 256
    useful = model / c.flops
    # executed >= useful (remat/attention/dispatch overhead), but within 3x
    assert 0.30 <= useful <= 1.05, (arch, useful)
    # memory traffic physically sane: bounded by ~4x the param-read streams
    # (3 reads x accum) plus a 64 GB activations/optimizer allowance
    param_stream = 3 * prof.accum_steps * cfg.param_count() / 16 * 2
    assert c.bytes < 4 * param_stream + 64e9, (arch, c.bytes, param_stream)


def test_decode_dominated_by_cache_or_params():
    shape = shape_by_name("decode_32k")
    mesh = MeshLike()
    for arch in ("gemma2-27b", "mamba2-780m"):
        prof = profiles.make_profile(arch, shape, mesh)
        c = structural_cost(configs.get_config(arch), shape, mesh, prof)
        d = dict(c.detail)
        mem_heavy = d.get("kv_cache", (0, 0))[1] + d.get("param_reads", (0, 0))[1]
        assert mem_heavy > 0.8 * c.bytes, d


def test_local_attention_cheaper_than_global():
    """gemma2 local layers must score fewer flops than full-context ones."""
    import dataclasses

    shape = shape_by_name("prefill_32k")
    mesh = MeshLike()
    cfg = configs.get_config("gemma2-27b")
    prof = profiles.make_profile("gemma2-27b", shape, mesh)
    with_window = structural_cost(cfg, shape, mesh, prof)
    no_window = structural_cost(dataclasses.replace(cfg, window=0), shape, mesh, prof)
    assert with_window.detail["attn_scores"][0] < no_window.detail["attn_scores"][0]


def test_fsdp_reduces_resident_not_traffic():
    import dataclasses

    shape = shape_by_name("train_4k")
    mesh = MeshLike()
    prof = profiles.make_profile("deepseek-v2-236b", shape, mesh)
    assert prof.fsdp
    c = structural_cost(configs.get_config("deepseek-v2-236b"), shape, mesh, prof)
    # param reads stay ~(2-3 x accum) x params/tp regardless of FSDP storage
    pr = c.detail["param_reads"][1]
    cfg = configs.get_config("deepseek-v2-236b")
    per_read = cfg.param_count() / 16 * 2
    assert pr == pytest.approx(3 * prof.accum_steps * per_read, rel=0.1)
