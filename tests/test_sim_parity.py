"""Differential parity: vectorized tier engine vs the node oracle
(DESIGN.md §10).

The vectorized engine is only allowed to exist because these tests pin it
to the node engine: at loss=0 every report field — delivered per-key
tables, per-tier byte telemetry, JCT, mapper finish times — must be
EXACTLY equal (``==`` on floats, not allclose) for every registered
AggOp, every placement shape, and the host-only baseline.  Under seeded
loss the engine falls back to the precompute+replay path, which must keep
the transport suite's exactly-once property and still agree with the node
engine bit for bit.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import dict_aggregate
from repro.core import aggops, dataplane, kvagg, planner
from repro.core import reduction_model as rm
from repro.net import sim as netsim

EMPTY = int(kvagg.EMPTY_KEY)


def _plan(caps, op="sum", enabled=None, bpe=True):
    en = enabled if enabled is not None else [True] * len(caps)
    return dataplane.CascadePlan(op=op, levels=tuple(
        dataplane.LevelSpec(capacity=c, enabled=e, bpe=bpe)
        for c, e in zip(caps, en)))


def _both(keys, vals, *, cfg=None, **kw):
    """Run the same job on both engines; return (node, vectorized)."""
    cfg = cfg or netsim.NetConfig(records_per_packet=16)
    rn = netsim.simulate_job(keys, vals, cfg=cfg, **kw)
    rv = netsim.simulate_job(
        keys, vals, cfg=dataclasses.replace(cfg, engine="vectorized"), **kw)
    return rn, rv


def _assert_identical(rn, rv):
    """The full parity contract: every observable is exactly equal."""
    assert rv.report() == rn.report()  # per-tier bytes/proc/queue included
    assert rv.delivered_table() == rn.delivered_table()  # bit-identical
    assert rv.jct_s == rn.jct_s
    assert rv.mapper_finish_s == rn.mapper_finish_s
    assert rv.retransmissions == rn.retransmissions
    assert rv.packets_dropped == rn.packets_dropped


@pytest.mark.parametrize("op", sorted(aggops.names()))
def test_lossless_bitwise_parity_every_op(op):
    """loss=0: tables and per-tier byte telemetry exactly equal for every
    registered AggOp, on both the exact-stream and sorted-batch paths."""
    keys = rm.zipf_keys(600, 64, seed=2).astype(np.int32)
    vals = np.random.default_rng(0).standard_normal(600).astype(np.float32)
    for es in (True, False):
        cfg = netsim.NetConfig(records_per_packet=16, exact_stream=es)
        rn, rv = _both(keys, vals, fanins=(2, 2),
                       plan=_plan([32, 16], op=op), cfg=cfg)
        _assert_identical(rn, rv)
    # and the delivered table is still the true grouped result
    want = dict_aggregate(keys, vals, op)
    got = rv.delivered_table()
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("enabled", [
    [True, True], [False, True], [True, False], [False, False]])
def test_lossless_parity_disabled_hops_and_host_only(enabled):
    """Placement-disabled (forward-only) hops and the aggregate=False
    baseline run through the same fast path: still exactly equal."""
    keys = rm.zipf_keys(500, 48, seed=5).astype(np.int32)
    vals = np.ones_like(keys, np.float32)
    rn, rv = _both(keys, vals, fanins=(2, 2),
                   plan=_plan([32, 16], enabled=enabled))
    _assert_identical(rn, rv)
    rn, rv = _both(keys, vals, fanins=(2, 2), plan=_plan([32, 16]),
                   aggregate=False)
    _assert_identical(rn, rv)


def test_fat_tree_parity_and_jct_ordering():
    """The rack-scale entry point: per-policy parity, and the vectorized
    engine preserves the §9 acceptance ordering full <= tor <= host."""
    ft = planner.FatTreeTopology(pods=4, tors_per_pod=2, hosts_per_tor=4,
                                 oversubscription=4.0, table_pairs=256)
    n = ft.n_hosts * 48
    keys = rm.zipf_keys(n, 256, skew=0.99, seed=1).astype(np.int32)
    vals = np.ones((n,), np.float32)
    cfg = netsim.NetConfig(records_per_packet=16, exact_stream=True)
    jct = {}
    for pol in ("host_only", "tor_only", "full"):
        pl = planner.place_aggregation_tree(ft, per_host_pairs=48,
                                            key_variety=256, policy=pol)
        rn = netsim.simulate_fat_tree_job(ft, keys, vals, placement=pl,
                                          cfg=cfg)
        rv = netsim.simulate_fat_tree_job(
            ft, keys, vals, placement=pl,
            cfg=dataclasses.replace(cfg, engine="vectorized"))
        _assert_identical(rn, rv)
        jct[pol] = rv.jct_s
    assert jct["full"] <= jct["tor_only"] <= jct["host_only"]


def test_scheduler_plan_and_jct_comparison_thread_the_engine():
    """simulate_job_plan / jct_comparison accept the engine switch and
    agree with the node oracle."""
    topo = planner.Topology(links=(
        planner.LinkBudget(axis="data", fanin=4, gbps=netsim.TEN_GBE),
        planner.LinkBudget(axis="pod", fanin=2, gbps=netsim.TEN_GBE / 4)))
    sched = planner.JobScheduler(topo, combiner_budget_pairs=256)
    jp = sched.admit(planner.LaunchRequest(
        job_id=1, n_workers=8, expected_pairs=256, key_variety=64,
        grad_bytes=1 << 20))
    keys = rm.zipf_keys(8 * 256, 64, seed=5).astype(np.int32)
    vals = np.ones_like(keys, np.float32)
    rn = netsim.simulate_job_plan(jp, keys, vals)
    rv = netsim.simulate_job_plan(
        jp, keys, vals, cfg=netsim.NetConfig(engine="vectorized"))
    _assert_identical(rn, rv)
    jn = netsim.jct_comparison(keys, vals, fanins=(2, 2),
                               plan=_plan([32, 16]))
    jv = netsim.jct_comparison(keys, vals, fanins=(2, 2),
                               plan=_plan([32, 16]),
                               cfg=netsim.NetConfig(engine="vectorized"))
    assert jv["jct_switchagg_s"] == jn["jct_switchagg_s"]
    assert jv["jct_host_only_s"] == jn["jct_host_only_s"]
    assert jv["jct_saved"] == jn["jct_saved"]


# --- exactly-once under loss (hypothesis; mirrors test_net_transport) ----
# only this property skips when the dev-only hypothesis dep is absent; the
# deterministic parity tests above must run everywhere

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="dev-only dep: pip install -r requirements-dev.txt")

if HAVE_HYPOTHESIS:
    def _loss_property(f):
        return settings(max_examples=25, deadline=None)(given(
            n=st.integers(1, 160),
            variety=st.integers(1, 32),
            loss_rate=st.floats(0.0, 0.6),
            seed=st.integers(0, 2**31 - 1),
            op=st.sampled_from(sorted(aggops.names())))(f))
else:
    def _loss_property(f):
        def stub():  # collected, skipped by needs_hypothesis
            raise AssertionError("unreachable")
        return stub

# the transport suite's geometry: hypothesis explores the LOSS space
_CFG = netsim.NetConfig(records_per_packet=16, window=4)
_CAPS = (16, 8)
_FANINS = (2, 2)


@needs_hypothesis
@_loss_property
def test_property_vectorized_exactly_once_under_any_loss(
        n, variety, loss_rate, seed, op):
    """Whatever the loss pattern, the vectorized engine delivers every
    record exactly once AND matches the node engine exactly."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, variety, size=n).astype(np.int32)
    vals = rng.standard_normal(n).astype(np.float32)
    plan = _plan(list(_CAPS), op=op)
    cfg = dataclasses.replace(_CFG, loss_rate=loss_rate, seed=seed,
                              engine="vectorized")
    res = netsim.simulate_job(keys, vals, fanins=_FANINS, plan=plan, cfg=cfg)
    ref = dataplane.run_cascade(jnp.asarray(keys), jnp.asarray(vals), plan)
    want = {int(k): np.asarray(v) for k, v in
            zip(np.asarray(ref.keys), np.asarray(ref.values)) if k != EMPTY}
    got = dict(zip(res.delivered_keys.tolist(), res.delivered_values))
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-3, atol=1e-4,
                                   err_msg=f"op={op} key={k} loss={loss_rate}")
    if loss_rate == 0.0:
        assert res.packets_dropped == 0 and res.retransmissions == 0
    assert res.retransmissions >= res.packets_dropped
    # differential: the engines agree packet for packet
    node = netsim.simulate_job(
        keys, vals, fanins=_FANINS, plan=plan,
        cfg=dataclasses.replace(cfg, engine="node"))
    _assert_identical(node, res)
