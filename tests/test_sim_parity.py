"""Differential parity: vectorized tier engine vs the node oracle
(DESIGN.md §10).

The vectorized engine is only allowed to exist because these tests pin it
to the node engine: at loss=0 every report field — delivered per-key
tables, per-tier byte telemetry, JCT, mapper finish times — must be
EXACTLY equal (``==`` on floats, not allclose) for every registered
AggOp, every placement shape, and the host-only baseline.  Under loss the
vectorized go-back-N window algebra must reproduce the node sender's
transport schedule exactly — same drops, same retransmit telemetry, same
JCT — and keep the transport suite's exactly-once property for arbitrary
drop masks, not just uniform draws.  Multi-job batches must be
bit-identical to running each job alone while collapsing same-signature
tiers into one kernel dispatch.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import dict_aggregate
from repro.core import aggops, dataplane, kvagg, planner
from repro.core import reduction_model as rm
from repro.net import sim as netsim
from repro.net import simulate

EMPTY = int(kvagg.EMPTY_KEY)


def _plan(caps, op="sum", enabled=None, bpe=True):
    en = enabled if enabled is not None else [True] * len(caps)
    return dataplane.CascadePlan(op=op, levels=tuple(
        dataplane.LevelSpec(capacity=c, enabled=e, bpe=bpe)
        for c, e in zip(caps, en)))


def _sim(keys, vals, **kw):
    return simulate(netsim.JobSpec(keys=keys, values=vals, **kw))


def _both(keys, vals, *, cfg=None, **kw):
    """Run the same job on both engines; return (node, vectorized)."""
    cfg = cfg or netsim.NetConfig(records_per_packet=16)
    rn = _sim(keys, vals, cfg=cfg, **kw)
    rv = _sim(keys, vals,
              cfg=dataclasses.replace(cfg, engine="vectorized"), **kw)
    return rn, rv


def _assert_identical(rn, rv):
    """The full parity contract: every observable is exactly equal."""
    assert rv.report() == rn.report()  # per-tier bytes/proc/queue included
    assert rv.delivered_table() == rn.delivered_table()  # bit-identical
    assert rv.jct_s == rn.jct_s
    assert rv.mapper_finish_s == rn.mapper_finish_s
    assert rv.retransmissions == rn.retransmissions
    assert rv.packets_dropped == rn.packets_dropped


@pytest.mark.parametrize("op", sorted(aggops.names()))
def test_lossless_bitwise_parity_every_op(op):
    """loss=0: tables and per-tier byte telemetry exactly equal for every
    registered AggOp, on both the exact-stream and sorted-batch paths."""
    keys = rm.zipf_keys(600, 64, seed=2).astype(np.int32)
    vals = np.random.default_rng(0).standard_normal(600).astype(np.float32)
    for es in (True, False):
        cfg = netsim.NetConfig(records_per_packet=16, exact_stream=es)
        rn, rv = _both(keys, vals, fanins=(2, 2),
                       plan=_plan([32, 16], op=op), cfg=cfg)
        _assert_identical(rn, rv)
    # and the delivered table is still the true grouped result
    want = dict_aggregate(keys, vals, op)
    got = rv.delivered_table()
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("enabled", [
    [True, True], [False, True], [True, False], [False, False]])
def test_lossless_parity_disabled_hops_and_host_only(enabled):
    """Placement-disabled (forward-only) hops and the aggregate=False
    baseline run through the same fast path: still exactly equal."""
    keys = rm.zipf_keys(500, 48, seed=5).astype(np.int32)
    vals = np.ones_like(keys, np.float32)
    rn, rv = _both(keys, vals, fanins=(2, 2),
                   plan=_plan([32, 16], enabled=enabled))
    _assert_identical(rn, rv)
    rn, rv = _both(keys, vals, fanins=(2, 2), plan=_plan([32, 16]),
                   aggregate=False)
    _assert_identical(rn, rv)


def test_fat_tree_parity_and_jct_ordering():
    """The rack-scale entry point: per-policy parity, and the vectorized
    engine preserves the §9 acceptance ordering full <= tor <= host."""
    ft = planner.FatTreeTopology(pods=4, tors_per_pod=2, hosts_per_tor=4,
                                 oversubscription=4.0, table_pairs=256)
    n = ft.n_hosts * 48
    keys = rm.zipf_keys(n, 256, skew=0.99, seed=1).astype(np.int32)
    vals = np.ones((n,), np.float32)
    cfg = netsim.NetConfig(records_per_packet=16, exact_stream=True)
    jct = {}
    for pol in ("host_only", "tor_only", "full"):
        pl = planner.place_aggregation_tree(ft, per_host_pairs=48,
                                            key_variety=256, policy=pol)
        rn = simulate(ft, keys, vals, placement=pl, cfg=cfg)
        rv = simulate(ft, keys, vals, placement=pl,
                      cfg=dataclasses.replace(cfg, engine="vectorized"))
        _assert_identical(rn, rv)
        jct[pol] = rv.jct_s
    assert jct["full"] <= jct["tor_only"] <= jct["host_only"]


def test_scheduler_plan_and_jct_comparison_thread_the_engine():
    """planned-job simulate / jct_comparison accept the engine switch and
    agree with the node oracle."""
    topo = planner.Topology(links=(
        planner.LinkBudget(axis="data", fanin=4, gbps=netsim.TEN_GBE),
        planner.LinkBudget(axis="pod", fanin=2, gbps=netsim.TEN_GBE / 4)))
    sched = planner.JobScheduler(topo, combiner_budget_pairs=256)
    jp = sched.admit(planner.LaunchRequest(
        job_id=1, n_workers=8, expected_pairs=256, key_variety=64,
        grad_bytes=1 << 20))
    keys = rm.zipf_keys(8 * 256, 64, seed=5).astype(np.int32)
    vals = np.ones_like(keys, np.float32)
    rn = simulate(jp, keys, vals)
    rv = simulate(jp, keys, vals, cfg=netsim.NetConfig(engine="vectorized"))
    _assert_identical(rn, rv)
    jn = netsim.jct_comparison(keys, vals, fanins=(2, 2),
                               plan=_plan([32, 16]))
    jv = netsim.jct_comparison(keys, vals, fanins=(2, 2),
                               plan=_plan([32, 16]),
                               cfg=netsim.NetConfig(engine="vectorized"))
    assert jv["jct_switchagg_s"] == jn["jct_switchagg_s"]
    assert jv["jct_host_only_s"] == jn["jct_host_only_s"]
    assert jv["jct_saved"] == jn["jct_saved"]


# --- exactly-once under loss (hypothesis; mirrors test_net_transport) ----
# only this property skips when the dev-only hypothesis dep is absent; the
# deterministic parity tests above must run everywhere

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="dev-only dep: pip install -r requirements-dev.txt")

if HAVE_HYPOTHESIS:
    def _loss_property(f):
        return settings(max_examples=25, deadline=None)(given(
            n=st.integers(1, 160),
            variety=st.integers(1, 32),
            loss_rate=st.floats(0.0, 0.6),
            seed=st.integers(0, 2**31 - 1),
            op=st.sampled_from(sorted(aggops.names())))(f))
else:
    def _loss_property(f):
        def stub():  # collected, skipped by needs_hypothesis
            raise AssertionError("unreachable")
        return stub

# the transport suite's geometry: hypothesis explores the LOSS space
_CFG = netsim.NetConfig(records_per_packet=16, window=4)
_CAPS = (16, 8)
_FANINS = (2, 2)


@needs_hypothesis
@_loss_property
def test_property_vectorized_exactly_once_under_any_loss(
        n, variety, loss_rate, seed, op):
    """Whatever the loss pattern, the vectorized engine delivers every
    record exactly once AND matches the node engine exactly."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, variety, size=n).astype(np.int32)
    vals = rng.standard_normal(n).astype(np.float32)
    plan = _plan(list(_CAPS), op=op)
    cfg = dataclasses.replace(_CFG, loss_rate=loss_rate, seed=seed,
                              engine="vectorized")
    res = _sim(keys, vals, fanins=_FANINS, plan=plan, cfg=cfg)
    ref = dataplane.run_cascade(jnp.asarray(keys), jnp.asarray(vals), plan)
    want = {int(k): np.asarray(v) for k, v in
            zip(np.asarray(ref.keys), np.asarray(ref.values)) if k != EMPTY}
    got = dict(zip(res.delivered_keys.tolist(), res.delivered_values))
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-3, atol=1e-4,
                                   err_msg=f"op={op} key={k} loss={loss_rate}")
    if loss_rate == 0.0:
        assert res.packets_dropped == 0 and res.retransmissions == 0
    assert res.retransmissions >= res.packets_dropped
    # differential: the engines agree packet for packet
    node = _sim(keys, vals, fanins=_FANINS, plan=plan,
                cfg=dataclasses.replace(cfg, engine="node"))
    _assert_identical(node, res)


# --- lossy parity: the vectorized window algebra vs the node sender -----
# (DESIGN.md §10: go-back-N as padded arrays stepped per tier)

_LOSS_RATES = (0.005, 0.02, 0.10)


@pytest.mark.parametrize("op", sorted(aggops.names()))
def test_lossy_bitwise_parity_every_op(op):
    """loss > 0: the vectorized go-back-N sender produces the exact same
    reports, delivered tables, JCTs and retransmit telemetry as the
    per-packet node oracle — for every AggOp, on both FPE paths, at
    0.5% / 2% / 10% loss."""
    keys = rm.zipf_keys(600, 64, seed=2).astype(np.int32)
    vals = np.random.default_rng(0).standard_normal(600).astype(np.float32)
    saw_retx = False
    for loss in _LOSS_RATES:
        for es in (True, False):
            cfg = netsim.NetConfig(records_per_packet=16, exact_stream=es,
                                   loss_rate=loss, seed=7, window=8)
            rn, rv = _both(keys, vals, fanins=(2, 2),
                           plan=_plan([32, 16], op=op), cfg=cfg)
            _assert_identical(rn, rv)
            assert rv.duplicate_discards == 0  # go-back-N never rewinds
            saw_retx = saw_retx or rv.retransmissions > 0
    assert saw_retx  # the sweep actually exercised the lossy path
    # and loss never corrupts the aggregate
    want = dict_aggregate(keys, vals, op)
    got = rv.delivered_table()
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("loss", _LOSS_RATES)
def test_lossy_parity_disabled_hops_and_host_only(loss):
    """Forward-only hops and the aggregate=False baseline run the same
    vectorized lossy transport: still exactly equal to the node engine."""
    keys = rm.zipf_keys(500, 48, seed=5).astype(np.int32)
    vals = np.ones_like(keys, np.float32)
    cfg = netsim.NetConfig(records_per_packet=16, loss_rate=loss, seed=11,
                           window=8)
    rn, rv = _both(keys, vals, fanins=(2, 2),
                   plan=_plan([32, 16], enabled=[False, True]), cfg=cfg)
    _assert_identical(rn, rv)
    rn, rv = _both(keys, vals, fanins=(2, 2), plan=_plan([32, 16]),
                   aggregate=False, cfg=cfg)
    _assert_identical(rn, rv)


def test_lossy_fat_tree_parity():
    """The rack-scale entry point under loss: every placement policy stays
    bit-identical between engines (one lockstep batch each)."""
    ft = planner.FatTreeTopology(pods=2, tors_per_pod=2, hosts_per_tor=4,
                                 oversubscription=4.0, table_pairs=256)
    n = ft.n_hosts * 32
    keys = rm.zipf_keys(n, 128, skew=0.99, seed=3).astype(np.int32)
    vals = np.ones((n,), np.float32)
    cfg = netsim.NetConfig(records_per_packet=16, loss_rate=0.02, seed=4,
                           window=8)
    cn = netsim.fat_tree_jct_comparison(ft, keys, vals, per_host_pairs=32,
                                        key_variety=128, cfg=cfg)
    cv = netsim.fat_tree_jct_comparison(
        ft, keys, vals, per_host_pairs=32, key_variety=128,
        cfg=dataclasses.replace(cfg, engine="vectorized"))
    for pol in cn["policies"]:
        _assert_identical(cn["_results"][pol], cv["_results"][pol])
        assert cv["jct_s"][pol] == cn["jct_s"][pol]


# --- arbitrary loss masks (hypothesis): exactly-once beyond uniform ------

from repro.core import planner as _planner  # noqa: E402  (already imported)
from repro.net import transport, vsim  # noqa: E402


class _MaskLoss(transport.LossModel):
    """Adversarial LossModel: drops exactly the (flow, psn, attempt)
    triples in an explicit set — hypothesis explores loss *patterns* the
    uniform hash never concentrates, e.g. every first attempt of one flow.
    ``rate`` is a >0 placeholder so the lossy transport path engages;
    ``drop``/``drop_array`` are overridden elementwise-consistently, the
    subclass contract in ``transport.LossModel``.
    """

    def __init__(self, mask):
        super().__init__(rate=0.5, seed=0)
        self.mask = frozenset(mask)

    def drop(self, flow_id, psn, attempt):
        return (int(flow_id), int(psn), int(attempt)) in self.mask

    def drop_array(self, flow_ids, psns, attempts):
        f, p, a = np.broadcast_arrays(np.asarray(flow_ids),
                                      np.asarray(psns), np.asarray(attempts))
        out = np.zeros(f.shape, bool)
        for idx in np.ndindex(f.shape):
            out[idx] = (int(f[idx]), int(p[idx]), int(a[idx])) in self.mask
        return out


if HAVE_HYPOTHESIS:
    def _mask_property(f):
        # attempts capped at 3 so every flow eventually gets through
        return settings(max_examples=20, deadline=None)(given(
            mask=st.sets(st.tuples(st.integers(0, 40), st.integers(0, 23),
                                   st.integers(1, 3)), max_size=80),
            seed=st.integers(0, 2**31 - 1),
            op=st.sampled_from(sorted(aggops.names())))(f))
else:
    def _mask_property(f):
        def stub():  # collected, skipped by needs_hypothesis
            raise AssertionError("unreachable")
        return stub


@needs_hypothesis
@_mask_property
def test_property_mask_loss_exactly_once_and_engine_parity(mask, seed, op):
    """For ARBITRARY drop masks — not just uniform draws — the vectorized
    transport delivers every record exactly once (table == run_cascade)
    and agrees with the node engine bit for bit."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 140))
    keys = rng.integers(0, 24, size=n).astype(np.int32)
    vals = rng.standard_normal(n).astype(np.float32)
    plan = _plan(list(_CAPS), op=op)
    loss = _MaskLoss(mask)
    cfg = dataclasses.replace(_CFG, loss_model=loss, engine="vectorized")
    res = _sim(keys, vals, fanins=_FANINS, plan=plan, cfg=cfg)
    # conservation: whatever got dropped was retransmitted and combined
    # exactly once — the delivered table IS the exact cascade result
    ref = dataplane.run_cascade(jnp.asarray(keys), jnp.asarray(vals), plan)
    want = {int(k): np.asarray(v) for k, v in
            zip(np.asarray(ref.keys), np.asarray(ref.values)) if k != EMPTY}
    got = dict(zip(res.delivered_keys.tolist(), res.delivered_values))
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-3, atol=1e-4,
                                   err_msg=f"op={op} key={k}")
    assert res.duplicate_discards == 0
    assert res.retransmissions >= res.packets_dropped
    node = _sim(keys, vals, fanins=_FANINS, plan=plan,
                cfg=dataclasses.replace(cfg, engine="node"))
    _assert_identical(node, res)


# --- multi-job tier batching (DESIGN.md §10) -----------------------------


def _plan_all_jobs(n_jobs):
    topo = _planner.Topology(links=(
        _planner.LinkBudget(axis="data", fanin=4, gbps=netsim.TEN_GBE),
        _planner.LinkBudget(axis="pod", fanin=2, gbps=netsim.TEN_GBE / 4)))
    sched = _planner.JobScheduler(topo, combiner_budget_pairs=1024)
    reqs = [_planner.LaunchRequest(
        job_id=j + 1, n_workers=8, expected_pairs=256, key_variety=64,
        grad_bytes=1 << 20) for j in range(n_jobs)]
    return list(sched.plan_all(reqs).jobs)


def test_multi_job_batching_parity_and_kernel_call_count():
    """A plan_all-admitted batch runs through ONE dispatch per
    (level, kernel-key) group — the measured ``tier_ingest`` call count
    equals the planner's ``batch_tier_groups`` prediction — and every
    per-job result is bit-identical to running that job alone, with and
    without loss."""
    jplans = _plan_all_jobs(4)
    keys_list = [rm.zipf_keys(8 * 256, 64, seed=20 + j).astype(np.int32)
                 for j in range(4)]
    vals_list = [np.random.default_rng(30 + j).standard_normal(
        8 * 256).astype(np.float32) for j in range(4)]
    for loss in (0.0, 0.02):
        cfg_v = netsim.NetConfig(records_per_packet=16, engine="vectorized",
                                 loss_rate=loss, seed=13, window=8)
        solo = [simulate(jp, k, v, cfg=cfg_v)
                for jp, k, v in zip(jplans, keys_list, vals_list)]
        before = vsim.ingest_calls
        batched = simulate(jplans, keys_list, vals_list, cfg=cfg_v)
        calls = vsim.ingest_calls - before
        groups = _planner.batch_tier_groups(jplans)
        predicted = sum(len(g) for g in groups.values())
        assert calls == predicted
        # batching actually collapsed work: fewer dispatches than
        # job x level tiers run separately
        n_tiers = sum(len(jp.configure.level_axes) for jp in jplans)
        assert calls < n_tiers
        for rs, rb in zip(solo, batched):
            _assert_identical(rs, rb)
        # and the batch agrees with the node oracle
        cfg_n = dataclasses.replace(cfg_v, engine="node")
        for jp, k, v, rb in zip(jplans, keys_list, vals_list, batched):
            _assert_identical(simulate(jp, k, v, cfg=cfg_n), rb)
