"""Checkpoint manager: atomicity, checksums, pruning, elastic restore."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (
    CheckpointManager, latest_step, restore_tree, save_tree, unflatten_like,
)


def _state(seed=0):
    r = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(r.standard_normal((8, 4)).astype(np.float32))},
        "opt": {"m": jnp.zeros((8, 4)), "count": jnp.asarray(3)},
    }


def test_roundtrip(tmp_path):
    st = _state()
    save_tree(st, str(tmp_path), 7, extras={"lr": 0.1})
    flat, manifest = restore_tree(str(tmp_path), 7)
    assert manifest["step"] == 7 and manifest["extras"]["lr"] == 0.1
    rebuilt = unflatten_like(st, flat)
    import jax

    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_pruning(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 5, 9):
        mgr.save(s, _state(s))
    assert mgr.latest_step() == 9
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000005", "step_00000009"]


def test_corruption_detected(tmp_path):
    save_tree(_state(), str(tmp_path), 3)
    arr = os.path.join(str(tmp_path), "step_00000003", "arrays.npz")
    with open(arr, "r+b") as f:
        f.seek(100)
        f.write(b"\x00\x00\x00\x00")
    with pytest.raises(IOError, match="checksum"):
        restore_tree(str(tmp_path), 3)


def test_crash_mid_save_leaves_previous_intact(tmp_path):
    """A tmp.<step> directory (simulated crash) is invisible to restore."""
    save_tree(_state(0), str(tmp_path), 1)
    os.makedirs(os.path.join(str(tmp_path), "tmp.2"))
    with open(os.path.join(str(tmp_path), "tmp.2", "garbage"), "w") as f:
        f.write("partial")
    assert latest_step(str(tmp_path)) == 1
    flat, manifest = restore_tree(str(tmp_path))
    assert manifest["step"] == 1


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(11, _state(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 11


def test_restore_shape_mismatch_raises(tmp_path):
    save_tree(_state(), str(tmp_path), 1)
    flat, _ = restore_tree(str(tmp_path), 1)
    bad = {"params": {"w": jnp.zeros((4, 4))},
           "opt": {"m": jnp.zeros((8, 4)), "count": jnp.asarray(0)}}
    with pytest.raises(ValueError, match="shape"):
        unflatten_like(bad, flat)


def test_manifest_records_leaves(tmp_path):
    save_tree(_state(), str(tmp_path), 2)
    with open(os.path.join(str(tmp_path), "step_00000002", "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["leaves"]["params/w"]["shape"] == [8, 4]
