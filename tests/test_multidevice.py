"""Multi-device integration tests (subprocess drivers, 8 fake CPU devices).

These exercise the actual SwitchAgg dataplane on a (pod, data, model) mesh:
collective equivalence, compressed exchange exactness, the word-count KV
tree, end-to-end training in every exchange mode, checkpoint/elastic
restart, and TP+cache-sharded serving.
"""

import jax
import pytest

from conftest import run_driver

# The dataplane drivers run shard_map manual over (pod, data) while the
# model axis stays auto.  jax releases without `jax.shard_map` only offer
# the experimental partial-auto path, whose SPMD partitioning crashes
# (fatal CHECK in spmd_partitioner.cc) — requires a jax with the stable API.
partial_auto = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-auto shard_map needs the stable jax.shard_map API",
)


@pytest.mark.integration
@partial_auto
def test_collectives_dataplane():
    out = run_driver("collectives_driver")
    assert "ALL OK" in out


@pytest.mark.integration
@partial_auto
def test_train_e2e_modes_checkpoint_elastic():
    out = run_driver("train_e2e_driver", timeout=600)
    assert "ALL OK" in out


@pytest.mark.integration
@partial_auto
def test_sharded_serving():
    out = run_driver("serve_driver", timeout=600)
    assert "ALL OK" in out


@pytest.mark.integration
@partial_auto
def test_compressed_exchange_training():
    out = run_driver("compressed_driver", timeout=600)
    assert "lossless limit OK" in out
    assert "ALL OK" in out


@pytest.mark.integration
def test_gpipe_pipeline():
    out = run_driver("pipeline_driver", timeout=420)
    assert "pipeline == sequential OK" in out
    assert "ALL OK" in out
