"""Multi-device integration tests (subprocess drivers, 8 fake CPU devices).

These exercise the actual SwitchAgg dataplane on a (pod, data, model) mesh:
collective equivalence, compressed exchange exactness, the word-count KV
tree, end-to-end training in every exchange mode, checkpoint/elastic
restart, and TP+cache-sharded serving.
"""

import pytest

from conftest import run_driver


@pytest.mark.integration
def test_collectives_dataplane():
    out = run_driver("collectives_driver")
    assert "ALL OK" in out


@pytest.mark.integration
def test_train_e2e_modes_checkpoint_elastic():
    out = run_driver("train_e2e_driver", timeout=600)
    assert "ALL OK" in out


@pytest.mark.integration
def test_sharded_serving():
    out = run_driver("serve_driver", timeout=600)
    assert "ALL OK" in out


@pytest.mark.integration
def test_compressed_exchange_training():
    out = run_driver("compressed_driver", timeout=600)
    assert "lossless limit OK" in out
    assert "ALL OK" in out


@pytest.mark.integration
def test_gpipe_pipeline():
    out = run_driver("pipeline_driver", timeout=420)
    assert "pipeline == sequential OK" in out
    assert "ALL OK" in out
