"""Wire format, link model, and transport primitives (DESIGN.md §7)."""

import numpy as np
import pytest

from repro.core import reduction_model as rm
from repro.net import links as links_lib
from repro.net import transport, wire


# --- wire constants: the single source ---------------------------------------


def test_constants_compose():
    assert wire.HEADER_BYTES == wire.ETH_HEADER_BYTES + wire.AGG_HEADER_BYTES
    assert wire.MAX_PAYLOAD_BYTES == wire.MTU_BYTES - wire.HEADER_BYTES
    assert (wire.RECORDS_PER_PACKET
            == wire.MAX_PAYLOAD_BYTES // wire.PAIR_BYTES)
    assert wire.RECORDS_PER_PACKET >= 1


def test_reduction_model_imports_wire_constants():
    # Eq. 2 defaults come from net.wire, not a duplicated literal
    assert rm.header_overhead_ratio(229) == wire.ETH_HEADER_BYTES / 229.0
    assert rm.header_overhead_bytes(1000, 229) == 1000 + (
        1000 // 229) * wire.ETH_HEADER_BYTES
    # Eq. 1 metadata default is the shared per-pair tag
    assert rm.switchagg_extra_traffic([10, 10]) == pytest.approx(
        (20 + 2 * wire.PAIR_META_BYTES) / 20)


# --- packing -----------------------------------------------------------------


def test_pack_records_framing():
    keys = np.arange(10, dtype=np.int32)
    vals = np.arange(10, dtype=np.float32)
    pkts = wire.pack_records(keys, vals, flow_id=3, records_per_packet=4,
                             eot=True)
    assert [p.header.n_records for p in pkts] == [4, 4, 2]
    assert [p.header.psn for p in pkts] == [0, 1, 2]
    assert [p.header.eot for p in pkts] == [False, False, True]
    assert all(p.header.flow_id == 3 for p in pkts)
    np.testing.assert_array_equal(
        np.concatenate([p.keys for p in pkts]), keys)
    np.testing.assert_array_equal(
        np.concatenate([p.values for p in pkts]), vals)
    assert pkts[0].wire_bytes == wire.HEADER_BYTES + 4 * wire.PAIR_BYTES


def test_pack_empty_stream_still_carries_eot():
    pkts = wire.pack_records(np.zeros((0,), np.int32),
                             np.zeros((0,), np.float32), eot=True)
    assert len(pkts) == 1
    assert pkts[0].header.eot and pkts[0].header.n_records == 0
    assert wire.pack_records(np.zeros((0,), np.int32),
                             np.zeros((0,), np.float32)) == []


def test_stream_wire_bytes_matches_framing():
    for n in (0, 1, 4, 5, 9, 123):
        pkts = wire.pack_records(np.zeros((n,), np.int32),
                                 np.zeros((n,), np.float32),
                                 records_per_packet=4)
        assert wire.stream_wire_bytes(n, 4) == sum(p.wire_bytes for p in pkts)


def test_pack_records_lane_values():
    vals = np.ones((5, 2), np.float32)  # mean's carried (sum, count) lanes
    pkts = wire.pack_records(np.arange(5, dtype=np.int32), vals,
                             records_per_packet=3)
    assert pkts[0].values.shape == (3, 2)
    assert pkts[0].payload_bytes == 3 * wire.PAIR_BYTES  # lanes: not a wire cost


# --- link model --------------------------------------------------------------


def test_link_fifo_serialization_and_queueing():
    link = links_lib.Link(name="l", axis="data", gbps=1.0, propagation_s=1e-6)
    dep1, arr1 = link.transmit(0.0, 1000)  # 1 us at 1 GB/s
    assert dep1 == pytest.approx(1e-6)
    assert arr1 == pytest.approx(2e-6)
    # second packet ready at t=0 queues behind the first
    dep2, _ = link.transmit(0.0, 1000)
    assert dep2 == pytest.approx(2e-6)
    assert link.queue_delay_s == pytest.approx(1e-6)
    assert link.bytes_sent == 2000 and link.packets_sent == 2
    assert link.busy_s == pytest.approx(2e-6)


def test_stats_by_axis_drain_is_busiest_link():
    a = links_lib.Link(name="a", axis="data", gbps=1.0)
    b = links_lib.Link(name="b", axis="data", gbps=1.0)
    a.transmit(0.0, 3000)
    b.transmit(0.0, 1000)
    s = links_lib.stats_by_axis([a, b])["data"]
    assert s["bytes"] == 4000 and s["links"] == 2
    assert s["drain_s"] == pytest.approx(3e-6)


# --- transport ---------------------------------------------------------------


def test_loss_model_deterministic_and_bounds():
    loss = transport.LossModel(rate=0.3, seed=5)
    rolls = [loss.drop(1, p, 1) for p in range(200)]
    assert rolls == [loss.drop(1, p, 1) for p in range(200)]
    assert 0 < sum(rolls) < 200  # neither all-drop nor no-drop
    assert not transport.LossModel(rate=0.0).drop(0, 0, 1)
    with pytest.raises(ValueError):
        transport.LossModel(rate=1.0)


def test_receiver_psn_dedupe():
    r = transport.Receiver()
    h = lambda psn: wire.PacketHeader(job_id=0, flow_id=1, level=0,  # noqa: E731
                                      psn=psn, n_records=1)
    assert r.accept(h(0)) and r.accept(h(1))
    assert not r.accept(h(1))  # duplicate (retransmission of combined data)
    assert not r.accept(h(3))  # gap (an earlier packet was lost)
    assert r.accept(h(2)) and r.accept(h(3))
    assert r.duplicate_discards == 1 and r.gap_discards == 1


def test_go_back_n_delivers_in_order_exactly_once():
    keys = np.arange(40, dtype=np.int32)
    pkts = wire.pack_records(keys, np.ones(40, np.float32),
                             flow_id=2, records_per_packet=4, eot=True)
    link = links_lib.Link(name="l", axis="data", gbps=1.0)
    loss = transport.LossModel(rate=0.3, seed=11)
    recv = transport.Receiver()
    got = []

    def deliver(p, t):
        if recv.accept(p.header):
            got.append((t, p))

    t_done, st = transport.send_stream([(0.0, p) for p in pkts], link, loss,
                                       flow_id=2, window=4, deliver=deliver)
    assert [p.header.psn for _, p in got] == list(range(len(pkts)))
    assert sorted(t for t, _ in got) == [t for t, _ in got]
    np.testing.assert_array_equal(
        np.concatenate([p.keys for _, p in got]), keys)
    assert st.packets_dropped > 0 and st.retransmissions > 0
    assert st.packets_sent == len(pkts) + st.retransmissions
    assert t_done >= got[-1][0] - link.propagation_s


def test_go_back_n_lossless_is_pure_pipeline():
    pkts = wire.pack_records(np.arange(8, dtype=np.int32),
                             np.ones(8, np.float32), records_per_packet=4,
                             eot=True)
    link = links_lib.Link(name="l", axis="data", gbps=1.0, propagation_s=0.0)
    seen = []
    t_done, st = transport.send_stream(
        [(0.0, p) for p in pkts], link, transport.LossModel(0.0), flow_id=0,
        deliver=lambda p, t: seen.append(t))
    assert st.retransmissions == 0 and st.timeouts == 0
    total = sum(p.wire_bytes for p in pkts)
    assert t_done == pytest.approx(total / 1e9)
    assert seen[-1] == pytest.approx(total / 1e9)
