"""core.aggops — the AggOp registry, the one source of op semantics."""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import dict_aggregate
from repro.core import aggops, kvagg

EMPTY = int(kvagg.EMPTY_KEY)


# --------------------------------------------------------------------------
# registry surface
# --------------------------------------------------------------------------


def test_registry_contains_paper_and_extended_ops():
    assert set(aggops.names()) >= {"sum", "max", "min", "count", "mean",
                                   "logsumexp"}


def test_unknown_op_raises_with_known_names():
    with pytest.raises(ValueError, match="logsumexp"):
        aggops.get("median")


def test_get_returns_registered_instance():
    assert aggops.get("sum") is aggops.SUM
    assert aggops.get("mean").lanes == 2


@pytest.mark.parametrize("name", ["sum", "max", "min", "count", "logsumexp"])
def test_combine_associative_commutative_samples(name, rng):
    op = aggops.get(name)
    a, b, c = (jnp.asarray(rng.standard_normal(16).astype(np.float32))
               for _ in range(3))
    left = op.combine(op.combine(a, b), c)
    right = op.combine(a, op.combine(b, c))
    np.testing.assert_allclose(left, right, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(op.combine(a, b), op.combine(b, a))


# --------------------------------------------------------------------------
# dtype-aware identities — the ±inf-for-integers bugfix
# --------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.int16])
def test_minmax_identity_uses_integer_bounds(dtype):
    info = jnp.iinfo(dtype)
    assert int(aggops.get("max").identity(dtype)) == info.min
    assert int(aggops.get("min").identity(dtype)) == info.max
    assert aggops.get("max").identity(dtype).dtype == jnp.dtype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_minmax_identity_uses_float_bounds(dtype):
    info = jnp.finfo(dtype)
    assert float(aggops.get("max").identity(dtype)) == float(info.min)
    assert float(aggops.get("min").identity(dtype)) == float(info.max)


def test_identity_neutral_under_combine():
    for name in ("sum", "max", "min", "logsumexp"):
        op = aggops.get(name)
        x = jnp.asarray([-3.5, 0.0, 7.25], jnp.float32)
        np.testing.assert_allclose(op.combine(x, op.identity(jnp.float32)), x)
    for name in ("sum", "max", "min"):
        op = aggops.get(name)
        xi = jnp.asarray([-3, 0, 7], jnp.int32)
        np.testing.assert_array_equal(op.combine(xi, op.identity(jnp.int32)), xi)


def test_int32_max_min_sorted_combine_regression(rng):
    """REGRESSION: ±inf identities corrupted int32 max/min aggregation."""
    keys = jnp.asarray(rng.integers(0, 8, 64).astype(np.int32))
    vals = jnp.asarray(rng.integers(-1000, 1000, 64).astype(np.int32))
    for op in ("max", "min"):
        res = kvagg.sorted_combine(keys, vals, op=op)
        assert res.combined_values.dtype == jnp.int32
        got = dict_aggregate(res.unique_keys, res.combined_values, op=op)
        want = dict_aggregate(keys, vals, op=op)
        assert got == want
        # padding slots hold the dtype-aware identity, not cast garbage
        nu = int(res.n_unique)
        pad_vals = np.asarray(res.combined_values)[nu:]
        bound = jnp.iinfo(jnp.int32).min if op == "max" else jnp.iinfo(jnp.int32).max
        assert np.all(pad_vals == int(bound))


def test_int32_max_min_two_level_regression(rng):
    keys = jnp.asarray(rng.integers(0, 32, 256).astype(np.int32))
    vals = jnp.asarray(rng.integers(-1000, 1000, 256).astype(np.int32))
    for op in ("max", "min"):
        res = kvagg.two_level_aggregate(keys, vals, capacity=8, ways=2, op=op)
        got = dict_aggregate(res.out_keys, res.out_values, op=op)
        want = dict_aggregate(keys, vals, op=op)
        assert got == want


# --------------------------------------------------------------------------
# prepare / finalize (carried representations)
# --------------------------------------------------------------------------


def test_count_prepare_maps_records_to_ones(rng):
    v = jnp.asarray(rng.standard_normal(10).astype(np.float32))
    carried = aggops.get("count").prepare_values(v)
    assert carried.dtype == jnp.int32
    np.testing.assert_array_equal(carried, np.ones(10, np.int32))


def test_mean_prepare_finalize_roundtrip(rng):
    v = jnp.asarray(rng.standard_normal(10).astype(np.float32))
    op = aggops.get("mean")
    carried = op.prepare_values(v)
    assert carried.shape == (10, 2)
    np.testing.assert_allclose(carried[:, 0], v)
    np.testing.assert_allclose(carried[:, 1], 1.0)
    np.testing.assert_allclose(op.finalize_values(carried), v, rtol=1e-6)


def test_mean_finalize_zero_count_is_zero_not_nan():
    out = aggops.get("mean").finalize_values(jnp.zeros((4, 2), jnp.float32))
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_array_equal(out, 0.0)


def test_mean_of_int_values_is_fractional():
    keys = jnp.asarray([7, 7, 7], jnp.int32)
    vals = jnp.asarray([1, 2, 2], jnp.int32)
    op = aggops.get("mean")
    res = kvagg.sorted_combine(keys, op.prepare_values(vals), op="mean")
    out = op.finalize_values(res.combined_values)
    np.testing.assert_allclose(np.asarray(out)[0], 5.0 / 3.0, rtol=1e-6)


def test_logsumexp_matches_numpy(rng):
    keys = jnp.asarray(rng.integers(0, 6, 64).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(64).astype(np.float32) * 10)
    op = aggops.get("logsumexp")
    res = kvagg.sorted_combine(keys, op.prepare_values(vals), op="logsumexp")
    got = dict_aggregate(res.unique_keys, res.combined_values, op="sum")
    # grouped logsumexp computed directly on the raw stream
    want = dict_aggregate(keys, vals, op="logsumexp")
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-5)
