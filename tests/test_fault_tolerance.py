"""TrainLoop: checkpoint/restart, crash recovery, straggler logging."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.runtime.fault_tolerance import (StragglerMonitor, TrainLoop,
                                           TrainLoopConfig)


def _make_loop(ckpt_dir, total=20, every=5, state=None, delay_hook=None):
    cfg = TrainLoopConfig(total_steps=total, ckpt_dir=ckpt_dir,
                          ckpt_every=every, ckpt_keep=2, ckpt_async=False,
                          log_every=1000)

    @jax.jit
    def step_fn(state, batch, step):
        new = {"w": state["w"] + batch["x"].sum(), "steps_done": state["steps_done"] + 1}
        return new, {"loss": jnp.sum(new["w"])}

    def batch_fn(step):  # pure in step (restart-reproducible)
        return {"x": jnp.full((4,), float(step))}

    st = state or {"w": jnp.zeros(()), "steps_done": jnp.zeros((), jnp.int32)}
    return TrainLoop(cfg, step_fn, batch_fn, st, delay_hook=delay_hook)


def _expected_w(n_steps):
    return sum(4.0 * s for s in range(n_steps))


def test_full_run(tmp_path):
    loop = _make_loop(str(tmp_path))
    final = loop.run()
    assert float(final["w"]) == _expected_w(20)
    assert int(final["steps_done"]) == 20
    assert len(loop.metrics_history) == 20


def test_restart_resumes_identically(tmp_path):
    # run to step 12, "crash"
    loop1 = _make_loop(str(tmp_path))
    loop1.run(until=12)  # checkpoints at 4, 9, and 11 (end-of-segment save)
    # new process: fresh loop auto-resumes from the newest checkpoint
    loop2 = _make_loop(str(tmp_path))
    assert loop2.start_step == 12
    final = loop2.run()
    assert float(final["w"]) == _expected_w(20)  # bit-identical end state
    assert int(final["steps_done"]) == 20


def test_corrupt_checkpoint_falls_back(tmp_path):
    loop1 = _make_loop(str(tmp_path))
    loop1.run(until=12)
    # corrupt the newest checkpoint (truncate arrays)
    newest = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))[-1]
    arr = os.path.join(str(tmp_path), newest, "arrays.npz")
    with open(arr, "wb") as f:
        f.write(b"garbage")
    loop2 = _make_loop(str(tmp_path))
    assert loop2.start_step == 10  # fell back to the previous checkpoint (9)
    final = loop2.run()
    assert float(final["w"]) == _expected_w(20)


def test_transient_restore_error_raises_and_keeps_checkpoints(
        tmp_path, monkeypatch):
    """A restore failure that is NOT verified corruption (here: a
    transient OSError) must surface, not silently rmtree good state —
    only ``CheckpointCorruptError`` from the manager licenses deletion."""
    loop1 = _make_loop(str(tmp_path))
    loop1.run(until=12)
    dirs_before = sorted(d for d in os.listdir(tmp_path)
                         if d.startswith("step_"))

    def flaky_restore(self, state, step):
        raise OSError("NFS mount went away")

    monkeypatch.setattr(CheckpointManager, "restore", flaky_restore)
    with pytest.raises(OSError, match="NFS"):
        _make_loop(str(tmp_path))
    # every checkpoint survived the failed resume
    assert sorted(d for d in os.listdir(tmp_path)
                  if d.startswith("step_")) == dirs_before
    # and once the "environment is fixed", the same state restores fine
    monkeypatch.undo()
    loop2 = _make_loop(str(tmp_path))
    assert loop2.start_step == 12


def test_straggler_monitor_seeds_ewma_from_warmup_median():
    """A 10x-slow step 0 (jit compile) must not poison the baseline: the
    EWMA seeds from the median of the warmup window, so a genuinely slow
    later step is flagged immediately."""
    mon = StragglerMonitor(factor=3.0, decay=0.9, warmup=3)
    assert not mon.observe(0, 10.0)  # compile-dominated first step
    assert not mon.observe(1, 1.0)
    assert not mon.observe(2, 1.1)
    assert mon.ewma == pytest.approx(1.1)  # median, not 10.0
    assert not mon.observe(3, 1.05)
    assert mon.observe(4, 9.0)  # would NOT trip a first-obs-seeded EWMA
    assert [e[0] for e in mon.events] == [4]
    # stragglers don't feed back into the baseline
    assert mon.ewma < 1.2


def test_straggler_events_logged(tmp_path):
    delays = {7: 0.3}
    loop = _make_loop(str(tmp_path), delay_hook=lambda s: delays.get(s, 0.0))
    loop.run()
    flagged = [e[0] for e in loop.monitor.events]
    assert 7 in flagged


def test_elastic_restart_same_values(tmp_path):
    """Checkpoints are mesh-agnostic full arrays: a restart that re-applies
    different shardings (here: trivially, a different jit) continues exactly."""
    loop1 = _make_loop(str(tmp_path), total=10, every=5)
    loop1.run(until=7)
    # 'new cluster': a new loop instance (fresh jit cache) resumes
    loop2 = _make_loop(str(tmp_path), total=10, every=5)
    final = loop2.run()
    assert float(final["w"]) == _expected_w(10)
