"""System-level coherence: registry, cells, public imports, mesh factory."""

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import ALL_SHAPES


def test_all_archs_registered():
    assert len(configs.ARCH_IDS) == 10
    for a in configs.ARCH_IDS:
        cfg = configs.get_config(a)
        assert cfg.name == a
        assert cfg.source, f"{a} missing provenance"
        # layer arithmetic closes
        assert len(cfg.prefix) + len(cfg.pattern) * cfg.n_groups == cfg.n_layers


def test_cells_enumeration():
    live = configs.cells()
    everything = configs.cells(include_skipped=True)
    assert len(everything) == 40  # 10 archs x 4 shapes
    assert len(live) == 34        # 6 long_500k skips (pure full-attention)
    skipped = {(a, s.name) for a, s, l in everything if not l}
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == {
        "phi4-mini-3.8b", "qwen3-32b", "deepseek-v2-236b", "olmoe-1b-7b",
        "paligemma-3b", "musicgen-medium"}


def test_shapes_match_brief():
    by = {s.name: s for s in ALL_SHAPES}
    assert (by["train_4k"].seq_len, by["train_4k"].global_batch) == (4096, 256)
    assert (by["prefill_32k"].seq_len, by["prefill_32k"].global_batch) == (32768, 32)
    assert (by["decode_32k"].seq_len, by["decode_32k"].global_batch) == (32768, 128)
    assert (by["long_500k"].seq_len, by["long_500k"].global_batch) == (524288, 1)
    assert by["decode_32k"].kind == "decode" and by["long_500k"].kind == "decode"


def test_public_imports():
    import repro.core.collectives
    import repro.core.kvagg
    import repro.core.planner
    import repro.core.reduction_model
    import repro.core.tree
    import repro.checkpoint.manager
    import repro.data.pipeline
    import repro.kernels.ops
    import repro.kernels.ref
    import repro.launch.hlo_analysis
    import repro.launch.hlo_cost
    import repro.launch.mesh
    import repro.launch.profiles
    import repro.models.model
    import repro.optim.adamw
    import repro.runtime.fault_tolerance
    import repro.train.step  # noqa: F401


def test_mesh_factory_is_lazy():
    """Importing mesh.py must not touch device state; constants defined."""
    from repro.launch import mesh as m

    assert callable(m.make_production_mesh)
    assert m.PEAK_FLOPS_BF16 == 197e12
    assert m.HBM_BW == 819e9


def test_vocab_shards_over_model_axis():
    for a in configs.ARCH_IDS:
        cfg = configs.get_config(a)
        assert cfg.padded_vocab % 16 == 0  # model axis of the production mesh
        assert cfg.padded_vocab >= cfg.vocab_size
