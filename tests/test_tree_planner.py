"""Aggregation tree + controller/planner (the paper's control plane)."""

import jax
import pytest

from repro.core import planner, tree as tree_lib
from repro.core.collectives import GradAggMode
from repro.runtime.fault_tolerance import StragglerMonitor


# ---------------------------------------------------------------------------
# Tree construction.
# ---------------------------------------------------------------------------


def test_from_mesh_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t = tree_lib.from_mesh(mesh)
    assert t.fanin == 1  # degenerate but total


def test_worker_tree_levels():
    t = tree_lib.worker_tree(7, fanin=4)
    # 7 workers, radix 4 -> 4 then 2 (paper Fig. 1: 7 mappers, 2 levels)
    assert [l.fanin for l in t.levels] == [4, 2]
    assert t.fanin == 8  # >= n_workers
    t1 = tree_lib.worker_tree(1, fanin=4)
    assert t1.fanin == 1
    with pytest.raises(ValueError):
        tree_lib.worker_tree(0, 4)


def test_worker_tree_describe():
    t = tree_lib.worker_tree(16, fanin=4)
    assert "lvl0(x4" in t.describe() and "root" in t.describe()


def test_traffic_model_from_tree():
    t = tree_lib.worker_tree(32, fanin=8)
    m = t.traffic_model(1 << 20)
    assert m.tree_reduction_at_root() > 0.8


# ---------------------------------------------------------------------------
# Controller: memory partitioning among trees (paper §4.2.2).
# ---------------------------------------------------------------------------


def test_controller_divides_memory_evenly():
    ctl = planner.Controller(combiner_budget_pairs=1024)
    t = tree_lib.worker_tree(8, 4)
    m1 = ctl.configure(planner.LaunchRequest(1, 8, 10000, 100), t)
    assert m1.fpe_capacity == 1024
    m2 = ctl.configure(planner.LaunchRequest(2, 8, 10000, 100), t)
    assert m2.fpe_capacity == 512
    assert ctl.active[1].fpe_capacity == 512  # re-partitioned
    ctl.release(1)
    assert ctl.active[2].fpe_capacity == 1024


def test_controller_carries_tree_shape():
    ctl = planner.Controller()
    t = tree_lib.worker_tree(16, 4)
    msg = ctl.configure(planner.LaunchRequest(9, 16, 1, 1), t)
    assert msg.fanins == (4, 4)
    assert msg.level_axes == ("lvl0", "lvl1")


# ---------------------------------------------------------------------------
# Planner.
# ---------------------------------------------------------------------------


def test_plan_grad_exchange_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = planner.plan_grad_exchange(mesh, mode=GradAggMode.TREE,
                                      grad_bytes=1 << 20)
    assert plan.mode == GradAggMode.TREE
    assert plan.upper_axes == ()


def test_size_fpe_capacity_inverts_eq3():
    from repro.core import reduction_model as rm

    N, M = 5000, 100000
    for target in (0.05, 0.3, 0.6):
        c = planner.size_fpe_capacity(N, target, M)
        achieved = rm.reduction_ratio(M, N, c)
        assert achieved >= target - 1e-9
    # asking for more than the ideal bound -> hold all keys
    assert planner.size_fpe_capacity(N, 0.999, M) == N


# ---------------------------------------------------------------------------
# Straggler monitor (fault tolerance unit).
# ---------------------------------------------------------------------------


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(factor=3.0, decay=0.9, warmup=2)
    for i in range(5):
        assert not mon.observe(i, 1.0)
    assert mon.observe(5, 10.0)  # 10x the EWMA
    assert mon.events and mon.events[0][0] == 5
    # the straggler did not poison the EWMA
    assert mon.ewma == pytest.approx(1.0, rel=1e-6)
    assert not mon.observe(6, 1.1)


def test_straggler_monitor_warmup_tolerant():
    mon = StragglerMonitor(factor=2.0, warmup=3)
    assert not mon.observe(0, 1.0)
    assert not mon.observe(1, 5.0)  # within warmup: compile steps etc.
    assert not mon.observe(2, 1.0)


def test_straggler_monitor_adapts():
    mon = StragglerMonitor(factor=3.0, decay=0.5, warmup=1)
    mon.observe(0, 1.0)
    for i in range(1, 10):
        mon.observe(i, 2.0)  # workload legitimately slows
    assert mon.ewma == pytest.approx(2.0, rel=1e-2)
    assert not mon.observe(10, 5.0)  # 2.5x new EWMA: fine
