"""Tier-batch properties of the vectorized engine (DESIGN.md §10).

Two invariants that make ``vsim.tier_ingest`` safe to scale:

  * partition invariance — however a tier's mapper streams are split
    across switches, grouped-combining every switch's output (eviction
    streams + resident tables) recovers exactly the brute-force grouped
    result, and matches the single-switch run (the tier-batch analogue of
    ``test_dataplane_properties.py``);
  * O(1) retraces — ``run_tier_fast`` pads the (switch, packet) batch to
    powers of two, so sweeping pod / mapper counts reuses a handful of
    compiled shapes instead of retracing per topology (the
    ``test_fpe_fast.py`` shape-stability pattern at tier scope).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import dict_aggregate
from repro.core import aggops, dataplane, kvagg
from repro.core import reduction_model as rm
from repro.net import sim as netsim
from repro.net import vsim

EMPTY = int(kvagg.EMPTY_KEY)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="dev-only dep: pip install -r requirements-dev.txt")

# fixed kernel geometry: hypothesis explores the PARTITION space, and the
# pad-to-pow2 framing below keeps the jit cache warm across examples
_CAP, _WAYS, _RPP = 16, 4, 8


def _tier_outputs(keys, carried, splits, *, op, exact_stream=True):
    """Frame each split as one switch's packet sequence, run the tier in
    ONE ``tier_ingest`` call, and return every (key, carried-value) the
    tier holds afterwards: eviction streams + resident tables."""
    parts = np.array_split(np.arange(keys.shape[0]), splits) if isinstance(
        splits, int) else splits
    S = vsim._pow2(len(parts))
    P = vsim._pow2(max(1, max(-(-len(p) // _RPP) for p in parts)))
    lane_shape = carried.shape[1:]
    kb = np.full((S, P, _RPP), EMPTY, np.int32)
    vb = np.zeros((S, P, _RPP) + lane_shape, carried.dtype)
    for s, idx in enumerate(parts):
        for j in range(0, len(idx), _RPP):
            chunk = idx[j:j + _RPP]
            kb[s, j // _RPP, :len(chunk)] = keys[chunk]
            vb[s, j // _RPP, :len(chunk)] = carried[chunk]
    tk, tv, ek, ev, _, _ = (np.asarray(a) for a in vsim.tier_ingest(
        jnp.asarray(kb), jnp.asarray(vb), capacity=_CAP, ways=_WAYS, op=op,
        bpe=True, exact_stream=exact_stream))
    out_k = np.concatenate([ek.reshape(-1), tk.reshape(-1)])
    out_v = np.concatenate([ev.reshape((-1,) + lane_shape),
                            tv.reshape((-1,) + lane_shape)])
    real = out_k != EMPTY
    return out_k[real], out_v[real]


def _grouped_finalized(keys, carried, *, op):
    """Grouped-combine carried values by key, then finalize — the op's
    own reduction semantics, independent of any switch partitioning."""
    aggop = aggops.get(op)
    acc: dict[int, np.ndarray] = {}
    for k, v in zip(keys.tolist(), carried):
        acc[k] = v if k not in acc else np.asarray(
            aggop.combine(jnp.asarray(acc[k]), jnp.asarray(v)))
    ks = sorted(acc)
    fin = np.asarray(aggop.finalize_values(
        jnp.asarray(np.stack([acc[k] for k in ks]))))
    return dict(zip(ks, fin.tolist()))


def _check_partition(keys, vals, parts, op):
    aggop = aggops.get(op)
    carried = np.asarray(aggop.prepare_values(jnp.asarray(vals)))
    ok, ov = _tier_outputs(keys, carried, parts, op=op)
    got = _grouped_finalized(ok, ov, op=op)
    single_k, single_v = _tier_outputs(keys, carried, 1, op=op)
    single = _grouped_finalized(single_k, single_v, op=op)
    want = dict_aggregate(keys, vals, op)
    assert got.keys() == want.keys() == single.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5,
                                   err_msg=f"op={op} key={k}")
        np.testing.assert_allclose(got[k], single[k], rtol=1e-4, atol=1e-5,
                                   err_msg=f"op={op} key={k} (vs 1-switch)")


@pytest.mark.parametrize("op", sorted(aggops.names()))
def test_partition_invariance_fixed_splits(op):
    """Deterministic spine of the property: 1/2/3/4-way splits of one
    stream all reduce to the same grouped table, for every op."""
    keys = rm.zipf_keys(200, 24, seed=3).astype(np.int32)
    vals = np.random.default_rng(1).standard_normal(200).astype(np.float32)
    for splits in (2, 3, 4):
        _check_partition(keys, vals, splits, op)


if HAVE_HYPOTHESIS:
    def _partition_property(f):
        return settings(max_examples=30, deadline=None)(given(
            n=st.integers(1, 120),
            variety=st.integers(1, 24),
            n_switches=st.integers(1, 6),
            seed=st.integers(0, 2**31 - 1),
            op=st.sampled_from(sorted(aggops.names())))(f))
else:
    def _partition_property(f):
        def stub():  # collected, skipped by needs_hypothesis
            raise AssertionError("unreachable")
        return stub


@needs_hypothesis
@_partition_property
def test_property_any_partition_matches_single_switch(
        n, variety, n_switches, seed, op):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, variety, size=n).astype(np.int32)
    vals = rng.standard_normal(n).astype(np.float32)
    # an arbitrary (possibly empty-celled) assignment of records->switches
    owner = rng.integers(0, n_switches, size=n)
    parts = [np.flatnonzero(owner == s) for s in range(n_switches)]
    _check_partition(keys, vals, parts, op)


# --- jit-cache shape stability across pod / mapper counts ----------------


def test_tier_ingest_o1_retraces_across_topologies():
    """Sweeping mapper counts, fanins, and stream lengths through the
    vectorized engine reuses pad-to-pow2 compiled shapes: the tier kernel
    retraces O(1) times, not once per topology."""
    cfg = netsim.NetConfig(records_per_packet=16, engine="vectorized")

    def run(fanins, n):
        plan = dataplane.CascadePlan(op="sum", levels=tuple(
            dataplane.LevelSpec(capacity=c)
            for c in (16, 8, 8)[:len(fanins)]))
        keys = rm.zipf_keys(n, 24, seed=0).astype(np.int32)
        vals = np.ones((n,), np.float32)
        from repro.net import simulate
        simulate(netsim.JobSpec(keys=keys, values=vals, fanins=fanins,
                                plan=plan, cfg=cfg))

    run((2, 2), 64)  # prime the cache
    before = vsim.tier_ingest._cache_size()
    sweep = [(fanins, n)
             for fanins in ((2, 2), (2, 3), (3, 2), (4, 2), (2, 2, 2))
             for n in (40, 70, 150, 220)]
    for fanins, n in sweep:
        run(fanins, n)
    grew = vsim.tier_ingest._cache_size() - before
    # ~45 tier calls across 20 topology/size combos collapse into a
    # handful of (capacity, S-pad, P-pad) buckets...
    assert grew <= 16, f"tier kernel retraced {grew} times across 20 runs"
    # ...and the shape space is saturated: a second identical sweep (and
    # fresh in-between sizes hitting the same pow2 buckets) retraces ZERO
    for fanins, n in sweep + [((2, 2), 50), ((4, 2), 200)]:
        run(fanins, n)
    assert vsim.tier_ingest._cache_size() - before == grew, \
        "repeat sweep retraced: batch shapes are not stable"
