"""core.kvagg — the pure-jnp SwitchAgg node (FPE scan + BPE sorted combine)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev-only dep: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from conftest import dict_aggregate
from repro.core import kvagg

EMPTY = int(kvagg.EMPTY_KEY)


# --------------------------------------------------------------------------
# sorted_combine (the BPE / vectorized exact aggregator)
# --------------------------------------------------------------------------


def test_sorted_combine_exact(rng):
    keys = jnp.asarray(rng.integers(0, 20, size=100).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(100).astype(np.float32))
    res = kvagg.sorted_combine(keys, vals)
    got = dict_aggregate(res.unique_keys, res.combined_values)
    want = dict_aggregate(keys, vals)
    assert got.keys() == want.keys()
    for k in want:
        # atol: near-cancelling fp32 sums reassociate under segment_sum
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-6)
    assert int(res.n_unique) == len(want)
    # packed ascending with EMPTY padding after n_unique
    uk = np.asarray(res.unique_keys)
    nu = int(res.n_unique)
    assert np.all(np.diff(uk[:nu]) > 0)
    assert np.all(uk[nu:] == EMPTY)


def test_sorted_combine_all_padding():
    keys = jnp.full((16,), EMPTY, jnp.int32)
    vals = jnp.zeros((16,), jnp.float32)
    res = kvagg.sorted_combine(keys, vals)
    assert int(res.n_unique) == 0
    assert np.all(np.asarray(res.unique_keys) == EMPTY)


def test_sorted_combine_single_key():
    keys = jnp.zeros((8,), jnp.int32)
    vals = jnp.ones((8,), jnp.float32)
    res = kvagg.sorted_combine(keys, vals)
    assert int(res.n_unique) == 1
    assert float(res.combined_values[0]) == 8.0


@pytest.mark.parametrize("op", ["max", "min"])
def test_sorted_combine_ops(op, rng):
    keys = jnp.asarray(rng.integers(0, 5, size=64).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    res = kvagg.sorted_combine(keys, vals, op=op)
    want = dict_aggregate(keys, vals, op=op)
    got = dict_aggregate(res.unique_keys, res.combined_values, op=op)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-6)


# --------------------------------------------------------------------------
# fpe_aggregate (paper-faithful hash engine) + two_level node
# --------------------------------------------------------------------------


def test_fpe_no_evictions_when_capacity_sufficient(rng):
    """Distinct keys <= direct capacity/ways buckets -> depends on hashing;
    use variety=1 which always fits."""
    keys = jnp.zeros((32,), jnp.int32)
    vals = jnp.ones((32,), jnp.float32)
    r = kvagg.fpe_aggregate(keys, vals, capacity=8, ways=4)
    assert np.all(np.asarray(r.evict_keys) == EMPTY)
    got = dict_aggregate(r.table_keys, r.table_values)
    assert got == {0: 32.0}


def test_fpe_eviction_forwards_resident_pair():
    """Force a collision: ways=1, two keys hashing to the same bucket."""
    # with n_buckets=1 every key collides
    keys = jnp.asarray([5, 9, 5], dtype=jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 4.0], dtype=jnp.float32)
    r = kvagg.fpe_aggregate(keys, vals, capacity=1, ways=1)
    ek = np.asarray(r.evict_keys)
    ev = np.asarray(r.evict_values)
    # key 5 inserted; 9 evicts 5; 5 evicts 9
    np.testing.assert_array_equal(ek, [EMPTY, 5, 9])
    np.testing.assert_allclose(ev, [0.0, 1.0, 2.0])
    assert np.asarray(r.table_keys)[0] == 5
    assert np.asarray(r.table_values)[0] == 4.0


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    variety=st.integers(1, 100),
    capacity=st.sampled_from([1, 4, 16, 128]),
    ways=st.sampled_from([1, 2, 4, 8]),
    op=st.sampled_from(["sum", "max", "min"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_two_level_exactness(n, variety, capacity, ways, op, seed):
    """two_level_aggregate(bpe=True) == exact group-by-key for any stream."""
    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.integers(0, variety, size=n).astype(np.int32))
    vals = jnp.asarray(r.integers(-16, 16, size=n).astype(np.float32))
    res = kvagg.two_level_aggregate(keys, vals, capacity=capacity, ways=ways, op=op)
    got = dict_aggregate(res.out_keys, res.out_values, op=op)
    want = dict_aggregate(keys, vals, op=op)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-6)
    assert int(res.n_in) == n


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_bpe_improves_reduction(seed):
    """M-* >= S-* (paper Fig. 9): BPE combine can only reduce output pairs."""
    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.integers(0, 64, size=256).astype(np.int32))
    vals = jnp.asarray(r.standard_normal(256).astype(np.float32))
    with_bpe = kvagg.two_level_aggregate(keys, vals, capacity=16, ways=4, bpe=True)
    without = kvagg.two_level_aggregate(keys, vals, capacity=16, ways=4, bpe=False)
    assert int(with_bpe.n_out) <= int(without.n_out)
    rr_with = float(kvagg.reduction_ratio(with_bpe))
    rr_without = float(kvagg.reduction_ratio(without))
    assert rr_with >= rr_without


def test_reduction_ratio_skewed_beats_uniform(rng):
    """Paper Fig. 9: Zipf hot keys aggregate in the FPE -> higher ratio."""
    n = 1024
    zipf = np.minimum(rng.zipf(1.5, size=n), 1000).astype(np.int32) - 1
    unif = rng.integers(0, 1000, size=n).astype(np.int32)
    vals = jnp.ones((n,), jnp.float32)
    r_z = kvagg.two_level_aggregate(jnp.asarray(zipf), vals, capacity=64, ways=4, bpe=False)
    r_u = kvagg.two_level_aggregate(jnp.asarray(unif), vals, capacity=64, ways=4, bpe=False)
    assert float(kvagg.reduction_ratio(r_z)) > float(kvagg.reduction_ratio(r_u))


# --------------------------------------------------------------------------
# payload analyzer (length grouping)
# --------------------------------------------------------------------------


def test_length_group_paper_bins():
    """Paper §5: keys 8..64 B in 8 groups of base 8."""
    lens = jnp.asarray([1, 8, 9, 16, 17, 33, 64, 200], jnp.int32)
    g = np.asarray(kvagg.length_group(lens, base=8, n_groups=8))
    np.testing.assert_array_equal(g, [0, 0, 1, 1, 2, 4, 7, 7])


def test_hash_key_range():
    keys = jnp.arange(-5, 1000, dtype=jnp.int32)
    h = np.asarray(kvagg.hash_key(keys, 17))
    assert h.min() >= 0 and h.max() < 17
