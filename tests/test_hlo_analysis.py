"""Roofline math + collective accounting (the §Roofline source of truth)."""

import jax
import pytest

import repro.configs as configs
from repro.configs.base import shape_by_name
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import DCN_BW, HBM_BW, ICI_BW, ICI_LINKS, PEAK_FLOPS_BF16


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_roofline_terms_math():
    coll = ha.CollectiveStats(ici_bytes=2 * ICI_BW * ICI_LINKS,
                              dcn_bytes=3 * DCN_BW)
    r = ha.roofline_terms(hlo_flops=PEAK_FLOPS_BF16 * 0.5, hlo_bytes=HBM_BW * 4,
                          coll=coll, n_chips=256, model_flops=PEAK_FLOPS_BF16 * 0.25)
    assert r.compute_s == pytest.approx(0.5)
    assert r.memory_s == pytest.approx(4.0)
    assert r.collective_s == pytest.approx(5.0)
    assert r.dominant == "collective"
    assert r.useful_flops_ratio == pytest.approx(0.5)
    # fraction: model_flops is per-device, so ideal = 0.25 s; bound = 5 s
    assert r.roofline_fraction == pytest.approx(0.25 / 5.0)


def test_collectives_level_accounting():
    """all-reduce over (data,pod): ring bytes at data level, shard/16 at pod."""
    mesh512 = type("M", (), {})()  # fake mesh-like for sizes
    real = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("pod", "data", "model")

        class devices:
            shape = (2, 16, 16)

    events = {"all-reduce|data,pod|32": 1024.0 * 1024.0}
    stats = ha.collectives_from_events(events, FakeMesh)
    mb = 1024.0 * 1024.0
    want_ici = 2 * 15 / 16 * mb            # data level on the full tensor
    want_dcn = 2 * 1 / 2 * (mb / 16)       # pod level on the 1/16 shard
    assert stats.ici_bytes == pytest.approx(want_ici)
    assert stats.dcn_bytes == pytest.approx(want_dcn)
    assert stats.by_op["all-reduce"] == pytest.approx(want_ici + want_dcn)


def test_collectives_all_gather_output_sized():
    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)

    events = {"all-gather|model|16": 1e6}
    stats = ha.collectives_from_events(events, FakeMesh)
    assert stats.ici_bytes == pytest.approx(15 / 16 * 1e6)
    assert stats.dcn_bytes == 0.0


def test_model_flops_6nd():
    cfg = configs.get_config("phi4-mini-3.8b")
    n = cfg.active_param_count()
    train = ha.model_flops_for(cfg, shape_by_name("train_4k"))
    assert train == pytest.approx(6.0 * n * 256 * 4096)
    dec = ha.model_flops_for(cfg, shape_by_name("decode_32k"))
    assert dec == pytest.approx(2.0 * n * 128)
    # MoE uses ACTIVE params
    moe = configs.get_config("deepseek-v2-236b")
    t = ha.model_flops_for(moe, shape_by_name("train_4k"))
    assert t < 6.0 * moe.param_count() * 256 * 4096 * 0.2


def test_shape_bytes_parser():
    assert ha._shape_bytes("bf16[256,4096]") == 256 * 4096 * 2
    assert ha._shape_bytes("f32[10]") == 40
    assert ha._shape_bytes("pred[8]") == 8
    assert ha._shape_bytes("u8[3,3]") == 9
