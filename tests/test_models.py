"""Layer-level model units: RoPE, norms, attention vs naive oracle, MoE."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerSpec, ModelConfig, MoEConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.layers import (
    apply_rope, cross_entropy, lm_logits, rms_norm, softcap,
)

F32 = jnp.float32


def _mini_cfg(**kw) -> ModelConfig:
    base = dict(
        name="mini", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=128,
        vocab_pad_multiple=32, dtype="float32",
        pattern=(LayerSpec(),),
    )
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------


def test_rope_preserves_norm(rng):
    x = jnp.asarray(rng.standard_normal((2, 6, 4, 8)).astype(np.float32))
    y = apply_rope(x, jnp.arange(6), 10000.0)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5
    )


def test_rope_zero_position_is_identity(rng):
    x = jnp.asarray(rng.standard_normal((1, 1, 2, 8)).astype(np.float32))
    y = apply_rope(x, jnp.zeros((1,), jnp.int32), 10000.0)
    np.testing.assert_allclose(x, y, atol=1e-6)


def test_rope_relative_property(rng):
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 16)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 16)).astype(np.float32))

    def dot_at(i, j):
        qi = apply_rope(q, jnp.asarray([i]), 10000.0)
        kj = apply_rope(k, jnp.asarray([j]), 10000.0)
        return float(jnp.sum(qi * kj))

    assert dot_at(5, 3) == pytest.approx(dot_at(9, 7), rel=1e-4)
    assert dot_at(0, 0) == pytest.approx(dot_at(11, 11), rel=1e-4)


# ---------------------------------------------------------------------------
# Norm / softcap / CE.
# ---------------------------------------------------------------------------


def test_rms_norm_matches_manual(rng):
    x = jnp.asarray(rng.standard_normal((3, 5, 16)).astype(np.float32))
    g = jnp.asarray(rng.standard_normal(16).astype(np.float32))
    got = rms_norm(x, g, 1e-6)
    want = x / np.sqrt(np.mean(np.asarray(x) ** 2, -1, keepdims=True) + 1e-6) * (
        1 + np.asarray(g)
    )
    np.testing.assert_allclose(got, want, rtol=2e-5)


def test_softcap_bounded_and_monotone():
    x = jnp.linspace(-100, 100, 201)
    y = softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    assert np.all(np.diff(np.asarray(y)) >= 0)
    # no-op when cap == 0
    np.testing.assert_array_equal(softcap(x, 0.0), x)


def test_cross_entropy_matches_manual(rng):
    v = 16
    logits = jnp.asarray(rng.standard_normal((2, 3, v)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, v, (2, 3)).astype(np.int32))
    got = float(cross_entropy(logits, labels, v))
    lse = np.log(np.exp(np.asarray(logits)).sum(-1))
    picked = np.take_along_axis(np.asarray(logits), np.asarray(labels)[..., None], -1)[..., 0]
    want = float(np.mean(lse - picked))
    assert got == pytest.approx(want, rel=1e-5)


def test_lm_logits_masks_padded_vocab(rng):
    """Vocab padding rows must never receive probability mass."""
    cfg = _mini_cfg()
    table = jnp.asarray(rng.standard_normal((cfg.padded_vocab, cfg.d_model)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((1, 2, cfg.d_model)).astype(np.float32))
    logits = lm_logits(x, table, 0.0, cfg.vocab_size)
    assert logits.shape[-1] == cfg.padded_vocab
    pad = np.asarray(logits)[..., cfg.vocab_size:]
    assert np.all(pad < -1e9)


# ---------------------------------------------------------------------------
# Attention vs naive oracle.
# ---------------------------------------------------------------------------


def _naive_attention(q, k, v, *, n_kv, scale, window, cap):
    """Materialized causal (optionally windowed, softcapped) GQA attention."""
    b, s, h, hd = q.shape
    rep = h // n_kv
    kk = np.repeat(np.asarray(k), rep, axis=2)
    vv = np.repeat(np.asarray(v), rep, axis=2)
    scores = np.einsum("bqhk,bshk->bhqs", np.asarray(q) * scale, kk)
    if cap:
        scores = cap * np.tanh(scores / cap)
    i = np.arange(s)[:, None]
    j = np.arange(s)[None, :]
    mask = j <= i
    if window:
        mask &= j > i - window
    scores = np.where(mask[None, None], scores, -1e30)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    return np.einsum("bhqs,bshk->bqhk", p, vv)


@pytest.mark.parametrize("window,cap", [(0, 0.0), (4, 0.0), (0, 20.0), (4, 20.0)])
def test_attn_dense_matches_naive(window, cap, rng):
    cfg = _mini_cfg(attn_softcap=cap, window=window)
    key = jax.random.PRNGKey(0)
    p = attn.init_attn_params(key, cfg, F32)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)).astype(np.float32)) * 0.3
    got = attn.attn_dense(x, p, cfg, window=window, q_chunk=4, k_chunk=4)

    pos = jnp.arange(8)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = _naive_attention(q, k, v, n_kv=cfg.n_kv_heads,
                         scale=cfg.head_dim ** -0.5, window=window, cap=cap)
    want = np.einsum("bqhk,hkd->bqd", o, np.asarray(p["wo"]))
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-5, rtol=1e-4)


def test_attn_chunk_invariance(rng):
    """Different q/k chunkings produce identical outputs (flash combine)."""
    cfg = _mini_cfg()
    p = attn.init_attn_params(jax.random.PRNGKey(1), cfg, F32)
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)).astype(np.float32))
    outs = [
        attn.attn_dense(x, p, cfg, window=0, q_chunk=qc, k_chunk=kc)
        for qc, kc in [(16, 16), (4, 16), (16, 4), (8, 2), (2, 8)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=3e-6)


def test_gqa_equals_mha_when_kv_equals_heads(rng):
    """n_kv == n_heads reduces GQA to standard MHA."""
    cfg = _mini_cfg(n_kv_heads=4)
    p = attn.init_attn_params(jax.random.PRNGKey(2), cfg, F32)
    x = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)).astype(np.float32))
    got = attn.attn_dense(x, p, cfg, window=0, q_chunk=8, k_chunk=8)
    assert got.shape == (1, 8, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(got)))


def test_qk_norm_applied(rng):
    cfg = _mini_cfg(qk_norm=True)
    p = attn.init_attn_params(jax.random.PRNGKey(3), cfg, F32)
    assert "q_norm" in p and "k_norm" in p
    x = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)).astype(np.float32))
    out = attn.attn_dense(x, p, cfg, window=0, q_chunk=8, k_chunk=8)
    assert np.all(np.isfinite(np.asarray(out)))


# ---------------------------------------------------------------------------
# MoE vs dense-dispatch reference.
# ---------------------------------------------------------------------------


def _moe_cfg(cf=8.0):
    return _mini_cfg(
        family="moe",
        pattern=(LayerSpec(ffn="moe"),),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, n_shared=1,
                      capacity_factor=cf),
    )


def test_moe_matches_reference(rng):
    cfg = _moe_cfg()
    p = moe_mod.init_moe_params(jax.random.PRNGKey(4), cfg, F32)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)).astype(np.float32)) * 0.5
    got, aux = moe_mod.moe_apply(x, p, cfg, attn.ShardingPolicy(), token_chunk=16)
    want = moe_mod.moe_ref(x, p, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-4)
    assert float(aux.load_balance) >= 0.0


def test_moe_chunk_invariance(rng):
    cfg = _moe_cfg()
    p = moe_mod.init_moe_params(jax.random.PRNGKey(5), cfg, F32)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)).astype(np.float32))
    o1, _ = moe_mod.moe_apply(x, p, cfg, attn.ShardingPolicy(), token_chunk=16)
    o2, _ = moe_mod.moe_apply(x, p, cfg, attn.ShardingPolicy(), token_chunk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_moe_capacity_drops_tokens(rng):
    """Tiny capacity factor must drop tokens (outputs differ from cf=8)."""
    cfg_hi, cfg_lo = _moe_cfg(8.0), _moe_cfg(0.25)
    p = moe_mod.init_moe_params(jax.random.PRNGKey(6), cfg_hi, F32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg_hi.d_model)).astype(np.float32))
    hi, _ = moe_mod.moe_apply(x, p, cfg_hi, attn.ShardingPolicy(), token_chunk=32)
    lo, _ = moe_mod.moe_apply(x, p, cfg_lo, attn.ShardingPolicy(), token_chunk=32)
    assert not np.allclose(np.asarray(hi), np.asarray(lo))


def test_moe_router_weights_normalized(rng):
    """Top-k router weights are a distribution over the selected experts."""
    cfg = _moe_cfg()
    p = moe_mod.init_moe_params(jax.random.PRNGKey(7), cfg, F32)
    x = jnp.asarray(rng.standard_normal((1, 4, cfg.d_model)).astype(np.float32))
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    w = jax.nn.softmax(logits, axis=-1)
    topw, _ = jax.lax.top_k(w, cfg.moe.top_k)
    assert np.all(np.asarray(topw.sum(-1)) <= 1.0 + 1e-6)
