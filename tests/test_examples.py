"""Smoke tests for the runnable examples' main paths.

The examples are the repo's front door and were previously untested — a
refactor could silently rot them.  Each runs in a subprocess (they set
their own XLA device-count flags before importing jax) on a reduced step
budget where the example supports one.
"""

import os
import subprocess
import sys

from conftest import REPO, SRC

EXAMPLES = os.path.join(REPO, "examples")


def run_example(name: str, *, env_extra: dict | None = None,
                timeout: int = 420) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.update(env_extra or {})
    proc = subprocess.run(
        [sys.executable, os.path.join(EXAMPLES, name)],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert proc.returncode == 0, (
        f"example {name} failed (rc={proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}"
    )
    return proc.stdout


def test_wordcount_switchagg_example():
    out = run_example("wordcount_switchagg.py")
    assert "word counts exact: True" in out
    assert "counts exact: True" in out  # the lossy rerun stays exact
    # the packet simulator's Fig. 10 claim: host-only vs switchagg JCT
    assert "simulated job-completion-time" in out
    saved = next(l for l in out.splitlines() if l.startswith("  JCT saved:"))
    pct = int(saved.split("JCT saved:")[1].split("%")[0].strip())
    assert pct >= 40, saved


def test_wordcount_rackscale_example():
    # the rack-scale variant (DESIGN.md §9): 128 mappers across a 4-pod
    # oversubscribed fat-tree, three placements of the same Zipf stream
    out = run_example("wordcount_rackscale.py")
    assert out.count("counts exact: True") == 3  # every placement is exact
    assert "JCT ordering full-tree <= ToR-only <= host-only: True" in out
    cut = next(l for l in out.splitlines()
               if l.startswith("full-tree cuts scarce-uplink bytes"))
    pct = int(cut.split("bytes")[1].split("%")[0].strip())
    assert pct >= 30, cut
    saved = next(l for l in out.splitlines()
                 if l.startswith("rack-scale JCT saved"))
    assert int(saved.split(":")[1].split("%")[0].strip()) >= 40, saved


def test_quickstart_example():
    out = run_example("quickstart.py", env_extra={"QUICKSTART_STEPS": "6"})
    assert "training 6 steps" in out
    assert "final loss" in out
    assert "greedy continuation:" in out
