"""Batched-block FPE fast path — deterministic coverage (DESIGN.md §8).

The fast path's contract is SEMANTIC equivalence with the scan oracle:
for any stream, block split, and registered AggOp, grouping (flush +
evictions) by key gives the exact input combine — while the eviction
*pattern* is free to differ.  This module checks that contract over
seeded sweeps, pins the resident-table invariants the closed form relies
on, and asserts the shape-stable streaming ingest compiles O(1) traces.
Hypothesis generalizations live in tests/test_fpe_fast_properties.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggops, dataplane, kvagg
from repro.core.dataplane import CascadePlan, LevelSpec

EMPTY = int(kvagg.EMPTY_KEY)


def _grouped(keys, values, op):
    """Grouped-combine of a carried-value stream -> {key: np value}."""
    c = kvagg.sorted_combine(jnp.asarray(keys), jnp.asarray(values), op=op)
    nu = int(c.n_unique)
    ks = np.asarray(c.unique_keys)[:nu]
    vs = np.asarray(c.combined_values)[:nu]
    return {int(k): vs[i] for i, k in enumerate(ks)}


def _assert_same_grouped(got, want, op):
    assert got.keys() == want.keys(), f"{op}: key set mismatch"
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5,
                                   err_msg=f"op={op} key={k}")


def _fast_stream_grouped(keys, carried, *, capacity, ways, op, n_blocks):
    """Run the fast path over n_blocks chunks of a persistent table and
    return the grouped-combine of (final flush + all evictions)."""
    tk = tv = None
    out_k = []
    out_v = []
    for ck, cv in zip(np.array_split(keys, n_blocks),
                      np.array_split(carried, n_blocks)):
        if ck.shape[0] == 0:
            continue
        res = kvagg.fpe_aggregate(
            jnp.asarray(ck), jnp.asarray(cv), capacity=capacity, ways=ways,
            op=op, exact_stream=False, table_keys=tk, table_values=tv)
        tk, tv = res.table_keys, res.table_values
        out_k.append(np.asarray(res.evict_keys))
        out_v.append(np.asarray(res.evict_values))
    return _grouped(np.concatenate([np.asarray(tk)] + out_k),
                    np.concatenate([np.asarray(tv)] + out_v), op)


def assert_table_invariants(table_keys, *, capacity, ways):
    """Bucketing, front-contiguity, and uniqueness of a resident table."""
    w = max(1, min(ways, capacity))
    nb = max(1, capacity // w)
    tk = np.asarray(table_keys).reshape(nb, w)
    nonempty = tk != EMPTY
    for b in range(nb):
        r_b = int(nonempty[b].sum())
        assert nonempty[b, :r_b].all() and not nonempty[b, r_b:].any(), \
            f"bucket {b} not front-contiguous: {tk[b]}"
        for k in tk[b, :r_b]:
            assert int(aggops.hash_key(jnp.int32(k), nb)) == b, \
                f"key {k} stored outside its bucket {b}"
    resident = tk[nonempty]
    assert len(set(resident.tolist())) == resident.shape[0], \
        "a key is resident twice"


@pytest.mark.parametrize("op", sorted(aggops.names()))
@pytest.mark.parametrize("capacity,ways,n_blocks", [
    (1, 1, 1), (4, 2, 2), (16, 4, 1), (16, 4, 3), (64, 4, 2),
])
def test_fast_path_equals_scan_grouped_combine(op, capacity, ways, n_blocks):
    """(flush + evictions) grouped by key: fast path == scan oracle, for
    every registered op, across block splits and table geometries."""
    r = np.random.default_rng(capacity * 7 + ways)
    n = 200
    keys = r.integers(0, 48, size=n).astype(np.int32)
    raw = r.integers(-8, 8, size=n).astype(np.float32)
    carried = np.asarray(aggops.get(op).prepare_values(jnp.asarray(raw)))
    scan = kvagg.fpe_aggregate(
        jnp.asarray(keys), jnp.asarray(carried), capacity=capacity,
        ways=ways, op=op, exact_stream=True)
    want = _grouped(np.concatenate([scan.table_keys, scan.evict_keys]),
                    np.concatenate([scan.table_values, scan.evict_values]),
                    op)
    got = _fast_stream_grouped(keys, carried, capacity=capacity, ways=ways,
                               op=op, n_blocks=n_blocks)
    _assert_same_grouped(got, want, op)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_fast_path_table_invariants(seed):
    r = np.random.default_rng(seed)
    n = 100 + 50 * seed
    keys = jnp.asarray(r.integers(0, 20 + 30 * seed, size=n)
                       .astype(np.int32))
    vals = jnp.asarray(r.standard_normal(n).astype(np.float32))
    capacity, ways = [(1, 1), (8, 2), (64, 4), (16, 16)][seed]
    res = kvagg.fpe_aggregate(keys, vals, capacity=capacity, ways=ways,
                              op="sum", exact_stream=False)
    assert_table_invariants(res.table_keys, capacity=capacity, ways=ways)


@pytest.mark.parametrize("op", ["sum", "mean"])
def test_fast_path_padded_stream(op, rng):
    """EMPTY_KEY padding must be skipped without touching totals."""
    keys = rng.integers(0, 12, size=160).astype(np.int32)
    mask = rng.random(160) < 0.3
    keys = np.where(mask, EMPTY, keys).astype(np.int32)
    raw = rng.standard_normal(160).astype(np.float32)
    carried = np.asarray(aggops.get(op).prepare_values(jnp.asarray(raw)))
    res = kvagg.fpe_aggregate(jnp.asarray(keys), jnp.asarray(carried),
                              capacity=16, ways=4, op=op, exact_stream=False)
    got = _grouped(np.concatenate([res.table_keys, res.evict_keys]),
                   np.concatenate([res.table_values, res.evict_values]), op)
    want = _grouped(keys, carried, op)
    _assert_same_grouped(got, want, op)
    assert EMPTY not in got


def test_fast_path_all_padding():
    res = kvagg.fpe_aggregate(jnp.full((8,), EMPTY, jnp.int32),
                              jnp.zeros((8,), jnp.float32),
                              capacity=4, ways=2, op="sum",
                              exact_stream=False)
    assert np.all(np.asarray(res.table_keys) == EMPTY)
    assert np.all(np.asarray(res.evict_keys) == EMPTY)


def test_two_level_fast_path_exactness(rng):
    """two_level_aggregate(exact_stream=False) keeps the node invariant."""
    keys = jnp.asarray(rng.integers(0, 48, size=256).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    res = kvagg.two_level_aggregate(keys, vals, capacity=16, ways=4,
                                    exact_stream=False)
    got = _grouped(res.out_keys, res.out_values, "sum")
    want = _grouped(keys, vals, "sum")
    _assert_same_grouped(got, want, "sum")
    assert int(res.n_in) == 256
    assert int(res.n_out) == int(np.sum(np.asarray(res.out_keys) != EMPTY))


@pytest.mark.parametrize("op", sorted(aggops.names()))
def test_cascade_fast_path_every_op(op, rng):
    """run_cascade(exact_stream=False) finalized output == exact combine
    for every registered op over a multi-level plan."""
    from conftest import dict_aggregate

    keys = jnp.asarray(rng.integers(0, 64, size=300).astype(np.int32))
    vals = jnp.asarray(rng.integers(-8, 8, size=300).astype(np.float32))
    plan = CascadePlan(op=op, levels=(LevelSpec(32, ways=4),
                                      LevelSpec(16, ways=2)))
    res = dataplane.run_cascade(keys, vals, plan, exact_stream=False)
    got = {int(k): float(v) for k, v in
           zip(np.asarray(res.keys), np.asarray(res.values)) if k != EMPTY}
    want = dict_aggregate(keys, vals, op=op)
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4, atol=1e-5)


def test_stream_fast_path_matches_monolithic(rng):
    """run_cascade_stream(exact_stream=False) over packets == run_cascade
    grouped result (multi-lane op to cover carried lanes end to end)."""
    keys = rng.integers(0, 40, size=400).astype(np.int32)
    vals = rng.standard_normal(400).astype(np.float32)
    plan = CascadePlan(op="mean", levels=(LevelSpec(16, ways=4),))
    batches = [(keys[i:i + 37], vals[i:i + 37])
               for i in range(0, 400, 37)]
    res = dataplane.run_cascade_stream(batches, plan, exact_stream=False)
    mono = dataplane.run_cascade(jnp.asarray(keys), jnp.asarray(vals), plan)
    got = {int(k): float(v) for k, v in
           zip(np.asarray(res.keys), np.asarray(res.values)) if k != EMPTY}
    want = {int(k): float(v) for k, v in
            zip(np.asarray(mono.keys), np.asarray(mono.values)) if k != EMPTY}
    assert got.keys() == want.keys()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-5, atol=1e-6)


def test_stream_ingest_is_shape_stable():
    """Varying packet lengths must reuse O(log max_len) compiled FPE
    traces (pow2 size buckets), not one trace per distinct length."""
    r = np.random.default_rng(3)
    lengths = sorted(set(r.integers(1, 200, size=50).tolist()))
    assert len(lengths) > 20  # the test only bites with many lengths
    batches = [(r.integers(0, 64, size=n).astype(np.int32),
                np.ones(n, np.float32)) for n in lengths]
    plan = CascadePlan(op="sum", levels=(LevelSpec(16, ways=4),))
    before = kvagg.fpe_aggregate._cache_size()
    res = dataplane.run_cascade_stream(batches, plan)
    grew = kvagg.fpe_aggregate._cache_size() - before
    # pow2 buckets for 1..200 with the MIN_PAD=8 floor: 8..256 -> 6 sizes,
    # +1 for the very first ingest (table_keys=None vs resumed signature)
    assert grew <= 7, f"{grew} FPE traces for {len(lengths)} packet lengths"
    assert int(res.n_in) == sum(lengths)


def test_sim_fast_path_delivers_same_totals():
    """The packet simulator with exact_stream=False delivers the same
    application table as the paper-faithful default."""
    from repro.net import sim, simulate

    r = np.random.default_rng(5)
    keys = r.integers(0, 64, size=256).astype(np.int32)
    vals = np.ones(256, np.float32)
    plan = CascadePlan(op="sum", levels=(LevelSpec(32, ways=4),
                                         LevelSpec(32, ways=4)))
    exact = simulate(sim.JobSpec(keys=keys, values=vals, fanins=(2, 2),
                                 plan=plan))
    fast = simulate(sim.JobSpec(keys=keys, values=vals, fanins=(2, 2),
                                plan=plan,
                                cfg=sim.NetConfig(exact_stream=False)))
    assert exact.delivered_table() == fast.delivered_table()
    assert fast.jct_s > 0


def test_sorted_combine_int32max_key_legal():
    """No sentinel remap: INT32_MAX stays a legal, distinct key."""
    imax = np.iinfo(np.int32).max
    keys = jnp.asarray([imax, imax, 5, EMPTY], jnp.int32)
    vals = jnp.asarray([1.0, 2.0, 3.0, 0.0], jnp.float32)
    c = kvagg.sorted_combine(keys, vals)
    assert int(c.n_unique) == 2
    uk = np.asarray(c.unique_keys)
    assert uk[0] == 5 and uk[1] == imax
    np.testing.assert_allclose(np.asarray(c.combined_values)[:2], [3.0, 3.0])
